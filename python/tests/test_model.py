"""L2 model tests: fused step vs oracle, loss branch structure, shapes."""

import numpy as np
import pytest

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp

from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def _data(seed, d, n, scale=1.0):
    rng = np.random.default_rng(seed)
    m = rng.normal(size=(d, d))
    mat = (m + m.T) / 2 * scale
    a = rng.normal(size=(n, d)) * scale
    b = rng.normal(size=(n, d)) * scale
    return jnp.array(mat), jnp.array(a), jnp.array(b)


# ------------------------------------------------------------ fused step

@pytest.mark.parametrize("d", [2, 5, 19])
@pytest.mark.parametrize("gamma", [0.01, 0.05, 0.5, 1.0])
def test_fused_step_matches_ref(d, gamma):
    mat, a, b = _data(d, d, 128)
    mask = jnp.ones(128)
    got = model.fused_step(mat, a, b, mask, jnp.float64(gamma), block=64)
    want = ref.fused_step_ref(mat, a, b, mask, gamma)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=1e-11, atol=1e-11)


def test_fused_step_mask_removes_padding():
    """Padded rows (mask 0) must not contribute to loss or gradient."""
    mat, a, b = _data(1, 6, 128)
    mask_full = jnp.ones(128)
    # zero out tail and compare against the truncated computation
    mask = mask_full.at[96:].set(0.0)
    loss_m, g_m, _ = model.fused_step(mat, a, b, mask, jnp.float64(0.05), block=32)
    loss_t, g_t, _ = ref.fused_step_ref(mat, a[:96], b[:96], jnp.ones(96), 0.05)
    np.testing.assert_allclose(loss_m, loss_t, rtol=1e-12)
    np.testing.assert_allclose(g_m, g_t, rtol=1e-11, atol=1e-11)


def test_fused_step_zero_matrix():
    """M = 0 -> every margin 0 -> loss = n*(1 - gamma/2), alpha = 1."""
    d, n, gamma = 4, 64, 0.05
    _, a, b = _data(2, d, n)
    loss, g, m = model.fused_step(
        jnp.zeros((d, d)), a, b, jnp.ones(n), jnp.float64(gamma), block=64
    )
    np.testing.assert_allclose(loss, n * (1 - gamma / 2), rtol=1e-12)
    np.testing.assert_allclose(m, np.zeros(n), atol=0)
    want_g = ref.wgram_ref(a, b, jnp.ones(n))
    np.testing.assert_allclose(g, want_g, rtol=1e-11, atol=1e-11)


def test_gradient_matches_jax_autodiff():
    """grad_loss_sum from the kernel == autodiff of the loss wrt M.

    d/dM sum_t l(<M,H_t>) = sum_t l'(m_t) H_t = -sum_t alpha_t H_t,
    so autodiff(loss) must equal -(our grad output).
    """
    d, n, gamma = 5, 64, 0.1
    mat, a, b = _data(3, d, n)

    def loss_fn(mm):
        m = ref.margins_ref(mm, a, b)
        return jnp.sum(ref.smoothed_hinge(m, gamma))

    auto = jax.grad(loss_fn)(mat)
    _, g, _ = model.fused_step(mat, a, b, jnp.ones(n), jnp.float64(gamma), block=64)
    np.testing.assert_allclose(auto, -g, rtol=1e-9, atol=1e-9)


# --------------------------------------------------------- loss structure

def test_smoothed_hinge_branches():
    gamma = 0.05
    m = jnp.array([2.0, 1.0 + 1e-9, 1.0, 1.0 - gamma / 2, 1.0 - gamma, 0.0, -3.0])
    l = ref.smoothed_hinge(m, gamma)
    assert float(l[0]) == 0.0 and float(l[1]) == 0.0
    np.testing.assert_allclose(float(l[2]), 0.0, atol=1e-15)
    np.testing.assert_allclose(float(l[3]), (gamma / 2) ** 2 / (2 * gamma))
    np.testing.assert_allclose(float(l[4]), gamma / 2)
    np.testing.assert_allclose(float(l[5]), 1 - gamma / 2)
    np.testing.assert_allclose(float(l[6]), 4 - gamma / 2)


def test_smoothed_hinge_alpha_branches():
    gamma = 0.05
    m = jnp.array([2.0, 1.0, 1.0 - gamma / 2, 1.0 - gamma, -1.0])
    a = ref.smoothed_hinge_alpha(m, gamma)
    np.testing.assert_allclose(np.asarray(a), [0.0, 0.0, 0.5, 1.0, 1.0], atol=1e-15)


def test_smoothed_hinge_is_convex_and_decreasing():
    gamma = 0.05
    xs = jnp.linspace(-2, 2, 401)
    l = np.asarray(ref.smoothed_hinge(xs, gamma))
    assert np.all(np.diff(l) <= 1e-15)  # non-increasing
    assert np.all(np.diff(l, 2) >= -1e-12)  # convex


@settings(max_examples=20, deadline=None)
@given(
    gamma=st.floats(1e-3, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_alpha_in_unit_interval(gamma, seed):
    rng = np.random.default_rng(seed)
    m = jnp.array(rng.normal(scale=3.0, size=256))
    a = np.asarray(ref.smoothed_hinge_alpha(m, gamma))
    assert np.all(a >= 0.0) and np.all(a <= 1.0)


def test_fenchel_young_equality_on_derivative():
    """l(m) + l*(-alpha) == -alpha*m when alpha = -l'(m) (KKT eq. (3))."""
    gamma = 0.05
    m = jnp.linspace(-2, 2, 101)
    alpha = ref.smoothed_hinge_alpha(m, gamma)
    lstar = gamma / 2 * alpha**2 - alpha  # conjugate from Appendix A
    lhs = ref.smoothed_hinge(m, gamma) + lstar
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(-alpha * m), atol=1e-12)
