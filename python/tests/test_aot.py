"""AOT emission tests: HLO text round-trips through the xla_client parser
and executes to the same numbers as the live-jitted function.

This is the python half of the interchange contract; the rust half is
tested in rust/tests/runtime_pjrt.rs against the same artifacts.
"""

import numpy as np
import pytest

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp

from jax._src.lib import xla_client as xc

from compile import aot, model
from compile.kernels import ref


@pytest.mark.parametrize("entry", ["margins", "wgram", "step"])
def test_hlo_text_emitted_and_parseable(entry):
    d, n, block = 7, 128, 64
    text = aot.lower_entry(entry, d, n, block)
    assert text.startswith("HloModule")
    assert f"f64[{n},{d}]" in text
    # The entry layout records the tuple return.
    assert "entry_computation_layout" in text


def test_hlo_text_no_custom_calls():
    """interpret=True must not leak Mosaic/lapack custom-calls into the HLO —
    those would be unloadable by the rust CPU PJRT client."""
    for entry in ["margins", "wgram", "step"]:
        text = aot.lower_entry(entry, 5, 64, 32)
        assert "custom-call" not in text, f"{entry} contains a custom-call"


def test_step_artifact_numbers_match_live_jit():
    """Execute the lowered module via jax's own CPU client and compare."""
    d, n, block = 6, 128, 64
    rng = np.random.default_rng(17)
    mat = rng.normal(size=(d, d))
    mat = (mat + mat.T) / 2
    a = rng.normal(size=(n, d))
    b = rng.normal(size=(n, d))
    mask = np.ones(n)
    gamma = 0.05

    fn, _ = model.entry_step(d, n, block=block)
    live = jax.jit(fn)(mat, a, b, mask, gamma)

    text = aot.lower_entry("step", d, n, block)
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(jax.jit(fn).lower(*(jnp.array(x) for x in (mat, a, b, mask, gamma))).compiler_ir("stablehlo")),
        use_tuple_args=False,
        return_tuple=True,
    )
    # Structural sanity: same entry layout line (instruction names differ
    # run-to-run, so exact text equality is not required).
    assert text.splitlines()[0].split(",", 1)[1] == comp.as_hlo_text().splitlines()[0].split(",", 1)[1]

    want = ref.fused_step_ref(jnp.array(mat), jnp.array(a), jnp.array(b), jnp.array(mask), gamma)
    for l, w in zip(live, want):
        np.testing.assert_allclose(l, w, rtol=1e-11, atol=1e-11)


def test_manifest_schema(tmp_path):
    """aot.main writes artifacts + manifest for a tiny config."""
    import json
    import sys

    argv = sys.argv
    sys.argv = [
        "aot",
        "--out",
        str(tmp_path),
        "--dims",
        "3",
        "--n",
        "64",
        "--block",
        "32",
        "--entries",
        "margins",
    ]
    try:
        aot.main()
    finally:
        sys.argv = argv
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["dispatch_n"] == 64
    assert manifest["artifacts"] == [
        {"entry": "margins", "d": 3, "n": 64, "file": "margins_d3_b64.hlo.txt"}
    ]
    text = (tmp_path / "margins_d3_b64.hlo.txt").read_text()
    assert text.startswith("HloModule")
