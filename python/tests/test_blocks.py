"""Block-size invariance and dispatch-shape coverage for the L1 kernels:
the Pallas grid decomposition must be semantically invisible, across every
block size the AOT pipeline can emit.
"""

import numpy as np
import pytest

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp

from compile import aot, model
from compile.kernels import ref, triplet_margins, weighted_gram


def _data(seed, n, d):
    rng = np.random.default_rng(seed)
    m = rng.normal(size=(d, d))
    return (
        jnp.array((m + m.T) / 2),
        jnp.array(rng.normal(size=(n, d))),
        jnp.array(rng.normal(size=(n, d))),
        jnp.array(rng.uniform(size=n)),
    )


@pytest.mark.parametrize("block", [32, 64, 128, 256, 512])
def test_margins_block_invariance(block):
    mat, a, b, _ = _data(1, 512, 11)
    got = triplet_margins(mat, a, b, block=block)
    want = ref.margins_ref(mat, a, b)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("block", [32, 128, 512])
def test_wgram_block_invariance(block):
    _, a, b, w = _data(2, 512, 9)
    got = weighted_gram(a, b, w, block=block)
    want = ref.wgram_ref(a, b, w)
    np.testing.assert_allclose(got, want, rtol=1e-11, atol=1e-11)


def test_blocks_produce_identical_results_to_each_other():
    mat, a, b, _ = _data(3, 1024, 7)
    m1 = triplet_margins(mat, a, b, block=64)
    m2 = triplet_margins(mat, a, b, block=512)
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))


@pytest.mark.parametrize("entry", ["margins", "wgram", "step"])
@pytest.mark.parametrize("n,block", [(64, 32), (1024, 256)])
def test_aot_lowering_every_entry_and_shape(entry, n, block):
    text = aot.lower_entry(entry, 6, n, block)
    assert text.startswith("HloModule")
    assert "custom-call" not in text


def test_default_dims_cover_experiment_datasets():
    # every analogue dimension used by the rust experiment suite must have
    # a default artifact dim, or the PJRT engine would silently fall back
    needed = {4, 13, 16, 19, 32, 36, 68, 100, 200}
    assert needed.issubset(set(aot.DEFAULT_DIMS))


def test_dispatch_n_is_block_multiple():
    assert aot.DISPATCH_N % 512 == 0


def test_step_gamma_runtime_parameter():
    """gamma enters as a runtime scalar: same jitted fn, different gamma,
    different losses — no retrace requirement baked into the artifact."""
    mat, a, b, _ = _data(4, 128, 5)
    mask = jnp.ones(128)
    fn, _ = model.entry_step(5, 128, block=64)
    jfn = jax.jit(fn)
    l1, _, _ = jfn(mat, a, b, mask, jnp.float64(0.05))
    l2, _, _ = jfn(mat, a, b, mask, jnp.float64(0.5))
    assert not np.allclose(float(l1), float(l2))
    w1 = ref.fused_step_ref(mat, a, b, mask, 0.05)[0]
    np.testing.assert_allclose(float(l1), float(w1), rtol=1e-11)
