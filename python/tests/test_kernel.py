"""L1 kernel correctness: Pallas (interpret=True) vs the pure-jnp oracle.

This is the CORE correctness signal for the compiled artifacts — every HLO
module the rust coordinator executes is lowered from exactly these
functions. Hypothesis sweeps shapes/dtypes/value scales.
"""

import numpy as np
import pytest

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp

from hypothesis import given, settings, strategies as st

from compile.kernels import triplet_margins, weighted_gram, ref


def rand(rng, *shape, scale=1.0, dtype=np.float64):
    return (rng.normal(size=shape) * scale).astype(dtype)


def sym(rng, d, dtype=np.float64):
    m = rng.normal(size=(d, d))
    return ((m + m.T) / 2).astype(dtype)


# ---------------------------------------------------------------- margins

@pytest.mark.parametrize("d", [1, 2, 3, 4, 7, 19, 33, 64])
@pytest.mark.parametrize("blocks", [1, 2, 5])
def test_margins_matches_ref(d, blocks):
    rng = np.random.default_rng(d * 100 + blocks)
    n = 64 * blocks
    mat, a, b = sym(rng, d), rand(rng, n, d), rand(rng, n, d)
    got = triplet_margins(jnp.array(mat), jnp.array(a), jnp.array(b), block=64)
    want = ref.margins_ref(jnp.array(mat), jnp.array(a), jnp.array(b))
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


def test_margins_matches_explicit_h():
    rng = np.random.default_rng(7)
    d, n = 5, 32
    mat, a, b = sym(rng, d), rand(rng, n, d), rand(rng, n, d)
    got = triplet_margins(jnp.array(mat), jnp.array(a), jnp.array(b), block=32)
    want = ref.margins_ref_explicit(jnp.array(mat), jnp.array(a), jnp.array(b))
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


def test_margins_identity_matrix_is_norm_difference():
    """<I, H_t> = ||a||^2 - ||b||^2."""
    rng = np.random.default_rng(3)
    d, n = 8, 128
    a, b = rand(rng, n, d), rand(rng, n, d)
    got = triplet_margins(jnp.eye(d, dtype=jnp.float64), jnp.array(a), jnp.array(b), block=128)
    want = (a * a).sum(1) - (b * b).sum(1)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


def test_margins_rejects_ragged_n():
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError):
        triplet_margins(
            jnp.eye(3, dtype=jnp.float64),
            jnp.array(rand(rng, 65, 3)),
            jnp.array(rand(rng, 65, 3)),
            block=64,
        )


def test_margins_psd_matrix_nonneg_when_b_zero():
    """a^T M a >= 0 for PSD M: screening geometry sanity."""
    rng = np.random.default_rng(11)
    d, n = 6, 64
    r = rng.normal(size=(d, d))
    mat = r @ r.T
    a = rand(rng, n, d)
    b = np.zeros((n, d))
    got = triplet_margins(jnp.array(mat), jnp.array(a), jnp.array(b), block=64)
    assert np.all(np.asarray(got) >= -1e-12)


@settings(max_examples=25, deadline=None)
@given(
    d=st.integers(1, 24),
    blocks=st.integers(1, 3),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
    seed=st.integers(0, 2**31 - 1),
)
def test_margins_hypothesis_sweep(d, blocks, scale, seed):
    rng = np.random.default_rng(seed)
    n = 32 * blocks
    mat = sym(rng, d) * scale
    a, b = rand(rng, n, d, scale=scale), rand(rng, n, d, scale=scale)
    got = triplet_margins(jnp.array(mat), jnp.array(a), jnp.array(b), block=32)
    want = ref.margins_ref(jnp.array(mat), jnp.array(a), jnp.array(b))
    np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-10 * scale**3)


# ----------------------------------------------------------------- wgram

@pytest.mark.parametrize("d", [1, 2, 5, 19, 40])
@pytest.mark.parametrize("blocks", [1, 3])
def test_wgram_matches_ref(d, blocks):
    rng = np.random.default_rng(d + blocks)
    n = 64 * blocks
    a, b, w = rand(rng, n, d), rand(rng, n, d), rng.uniform(size=n)
    got = weighted_gram(jnp.array(a), jnp.array(b), jnp.array(w), block=64)
    want = ref.wgram_ref(jnp.array(a), jnp.array(b), jnp.array(w))
    np.testing.assert_allclose(got, want, rtol=1e-11, atol=1e-11)


def test_wgram_zero_weights_vanish():
    rng = np.random.default_rng(5)
    d, n = 7, 128
    a, b = rand(rng, n, d), rand(rng, n, d)
    got = weighted_gram(jnp.array(a), jnp.array(b), jnp.zeros(n), block=64)
    np.testing.assert_allclose(got, np.zeros((d, d)), atol=0)


def test_wgram_is_symmetric():
    rng = np.random.default_rng(9)
    d, n = 12, 256
    a, b, w = rand(rng, n, d), rand(rng, n, d), rng.uniform(size=n)
    got = np.asarray(weighted_gram(jnp.array(a), jnp.array(b), jnp.array(w), block=128))
    np.testing.assert_allclose(got, got.T, rtol=1e-12, atol=1e-12)


def test_wgram_linearity_in_w():
    rng = np.random.default_rng(13)
    d, n = 4, 64
    a, b = rand(rng, n, d), rand(rng, n, d)
    w1, w2 = rng.uniform(size=n), rng.uniform(size=n)
    g1 = weighted_gram(jnp.array(a), jnp.array(b), jnp.array(w1), block=64)
    g2 = weighted_gram(jnp.array(a), jnp.array(b), jnp.array(w2), block=64)
    g12 = weighted_gram(jnp.array(a), jnp.array(b), jnp.array(w1 + w2), block=64)
    np.testing.assert_allclose(g12, g1 + g2, rtol=1e-11, atol=1e-11)


@settings(max_examples=20, deadline=None)
@given(d=st.integers(1, 16), blocks=st.integers(1, 3), seed=st.integers(0, 2**31 - 1))
def test_wgram_hypothesis_sweep(d, blocks, seed):
    rng = np.random.default_rng(seed)
    n = 32 * blocks
    a, b = rand(rng, n, d), rand(rng, n, d)
    w = rng.uniform(-1, 1, size=n)
    got = weighted_gram(jnp.array(a), jnp.array(b), jnp.array(w), block=32)
    want = ref.wgram_ref(jnp.array(a), jnp.array(b), jnp.array(w))
    np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-10)


# ---------------------------------------------------- margin/wgram duality

def test_margin_wgram_adjointness():
    """<wgram(w), M> == w . margins(M): the two kernels are adjoint maps.

    This identity is what lets the coordinator reuse margins(Q) as <H_t,Q>
    in the screening rules (paper §3.3).
    """
    rng = np.random.default_rng(21)
    d, n = 9, 128
    mat, a, b = sym(rng, d), rand(rng, n, d), rand(rng, n, d)
    w = rng.uniform(size=n)
    m = triplet_margins(jnp.array(mat), jnp.array(a), jnp.array(b), block=64)
    g = weighted_gram(jnp.array(a), jnp.array(b), jnp.array(w), block=64)
    lhs = float(jnp.sum(jnp.array(mat) * g))
    rhs = float(jnp.dot(jnp.array(w), m))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-11)
