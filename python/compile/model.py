"""Layer-2: the RTLM compute graph, composed from the L1 Pallas kernels.

Three exported entry points (all f64; the rust coordinator owns the solver
state and the regularization term, so lambda never appears here):

  margins(mat, a, b)            -> m[n]             (objective & screening)
  wgram(a, b, w)                -> G[d,d]           (sum_t w_t H_t)
  fused_step(mat, a, b, mask, gamma) -> (loss_sum, grad_loss_sum, margins)

``fused_step`` fuses margin computation, the smoothed-hinge loss/derivative
and the gradient accumulation into a single HLO module so the rust hot loop
pays one PJRT dispatch per triplet block instead of three.

The smoothed hinge here must match ``rust/src/loss/`` bit-for-bit in
branch structure:

    l(m)  = 0                     m > 1
          = (1-m)^2 / (2 gamma)   1-gamma <= m <= 1
          = 1 - m - gamma/2       m < 1-gamma
    alpha = -l'(m) = clip((1-m)/gamma, 0, 1)

gamma is a runtime scalar input (not baked) so one artifact serves every
loss configuration; the hinge loss is the gamma->0 limit and is handled on
the rust side natively (alpha is set-valued at the kink).
"""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from .kernels import triplet_margins, weighted_gram, DEFAULT_BLOCK
from .kernels import ref


def margins(mat, a, b, *, block=DEFAULT_BLOCK, interpret=True):
    """<M, H_t> for every triplet row; serves <H_t, Q> for screening too."""
    return triplet_margins(mat, a, b, block=block, interpret=interpret)


def wgram(a, b, w, *, block=DEFAULT_BLOCK, interpret=True):
    """sum_t w_t H_t as A^T diag(w) A - B^T diag(w) B."""
    return weighted_gram(a, b, w, block=block, interpret=interpret)


def fused_step(mat, a, b, mask, gamma, *, block=DEFAULT_BLOCK, interpret=True):
    """One objective/gradient evaluation over a (padded) triplet block.

    Returns (loss_sum, grad_loss_sum, margins): the rust side forms
      P_lambda      = loss_sum + lambda/2 ||M||_F^2   (+ screened-L terms)
      grad P_lambda = -grad_loss_sum + lambda M       (+ screened-L terms)
    Padded tail rows must carry mask=0.
    """
    m = triplet_margins(mat, a, b, block=block, interpret=interpret)
    loss = jnp.sum(ref.smoothed_hinge(m, gamma) * mask)
    alpha = ref.smoothed_hinge_alpha(m, gamma) * mask
    g = weighted_gram(a, b, alpha, block=block, interpret=interpret)
    return loss, g, m


def entry_margins(d, n, block=DEFAULT_BLOCK):
    """Build the jittable margins entry point and its example args."""

    def fn(mat, a, b):
        return (margins(mat, a, b, block=block),)

    spec = jax.ShapeDtypeStruct
    args = (
        spec((d, d), jnp.float64),
        spec((n, d), jnp.float64),
        spec((n, d), jnp.float64),
    )
    return fn, args


def entry_wgram(d, n, block=DEFAULT_BLOCK):
    def fn(a, b, w):
        return (wgram(a, b, w, block=block),)

    spec = jax.ShapeDtypeStruct
    args = (
        spec((n, d), jnp.float64),
        spec((n, d), jnp.float64),
        spec((n,), jnp.float64),
    )
    return fn, args


def entry_step(d, n, block=DEFAULT_BLOCK):
    def fn(mat, a, b, mask, gamma):
        return fused_step(mat, a, b, mask, gamma, block=block)

    spec = jax.ShapeDtypeStruct
    args = (
        spec((d, d), jnp.float64),
        spec((n, d), jnp.float64),
        spec((n, d), jnp.float64),
        spec((n,), jnp.float64),
        spec((), jnp.float64),
    )
    return fn, args
