"""AOT lowering: JAX (L2, calling the L1 Pallas kernels) -> HLO text.

HLO *text* is the interchange format, NOT a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the published ``xla`` 0.1.6 rust crate links) rejects
(``proto.id() <= INT_MAX``). The text parser reassigns ids, so text
round-trips cleanly. Lowered with ``return_tuple=True``; the rust side
unwraps with ``to_tuple1()`` / tuple indexing.

Artifacts are keyed by (entry, d, n): ``<entry>_d{d}_b{n}.hlo.txt`` where n
is the padded triplet-block length per PJRT dispatch (DISPATCH_N rows,
internally tiled by the Pallas block). ``make artifacts`` is incremental:
the Makefile stamps the directory and skips when inputs are unchanged.

A ``manifest.json`` records every emitted artifact so the rust registry can
enumerate them without globbing conventions drifting.
"""

import argparse
import json
import os

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc

from . import model

# Feature dimensions of the dataset analogues used by the experiment suite
# (see DESIGN.md §5) plus power-of-two sizes for the perf sweep.
DEFAULT_DIMS = [4, 13, 16, 19, 32, 36, 64, 68, 100, 128, 200]
# Rows per PJRT dispatch; multiple of the Pallas block (512).
DISPATCH_N = 8192

ENTRIES = {
    "margins": model.entry_margins,
    "wgram": model.entry_wgram,
    "step": model.entry_step,
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(entry: str, d: int, n: int, block: int) -> str:
    fn, args = ENTRIES[entry](d, n, block=block)
    lowered = jax.jit(fn).lower(*args)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--dims", type=int, nargs="*", default=DEFAULT_DIMS)
    ap.add_argument("--n", type=int, default=DISPATCH_N)
    ap.add_argument("--block", type=int, default=512)
    ap.add_argument(
        "--entries", nargs="*", default=list(ENTRIES), choices=list(ENTRIES)
    )
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    manifest = {
        "dispatch_n": args.n,
        "pallas_block": args.block,
        "dtype": "f64",
        "artifacts": [],
    }
    for d in args.dims:
        for entry in args.entries:
            name = f"{entry}_d{d}_b{args.n}.hlo.txt"
            path = os.path.join(args.out, name)
            text = lower_entry(entry, d, args.n, args.block)
            with open(path, "w") as f:
                f.write(text)
            manifest["artifacts"].append(
                {"entry": entry, "d": d, "n": args.n, "file": name}
            )
            print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"manifest: {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
