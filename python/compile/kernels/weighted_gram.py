"""Weighted gram-difference Pallas kernel: the loss-gradient accumulation.

    G = sum_t w_t H_t = A^T diag(w) A - B^T diag(w) B,

with w_t = alpha_t (the dual-feasible coefficients -l'(m_t)). Together with
``triplet_margins`` this covers every O(d^2 |T|) operation in RTLM.

TPU mapping: the grid walks triplet tiles; each step performs two
``[d, block] x [block, d]`` MXU matmuls and accumulates into the
VMEM-resident [d, d] output block (revisited across the whole grid, which
Pallas keeps live between steps).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .triplet_margin import DEFAULT_BLOCK


def _wgram_kernel(a_ref, b_ref, w_ref, out_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = a_ref[...]
    b = b_ref[...]
    w = w_ref[...]
    aw = a * w[:, None]
    bw = b * w[:, None]
    out_ref[...] += aw.T @ a - bw.T @ b


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def weighted_gram(a, b, w, *, block=DEFAULT_BLOCK, interpret=True):
    """G = A^T diag(w) A - B^T diag(w) B, [d, d].

    Padded tail rows must carry w=0 so they contribute nothing.
    """
    n, d = a.shape
    if n % block != 0:
        raise ValueError(f"n={n} must be a multiple of block={block}")
    grid = (n // block,)
    return pl.pallas_call(
        _wgram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, d), lambda i: (i, 0)),
            pl.BlockSpec((block, d), lambda i: (i, 0)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((d, d), lambda i: (0, 0)),  # accumulator
        out_shape=jax.ShapeDtypeStruct((d, d), a.dtype),
        interpret=interpret,
    )(a, b, w)
