"""Layer-1 Pallas kernels for safe triplet screening.

Both kernels are authored as Pallas kernels and lowered with
``interpret=True`` so the resulting HLO runs on any PJRT backend (the rust
CPU client in particular). Real-TPU lowering would emit Mosaic custom-calls
the CPU plugin cannot execute; see DESIGN.md §Hardware-Adaptation.
"""

from .triplet_margin import triplet_margins, DEFAULT_BLOCK
from .weighted_gram import weighted_gram

__all__ = ["triplet_margins", "weighted_gram", "DEFAULT_BLOCK"]
