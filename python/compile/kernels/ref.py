"""Pure-jnp correctness oracles for the Pallas kernels and the L2 model.

Everything here is deliberately naive (einsum over explicit H_t where
feasible) — the single source of numerical truth for pytest.
"""

import jax.numpy as jnp


def margins_ref(mat, a, b):
    """m_t = a_t^T mat a_t - b_t^T mat b_t (vectorized, no Pallas)."""
    return jnp.einsum("ti,ij,tj->t", a, mat, a) - jnp.einsum(
        "ti,ij,tj->t", b, mat, b
    )


def margins_ref_explicit(mat, a, b):
    """Same via explicit H_t matrices — O(n d^2) memory, tiny inputs only."""
    h = a[:, :, None] * a[:, None, :] - b[:, :, None] * b[:, None, :]
    return jnp.einsum("tij,ij->t", h, mat)


def wgram_ref(a, b, w):
    """sum_t w_t (a_t a_t^T - b_t b_t^T)."""
    return jnp.einsum("t,ti,tj->ij", w, a, a) - jnp.einsum(
        "t,ti,tj->ij", w, b, b
    )


def smoothed_hinge(m, gamma):
    """l(m): 0 for m>1; (1-m)^2/(2 gamma) on [1-gamma, 1]; 1-m-gamma/2 below."""
    return jnp.where(
        m > 1.0,
        0.0,
        jnp.where(
            m >= 1.0 - gamma,
            (1.0 - m) ** 2 / (2.0 * gamma),
            1.0 - m - gamma / 2.0,
        ),
    )


def smoothed_hinge_alpha(m, gamma):
    """alpha = -l'(m) in [0, 1]."""
    return jnp.clip((1.0 - m) / gamma, 0.0, 1.0)


def fused_step_ref(mat, a, b, mask, gamma):
    """Reference for the fused AOT step: (loss_sum, grad_loss_sum, margins).

    grad_loss_sum = sum_t alpha_t H_t (the rust side forms
    grad P = -grad_loss_sum + lambda M itself).
    """
    m = margins_ref(mat, a, b)
    loss = jnp.sum(smoothed_hinge(m, gamma) * mask)
    alpha = smoothed_hinge_alpha(m, gamma) * mask
    g = wgram_ref(a, b, alpha)
    return loss, g, m
