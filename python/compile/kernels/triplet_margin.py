"""Triplet bilinear-form (margin) Pallas kernel.

For triplet t with ``a_t = x_i - x_l`` and ``b_t = x_i - x_j``, the margin is

    m_t = <M, H_t> = a_t^T M a_t - b_t^T M b_t,
    H_t = a_t a_t^T - b_t b_t^T.

This is the O(d^2 |T|) hot spot of both the objective evaluation (with the
iterate ``M``) and the screening statistic ``<H_t, Q>`` (with the sphere
center ``Q``) — one kernel serves both, which is the reuse the paper's
§3.3 cost analysis relies on.

TPU mapping: the triplet axis is tiled in blocks of ``block`` rows; each
grid step keeps ``M [d,d]`` VMEM-resident and streams one ``[block, d]``
tile of A and B through the MXU as ``(A @ M) * A`` row reductions —
a ``[block,d] x [d,d]`` matmul per tile (bf16/f32 on real hardware; f64
here because the rust coordinator wants exact duality gaps on CPU).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 512


def _margin_kernel(mat_ref, a_ref, b_ref, out_ref):
    """One grid step: margins for one [block, d] tile of triplets."""
    mat = mat_ref[...]
    a = a_ref[...]
    b = b_ref[...]
    # (A @ M) ∘ A summed along d == rowwise a^T M a; MXU-shaped matmul.
    qa = jnp.sum((a @ mat) * a, axis=-1)
    qb = jnp.sum((b @ mat) * b, axis=-1)
    out_ref[...] = qa - qb


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def triplet_margins(mat, a, b, *, block=DEFAULT_BLOCK, interpret=True):
    """m[t] = a_t^T mat a_t - b_t^T mat b_t for every row t.

    Args:
      mat: [d, d] symmetric matrix (iterate M or sphere center Q).
      a:   [n, d] rows ``x_i - x_l``. n must be a multiple of ``block``
           (the rust coordinator pads the final tile and ignores the tail).
      b:   [n, d] rows ``x_i - x_j``.
    Returns:
      [n] margins.
    """
    n, d = a.shape
    if n % block != 0:
        raise ValueError(f"n={n} must be a multiple of block={block}")
    grid = (n // block,)
    return pl.pallas_call(
        _margin_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((d, d), lambda i: (0, 0)),  # M resident in VMEM
            pl.BlockSpec((block, d), lambda i: (i, 0)),  # stream A tiles
            pl.BlockSpec((block, d), lambda i: (i, 0)),  # stream B tiles
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), mat.dtype),
        interpret=interpret,
    )(mat, a, b)
