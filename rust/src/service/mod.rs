//! Multi-tenant serving layer: sharded admission, frame caching, and
//! per-tenant sessions with budgets — the first subsystem of the crate
//! that runs as a resident process rather than a batch experiment
//! (`triplet-serve`).
//!
//! Layering (each piece is independently testable):
//!
//! - [`shard`] — fan a [`crate::triplet::CandidateBatch`] across the
//!   persistent worker pool, decide each candidate against a
//!   `Send + Sync` [`shard::FrameSnapshot`] of the reference frame, and
//!   merge the outcomes serially in enumeration order. Bitwise
//!   shard-count invariance by construction; worker panics degrade to a
//!   serial replay of the same plan.
//! - [`frame_store`] — an LRU cache of solved paths keyed by a 128-bit
//!   dataset fingerprint, with bitwise dataset verification on every
//!   hit so a mutated dataset can never reach a stale frame.
//! - [`session`] — per-tenant lifecycle: budget checks, cache hits
//!   (zero rule evaluations), incremental warm starts that revive only
//!   affected triplets, cold sharded path solves, and
//!   BENCH_SCHEMA.md-conformant request telemetry.
//!
//! The test battery lives in `rust/tests/service_safety.rs`,
//! `rust/tests/service_faults.rs` and `rust/tests/service_soak.rs`;
//! `benches/screening.rs` gates the warm-hit and shard-scaling
//! economics.

pub mod frame_store;
pub mod session;
pub mod shard;

pub use frame_store::{fingerprint, CachedSolve, FrameStore};
pub use session::{
    materialize_universe, RequestTelemetry, ServeResult, ServiceError, Session, SessionConfig,
};
pub use shard::{
    apply_admissions, AdmissionCounters, FrameSnapshot, ShardOutcome, ShardedAdmitter,
};
