//! Multi-tenant serving layer: sharded admission, frame caching,
//! per-tenant sessions with budgets, and a concurrent request front
//! end — the first subsystem of the crate that runs as a resident
//! process rather than a batch experiment (`triplet-serve`).
//!
//! Layering (each piece is independently testable):
//!
//! - [`shard`] — fan a [`crate::triplet::CandidateBatch`] across the
//!   persistent worker pool, decide each candidate against a
//!   `Send + Sync` [`shard::FrameSnapshot`] of the reference frame, and
//!   merge the outcomes serially in enumeration order. Bitwise
//!   shard-count invariance by construction; worker panics degrade to a
//!   serial replay of the same plan.
//! - [`frame_store`] — an LRU cache of solved paths keyed by a 128-bit
//!   dataset fingerprint, with bitwise dataset verification on every
//!   hit so a mutated dataset can never reach a stale frame. PR 10
//!   added the [`FrameCache`] trait (serial store and shared store
//!   behind one serve path), the sharded-lock [`SharedFrameStore`],
//!   and a versioned, checksummed, fingerprint-stamped frame codec
//!   ([`encode_frame`]/[`decode_frame`]) for cross-process export.
//! - [`session`] — per-tenant lifecycle: budget checks, cache hits
//!   (zero rule evaluations), incremental warm starts that revive only
//!   affected triplets, cold sharded path solves, and
//!   BENCH_SCHEMA.md-conformant request telemetry.
//! - [`queue`] + [`server`] — the concurrent front end: a bounded MPMC
//!   request queue with typed backpressure, per-tenant actor mailboxes
//!   that keep each `Session` serial while tenants run concurrently on
//!   OS worker threads, per-request deadlines, confined worker panics,
//!   and the line-oriented request protocol behind
//!   `triplet-serve serve`.
//!
//! The test battery lives in `rust/tests/service_safety.rs`,
//! `rust/tests/service_faults.rs`, `rust/tests/service_soak.rs`,
//! `rust/tests/service_concurrent.rs` and
//! `rust/tests/service_protocol.rs`; `benches/screening.rs` gates the
//! warm-hit, shard-scaling and front-end-concurrency economics.

pub mod frame_store;
pub mod queue;
pub mod server;
pub mod session;
pub mod shard;

pub use frame_store::{
    decode_frame, encode_frame, fingerprint, frame_checksum, CachedSolve, CodecError, FrameCache,
    FrameStore, SharedFrameStore,
};
pub use queue::{BoundedQueue, PushError};
pub use server::{
    parse_request, request_dataset, FrontConfig, ProtocolError, Request, ServeFront,
    SubmitOptions, Ticket, MAX_LINE_BYTES,
};
pub use session::{
    materialize_universe, RequestTelemetry, ServeResult, ServiceError, Session, SessionConfig,
};
pub use shard::{
    apply_admissions, AdmissionCounters, FrameSnapshot, ShardOutcome, ShardedAdmitter,
};
