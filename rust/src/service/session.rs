//! Per-tenant serving state: budgets, warm starts, sharded λ-path
//! solves, and per-request telemetry.
//!
//! ## Session lifecycle
//!
//! A [`Session`] owns one tenant's configuration and warm-start lineage
//! and serves requests against any [`FrameCache`] — the single-owner
//! [`crate::service::FrameStore`] on the serial path, the sharded-lock
//! [`crate::service::SharedFrameStore`] under the concurrent front end:
//!
//! 1. **Budget check** — the request's candidate universe is counted
//!    *before* any compute and rejected with a typed
//!    [`ServiceError::BudgetExhausted`] if it exceeds
//!    `max_candidates`; workset growth is checked against
//!    `max_workset_rows` after every admission sweep. A rejected
//!    request leaves the `FrameStore` untouched — budget errors can
//!    never publish a partial frame.
//! 2. **Warm hit** — if the `(dataset, k)` fingerprint verifies
//!    bitwise in the store, the cached solve is replayed with zero
//!    rule evaluations and zero admission work (`frames_reused = 1`).
//! 3. **Incremental update** — if the tenant solved before (same `d`)
//!    but the data changed, the service does *not* re-solve from
//!    λ_max: it re-solves the **new** problem once at
//!    λ₀ = λ_target/ρ, warm-started from the previous final iterate
//!    (a few iterations when the update is small), takes the exact
//!    duality gap as the reference ε — so the frame is sound for the
//!    new problem by construction — and then runs a single sharded
//!    admission + solve step down to the tenant's pinned λ_target.
//!    Unaffected triplets sit deep inside their certified λ-ranges
//!    and are rejected at admission; only triplets whose margins the
//!    update actually moved get revived into the workset via the
//!    pending-certificate / `retarget_lambda` machinery.
//! 4. **Cold solve** — otherwise the full streamed path runs from
//!    λ_max, with every admission sweep sharded across the pool
//!    ([`crate::service::shard`]).
//!
//! Successful solves are published to the `FrameStore` and recorded as
//! the tenant's new warm-start lineage. Every request emits a
//! [`RequestTelemetry`] whose JSON keys are documented in
//! `rust/docs/BENCH_SCHEMA.md` (conformance-gated in the service test
//! battery).

use std::rc::Rc;
use std::time::Instant;

use crate::data::Dataset;
use crate::linalg::{psd_split, Mat};
use crate::loss::Loss;
use crate::runtime::Engine;
use crate::screening::{
    BoundKind, CertFamilies, ReferenceFrame, RuleKind, ScreeningConfig, ScreeningManager,
};
use crate::solver::{Problem, ProblemState, ScreenCtx, Solver, SolverConfig};
use crate::triplet::{
    CandidateBatch, MiningStrategy, PendingCert, PendingPool, StatusVec, TripletMiner,
    TripletStore,
};
use crate::util::json::Json;

use super::frame_store::{CachedSolve, FrameCache};
use super::shard::{apply_admissions, AdmissionCounters, ShardedAdmitter};

/// Per-tenant service configuration: path shape, sharding, and budgets.
#[derive(Clone, Debug)]
pub struct SessionConfig {
    /// neighbors per anchor for triplet construction
    pub k: usize,
    /// mining batch size (candidates per admission sweep)
    pub batch: usize,
    /// admission shards per batch (clamped to ≥ 1)
    pub shards: usize,
    /// geometric λ decay per path step
    pub rho: f64,
    /// λ steps per cold solve
    pub max_steps: usize,
    /// paper §5 early-termination ratio (0 disables — keeps λ grids
    /// identical across tenants/configs, which the determinism tests
    /// rely on)
    pub stop_ratio: f64,
    /// smoothed-hinge γ (0 = plain hinge)
    pub gamma: f64,
    /// solver duality-gap tolerance
    pub tol: f64,
    /// per-request candidate-universe budget (0 = unlimited)
    pub max_candidates: usize,
    /// per-request admitted-workset budget in rows (0 = unlimited)
    pub max_workset_rows: usize,
}

impl Default for SessionConfig {
    fn default() -> SessionConfig {
        SessionConfig {
            k: 3,
            batch: 1024,
            shards: 1,
            rho: 0.9,
            max_steps: 8,
            stop_ratio: 0.0,
            gamma: 0.05,
            tol: 1e-6,
            max_candidates: 0,
            max_workset_rows: 0,
        }
    }
}

impl SessionConfig {
    fn loss(&self) -> Loss {
        if self.gamma > 0.0 {
            Loss::smoothed_hinge(self.gamma)
        } else {
            Loss::hinge()
        }
    }

    fn solver(&self) -> SolverConfig {
        SolverConfig {
            tol: self.tol,
            ..SolverConfig::default()
        }
    }
}

/// Typed request-rejection errors. Budget errors are raised *before*
/// any partial result could be published, so a rejected request never
/// leaves a frame (partial or otherwise) in the
/// [`crate::service::FrameStore`]. The queue/front-end variants
/// (`QueueFull`, `TimedOut`, `ShuttingDown`, `UnknownTenant`,
/// `WorkerPanicked`) are raised by [`crate::service::ServeFront`]
/// before or instead of a `Session` ever running, so they share the
/// same guarantee.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServiceError {
    /// A per-request budget would be exceeded.
    BudgetExhausted {
        /// which budget tripped (`"candidates"` or `"workset_rows"`)
        resource: &'static str,
        /// the configured limit
        limit: usize,
        /// what the request needed
        requested: usize,
    },
    /// The dataset yields no triplet candidates (or a degenerate
    /// λ_max), so there is nothing to solve.
    EmptyUniverse,
    /// The front-end request queue is at capacity — backpressure;
    /// nothing was enqueued and nothing will run.
    QueueFull {
        /// configured queue capacity
        capacity: usize,
    },
    /// The request's deadline expired while it was still queued; it
    /// never reached a `Session`.
    TimedOut,
    /// The front end is draining for shutdown; no new requests are
    /// accepted.
    ShuttingDown,
    /// The request names a tenant the front end was not built with.
    UnknownTenant(String),
    /// The worker solving this request panicked. The tenant's session
    /// and the shared store are unaffected (the panic was confined to
    /// this request), but the request itself produced no result.
    WorkerPanicked,
}

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServiceError::BudgetExhausted {
                resource,
                limit,
                requested,
            } => write!(
                f,
                "budget exhausted: {requested} {resource} requested, limit {limit}"
            ),
            ServiceError::EmptyUniverse => write!(f, "no triplet candidates to solve"),
            ServiceError::QueueFull { capacity } => {
                write!(f, "request queue full (capacity {capacity})")
            }
            ServiceError::TimedOut => write!(f, "request deadline expired before service"),
            ServiceError::ShuttingDown => write!(f, "front end is shutting down"),
            ServiceError::UnknownTenant(t) => write!(f, "unknown tenant '{t}'"),
            ServiceError::WorkerPanicked => write!(f, "worker panicked while serving the request"),
        }
    }
}

impl std::error::Error for ServiceError {}

/// Per-request telemetry; `to_json` keys are documented in
/// `rust/docs/BENCH_SCHEMA.md` (the service tests run the same
/// `undocumented_keys` conformance gate the bench uses).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RequestTelemetry {
    /// cached frames this request was served from (0 or 1)
    pub frames_reused: usize,
    /// admission shards configured for the request
    pub shards: usize,
    /// worker panics caught and degraded to serial during admission
    pub shard_faults: usize,
    /// whether the solve warm-started from tenant lineage or a cache hit
    pub warm_start: bool,
    /// λ steps executed (0 for a pure cache hit)
    pub steps: usize,
    /// candidates decided at admission
    pub adm_candidates: usize,
    /// candidates admitted into the workset
    pub adm_admitted: usize,
    /// candidates certified into L* at admission
    pub adm_rejected_l: usize,
    /// candidates certified into R* at admission
    pub adm_rejected_r: usize,
    /// screening-rule evaluations performed by the dynamic screener
    pub rule_evals: usize,
    /// dynamic-screening calls during the solves
    pub screen_calls: usize,
    /// L-certified candidates folded into the external L̂ accumulator
    pub external_l: usize,
    /// pending admission certificates alive at the end of the request
    pub pending_certs: usize,
    /// peak admitted workset rows across the path
    pub peak_workset_rows: usize,
    /// wall seconds in sharded admission (margins + decisions)
    pub admit_wall_seconds: f64,
    /// wall seconds in the serial merge phase of admission
    pub merge_wall_seconds: f64,
    /// end-to-end request wall seconds
    pub wall_seconds: f64,
}

impl RequestTelemetry {
    /// All deterministic (non-wall-clock) counters as a fixed-size
    /// array — the soak test compares these across interleaved vs
    /// isolated runs.
    pub fn counters(&self) -> [usize; 14] {
        [
            self.frames_reused,
            self.shards,
            self.shard_faults,
            self.warm_start as usize,
            self.steps,
            self.adm_candidates,
            self.adm_admitted,
            self.adm_rejected_l,
            self.adm_rejected_r,
            self.rule_evals,
            self.screen_calls,
            self.external_l,
            self.pending_certs,
            self.peak_workset_rows,
        ]
    }

    /// Emit the telemetry as a JSON object (BENCH_SCHEMA.md-conformant
    /// keys).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("frames_reused", Json::Num(self.frames_reused as f64)),
            ("shards", Json::Num(self.shards as f64)),
            ("shard_faults", Json::Num(self.shard_faults as f64)),
            ("warm_start", Json::Bool(self.warm_start)),
            ("steps", Json::Num(self.steps as f64)),
            ("adm_candidates", Json::Num(self.adm_candidates as f64)),
            ("adm_admitted", Json::Num(self.adm_admitted as f64)),
            ("adm_rejected_l", Json::Num(self.adm_rejected_l as f64)),
            ("adm_rejected_r", Json::Num(self.adm_rejected_r as f64)),
            ("rule_evals", Json::Num(self.rule_evals as f64)),
            ("screen_calls", Json::Num(self.screen_calls as f64)),
            ("external_l", Json::Num(self.external_l as f64)),
            ("pending_certs", Json::Num(self.pending_certs as f64)),
            ("peak_workset_rows", Json::Num(self.peak_workset_rows as f64)),
            ("admit_wall_seconds", Json::Num(self.admit_wall_seconds)),
            ("merge_wall_seconds", Json::Num(self.merge_wall_seconds)),
            ("wall_seconds", Json::Num(self.wall_seconds)),
        ])
    }
}

/// Result of one served request.
#[derive(Clone, Debug)]
pub struct ServeResult {
    /// learned Mahalanobis matrix at the final λ
    pub m: Mat,
    /// final λ of the path
    pub lambda: f64,
    /// λ_max the (cold) path started from
    pub lambda_max: f64,
    /// reduced primal at the final step
    pub p: f64,
    /// λ steps executed by the original solve
    pub steps: usize,
    /// `(i, j, l)` ids admitted into the final workset, admission order
    pub admitted_idx: Vec<(u32, u32, u32)>,
    /// triplets screened into L* at the final step
    pub screened_l: usize,
    /// triplets screened into R* at the final step
    pub screened_r: usize,
    /// per-request telemetry
    pub telemetry: RequestTelemetry,
}

/// Tenant warm-start lineage: the last successful solve.
#[derive(Clone, Debug)]
struct PreviousSolve {
    m: Mat,
    lambda: f64,
    lambda_max: f64,
    d: usize,
}

/// Internal warm-start plan for an incremental update.
struct WarmStart {
    m_ref: Mat,
    lambda0: f64,
    eps0: f64,
    lambda_target: f64,
    lambda_max: f64,
}

/// Outcome of one sharded path run (pre-publication).
struct SolveOutcome {
    m: Mat,
    lambda: f64,
    lambda_max: f64,
    p: f64,
    eps: f64,
    steps: usize,
    admitted_idx: Vec<(u32, u32, u32)>,
    screened_l: usize,
    screened_r: usize,
}

/// Per-tenant serving session; see the module docs for the lifecycle.
pub struct Session {
    tenant: String,
    cfg: SessionConfig,
    admitter: ShardedAdmitter,
    previous: Option<PreviousSolve>,
    requests: usize,
}

impl Session {
    /// A new session for `tenant` with the given configuration.
    pub fn new(tenant: impl Into<String>, cfg: SessionConfig) -> Session {
        let admitter = ShardedAdmitter::new(cfg.shards);
        Session {
            tenant: tenant.into(),
            cfg,
            admitter,
            previous: None,
            requests: 0,
        }
    }

    /// The tenant this session belongs to.
    pub fn tenant(&self) -> &str {
        &self.tenant
    }

    /// The session's configuration.
    pub fn config(&self) -> &SessionConfig {
        &self.cfg
    }

    /// Requests served (including rejected ones).
    pub fn requests(&self) -> usize {
        self.requests
    }

    /// Arm a one-shot injected worker panic in the next admission pass
    /// (fault-injection tests; see
    /// [`crate::service::shard::ShardedAdmitter::inject_fault`]).
    pub fn inject_shard_fault(&mut self) {
        self.admitter.inject_fault();
    }

    /// Worker panics caught (and recovered from) by this session.
    pub fn faults_caught(&self) -> usize {
        self.admitter.faults_caught()
    }

    /// Serve one request: budget check, then cache hit / incremental
    /// warm start / cold sharded path solve, in that order. Successful
    /// solves are published to `frames` and become the tenant's
    /// warm-start lineage; errors publish nothing. Generic over the
    /// cache so the serial [`crate::service::FrameStore`] and the
    /// concurrent front end's shared
    /// [`crate::service::SharedFrameStore`] drive the identical path.
    pub fn serve<C: FrameCache>(
        &mut self,
        ds: &Dataset,
        frames: &mut C,
        engine: &dyn Engine,
    ) -> Result<ServeResult, ServiceError> {
        let t0 = Instant::now();
        self.requests += 1;
        let mut tel = RequestTelemetry {
            shards: self.admitter.shards(),
            ..RequestTelemetry::default()
        };

        let mut miner =
            TripletMiner::new(ds, self.cfg.k, MiningStrategy::Exhaustive, self.cfg.batch);
        let universe = miner.total_candidates();
        if universe == 0 {
            return Err(ServiceError::EmptyUniverse);
        }
        if self.cfg.max_candidates > 0 && universe > self.cfg.max_candidates {
            return Err(ServiceError::BudgetExhausted {
                resource: "candidates",
                limit: self.cfg.max_candidates,
                requested: universe,
            });
        }

        if let Some(hit) = frames.lookup_cached(ds, self.cfg.k) {
            tel.frames_reused = 1;
            tel.warm_start = true;
            tel.steps = hit.steps;
            tel.peak_workset_rows = hit.admitted_idx.len();
            tel.wall_seconds = t0.elapsed().as_secs_f64();
            let res = ServeResult {
                m: hit.m_final.clone(),
                lambda: hit.lambda,
                lambda_max: hit.lambda_max,
                p: hit.p,
                steps: hit.steps,
                admitted_idx: hit.admitted_idx.clone(),
                screened_l: hit.screened_l,
                screened_r: hit.screened_r,
                telemetry: tel,
            };
            self.previous = Some(PreviousSolve {
                m: res.m.clone(),
                lambda: res.lambda,
                lambda_max: res.lambda_max,
                d: ds.d(),
            });
            return Ok(res);
        }

        let warm = match &self.previous {
            Some(prev) if prev.d == ds.d() => {
                // Incremental update: re-solve the *new* problem once at
                // λ₀ = λ_target/ρ, warm from the previous iterate. The
                // duality gap of that solve gives the reference ε, so
                // the frame below is sound for the new problem no
                // matter how much the data moved.
                tel.warm_start = true;
                let full = materialize_universe(&mut miner);
                let lambda_target = prev.lambda;
                let lambda0 = lambda_target / self.cfg.rho;
                let loss = self.cfg.loss();
                let mut problem = Problem::new(&full, loss, lambda0);
                let solver = Solver::new(self.cfg.solver());
                let (m_ref, st) = solver.solve(&mut problem, engine, prev.m.clone(), None);
                let eps0 = (2.0 * st.gap.max(0.0) / lambda0).sqrt();
                Some(WarmStart {
                    m_ref,
                    lambda0,
                    eps0,
                    lambda_target,
                    lambda_max: prev.lambda_max,
                })
            }
            _ => None,
        };

        let outcome = run_sharded_path(
            &self.cfg,
            &mut self.admitter,
            &mut miner,
            engine,
            warm,
            &mut tel,
        )?;

        let cached = CachedSolve {
            m_final: outcome.m.clone(),
            lambda: outcome.lambda,
            lambda_max: outcome.lambda_max,
            eps: outcome.eps,
            p: outcome.p,
            steps: outcome.steps,
            admitted_idx: outcome.admitted_idx.clone(),
            screened_l: outcome.screened_l,
            screened_r: outcome.screened_r,
        };
        frames.publish(ds, self.cfg.k, cached);
        self.previous = Some(PreviousSolve {
            m: outcome.m.clone(),
            lambda: outcome.lambda,
            lambda_max: outcome.lambda_max,
            d: ds.d(),
        });
        tel.steps = outcome.steps;
        tel.wall_seconds = t0.elapsed().as_secs_f64();
        Ok(ServeResult {
            m: outcome.m,
            lambda: outcome.lambda,
            lambda_max: outcome.lambda_max,
            p: outcome.p,
            steps: outcome.steps,
            admitted_idx: outcome.admitted_idx,
            screened_l: outcome.screened_l,
            screened_r: outcome.screened_r,
            telemetry: tel,
        })
    }
}

/// Materialize the miner's full candidate universe into a
/// [`TripletStore`] (enumeration order). Used for the incremental
/// warm-start reference solve and as the oracle in the service tests.
pub fn materialize_universe(miner: &mut TripletMiner<'_>) -> TripletStore {
    let mut store = TripletStore::empty(miner.d());
    let mut batch = CandidateBatch::new(miner.d());
    miner.reset();
    while miner.next_into(&mut batch) {
        for t in 0..batch.len() {
            store.push(batch.idx[t], batch.a.row(t), batch.b.row(t), batch.h_norm[t]);
        }
    }
    store
}

/// The sharded streamed λ-path loop (the service mirror of the path
/// driver's streamed mode): per step, shard-admit the candidate
/// universe against the current frame, re-test expired pending
/// certificates, then solve the reduced problem warm-started from the
/// previous iterate, rebuilding the frame between steps. Errors out on
/// workset-budget exhaustion before anything is published.
#[allow(clippy::too_many_arguments)]
fn run_sharded_path(
    cfg: &SessionConfig,
    admitter: &mut ShardedAdmitter,
    miner: &mut TripletMiner<'_>,
    engine: &dyn Engine,
    warm: Option<WarmStart>,
    tel: &mut RequestTelemetry,
) -> Result<SolveOutcome, ServiceError> {
    let loss = cfg.loss();
    let families = CertFamilies::rrpb_only();
    let d = miner.d();
    let mut batch = CandidateBatch::new(d);
    let mut store = TripletStore::empty(d);
    let mut lane: Vec<f64> = Vec::new();
    let mut pending = PendingPool::new();
    let mut h_ext = Mat::zeros(d, d);
    let mut n_ext: usize = 0;
    let mut expired: Vec<PendingCert> = Vec::new();
    let mut retest_idx: Vec<(u32, u32, u32)> = Vec::new();
    let mut cover_l: Vec<usize> = Vec::new();
    let mut cover_r: Vec<usize> = Vec::new();
    let mut counters = AdmissionCounters::default();

    // Reference frame + path start: λ_max closed form (cold) or the
    // caller's warm reference (incremental).
    let (lambda_max, mut lambda, lambda_target, mut m_warm, mut frame) = match warm {
        None => {
            let sum_h = miner.sum_h_streamed(engine, &mut batch);
            let sum_h_plus = psd_split(&sum_h).plus;
            let max_hq = miner.max_margin_streamed(&sum_h_plus, engine, &mut batch);
            let lambda_max = Problem::lambda_max_from_parts(max_hq, &loss);
            if !(lambda_max.is_finite() && lambda_max > 0.0) {
                return Err(ServiceError::EmptyUniverse);
            }
            let m_warm = sum_h_plus.scaled(1.0 / lambda_max);
            let frame = Rc::new(ReferenceFrame::build(
                m_warm.clone(),
                lambda_max,
                0.0,
                &store,
                engine,
                Some((&loss, families)),
            ));
            (lambda_max, lambda_max, None, m_warm, frame)
        }
        Some(w) => {
            let frame = Rc::new(ReferenceFrame::build(
                w.m_ref.clone(),
                w.lambda0,
                w.eps0,
                &store,
                engine,
                Some((&loss, families)),
            ));
            (w.lambda_max, w.lambda0, Some(w.lambda_target), w.m_ref, frame)
        }
    };

    let scfg = ScreeningConfig::new(BoundKind::Rrpb, RuleKind::Sphere);
    let mut manager = ScreeningManager::new(scfg);
    manager.set_frame(frame.clone());

    let steps_cap = if lambda_target.is_some() {
        1
    } else {
        cfg.max_steps
    };
    let mut state: Option<ProblemState> = None;
    let mut mined_all = false;
    let mut prev_loss_term = f64::INFINITY;
    let mut eps = 0.0;
    let mut last_p = 0.0;
    let mut steps = 0usize;

    for step_i in 0..steps_cap {
        let lambda_prev = lambda;
        lambda = match lambda_target {
            // incremental: land exactly on the tenant's pinned λ
            Some(t) => t,
            None => lambda * cfg.rho,
        };

        // ---- sharded admission sweep -------------------------------
        let t_admit = Instant::now();
        if !mined_all {
            miner.reset();
            while miner.next_into(&mut batch) {
                let out = admitter.admit(&frame, engine, &batch, lambda, &loss);
                if out.degraded {
                    tel.shard_faults += 1;
                }
                let t_merge = Instant::now();
                apply_admissions(
                    &batch,
                    &out,
                    &mut store,
                    &mut lane,
                    &mut pending,
                    &mut h_ext,
                    &mut n_ext,
                    None,
                    &mut counters,
                );
                tel.merge_wall_seconds += t_merge.elapsed().as_secs_f64();
            }
            mined_all = true;
        }
        pending.pop_expired(lambda, &mut expired);
        for group in expired.chunks(miner.batch_size()) {
            retest_idx.clear();
            retest_idx.extend(group.iter().map(|r| r.idx));
            miner.materialize_into(&retest_idx, &mut batch);
            let out = admitter.admit(&frame, engine, &batch, lambda, &loss);
            if out.degraded {
                tel.shard_faults += 1;
            }
            let t_merge = Instant::now();
            apply_admissions(
                &batch,
                &out,
                &mut store,
                &mut lane,
                &mut pending,
                &mut h_ext,
                &mut n_ext,
                Some(group),
                &mut counters,
            );
            tel.merge_wall_seconds += t_merge.elapsed().as_secs_f64();
        }
        tel.admit_wall_seconds += t_admit.elapsed().as_secs_f64();
        tel.peak_workset_rows = tel.peak_workset_rows.max(store.len());

        // ---- workset budget (typed error, nothing published) -------
        if cfg.max_workset_rows > 0 && store.len() > cfg.max_workset_rows {
            return Err(ServiceError::BudgetExhausted {
                resource: "workset_rows",
                limit: cfg.max_workset_rows,
                requested: store.len(),
            });
        }

        // ---- certificate range pass + reduced solve ----------------
        cover_l.clear();
        cover_r.clear();
        frame.advance_covered(lambda, &mut cover_l, &mut cover_r);
        let mut problem = match state.take() {
            None => Problem::new(&store, loss, lambda),
            Some(st) => Problem::resume(&store, loss, lambda, st),
        };
        problem.retarget_lambda(lambda, &cover_l, &cover_r);
        problem.set_external_l(&h_ext, n_ext);
        problem.install_ref_margins(&lane, frame.tag());
        let (m_sol, stats) = {
            let mut cb = |p: &Problem, ctx: &ScreenCtx| manager.screen(p, ctx, engine);
            Solver::new(cfg.solver()).solve(&mut problem, engine, m_warm.clone(), Some(&mut cb))
        };

        let loss_term = stats.p - 0.5 * lambda * m_sol.norm_sq();
        eps = (2.0 * stats.gap.max(0.0) / lambda).sqrt();
        last_p = stats.p;
        m_warm = m_sol;
        state = Some(problem.into_state());
        steps += 1;

        // paper termination criterion (only meaningful on cold paths
        // with stop_ratio > 0 and a positive previous loss term)
        let mut stop = false;
        if cfg.stop_ratio > 0.0 && prev_loss_term.is_finite() && prev_loss_term > 0.0 {
            let drop = (prev_loss_term - loss_term) / prev_loss_term;
            let stretch = lambda_prev / (lambda_prev - lambda);
            stop = drop * stretch < cfg.stop_ratio;
        }
        prev_loss_term = loss_term;
        if stop {
            break;
        }

        // rebuild the reference at the fresh solution for the next step
        if step_i + 1 < steps_cap {
            frame = Rc::new(ReferenceFrame::build(
                m_warm.clone(),
                lambda,
                eps,
                &store,
                engine,
                Some((&loss, families)),
            ));
            manager.set_frame(frame.clone());
            lane = frame.margins().to_vec();
        }
    }

    let status = state
        .map(|st| st.into_status())
        .unwrap_or_else(|| StatusVec::new(store.len()));

    tel.adm_candidates = counters.candidates;
    tel.adm_admitted = counters.admitted;
    tel.adm_rejected_l = counters.rejected_l;
    tel.adm_rejected_r = counters.rejected_r;
    tel.rule_evals = manager.stats.rule_evals;
    tel.screen_calls = manager.stats.calls;
    tel.external_l = n_ext;
    tel.pending_certs = pending.len();

    Ok(SolveOutcome {
        m: m_warm,
        lambda,
        lambda_max,
        p: last_p,
        eps,
        steps,
        admitted_idx: store.idx.clone(),
        screened_l: status.n_screened_l(),
        screened_r: status.n_screened_r(),
    })
}
