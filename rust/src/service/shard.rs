//! Sharded admission: fan one [`CandidateBatch`] out across the worker
//! pool, decide every candidate against an immutable frame snapshot, and
//! merge the outcomes back in enumeration order.
//!
//! ## Determinism argument
//!
//! A shard is a *contiguous slice* of the batch in candidate enumeration
//! order ([`crate::util::parallel::split_ranges`]). Each shard computes
//! its candidates' exact-f64 reference margins with
//! [`Engine::ref_margins`]; the tiled margin kernels accumulate each
//! row's chain independently of every other row (the summation order
//! depends only on `d`, never on batch composition — the PR 7 bitwise
//! batteries pin this), so slicing the batch does not change a single
//! margin bit. Decisions are pure functions of `(margin, ‖H‖, λ)` via
//! [`FrameSnapshot::decide`], and the merge phase replays the outcomes
//! serially in shard order = enumeration order, so the admitted store,
//! pending heap, external-L̂ accumulator and margins lane after an
//! N-shard pass are **bitwise identical** to the single-shard pass
//! (`rust/tests/service_safety.rs` asserts this at shards ∈ {1, 2, 7}).
//!
//! ## Fault path
//!
//! The parallel phase runs under `catch_unwind`: a worker panicking
//! mid-shard (the pool re-raises it on the caller after sibling tasks
//! drain — see `util::parallel::ThreadPool`) degrades the whole batch to
//! a serial re-run over the same shard plan, which produces the same
//! bits. [`ShardedAdmitter::inject_fault`] arms a one-shot panic in the
//! last shard so `rust/tests/service_faults.rs` can exercise the path
//! under real load.

use std::ops::Range;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};

use crate::linalg::Mat;
use crate::loss::Loss;
use crate::runtime::Engine;
use crate::screening::{l_range, r_range, Admission, CertSide, ReferenceFrame};
use crate::triplet::{CandidateBatch, PendingCert, PendingPool, TripletStore};
use crate::util::parallel;

/// Immutable, `Send + Sync` view of the scalars a [`ReferenceFrame`]
/// admission decision needs (`‖M₀‖`, ε, λ₀). The frame itself is not
/// `Sync` (it carries interior sweep state), so shard workers decide
/// against this snapshot; [`FrameSnapshot::decide`] mirrors
/// [`ReferenceFrame::admission_decision`] term for term and the
/// module-level tests hold the two to exact agreement.
#[derive(Clone, Copy, Debug)]
pub struct FrameSnapshot {
    m0_norm: f64,
    eps: f64,
    lambda0: f64,
}

impl FrameSnapshot {
    /// Snapshot the decision scalars of `frame`.
    pub fn of(frame: &ReferenceFrame) -> FrameSnapshot {
        FrameSnapshot {
            m0_norm: frame.m0_norm(),
            eps: frame.eps(),
            lambda0: frame.lambda0(),
        }
    }

    /// Admission decision for one candidate from its reference margin
    /// `hm = ⟨H, M₀⟩` and norm `hn = ‖H‖_F` — the same closed RRPB
    /// range forms, in the same order (R first, then L), as
    /// [`ReferenceFrame::admission_decision`].
    pub fn decide(&self, hm: f64, hn: f64, lambda: f64, loss: &Loss) -> Admission {
        let rr = r_range(hm, hn, self.m0_norm, self.eps, self.lambda0, loss.r_threshold());
        if rr.contains(lambda) {
            return Admission::Certified {
                side: CertSide::R,
                expires: rr.lo.max(0.0),
            };
        }
        let rl = l_range(hm, hn, self.m0_norm, self.eps, self.lambda0, loss.l_threshold());
        if rl.contains(lambda) {
            return Admission::Certified {
                side: CertSide::L,
                expires: rl.lo.max(0.0),
            };
        }
        Admission::Admit
    }
}

/// Merged result of one sharded admission pass over a batch: per
/// candidate (enumeration order) the exact-f64 reference margin and the
/// decision, plus how the pass executed.
#[derive(Clone, Debug)]
pub struct ShardOutcome {
    /// exact reference margins `⟨H_t, M₀⟩`, aligned with the batch
    pub hm: Vec<f64>,
    /// admission decisions, aligned with the batch
    pub decisions: Vec<Admission>,
    /// number of shards the batch was split into
    pub shards_run: usize,
    /// true when a worker panicked and the serial fallback produced the
    /// outcome (bits are identical either way — see the module docs)
    pub degraded: bool,
}

/// Monotone admission counters accumulated by [`apply_admissions`] —
/// the service-level mirror of the manager's `adm_*` statistics.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AdmissionCounters {
    /// candidates decided
    pub candidates: usize,
    /// candidates admitted into the workset
    pub admitted: usize,
    /// candidates certified into L* at admission
    pub rejected_l: usize,
    /// candidates certified into R* at admission
    pub rejected_r: usize,
}

/// Executes sharded admission passes; owns the shard count and the
/// fault-injection / degrade bookkeeping.
#[derive(Debug)]
pub struct ShardedAdmitter {
    shards: usize,
    fault_pending: bool,
    faults_caught: usize,
}

impl ShardedAdmitter {
    /// A sharded admitter splitting every batch into (at most) `shards`
    /// contiguous slices; `shards` is clamped to ≥ 1.
    pub fn new(shards: usize) -> ShardedAdmitter {
        ShardedAdmitter {
            shards: shards.max(1),
            fault_pending: false,
            faults_caught: 0,
        }
    }

    /// Configured shard count.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Arm a one-shot injected panic: the next parallel pass panics in
    /// its last shard, exercising the degrade-to-serial path
    /// (test-only; the serial re-run consumes the fault and succeeds).
    pub fn inject_fault(&mut self) {
        self.fault_pending = true;
    }

    /// Worker panics caught (and recovered from) so far.
    pub fn faults_caught(&self) -> usize {
        self.faults_caught
    }

    /// Decide every candidate in `batch` at `lambda` against `frame`,
    /// fanning the margin passes across the pool. Margins always take
    /// the exact f64 [`Engine::ref_margins`] path (the mixed-precision
    /// envelope tier is a manager-side concern), so the merged outcome
    /// is bitwise independent of the shard count.
    pub fn admit(
        &mut self,
        frame: &ReferenceFrame,
        engine: &dyn Engine,
        batch: &CandidateBatch,
        lambda: f64,
        loss: &Loss,
    ) -> ShardOutcome {
        let n = batch.len();
        let m0: &Mat = frame.m0();
        let snap = FrameSnapshot::of(frame);
        let ranges = parallel::split_ranges(n, self.shards);
        let shards_run = ranges.len().max(1);

        // One-shot injected fault: armed before dispatch, consumed by
        // the first shard that trips it, so the serial fallback below
        // re-runs clean.
        let fault = AtomicBool::new(self.fault_pending);
        self.fault_pending = false;
        let fault_start = ranges.last().map(|r| r.start);

        let run_shard = |r: Range<usize>| -> (Vec<f64>, Vec<Admission>) {
            if Some(r.start) == fault_start && fault.swap(false, Ordering::SeqCst) {
                panic!("injected shard fault (service fault-injection test)");
            }
            let idx: Vec<usize> = r.clone().collect();
            let mut hm = vec![0.0; idx.len()];
            if !idx.is_empty() {
                let a = batch.a.select_rows(&idx);
                let b = batch.b.select_rows(&idx);
                engine.ref_margins(m0, &a, &b, &mut hm);
            }
            let decisions = hm
                .iter()
                .zip(r)
                .map(|(&m, t)| snap.decide(m, batch.h_norm[t], lambda, loss))
                .collect();
            (hm, decisions)
        };

        let attempt = catch_unwind(AssertUnwindSafe(|| {
            parallel::par_range_tasks(ranges.clone(), &run_shard)
        }));
        let (per_shard, degraded) = match attempt {
            Ok(v) => (v, false),
            Err(_) => {
                // A worker died mid-shard. The pool has already drained
                // sibling tasks and stays usable (PR 7 guarantee);
                // replay the same shard plan serially — same rows, same
                // chains, same bits.
                self.faults_caught += 1;
                let serial: Vec<_> = ranges.into_iter().map(&run_shard).collect();
                (serial, true)
            }
        };

        let mut hm = Vec::with_capacity(n);
        let mut decisions = Vec::with_capacity(n);
        for (h, d) in per_shard {
            hm.extend(h);
            decisions.extend(d);
        }
        debug_assert_eq!(hm.len(), n);
        ShardOutcome {
            hm,
            decisions,
            shards_run,
            degraded,
        }
    }
}

/// Serial merge phase: replay a [`ShardOutcome`] onto the tenant state
/// in enumeration order — admitted rows into the store + margins lane,
/// certificates into the pending heap, L-certified mass folded into the
/// row-less external L̂ accumulator. Mirrors the streamed path driver's
/// admission bookkeeping exactly (including the `prior` transition
/// handling for re-tested pending certificates); the external-L̂ outer
/// products are applied serially in enumeration order on purpose — f64
/// addition is not associative, and this pins the accumulator's bits
/// across shard counts.
#[allow(clippy::too_many_arguments)]
pub fn apply_admissions(
    batch: &CandidateBatch,
    outcome: &ShardOutcome,
    store: &mut TripletStore,
    lane: &mut Vec<f64>,
    pending: &mut PendingPool,
    h_ext: &mut Mat,
    n_ext: &mut usize,
    prior: Option<&[PendingCert]>,
    counters: &mut AdmissionCounters,
) {
    debug_assert_eq!(outcome.hm.len(), batch.len());
    debug_assert_eq!(outcome.decisions.len(), batch.len());
    for t in 0..batch.len() {
        let decision = outcome.decisions[t];
        counters.candidates += 1;
        let was_l = prior.is_some_and(|p| p[t].side == CertSide::L);
        let now_l = matches!(
            decision,
            Admission::Certified {
                side: CertSide::L,
                ..
            }
        );
        if was_l && !now_l {
            h_ext.add_h_outer(batch.a.row(t), batch.b.row(t), -1.0);
            *n_ext -= 1;
        } else if !was_l && now_l {
            h_ext.add_h_outer(batch.a.row(t), batch.b.row(t), 1.0);
            *n_ext += 1;
        }
        match decision {
            Admission::Admit => {
                store.push(batch.idx[t], batch.a.row(t), batch.b.row(t), batch.h_norm[t]);
                lane.push(outcome.hm[t]);
                counters.admitted += 1;
            }
            Admission::Certified { side, expires } => {
                pending.push(PendingCert {
                    idx: batch.idx[t],
                    side,
                    expires,
                });
                match side {
                    CertSide::L => counters.rejected_l += 1,
                    CertSide::R => counters.rejected_r += 1,
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::runtime::NativeEngine;
    use crate::screening::CertFamilies;
    use crate::solver::Problem;
    use crate::triplet::{MiningStrategy, TripletMiner};
    use crate::util::quickcheck::forall;
    use crate::util::rng::Pcg64;

    fn fixture(seed: u64) -> (crate::data::Dataset, NativeEngine, Loss) {
        let mut rng = Pcg64::seed(seed);
        let ds = synthetic::gaussian_mixture("shard", 36, 4, 3, 2.6, &mut rng);
        (ds, NativeEngine::new(2), Loss::smoothed_hinge(0.05))
    }

    /// `FrameSnapshot::decide` must agree with the frame's own
    /// `admission_decision` on every candidate, at several λ.
    #[test]
    fn snapshot_decide_matches_frame() {
        let (ds, engine, loss) = fixture(11);
        let mut rng = Pcg64::seed(12);
        let store = crate::triplet::TripletStore::from_dataset(&ds, 3, &mut rng);
        let lambda0 = Problem::lambda_max(&store, &loss, &engine);
        let ones = vec![1.0; store.len()];
        let m0 = crate::linalg::psd_project(&engine.wgram(&store.a, &store.b, &ones))
            .scaled(1.0 / lambda0);
        let frame = ReferenceFrame::build(
            m0,
            lambda0,
            1e-3,
            &store,
            &engine,
            Some((&loss, CertFamilies::rrpb_only())),
        );
        let snap = FrameSnapshot::of(&frame);
        for (t, &hm) in frame.margins().iter().enumerate() {
            let hn = store.h_norm[t];
            for mul in [0.95, 0.7, 0.4, 0.1] {
                let lambda = lambda0 * mul;
                assert_eq!(
                    snap.decide(hm, hn, lambda, &loss),
                    frame.admission_decision(hm, hn, lambda, &loss),
                    "snapshot diverged at t={t} lambda={lambda}"
                );
            }
        }
    }

    /// Any shard count produces bitwise-identical margins and decisions.
    #[test]
    fn shard_count_invariance() {
        let (ds, engine, loss) = fixture(21);
        let mut miner = TripletMiner::new(&ds, 3, MiningStrategy::Exhaustive, 4096);
        let mut batch = CandidateBatch::new(ds.d());
        let sum_h = miner.sum_h_streamed(&engine, &mut batch);
        let plus = crate::linalg::psd_split(&sum_h).plus;
        let max_hq = miner.max_margin_streamed(&plus, &engine, &mut batch);
        let lambda0 = Problem::lambda_max_from_parts(max_hq, &loss);
        let m0 = plus.scaled(1.0 / lambda0);
        let empty = TripletStore::empty(ds.d());
        let frame = ReferenceFrame::build(m0, lambda0, 0.0, &empty, &engine, None);

        miner.reset();
        assert!(miner.next_into(&mut batch));
        let lambda = lambda0 * 0.8;
        let base = ShardedAdmitter::new(1).admit(&frame, &engine, &batch, lambda, &loss);
        for shards in [2, 3, 7, 16] {
            let out = ShardedAdmitter::new(shards).admit(&frame, &engine, &batch, lambda, &loss);
            assert_eq!(out.decisions, base.decisions, "decisions differ at {shards} shards");
            for t in 0..batch.len() {
                assert_eq!(
                    out.hm[t].to_bits(),
                    base.hm[t].to_bits(),
                    "margin bits differ at {shards} shards, t={t}"
                );
            }
        }
    }

    /// The injected fault degrades to serial and still produces the
    /// same bits; the admitter records the catch and the pool survives.
    #[test]
    fn injected_fault_degrades_to_serial() {
        let (ds, engine, loss) = fixture(31);
        let mut miner = TripletMiner::new(&ds, 3, MiningStrategy::Exhaustive, 4096);
        let mut batch = CandidateBatch::new(ds.d());
        let sum_h = miner.sum_h_streamed(&engine, &mut batch);
        let plus = crate::linalg::psd_split(&sum_h).plus;
        let max_hq = miner.max_margin_streamed(&plus, &engine, &mut batch);
        let lambda0 = Problem::lambda_max_from_parts(max_hq, &loss);
        let empty = TripletStore::empty(ds.d());
        let m0 = plus.scaled(1.0 / lambda0);
        let frame = ReferenceFrame::build(m0, lambda0, 0.0, &empty, &engine, None);

        miner.reset();
        assert!(miner.next_into(&mut batch));
        let lambda = lambda0 * 0.8;
        let mut clean = ShardedAdmitter::new(4);
        let base = clean.admit(&frame, &engine, &batch, lambda, &loss);
        assert!(!base.degraded);

        let mut faulty = ShardedAdmitter::new(4);
        faulty.inject_fault();
        let out = faulty.admit(&frame, &engine, &batch, lambda, &loss);
        assert!(out.degraded, "injected fault must trip the serial fallback");
        assert_eq!(faulty.faults_caught(), 1);
        assert_eq!(out.decisions, base.decisions);
        for t in 0..batch.len() {
            assert_eq!(out.hm[t].to_bits(), base.hm[t].to_bits());
        }
        // the pool and the admitter both stay usable
        let again = faulty.admit(&frame, &engine, &batch, lambda, &loss);
        assert!(!again.degraded);
        assert_eq!(again.decisions, base.decisions);
    }

    /// Property: `decide` never returns a certificate whose range fails
    /// to contain the query λ (consistency with the range forms).
    #[test]
    fn decide_certificates_contain_lambda() {
        forall("shard_decide_contains", 64, |rng| {
            let m0_norm = rng.range(0.1, 5.0);
            let eps = rng.range(0.0, 0.5);
            let lambda0 = rng.range(0.5, 3.0);
            let snap = FrameSnapshot {
                m0_norm,
                eps,
                lambda0,
            };
            let loss = Loss::smoothed_hinge(0.05);
            let hm = rng.range(-3.0, 3.0);
            let hn = rng.range(0.05, 4.0);
            let lambda = lambda0 * rng.range(0.05, 0.999);
            match snap.decide(hm, hn, lambda, &loss) {
                Admission::Admit => Ok(()),
                Admission::Certified { side, expires } => {
                    let range = match side {
                        CertSide::R => r_range(hm, hn, m0_norm, eps, lambda0, loss.r_threshold()),
                        CertSide::L => l_range(hm, hn, m0_norm, eps, lambda0, loss.l_threshold()),
                    };
                    if !range.contains(lambda) {
                        return Err(format!("certified outside its own range at λ={lambda}"));
                    }
                    if expires > lambda {
                        return Err(format!("expires {expires} above query λ {lambda}"));
                    }
                    Ok(())
                }
            }
        });
    }
}
