//! `ServeFront` — the concurrent request front end over the PR 9
//! serving layer, plus the line-oriented request protocol behind
//! `triplet-serve serve`.
//!
//! ## Two thread domains
//!
//! The front end owns a small pool of **OS worker threads**
//! (`ts-front-{i}`) whose only job is draining the request queue and
//! driving tenant sessions. They are deliberately distinct from the
//! compute [`crate::util::parallel::ThreadPool`] (`ts-pool-{n}`): a
//! front-end worker *calls into* the compute pool (via
//! [`crate::service::Session::serve`] → sharded admission → kernels)
//! and blocks until its request finishes; compute workers never block
//! on front-end state. [`Ticket::wait`] asserts it is not called from
//! a compute pool thread, so the two domains cannot deadlock by
//! construction.
//!
//! ## Actor mailboxes keep each tenant serial
//!
//! Every tenant gets an actor: a mailbox (`VecDeque` of queued
//! requests) plus an `executing` flag, both behind one small lock.
//! The shared [`BoundedQueue`] carries only tenant-index *tokens* —
//! one per accepted request. A worker popping a token tries to become
//! the tenant's **exclusive executor**: if the flag is already set the
//! token is a no-op hint (the active executor is obligated to drain
//! the mailbox before clearing the flag, and it only clears it under
//! the lock with the mailbox observed empty), otherwise the worker
//! sets the flag and drains the mailbox itself. So:
//!
//! * a tenant's requests are processed strictly one at a time, in
//!   submission order — `Session` stays `&mut self`-serial and PR 9's
//!   never-publish-partial-state invariant carries over unchanged;
//! * different tenants are driven by different workers concurrently;
//! * no request is ever stranded: while a request sits in a mailbox,
//!   either its token is still in the queue (some worker will pop it —
//!   after [`ServeFront::shutdown`] closes the queue, pops keep
//!   draining queued tokens before returning `None`) or an executor is
//!   active and must pop the request before it may deactivate.
//!
//! Submission holds the tenant lock across mailbox-push *and* token
//! push; a full queue rolls the mailbox entry back under the same
//! lock, so [`crate::service::ServiceError::QueueFull`] means
//! *nothing* was enqueued anywhere. Lock order is always
//! tenant-core → queue; workers take the queue lock and the core lock
//! only in separate critical sections, so the ordering is acyclic.
//!
//! ## Determinism
//!
//! The front end adds scheduling, not arithmetic: each request runs
//! the same `Session::serve` path on the same engine as the serial
//! schedule, and each tenant's requests run in submission order.
//! Per-tenant results are therefore bitwise identical to the serial
//! schedule at any worker count — proven across workers {1, 2, 4} in
//! `rust/tests/service_concurrent.rs`.
//!
//! ## Request protocol
//!
//! `triplet-serve serve` reads newline-delimited requests:
//!
//! ```text
//! solve <tenant> <n> <d> <classes> <seed>
//! ```
//!
//! All five fields are required; `n`/`d`/`classes`/`seed` are decimal
//! integers. The grammar is numeric-only by design — the dataset is
//! *generated* (`gaussian_mixture`, separation 2.6, seeded) rather
//! than named, so no request line can reach a panicking loader. Lines
//! over [`MAX_LINE_BYTES`], unknown commands, missing/non-numeric
//! fields and out-of-range sizes are typed [`ProtocolError`]s; unknown
//! tenants surface as `ServiceError::UnknownTenant` at submission.
//! Blank lines are [`ProtocolError::Empty`] so empty input is an
//! explicit typed outcome, never a panic.

use std::collections::BTreeMap;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

use crate::data::{synthetic, Dataset};
use crate::runtime::Engine;
use crate::util::parallel::on_pool_thread;
use crate::util::rng::Pcg64;

use super::frame_store::SharedFrameStore;
use super::queue::{BoundedQueue, PushError};
use super::session::{ServeResult, ServiceError, Session, SessionConfig};

/// Front-end shape: worker count, queue depth, shared-store geometry,
/// and the per-tenant session configuration.
#[derive(Clone, Debug)]
pub struct FrontConfig {
    /// OS worker threads draining the queue. `0` means caller-driven:
    /// no threads are spawned and requests run on whichever thread
    /// calls [`ServeFront::drain_now`] — the mode the deterministic
    /// fault tests use to pin exact queue occupancy.
    pub workers: usize,
    /// Request-queue capacity; submissions beyond it fail with
    /// [`ServiceError::QueueFull`].
    pub queue_capacity: usize,
    /// Lock shards of the shared frame store.
    pub store_shards: usize,
    /// Cached frames per store shard.
    pub store_capacity: usize,
    /// Session configuration applied to every tenant.
    pub session: SessionConfig,
}

impl Default for FrontConfig {
    fn default() -> FrontConfig {
        FrontConfig {
            workers: 2,
            queue_capacity: 64,
            store_shards: 4,
            store_capacity: 8,
            session: SessionConfig::default(),
        }
    }
}

/// Per-request submission options.
#[derive(Clone, Debug, Default)]
pub struct SubmitOptions {
    /// Give up if the request is still queued after this long; expiry
    /// completes the ticket with [`ServiceError::TimedOut`] without
    /// ever touching the tenant's session.
    pub deadline: Option<Duration>,
    /// Fault injection: panic the worker at the top of this request's
    /// solve. The panic is confined to the request (ticket resolves to
    /// [`ServiceError::WorkerPanicked`]); the tenant session and the
    /// shared store are untouched.
    pub inject_panic: bool,
}

struct ResponseState {
    result: Option<Result<ServeResult, ServiceError>>,
}

struct ResponseSlot {
    state: Mutex<ResponseState>,
    ready: Condvar,
}

impl ResponseSlot {
    fn new() -> ResponseSlot {
        ResponseSlot {
            state: Mutex::new(ResponseState { result: None }),
            ready: Condvar::new(),
        }
    }

    fn complete(&self, result: Result<ServeResult, ServiceError>) {
        let mut st = self.state.lock().unwrap_or_else(PoisonError::into_inner);
        st.result = Some(result);
        drop(st);
        self.ready.notify_all();
    }
}

/// Handle to one accepted request; resolves exactly once.
pub struct Ticket {
    slot: Arc<ResponseSlot>,
}

impl Ticket {
    /// Block until the request resolves. Panics if called from a
    /// compute pool worker — a compute thread blocking on front-end
    /// progress would invert the two thread domains (see the module
    /// docs) and can deadlock.
    pub fn wait(self) -> Result<ServeResult, ServiceError> {
        assert!(
            !on_pool_thread(),
            "Ticket::wait called from a compute pool worker; \
             front-end waits must stay out of the kernel thread domain"
        );
        let mut st = self
            .slot
            .state
            .lock()
            .unwrap_or_else(PoisonError::into_inner);
        loop {
            if let Some(result) = st.result.take() {
                return result;
            }
            st = self
                .slot
                .ready
                .wait(st)
                .unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Non-blocking poll: `Some` exactly once, after resolution.
    pub fn try_wait(&self) -> Option<Result<ServeResult, ServiceError>> {
        self.slot
            .state
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .result
            .take()
    }
}

struct QueuedRequest {
    dataset: Dataset,
    deadline: Option<Instant>,
    inject_panic: bool,
    slot: Arc<ResponseSlot>,
}

struct ActorCore {
    mailbox: VecDeque<QueuedRequest>,
    executing: bool,
}

struct TenantActor {
    core: Mutex<ActorCore>,
    /// Exclusivity comes from `ActorCore::executing`; this lock exists
    /// only to make the session shareable across worker threads, and
    /// is uncontended by construction.
    session: Mutex<Session>,
}

struct FrontShared {
    queue: BoundedQueue<usize>,
    tenants: Vec<TenantActor>,
    tenant_index: BTreeMap<String, usize>,
    store: SharedFrameStore,
    engine: Arc<dyn Engine + Send>,
    accepted: AtomicUsize,
    rejected_full: AtomicUsize,
    completed: AtomicUsize,
    timed_out: AtomicUsize,
    panics: AtomicUsize,
}

impl FrontShared {
    fn core(&self, idx: usize) -> MutexGuard<'_, ActorCore> {
        self.tenants[idx]
            .core
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
    }

    /// Process one popped token: become `idx`'s exclusive executor if
    /// nobody is, then drain the mailbox; otherwise the token is a
    /// no-op hint.
    fn drive_actor(&self, idx: usize) {
        {
            let mut core = self.core(idx);
            if core.executing || core.mailbox.is_empty() {
                return;
            }
            core.executing = true;
        }
        loop {
            let req = {
                let mut core = self.core(idx);
                match core.mailbox.pop_front() {
                    Some(req) => req,
                    None => {
                        // Deactivate only under the lock with the
                        // mailbox observed empty — the linchpin of the
                        // no-stranded-request argument (module docs).
                        core.executing = false;
                        return;
                    }
                }
            };
            self.process(idx, req);
        }
    }

    fn process(&self, idx: usize, req: QueuedRequest) {
        if let Some(deadline) = req.deadline {
            if Instant::now() >= deadline {
                // Expired in the queue: resolve without ever touching
                // the session.
                self.timed_out.fetch_add(1, Ordering::Relaxed);
                req.slot.complete(Err(ServiceError::TimedOut));
                return;
            }
        }
        let mut session = self.tenants[idx]
            .session
            .lock()
            .unwrap_or_else(PoisonError::into_inner);
        let mut cache = &self.store;
        let engine: &dyn Engine = &*self.engine;
        // The session is captured by `&mut`, not moved, so a panicking
        // request leaves the tenant's session alive for the next one;
        // serve() itself never publishes partial state on any path.
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            if req.inject_panic {
                panic!("injected front-end worker fault");
            }
            session.serve(&req.dataset, &mut cache, engine)
        }));
        let result = match outcome {
            Ok(result) => result,
            Err(_) => {
                self.panics.fetch_add(1, Ordering::Relaxed);
                Err(ServiceError::WorkerPanicked)
            }
        };
        self.completed.fetch_add(1, Ordering::Relaxed);
        req.slot.complete(result);
    }
}

/// The concurrent front end; see the module docs for the scheduling
/// and determinism arguments.
pub struct ServeFront {
    shared: Arc<FrontShared>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl ServeFront {
    /// Build a front end for the given tenants (one actor + session
    /// each). With `cfg.workers > 0`, that many `ts-front-{i}` OS
    /// threads start draining immediately; with `workers == 0` the
    /// caller drives processing via [`ServeFront::drain_now`].
    pub fn new<S: AsRef<str>>(
        cfg: FrontConfig,
        tenants: &[S],
        engine: Arc<dyn Engine + Send>,
    ) -> ServeFront {
        let mut actors = Vec::with_capacity(tenants.len());
        let mut tenant_index = BTreeMap::new();
        for t in tenants {
            let name = t.as_ref().to_string();
            tenant_index.insert(name.clone(), actors.len());
            actors.push(TenantActor {
                core: Mutex::new(ActorCore {
                    mailbox: VecDeque::new(),
                    executing: false,
                }),
                session: Mutex::new(Session::new(name, cfg.session.clone())),
            });
        }
        let shared = Arc::new(FrontShared {
            queue: BoundedQueue::new(cfg.queue_capacity),
            tenants: actors,
            tenant_index,
            store: SharedFrameStore::new(cfg.store_shards, cfg.store_capacity),
            engine,
            accepted: AtomicUsize::new(0),
            rejected_full: AtomicUsize::new(0),
            completed: AtomicUsize::new(0),
            timed_out: AtomicUsize::new(0),
            panics: AtomicUsize::new(0),
        });
        let workers = (0..cfg.workers)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("ts-front-{i}"))
                    .spawn(move || {
                        while let Some(idx) = shared.queue.pop() {
                            shared.drive_actor(idx);
                        }
                    })
                    .expect("spawn front-end worker")
            })
            .collect();
        ServeFront { shared, workers }
    }

    /// Submit one request for `tenant`. Accepted submissions return a
    /// [`Ticket`] that always resolves; rejections
    /// ([`ServiceError::UnknownTenant`], [`ServiceError::QueueFull`],
    /// [`ServiceError::ShuttingDown`]) enqueue nothing at all.
    pub fn submit(
        &self,
        tenant: &str,
        ds: &Dataset,
        opts: SubmitOptions,
    ) -> Result<Ticket, ServiceError> {
        let shared = &self.shared;
        let idx = *shared
            .tenant_index
            .get(tenant)
            .ok_or_else(|| ServiceError::UnknownTenant(tenant.to_string()))?;
        let slot = Arc::new(ResponseSlot::new());
        let req = QueuedRequest {
            dataset: ds.clone(),
            deadline: opts.deadline.map(|d| Instant::now() + d),
            inject_panic: opts.inject_panic,
            slot: Arc::clone(&slot),
        };
        // Mailbox push and token push under one lock; a failed token
        // push rolls the mailbox entry back before the lock drops, so
        // a rejected submission leaves no trace anywhere.
        let mut core = shared.core(idx);
        core.mailbox.push_back(req);
        match shared.queue.try_push(idx) {
            Ok(()) => {
                drop(core);
                shared.accepted.fetch_add(1, Ordering::Relaxed);
                Ok(Ticket { slot })
            }
            Err(PushError::Full(_)) => {
                core.mailbox.pop_back();
                drop(core);
                shared.rejected_full.fetch_add(1, Ordering::Relaxed);
                Err(ServiceError::QueueFull {
                    capacity: shared.queue.capacity(),
                })
            }
            Err(PushError::Closed(_)) => {
                core.mailbox.pop_back();
                Err(ServiceError::ShuttingDown)
            }
        }
    }

    /// Drain queued tokens on the calling thread until the queue is
    /// momentarily empty. The processing path in the `workers == 0`
    /// mode, and part of [`shutdown`](ServeFront::shutdown)'s graceful
    /// drain in every mode.
    pub fn drain_now(&self) {
        while let Some(idx) = self.shared.queue.try_pop() {
            self.shared.drive_actor(idx);
        }
    }

    /// Graceful shutdown: stop accepting, drain every queued token
    /// (worker threads keep popping until the closed queue is empty,
    /// and the caller helps), then join the workers. Every ticket
    /// accepted before shutdown resolves — zero dropped-but-
    /// acknowledged requests, asserted in the fault battery.
    pub fn shutdown(&mut self) {
        self.shared.queue.close();
        self.drain_now();
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
    }

    /// The shared frame store (for export/import and cache counters).
    pub fn store(&self) -> &SharedFrameStore {
        &self.shared.store
    }

    /// Tokens currently queued.
    pub fn pending(&self) -> usize {
        self.shared.queue.len()
    }

    /// Request-queue capacity.
    pub fn queue_capacity(&self) -> usize {
        self.shared.queue.capacity()
    }

    /// Submissions accepted (ticket issued).
    pub fn accepted(&self) -> usize {
        self.shared.accepted.load(Ordering::Relaxed)
    }

    /// Submissions bounced with [`ServiceError::QueueFull`].
    pub fn rejected_full(&self) -> usize {
        self.shared.rejected_full.load(Ordering::Relaxed)
    }

    /// Requests resolved by a worker (success, typed error, or caught
    /// panic) — excludes deadline expiries.
    pub fn completed(&self) -> usize {
        self.shared.completed.load(Ordering::Relaxed)
    }

    /// Requests that expired in the queue without touching a session.
    pub fn timed_out(&self) -> usize {
        self.shared.timed_out.load(Ordering::Relaxed)
    }

    /// Worker panics caught and confined to their request.
    pub fn panics_caught(&self) -> usize {
        self.shared.panics.load(Ordering::Relaxed)
    }

    /// Requests counted by `tenant`'s session (includes rejected ones,
    /// per [`crate::service::Session::requests`]); `None` for unknown
    /// tenants.
    pub fn session_requests(&self, tenant: &str) -> Option<usize> {
        let idx = *self.shared.tenant_index.get(tenant)?;
        Some(
            self.shared.tenants[idx]
                .session
                .lock()
                .unwrap_or_else(PoisonError::into_inner)
                .requests(),
        )
    }
}

impl Drop for ServeFront {
    fn drop(&mut self) {
        if !self.workers.is_empty() || !self.shared.queue.is_closed() {
            self.shutdown();
        }
    }
}

// ---------------------------------------------------------------------
// request protocol
// ---------------------------------------------------------------------

/// Longest request line `triplet-serve serve` accepts, in bytes.
pub const MAX_LINE_BYTES: usize = 4096;

/// Largest synthetic dataset a request may name: n ≤ 65536, d ≤ 1024,
/// 2 ≤ classes ≤ min(n, 64), n·d ≤ 2²⁰ cells.
const MAX_REQ_N: usize = 65_536;
const MAX_REQ_D: usize = 1_024;
const MAX_REQ_CLASSES: usize = 64;
const MAX_REQ_CELLS: usize = 1 << 20;

/// Typed rejection of a request line — every parse failure is one of
/// these; parsing never panics (fuzzed over arbitrary lines in
/// `rust/tests/service_protocol.rs`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ProtocolError {
    /// The line is blank (or whitespace only).
    Empty,
    /// The line exceeds [`MAX_LINE_BYTES`].
    Oversized {
        /// observed line length in bytes
        bytes: usize,
    },
    /// The leading word is not a known command.
    UnknownCommand(String),
    /// A required field is absent (truncated line).
    MissingField(&'static str),
    /// A numeric field did not parse as a decimal integer.
    BadNumber(&'static str),
    /// A field parsed but violates the size limits.
    OutOfRange(&'static str),
    /// Extra fields after a complete request.
    TrailingFields,
}

impl std::fmt::Display for ProtocolError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProtocolError::Empty => write!(f, "empty request line"),
            ProtocolError::Oversized { bytes } => {
                write!(f, "request line of {bytes} bytes exceeds {MAX_LINE_BYTES}")
            }
            ProtocolError::UnknownCommand(cmd) => write!(f, "unknown command '{cmd}'"),
            ProtocolError::MissingField(field) => write!(f, "missing field <{field}>"),
            ProtocolError::BadNumber(field) => write!(f, "field <{field}> is not an integer"),
            ProtocolError::OutOfRange(field) => write!(f, "field <{field}> is out of range"),
            ProtocolError::TrailingFields => write!(f, "trailing fields after request"),
        }
    }
}

impl std::error::Error for ProtocolError {}

/// One parsed `solve` request: which tenant, and the seeded synthetic
/// dataset shape to solve.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Request {
    /// tenant id the request is routed to
    pub tenant: String,
    /// dataset rows
    pub n: usize,
    /// dataset features
    pub d: usize,
    /// mixture classes
    pub classes: usize,
    /// generator seed
    pub seed: u64,
}

fn num_field(
    parts: &mut std::str::SplitWhitespace<'_>,
    name: &'static str,
) -> Result<u64, ProtocolError> {
    let raw = parts.next().ok_or(ProtocolError::MissingField(name))?;
    raw.parse::<u64>().map_err(|_| ProtocolError::BadNumber(name))
}

/// Parse one request line (`solve <tenant> <n> <d> <classes> <seed>`);
/// see the module docs for the grammar and limits.
pub fn parse_request(line: &str) -> Result<Request, ProtocolError> {
    if line.len() > MAX_LINE_BYTES {
        return Err(ProtocolError::Oversized { bytes: line.len() });
    }
    let mut parts = line.split_whitespace();
    let cmd = parts.next().ok_or(ProtocolError::Empty)?;
    if cmd != "solve" {
        return Err(ProtocolError::UnknownCommand(cmd.to_string()));
    }
    let tenant = parts
        .next()
        .ok_or(ProtocolError::MissingField("tenant"))?
        .to_string();
    let n = num_field(&mut parts, "n")? as usize;
    let d = num_field(&mut parts, "d")? as usize;
    let classes = num_field(&mut parts, "classes")? as usize;
    let seed = num_field(&mut parts, "seed")?;
    if parts.next().is_some() {
        return Err(ProtocolError::TrailingFields);
    }
    if n == 0 || n > MAX_REQ_N {
        return Err(ProtocolError::OutOfRange("n"));
    }
    if d == 0 || d > MAX_REQ_D {
        return Err(ProtocolError::OutOfRange("d"));
    }
    // the generator requires ≥ 2 classes and n ≥ classes; enforce both
    // here so `request_dataset` can never hit a generator assert
    if classes < 2 || classes > classes_limit(n) {
        return Err(ProtocolError::OutOfRange("classes"));
    }
    if n * d > MAX_REQ_CELLS {
        return Err(ProtocolError::OutOfRange("n*d"));
    }
    Ok(Request {
        tenant,
        n,
        d,
        classes,
        seed,
    })
}

fn classes_limit(n: usize) -> usize {
    MAX_REQ_CLASSES.min(n)
}

/// Materialize the dataset a [`Request`] names: a seeded
/// `gaussian_mixture` at separation 2.6, so identical requests hash to
/// identical fingerprints (and repeat requests hit the frame cache).
pub fn request_dataset(req: &Request) -> Dataset {
    let mut rng = Pcg64::seed(req.seed);
    let name = format!(
        "req-{}-{}x{}c{}s{}",
        req.tenant, req.n, req.d, req.classes, req.seed
    );
    synthetic::gaussian_mixture(&name, req.n, req.d, req.classes, 2.6, &mut rng)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_the_canonical_line() {
        let req = parse_request("solve alice 24 4 3 7").expect("parses");
        assert_eq!(
            req,
            Request {
                tenant: "alice".to_string(),
                n: 24,
                d: 4,
                classes: 3,
                seed: 7,
            }
        );
        let ds = request_dataset(&req);
        assert_eq!(ds.n(), 24);
        assert_eq!(ds.d(), 4);
        let again = request_dataset(&req);
        assert_eq!(
            crate::service::fingerprint(&ds, 3),
            crate::service::fingerprint(&again, 3),
            "identical requests must fingerprint identically"
        );
    }

    #[test]
    fn parse_rejects_each_malformation_with_its_own_error() {
        assert_eq!(parse_request(""), Err(ProtocolError::Empty));
        assert_eq!(parse_request("   \t "), Err(ProtocolError::Empty));
        assert_eq!(
            parse_request("frobnicate alice 8 3 2 1"),
            Err(ProtocolError::UnknownCommand("frobnicate".to_string()))
        );
        assert_eq!(
            parse_request("solve"),
            Err(ProtocolError::MissingField("tenant"))
        );
        assert_eq!(
            parse_request("solve alice 8 3"),
            Err(ProtocolError::MissingField("classes"))
        );
        assert_eq!(
            parse_request("solve alice eight 3 2 1"),
            Err(ProtocolError::BadNumber("n"))
        );
        assert_eq!(
            parse_request("solve alice 8 3 2 1 extra"),
            Err(ProtocolError::TrailingFields)
        );
        assert_eq!(
            parse_request("solve alice 0 3 2 1"),
            Err(ProtocolError::OutOfRange("n"))
        );
        assert_eq!(
            parse_request("solve alice 8 2048 2 1"),
            Err(ProtocolError::OutOfRange("d"))
        );
        assert_eq!(
            parse_request("solve alice 8 3 9 1"),
            Err(ProtocolError::OutOfRange("classes")),
            "classes must not exceed n"
        );
        assert_eq!(
            parse_request("solve alice 8 3 1 1"),
            Err(ProtocolError::OutOfRange("classes")),
            "the mixture generator needs at least 2 classes"
        );
        assert_eq!(
            parse_request("solve alice 65536 1024 2 1"),
            Err(ProtocolError::OutOfRange("n*d"))
        );
        let long = format!("solve alice 8 3 2 {}", "9".repeat(MAX_LINE_BYTES));
        assert_eq!(
            parse_request(&long),
            Err(ProtocolError::Oversized { bytes: long.len() })
        );
    }
}
