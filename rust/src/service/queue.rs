//! `BoundedQueue` — the MPMC request queue behind the serving front
//! end's backpressure.
//!
//! A fixed-capacity FIFO shared by every submitter and every front-end
//! worker. `try_push` never blocks: a full queue is an immediate
//! [`PushError::Full`] that hands the item back, which is what turns
//! overload into the typed `ServiceError::QueueFull` at the
//! [`crate::service::ServeFront`] layer instead of unbounded memory
//! growth. `pop` blocks until an item arrives or the queue is closed;
//! after [`close`](BoundedQueue::close) it keeps draining whatever is
//! already queued (graceful shutdown never drops an accepted request)
//! and only then starts returning `None`.
//!
//! The queue carries plain values and takes its one lock only for
//! pointer-sized pushes and pops — requests themselves live in the
//! per-tenant actor mailboxes, so the queue never holds a dataset.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex, PoisonError};

/// Why a `try_push` did not enqueue; the rejected item is handed back
/// so the caller can roll back whatever bookkeeping preceded the push.
#[derive(Debug, PartialEq, Eq)]
pub enum PushError<T> {
    /// The queue is at capacity — backpressure, not failure.
    Full(T),
    /// The queue was closed (shutdown in progress); nothing new is
    /// accepted.
    Closed(T),
}

struct QueueState<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Bounded multi-producer/multi-consumer FIFO; see the module docs.
pub struct BoundedQueue<T> {
    state: Mutex<QueueState<T>>,
    capacity: usize,
    available: Condvar,
}

impl<T> BoundedQueue<T> {
    /// An open queue holding at most `capacity` items (`capacity` is
    /// clamped to ≥ 1).
    pub fn new(capacity: usize) -> BoundedQueue<T> {
        BoundedQueue {
            state: Mutex::new(QueueState {
                items: VecDeque::new(),
                closed: false,
            }),
            capacity: capacity.max(1),
            available: Condvar::new(),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, QueueState<T>> {
        // The lock is only ever held across non-panicking VecDeque
        // operations, but recover from poisoning anyway: a poisoned
        // queue would otherwise cascade one worker's panic into every
        // submitter.
        self.state.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Maximum number of queued items.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Items currently queued.
    pub fn len(&self) -> usize {
        self.lock().items.len()
    }

    /// Whether nothing is currently queued.
    pub fn is_empty(&self) -> bool {
        self.lock().items.is_empty()
    }

    /// Whether [`close`](BoundedQueue::close) has been called.
    pub fn is_closed(&self) -> bool {
        self.lock().closed
    }

    /// Enqueue `item` without blocking. Fails with the item handed
    /// back if the queue is full ([`PushError::Full`]) or closed
    /// ([`PushError::Closed`]).
    pub fn try_push(&self, item: T) -> Result<(), PushError<T>> {
        let mut st = self.lock();
        if st.closed {
            return Err(PushError::Closed(item));
        }
        if st.items.len() >= self.capacity {
            return Err(PushError::Full(item));
        }
        st.items.push_back(item);
        drop(st);
        self.available.notify_one();
        Ok(())
    }

    /// Block until an item is available and dequeue it. Returns `None`
    /// only once the queue is closed **and** fully drained — pending
    /// items always come out first.
    pub fn pop(&self) -> Option<T> {
        let mut st = self.lock();
        loop {
            if let Some(item) = st.items.pop_front() {
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self
                .available
                .wait(st)
                .unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Dequeue without blocking: `None` means "nothing queued right
    /// now", whether or not the queue is closed.
    pub fn try_pop(&self) -> Option<T> {
        self.lock().items.pop_front()
    }

    /// Close the queue: subsequent pushes fail with
    /// [`PushError::Closed`], blocked `pop`s wake, and pops keep
    /// draining already-queued items before returning `None`.
    pub fn close(&self) {
        self.lock().closed = true;
        self.available.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order_and_capacity() {
        let q = BoundedQueue::new(2);
        assert_eq!(q.capacity(), 2);
        assert!(q.is_empty());
        q.try_push(1).expect("first push fits");
        q.try_push(2).expect("second push fits");
        assert_eq!(q.len(), 2);
        assert_eq!(q.try_push(3), Err(PushError::Full(3)), "third push bounces");
        assert_eq!(q.pop(), Some(1), "FIFO order");
        q.try_push(3).expect("space freed");
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
        assert_eq!(q.try_pop(), None);
    }

    #[test]
    fn zero_capacity_is_clamped() {
        let q = BoundedQueue::new(0);
        assert_eq!(q.capacity(), 1);
        q.try_push(7).expect("clamped capacity admits one item");
    }

    #[test]
    fn close_drains_then_ends() {
        let q = BoundedQueue::new(4);
        q.try_push("a").expect("push");
        q.try_push("b").expect("push");
        q.close();
        assert!(q.is_closed());
        assert_eq!(
            q.try_push("c"),
            Err(PushError::Closed("c")),
            "closed queue rejects new items"
        );
        // graceful drain: queued items still come out, then None
        assert_eq!(q.pop(), Some("a"));
        assert_eq!(q.pop(), Some("b"));
        assert_eq!(q.pop(), None);
        assert_eq!(q.pop(), None, "closed + drained stays terminal");
    }

    #[test]
    fn close_wakes_blocked_consumers() {
        let q = Arc::new(BoundedQueue::<usize>::new(2));
        let q2 = Arc::clone(&q);
        let waiter = std::thread::spawn(move || q2.pop());
        // give the consumer a moment to block, then close
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert_eq!(waiter.join().expect("no panic"), None);
    }

    #[test]
    fn concurrent_producers_and_consumers_conserve_items() {
        let q = Arc::new(BoundedQueue::<usize>::new(8));
        let mut producers = Vec::new();
        for p in 0..4 {
            let q = Arc::clone(&q);
            producers.push(std::thread::spawn(move || {
                let mut pushed = 0usize;
                for i in 0..64 {
                    let mut item = p * 1000 + i;
                    loop {
                        match q.try_push(item) {
                            Ok(()) => {
                                pushed += 1;
                                break;
                            }
                            Err(PushError::Full(back)) => {
                                item = back;
                                std::thread::yield_now();
                            }
                            Err(PushError::Closed(_)) => unreachable!("queue stays open"),
                        }
                    }
                }
                pushed
            }));
        }
        let mut consumers = Vec::new();
        for _ in 0..2 {
            let q = Arc::clone(&q);
            consumers.push(std::thread::spawn(move || {
                let mut got = 0usize;
                while q.pop().is_some() {
                    got += 1;
                }
                got
            }));
        }
        let pushed: usize = producers
            .into_iter()
            .map(|h| h.join().expect("producer ok"))
            .sum();
        q.close();
        let got: usize = consumers
            .into_iter()
            .map(|h| h.join().expect("consumer ok"))
            .sum();
        assert_eq!(pushed, 4 * 64);
        assert_eq!(got, pushed, "every pushed item is popped exactly once");
    }
}
