//! `FrameStore` — an LRU cache of solved regularization paths keyed by
//! dataset fingerprint.
//!
//! ## Fingerprint scheme
//!
//! The key is a 128-bit FNV-1a hash over everything that determines a
//! tenant's solve: `n`, `d`, the triplet-construction `k`, every label,
//! and the raw IEEE-754 bit pattern of every feature value (so `-0.0`
//! vs `0.0` or a 1-ulp perturbation changes the key — bitwise equality
//! is exactly the granularity at which the service guarantees replay).
//! Because a 128-bit hash can still collide in principle, every entry
//! keeps the dataset it was keyed from and a lookup verifies **bitwise
//! equality** of rows + labels + `k` before reporting a hit: a mutated
//! dataset can never be served a stale frame, no matter what the hash
//! does (`rust/tests/service_safety.rs` holds property tests to this).
//!
//! A hit returns the cached [`CachedSolve`] without touching the
//! solver, the screening rules, or the admission pipeline — zero rule
//! evaluations by construction (asserted in the safety battery and
//! gated in `benches/screening.rs`).
//!
//! ## Shared wrapper and the cache trait (PR 10)
//!
//! [`SharedFrameStore`] makes the store drivable from many OS threads
//! at once: N independent `Mutex<FrameStore>` lock shards, with every
//! operation routed by `fingerprint % N`. Because the routing is a pure
//! function of the key, shard `i` observes *exactly* the subsequence of
//! operations a serial [`FrameStore`] would observe if fed only those
//! keys — its hit/miss/LRU/eviction behavior is the serial store's by
//! construction (one shard **is** the serial store), which the
//! equivalence property test replays against manually-routed serial
//! stores. [`FrameCache`] abstracts over the two so
//! [`crate::service::Session::serve`] runs unchanged against either.
//!
//! ## Frame codec (PR 10)
//!
//! [`encode_frame`]/[`decode_frame`] give every cached solve a
//! versioned, fingerprint-stamped byte format (magic `TSFR`): all f64
//! payloads travel as raw IEEE-754 bit patterns so a round trip is
//! bitwise exact, the fingerprint stamp must re-verify against the
//! *decoded* dataset, and a 128-bit FNV-1a trailer rejects corruption.
//! `export_bytes`/`import_bytes` wrap whole stores in a `TSFS`
//! container so frames survive process boundaries
//! (`triplet-serve export-frames` / `serve --import-frames`); every
//! rejection is a typed [`CodecError`], never a panic.

use std::sync::{Mutex, MutexGuard, PoisonError};

use crate::data::Dataset;
use crate::linalg::Mat;

/// 128-bit FNV-1a offset basis.
const FNV_OFFSET: u128 = 0x6c62272e07bb014262b821756295c58d;
/// 128-bit FNV-1a prime.
const FNV_PRIME: u128 = 0x0000000001000000000000000000013b;

fn fnv_mix(h: &mut u128, bytes: &[u8]) {
    for &b in bytes {
        *h ^= b as u128;
        *h = h.wrapping_mul(FNV_PRIME);
    }
}

/// 128-bit fingerprint of `(dataset, k)`: FNV-1a over the dimensions,
/// `k`, the labels, and the bit patterns of every feature value.
pub fn fingerprint(ds: &Dataset, k: usize) -> u128 {
    let mut h = FNV_OFFSET;
    fnv_mix(&mut h, &(ds.n() as u64).to_le_bytes());
    fnv_mix(&mut h, &(ds.d() as u64).to_le_bytes());
    fnv_mix(&mut h, &(k as u64).to_le_bytes());
    for &y in &ds.y {
        fnv_mix(&mut h, &(y as u64).to_le_bytes());
    }
    for &x in ds.x.as_slice() {
        fnv_mix(&mut h, &x.to_bits().to_le_bytes());
    }
    h
}

/// Bitwise dataset equality at the fingerprint's granularity: same
/// shape, same labels, same feature bit patterns.
fn same_dataset(a: &Dataset, b: &Dataset) -> bool {
    a.n() == b.n()
        && a.d() == b.d()
        && a.y == b.y
        && a.x
            .as_slice()
            .iter()
            .zip(b.x.as_slice())
            .all(|(p, q)| p.to_bits() == q.to_bits())
}

/// Everything a warm hit replays without re-solving: the final iterate
/// and path position plus the screening outcome summary of the original
/// request.
#[derive(Clone, Debug)]
pub struct CachedSolve {
    /// final Mahalanobis matrix of the path
    pub m_final: Mat,
    /// λ the path stopped at
    pub lambda: f64,
    /// λ_max the cold path started from
    pub lambda_max: f64,
    /// ε-accuracy of `m_final` at `lambda` (from the duality gap)
    pub eps: f64,
    /// reduced primal at the final step
    pub p: f64,
    /// λ steps the cold path took
    pub steps: usize,
    /// `(i, j, l)` ids admitted into the final workset, admission order
    pub admitted_idx: Vec<(u32, u32, u32)>,
    /// triplets screened into L* at the final step
    pub screened_l: usize,
    /// triplets screened into R* at the final step
    pub screened_r: usize,
}

struct Entry {
    key: u128,
    k: usize,
    dataset: Dataset,
    solve: CachedSolve,
}

/// LRU cache of solved frames keyed by [`fingerprint`]; see the module
/// docs for the scheme and the staleness guarantee.
pub struct FrameStore {
    capacity: usize,
    /// recency order: index 0 = least recently used, last = most recent
    entries: Vec<Entry>,
    hits: usize,
    misses: usize,
    insertions: usize,
    evictions: usize,
}

impl FrameStore {
    /// An empty store holding at most `capacity` solved frames
    /// (`capacity` is clamped to ≥ 1).
    pub fn new(capacity: usize) -> FrameStore {
        FrameStore {
            capacity: capacity.max(1),
            entries: Vec::new(),
            hits: 0,
            misses: 0,
            insertions: 0,
            evictions: 0,
        }
    }

    /// Cached solves currently held.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether the store holds no entries.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Maximum number of cached solves.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Lookups that returned a verified hit.
    pub fn hits(&self) -> usize {
        self.hits
    }

    /// Lookups that missed (or failed bitwise verification).
    pub fn misses(&self) -> usize {
        self.misses
    }

    /// Entries inserted over the store's lifetime.
    pub fn insertions(&self) -> usize {
        self.insertions
    }

    /// Entries evicted to respect the capacity.
    pub fn evictions(&self) -> usize {
        self.evictions
    }

    /// Look up the solved frame for `(ds, k)`. A hit requires both the
    /// fingerprint match **and** bitwise dataset equality (stale frames
    /// are unreachable even under hash collision) and promotes the
    /// entry to most-recently-used.
    pub fn lookup(&mut self, ds: &Dataset, k: usize) -> Option<&CachedSolve> {
        let key = fingerprint(ds, k);
        let pos = self
            .entries
            .iter()
            .position(|e| e.key == key && e.k == k && same_dataset(&e.dataset, ds));
        match pos {
            Some(i) => {
                self.hits += 1;
                let entry = self.entries.remove(i);
                self.entries.push(entry);
                Some(&self.entries.last().expect("just pushed").solve)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert (or replace) the solved frame for `(ds, k)` as the
    /// most-recently-used entry, evicting the least-recently-used one
    /// if the store is at capacity. The dataset is copied into the
    /// entry for the bitwise verification on later lookups.
    pub fn insert(&mut self, ds: &Dataset, k: usize, solve: CachedSolve) {
        let key = fingerprint(ds, k);
        if let Some(i) = self
            .entries
            .iter()
            .position(|e| e.key == key && e.k == k && same_dataset(&e.dataset, ds))
        {
            self.entries.remove(i);
        } else if self.entries.len() >= self.capacity {
            self.entries.remove(0);
            self.evictions += 1;
        }
        self.insertions += 1;
        self.entries.push(Entry {
            key,
            k,
            dataset: ds.clone(),
            solve,
        });
    }

    /// Serialize every resident frame (LRU → MRU order, so an import
    /// reconstructs the recency order) into a `TSFS` container; see the
    /// module docs for the format.
    pub fn export_bytes(&self) -> Vec<u8> {
        let blobs: Vec<Vec<u8>> = self
            .entries
            .iter()
            .map(|e| encode_frame(&e.dataset, e.k, &e.solve))
            .collect();
        container_from(&blobs)
    }

    /// Import every frame of a `TSFS` container (in container order, so
    /// recency is preserved), inserting each as if it had just been
    /// solved. Returns the number of frames imported; any malformed
    /// byte is a typed [`CodecError`] and nothing before the error is
    /// rolled back (each frame is self-validating, so partial imports
    /// only ever contain verified frames).
    pub fn import_bytes(&mut self, bytes: &[u8]) -> Result<usize, CodecError> {
        let frames = split_container(bytes)?;
        let mut imported = 0usize;
        for blob in frames {
            let (ds, k, solve) = decode_frame(blob)?;
            self.insert(&ds, k, solve);
            imported += 1;
        }
        Ok(imported)
    }
}

/// What [`crate::service::Session::serve`] needs from a frame cache:
/// an owned copy of a verified hit, and publication of a fresh solve.
/// Implemented by the single-owner [`FrameStore`] (the serial serving
/// path) and by `&`[`SharedFrameStore`] (the concurrent front end —
/// interior mutability behind the lock shards, so worker threads share
/// one store through a plain shared reference).
pub trait FrameCache {
    /// Verified lookup of `(ds, k)`; a hit is returned by value (the
    /// serve path clones the cached fields anyway) and promotes the
    /// entry to most-recently-used.
    fn lookup_cached(&mut self, ds: &Dataset, k: usize) -> Option<CachedSolve>;
    /// Publish a completed solve for `(ds, k)` as the newest entry.
    fn publish(&mut self, ds: &Dataset, k: usize, solve: CachedSolve);
}

impl FrameCache for FrameStore {
    fn lookup_cached(&mut self, ds: &Dataset, k: usize) -> Option<CachedSolve> {
        self.lookup(ds, k).cloned()
    }

    fn publish(&mut self, ds: &Dataset, k: usize, solve: CachedSolve) {
        self.insert(ds, k, solve);
    }
}

impl FrameCache for &SharedFrameStore {
    fn lookup_cached(&mut self, ds: &Dataset, k: usize) -> Option<CachedSolve> {
        SharedFrameStore::lookup(self, ds, k)
    }

    fn publish(&mut self, ds: &Dataset, k: usize, solve: CachedSolve) {
        SharedFrameStore::insert(self, ds, k, solve);
    }
}

/// A [`FrameStore`] shareable across OS threads: N `Mutex<FrameStore>`
/// lock shards with every operation routed by `fingerprint % N`. See
/// the module docs for the serial-equivalence argument; the property
/// test in `rust/tests/service_concurrent.rs` replays it against
/// manually-routed serial stores.
pub struct SharedFrameStore {
    shards: Vec<Mutex<FrameStore>>,
}

impl SharedFrameStore {
    /// A store with `shards` lock shards (clamped to ≥ 1), each an
    /// independent serial [`FrameStore`] holding at most
    /// `capacity_per_shard` frames.
    pub fn new(shards: usize, capacity_per_shard: usize) -> SharedFrameStore {
        let n = shards.max(1);
        SharedFrameStore {
            shards: (0..n)
                .map(|_| Mutex::new(FrameStore::new(capacity_per_shard)))
                .collect(),
        }
    }

    fn shard(&self, i: usize) -> MutexGuard<'_, FrameStore> {
        // Locks are held only across non-panicking FrameStore calls;
        // recover from poisoning so one worker's panic elsewhere can
        // never wedge the cache for every tenant.
        self.shards[i].lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Number of lock shards.
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// Which lock shard `(ds, k)` routes to — a pure function of the
    /// fingerprint, exposed so the equivalence test can route the same
    /// operations through serial stores.
    pub fn shard_of(&self, ds: &Dataset, k: usize) -> usize {
        (fingerprint(ds, k) % self.shards.len() as u128) as usize
    }

    /// Verified lookup (fingerprint + bitwise dataset equality) on the
    /// routed shard; a hit is returned by value and promotes the entry
    /// to most-recently-used within its shard.
    pub fn lookup(&self, ds: &Dataset, k: usize) -> Option<CachedSolve> {
        let i = self.shard_of(ds, k);
        self.shard(i).lookup(ds, k).cloned()
    }

    /// Insert (or replace) the solved frame for `(ds, k)` on the
    /// routed shard, evicting that shard's LRU entry at capacity.
    pub fn insert(&self, ds: &Dataset, k: usize, solve: CachedSolve) {
        let i = self.shard_of(ds, k);
        self.shard(i).insert(ds, k, solve);
    }

    /// Cached solves currently held, across all shards.
    pub fn len(&self) -> usize {
        (0..self.shards.len()).map(|i| self.shard(i).len()).sum()
    }

    /// Whether no shard holds an entry.
    pub fn is_empty(&self) -> bool {
        (0..self.shards.len()).all(|i| self.shard(i).is_empty())
    }

    /// Total capacity (shards × per-shard capacity).
    pub fn capacity(&self) -> usize {
        (0..self.shards.len())
            .map(|i| self.shard(i).capacity())
            .sum()
    }

    /// Verified hits across all shards.
    pub fn hits(&self) -> usize {
        (0..self.shards.len()).map(|i| self.shard(i).hits()).sum()
    }

    /// Misses (or failed verifications) across all shards.
    pub fn misses(&self) -> usize {
        (0..self.shards.len()).map(|i| self.shard(i).misses()).sum()
    }

    /// Lifetime insertions across all shards.
    pub fn insertions(&self) -> usize {
        (0..self.shards.len())
            .map(|i| self.shard(i).insertions())
            .sum()
    }

    /// Capacity evictions across all shards.
    pub fn evictions(&self) -> usize {
        (0..self.shards.len())
            .map(|i| self.shard(i).evictions())
            .sum()
    }

    /// Serialize every resident frame (shard 0 → N, LRU → MRU inside
    /// each) into one `TSFS` container.
    pub fn export_bytes(&self) -> Vec<u8> {
        let mut blobs: Vec<Vec<u8>> = Vec::new();
        for i in 0..self.shards.len() {
            let store = self.shard(i);
            for e in &store.entries {
                blobs.push(encode_frame(&e.dataset, e.k, &e.solve));
            }
        }
        container_from(&blobs)
    }

    /// Import every frame of a `TSFS` container, routing each to its
    /// fingerprint shard. Returns the number of frames imported.
    pub fn import_bytes(&self, bytes: &[u8]) -> Result<usize, CodecError> {
        let frames = split_container(bytes)?;
        let mut imported = 0usize;
        for blob in frames {
            let (ds, k, solve) = decode_frame(blob)?;
            self.insert(&ds, k, solve);
            imported += 1;
        }
        Ok(imported)
    }
}

// ---------------------------------------------------------------------
// frame codec
// ---------------------------------------------------------------------

/// Magic prefix of a single serialized frame.
const FRAME_MAGIC: [u8; 4] = *b"TSFR";
/// Magic prefix of a multi-frame store container.
const STORE_MAGIC: [u8; 4] = *b"TSFS";
/// Current codec version; bumped on any layout change.
const CODEC_VERSION: u32 = 1;
/// Bytes of the FNV-1a trailer at the end of every frame blob.
const CHECKSUM_BYTES: usize = 16;

/// Typed rejection of serialized frame bytes — every way an import can
/// fail, none of them a panic.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CodecError {
    /// The byte stream ends before a declared field does.
    Truncated,
    /// The magic prefix is not `TSFR` (frame) / `TSFS` (container).
    BadMagic,
    /// The version field names a layout this build does not read.
    BadVersion {
        /// the version found in the byte stream
        found: u32,
    },
    /// The FNV-1a trailer does not match the payload — corruption.
    BadChecksum,
    /// The fingerprint stamp does not match the decoded `(dataset, k)`
    /// — the frame was stamped for different data.
    FingerprintMismatch,
    /// A structurally invalid field (impossible length, empty dataset,
    /// non-UTF-8 name, trailing bytes).
    Malformed(&'static str),
}

impl std::fmt::Display for CodecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CodecError::Truncated => write!(f, "frame bytes truncated"),
            CodecError::BadMagic => write!(f, "bad frame magic"),
            CodecError::BadVersion { found } => {
                write!(f, "unsupported frame version {found} (expected {CODEC_VERSION})")
            }
            CodecError::BadChecksum => write!(f, "frame checksum mismatch"),
            CodecError::FingerprintMismatch => {
                write!(f, "fingerprint stamp does not match the decoded dataset")
            }
            CodecError::Malformed(what) => write!(f, "malformed frame: {what}"),
        }
    }
}

impl std::error::Error for CodecError {}

/// The 128-bit FNV-1a digest the codec stamps at the end of every
/// frame blob — exposed so tools (and the corruption battery) can
/// re-stamp deliberately tampered bytes.
pub fn frame_checksum(bytes: &[u8]) -> u128 {
    let mut h = FNV_OFFSET;
    fnv_mix(&mut h, bytes);
    h
}

fn push_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn push_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Serialize one solved frame; see the module docs for the layout.
/// Every f64 travels as its raw bit pattern, so
/// [`decode_frame`] ∘ [`encode_frame`] is bitwise identity
/// (quickcheck'd in `rust/tests/service_safety.rs`).
pub fn encode_frame(ds: &Dataset, k: usize, solve: &CachedSolve) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&FRAME_MAGIC);
    push_u32(&mut out, CODEC_VERSION);
    out.extend_from_slice(&fingerprint(ds, k).to_le_bytes());

    push_u64(&mut out, k as u64);
    let name = ds.name.as_bytes();
    push_u64(&mut out, name.len() as u64);
    out.extend_from_slice(name);
    push_u64(&mut out, ds.n() as u64);
    push_u64(&mut out, ds.d() as u64);
    for &y in &ds.y {
        push_u64(&mut out, y as u64);
    }
    for &x in ds.x.as_slice() {
        push_u64(&mut out, x.to_bits());
    }

    push_u64(&mut out, solve.m_final.rows() as u64);
    push_u64(&mut out, solve.m_final.cols() as u64);
    for &m in solve.m_final.as_slice() {
        push_u64(&mut out, m.to_bits());
    }
    push_u64(&mut out, solve.lambda.to_bits());
    push_u64(&mut out, solve.lambda_max.to_bits());
    push_u64(&mut out, solve.eps.to_bits());
    push_u64(&mut out, solve.p.to_bits());
    push_u64(&mut out, solve.steps as u64);
    push_u64(&mut out, solve.admitted_idx.len() as u64);
    for &(i, j, l) in &solve.admitted_idx {
        out.extend_from_slice(&i.to_le_bytes());
        out.extend_from_slice(&j.to_le_bytes());
        out.extend_from_slice(&l.to_le_bytes());
    }
    push_u64(&mut out, solve.screened_l as u64);
    push_u64(&mut out, solve.screened_r as u64);

    let sum = frame_checksum(&out);
    out.extend_from_slice(&sum.to_le_bytes());
    out
}

/// Bounds-checked little-endian reader over a frame body.
struct Cursor<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], CodecError> {
        let end = self.pos.checked_add(n).ok_or(CodecError::Truncated)?;
        if end > self.bytes.len() {
            return Err(CodecError::Truncated);
        }
        let out = &self.bytes[self.pos..end];
        self.pos = end;
        Ok(out)
    }

    fn u32(&mut self) -> Result<u32, CodecError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, CodecError> {
        let b = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(u64::from_le_bytes(a))
    }

    fn u128(&mut self) -> Result<u128, CodecError> {
        let b = self.take(16)?;
        let mut a = [0u8; 16];
        a.copy_from_slice(b);
        Ok(u128::from_le_bytes(a))
    }

    fn f64_bits(&mut self) -> Result<f64, CodecError> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// A length field that must still fit in the unread remainder at
    /// `elem_bytes` per element — checked *before* any allocation, so
    /// a corrupted length can never demand absurd memory.
    fn len_field(&mut self, elem_bytes: usize) -> Result<usize, CodecError> {
        let n = self.u64()? as usize;
        let need = n
            .checked_mul(elem_bytes)
            .ok_or(CodecError::Malformed("length overflow"))?;
        if self.pos.checked_add(need).ok_or(CodecError::Truncated)? > self.bytes.len() {
            return Err(CodecError::Truncated);
        }
        Ok(n)
    }
}

/// Decode one frame blob back into its `(dataset, k, solve)` triple.
/// Validation order: magic, checksum trailer, version, structure, then
/// the fingerprint stamp against the *decoded* dataset — so corruption,
/// version skew and mis-stamped frames each surface as their own typed
/// [`CodecError`].
pub fn decode_frame(bytes: &[u8]) -> Result<(Dataset, usize, CachedSolve), CodecError> {
    if bytes.len() < 4 {
        return Err(CodecError::Truncated);
    }
    if bytes[0..4] != FRAME_MAGIC {
        return Err(CodecError::BadMagic);
    }
    if bytes.len() < 4 + 4 + 16 + CHECKSUM_BYTES {
        return Err(CodecError::Truncated);
    }
    let payload_end = bytes.len() - CHECKSUM_BYTES;
    let mut trailer = [0u8; CHECKSUM_BYTES];
    trailer.copy_from_slice(&bytes[payload_end..]);
    if frame_checksum(&bytes[..payload_end]) != u128::from_le_bytes(trailer) {
        return Err(CodecError::BadChecksum);
    }

    let mut c = Cursor {
        bytes: &bytes[..payload_end],
        pos: 4,
    };
    let version = c.u32()?;
    if version != CODEC_VERSION {
        return Err(CodecError::BadVersion { found: version });
    }
    let stamp = c.u128()?;

    let k = c.u64()? as usize;
    let name_len = c.len_field(1)?;
    let name = std::str::from_utf8(c.take(name_len)?)
        .map_err(|_| CodecError::Malformed("dataset name is not UTF-8"))?
        .to_string();
    let n = c.u64()? as usize;
    let d = c.u64()? as usize;
    if n == 0 || d == 0 {
        return Err(CodecError::Malformed("empty dataset"));
    }
    let n_checked = {
        // the label and feature lengths are implied by (n, d); check
        // them against the remainder before allocating either
        let cells = n.checked_mul(d).ok_or(CodecError::Malformed("n*d overflow"))?;
        let need = n
            .checked_add(cells)
            .and_then(|w| w.checked_mul(8))
            .ok_or(CodecError::Malformed("n*d overflow"))?;
        if c.pos.checked_add(need).ok_or(CodecError::Truncated)? > c.bytes.len() {
            return Err(CodecError::Truncated);
        }
        cells
    };
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        y.push(c.u64()? as usize);
    }
    let mut x = Vec::with_capacity(n_checked);
    for _ in 0..n_checked {
        x.push(c.f64_bits()?);
    }
    let ds = Dataset::new(name, Mat::from_rows(n, d, x), y);

    let m_rows = c.u64()? as usize;
    let m_cols = c.u64()? as usize;
    let m_cells = {
        let cells = m_rows
            .checked_mul(m_cols)
            .ok_or(CodecError::Malformed("matrix shape overflow"))?;
        if c.pos
            .checked_add(cells.checked_mul(8).ok_or(CodecError::Malformed("matrix shape overflow"))?)
            .ok_or(CodecError::Truncated)?
            > c.bytes.len()
        {
            return Err(CodecError::Truncated);
        }
        cells
    };
    let mut m = Vec::with_capacity(m_cells);
    for _ in 0..m_cells {
        m.push(c.f64_bits()?);
    }
    let solve = CachedSolve {
        m_final: Mat::from_rows(m_rows, m_cols, m),
        lambda: c.f64_bits()?,
        lambda_max: c.f64_bits()?,
        eps: c.f64_bits()?,
        p: c.f64_bits()?,
        steps: c.u64()? as usize,
        admitted_idx: {
            let len = c.len_field(12)?;
            let mut idx = Vec::with_capacity(len);
            for _ in 0..len {
                let i = c.u32()?;
                let j = c.u32()?;
                let l = c.u32()?;
                idx.push((i, j, l));
            }
            idx
        },
        screened_l: c.u64()? as usize,
        screened_r: c.u64()? as usize,
    };
    if c.pos != c.bytes.len() {
        return Err(CodecError::Malformed("trailing bytes after frame payload"));
    }
    if fingerprint(&ds, k) != stamp {
        return Err(CodecError::FingerprintMismatch);
    }
    Ok((ds, k, solve))
}

/// Wrap per-frame blobs in the `TSFS` container layout.
fn container_from(blobs: &[Vec<u8>]) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&STORE_MAGIC);
    push_u32(&mut out, CODEC_VERSION);
    push_u64(&mut out, blobs.len() as u64);
    for blob in blobs {
        push_u64(&mut out, blob.len() as u64);
        out.extend_from_slice(blob);
    }
    out
}

/// Split a `TSFS` container into its per-frame blobs (still encoded —
/// each frame self-validates in [`decode_frame`]).
fn split_container(bytes: &[u8]) -> Result<Vec<&[u8]>, CodecError> {
    if bytes.len() < 4 {
        return Err(CodecError::Truncated);
    }
    if bytes[0..4] != STORE_MAGIC {
        return Err(CodecError::BadMagic);
    }
    let mut c = Cursor { bytes, pos: 4 };
    let version = c.u32()?;
    if version != CODEC_VERSION {
        return Err(CodecError::BadVersion { found: version });
    }
    let count = c.u64()? as usize;
    let mut frames = Vec::new();
    for _ in 0..count {
        let len = c.len_field(1)?;
        frames.push(c.take(len)?);
    }
    if c.pos != bytes.len() {
        return Err(CodecError::Malformed("trailing bytes after container"));
    }
    Ok(frames)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::util::rng::Pcg64;

    fn dummy_solve(d: usize) -> CachedSolve {
        CachedSolve {
            m_final: Mat::identity(d),
            lambda: 0.5,
            lambda_max: 1.0,
            eps: 0.0,
            p: 1.0,
            steps: 3,
            admitted_idx: vec![(0, 1, 2)],
            screened_l: 1,
            screened_r: 2,
        }
    }

    #[test]
    fn fingerprint_sensitive_to_every_field() {
        let mut rng = Pcg64::seed(5);
        let ds = synthetic::gaussian_mixture("fp", 10, 3, 2, 2.0, &mut rng);
        let base = fingerprint(&ds, 2);
        assert_eq!(base, fingerprint(&ds.clone(), 2), "fingerprint must be pure");
        assert_ne!(base, fingerprint(&ds, 3), "k must enter the key");

        let mut row = ds.clone();
        row.x.row_mut(4)[1] += 1e-12;
        assert_ne!(base, fingerprint(&row, 2), "row bits must enter the key");

        let mut label = ds.clone();
        label.y[7] = (label.y[7] + 1) % label.n_classes;
        assert_ne!(base, fingerprint(&label, 2), "labels must enter the key");
    }

    #[test]
    fn lru_eviction_and_recency_promotion() {
        let mut rng = Pcg64::seed(6);
        let mk = |rng: &mut Pcg64, n: usize| synthetic::gaussian_mixture("lru", n, 3, 2, 2.0, rng);
        let a = mk(&mut rng, 8);
        let b = mk(&mut rng, 10);
        let c = mk(&mut rng, 12);
        let mut store = FrameStore::new(2);
        store.insert(&a, 2, dummy_solve(3));
        store.insert(&b, 2, dummy_solve(3));
        assert!(store.lookup(&a, 2).is_some(), "a is resident");
        // a is now most-recent; inserting c must evict b, not a
        store.insert(&c, 2, dummy_solve(3));
        assert_eq!(store.len(), 2);
        assert_eq!(store.evictions(), 1);
        assert!(store.lookup(&b, 2).is_none(), "b was the LRU victim");
        assert!(store.lookup(&a, 2).is_some());
        assert!(store.lookup(&c, 2).is_some());
    }

    #[test]
    fn reinsert_same_key_replaces_without_eviction() {
        let mut rng = Pcg64::seed(7);
        let ds = synthetic::gaussian_mixture("dup", 9, 3, 2, 2.0, &mut rng);
        let mut store = FrameStore::new(2);
        store.insert(&ds, 2, dummy_solve(3));
        let mut newer = dummy_solve(3);
        newer.steps = 9;
        store.insert(&ds, 2, newer);
        assert_eq!(store.len(), 1);
        assert_eq!(store.evictions(), 0);
        assert_eq!(store.lookup(&ds, 2).expect("hit").steps, 9);
    }

    #[test]
    fn frame_codec_round_trips_bitwise() {
        let mut rng = Pcg64::seed(8);
        let ds = synthetic::gaussian_mixture("codec", 11, 4, 3, 2.0, &mut rng);
        let mut solve = dummy_solve(4);
        solve.lambda = -0.0; // sign-of-zero must survive
        solve.eps = f64::MIN_POSITIVE;
        let bytes = encode_frame(&ds, 3, &solve);
        let (ds2, k2, solve2) = decode_frame(&bytes).expect("round trip decodes");
        assert_eq!(k2, 3);
        assert_eq!(ds2.name, ds.name);
        assert_eq!(ds2.y, ds.y);
        assert_eq!(
            fingerprint(&ds2, k2),
            fingerprint(&ds, 3),
            "decoded dataset is bitwise identical"
        );
        assert_eq!(solve2.lambda.to_bits(), solve.lambda.to_bits());
        assert_eq!(solve2.eps.to_bits(), solve.eps.to_bits());
        assert_eq!(solve2.admitted_idx, solve.admitted_idx);
        let m1: Vec<u64> = solve.m_final.as_slice().iter().map(|v| v.to_bits()).collect();
        let m2: Vec<u64> = solve2.m_final.as_slice().iter().map(|v| v.to_bits()).collect();
        assert_eq!(m1, m2, "optimum matrix bits survive the codec");
    }

    #[test]
    fn frame_codec_rejects_tampering_with_typed_errors() {
        let mut rng = Pcg64::seed(9);
        let ds = synthetic::gaussian_mixture("tamper", 8, 3, 2, 2.0, &mut rng);
        let bytes = encode_frame(&ds, 2, &dummy_solve(3));

        assert_eq!(decode_frame(&bytes[..bytes.len() - 1]).err(), Some(CodecError::BadChecksum));
        assert_eq!(decode_frame(&bytes[..2]).err(), Some(CodecError::Truncated));
        assert_eq!(decode_frame(b"NOPE").err(), Some(CodecError::BadMagic));

        // flip a payload byte: the checksum catches it first
        let mut corrupt = bytes.clone();
        corrupt[30] ^= 0xff;
        assert_eq!(decode_frame(&corrupt).err(), Some(CodecError::BadChecksum));

        // bump the version and re-stamp: typed version error
        let mut versioned = bytes.clone();
        versioned[4] = 99;
        let end = versioned.len() - CHECKSUM_BYTES;
        let sum = frame_checksum(&versioned[..end]).to_le_bytes();
        versioned[end..].copy_from_slice(&sum);
        assert_eq!(
            decode_frame(&versioned).err(),
            Some(CodecError::BadVersion { found: 99 })
        );

        // swap the fingerprint stamp and re-stamp the checksum: the
        // decoded dataset no longer matches the claim
        let mut restamped = bytes.clone();
        restamped[8] ^= 0x01;
        let sum = frame_checksum(&restamped[..end]).to_le_bytes();
        restamped[end..].copy_from_slice(&sum);
        assert_eq!(decode_frame(&restamped).err(), Some(CodecError::FingerprintMismatch));
    }

    #[test]
    fn store_export_import_preserves_frames_and_recency() {
        let mut rng = Pcg64::seed(10);
        let a = synthetic::gaussian_mixture("exp-a", 8, 3, 2, 2.0, &mut rng);
        let b = synthetic::gaussian_mixture("exp-b", 10, 3, 2, 2.0, &mut rng);
        let mut store = FrameStore::new(4);
        store.insert(&a, 2, dummy_solve(3));
        store.insert(&b, 2, dummy_solve(3));
        store.lookup(&a, 2).expect("promote a to MRU");

        let bytes = store.export_bytes();
        let mut fresh = FrameStore::new(4);
        assert_eq!(fresh.import_bytes(&bytes), Ok(2));
        assert_eq!(fresh.len(), 2);
        assert!(fresh.lookup(&a, 2).is_some());
        assert!(fresh.lookup(&b, 2).is_some());

        // a was MRU at export; after import + one insert at capacity 2,
        // the LRU victim must be b, mirroring the source store.
        let mut tight = FrameStore::new(2);
        tight.import_bytes(&bytes).expect("import");
        let c = synthetic::gaussian_mixture("exp-c", 12, 3, 2, 2.0, &mut rng);
        tight.insert(&c, 2, dummy_solve(3));
        assert!(tight.lookup(&b, 2).is_none(), "b was LRU at export");
        assert!(tight.lookup(&a, 2).is_some(), "a kept its MRU recency");

        assert_eq!(fresh.import_bytes(b"TSFRjunk"), Err(CodecError::BadMagic));
    }

    #[test]
    fn shared_store_matches_manually_routed_serial_stores() {
        let mut rng = Pcg64::seed(11);
        let shared = SharedFrameStore::new(2, 2);
        let mut serial: Vec<FrameStore> = (0..2).map(|_| FrameStore::new(2)).collect();
        let datasets: Vec<_> = (0..6)
            .map(|i| synthetic::gaussian_mixture("shard", 8 + i, 3, 2, 2.0, &mut rng))
            .collect();
        for ds in &datasets {
            let i = shared.shard_of(ds, 2);
            shared.insert(ds, 2, dummy_solve(3));
            serial[i].insert(ds, 2, dummy_solve(3));
        }
        for ds in &datasets {
            let i = shared.shard_of(ds, 2);
            assert_eq!(
                shared.lookup(ds, 2).is_some(),
                serial[i].lookup(ds, 2).is_some(),
                "per-shard hit/evict behaviour must equal the serial store"
            );
        }
        let serial_hits: usize = serial.iter().map(|s| s.hits()).sum();
        let serial_evictions: usize = serial.iter().map(|s| s.evictions()).sum();
        assert_eq!(shared.hits(), serial_hits);
        assert_eq!(shared.evictions(), serial_evictions);
        assert_eq!(shared.len(), serial.iter().map(|s| s.len()).sum::<usize>());
    }
}
