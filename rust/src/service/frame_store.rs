//! `FrameStore` — an LRU cache of solved regularization paths keyed by
//! dataset fingerprint.
//!
//! ## Fingerprint scheme
//!
//! The key is a 128-bit FNV-1a hash over everything that determines a
//! tenant's solve: `n`, `d`, the triplet-construction `k`, every label,
//! and the raw IEEE-754 bit pattern of every feature value (so `-0.0`
//! vs `0.0` or a 1-ulp perturbation changes the key — bitwise equality
//! is exactly the granularity at which the service guarantees replay).
//! Because a 128-bit hash can still collide in principle, every entry
//! keeps the dataset it was keyed from and a lookup verifies **bitwise
//! equality** of rows + labels + `k` before reporting a hit: a mutated
//! dataset can never be served a stale frame, no matter what the hash
//! does (`rust/tests/service_safety.rs` holds property tests to this).
//!
//! A hit returns the cached [`CachedSolve`] without touching the
//! solver, the screening rules, or the admission pipeline — zero rule
//! evaluations by construction (asserted in the safety battery and
//! gated in `benches/screening.rs`).

use crate::data::Dataset;
use crate::linalg::Mat;

/// 128-bit FNV-1a offset basis.
const FNV_OFFSET: u128 = 0x6c62272e07bb014262b821756295c58d;
/// 128-bit FNV-1a prime.
const FNV_PRIME: u128 = 0x0000000001000000000000000000013b;

fn fnv_mix(h: &mut u128, bytes: &[u8]) {
    for &b in bytes {
        *h ^= b as u128;
        *h = h.wrapping_mul(FNV_PRIME);
    }
}

/// 128-bit fingerprint of `(dataset, k)`: FNV-1a over the dimensions,
/// `k`, the labels, and the bit patterns of every feature value.
pub fn fingerprint(ds: &Dataset, k: usize) -> u128 {
    let mut h = FNV_OFFSET;
    fnv_mix(&mut h, &(ds.n() as u64).to_le_bytes());
    fnv_mix(&mut h, &(ds.d() as u64).to_le_bytes());
    fnv_mix(&mut h, &(k as u64).to_le_bytes());
    for &y in &ds.y {
        fnv_mix(&mut h, &(y as u64).to_le_bytes());
    }
    for &x in ds.x.as_slice() {
        fnv_mix(&mut h, &x.to_bits().to_le_bytes());
    }
    h
}

/// Bitwise dataset equality at the fingerprint's granularity: same
/// shape, same labels, same feature bit patterns.
fn same_dataset(a: &Dataset, b: &Dataset) -> bool {
    a.n() == b.n()
        && a.d() == b.d()
        && a.y == b.y
        && a.x
            .as_slice()
            .iter()
            .zip(b.x.as_slice())
            .all(|(p, q)| p.to_bits() == q.to_bits())
}

/// Everything a warm hit replays without re-solving: the final iterate
/// and path position plus the screening outcome summary of the original
/// request.
#[derive(Clone, Debug)]
pub struct CachedSolve {
    /// final Mahalanobis matrix of the path
    pub m_final: Mat,
    /// λ the path stopped at
    pub lambda: f64,
    /// λ_max the cold path started from
    pub lambda_max: f64,
    /// ε-accuracy of `m_final` at `lambda` (from the duality gap)
    pub eps: f64,
    /// reduced primal at the final step
    pub p: f64,
    /// λ steps the cold path took
    pub steps: usize,
    /// `(i, j, l)` ids admitted into the final workset, admission order
    pub admitted_idx: Vec<(u32, u32, u32)>,
    /// triplets screened into L* at the final step
    pub screened_l: usize,
    /// triplets screened into R* at the final step
    pub screened_r: usize,
}

struct Entry {
    key: u128,
    k: usize,
    dataset: Dataset,
    solve: CachedSolve,
}

/// LRU cache of solved frames keyed by [`fingerprint`]; see the module
/// docs for the scheme and the staleness guarantee.
pub struct FrameStore {
    capacity: usize,
    /// recency order: index 0 = least recently used, last = most recent
    entries: Vec<Entry>,
    hits: usize,
    misses: usize,
    insertions: usize,
    evictions: usize,
}

impl FrameStore {
    /// An empty store holding at most `capacity` solved frames
    /// (`capacity` is clamped to ≥ 1).
    pub fn new(capacity: usize) -> FrameStore {
        FrameStore {
            capacity: capacity.max(1),
            entries: Vec::new(),
            hits: 0,
            misses: 0,
            insertions: 0,
            evictions: 0,
        }
    }

    /// Cached solves currently held.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether the store holds no entries.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Maximum number of cached solves.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Lookups that returned a verified hit.
    pub fn hits(&self) -> usize {
        self.hits
    }

    /// Lookups that missed (or failed bitwise verification).
    pub fn misses(&self) -> usize {
        self.misses
    }

    /// Entries inserted over the store's lifetime.
    pub fn insertions(&self) -> usize {
        self.insertions
    }

    /// Entries evicted to respect the capacity.
    pub fn evictions(&self) -> usize {
        self.evictions
    }

    /// Look up the solved frame for `(ds, k)`. A hit requires both the
    /// fingerprint match **and** bitwise dataset equality (stale frames
    /// are unreachable even under hash collision) and promotes the
    /// entry to most-recently-used.
    pub fn lookup(&mut self, ds: &Dataset, k: usize) -> Option<&CachedSolve> {
        let key = fingerprint(ds, k);
        let pos = self
            .entries
            .iter()
            .position(|e| e.key == key && e.k == k && same_dataset(&e.dataset, ds));
        match pos {
            Some(i) => {
                self.hits += 1;
                let entry = self.entries.remove(i);
                self.entries.push(entry);
                Some(&self.entries.last().expect("just pushed").solve)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert (or replace) the solved frame for `(ds, k)` as the
    /// most-recently-used entry, evicting the least-recently-used one
    /// if the store is at capacity. The dataset is copied into the
    /// entry for the bitwise verification on later lookups.
    pub fn insert(&mut self, ds: &Dataset, k: usize, solve: CachedSolve) {
        let key = fingerprint(ds, k);
        if let Some(i) = self
            .entries
            .iter()
            .position(|e| e.key == key && e.k == k && same_dataset(&e.dataset, ds))
        {
            self.entries.remove(i);
        } else if self.entries.len() >= self.capacity {
            self.entries.remove(0);
            self.evictions += 1;
        }
        self.insertions += 1;
        self.entries.push(Entry {
            key,
            k,
            dataset: ds.clone(),
            solve,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::util::rng::Pcg64;

    fn dummy_solve(d: usize) -> CachedSolve {
        CachedSolve {
            m_final: Mat::identity(d),
            lambda: 0.5,
            lambda_max: 1.0,
            eps: 0.0,
            p: 1.0,
            steps: 3,
            admitted_idx: vec![(0, 1, 2)],
            screened_l: 1,
            screened_r: 2,
        }
    }

    #[test]
    fn fingerprint_sensitive_to_every_field() {
        let mut rng = Pcg64::seed(5);
        let ds = synthetic::gaussian_mixture("fp", 10, 3, 2, 2.0, &mut rng);
        let base = fingerprint(&ds, 2);
        assert_eq!(base, fingerprint(&ds.clone(), 2), "fingerprint must be pure");
        assert_ne!(base, fingerprint(&ds, 3), "k must enter the key");

        let mut row = ds.clone();
        row.x.row_mut(4)[1] += 1e-12;
        assert_ne!(base, fingerprint(&row, 2), "row bits must enter the key");

        let mut label = ds.clone();
        label.y[7] = (label.y[7] + 1) % label.n_classes;
        assert_ne!(base, fingerprint(&label, 2), "labels must enter the key");
    }

    #[test]
    fn lru_eviction_and_recency_promotion() {
        let mut rng = Pcg64::seed(6);
        let mk = |rng: &mut Pcg64, n: usize| synthetic::gaussian_mixture("lru", n, 3, 2, 2.0, rng);
        let a = mk(&mut rng, 8);
        let b = mk(&mut rng, 10);
        let c = mk(&mut rng, 12);
        let mut store = FrameStore::new(2);
        store.insert(&a, 2, dummy_solve(3));
        store.insert(&b, 2, dummy_solve(3));
        assert!(store.lookup(&a, 2).is_some(), "a is resident");
        // a is now most-recent; inserting c must evict b, not a
        store.insert(&c, 2, dummy_solve(3));
        assert_eq!(store.len(), 2);
        assert_eq!(store.evictions(), 1);
        assert!(store.lookup(&b, 2).is_none(), "b was the LRU victim");
        assert!(store.lookup(&a, 2).is_some());
        assert!(store.lookup(&c, 2).is_some());
    }

    #[test]
    fn reinsert_same_key_replaces_without_eviction() {
        let mut rng = Pcg64::seed(7);
        let ds = synthetic::gaussian_mixture("dup", 9, 3, 2, 2.0, &mut rng);
        let mut store = FrameStore::new(2);
        store.insert(&ds, 2, dummy_solve(3));
        let mut newer = dummy_solve(3);
        newer.steps = 9;
        store.insert(&ds, 2, newer);
        assert_eq!(store.len(), 1);
        assert_eq!(store.evictions(), 0);
        assert_eq!(store.lookup(&ds, 2).expect("hit").steps, 9);
    }
}
