//! Low-rank factored screening engine: O(r) rule scalars for very high d.
//!
//! [`FactoredEngine`] wraps a [`NativeEngine`] and changes exactly one
//! thing: how *reference* matrices are consumed. When the screening
//! layer builds a frame it hands the reference through
//! [`Engine::compress_reference`]; this engine replaces it with the
//! rank-r reconstruction `M̃ = LᵀL` ([`LowRankFactor::compress`]) and
//! returns the **exact** compression error τ, which the frame folds
//! into its ε. By the paper's Theorem 3.10 the compressed reference is
//! just another approximate reference at distance `ε + τ` from the
//! optimum, so every sphere bound built from it remains **safe for the
//! original dense problem** — screening only ever discards triplets the
//! dense rules would also discard at that slack. The solve itself stays
//! dense f64: [`Engine::margins`]/[`Engine::wgram`]/[`Engine::step`]
//! delegate to the inner engine untouched, so solver trajectories are
//! bitwise identical to the dense backend's.
//!
//! After compression the two reference-scoped queries are cheap:
//!
//! - [`Engine::ref_margins`] — embed the rows once (`Z = X·Lᵀ`, the
//!   panel GEMM, O(n·d·r)) and answer each margin as
//!   `‖z_a‖² − ‖z_b‖²` in O(r), against the dense path's O(n·d²).
//!   Embeddings are cached per (factor, input allocation) and verified
//!   by **full bitwise comparison** before reuse — a stale pointer can
//!   never silently serve wrong margins.
//! - [`Engine::ref_norm`] — `‖M̃‖_F = ‖LLᵀ‖_F` from the cached r×r
//!   Gram, O(1) per query.
//!
//! Reference identity is established the same defensive way: a matrix
//! is treated as "ours" only if its bits equal a reconstruction this
//! engine produced (allocation pointers are used as a shortlist, never
//! as proof). Anything unrecognized falls back to the dense kernels,
//! so a [`FactoredEngine`] is *always* correct, merely slower off its
//! fast path.
//!
//! Determinism: compression is a pure function of `(M, r)` (seeded
//! range finder), the embed GEMM and the O(r) margins are whole-chain
//! [`crate::linalg::gemm::dot`] kernels, so N-worker factored output is
//! bitwise identical to 1-worker — the same contract the dense pool
//! kernels carry.

use super::{Engine, NativeEngine, PrecisionTier, StepOut};
use crate::linalg::{gemm, LowRankFactor, Mat};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// References remembered per engine (a solver holds one live frame;
/// the slack covers tests and interleaved path studies).
const REF_CAP: usize = 4;

/// Embedding-cache entries per engine: one pair of store-sized arrays
/// per frame plus a few admission batches in flight.
const EMBED_CAP: usize = 8;

/// Parse a `--rank` / `[engine] rank` value. The empty string means
/// "no factored tier" (`None`, dense backend); `0` and non-numeric
/// input are loud configuration errors, mirroring the `TS_THREADS`
/// hardening in [`crate::util::parallel::parse_ts_threads`]. The upper
/// bound r ≤ d is checked once the data dimension is known — see
/// [`validate_rank`].
pub fn parse_rank(v: &str) -> Option<usize> {
    let v = v.trim();
    if v.is_empty() {
        return None;
    }
    match v.parse::<usize>() {
        Ok(0) => panic!("--rank must be a positive integer (r = 0 has no factored form; omit the flag for the dense backend)"),
        Ok(n) => Some(n),
        Err(_) => panic!("--rank must be a positive integer, got {v:?}"),
    }
}

/// Reject a factor rank above the feature dimension with a CLI-grade
/// message. `r = d` is allowed (the lossless parity configuration);
/// `r > d` would silently degrade to r = d work while reporting r, so
/// it is refused outright.
pub fn validate_rank(rank: usize, d: usize) {
    assert!(
        rank <= d,
        "--rank {rank} exceeds the feature dimension d = {d}; pick r in 1..={d}"
    );
}

/// Counters of the factored backend's cache and fast-path traffic,
/// snapshot via [`Engine::factored_telemetry`].
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FactoredTelemetry {
    /// Factor rank r the engine compresses references to.
    pub rank: usize,
    /// References compressed (one per frame build).
    pub compressions: u64,
    /// Embedding GEMM passes actually run (cache misses).
    pub embed_passes: u64,
    /// Embedding requests served from the verified cache.
    pub embed_cache_hits: u64,
    /// Margin rows answered on the O(r) factored fast path.
    pub factored_rows: u64,
    /// Margin rows that fell back to the dense kernels (reference not
    /// recognized — by design for sphere centers not proportional to a
    /// compressed reference).
    pub dense_fallback_rows: u64,
    /// Compression error τ of the most recent reference (the additive
    /// ε inflation handed to the frame).
    pub last_tau: f64,
}

/// A reference this engine compressed: the reconstruction kept for
/// bitwise identification, the allocation pointer of the copy handed to
/// the caller (shortlist only), and the factor serving the fast path.
struct RefEntry {
    dense: Mat,
    ptr: usize,
    factor: LowRankFactor,
}

/// One verified embedding: `z = x·lᵀ` for factor `factor_version`,
/// with a full copy of `x` so reuse is provably sound.
struct EmbedEntry {
    factor_version: u64,
    ptr: usize,
    x_copy: Mat,
    z: Mat,
}

#[derive(Default)]
struct FactoredState {
    refs: Vec<RefEntry>,
    embeds: Vec<EmbedEntry>,
}

/// The factored compute engine (see the module docs).
pub struct FactoredEngine {
    inner: NativeEngine,
    rank: usize,
    state: Mutex<FactoredState>,
    compressions: AtomicU64,
    embed_passes: AtomicU64,
    embed_cache_hits: AtomicU64,
    factored_rows: AtomicU64,
    dense_fallback_rows: AtomicU64,
    last_tau_bits: AtomicU64,
}

impl FactoredEngine {
    /// Wrap a dense engine with a rank-r factored reference tier. The
    /// rank must be positive ([`parse_rank`] enforces this for CLI
    /// input); r ≤ d is enforced per reference at compression time.
    pub fn new(inner: NativeEngine, rank: usize) -> FactoredEngine {
        assert!(rank >= 1, "factor rank must be at least 1");
        FactoredEngine {
            inner,
            rank,
            state: Mutex::new(FactoredState::default()),
            compressions: AtomicU64::new(0),
            embed_passes: AtomicU64::new(0),
            embed_cache_hits: AtomicU64::new(0),
            factored_rows: AtomicU64::new(0),
            dense_fallback_rows: AtomicU64::new(0),
            last_tau_bits: AtomicU64::new(0),
        }
    }

    /// The wrapped dense engine (solver kernels delegate to it).
    pub fn inner(&self) -> &NativeEngine {
        &self.inner
    }

    fn slices_bit_equal(x: &[f64], y: &[f64]) -> bool {
        x.len() == y.len()
            && x.iter()
                .zip(y)
                .all(|(a, b)| a.to_bits() == b.to_bits())
    }

    /// Index of the remembered reference whose reconstruction is
    /// bit-identical to `m0` — pointer matches first (the common case:
    /// the very allocation we returned, moved into the frame), then any
    /// value-identical entry. Always verified by full comparison.
    fn find_ref(st: &FactoredState, m0: &Mat) -> Option<usize> {
        let ptr = m0.as_slice().as_ptr() as usize;
        let candidate = |e: &RefEntry| {
            (e.dense.rows(), e.dense.cols()) == (m0.rows(), m0.cols())
                && Self::slices_bit_equal(e.dense.as_slice(), m0.as_slice())
        };
        if let Some(i) = st
            .refs
            .iter()
            .rposition(|e| e.ptr == ptr && candidate(e))
        {
            return Some(i);
        }
        st.refs.iter().rposition(candidate)
    }

    /// Embed `x` under `factor`, reusing a cached embedding only after
    /// verifying the cached input copy is bit-identical to `x`.
    fn embed_cached(&self, embeds: &mut Vec<EmbedEntry>, factor: &LowRankFactor, x: &Mat) -> Mat {
        let ptr = x.as_slice().as_ptr() as usize;
        for e in embeds.iter() {
            if e.factor_version == factor.version()
                && e.ptr == ptr
                && (e.x_copy.rows(), e.x_copy.cols()) == (x.rows(), x.cols())
                && Self::slices_bit_equal(e.x_copy.as_slice(), x.as_slice())
            {
                self.embed_cache_hits.fetch_add(1, Ordering::Relaxed);
                return e.z.clone();
            }
        }
        let z = factor.embed(x, self.inner.workers());
        self.embed_passes.fetch_add(1, Ordering::Relaxed);
        embeds.push(EmbedEntry {
            factor_version: factor.version(),
            ptr,
            x_copy: x.clone(),
            z: z.clone(),
        });
        if embeds.len() > EMBED_CAP {
            embeds.remove(0);
        }
        z
    }
}

impl Engine for FactoredEngine {
    fn name(&self) -> &'static str {
        "factored"
    }

    fn margins(&self, mat: &Mat, a: &Mat, b: &Mat, out: &mut [f64]) {
        self.inner.margins(mat, a, b, out);
    }

    fn wgram(&self, a: &Mat, b: &Mat, w: &[f64]) -> Mat {
        self.inner.wgram(a, b, w)
    }

    fn step(&self, mat: &Mat, a: &Mat, b: &Mat, gamma: f64, margins_out: &mut [f64]) -> StepOut {
        self.inner.step(mat, a, b, gamma, margins_out)
    }

    fn workers(&self) -> usize {
        self.inner.workers()
    }

    fn precision(&self) -> PrecisionTier {
        self.inner.precision()
    }

    fn margins_f32(&self, mat: &Mat, a: &Mat, b: &Mat, out: &mut [f64], env: &mut [f64]) -> bool {
        self.inner.margins_f32(mat, a, b, out, env)
    }

    fn compress_reference(&self, m0: Mat) -> (Mat, f64) {
        validate_rank(self.rank, m0.rows());
        let (factor, tau) = LowRankFactor::compress(&m0, self.rank);
        let dense = factor.to_dense(self.inner.workers());
        let ptr = dense.as_slice().as_ptr() as usize;
        let mut st = self.state.lock().unwrap();
        st.refs.push(RefEntry {
            dense: dense.clone(),
            ptr,
            factor,
        });
        if st.refs.len() > REF_CAP {
            st.refs.remove(0);
        }
        let FactoredState { refs, embeds } = &mut *st;
        embeds.retain(|e| refs.iter().any(|rf| rf.factor.version() == e.factor_version));
        drop(st);
        self.compressions.fetch_add(1, Ordering::Relaxed);
        self.last_tau_bits.store(tau.to_bits(), Ordering::Relaxed);
        (dense, tau)
    }

    fn ref_margins(&self, m0: &Mat, a: &Mat, b: &Mat, out: &mut [f64]) {
        debug_assert_eq!(a.rows(), b.rows());
        debug_assert_eq!(out.len(), a.rows());
        {
            let mut st = self.state.lock().unwrap();
            if let Some(i) = Self::find_ref(&st, m0) {
                let FactoredState { refs, embeds } = &mut *st;
                let factor = &refs[i].factor;
                let za = self.embed_cached(embeds, factor, a);
                let zb = self.embed_cached(embeds, factor, b);
                drop(st);
                gemm::embed_margins_parallel(&za, &zb, out, self.inner.workers());
                self.factored_rows
                    .fetch_add(a.rows() as u64, Ordering::Relaxed);
                return;
            }
        }
        self.dense_fallback_rows
            .fetch_add(a.rows() as u64, Ordering::Relaxed);
        self.inner.margins(m0, a, b, out);
    }

    fn ref_norm(&self, m0: &Mat) -> f64 {
        let st = self.state.lock().unwrap();
        match Self::find_ref(&st, m0) {
            Some(i) => st.refs[i].factor.norm(),
            None => m0.norm(),
        }
    }

    fn rank(&self) -> Option<usize> {
        Some(self.rank)
    }

    fn factored_telemetry(&self) -> Option<FactoredTelemetry> {
        Some(FactoredTelemetry {
            rank: self.rank,
            compressions: self.compressions.load(Ordering::Relaxed),
            embed_passes: self.embed_passes.load(Ordering::Relaxed),
            embed_cache_hits: self.embed_cache_hits.load(Ordering::Relaxed),
            factored_rows: self.factored_rows.load(Ordering::Relaxed),
            dense_fallback_rows: self.dense_fallback_rows.load(Ordering::Relaxed),
            last_tau: f64::from_bits(self.last_tau_bits.load(Ordering::Relaxed)),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn rand_psd(rng: &mut Pcg64, d: usize, rank: usize) -> Mat {
        let mut m = Mat::zeros(d, d);
        for _ in 0..rank {
            let v: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
            m.axpy(1.0, &Mat::outer(&v));
        }
        m
    }

    #[test]
    fn parse_rank_accepts_valid_and_empty() {
        assert_eq!(parse_rank("64"), Some(64));
        assert_eq!(parse_rank("  16 "), Some(16));
        assert_eq!(parse_rank(""), None);
        assert_eq!(parse_rank("   "), None);
    }

    #[test]
    #[should_panic(expected = "--rank must be a positive integer (r = 0")]
    fn parse_rank_rejects_zero() {
        parse_rank("0");
    }

    #[test]
    #[should_panic(expected = "--rank must be a positive integer, got")]
    fn parse_rank_rejects_junk() {
        parse_rank("sixteen");
    }

    #[test]
    #[should_panic(expected = "exceeds the feature dimension d = 8")]
    fn validate_rank_rejects_rank_above_dim() {
        validate_rank(9, 8);
    }

    #[test]
    fn validate_rank_allows_full_rank() {
        validate_rank(8, 8);
        validate_rank(1, 8);
    }

    #[test]
    fn solver_kernels_delegate_bitwise_to_inner() {
        let mut rng = Pcg64::seed(21);
        let (n, d) = (37usize, 9usize);
        let mut m = Mat::from_fn(d, d, |_, _| rng.normal());
        m.symmetrize();
        let a = Mat::from_fn(n, d, |_, _| rng.normal());
        let b = Mat::from_fn(n, d, |_, _| rng.normal());
        let dense = NativeEngine::new(0);
        let fact = FactoredEngine::new(NativeEngine::new(0), 4);
        let (mut out_d, mut out_f) = (vec![0.0; n], vec![0.0; n]);
        dense.margins(&m, &a, &b, &mut out_d);
        fact.margins(&m, &a, &b, &mut out_f);
        for t in 0..n {
            assert_eq!(out_d[t].to_bits(), out_f[t].to_bits(), "margins differ at {t}");
        }
        let (ld, gd) = dense.step(&m, &a, &b, 0.1, &mut out_d);
        let (lf, gf) = fact.step(&m, &a, &b, 0.1, &mut out_f);
        assert_eq!(ld.to_bits(), lf.to_bits());
        for (x, y) in gd.as_slice().iter().zip(gf.as_slice()) {
            assert_eq!(x.to_bits(), y.to_bits(), "step gradient differs");
        }
    }

    #[test]
    fn compressed_reference_serves_factored_margins() {
        let mut rng = Pcg64::seed(33);
        let (n, d) = (90usize, 13usize);
        let m0 = rand_psd(&mut rng, d, d + 3);
        let a = Mat::from_fn(n, d, |_, _| rng.normal());
        let b = Mat::from_fn(n, d, |_, _| rng.normal());
        let fact = FactoredEngine::new(NativeEngine::new(0), d);
        let (mt, tau) = fact.compress_reference(m0.clone());
        // full rank on a PSD reference: reconstruction ≈ original, τ tiny
        assert!(mt.sub(&m0).max_abs() < 1e-9 * (1.0 + m0.max_abs()));
        assert!(tau < 1e-9 * (1.0 + m0.norm()), "τ = {tau}");
        let (mut fast, mut dense) = (vec![0.0; n], vec![0.0; n]);
        fact.ref_margins(&mt, &a, &b, &mut fast);
        fact.margins(&mt, &a, &b, &mut dense);
        for t in 0..n {
            let tol = 1e-9 * (1.0 + dense[t].abs());
            assert!(
                (fast[t] - dense[t]).abs() < tol,
                "factored margin {t}: {} vs dense {}",
                fast[t],
                dense[t]
            );
        }
        let tel = fact.factored_telemetry().unwrap();
        assert_eq!(tel.compressions, 1);
        assert_eq!(tel.factored_rows, n as u64);
        assert_eq!(tel.dense_fallback_rows, 0);
        assert_eq!(tel.embed_passes, 2);
        // ‖M̃‖ from the Gram matches the dense norm
        assert!((fact.ref_norm(&mt) - mt.norm()).abs() < 1e-9 * (1.0 + mt.norm()));
    }

    #[test]
    fn embed_cache_hits_on_repeated_inputs_and_verifies_content() {
        let mut rng = Pcg64::seed(5);
        let (n, d) = (40usize, 10usize);
        let m0 = rand_psd(&mut rng, d, d);
        let a = Mat::from_fn(n, d, |_, _| rng.normal());
        let b = Mat::from_fn(n, d, |_, _| rng.normal());
        let fact = FactoredEngine::new(NativeEngine::new(0), 3);
        let (mt, _tau) = fact.compress_reference(m0);
        let mut out1 = vec![0.0; n];
        fact.ref_margins(&mt, &a, &b, &mut out1);
        let mut out2 = vec![0.0; n];
        fact.ref_margins(&mt, &a, &b, &mut out2);
        for t in 0..n {
            assert_eq!(out1[t].to_bits(), out2[t].to_bits());
        }
        let tel = fact.factored_telemetry().unwrap();
        assert_eq!(tel.embed_passes, 2, "second pass must be served from cache");
        assert_eq!(tel.embed_cache_hits, 2);
        // mutating the input (same allocation!) must not reuse the
        // stale embedding — the bitwise verification catches it
        let mut a2 = a.clone();
        a2[(0, 0)] += 1.0;
        let mut out3 = vec![0.0; n];
        fact.ref_margins(&mt, &a2, &b, &mut out3);
        let tel = fact.factored_telemetry().unwrap();
        assert_eq!(tel.embed_passes, 3, "changed input must re-embed");
    }

    #[test]
    fn unrecognized_reference_falls_back_to_dense_bitwise() {
        let mut rng = Pcg64::seed(77);
        let (n, d) = (25usize, 7usize);
        let mut q = Mat::from_fn(d, d, |_, _| rng.normal());
        q.symmetrize();
        let a = Mat::from_fn(n, d, |_, _| rng.normal());
        let b = Mat::from_fn(n, d, |_, _| rng.normal());
        let fact = FactoredEngine::new(NativeEngine::new(0), 3);
        let (mut via_ref, mut via_dense) = (vec![0.0; n], vec![0.0; n]);
        fact.ref_margins(&q, &a, &b, &mut via_ref);
        fact.margins(&q, &a, &b, &mut via_dense);
        for t in 0..n {
            assert_eq!(via_ref[t].to_bits(), via_dense[t].to_bits());
        }
        let tel = fact.factored_telemetry().unwrap();
        assert_eq!(tel.dense_fallback_rows, n as u64);
        assert_eq!(tel.factored_rows, 0);
        assert_eq!(fact.ref_norm(&q).to_bits(), q.norm().to_bits());
    }

    #[test]
    fn factored_margins_bitwise_invariant_across_worker_counts() {
        let mut rng = Pcg64::seed(13);
        let (n, d, r) = (70usize, 11usize, 4usize);
        let m0 = rand_psd(&mut rng, d, d);
        let a = Mat::from_fn(n, d, |_, _| rng.normal());
        let b = Mat::from_fn(n, d, |_, _| rng.normal());
        let mut reference: Option<Vec<f64>> = None;
        for workers in [1usize, 2, 7] {
            let fact = FactoredEngine::new(
                NativeEngine::from_options(workers, None, None, None),
                r,
            );
            let (mt, _tau) = fact.compress_reference(m0.clone());
            let mut out = vec![0.0; n];
            fact.ref_margins(&mt, &a, &b, &mut out);
            match &reference {
                None => reference = Some(out),
                Some(want) => {
                    for t in 0..n {
                        assert_eq!(
                            out[t].to_bits(),
                            want[t].to_bits(),
                            "workers={workers} row {t} split bits"
                        );
                    }
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "exceeds the feature dimension")]
    fn compress_reference_rejects_rank_above_dim() {
        let fact = FactoredEngine::new(NativeEngine::new(0), 9);
        let _ = fact.compress_reference(Mat::identity(4));
    }
}
