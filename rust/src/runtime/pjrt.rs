//! PJRT engine: loads the AOT artifacts and runs them via the `xla` crate.
//!
//! Interchange contract (see `python/compile/aot.py` and
//! /opt/xla-example/README.md): artifacts are **HLO text** — jax ≥ 0.5
//! serialized protos carry 64-bit instruction ids that xla_extension 0.5.1
//! rejects, while the text parser reassigns ids. Modules are lowered with
//! `return_tuple=True`, so outputs are unwrapped as tuples here.
//!
//! Executables are compiled lazily per (entry, d) on first use and cached.
//! Inputs are padded to the artifact's dispatch length `n`; the `step`
//! artifact takes an explicit mask so padded rows contribute nothing, the
//! `wgram` artifact gets w = 0 padding, and padded `margins` outputs are
//! simply dropped. All access is serialized through a mutex — PJRT-CPU
//! parallelizes internally, and the coordinator's callers are sequential.
//!
//! Grid geometry: each dispatch covers a contiguous row block whose
//! Pallas kernel internally tiles rows in the same
//! [`crate::linalg::gemm::PANEL_ROWS`]-row panels the native tiled core
//! uses, accumulating per-block partial gradients that this wrapper
//! reduces (`g.axpy` per chunk) exactly like the native worker
//! reduction — so native-vs-PJRT timings compare backends under one
//! blocking scheme. For d past [`crate::linalg::gemm::D_BLOCK_MIN_D`]
//! the native core switches to its d-blocked geometry
//! ([`crate::linalg::gemm::D_BLOCK`]-column feature tiles), which is
//! the CPU mirror of the Pallas kernels' (row-block × feature-block)
//! grid — VMEM-sized feature tiles on TPU, cache-sized column blocks
//! here — so the comparison stays blocking-equivalent at every d.

use super::{Engine, StepOut};
use crate::linalg::Mat;
use crate::util::json::{self, Json};
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Environment variable overriding the artifacts directory.
pub const ARTIFACTS_DIR_ENV: &str = "TS_ARTIFACTS_DIR";

/// Default artifacts directory (relative to the working directory).
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var(ARTIFACTS_DIR_ENV)
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[derive(Clone, Debug, PartialEq, Eq, Hash)]
struct Key {
    entry: &'static str,
    d: usize,
}

#[derive(Clone, Debug)]
struct ArtifactMeta {
    n: usize,
    file: PathBuf,
}

struct Inner {
    client: xla::PjRtClient,
    /// compiled executables keyed by (entry, d, n)
    exes: HashMap<(Key, usize), xla::PjRtLoadedExecutable>,
}

// SAFETY: every use of `Inner` is serialized behind `PjrtEngine::inner`'s
// mutex; the PJRT CPU client itself is internally synchronized.
unsafe impl Send for Inner {}

/// Engine backed by AOT-compiled HLO artifacts executed through PJRT.
pub struct PjrtEngine {
    dir: PathBuf,
    /// (entry, d) -> available dispatch sizes (ascending)
    registry: HashMap<Key, Vec<ArtifactMeta>>,
    inner: Mutex<Inner>,
}

impl PjrtEngine {
    /// Load the manifest from `dir` and start a PJRT CPU client.
    pub fn from_dir(dir: impl AsRef<Path>) -> Result<PjrtEngine> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} (run `make artifacts`)"))?;
        let manifest =
            json::parse(&text).map_err(|e| anyhow!("parsing {manifest_path:?}: {e}"))?;
        let mut registry: HashMap<Key, Vec<ArtifactMeta>> = HashMap::new();
        for art in manifest
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing artifacts[]"))?
        {
            let entry = match art.get("entry").and_then(Json::as_str) {
                Some("margins") => "margins",
                Some("wgram") => "wgram",
                Some("step") => "step",
                other => return Err(anyhow!("unknown artifact entry {other:?}")),
            };
            let d = art
                .get("d")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("artifact missing d"))?;
            let n = art
                .get("n")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("artifact missing n"))?;
            let file = art
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact missing file"))?;
            registry
                .entry(Key { entry, d })
                .or_default()
                .push(ArtifactMeta {
                    n,
                    file: dir.join(file),
                });
        }
        for metas in registry.values_mut() {
            metas.sort_by_key(|m| m.n);
        }
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(PjrtEngine {
            dir,
            registry,
            inner: Mutex::new(Inner {
                client,
                exes: HashMap::new(),
            }),
        })
    }

    /// Load from `$TS_ARTIFACTS_DIR` / `./artifacts`.
    pub fn from_default_dir() -> Result<PjrtEngine> {
        Self::from_dir(default_artifacts_dir())
    }

    /// Does the registry have artifacts for dimension `d`?
    pub fn supports_dim(&self, d: usize) -> bool {
        ["margins", "wgram", "step"]
            .iter()
            .all(|e| self.registry.contains_key(&Key { entry: e, d }))
    }

    /// The directory the artifacts were loaded from.
    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    /// Pick the smallest dispatch size that fits `rows`, else the largest.
    fn pick_meta<'a>(&'a self, key: &Key, rows: usize) -> Result<&'a ArtifactMeta> {
        let metas = self.registry.get(key).ok_or_else(|| {
            anyhow!(
                "no artifact for entry={} d={} under {:?} (run `make artifacts`)",
                key.entry,
                key.d,
                self.dir
            )
        })?;
        Ok(metas
            .iter()
            .find(|m| m.n >= rows)
            .unwrap_or_else(|| metas.last().unwrap()))
    }

    /// Execute `entry` over all row chunks, invoking `consume` with
    /// (chunk_range, outputs) per dispatch.
    fn run_chunks(
        &self,
        entry: &'static str,
        mat: Option<&Mat>,
        a: &Mat,
        b: &Mat,
        w_or_mask: Option<&[f64]>,
        gamma: Option<f64>,
        mut consume: impl FnMut(std::ops::Range<usize>, Vec<xla::Literal>) -> Result<()>,
    ) -> Result<()> {
        let d = a.cols();
        let rows = a.rows();
        let key = Key { entry, d };
        let meta = self.pick_meta(&key, rows)?.clone();
        let n = meta.n;
        let mut inner = self.inner.lock().expect("pjrt mutex poisoned");
        if !inner.exes.contains_key(&(key.clone(), n)) {
            let proto = xla::HloModuleProto::from_text_file(&meta.file)
                .map_err(|e| anyhow!("loading {:?}: {e:?}", meta.file))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = inner
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {:?}: {e:?}", meta.file))?;
            inner.exes.insert((key.clone(), n), exe);
        }
        let exe = inner.exes.get(&(key, n)).unwrap();

        let mat_lit = mat.map(|m| mat_literal(m, &[d, n.min(usize::MAX)])).transpose()?;
        let mut start = 0;
        while start < rows {
            let take = (rows - start).min(n);
            let range = start..start + take;
            let a_lit = rows_literal(a, range.clone(), n)?;
            let b_lit = rows_literal(b, range.clone(), n)?;
            let mut args: Vec<xla::Literal> = Vec::with_capacity(5);
            if let Some(m) = &mat_lit {
                args.push(m.clone());
            }
            args.push(a_lit);
            args.push(b_lit);
            if let Some(w) = w_or_mask {
                let mut padded = vec![0.0f64; n];
                padded[..take].copy_from_slice(&w[range.clone()]);
                args.push(vec_literal(&padded, &[n])?);
            }
            if let Some(g) = gamma {
                args.push(scalar_literal(g)?);
            }
            let result = exe
                .execute::<xla::Literal>(&args)
                .map_err(|e| anyhow!("executing {entry}: {e:?}"))?;
            let lit = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetching {entry} result: {e:?}"))?;
            let parts = lit
                .to_tuple()
                .map_err(|e| anyhow!("untupling {entry} result: {e:?}"))?;
            consume(range, parts)?;
            start += take;
        }
        Ok(())
    }
}

fn mat_literal(m: &Mat, _hint: &[usize]) -> Result<xla::Literal> {
    let bytes = f64_bytes(m.as_slice());
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F64,
        &[m.rows(), m.cols()],
        bytes,
    )
    .map_err(|e| anyhow!("matrix literal: {e:?}"))
}

/// Rows `range` of `m`, zero-padded to `n` rows.
fn rows_literal(m: &Mat, range: std::ops::Range<usize>, n: usize) -> Result<xla::Literal> {
    let d = m.cols();
    let take = range.len();
    if take == n {
        let flat = &m.as_slice()[range.start * d..range.end * d];
        return xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F64,
            &[n, d],
            f64_bytes(flat),
        )
        .map_err(|e| anyhow!("rows literal: {e:?}"));
    }
    let mut padded = vec![0.0f64; n * d];
    padded[..take * d].copy_from_slice(&m.as_slice()[range.start * d..range.end * d]);
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F64,
        &[n, d],
        f64_bytes(&padded),
    )
    .map_err(|e| anyhow!("rows literal: {e:?}"))
}

fn vec_literal(v: &[f64], dims: &[usize]) -> Result<xla::Literal> {
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F64, dims, f64_bytes(v))
        .map_err(|e| anyhow!("vector literal: {e:?}"))
}

fn scalar_literal(x: f64) -> Result<xla::Literal> {
    vec_literal(std::slice::from_ref(&x), &[])
}

fn f64_bytes(xs: &[f64]) -> &[u8] {
    // SAFETY: plain-old-data reinterpretation, alignment of u8 is 1.
    unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 8) }
}

impl Engine for PjrtEngine {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn margins(&self, mat: &Mat, a: &Mat, b: &Mat, out: &mut [f64]) {
        assert_eq!(out.len(), a.rows());
        self.run_chunks("margins", Some(mat), a, b, None, None, |range, parts| {
            let vals: Vec<f64> = parts[0]
                .to_vec::<f64>()
                .map_err(|e| anyhow!("margins output: {e:?}"))?;
            out[range.clone()].copy_from_slice(&vals[..range.len()]);
            Ok(())
        })
        .expect("pjrt margins failed");
    }

    fn wgram(&self, a: &Mat, b: &Mat, w: &[f64]) -> Mat {
        let d = a.cols();
        let mut g = Mat::zeros(d, d);
        self.run_chunks("wgram", None, a, b, Some(w), None, |_range, parts| {
            let vals: Vec<f64> = parts[0]
                .to_vec::<f64>()
                .map_err(|e| anyhow!("wgram output: {e:?}"))?;
            let chunk = Mat::from_rows(d, d, vals);
            g.axpy(1.0, &chunk);
            Ok(())
        })
        .expect("pjrt wgram failed");
        g
    }

    fn step(
        &self,
        mat: &Mat,
        a: &Mat,
        b: &Mat,
        gamma: f64,
        margins_out: &mut [f64],
    ) -> StepOut {
        let d = a.cols();
        assert_eq!(margins_out.len(), a.rows());
        let ones = vec![1.0f64; a.rows()];
        let mut loss_sum = 0.0;
        let mut g = Mat::zeros(d, d);
        self.run_chunks(
            "step",
            Some(mat),
            a,
            b,
            Some(&ones),
            Some(gamma),
            |range, parts| {
                // outputs: (loss_sum, grad, margins)
                loss_sum += parts[0]
                    .to_vec::<f64>()
                    .map_err(|e| anyhow!("step loss: {e:?}"))?[0];
                let gv: Vec<f64> = parts[1]
                    .to_vec::<f64>()
                    .map_err(|e| anyhow!("step grad: {e:?}"))?;
                g.axpy(1.0, &Mat::from_rows(d, d, gv));
                let mv: Vec<f64> = parts[2]
                    .to_vec::<f64>()
                    .map_err(|e| anyhow!("step margins: {e:?}"))?;
                margins_out[range.clone()].copy_from_slice(&mv[..range.len()]);
                Ok(())
            },
        )
        .expect("pjrt step failed");
        (loss_sum, g)
    }
}

// SAFETY: all interior mutability is behind the mutex (see `Inner`).
unsafe impl Sync for PjrtEngine {}
