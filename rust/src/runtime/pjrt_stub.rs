//! Offline stand-in for the PJRT engine.
//!
//! The real engine (`pjrt.rs`, behind the `pjrt` cargo feature) needs the
//! vendored `xla` + `anyhow` crates, which the offline build environment
//! does not ship. This stub keeps the public API identical so every call
//! site compiles unchanged: construction always fails with a descriptive
//! error, and callers take their documented fallback path (tests skip,
//! examples and binaries fall back to [`super::NativeEngine`]).
//!
//! Contract carried by the real engine (and honored by the native tiled
//! core so comparisons stay meaningful): dispatches are row-blocked with
//! per-block accumulators reduced at the end — the same grid-accumulator
//! structure and row-tile geometry as the native panels
//! ([`crate::linalg::gemm::PANEL_ROWS`] rows per tile). For the high-d
//! regime the native core additionally blocks the feature dimension in
//! [`crate::linalg::gemm::D_BLOCK`]-column tiles, matching the Pallas
//! kernels' (row-block × feature-block) grid decomposition, so the
//! native-vs-PJRT comparison stays blocking-equivalent at every d.

use super::{Engine, StepOut};
use crate::linalg::Mat;
use std::path::{Path, PathBuf};

/// Environment variable overriding the artifacts directory.
pub const ARTIFACTS_DIR_ENV: &str = "TS_ARTIFACTS_DIR";

/// Default artifacts directory (relative to the working directory).
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var(ARTIFACTS_DIR_ENV)
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Error returned by every stub constructor.
#[derive(Debug, Clone)]
pub struct PjrtUnavailable {
    dir: PathBuf,
}

impl std::fmt::Display for PjrtUnavailable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "PJRT support not compiled in (build with `--features pjrt` and the \
             vendored xla/anyhow crates); artifacts dir was {:?}",
            self.dir
        )
    }
}

impl std::error::Error for PjrtUnavailable {}

/// Stub engine: can never be constructed.
pub struct PjrtEngine {
    _never: std::convert::Infallible,
    dir: PathBuf,
}

impl PjrtEngine {
    /// Always fails: the `pjrt` feature is off in this build.
    pub fn from_dir(dir: impl AsRef<Path>) -> Result<PjrtEngine, PjrtUnavailable> {
        Err(PjrtUnavailable {
            dir: dir.as_ref().to_path_buf(),
        })
    }

    /// Always fails: the `pjrt` feature is off in this build.
    pub fn from_default_dir() -> Result<PjrtEngine, PjrtUnavailable> {
        Self::from_dir(default_artifacts_dir())
    }

    /// Always false: no artifacts exist in a stub build.
    pub fn supports_dim(&self, _d: usize) -> bool {
        false
    }

    /// The directory the (unavailable) artifacts were looked up in.
    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }
}

impl Engine for PjrtEngine {
    fn name(&self) -> &'static str {
        "pjrt-stub"
    }

    fn margins(&self, _mat: &Mat, _a: &Mat, _b: &Mat, _out: &mut [f64]) {
        unreachable!("stub PjrtEngine cannot be constructed")
    }

    fn wgram(&self, _a: &Mat, _b: &Mat, _w: &[f64]) -> Mat {
        unreachable!("stub PjrtEngine cannot be constructed")
    }

    fn step(
        &self,
        _mat: &Mat,
        _a: &Mat,
        _b: &Mat,
        _gamma: f64,
        _margins_out: &mut [f64],
    ) -> StepOut {
        unreachable!("stub PjrtEngine cannot be constructed")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_always_fails_with_readable_error() {
        let err = PjrtEngine::from_default_dir().unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("pjrt"), "unhelpful error: {msg}");
        let err2 = PjrtEngine::from_dir("/tmp/x").unwrap_err();
        assert!(format!("{err2}").contains("/tmp/x"));
    }
}
