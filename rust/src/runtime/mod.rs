//! Compute engines: where the O(d²·|T|) kernels run.
//!
//! [`Engine`] abstracts the three hot operations (margins, weighted gram,
//! fused step). Two implementations:
//!
//! - [`NativeEngine`] — pure-rust f64, threaded. Routes every FLOP
//!   through the tiled GEMM/SYRK core in [`crate::linalg::gemm`]:
//!   [`KernelCore::Auto`] (the default) picks the row-stream geometry
//!   ([`KernelCore::Tiled`]) below `gemm::D_BLOCK_MIN_D` and the
//!   d-blocked geometry ([`KernelCore::DBlocked`], cache-sized buffers
//!   independently of d) at and above it — the two are bitwise
//!   identical, so the switch is invisible to results. The original
//!   scalar core ([`KernelCore::Scalar`], via [`NativeEngine::scalar`])
//!   is kept as the parity oracle and perf baseline, and as the
//!   fallback for dimensions without compiled artifacts.
//! - [`PjrtEngine`] — loads the AOT artifacts (`artifacts/*.hlo.txt`,
//!   lowered from the L2 JAX model wrapping the L1 Pallas kernels) and
//!   executes them through the PJRT C API via the `xla` crate. Its
//!   dispatch keeps the same grid-accumulator structure and row-block
//!   geometry as the native panels ([`crate::linalg::gemm::PANEL_ROWS`]),
//!   so native-vs-PJRT comparisons measure the backend, not the blocking.
//!
//! Both must agree to f64 round-off; `rust/tests/runtime_pjrt.rs` checks
//! exactly that on the real artifacts, and `rust/tests/kernel_parity.rs`
//! checks the tiled core against the scalar reference.
//!
//! **Precision tiers.** An engine additionally advertises a
//! [`PrecisionTier`]: under [`PrecisionTier::MixedCertified`] the
//! screening manager and the streaming admission path route their bulk
//! margin passes through [`Engine::margins_f32`] — the same generic
//! panel kernels instantiated at `f32`, roughly halving memory traffic —
//! and receive alongside each margin a certified forward-error envelope
//! (`screening::bounds::eps_round`). Every consumer then evaluates its
//! rule at *both* envelope endpoints; only rows whose decision flips
//! inside the envelope are promoted to the exact f64 path, so the
//! screened sets are provably identical to an all-f64 run (the
//! safety battery in `rust/tests/workset_safety.rs` enforces this).
//!
//! **Reference-scoped factored access.** The screening layer consumes a
//! reference matrix `M̃₀` only through three operations — margins
//! against it, its Frobenius norm, and (optionally) a compression step
//! when the frame is built. [`Engine::compress_reference`],
//! [`Engine::ref_margins`] and [`Engine::ref_norm`] lift exactly those
//! behind the trait, with dense pass-through defaults, so
//! `ScreeningManager::screen`, `admit_batch`,
//! `ReferenceFrame::admission_decision` and the rule loop are
//! backend-agnostic: [`NativeEngine`] (and the PJRT stub) run them
//! unchanged, while [`FactoredEngine`] compresses the reference to a
//! rank-r factor `L` (`M̃ = LᵀL`, [`crate::linalg::LowRankFactor`]),
//! answers `ref_margins` in O(r) per row from cached embeddings
//! `Z = X·Lᵀ`, answers `ref_norm` from the r×r Gram, and folds the
//! exact compression error τ into the frame's ε (the paper's Thm 3.10
//! reference-ball argument), so factored screening stays safe for the
//! *dense* problem. The solve itself always stays dense f64.

mod factored;
mod native;
// The real PJRT engine needs the vendored `xla` + `anyhow` crates, which
// the offline image cannot carry in Cargo.toml. `--features pjrt` opts
// into the PJRT surface; compiling the *real* engine additionally
// requires `RUSTFLAGS="--cfg pjrt_runtime"` once those crates are
// vendored as path deps. This keeps the whole feature matrix compiling
// (`cargo check --features pjrt` builds the stub, enforced in CI); the
// stub is API-identical and its constructors fail cleanly, so every
// caller falls back to the native engine.
#[cfg(all(feature = "pjrt", pjrt_runtime))]
mod pjrt;
#[cfg(not(all(feature = "pjrt", pjrt_runtime)))]
#[path = "pjrt_stub.rs"]
mod pjrt;

pub use factored::{parse_rank, validate_rank, FactoredEngine, FactoredTelemetry};
pub use native::{KernelCore, NativeEngine};
pub use pjrt::{PjrtEngine, ARTIFACTS_DIR_ENV};

use crate::linalg::Mat;

/// Numeric tier an engine runs the *bulk* screening passes at.
///
/// The solver's descent arithmetic is always f64; the tier only governs
/// the screening-statistic and admission margin passes, which are
/// bandwidth-bound and certified by an explicit rounding envelope.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum PrecisionTier {
    /// Everything in f64 — the exact reference path (default).
    #[default]
    F64,
    /// Bulk margin passes in f32 with a certified per-row error
    /// envelope; boundary-ambiguous rows are promoted to f64. Screened
    /// sets are provably identical to [`PrecisionTier::F64`].
    MixedCertified,
}

impl PrecisionTier {
    /// Parse a tier name (case-insensitive): `f64` / `double` / `exact`,
    /// or `mixed` / `mixed-certified` / `f32`. Returns `None` for
    /// anything else.
    pub fn parse(s: &str) -> Option<PrecisionTier> {
        match s.to_ascii_lowercase().as_str() {
            "f64" | "double" | "exact" => Some(PrecisionTier::F64),
            "mixed" | "mixed-certified" | "f32" => Some(PrecisionTier::MixedCertified),
            _ => None,
        }
    }

    /// [`PrecisionTier::parse`] with a loud CLI-grade failure.
    pub fn parse_cli(s: &str) -> PrecisionTier {
        PrecisionTier::parse(s)
            .unwrap_or_else(|| panic!("unknown precision tier {s:?} (use f64 or mixed)"))
    }

    /// Stable label for telemetry (`f64` / `mixed`).
    pub fn label(self) -> &'static str {
        match self {
            PrecisionTier::F64 => "f64",
            PrecisionTier::MixedCertified => "mixed",
        }
    }
}

/// One objective/gradient evaluation: `(loss_sum, grad_loss_sum)` where
/// `grad_loss_sum = Σ_t α_t H_t`; margins are written to `margins_out`.
pub type StepOut = (f64, Mat);

/// A compute engine for the triplet kernels.
///
/// Rows of `a`/`b` are the difference vectors `x_i − x_l` / `x_i − x_j`
/// of the (compacted) triplet set. All matrices are row-major f64.
pub trait Engine: Sync {
    /// Engine label for reports (`native`, `native-scalar`, `pjrt`).
    fn name(&self) -> &'static str;

    /// `out[t] = a_t^T mat a_t − b_t^T mat b_t` — serves both `⟨M, H_t⟩`
    /// (objective) and `⟨H_t, Q⟩` (screening statistic).
    fn margins(&self, mat: &Mat, a: &Mat, b: &Mat, out: &mut [f64]);

    /// `Σ_t w_t H_t = A^T diag(w) A − B^T diag(w) B`.
    fn wgram(&self, a: &Mat, b: &Mat, w: &[f64]) -> Mat;

    /// Fused margins + smoothed-hinge loss/derivative + gradient
    /// accumulation (one PJRT dispatch per block on the AOT path):
    /// returns `(Σ_t ℓ(m_t), Σ_t α_t H_t)` and fills `margins_out`.
    fn step(
        &self,
        mat: &Mat,
        a: &Mat,
        b: &Mat,
        gamma: f64,
        margins_out: &mut [f64],
    ) -> StepOut;

    /// Worker count this engine dispatches pooled sections at. Callers
    /// that parallelize around the engine (the screening rule loop, the
    /// streamed-admission batches) use this so one `--threads` knob
    /// governs every pass. Defaults to the `TS_THREADS`/auto-detected
    /// count from [`crate::util::parallel::default_threads`].
    fn workers(&self) -> usize {
        crate::util::parallel::default_threads()
    }

    /// The precision tier this engine runs bulk screening passes at.
    /// Defaults to [`PrecisionTier::F64`] so existing engines (and the
    /// PJRT stub) are exact without opting in.
    fn precision(&self) -> PrecisionTier {
        PrecisionTier::F64
    }

    /// Certified-f32 bulk margins: compute [`Engine::margins`] in f32
    /// (widened into `out`) and fill `env[t]` with a rigorous bound on
    /// `|out[t] − margins_f64[t]|` (`screening::bounds::eps_round`).
    /// Returns `false` — leaving `out`/`env` untouched — when the
    /// engine has no f32 tier (the default, and whenever
    /// [`Engine::precision`] is [`PrecisionTier::F64`]); callers must
    /// then use the exact [`Engine::margins`] path.
    fn margins_f32(&self, mat: &Mat, a: &Mat, b: &Mat, out: &mut [f64], env: &mut [f64]) -> bool {
        let _ = (mat, a, b, out, env);
        false
    }

    /// Optionally rewrite a reference matrix at frame-build time,
    /// returning the (possibly replaced) reference plus an **additive
    /// ε inflation** bounding `‖returned − original‖_F`. The screening
    /// layer hands every new frame reference through this hook; dense
    /// engines return it untouched with inflation 0 (the default).
    /// [`FactoredEngine`] returns the rank-r reconstruction `M̃ = LᵀL`
    /// and its exact compression error τ — Theorem 3.10's
    /// approximate-reference argument then keeps every rule safe for
    /// the original dense problem.
    fn compress_reference(&self, m0: Mat) -> (Mat, f64) {
        (m0, 0.0)
    }

    /// Margins against a *reference* matrix previously returned by
    /// [`Engine::compress_reference`] (the frame's `m0`, or a sphere
    /// center proportional to it). Defaults to the dense
    /// [`Engine::margins`]; [`FactoredEngine`] recognizes its own
    /// reconstructions and answers in O(r) per row from cached
    /// embeddings instead.
    fn ref_margins(&self, m0: &Mat, a: &Mat, b: &Mat, out: &mut [f64]) {
        self.margins(m0, a, b, out);
    }

    /// Frobenius norm of a reference matrix previously returned by
    /// [`Engine::compress_reference`]. Defaults to the dense
    /// `m0.norm()`; [`FactoredEngine`] answers from the r×r Gram
    /// (`‖LᵀL‖_F = ‖LLᵀ‖_F`) without touching a d×d object.
    fn ref_norm(&self, m0: &Mat) -> f64 {
        m0.norm()
    }

    /// The factored-backend rank, when this engine screens against
    /// rank-r compressed references (`None` for dense engines — the
    /// default). Telemetry and reports key on this.
    fn rank(&self) -> Option<usize> {
        None
    }

    /// Factored-backend counters (embedding cache traffic, O(r) margin
    /// rows served), when this engine keeps them. `None` for dense
    /// engines (the default).
    fn factored_telemetry(&self) -> Option<FactoredTelemetry> {
        None
    }
}
