//! Compute engines: where the O(d²·|T|) kernels run.
//!
//! [`Engine`] abstracts the three hot operations (margins, weighted gram,
//! fused step). Two implementations:
//!
//! - [`NativeEngine`] — pure-rust f64, threaded. Routes every FLOP
//!   through the tiled GEMM/SYRK core in [`crate::linalg::gemm`]:
//!   [`KernelCore::Auto`] (the default) picks the row-stream geometry
//!   ([`KernelCore::Tiled`]) below `gemm::D_BLOCK_MIN_D` and the
//!   d-blocked geometry ([`KernelCore::DBlocked`], cache-sized buffers
//!   independently of d) at and above it — the two are bitwise
//!   identical, so the switch is invisible to results. The original
//!   scalar core ([`KernelCore::Scalar`], via [`NativeEngine::scalar`])
//!   is kept as the parity oracle and perf baseline, and as the
//!   fallback for dimensions without compiled artifacts.
//! - [`PjrtEngine`] — loads the AOT artifacts (`artifacts/*.hlo.txt`,
//!   lowered from the L2 JAX model wrapping the L1 Pallas kernels) and
//!   executes them through the PJRT C API via the `xla` crate. Its
//!   dispatch keeps the same grid-accumulator structure and row-block
//!   geometry as the native panels ([`crate::linalg::gemm::PANEL_ROWS`]),
//!   so native-vs-PJRT comparisons measure the backend, not the blocking.
//!
//! Both must agree to f64 round-off; `rust/tests/runtime_pjrt.rs` checks
//! exactly that on the real artifacts, and `rust/tests/kernel_parity.rs`
//! checks the tiled core against the scalar reference.

mod native;
// The real PJRT engine needs the vendored `xla` + `anyhow` crates, which
// the offline image cannot carry in Cargo.toml. `--features pjrt` opts
// into the PJRT surface; compiling the *real* engine additionally
// requires `RUSTFLAGS="--cfg pjrt_runtime"` once those crates are
// vendored as path deps. This keeps the whole feature matrix compiling
// (`cargo check --features pjrt` builds the stub, enforced in CI); the
// stub is API-identical and its constructors fail cleanly, so every
// caller falls back to the native engine.
#[cfg(all(feature = "pjrt", pjrt_runtime))]
mod pjrt;
#[cfg(not(all(feature = "pjrt", pjrt_runtime)))]
#[path = "pjrt_stub.rs"]
mod pjrt;

pub use native::{KernelCore, NativeEngine};
pub use pjrt::{PjrtEngine, ARTIFACTS_DIR_ENV};

use crate::linalg::Mat;

/// One objective/gradient evaluation: `(loss_sum, grad_loss_sum)` where
/// `grad_loss_sum = Σ_t α_t H_t`; margins are written to `margins_out`.
pub type StepOut = (f64, Mat);

/// A compute engine for the triplet kernels.
///
/// Rows of `a`/`b` are the difference vectors `x_i − x_l` / `x_i − x_j`
/// of the (compacted) triplet set. All matrices are row-major f64.
pub trait Engine: Sync {
    /// Engine label for reports (`native`, `native-scalar`, `pjrt`).
    fn name(&self) -> &'static str;

    /// `out[t] = a_t^T mat a_t − b_t^T mat b_t` — serves both `⟨M, H_t⟩`
    /// (objective) and `⟨H_t, Q⟩` (screening statistic).
    fn margins(&self, mat: &Mat, a: &Mat, b: &Mat, out: &mut [f64]);

    /// `Σ_t w_t H_t = A^T diag(w) A − B^T diag(w) B`.
    fn wgram(&self, a: &Mat, b: &Mat, w: &[f64]) -> Mat;

    /// Fused margins + smoothed-hinge loss/derivative + gradient
    /// accumulation (one PJRT dispatch per block on the AOT path):
    /// returns `(Σ_t ℓ(m_t), Σ_t α_t H_t)` and fills `margins_out`.
    fn step(
        &self,
        mat: &Mat,
        a: &Mat,
        b: &Mat,
        gamma: f64,
        margins_out: &mut [f64],
    ) -> StepOut;
}
