//! Pure-rust reference engine (threaded f64).
//!
//! Each worker processes a contiguous block of triplets and accumulates a
//! worker-local gradient that is reduced at the end — matching the Pallas
//! kernel's grid-accumulator structure exactly, which keeps
//! native-vs-PJRT comparisons meaningful.
//!
//! Two interchangeable compute cores share that scaffold
//! ([`KernelCore`]):
//!
//! - **Tiled** (the default): routes every FLOP through
//!   [`crate::linalg::gemm`] — panel-tiled GEMM margins
//!   ([`gemm::PANEL_ROWS`] rows per tile, `M` L2-resident, each streamed
//!   `M` row reused across the whole panel from L1) and the
//!   upper-triangle weighted SYRK (half the FLOPs of the rank-1
//!   reference, mirrored once after the reduction).
//! - **Scalar**: the original per-row matvec + full rank-1 update
//!   reference, kept as the parity oracle
//!   (`rust/tests/kernel_parity.rs`) and the perf baseline
//!   (`benches/screening.rs` asserts the tiled core beats it).
//!
//! Worker scratch (the `M·x` lane, the panel `Y` tile, the per-panel α
//! lane) comes from a reusable [`ScratchPool`] instead of per-call
//! `vec![0.0; d]` allocations: after warm-up a kernel call allocates
//! nothing but its output. Every lane taken here is fully overwritten
//! before it is read (`matvec` fills `tmp`, `quad_forms_panel` zeroes
//! its panel, `alpha[k]` is assigned before `wsyrk_upper` reads it), so
//! the non-zeroing `take` is sound.

use super::{Engine, StepOut};
use crate::linalg::{gemm, Mat};
use crate::loss::Loss;
use crate::util::parallel;
use crate::util::pool::ScratchPool;

/// Which compute core a [`NativeEngine`] routes its kernels through.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelCore {
    /// per-row matvec margins + full rank-1 gradient updates (the
    /// original scalar reference; parity oracle and perf baseline)
    Scalar,
    /// panel-tiled GEMM margins + upper-triangle weighted SYRK
    /// (`linalg::gemm`)
    Tiled,
}

/// Native engine; `threads = 0` means auto.
pub struct NativeEngine {
    threads: usize,
    core: KernelCore,
    scratch: ScratchPool,
}

impl NativeEngine {
    /// Default engine: tiled compute core.
    pub fn new(threads: usize) -> NativeEngine {
        NativeEngine::with_core(threads, KernelCore::Tiled)
    }

    /// The original scalar core — parity oracle and perf baseline.
    pub fn scalar(threads: usize) -> NativeEngine {
        NativeEngine::with_core(threads, KernelCore::Scalar)
    }

    /// Engine with an explicit compute core.
    pub fn with_core(threads: usize, core: KernelCore) -> NativeEngine {
        NativeEngine {
            threads,
            core,
            scratch: ScratchPool::default(),
        }
    }

    /// The compute core this engine routes kernels through.
    pub fn core(&self) -> KernelCore {
        self.core
    }

    fn workers(&self) -> usize {
        if self.threads == 0 {
            parallel::default_threads()
        } else {
            self.threads
        }
    }
}

impl Default for NativeEngine {
    fn default() -> Self {
        NativeEngine::new(0)
    }
}

#[inline]
fn row_quad(mat: &Mat, x: &[f64], tmp: &mut [f64]) -> f64 {
    mat.matvec(x, tmp);
    let mut acc = 0.0;
    for (xi, ti) in x.iter().zip(tmp.iter()) {
        acc += xi * ti;
    }
    acc
}

impl Engine for NativeEngine {
    fn name(&self) -> &'static str {
        match self.core {
            KernelCore::Tiled => "native",
            KernelCore::Scalar => "native-scalar",
        }
    }

    fn margins(&self, mat: &Mat, a: &Mat, b: &Mat, out: &mut [f64]) {
        let d = mat.rows();
        debug_assert_eq!(a.cols(), d);
        debug_assert_eq!(a.rows(), out.len());
        debug_assert_eq!(b.rows(), out.len());
        let workers = self.workers();
        match self.core {
            KernelCore::Scalar => parallel::par_fill(out, workers, |range, chunk| {
                let mut tmp = self.scratch.take(d);
                for (k, t) in range.enumerate() {
                    chunk[k] =
                        row_quad(mat, a.row(t), &mut tmp) - row_quad(mat, b.row(t), &mut tmp);
                }
                self.scratch.put(tmp);
            }),
            KernelCore::Tiled => parallel::par_fill(out, workers, |range, chunk| {
                let mut y = self.scratch.take(gemm::PANEL_ROWS * d);
                gemm::margins_into(mat, a, b, range, chunk, &mut y);
                self.scratch.put(y);
            }),
        }
    }

    fn wgram(&self, a: &Mat, b: &Mat, w: &[f64]) -> Mat {
        let (n, d) = (a.rows(), a.cols());
        debug_assert_eq!(w.len(), n);
        let core = self.core;
        let partials = parallel::par_ranges(n, self.workers(), |range| {
            let mut g = Mat::zeros(d, d);
            match core {
                KernelCore::Tiled => {
                    let w_chunk = &w[range.clone()];
                    gemm::wsyrk_upper(&mut g, a, b, range, w_chunk);
                }
                KernelCore::Scalar => {
                    for t in range {
                        let wt = w[t];
                        if wt == 0.0 {
                            continue;
                        }
                        let (ra, rb) = (a.row(t), b.row(t));
                        for i in 0..d {
                            let (wai, wbi) = (wt * ra[i], wt * rb[i]);
                            let grow = g.row_mut(i);
                            for j in 0..d {
                                grow[j] += wai * ra[j] - wbi * rb[j];
                            }
                        }
                    }
                }
            }
            g
        });
        let mut g = Mat::zeros(d, d);
        for p in partials {
            g.axpy(1.0, &p);
        }
        // Both cores emit an exactly-symmetric gram from the same upper
        // triangle: the tiled core never computed the lower half, and
        // the scalar core's lower half is overwritten by the mirror.
        // The upper-triangle summands and the reduction order coincide,
        // so the two cores' outputs are bitwise identical — which is
        // what lets benches assert identical screening trajectories
        // across cores. (The scalar core still pays its full-rank-1
        // inner loop: the perf baseline is untouched.)
        gemm::mirror_upper(&mut g);
        g
    }

    fn step(
        &self,
        mat: &Mat,
        a: &Mat,
        b: &Mat,
        gamma: f64,
        margins_out: &mut [f64],
    ) -> StepOut {
        let (n, d) = (a.rows(), a.cols());
        debug_assert_eq!(margins_out.len(), n);
        let loss = if gamma > 0.0 {
            Loss::smoothed_hinge(gamma)
        } else {
            Loss::hinge()
        };
        let core = self.core;
        // one fused pass per worker: margins, loss, alpha, local gram —
        // the Pallas grid-accumulator structure, per compute core
        let ranges = parallel::split_ranges(n, self.workers());
        let results: Vec<(f64, Mat)> = std::thread::scope(|scope| {
            // split margins_out into per-range chunks
            let mut handles = Vec::new();
            let mut rest: &mut [f64] = margins_out;
            for range in &ranges {
                let (head, tail) = rest.split_at_mut(range.len());
                rest = tail;
                let range = range.clone();
                let scratch = &self.scratch;
                handles.push(scope.spawn(move || {
                    let mut g = Mat::zeros(d, d);
                    let mut lsum = 0.0;
                    match core {
                        KernelCore::Scalar => {
                            let mut tmp = scratch.take(d);
                            for (k, t) in range.enumerate() {
                                let (ra, rb) = (a.row(t), b.row(t));
                                let m = row_quad(mat, ra, &mut tmp)
                                    - row_quad(mat, rb, &mut tmp);
                                head[k] = m;
                                lsum += loss.value(m);
                                let alpha = loss.alpha(m);
                                if alpha != 0.0 {
                                    for i in 0..d {
                                        let (wai, wbi) = (alpha * ra[i], alpha * rb[i]);
                                        let grow = g.row_mut(i);
                                        for j in 0..d {
                                            grow[j] += wai * ra[j] - wbi * rb[j];
                                        }
                                    }
                                }
                            }
                            scratch.put(tmp);
                        }
                        KernelCore::Tiled => {
                            let mut y = scratch.take(gemm::PANEL_ROWS * d);
                            let mut alpha = scratch.take(gemm::PANEL_ROWS);
                            let mut p0 = range.start;
                            while p0 < range.end {
                                let pr = gemm::PANEL_ROWS.min(range.end - p0);
                                let off = p0 - range.start;
                                let chunk = &mut head[off..off + pr];
                                gemm::margins_into(mat, a, b, p0..p0 + pr, chunk, &mut y);
                                for (k, &m) in chunk.iter().enumerate() {
                                    lsum += loss.value(m);
                                    alpha[k] = loss.alpha(m);
                                }
                                gemm::wsyrk_upper(&mut g, a, b, p0..p0 + pr, &alpha[..pr]);
                                p0 += pr;
                            }
                            scratch.put(y);
                            scratch.put(alpha);
                        }
                    }
                    (lsum, g)
                }));
            }
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let mut lsum = 0.0;
        let mut g = Mat::zeros(d, d);
        for (l, p) in results {
            lsum += l;
            g.axpy(1.0, &p);
        }
        // mirror for BOTH cores — see the wgram comment: bitwise-equal
        // symmetric gradients keep the cores' solver trajectories
        // identical without touching the scalar perf baseline
        gemm::mirror_upper(&mut g);
        (lsum, g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{close, forall};
    use crate::util::rng::Pcg64;

    fn rand_inputs(rng: &mut Pcg64, n: usize, d: usize) -> (Mat, Mat, Mat) {
        let mut m = Mat::from_fn(d, d, |_, _| rng.normal());
        m.symmetrize();
        let a = Mat::from_fn(n, d, |_, _| rng.normal());
        let b = Mat::from_fn(n, d, |_, _| rng.normal());
        (m, a, b)
    }

    #[test]
    fn margins_match_naive() {
        forall("native-margins", 16, |rng| {
            let (n, d) = (1 + rng.below(200), 1 + rng.below(12));
            let (m, a, b) = rand_inputs(rng, n, d);
            for engine in [NativeEngine::new(3), NativeEngine::scalar(3)] {
                let mut out = vec![0.0; n];
                engine.margins(&m, &a, &b, &mut out);
                for t in 0..n {
                    let want = m.quad_form(a.row(t)) - m.quad_form(b.row(t));
                    close(out[t], want, 1e-12, 1e-12, engine.name())?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn wgram_matches_outer_sum() {
        forall("native-wgram", 12, |rng| {
            let (n, d) = (1 + rng.below(100), 1 + rng.below(10));
            let (_, a, b) = rand_inputs(rng, n, d);
            let w: Vec<f64> = (0..n).map(|_| rng.uniform()).collect();
            let mut want = Mat::zeros(d, d);
            for t in 0..n {
                want.axpy(w[t], &Mat::outer(a.row(t)));
                want.axpy(-w[t], &Mat::outer(b.row(t)));
            }
            for engine in [NativeEngine::new(2), NativeEngine::scalar(2)] {
                let g = engine.wgram(&a, &b, &w);
                close(g.sub(&want).max_abs(), 0.0, 0.0, 1e-10, engine.name())?;
            }
            Ok(())
        });
    }

    #[test]
    fn step_consistent_with_parts() {
        forall("native-step", 12, |rng| {
            let (n, d) = (8 + rng.below(120), 1 + rng.below(10));
            let (m, a, b) = rand_inputs(rng, n, d);
            let gamma = 0.05;
            let loss = Loss::smoothed_hinge(gamma);
            for eng in [NativeEngine::new(4), NativeEngine::scalar(4)] {
                let mut margins = vec![0.0; n];
                let (lsum, g) = eng.step(&m, &a, &b, gamma, &mut margins);
                let mut margins2 = vec![0.0; n];
                eng.margins(&m, &a, &b, &mut margins2);
                for t in 0..n {
                    close(margins[t], margins2[t], 1e-13, 1e-13, "m")?;
                }
                let want_l: f64 = margins2.iter().map(|&m| loss.value(m)).sum();
                close(lsum, want_l, 1e-11, 1e-11, "loss")?;
                let alpha: Vec<f64> = margins2.iter().map(|&m| loss.alpha(m)).collect();
                let want_g = eng.wgram(&a, &b, &alpha);
                close(g.sub(&want_g).max_abs(), 0.0, 0.0, 1e-10, "grad")?;
            }
            Ok(())
        });
    }

    #[test]
    fn tiled_matches_scalar_core() {
        // cross-core parity on panel-straddling shapes (also covered at
        // integration level by rust/tests/kernel_parity.rs)
        forall("native-core-parity", 12, |rng| {
            let n = 1 + rng.below(3 * gemm::PANEL_ROWS);
            let d = 1 + rng.below(20);
            let (m, a, b) = rand_inputs(rng, n, d);
            let tiled = NativeEngine::new(2);
            let scalar = NativeEngine::scalar(2);
            let mut mt = vec![0.0; n];
            let mut ms = vec![0.0; n];
            let (lt, gt) = tiled.step(&m, &a, &b, 0.05, &mut mt);
            let (ls, gs) = scalar.step(&m, &a, &b, 0.05, &mut ms);
            close(lt, ls, 1e-10, 1e-10, "loss")?;
            close(gt.sub(&gs).max_abs(), 0.0, 0.0, 1e-10, "grad")?;
            for t in 0..n {
                close(mt[t], ms[t], 1e-10, 1e-10, "margin")?;
            }
            Ok(())
        });
    }

    #[test]
    fn thread_count_invariance() {
        let mut rng = Pcg64::seed(5);
        let (m, a, b) = rand_inputs(&mut rng, 333, 7);
        for mk in [NativeEngine::new as fn(usize) -> NativeEngine, NativeEngine::scalar] {
            let mut o1 = vec![0.0; 333];
            let mut o8 = vec![0.0; 333];
            mk(1).margins(&m, &a, &b, &mut o1);
            mk(8).margins(&m, &a, &b, &mut o8);
            for t in 0..333 {
                assert!((o1[t] - o8[t]).abs() < 1e-12);
            }
            let w = vec![0.5; 333];
            let g1 = mk(1).wgram(&a, &b, &w);
            let g8 = mk(8).wgram(&a, &b, &w);
            assert!(g1.sub(&g8).max_abs() < 1e-10);
        }
    }

    #[test]
    fn hinge_step_gamma_zero() {
        let mut rng = Pcg64::seed(6);
        let (m, a, b) = rand_inputs(&mut rng, 64, 5);
        for eng in [NativeEngine::new(2), NativeEngine::scalar(2)] {
            let mut margins = vec![0.0; 64];
            let (lsum, _) = eng.step(&m, &a, &b, 0.0, &mut margins);
            let want: f64 = margins.iter().map(|&m| (1.0 - m).max(0.0)).sum();
            assert!((lsum - want).abs() < 1e-10);
        }
    }

    #[test]
    fn engine_scratch_is_recycled_across_calls() {
        // after a first call warmed the pool, later calls reuse lanes
        let eng = NativeEngine::new(2);
        let mut rng = Pcg64::seed(9);
        let (m, a, b) = rand_inputs(&mut rng, 100, 6);
        let mut out = vec![0.0; 100];
        eng.margins(&m, &a, &b, &mut out);
        let warmed = eng.scratch.pooled();
        assert!(warmed > 0, "no lanes returned to the pool");
        eng.margins(&m, &a, &b, &mut out);
        assert_eq!(eng.scratch.pooled(), warmed, "pool grew on a warm call");
    }

    #[test]
    fn engine_names_distinguish_cores() {
        assert_eq!(NativeEngine::new(1).name(), "native");
        assert_eq!(NativeEngine::scalar(1).name(), "native-scalar");
        assert_eq!(NativeEngine::new(1).core(), KernelCore::Tiled);
        assert_eq!(NativeEngine::scalar(1).core(), KernelCore::Scalar);
    }
}
