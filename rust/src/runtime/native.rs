//! Pure-rust reference engine (threaded f64).
//!
//! Every parallel pass rides the persistent worker pool
//! (`util::parallel`), split so that **each worker owns whole summation
//! chains**: margins parallelize over [`gemm::PANEL_ROWS`]-aligned row
//! chunks (each row's margin is one independent chain, and aligned
//! chunks keep the panel decomposition itself identical at any worker
//! count), the weighted SYRK over [`gemm::syrk_bands`] — disjoint
//! horizontal bands of the Gram's upper triangle, each worker
//! accumulating its band's cells outright — and the fused step runs
//! parallel margins, a *serial* O(n) loss/α pass (one `Σ_t ℓ(m_t)`
//! chain, owned by the calling thread), then the band-parallel SYRK on
//! the α weights. No pass anywhere reduces partial per-cell
//! accumulators, so N-worker output is **bitwise identical** to
//! 1-worker for every kernel ([`Engine::workers`] can never move a
//! screening decision — `rust/tests/kernel_parity.rs` asserts `==` on
//! bits across worker counts).
//!
//! Interchangeable compute cores share that scaffold ([`KernelCore`]):
//!
//! - **Tiled** (row-stream): routes every FLOP through
//!   [`crate::linalg::gemm`] — panel-tiled GEMM margins
//!   ([`gemm::PANEL_ROWS`] rows per tile, `M` L2-resident, each streamed
//!   `M` row reused across the whole panel from L1) and the
//!   upper-triangle weighted SYRK (half the FLOPs of the rank-1
//!   reference, mirrored once after the reduction).
//! - **DBlocked**: the same panels with the feature dimension
//!   additionally split into [`gemm::D_BLOCK`]-column blocks
//!   ([`gemm::margins_into_d_blocked`] / [`gemm::wsyrk_upper_d_blocked`])
//!   so every hot buffer is cache-sized independently of d — the
//!   geometry for the paper's d ≳ 512 benchmarks, bitwise identical to
//!   the row-stream core by construction.
//! - **Auto** (the default): picks DBlocked when the call's d reaches
//!   the engine's threshold ([`gemm::D_BLOCK_MIN_D`] unless overridden
//!   via [`NativeEngine::with_d_threshold`] / CLI `--d-threshold`),
//!   Tiled below it. Because the two geometries are bitwise identical,
//!   the switch can never change a result — only the cache behavior.
//! - **Scalar**: the original per-row matvec + full rank-1 update
//!   reference, kept as the parity oracle
//!   (`rust/tests/kernel_parity.rs`) and the perf baseline
//!   (`benches/screening.rs` asserts the tiled core beats it).
//!
//! Worker scratch (the `M·x` lane, the panel `Y` tile, the per-panel α
//! lane) comes from a reusable [`ScratchPool`] instead of per-call
//! `vec![0.0; d]` allocations: after warm-up a kernel call allocates
//! nothing but its output. Every lane taken here is fully overwritten
//! before it is read (`matvec` fills `tmp`, `quad_forms_panel` zeroes
//! its panel, `alpha[k]` is assigned before `wsyrk_upper` reads it), so
//! the non-zeroing `take` is sound.
//!
//! Under [`PrecisionTier::MixedCertified`]
//! ([`NativeEngine::with_precision`], CLI `--precision mixed`) the
//! engine additionally serves [`Engine::margins_f32`]: inputs are
//! converted once per pass (O(n·d), against the O(n·d²) kernel), the
//! *same* generic panel kernels run instantiated at `f32` (through the
//! row-stream or d-blocked geometry the core selection dictates), and
//! each row gets the certified rounding envelope
//! [`crate::screening::bounds::eps_round`] computed from the f64 data
//! norms during conversion. The f32 lanes live in a second
//! [`ScratchPool`] so warm mixed-tier passes allocate nothing either.

use super::{Engine, PrecisionTier, StepOut};
use crate::linalg::{gemm, Mat};
use crate::loss::Loss;
use crate::screening::bounds::eps_round;
use crate::util::parallel;
use crate::util::pool::ScratchPool;

/// Which compute core a [`NativeEngine`] routes its kernels through.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelCore {
    /// per-row matvec margins + full rank-1 gradient updates (the
    /// original scalar reference; parity oracle and perf baseline)
    Scalar,
    /// row-stream geometry: panel-tiled GEMM margins + upper-triangle
    /// weighted SYRK (`linalg::gemm`), whole rows of `M`/`G` resident
    Tiled,
    /// d-blocked geometry: the same panels with the feature dimension
    /// split into `gemm::D_BLOCK`-column blocks — cache-sized buffers
    /// independently of d, bitwise identical to `Tiled`
    DBlocked,
    /// per-call selection: `DBlocked` once d reaches the engine's
    /// threshold (`gemm::D_BLOCK_MIN_D` by default), `Tiled` below it
    Auto,
}

impl KernelCore {
    /// Parse a CLI/config spelling (`auto`, `row-stream`, `d-blocked`,
    /// `scalar`; aliases `tiled` and `dblocked` accepted).
    pub fn parse(s: &str) -> Option<KernelCore> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Some(KernelCore::Auto),
            "row-stream" | "rowstream" | "tiled" => Some(KernelCore::Tiled),
            "d-blocked" | "dblocked" => Some(KernelCore::DBlocked),
            "scalar" => Some(KernelCore::Scalar),
            _ => None,
        }
    }

    /// [`Self::parse`] with the canonical CLI failure: panics, naming
    /// the valid spellings. Both binaries route `--kernel-core` through
    /// this so the message (and the accepted set) cannot drift.
    pub fn parse_cli(s: &str) -> KernelCore {
        KernelCore::parse(s).unwrap_or_else(|| {
            panic!("unknown --kernel-core {s:?} (auto|row-stream|d-blocked|scalar)")
        })
    }
}

/// Native engine; `threads = 0` means auto.
pub struct NativeEngine {
    threads: usize,
    core: KernelCore,
    /// d at which `KernelCore::Auto` switches to the d-blocked geometry
    d_threshold: usize,
    /// numeric tier of the bulk screening passes (`F64` unless opted in)
    precision: PrecisionTier,
    scratch: ScratchPool,
    /// f32 conversion/compute lanes of the mixed-precision tier
    scratch32: ScratchPool<f32>,
}

impl NativeEngine {
    /// Default engine: auto core (row-stream below
    /// [`gemm::D_BLOCK_MIN_D`], d-blocked at and above it).
    pub fn new(threads: usize) -> NativeEngine {
        NativeEngine::with_core(threads, KernelCore::Auto)
    }

    /// The original scalar core — parity oracle and perf baseline.
    pub fn scalar(threads: usize) -> NativeEngine {
        NativeEngine::with_core(threads, KernelCore::Scalar)
    }

    /// Row-stream geometry pinned regardless of d (bench baseline for
    /// the d-blocked comparison).
    pub fn row_stream(threads: usize) -> NativeEngine {
        NativeEngine::with_core(threads, KernelCore::Tiled)
    }

    /// d-blocked geometry pinned regardless of d.
    pub fn d_blocked(threads: usize) -> NativeEngine {
        NativeEngine::with_core(threads, KernelCore::DBlocked)
    }

    /// Engine with an explicit compute core.
    pub fn with_core(threads: usize, core: KernelCore) -> NativeEngine {
        NativeEngine {
            threads,
            core,
            d_threshold: gemm::D_BLOCK_MIN_D,
            precision: PrecisionTier::F64,
            scratch: ScratchPool::default(),
            scratch32: ScratchPool::default(),
        }
    }

    /// Engine from CLI/config-style options: `None` falls back to the
    /// defaults (`Auto` core, [`gemm::D_BLOCK_MIN_D`] threshold, exact
    /// `F64` tier). The one construction path both binaries share —
    /// pair with [`KernelCore::parse_cli`] /
    /// [`PrecisionTier::parse_cli`] for the spelling parses.
    pub fn from_options(
        threads: usize,
        core: Option<KernelCore>,
        d_threshold: Option<usize>,
        precision: Option<PrecisionTier>,
    ) -> NativeEngine {
        let mut engine = NativeEngine::with_core(threads, core.unwrap_or(KernelCore::Auto));
        if let Some(t) = d_threshold {
            engine = engine.with_d_threshold(t);
        }
        engine.with_precision(precision.unwrap_or_default())
    }

    /// Override the `Auto` switch-over dimension (CLI `--d-threshold`).
    /// No effect on pinned cores.
    pub fn with_d_threshold(mut self, d_threshold: usize) -> NativeEngine {
        self.d_threshold = d_threshold.max(1);
        self
    }

    /// Select the numeric tier of the bulk screening passes (CLI
    /// `--precision`). [`PrecisionTier::MixedCertified`] turns
    /// [`Engine::margins_f32`] on; everything else is unaffected.
    pub fn with_precision(mut self, precision: PrecisionTier) -> NativeEngine {
        self.precision = precision;
        self
    }

    /// Override the worker count after construction (builder form of the
    /// constructors' `threads` argument; `0` = auto, i.e.
    /// `parallel::default_threads()`). Worker counts size the split only
    /// — every kernel is bitwise identical at any setting.
    pub fn with_workers(mut self, workers: usize) -> NativeEngine {
        self.threads = workers;
        self
    }

    /// The compute core this engine routes kernels through (possibly
    /// `Auto`; see [`Self::core_for`] for the per-d resolution).
    pub fn core(&self) -> KernelCore {
        self.core
    }

    /// The concrete core a call with feature dimension `d` runs on —
    /// never `Auto`.
    pub fn core_for(&self, d: usize) -> KernelCore {
        match self.core {
            KernelCore::Auto => {
                if d >= self.d_threshold {
                    KernelCore::DBlocked
                } else {
                    KernelCore::Tiled
                }
            }
            pinned => pinned,
        }
    }
}

impl Default for NativeEngine {
    fn default() -> Self {
        NativeEngine::new(0)
    }
}

#[inline]
fn row_quad(mat: &Mat, x: &[f64], tmp: &mut [f64]) -> f64 {
    mat.matvec(x, tmp);
    let mut acc = 0.0;
    for (xi, ti) in x.iter().zip(tmp.iter()) {
        acc += xi * ti;
    }
    acc
}

impl Engine for NativeEngine {
    fn name(&self) -> &'static str {
        match self.core {
            KernelCore::Auto => "native",
            KernelCore::Tiled => "native-rowstream",
            KernelCore::DBlocked => "native-dblocked",
            KernelCore::Scalar => "native-scalar",
        }
    }

    fn workers(&self) -> usize {
        if self.threads == 0 {
            parallel::default_threads()
        } else {
            self.threads
        }
    }

    fn margins(&self, mat: &Mat, a: &Mat, b: &Mat, out: &mut [f64]) {
        let d = mat.rows();
        debug_assert_eq!(a.cols(), d);
        debug_assert_eq!(a.rows(), out.len());
        debug_assert_eq!(b.rows(), out.len());
        let workers = self.workers();
        // chunk boundaries on PANEL_ROWS multiples: each row's margin is
        // an independent chain, and aligned chunks additionally keep the
        // panel decomposition itself identical at any worker count
        let align = gemm::PANEL_ROWS;
        match self.core_for(d) {
            KernelCore::Scalar => parallel::par_fill(out, workers, |range, chunk| {
                let mut tmp = self.scratch.take(d);
                for (k, t) in range.enumerate() {
                    chunk[k] =
                        row_quad(mat, a.row(t), &mut tmp) - row_quad(mat, b.row(t), &mut tmp);
                }
                self.scratch.put(tmp);
            }),
            KernelCore::Tiled => parallel::par_fill_aligned(out, workers, align, |range, chunk| {
                let mut y = self.scratch.take(gemm::PANEL_ROWS * d);
                gemm::margins_into(mat, a, b, range, chunk, &mut y);
                self.scratch.put(y);
            }),
            KernelCore::DBlocked => {
                parallel::par_fill_aligned(out, workers, align, |range, chunk| {
                    let mut y =
                        self.scratch.take(gemm::PANEL_ROWS * gemm::D_BLOCK.min(d.max(1)));
                    let mut acc = self.scratch.take(gemm::PANEL_ACC_LEN);
                    gemm::margins_into_d_blocked(
                        mat,
                        a,
                        b,
                        range,
                        chunk,
                        &mut y,
                        &mut acc,
                        gemm::D_BLOCK,
                    );
                    self.scratch.put(y);
                    self.scratch.put(acc);
                })
            }
            KernelCore::Auto => unreachable!("core_for never returns Auto"),
        }
    }

    fn wgram(&self, a: &Mat, b: &Mat, w: &[f64]) -> Mat {
        let (n, d) = (a.rows(), a.cols());
        debug_assert_eq!(w.len(), n);
        let workers = self.workers();
        let mut g = Mat::zeros(d, d);
        match self.core_for(d) {
            KernelCore::Tiled => gemm::wsyrk_upper_parallel(&mut g, a, b, 0..n, w, workers),
            KernelCore::DBlocked => gemm::wsyrk_upper_d_blocked_parallel(
                &mut g,
                a,
                b,
                0..n,
                w,
                gemm::D_BLOCK,
                workers,
            ),
            KernelCore::Scalar => {
                // band-parallel like the tiled cores — each worker owns
                // whole Gram rows, so every cell's Σ_t chain stays in
                // one worker — but rows cost the same here (full
                // rank-1 inner loop, lower half included), so the split
                // is by equal row count, not triangle cells
                let (a_s, b_s) = (a.as_slice(), b.as_slice());
                let bands = parallel::split_ranges(d, workers);
                let elems: Vec<std::ops::Range<usize>> =
                    bands.iter().map(|bd| bd.start * d..bd.end * d).collect();
                parallel::par_fill_ranges(g.as_mut_slice(), elems, |r, chunk| {
                    let band = r.start / d..r.end / d;
                    for t in 0..n {
                        let wt = w[t];
                        if wt == 0.0 {
                            continue;
                        }
                        let (ra, rb) = (&a_s[t * d..(t + 1) * d], &b_s[t * d..(t + 1) * d]);
                        for i in band.clone() {
                            let (wai, wbi) = (wt * ra[i], wt * rb[i]);
                            let row0 = (i - band.start) * d;
                            let grow = &mut chunk[row0..row0 + d];
                            for j in 0..d {
                                grow[j] += wai * ra[j] - wbi * rb[j];
                            }
                        }
                    }
                });
            }
            KernelCore::Auto => unreachable!("core_for never returns Auto"),
        }
        // Every core emits an exactly-symmetric gram from the same upper
        // triangle: the tiled/d-blocked cores never computed the lower
        // half, and the scalar core's lower half is overwritten by the
        // mirror. The upper-triangle summands and the per-cell chain
        // order coincide — each cell's Σ_t lives whole inside one band —
        // so all cores' outputs are bitwise identical at any worker
        // count, which is what lets benches assert identical screening
        // trajectories across cores and worker counts. (The scalar core
        // still pays its full-rank-1 inner loop: the perf baseline is
        // untouched.)
        gemm::mirror_upper(&mut g);
        g
    }

    fn step(
        &self,
        mat: &Mat,
        a: &Mat,
        b: &Mat,
        gamma: f64,
        margins_out: &mut [f64],
    ) -> StepOut {
        let (n, _d) = (a.rows(), a.cols());
        debug_assert_eq!(margins_out.len(), n);
        let loss = if gamma > 0.0 {
            Loss::smoothed_hinge(gamma)
        } else {
            Loss::hinge()
        };
        // three passes, each bitwise worker-count-invariant: pooled
        // margins (row chains), a serial O(n) loss/α pass (one Σ_t loss
        // chain, t ascending — same order the old fused single-worker
        // pass used), then the band-parallel wgram. The fused per-worker
        // pass this replaces reduced per-worker partial grams in chunk
        // order, which regrouped per-cell chains and made the bits
        // depend on the worker count.
        self.margins(mat, a, b, margins_out);
        let mut alpha = self.scratch.take(n);
        let mut lsum = 0.0;
        for (k, &m) in margins_out.iter().enumerate() {
            lsum += loss.value(m);
            alpha[k] = loss.alpha(m);
        }
        let g = self.wgram(a, b, &alpha[..n]);
        self.scratch.put(alpha);
        (lsum, g)
    }

    fn precision(&self) -> PrecisionTier {
        self.precision
    }

    fn margins_f32(&self, mat: &Mat, a: &Mat, b: &Mat, out: &mut [f64], env: &mut [f64]) -> bool {
        if self.precision != PrecisionTier::MixedCertified {
            return false;
        }
        let d = mat.rows();
        let n = a.rows();
        debug_assert!(mat.is_square());
        debug_assert_eq!(a.cols(), d);
        debug_assert_eq!(b.cols(), d);
        debug_assert_eq!(out.len(), n);
        debug_assert_eq!(env.len(), n);
        let q_norm = mat.norm();
        // One O(n·d) conversion + envelope pass against the O(n·d²)
        // kernel. The envelope's row norms accumulate in f64, per side
        // in ascending index order — `CandidateBatch::push`'s chains —
        // so the two admission surfaces quote identical norms.
        let mut m32 = self.scratch32.take(d * d);
        for (dst, &src) in m32.iter_mut().zip(mat.as_slice()) {
            *dst = src as f32;
        }
        let mut a32 = self.scratch32.take(n * d);
        let mut b32 = self.scratch32.take(n * d);
        for t in 0..n {
            let mut na = 0.0;
            for (dst, &src) in a32[t * d..(t + 1) * d].iter_mut().zip(a.row(t)) {
                *dst = src as f32;
                na += src * src;
            }
            let mut nb = 0.0;
            for (dst, &src) in b32[t * d..(t + 1) * d].iter_mut().zip(b.row(t)) {
                *dst = src as f32;
                nb += src * src;
            }
            env[t] = eps_round(d, q_norm, na + nb);
        }
        let mut out32 = self.scratch32.take(n);
        let workers = self.workers();
        match self.core_for(d) {
            // the f32 tier always runs the microkernel panels — the
            // scalar core routes through the row-stream geometry
            KernelCore::Scalar | KernelCore::Tiled => {
                parallel::par_fill_aligned(&mut out32, workers, gemm::PANEL_ROWS, |range, chunk| {
                    let mut y = self.scratch32.take(gemm::PANEL_ROWS * d.max(1));
                    gemm::margins_into_g(&m32, d, &a32, &b32, range, chunk, &mut y);
                    self.scratch32.put(y);
                });
            }
            KernelCore::DBlocked => {
                parallel::par_fill_aligned(&mut out32, workers, gemm::PANEL_ROWS, |range, chunk| {
                    let mut y = self
                        .scratch32
                        .take(gemm::PANEL_ROWS * gemm::D_BLOCK.min(d.max(1)));
                    let mut acc = self.scratch32.take(gemm::PANEL_ACC_LEN);
                    gemm::margins_into_d_blocked_g(
                        &m32,
                        d,
                        &a32,
                        &b32,
                        range,
                        chunk,
                        &mut y,
                        &mut acc,
                        gemm::D_BLOCK,
                    );
                    self.scratch32.put(y);
                    self.scratch32.put(acc);
                });
            }
            KernelCore::Auto => unreachable!("core_for never returns Auto"),
        }
        for (o, &v) in out.iter_mut().zip(out32.iter()) {
            *o = v as f64;
        }
        self.scratch32.put(m32);
        self.scratch32.put(a32);
        self.scratch32.put(b32);
        self.scratch32.put(out32);
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{close, forall};
    use crate::util::rng::Pcg64;

    fn rand_inputs(rng: &mut Pcg64, n: usize, d: usize) -> (Mat, Mat, Mat) {
        let mut m = Mat::from_fn(d, d, |_, _| rng.normal());
        m.symmetrize();
        let a = Mat::from_fn(n, d, |_, _| rng.normal());
        let b = Mat::from_fn(n, d, |_, _| rng.normal());
        (m, a, b)
    }

    fn all_cores(threads: usize) -> [NativeEngine; 4] {
        [
            NativeEngine::new(threads),
            NativeEngine::row_stream(threads),
            NativeEngine::d_blocked(threads),
            NativeEngine::scalar(threads),
        ]
    }

    #[test]
    fn margins_match_naive() {
        forall("native-margins", 16, |rng| {
            let (n, d) = (1 + rng.below(200), 1 + rng.below(12));
            let (m, a, b) = rand_inputs(rng, n, d);
            for engine in all_cores(3) {
                let mut out = vec![0.0; n];
                engine.margins(&m, &a, &b, &mut out);
                for t in 0..n {
                    let want = m.quad_form(a.row(t)) - m.quad_form(b.row(t));
                    close(out[t], want, 1e-12, 1e-12, engine.name())?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn wgram_matches_outer_sum() {
        forall("native-wgram", 12, |rng| {
            let (n, d) = (1 + rng.below(100), 1 + rng.below(10));
            let (_, a, b) = rand_inputs(rng, n, d);
            let w: Vec<f64> = (0..n).map(|_| rng.uniform()).collect();
            let mut want = Mat::zeros(d, d);
            for t in 0..n {
                want.axpy(w[t], &Mat::outer(a.row(t)));
                want.axpy(-w[t], &Mat::outer(b.row(t)));
            }
            for engine in all_cores(2) {
                let g = engine.wgram(&a, &b, &w);
                close(g.sub(&want).max_abs(), 0.0, 0.0, 1e-10, engine.name())?;
            }
            Ok(())
        });
    }

    #[test]
    fn step_consistent_with_parts() {
        forall("native-step", 12, |rng| {
            let (n, d) = (8 + rng.below(120), 1 + rng.below(10));
            let (m, a, b) = rand_inputs(rng, n, d);
            let gamma = 0.05;
            let loss = Loss::smoothed_hinge(gamma);
            for eng in all_cores(4) {
                let mut margins = vec![0.0; n];
                let (lsum, g) = eng.step(&m, &a, &b, gamma, &mut margins);
                let mut margins2 = vec![0.0; n];
                eng.margins(&m, &a, &b, &mut margins2);
                for t in 0..n {
                    close(margins[t], margins2[t], 1e-13, 1e-13, "m")?;
                }
                let want_l: f64 = margins2.iter().map(|&m| loss.value(m)).sum();
                close(lsum, want_l, 1e-11, 1e-11, "loss")?;
                let alpha: Vec<f64> = margins2.iter().map(|&m| loss.alpha(m)).collect();
                let want_g = eng.wgram(&a, &b, &alpha);
                close(g.sub(&want_g).max_abs(), 0.0, 0.0, 1e-10, "grad")?;
            }
            Ok(())
        });
    }

    #[test]
    fn tiled_matches_scalar_core() {
        // cross-core parity on panel-straddling shapes (also covered at
        // integration level by rust/tests/kernel_parity.rs)
        forall("native-core-parity", 12, |rng| {
            let n = 1 + rng.below(3 * gemm::PANEL_ROWS);
            let d = 1 + rng.below(20);
            let (m, a, b) = rand_inputs(rng, n, d);
            let tiled = NativeEngine::row_stream(2);
            let scalar = NativeEngine::scalar(2);
            let mut mt = vec![0.0; n];
            let mut ms = vec![0.0; n];
            let (lt, gt) = tiled.step(&m, &a, &b, 0.05, &mut mt);
            let (ls, gs) = scalar.step(&m, &a, &b, 0.05, &mut ms);
            close(lt, ls, 1e-10, 1e-10, "loss")?;
            close(gt.sub(&gs).max_abs(), 0.0, 0.0, 1e-10, "grad")?;
            for t in 0..n {
                close(mt[t], ms[t], 1e-10, 1e-10, "margin")?;
            }
            Ok(())
        });
    }

    #[test]
    fn d_blocked_core_is_bitwise_identical_to_row_stream() {
        // core selection must never change a bit: same step outputs for
        // the d-blocked geometry as for the row-stream one, on shapes
        // straddling both the row-panel and (via small d vs D_BLOCK) the
        // single-partial-block edge
        forall("native-dblock-bitwise", 12, |rng| {
            let n = 1 + rng.below(3 * gemm::PANEL_ROWS);
            let d = 1 + rng.below(20);
            let (m, a, b) = rand_inputs(rng, n, d);
            let rs = NativeEngine::row_stream(2);
            let db = NativeEngine::d_blocked(2);
            let mut mr = vec![0.0; n];
            let mut md = vec![0.0; n];
            let (lr, gr) = rs.step(&m, &a, &b, 0.05, &mut mr);
            let (ld, gd) = db.step(&m, &a, &b, 0.05, &mut md);
            if lr.to_bits() != ld.to_bits() {
                return Err(format!("loss bits diverged: {lr} vs {ld}"));
            }
            for t in 0..n {
                if mr[t].to_bits() != md[t].to_bits() {
                    return Err(format!("margin {t} bits diverged"));
                }
            }
            for i in 0..d {
                for j in 0..d {
                    if gr[(i, j)].to_bits() != gd[(i, j)].to_bits() {
                        return Err(format!("grad ({i},{j}) bits diverged"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn auto_core_resolves_by_d_threshold() {
        let auto = NativeEngine::new(1);
        assert_eq!(auto.core(), KernelCore::Auto);
        assert_eq!(auto.core_for(gemm::D_BLOCK_MIN_D - 1), KernelCore::Tiled);
        assert_eq!(auto.core_for(gemm::D_BLOCK_MIN_D), KernelCore::DBlocked);
        let low = NativeEngine::new(1).with_d_threshold(8);
        assert_eq!(low.core_for(7), KernelCore::Tiled);
        assert_eq!(low.core_for(8), KernelCore::DBlocked);
        // pinned cores ignore the threshold
        assert_eq!(
            NativeEngine::scalar(1).with_d_threshold(1).core_for(999),
            KernelCore::Scalar
        );
        assert_eq!(
            NativeEngine::row_stream(1).with_d_threshold(1).core_for(999),
            KernelCore::Tiled
        );
    }

    #[test]
    fn kernel_core_parses_cli_spellings() {
        assert_eq!(KernelCore::parse("auto"), Some(KernelCore::Auto));
        assert_eq!(KernelCore::parse("row-stream"), Some(KernelCore::Tiled));
        assert_eq!(KernelCore::parse("tiled"), Some(KernelCore::Tiled));
        assert_eq!(KernelCore::parse("d-blocked"), Some(KernelCore::DBlocked));
        assert_eq!(KernelCore::parse("DBlocked"), Some(KernelCore::DBlocked));
        assert_eq!(KernelCore::parse("scalar"), Some(KernelCore::Scalar));
        assert_eq!(KernelCore::parse("mmx"), None);
        assert_eq!(KernelCore::parse_cli("d-blocked"), KernelCore::DBlocked);
    }

    #[test]
    #[should_panic(expected = "unknown --kernel-core")]
    fn kernel_core_cli_typo_fails_loudly() {
        let _ = KernelCore::parse_cli("dblockedd");
    }

    #[test]
    fn from_options_applies_overrides() {
        let defaulted = NativeEngine::from_options(2, None, None, None);
        assert_eq!(defaulted.core(), KernelCore::Auto);
        assert_eq!(defaulted.core_for(gemm::D_BLOCK_MIN_D), KernelCore::DBlocked);
        assert_eq!(defaulted.precision(), PrecisionTier::F64);
        let pinned = NativeEngine::from_options(2, Some(KernelCore::Scalar), Some(4), None);
        assert_eq!(pinned.core(), KernelCore::Scalar);
        let low = NativeEngine::from_options(2, Some(KernelCore::Auto), Some(4), None);
        assert_eq!(low.core_for(4), KernelCore::DBlocked);
        assert_eq!(low.core_for(3), KernelCore::Tiled);
        let mixed = NativeEngine::from_options(
            2,
            None,
            None,
            Some(PrecisionTier::MixedCertified),
        );
        assert_eq!(mixed.precision(), PrecisionTier::MixedCertified);
    }

    #[test]
    fn precision_tier_parses_cli_spellings() {
        assert_eq!(PrecisionTier::parse("f64"), Some(PrecisionTier::F64));
        assert_eq!(PrecisionTier::parse("exact"), Some(PrecisionTier::F64));
        assert_eq!(
            PrecisionTier::parse("mixed"),
            Some(PrecisionTier::MixedCertified)
        );
        assert_eq!(
            PrecisionTier::parse("mixed-certified"),
            Some(PrecisionTier::MixedCertified)
        );
        assert_eq!(PrecisionTier::parse("f16"), None);
        assert_eq!(PrecisionTier::parse_cli("f32"), PrecisionTier::MixedCertified);
        assert_eq!(PrecisionTier::F64.label(), "f64");
        assert_eq!(PrecisionTier::MixedCertified.label(), "mixed");
    }

    #[test]
    #[should_panic(expected = "unknown precision tier")]
    fn precision_tier_cli_typo_fails_loudly() {
        let _ = PrecisionTier::parse_cli("mixedd");
    }

    #[test]
    fn margins_f32_requires_mixed_tier() {
        // an exact-tier engine must decline, leaving the buffers alone
        let eng = NativeEngine::new(1);
        let mut rng = Pcg64::seed(11);
        let (m, a, b) = rand_inputs(&mut rng, 10, 4);
        let mut out = vec![-9.0; 10];
        let mut env = vec![-9.0; 10];
        assert!(!eng.margins_f32(&m, &a, &b, &mut out, &mut env));
        assert!(out.iter().all(|&v| v == -9.0));
        assert!(env.iter().all(|&v| v == -9.0));
    }

    #[test]
    fn margins_f32_within_envelope_of_exact() {
        forall("native-margins-f32", 12, |rng| {
            let (n, d) = (1 + rng.below(150), 1 + rng.below(16));
            let (m, a, b) = rand_inputs(rng, n, d);
            let mut exact = vec![0.0; n];
            NativeEngine::new(2).margins(&m, &a, &b, &mut exact);
            let mut bits: Option<Vec<u64>> = None;
            for mk in [
                NativeEngine::row_stream as fn(usize) -> NativeEngine,
                NativeEngine::d_blocked,
                NativeEngine::scalar,
            ] {
                let eng = mk(2).with_precision(PrecisionTier::MixedCertified);
                let mut out = vec![0.0; n];
                let mut env = vec![0.0; n];
                if !eng.margins_f32(&m, &a, &b, &mut out, &mut env) {
                    return Err("mixed engine declined margins_f32".into());
                }
                for t in 0..n {
                    if env[t] <= 0.0 {
                        return Err(format!("t={t}: non-positive envelope {}", env[t]));
                    }
                    if (out[t] - exact[t]).abs() > env[t] {
                        return Err(format!(
                            "t={t}: |{} - {}| > env {}",
                            out[t], exact[t], env[t]
                        ));
                    }
                }
                // every core serves the same f32 bits (scalar routes
                // through the row-stream panels; d-blocked is bitwise
                // identical to them by construction)
                let ob: Vec<u64> = out.iter().map(|v| v.to_bits()).collect();
                match &bits {
                    None => bits = Some(ob),
                    Some(prev) => {
                        if *prev != ob {
                            return Err("f32 bits differ across cores".into());
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn thread_count_invariance_is_bitwise() {
        // the pool contract: every summation chain lives whole inside
        // one worker, so worker count never changes a bit
        let mut rng = Pcg64::seed(5);
        let (m, a, b) = rand_inputs(&mut rng, 333, 7);
        let w: Vec<f64> = (0..333).map(|_| rng.uniform()).collect();
        for mk in [
            NativeEngine::new as fn(usize) -> NativeEngine,
            NativeEngine::d_blocked,
            NativeEngine::scalar,
        ] {
            let mut o1 = vec![0.0; 333];
            let mut o8 = vec![0.0; 333];
            mk(1).margins(&m, &a, &b, &mut o1);
            mk(8).margins(&m, &a, &b, &mut o8);
            for t in 0..333 {
                assert_eq!(o1[t].to_bits(), o8[t].to_bits(), "margin {t}");
            }
            let g1 = mk(1).wgram(&a, &b, &w);
            let g8 = mk(8).wgram(&a, &b, &w);
            for i in 0..7 {
                for j in 0..7 {
                    assert_eq!(g1[(i, j)].to_bits(), g8[(i, j)].to_bits(), "g ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn step_is_bitwise_invariant_across_worker_counts() {
        let mut rng = Pcg64::seed(17);
        let (m, a, b) = rand_inputs(&mut rng, 257, 9);
        for mk in [
            NativeEngine::new as fn(usize) -> NativeEngine,
            NativeEngine::d_blocked,
            NativeEngine::scalar,
        ] {
            let mut ref_margins = vec![0.0; 257];
            let (ref_l, ref_g) = mk(0).with_workers(1).step(&m, &a, &b, 0.05, &mut ref_margins);
            for workers in [2, 3, 8] {
                let eng = mk(0).with_workers(workers);
                let mut margins = vec![0.0; 257];
                let (l, g) = eng.step(&m, &a, &b, 0.05, &mut margins);
                assert_eq!(l.to_bits(), ref_l.to_bits(), "loss at {workers} workers");
                for t in 0..257 {
                    assert_eq!(margins[t].to_bits(), ref_margins[t].to_bits());
                }
                for i in 0..9 {
                    for j in 0..9 {
                        assert_eq!(g[(i, j)].to_bits(), ref_g[(i, j)].to_bits());
                    }
                }
            }
        }
    }

    #[test]
    fn hinge_step_gamma_zero() {
        let mut rng = Pcg64::seed(6);
        let (m, a, b) = rand_inputs(&mut rng, 64, 5);
        for eng in all_cores(2) {
            let mut margins = vec![0.0; 64];
            let (lsum, _) = eng.step(&m, &a, &b, 0.0, &mut margins);
            let want: f64 = margins.iter().map(|&m| (1.0 - m).max(0.0)).sum();
            assert!((lsum - want).abs() < 1e-10);
        }
    }

    #[test]
    fn engine_scratch_is_recycled_across_calls() {
        // after a first call warmed the pool, later calls reuse lanes
        let eng = NativeEngine::new(2);
        let mut rng = Pcg64::seed(9);
        let (m, a, b) = rand_inputs(&mut rng, 100, 6);
        let mut out = vec![0.0; 100];
        eng.margins(&m, &a, &b, &mut out);
        let warmed = eng.scratch.pooled();
        assert!(warmed > 0, "no lanes returned to the pool");
        eng.margins(&m, &a, &b, &mut out);
        assert_eq!(eng.scratch.pooled(), warmed, "pool grew on a warm call");
    }

    #[test]
    fn engine_names_distinguish_cores() {
        assert_eq!(NativeEngine::new(1).name(), "native");
        assert_eq!(NativeEngine::row_stream(1).name(), "native-rowstream");
        assert_eq!(NativeEngine::d_blocked(1).name(), "native-dblocked");
        assert_eq!(NativeEngine::scalar(1).name(), "native-scalar");
        assert_eq!(NativeEngine::new(1).core(), KernelCore::Auto);
        assert_eq!(NativeEngine::row_stream(1).core(), KernelCore::Tiled);
        assert_eq!(NativeEngine::d_blocked(1).core(), KernelCore::DBlocked);
        assert_eq!(NativeEngine::scalar(1).core(), KernelCore::Scalar);
    }
}
