//! Pure-rust reference engine (threaded f64).
//!
//! Each worker processes a contiguous block of triplets: margins via a
//! per-row `M a` matvec (M stays L2-resident for d ≤ a few hundred), the
//! fused step additionally accumulates a worker-local `Σ α_t H_t` that is
//! reduced at the end — matching the Pallas kernel's grid-accumulator
//! structure exactly, which keeps native-vs-PJRT comparisons meaningful.

use super::{Engine, StepOut};
use crate::linalg::Mat;
use crate::loss::Loss;
use crate::util::parallel;

/// Native engine; `threads = 0` means auto.
pub struct NativeEngine {
    threads: usize,
}

impl NativeEngine {
    pub fn new(threads: usize) -> NativeEngine {
        NativeEngine { threads }
    }

    fn workers(&self) -> usize {
        if self.threads == 0 {
            parallel::default_threads()
        } else {
            self.threads
        }
    }
}

impl Default for NativeEngine {
    fn default() -> Self {
        NativeEngine::new(0)
    }
}

#[inline]
fn row_quad(mat: &Mat, x: &[f64], tmp: &mut [f64]) -> f64 {
    mat.matvec(x, tmp);
    let mut acc = 0.0;
    for (xi, ti) in x.iter().zip(tmp.iter()) {
        acc += xi * ti;
    }
    acc
}

impl Engine for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn margins(&self, mat: &Mat, a: &Mat, b: &Mat, out: &mut [f64]) {
        let d = mat.rows();
        debug_assert_eq!(a.cols(), d);
        debug_assert_eq!(a.rows(), out.len());
        debug_assert_eq!(b.rows(), out.len());
        parallel::par_fill(out, self.workers(), |range, chunk| {
            let mut tmp = vec![0.0; d];
            for (k, t) in range.enumerate() {
                chunk[k] = row_quad(mat, a.row(t), &mut tmp) - row_quad(mat, b.row(t), &mut tmp);
            }
        });
    }

    fn wgram(&self, a: &Mat, b: &Mat, w: &[f64]) -> Mat {
        let (n, d) = (a.rows(), a.cols());
        debug_assert_eq!(w.len(), n);
        let partials = parallel::par_ranges(n, self.workers(), |range| {
            let mut g = Mat::zeros(d, d);
            for t in range {
                let wt = w[t];
                if wt == 0.0 {
                    continue;
                }
                let (ra, rb) = (a.row(t), b.row(t));
                for i in 0..d {
                    let (wai, wbi) = (wt * ra[i], wt * rb[i]);
                    let grow = g.row_mut(i);
                    for j in 0..d {
                        grow[j] += wai * ra[j] - wbi * rb[j];
                    }
                }
            }
            g
        });
        let mut g = Mat::zeros(d, d);
        for p in partials {
            g.axpy(1.0, &p);
        }
        g
    }

    fn step(
        &self,
        mat: &Mat,
        a: &Mat,
        b: &Mat,
        gamma: f64,
        margins_out: &mut [f64],
    ) -> StepOut {
        let (n, d) = (a.rows(), a.cols());
        debug_assert_eq!(margins_out.len(), n);
        let loss = if gamma > 0.0 {
            Loss::smoothed_hinge(gamma)
        } else {
            Loss::hinge()
        };
        // one fused pass per worker: margins, loss, alpha, local gram
        let ranges = parallel::split_ranges(n, self.workers());
        let results: Vec<(f64, Mat)> = std::thread::scope(|scope| {
            // split margins_out into per-range chunks
            let mut handles = Vec::new();
            let mut rest: &mut [f64] = margins_out;
            for range in &ranges {
                let (head, tail) = rest.split_at_mut(range.len());
                rest = tail;
                let range = range.clone();
                handles.push(scope.spawn(move || {
                    let mut tmp = vec![0.0; d];
                    let mut g = Mat::zeros(d, d);
                    let mut lsum = 0.0;
                    for (k, t) in range.enumerate() {
                        let (ra, rb) = (a.row(t), b.row(t));
                        let m =
                            row_quad(mat, ra, &mut tmp) - row_quad(mat, rb, &mut tmp);
                        head[k] = m;
                        lsum += loss.value(m);
                        let alpha = loss.alpha(m);
                        if alpha != 0.0 {
                            for i in 0..d {
                                let (wai, wbi) = (alpha * ra[i], alpha * rb[i]);
                                let grow = g.row_mut(i);
                                for j in 0..d {
                                    grow[j] += wai * ra[j] - wbi * rb[j];
                                }
                            }
                        }
                    }
                    (lsum, g)
                }));
            }
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let mut lsum = 0.0;
        let mut g = Mat::zeros(d, d);
        for (l, p) in results {
            lsum += l;
            g.axpy(1.0, &p);
        }
        (lsum, g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{close, forall};
    use crate::util::rng::Pcg64;

    fn rand_inputs(rng: &mut Pcg64, n: usize, d: usize) -> (Mat, Mat, Mat) {
        let mut m = Mat::from_fn(d, d, |_, _| rng.normal());
        m.symmetrize();
        let a = Mat::from_fn(n, d, |_, _| rng.normal());
        let b = Mat::from_fn(n, d, |_, _| rng.normal());
        (m, a, b)
    }

    #[test]
    fn margins_match_naive() {
        forall("native-margins", 16, |rng| {
            let (n, d) = (1 + rng.below(200), 1 + rng.below(12));
            let (m, a, b) = rand_inputs(rng, n, d);
            let mut out = vec![0.0; n];
            NativeEngine::new(3).margins(&m, &a, &b, &mut out);
            for t in 0..n {
                let want = m.quad_form(a.row(t)) - m.quad_form(b.row(t));
                close(out[t], want, 1e-12, 1e-12, "margin")?;
            }
            Ok(())
        });
    }

    #[test]
    fn wgram_matches_outer_sum() {
        forall("native-wgram", 12, |rng| {
            let (n, d) = (1 + rng.below(100), 1 + rng.below(10));
            let (_, a, b) = rand_inputs(rng, n, d);
            let w: Vec<f64> = (0..n).map(|_| rng.uniform()).collect();
            let g = NativeEngine::new(2).wgram(&a, &b, &w);
            let mut want = Mat::zeros(d, d);
            for t in 0..n {
                want.axpy(w[t], &Mat::outer(a.row(t)));
                want.axpy(-w[t], &Mat::outer(b.row(t)));
            }
            close(g.sub(&want).max_abs(), 0.0, 0.0, 1e-10, "wgram")
        });
    }

    #[test]
    fn step_consistent_with_parts() {
        forall("native-step", 12, |rng| {
            let (n, d) = (8 + rng.below(120), 1 + rng.below(10));
            let (m, a, b) = rand_inputs(rng, n, d);
            let gamma = 0.05;
            let loss = Loss::smoothed_hinge(gamma);
            let eng = NativeEngine::new(4);
            let mut margins = vec![0.0; n];
            let (lsum, g) = eng.step(&m, &a, &b, gamma, &mut margins);
            let mut margins2 = vec![0.0; n];
            eng.margins(&m, &a, &b, &mut margins2);
            for t in 0..n {
                close(margins[t], margins2[t], 1e-13, 1e-13, "m")?;
            }
            let want_l: f64 = margins2.iter().map(|&m| loss.value(m)).sum();
            close(lsum, want_l, 1e-11, 1e-11, "loss")?;
            let alpha: Vec<f64> = margins2.iter().map(|&m| loss.alpha(m)).collect();
            let want_g = eng.wgram(&a, &b, &alpha);
            close(g.sub(&want_g).max_abs(), 0.0, 0.0, 1e-10, "grad")
        });
    }

    #[test]
    fn thread_count_invariance() {
        let mut rng = Pcg64::seed(5);
        let (m, a, b) = rand_inputs(&mut rng, 333, 7);
        let mut o1 = vec![0.0; 333];
        let mut o8 = vec![0.0; 333];
        NativeEngine::new(1).margins(&m, &a, &b, &mut o1);
        NativeEngine::new(8).margins(&m, &a, &b, &mut o8);
        for t in 0..333 {
            assert!((o1[t] - o8[t]).abs() < 1e-12);
        }
        let g1 = NativeEngine::new(1).wgram(&a, &b, &vec![0.5; 333]);
        let g8 = NativeEngine::new(8).wgram(&a, &b, &vec![0.5; 333]);
        assert!(g1.sub(&g8).max_abs() < 1e-10);
    }

    #[test]
    fn hinge_step_gamma_zero() {
        let mut rng = Pcg64::seed(6);
        let (m, a, b) = rand_inputs(&mut rng, 64, 5);
        let mut margins = vec![0.0; 64];
        let (lsum, _) = NativeEngine::new(2).step(&m, &a, &b, 0.0, &mut margins);
        let want: f64 = margins.iter().map(|&m| (1.0 - m).max(0.0)).sum();
        assert!((lsum - want).abs() < 1e-10);
    }
}
