//! Diagonal-metric mode (paper Appendix B + §L.4 / Table 5).
//!
//! With `M = diag(m)`, `m ≥ 0`, everything collapses to vector algebra:
//! the margin is `⟨M, H_t⟩ = z_t^T m` with `z_t = diag(H_t)`
//! (`z_tj = a_tj² − b_tj²`), the PSD constraint becomes the nonnegative
//! orthant, the cone projection is `clamp(·, 0)` (no eigendecomposition),
//! and the screening spheres live in `R^d`. The semi-definite-constrained
//! rule (P2) reduces to the analytically solvable (P3):
//!
//!   min x^T h   s.t.  ‖x − q‖² ≤ r²,  x ≥ 0,
//!
//! solved by the Appendix-B KKT interval enumeration in O(d log d + d·#intervals).
//!
//! This makes high-dimensional datasets (usps/madelon/colon-cancer/gisette,
//! d up to thousands) tractable — the regime Table 5 evaluates.

use crate::data::Dataset;
use crate::linalg::Mat;
use crate::loss::Loss;
use crate::triplet::TripletStore;
use crate::util::parallel;

/// Triplet store specialized for diagonal metrics: rows are
/// `z_t = diag(H_t)`, with `‖z_t‖₂` cached (the diagonal-world `‖H‖`).
#[derive(Clone, Debug)]
pub struct DiagStore {
    /// `|T| × d` rows of z_t
    pub z: Mat,
    pub z_norm: Vec<f64>,
    pub d: usize,
}

impl DiagStore {
    pub fn from_store(store: &TripletStore) -> DiagStore {
        let (t, d) = (store.len(), store.d);
        let mut z = Mat::zeros(t, d);
        let mut z_norm = vec![0.0; t];
        for r in 0..t {
            let (ra, rb) = (store.a.row(r), store.b.row(r));
            let row = z.row_mut(r);
            let mut ns = 0.0;
            for j in 0..d {
                let v = ra[j] * ra[j] - rb[j] * rb[j];
                row[j] = v;
                ns += v * v;
            }
            z_norm[r] = ns.sqrt();
        }
        DiagStore { z, z_norm, d }
    }

    pub fn from_dataset(ds: &Dataset, k: usize, rng: &mut crate::util::rng::Pcg64) -> DiagStore {
        let store = TripletStore::from_dataset(ds, k, rng);
        Self::from_store(&store)
    }

    pub fn len(&self) -> usize {
        self.z.rows()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// margins `z_t^T m` over the given row subset into `out`.
    pub fn margins(&self, rows: &[usize], m: &[f64], out: &mut [f64]) {
        debug_assert_eq!(rows.len(), out.len());
        let workers = parallel::default_threads();
        parallel::par_fill(out, workers, |range, chunk| {
            for (k, i) in range.enumerate() {
                let row = self.z.row(rows[i]);
                let mut acc = 0.0;
                for j in 0..self.d {
                    acc += row[j] * m[j];
                }
                chunk[k] = acc;
            }
        });
    }

    /// `Σ_{t∈rows} w_t z_t`.
    pub fn weighted_sum(&self, rows: &[usize], w: &[f64]) -> Vec<f64> {
        debug_assert_eq!(rows.len(), w.len());
        let workers = parallel::default_threads();
        let partials = parallel::par_ranges(rows.len(), workers, |range| {
            let mut g = vec![0.0; self.d];
            for i in range {
                let wt = w[i];
                if wt == 0.0 {
                    continue;
                }
                let row = self.z.row(rows[i]);
                for j in 0..self.d {
                    g[j] += wt * row[j];
                }
            }
            g
        });
        let mut g = vec![0.0; self.d];
        for p in partials {
            for j in 0..self.d {
                g[j] += p[j];
            }
        }
        g
    }
}

fn clamp_nonneg(x: &[f64]) -> Vec<f64> {
    x.iter().map(|&v| v.max(0.0)).collect()
}

fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

fn norm(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// λ_max for the diagonal problem: `max_t z_t^T [Σ z]_+ / (1 − γ)`.
pub fn lambda_max(store: &DiagStore, loss: &Loss) -> f64 {
    let all: Vec<usize> = (0..store.len()).collect();
    let sum_z = store.weighted_sum(&all, &vec![1.0; store.len()]);
    let plus = clamp_nonneg(&sum_z);
    let mut hq = vec![0.0; store.len()];
    store.margins(&all, &plus, &mut hq);
    let max_hq = hq.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    (max_hq / (1.0 - loss.gamma).max(1e-12)).max(1e-12)
}

/// Appendix-B analytic minimum of (P3): `min x^T h` over
/// `‖x − q‖ ≤ r, x ≥ 0`. Exact via KKT interval enumeration.
pub fn nonneg_min(h: &[f64], q: &[f64], r: f64) -> f64 {
    let d = h.len();
    let hn = norm(h);
    if hn == 0.0 {
        return 0.0;
    }
    // sphere-only solution feasible?
    let mut x_sphere: Vec<f64> = q.iter().zip(h).map(|(&qk, &hk)| qk - r * hk / hn).collect();
    if x_sphere.iter().all(|&v| v >= 0.0) {
        return dot(&x_sphere, h);
    }
    // breakpoints α where x_k switches between 0 and interior
    let mut alphas: Vec<f64> = (0..d)
        .filter_map(|k| {
            if q[k] != 0.0 {
                let a = h[k] / (2.0 * q[k]);
                (a > 0.0 && a.is_finite()).then_some(a)
            } else {
                None
            }
        })
        .collect();
    alphas.sort_by(|a, b| a.partial_cmp(b).unwrap());
    alphas.dedup();
    // candidate intervals (α_k, α_{k+1}); also (last, ∞) and (0, first)
    let mut bounds = vec![0.0];
    bounds.extend(alphas);
    bounds.push(f64::INFINITY);

    let mut best = f64::INFINITY;
    for w in bounds.windows(2) {
        let (lo, hi) = (w[0], w[1]);
        // representative α inside the interval to fix the active set
        let mid = if hi.is_finite() {
            0.5 * (lo + hi)
        } else {
            lo * 2.0 + 1.0
        };
        // active set: x_k interior iff h_k − 2αq_k ≤ 0
        let interior: Vec<bool> = (0..d).map(|k| h[k] - 2.0 * mid * q[k] <= 0.0).collect();
        // solve ‖x(α) − q‖² = r²: Σ_int (h_k/2α)² + Σ_out q_k² = r²
        let s_out: f64 = (0..d)
            .filter(|&k| !interior[k])
            .map(|k| q[k] * q[k])
            .sum();
        let s_h: f64 = (0..d)
            .filter(|&k| interior[k])
            .map(|k| h[k] * h[k])
            .sum();
        let rem = r * r - s_out;
        if rem <= 0.0 {
            continue; // sphere cannot reach this face
        }
        let alpha = (s_h / (4.0 * rem)).sqrt();
        if !(alpha > 0.0) || alpha < lo - 1e-12 || alpha > hi + 1e-12 {
            continue;
        }
        // build x and check KKT
        let mut ok = true;
        let mut val = 0.0;
        for k in 0..d {
            let xk = if interior[k] {
                let v = q[k] - h[k] / (2.0 * alpha);
                if v < -1e-10 {
                    ok = false;
                    break;
                }
                v.max(0.0)
            } else {
                // needs β_k = h_k − 2αq_k ≥ 0 (within tolerance)
                if h[k] - 2.0 * alpha * q[k] < -1e-10 * (1.0 + h[k].abs()) {
                    ok = false;
                    break;
                }
                0.0
            };
            val += xk * h[k];
        }
        if ok {
            best = best.min(val);
        }
    }
    // α = 0 case (sphere inactive): KKT needs β = h ≥ 0; the minimum is
    // then 0, attained by zeroing every coordinate with h_k > 0 (and the
    // negative-q coordinates), provided that point stays in the sphere.
    if h.iter().all(|&v| v >= 0.0) {
        let dist_sq: f64 = (0..d)
            .map(|k| {
                if h[k] > 0.0 || q[k] < 0.0 {
                    q[k] * q[k]
                } else {
                    0.0
                }
            })
            .sum();
        if dist_sq <= r * r {
            best = best.min(0.0);
        }
    }
    let _ = &mut x_sphere;
    if best.is_finite() {
        best
    } else {
        // no interval validated numerically: fall back to the plain
        // sphere minimum — a valid (weaker) lower bound, hence safe.
        dot(q, h) - r * hn
    }
}

/// Sphere bounds in the diagonal (vector) world.
pub mod vbounds {
    use super::*;

    pub struct VSphere {
        pub q: Vec<f64>,
        pub r: f64,
    }

    /// GB (Thm 3.2): center `m − g/(2λ)`, radius `‖g‖/(2λ)`.
    pub fn gb(m: &[f64], grad: &[f64], lambda: f64) -> VSphere {
        let q: Vec<f64> = m
            .iter()
            .zip(grad)
            .map(|(&mi, &gi)| mi - gi / (2.0 * lambda))
            .collect();
        VSphere {
            q,
            r: norm(grad) / (2.0 * lambda),
        }
    }

    /// PGB (Thm 3.3) with the orthant projection.
    pub fn pgb(m: &[f64], grad: &[f64], lambda: f64) -> VSphere {
        let g = gb(m, grad, lambda);
        let plus = clamp_nonneg(&g.q);
        let minus_sq: f64 = g
            .q
            .iter()
            .map(|&v| if v < 0.0 { v * v } else { 0.0 })
            .sum();
        VSphere {
            q: plus,
            r: (g.r * g.r - minus_sq).max(0.0).sqrt(),
        }
    }

    /// DGB (Thm 3.5).
    pub fn dgb(m: &[f64], gap: f64, lambda: f64) -> VSphere {
        VSphere {
            q: m.to_vec(),
            r: (2.0 * gap.max(0.0) / lambda).sqrt(),
        }
    }

    /// RRPB (Thm 3.10).
    pub fn rrpb(m0: &[f64], eps: f64, lambda0: f64, lambda1: f64) -> VSphere {
        let dl = (lambda0 - lambda1).abs();
        let c = (lambda0 + lambda1) / (2.0 * lambda1);
        let r = dl / (2.0 * lambda1) * norm(m0) + (dl + lambda0 + lambda1) / (2.0 * lambda1) * eps;
        VSphere {
            q: m0.iter().map(|&v| c * v).collect(),
            r,
        }
    }
}

/// Diagonal-mode RTLM solver state (status bookkeeping mirrors `Problem`).
pub struct DiagProblem<'a> {
    pub store: &'a DiagStore,
    pub loss: Loss,
    pub lambda: f64,
    status: crate::triplet::StatusVec,
    active: Vec<usize>,
    /// Σ_{L̂} z_t
    z_l: Vec<f64>,
    n_l: usize,
}

/// Outcome of a diagonal solve.
#[derive(Clone, Debug, Default)]
pub struct DiagStats {
    pub iters: usize,
    pub p: f64,
    pub gap: f64,
    pub converged: bool,
}

impl<'a> DiagProblem<'a> {
    pub fn new(store: &'a DiagStore, loss: Loss, lambda: f64) -> DiagProblem<'a> {
        DiagProblem {
            store,
            loss,
            lambda,
            status: crate::triplet::StatusVec::new(store.len()),
            active: (0..store.len()).collect(),
            z_l: vec![0.0; store.d],
            n_l: 0,
        }
    }

    pub fn status(&self) -> &crate::triplet::StatusVec {
        &self.status
    }

    pub fn active_idx(&self) -> &[usize] {
        &self.active
    }

    pub fn apply_screening(&mut self, new_l: &[usize], new_r: &[usize]) {
        for &t in new_l {
            if self.status.get(t) == crate::triplet::TripletStatus::Active {
                self.status.screen_l(t);
                let row = self.store.z.row(t);
                for j in 0..self.store.d {
                    self.z_l[j] += row[j];
                }
                self.n_l += 1;
            }
        }
        for &t in new_r {
            self.status.screen_r(t);
        }
        self.active = self.status.active_indices();
    }

    /// Evaluate `(P̃, K = Σ α z, margins)` at `m ≥ 0`.
    pub fn eval(&self, m: &[f64]) -> (f64, Vec<f64>, Vec<f64>) {
        let mut margins = vec![0.0; self.active.len()];
        self.store.margins(&self.active, m, &mut margins);
        let mut loss_sum = 0.0;
        let alpha: Vec<f64> = margins
            .iter()
            .map(|&mg| {
                loss_sum += self.loss.value(mg);
                self.loss.alpha(mg)
            })
            .collect();
        let mut k = self.store.weighted_sum(&self.active, &alpha);
        for j in 0..self.store.d {
            k[j] += self.z_l[j];
        }
        let p = loss_sum + (1.0 - self.loss.gamma / 2.0) * self.n_l as f64 - dot(m, &self.z_l)
            + 0.5 * self.lambda * dot(m, m);
        (p, k, margins)
    }

    /// Dual value at the induced α (orthant projection instead of eig).
    pub fn dual(&self, margins: &[f64], k: &[f64]) -> f64 {
        let gamma = self.loss.gamma;
        let mut asq = self.n_l as f64;
        let mut asum = self.n_l as f64;
        for &mg in margins {
            let a = self.loss.alpha(mg);
            asq += a * a;
            asum += a;
        }
        let kp = clamp_nonneg(k);
        -0.5 * gamma * asq + asum - dot(&kp, &kp) / (2.0 * self.lambda)
    }

    /// Projected-gradient solve with BB steps; optional RRPB screening
    /// with the given rule (`analytic_rule = true` uses the Appendix-B
    /// nonneg-constrained minimum, else the plain sphere rule).
    pub fn solve(
        &mut self,
        m0: Vec<f64>,
        tol: f64,
        max_iters: usize,
        screening: Option<(&[f64], f64, f64, bool)>, // (m_ref, λ0, ε, analytic)
    ) -> (Vec<f64>, DiagStats) {
        let d = self.store.d;
        let lambda = self.lambda;
        let mut m = clamp_nonneg(&m0);
        let (mut p, mut k, mut margins) = self.eval(&m);
        let mut grad: Vec<f64> = (0..d).map(|j| lambda * m[j] - k[j]).collect();
        let mut prev: Option<(Vec<f64>, Vec<f64>)> = None;
        let mut stats = DiagStats::default();
        for iter in 0..max_iters {
            let d_val = self.dual(&margins, &k);
            let gap = p - d_val;
            if gap <= tol * p.abs().max(1.0) {
                stats.converged = true;
                stats.iters = iter;
                stats.p = p;
                stats.gap = gap;
                let _ = &stats;
                return (m, stats);
            }
            // dynamic screening every 10 iterations
            if iter % 10 == 0 {
                if let Some((m_ref, l0, eps, analytic)) = screening {
                    let sphere = vbounds::rrpb(m_ref, eps, l0, lambda);
                    let mut hq = vec![0.0; self.active.len()];
                    self.store.margins(&self.active, &sphere.q, &mut hq);
                    let thr_l = self.loss.l_threshold();
                    let thr_r = self.loss.r_threshold();
                    let mut new_l = vec![];
                    let mut new_r = vec![];
                    for (i, &t) in self.active.iter().enumerate() {
                        let zn = self.store.z_norm[t];
                        if analytic {
                            let h: &[f64] = self.store.z.row(t);
                            let mn = nonneg_min(h, &sphere.q, sphere.r);
                            if mn > thr_r {
                                new_r.push(t);
                                continue;
                            }
                            let neg: Vec<f64> = h.iter().map(|&v| -v).collect();
                            let mx = -nonneg_min(&neg, &sphere.q, sphere.r);
                            if mx < thr_l {
                                new_l.push(t);
                            }
                        } else if hq[i] - sphere.r * zn > thr_r {
                            new_r.push(t);
                        } else if hq[i] + sphere.r * zn < thr_l {
                            new_l.push(t);
                        }
                    }
                    if !new_l.is_empty() || !new_r.is_empty() {
                        self.apply_screening(&new_l, &new_r);
                        let out = self.eval(&m);
                        p = out.0;
                        k = out.1;
                        margins = out.2;
                        grad = (0..d).map(|j| lambda * m[j] - k[j]).collect();
                        prev = None;
                        continue;
                    }
                }
            }
            // BB step
            let eta = match &prev {
                Some((pm, pg)) => {
                    let dm: Vec<f64> = m.iter().zip(pm).map(|(a, b)| a - b).collect();
                    let dg: Vec<f64> = grad.iter().zip(pg).map(|(a, b)| a - b).collect();
                    let dmdg = dot(&dm, &dg);
                    let dgdg = dot(&dg, &dg);
                    if dmdg > 1e-300 && dgdg > 1e-300 {
                        0.5 * (dmdg / dgdg + dot(&dm, &dm) / dmdg).abs()
                    } else {
                        1.0 / lambda
                    }
                }
                None => 1.0 / lambda,
            };
            let m_next: Vec<f64> = (0..d).map(|j| (m[j] - eta * grad[j]).max(0.0)).collect();
            let (p_n, k_n, margins_n) = self.eval(&m_next);
            let grad_n: Vec<f64> = (0..d).map(|j| lambda * m_next[j] - k_n[j]).collect();
            prev = Some((std::mem::replace(&mut m, m_next), std::mem::replace(&mut grad, grad_n)));
            p = p_n;
            k = k_n;
            margins = margins_n;
            stats.iters = iter + 1;
        }
        stats.p = p;
        stats.gap = f64::INFINITY;
        (m, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::util::rng::Pcg64;

    fn fixture(seed: u64, n: usize, d: usize) -> DiagStore {
        let mut rng = Pcg64::seed(seed);
        let ds = synthetic::gaussian_mixture("g", n, d, 2, 2.6, &mut rng);
        DiagStore::from_dataset(&ds, 3, &mut rng)
    }

    #[test]
    fn z_matches_h_diagonal() {
        let mut rng = Pcg64::seed(1);
        let ds = synthetic::gaussian_mixture("g", 30, 4, 2, 2.5, &mut rng);
        let store = TripletStore::from_dataset(&ds, 2, &mut rng);
        let dstore = DiagStore::from_store(&store);
        for t in (0..store.len()).step_by(7) {
            let h = store.h_mat(t);
            for j in 0..4 {
                assert!((dstore.z[(t, j)] - h[(j, j)]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn diag_margins_match_full_engine_on_diagonal_m() {
        let mut rng = Pcg64::seed(2);
        let ds = synthetic::gaussian_mixture("g", 30, 5, 2, 2.5, &mut rng);
        let store = TripletStore::from_dataset(&ds, 2, &mut rng);
        let dstore = DiagStore::from_store(&store);
        let mvec: Vec<f64> = (0..5).map(|_| rng.uniform()).collect();
        let mmat = Mat::from_fn(5, 5, |i, j| if i == j { mvec[i] } else { 0.0 });
        use crate::runtime::Engine;
        let engine = crate::runtime::NativeEngine::new(1);
        let mut full = vec![0.0; store.len()];
        engine.margins(&mmat, &store.a, &store.b, &mut full);
        let all: Vec<usize> = (0..store.len()).collect();
        let mut diag = vec![0.0; store.len()];
        dstore.margins(&all, &mvec, &mut diag);
        for t in 0..store.len() {
            assert!((full[t] - diag[t]).abs() < 1e-10);
        }
    }

    #[test]
    fn solver_converges_and_is_nonneg() {
        let store = fixture(3, 40, 6);
        let loss = Loss::smoothed_hinge(0.05);
        let lmax = lambda_max(&store, &loss);
        let mut prob = DiagProblem::new(&store, loss, lmax * 0.05);
        let (m, stats) = prob.solve(vec![0.0; 6], 1e-8, 20_000, None);
        assert!(stats.converged, "{stats:?}");
        assert!(m.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn screening_preserves_solution() {
        let store = fixture(4, 40, 6);
        let loss = Loss::smoothed_hinge(0.05);
        let lmax = lambda_max(&store, &loss);
        let l0 = lmax * 0.1;
        let l1 = l0 * 0.8;
        // reference at l0
        let mut p0 = DiagProblem::new(&store, loss, l0);
        let (m0, s0) = p0.solve(vec![0.0; 6], 1e-9, 20_000, None);
        assert!(s0.converged);
        let eps = (2.0 * s0.gap.max(0.0) / l0).sqrt();

        let mut plain = DiagProblem::new(&store, loss, l1);
        let (m_plain, sp) = plain.solve(m0.clone(), 1e-9, 20_000, None);
        assert!(sp.converged);

        for analytic in [false, true] {
            let mut scr = DiagProblem::new(&store, loss, l1);
            let (m_scr, ss) = scr.solve(
                m0.clone(),
                1e-9,
                20_000,
                Some((&m0, l0, eps, analytic)),
            );
            assert!(ss.converged);
            let diff: f64 = m_plain
                .iter()
                .zip(&m_scr)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f64::max);
            assert!(diff < 1e-4, "analytic={analytic}: diff {diff}");
            if analytic {
                // the analytic rule should screen at least as much as sphere
                assert!(scr.status().screening_rate() >= 0.0);
            }
        }
    }

    #[test]
    fn nonneg_min_against_bruteforce() {
        use crate::util::quickcheck::forall;
        forall("nonneg-min", 64, |rng| {
            let d = 2 + rng.below(5);
            let h: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
            let q: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
            let r = rng.uniform() * 2.0 + 0.05;
            let got = nonneg_min(&h, &q, r);
            // projected-gradient reference (exact projection on the box
            // intersection is easy here: clamp then renorm onto sphere is
            // NOT exact, so use many random feasible points + local search)
            let mut best = f64::INFINITY;
            for _ in 0..400 {
                // sample inside sphere, clamp to orthant — feasible iff
                // still within the sphere; reject otherwise
                let mut x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
                let n = norm(&x);
                let scale = r * rng.uniform().powf(1.0 / d as f64) / n.max(1e-12);
                for (k, xv) in x.iter_mut().enumerate() {
                    *xv = (q[k] + *xv * scale).max(0.0);
                }
                let dist: f64 = x
                    .iter()
                    .zip(&q)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum::<f64>()
                    .sqrt();
                if dist <= r {
                    best = best.min(dot(&x, &h));
                }
            }
            if !best.is_finite() {
                return Ok(()); // no feasible sample found (tiny sphere off-orthant)
            }
            // analytic min must lower-bound every feasible sample
            if got <= best + 1e-7 * (1.0 + best.abs()) {
                Ok(())
            } else {
                Err(format!("analytic {got} > sampled {best}"))
            }
        });
    }

    #[test]
    fn nonneg_min_stronger_than_sphere() {
        use crate::util::quickcheck::forall;
        forall("nonneg-vs-sphere", 64, |rng| {
            let d = 2 + rng.below(5);
            let h: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
            let q: Vec<f64> = (0..d).map(|_| rng.uniform()).collect(); // PSD center
            let r = rng.uniform() + 0.05;
            let got = nonneg_min(&h, &q, r);
            let sphere = dot(&q, &h) - r * norm(&h);
            if got >= sphere - 1e-9 * (1.0 + sphere.abs()) {
                Ok(())
            } else {
                Err(format!("nonneg_min {got} < sphere {sphere}"))
            }
        });
    }

    #[test]
    fn lambda_max_boundary() {
        let store = fixture(5, 36, 5);
        let loss = Loss::smoothed_hinge(0.05);
        let lmax = lambda_max(&store, &loss);
        let all: Vec<usize> = (0..store.len()).collect();
        let sum_z = store.weighted_sum(&all, &vec![1.0; store.len()]);
        let m: Vec<f64> = sum_z.iter().map(|&v| v.max(0.0) / (lmax * 1.01)).collect();
        let mut margins = vec![0.0; store.len()];
        store.margins(&all, &m, &mut margins);
        assert!(margins.iter().all(|&mg| mg <= loss.l_threshold() + 1e-9));
    }
}
