//! Safe triplet screening (paper §3–§4).
//!
//! Two-step structure exactly as in the paper:
//!
//! 1. **Sphere bound** (§3.2) — a hypersphere `B(Q, r)` guaranteed to
//!    contain the optimal `M*`, built from the current solver state:
//!    GB / PGB (gradient-based, Thm 3.2/3.3), DGB / CDGB (duality-gap,
//!    Thm 3.5/3.6), RPB / RRPB (regularization path, Thm 3.7/3.10).
//! 2. **Screening rule** (§3.1) — per triplet, bound `⟨X, H_t⟩` over `B`
//!    (optionally intersected with the PSD cone or its linear relaxation)
//!    and compare against the loss thresholds:
//!       max < 1−γ ⟹ t ∈ L*  (α* = 1)      min > 1 ⟹ t ∈ R*  (α* = 0).
//!
//! Plus the range-based extension (§4): intervals of λ on which a rule is
//! guaranteed to keep firing, so the path driver can skip rule evaluation
//! altogether.
//!
//! ## Workset pipeline (architecture)
//!
//! Screening only pays for itself if the rules cost less than the solver
//! passes they save (§3.3), so the hot path is organized as a **blocked,
//! parallel, incremental pipeline** over a compacted active workset
//! ([`crate::triplet::ActiveWorkset`]):
//!
//! - the [`crate::solver::Problem`] owns a swap-remove arena that
//!   *permanently retires* screened ids and keeps every per-triplet lane
//!   (`a`/`b` rows, `‖H‖_F`, RPB/RRPB reference margins) contiguous;
//! - [`ScreeningManager::screen`] evaluates the configured rule in
//!   cache-sized blocks fanned out across `util::parallel` workers, with
//!   batched `Engine::margins` calls over only the active rows and
//!   reusable scratch lanes instead of per-call allocations;
//! - the λ-crossing state is a first-class [`ReferenceFrame`]: built once
//!   per reference solution, it owns the identity tag, `M₀`/`λ₀`/`ε`, the
//!   shared full-store margins lane (installed into the workset, compacts
//!   in lockstep) and per-triplet **certified λ-intervals** derived from
//!   the §4 range forms (closed-form RRPB plus, optionally, the DGB/GB
//!   general forms of Appendix K.1);
//! - the frame's **expiry schedule** (certificates sorted by interval
//!   endpoints) makes the per-λ range pass O(entering + expiring)
//!   bookkeeping (plus emission of the live ids) instead of
//!   a full-store interval scan, and its exact RRPB intervals pre-seed
//!   the managers' `no_fire` memo: under RRPB + sphere rule a λ step
//!   performs **zero** rule evaluations — the certificates already decide
//!   every triplet.
//!
//! ### Per-call cost, before → after
//!
//! | phase                   | before (full-store scan)   | after (workset pipeline + frame)              |
//! |-------------------------|----------------------------|-----------------------------------------------|
//! | margins pass with `Q`   | O(T·d²)                    | O(active·d²), batched                         |
//! | RPB/RRPB center margins | O(T·d²) per manager per λ  | one shared pass per reference + O(active)     |
//! | range pass per λ        | O(T) interval scan         | O(entering + expiring) sweep + live emission  |
//! | rule evaluation         | O(T) every call            | 0 for RRPB+sphere (certs); O(active) else     |
//! | applying a decision     | O(T·d) full recompaction   | O(d) swap-remove (+O(d²) `H_L` update for L)  |
//! | buffers                 | fresh `Vec`s per call      | reusable scratch lanes                        |
//!
//! (T = total triplets, active = currently unscreened.)
//! `ScreeningStats::rule_evals` counts evaluations actually performed and
//! `skipped` the memo hits; over a screened path `rule_evals` stays
//! strictly below `T × path_steps` (asserted by `benches/screening.rs`
//! and `rust/tests/workset_safety.rs`, which also oracle-verifies the
//! certificate-carrying path).

pub mod bounds;
mod frame;
pub mod general_range;
mod manager;
pub mod range;
pub mod rules;
pub mod sdls;

pub use bounds::Sphere;
pub use frame::{Admission, CertFamilies, CertSide, Certificate, ReferenceFrame};
pub use manager::{ScreeningManager, ScreeningStats};
pub use range::{l_range, r_range, LambdaRange};

/// Which sphere bound to construct (paper §3.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BoundKind {
    /// Gradient Bound (Thm 3.2)
    Gb,
    /// Projected Gradient Bound (Thm 3.3)
    Pgb,
    /// Duality Gap Bound (Thm 3.5)
    Dgb,
    /// Constrained Duality Gap Bound (Thm 3.6)
    Cdgb,
    /// Regularization Path Bound (Thm 3.7; requires the previous-λ optimum)
    Rpb,
    /// Relaxed Regularization Path Bound (Thm 3.10)
    Rrpb,
}

impl BoundKind {
    /// The paper's name for the bound (table/label rendering).
    pub fn name(&self) -> &'static str {
        match self {
            BoundKind::Gb => "GB",
            BoundKind::Pgb => "PGB",
            BoundKind::Dgb => "DGB",
            BoundKind::Cdgb => "CDGB",
            BoundKind::Rpb => "RPB",
            BoundKind::Rrpb => "RRPB",
        }
    }

    /// Bounds that need a reference solution from a previous λ.
    pub fn needs_reference(&self) -> bool {
        matches!(self, BoundKind::Rpb | BoundKind::Rrpb)
    }
}

/// Which screening rule to evaluate on the sphere (paper §3.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RuleKind {
    /// plain sphere rule (§3.1.1, eq. (5))
    Sphere,
    /// sphere ∩ halfspace relaxation of the PSD cone (§3.1.3, Thm 3.1)
    Linear,
    /// sphere ∩ PSD cone via SDLS dual ascent (§3.1.2)
    SemiDefinite,
}

impl RuleKind {
    /// Lower-case rule name (CLI/label rendering).
    pub fn name(&self) -> &'static str {
        match self {
            RuleKind::Sphere => "sphere",
            RuleKind::Linear => "linear",
            RuleKind::SemiDefinite => "semidefinite",
        }
    }
}

/// Full screening configuration.
#[derive(Clone, Copy, Debug)]
pub struct ScreeningConfig {
    /// which sphere bound to construct (§3.2)
    pub bound: BoundKind,
    /// which rule to evaluate on it (§3.1)
    pub rule: RuleKind,
    /// max SDLS dual-ascent iterations per triplet
    pub sdls_max_iter: usize,
    /// pre-seed the no-fire memo from the reference frame's exact RRPB
    /// λ-intervals (RRPB bound + sphere rule only): a triplet whose
    /// certificate excludes the current λ provably cannot fire, so the
    /// rule pass skips it. Off reproduces the PR 1 pipeline (every active
    /// triplet rule-evaluated once per λ) — kept as a bench baseline.
    pub use_frame_certs: bool,
}

impl ScreeningConfig {
    /// Configuration with the default memo/SDLS knobs.
    pub fn new(bound: BoundKind, rule: RuleKind) -> ScreeningConfig {
        ScreeningConfig {
            bound,
            rule,
            sdls_max_iter: 12,
            use_frame_certs: true,
        }
    }

    /// The paper's combination label, e.g. `RRPB` or `PGB+linear`.
    pub fn label(&self) -> String {
        match self.rule {
            RuleKind::Sphere => self.bound.name().to_string(),
            _ => format!("{}+{}", self.bound.name(), self.rule.name()),
        }
    }
}
