//! Screening rules (paper §3.1): given a sphere `B(Q, r)` containing `M*`,
//! decide per triplet whether
//!
//!   min_{X ∈ B ∩ C} ⟨X, H_t⟩ > 1      ⟹ t ∈ R*   (rule R2)
//!   max_{X ∈ B ∩ C} ⟨X, H_t⟩ < 1 − γ  ⟹ t ∈ L*   (rule R1)
//!
//! where `C` is: nothing (sphere rule §3.1.1), a halfspace relaxation of
//! the PSD cone (linear rule §3.1.3 / Thm 3.1), or the PSD cone itself
//! (SDLS rule §3.1.2, in `sdls.rs`).
//!
//! All rules consume precomputed per-triplet scalars:
//! `hq = ⟨H_t, Q⟩` (one margins-kernel pass with Q), `hn = ‖H_t‖_F`
//! (cached in the store), and for the linear rule `hp = ⟨H_t, P⟩`
//! (one margins pass with P).

/// Decision for one triplet.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Decision {
    /// the rule cannot conclude: the triplet stays active
    None,
    /// proven `t ∈ L*` (α* = 1)
    ScreenL,
    /// proven `t ∈ R*` (α* = 0)
    ScreenR,
}

/// Plain sphere rule (eq. (5) + its R1 twin):
///   `hq − r·hn > thr_r` ⟹ R*,  `hq + r·hn < thr_l` ⟹ L*.
///
/// The extreme inner products over the sphere are `hq ± r·hn`
/// (Cauchy–Schwarz), so one comparison per side decides:
///
/// ```
/// use triplet_screen::screening::rules::{sphere_rule, Decision};
/// // min over the sphere = 2.0 − 0.5·1.0 = 1.5 > 1    ⟹ t ∈ R*
/// assert_eq!(sphere_rule(2.0, 1.0, 0.5, 0.95, 1.0), Decision::ScreenR);
/// // max over the sphere = 0.2 + 0.5·1.0 = 0.7 < 0.95 ⟹ t ∈ L*
/// assert_eq!(sphere_rule(0.2, 1.0, 0.5, 0.95, 1.0), Decision::ScreenL);
/// // a wide radius straddles both thresholds ⟹ undecided
/// assert_eq!(sphere_rule(1.0, 1.0, 5.0, 0.95, 1.0), Decision::None);
/// ```
#[inline]
pub fn sphere_rule(hq: f64, hn: f64, r: f64, thr_l: f64, thr_r: f64) -> Decision {
    if hq - r * hn > thr_r {
        Decision::ScreenR
    } else if hq + r * hn < thr_l {
        Decision::ScreenL
    } else {
        Decision::None
    }
}

/// Certified sphere rule over an approximate statistic `hq ± env`
/// (the mixed-precision tier: `hq` from the f32 pass, `env` its
/// [`crate::screening::bounds::eps_round`] envelope).
///
/// As a function of the true `hq`, [`sphere_rule`]'s decision regions
/// are the ordered intervals L / None / R, so evaluating the rule at
/// the interval's two endpoints certifies it on the whole interval:
/// agreement means the returned decision **is** the exact-f64 decision
/// (the true `hq` lies between the endpoints); disagreement returns
/// `None` — the statistic is within the envelope of a boundary and the
/// caller must promote the triplet to the exact f64 path.
///
/// ```
/// use triplet_screen::screening::rules::{sphere_rule_enveloped, Decision};
/// // far from every boundary: certified R even with the envelope
/// assert_eq!(
///     sphere_rule_enveloped(2.0, 1.0, 0.5, 0.95, 1.0, 1e-6),
///     Some(Decision::ScreenR)
/// );
/// // min over the sphere sits exactly on the threshold: ambiguous
/// assert_eq!(sphere_rule_enveloped(1.5, 1.0, 0.5, 0.95, 1.0, 1e-6), None);
/// // certified-undecided is also an agreement (no promotion needed)
/// assert_eq!(
///     sphere_rule_enveloped(1.0, 1.0, 5.0, 0.95, 1.0, 1e-6),
///     Some(Decision::None)
/// );
/// ```
#[inline]
pub fn sphere_rule_enveloped(
    hq: f64,
    hn: f64,
    r: f64,
    thr_l: f64,
    thr_r: f64,
    env: f64,
) -> Option<Decision> {
    debug_assert!(env >= 0.0, "envelope must be >= 0, got {env}");
    let lo = sphere_rule(hq - env, hn, r, thr_l, thr_r);
    let hi = sphere_rule(hq + env, hn, r, thr_l, thr_r);
    if lo == hi {
        Some(lo)
    } else {
        None
    }
}

/// Analytic minimum of `⟨X, H⟩` over sphere ∩ halfspace `⟨P, X⟩ ≥ 0`
/// (Thm 3.1). Inputs: `hq = ⟨H,Q⟩`, `hn = ‖H‖`, `hp = ⟨P,H⟩`,
/// `pq = ⟨P,Q⟩`, `pn_sq = ‖P‖²`, radius `r`.
pub fn linear_min(hq: f64, hn: f64, hp: f64, pq: f64, pn_sq: f64, r: f64) -> f64 {
    if hn <= 0.0 {
        return 0.0; // H = 0: inner product is identically 0
    }
    if pn_sq <= 0.0 {
        // degenerate hyperplane: fall back to the sphere minimum
        return hq - r * hn;
    }
    // case 1: H parallel to P (Thm 3.1 first branch) -> minimum 0
    let par = pn_sq * hn * hn - hp * hp;
    if par <= 1e-12 * pn_sq * hn * hn && hp > 0.0 {
        return 0.0;
    }
    // case 2: sphere minimizer X = Q − r·H/‖H‖ already feasible
    if pq - r * hp / hn >= 0.0 {
        return hq - r * hn;
    }
    // case 3: both constraints active (Thm 3.1 third branch)
    let denom = r * r * pn_sq - pq * pq;
    if denom <= 0.0 {
        // sphere does not reach the hyperplane interiorly; the sphere
        // minimum is the safe (weaker) value
        return hq - r * hn;
    }
    let alpha = (par / denom).sqrt();
    if alpha <= 0.0 {
        return hq - r * hn;
    }
    let beta = (hp - alpha * pq) / pn_sq;
    // <H, (βP − H)/α + Q> = hq + (β·hp − ‖H‖²)/α
    hq + (beta * hp - hn * hn) / alpha
}

/// Linear-constraint rule (§3.1.3): R2 via `linear_min`, R1 via the
/// mirrored problem `max⟨X,H⟩ = −min⟨X,−H⟩` (flip `hq`, `hp`).
pub fn linear_rule(
    hq: f64,
    hn: f64,
    hp: f64,
    pq: f64,
    pn_sq: f64,
    r: f64,
    thr_l: f64,
    thr_r: f64,
) -> Decision {
    let min_val = linear_min(hq, hn, hp, pq, pn_sq, r);
    if min_val > thr_r {
        return Decision::ScreenR;
    }
    let max_val = -linear_min(-hq, hn, -hp, pq, pn_sq, r);
    if max_val < thr_l {
        return Decision::ScreenL;
    }
    Decision::None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::util::quickcheck::forall;
    use crate::util::rng::Pcg64;

    #[test]
    fn sphere_rule_basic() {
        // hq=2, hn=1, r=0.5 -> min=1.5 > 1 -> R
        assert_eq!(sphere_rule(2.0, 1.0, 0.5, 0.95, 1.0), Decision::ScreenR);
        // hq=0.2, hn=1, r=0.5 -> max=0.7 < 0.95 -> L
        assert_eq!(sphere_rule(0.2, 1.0, 0.5, 0.95, 1.0), Decision::ScreenL);
        // wide radius -> none
        assert_eq!(sphere_rule(1.0, 1.0, 5.0, 0.95, 1.0), Decision::None);
    }

    #[test]
    fn sphere_rule_zero_radius_classifies_by_margin() {
        assert_eq!(sphere_rule(1.01, 3.0, 0.0, 0.95, 1.0), Decision::ScreenR);
        assert_eq!(sphere_rule(0.94, 3.0, 0.0, 0.95, 1.0), Decision::ScreenL);
        assert_eq!(sphere_rule(0.97, 3.0, 0.0, 0.95, 1.0), Decision::None);
    }

    /// The enveloped rule certifies iff the whole interval agrees — and
    /// when it certifies, the decision equals the exact rule's at every
    /// point of the interval (fuzzed against dense sampling).
    #[test]
    fn enveloped_rule_certifies_exactly_or_abstains() {
        forall("sphere-enveloped", 256, |rng| {
            let hq = rng.normal() * 2.0;
            let hn = rng.uniform() * 2.0;
            let r = rng.uniform();
            let env = rng.uniform() * 0.3;
            let (thr_l, thr_r) = (0.95, 1.0);
            let got = sphere_rule_enveloped(hq, hn, r, thr_l, thr_r, env);
            // dense sample of the interval, endpoints included
            let mut seen = Vec::new();
            for k in 0..=16 {
                // endpoints sampled at the rule's own evaluation points
                let m = match k {
                    0 => hq - env,
                    16 => hq + env,
                    _ => hq - env + 2.0 * env * (k as f64 / 16.0),
                };
                seen.push(sphere_rule(m, hn, r, thr_l, thr_r));
            }
            let uniform = seen.iter().all(|&s| s == seen[0]);
            match got {
                Some(dec) => {
                    if !uniform || dec != seen[0] {
                        return Err(format!("certified {dec:?} but interval mixes {seen:?}"));
                    }
                }
                None => {
                    // abstained: the endpoints genuinely disagree
                    if seen[0] == *seen.last().unwrap() {
                        return Err("abstained on an agreeing interval".into());
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn enveloped_rule_zero_envelope_is_exact_rule() {
        for hq in [0.5, 0.97, 1.2, 2.0] {
            assert_eq!(
                sphere_rule_enveloped(hq, 1.0, 0.1, 0.95, 1.0, 0.0),
                Some(sphere_rule(hq, 1.0, 0.1, 0.95, 1.0))
            );
        }
    }

    /// The linear rule is never weaker than the sphere rule, and its
    /// minimum is never below the sphere minimum (the feasible set is
    /// smaller).
    #[test]
    fn linear_min_dominates_sphere_min() {
        forall("linear>=sphere", 128, |rng| {
            let d = 3 + rng.below(4);
            let mk = |rng: &mut Pcg64| {
                let mut m = Mat::from_fn(d, d, |_, _| rng.normal());
                m.symmetrize();
                m
            };
            let h = mk(rng);
            let p = mk(rng);
            let q = mk(rng);
            let r = rng.uniform() * 2.0 + 0.01;
            let (hq, hn, hp, pq, pn_sq) = (q.dot(&h), h.norm(), p.dot(&h), p.dot(&q), p.norm_sq());
            let lin = linear_min(hq, hn, hp, pq, pn_sq, r);
            let sph = hq - r * hn;
            if lin >= sph - 1e-9 * (1.0 + sph.abs()) {
                Ok(())
            } else {
                Err(format!("linear_min {lin} < sphere {sph}"))
            }
        });
    }

    /// Soundness + tightness of `linear_min`:
    /// - the analytic minimum must be *achieved* by a feasible KKT witness
    ///   `X*` (so it is never an unsafe over-restriction), and
    /// - no randomly sampled feasible point may beat it (so it is a true
    ///   lower bound over the feasible set).
    #[test]
    fn linear_min_witness_and_sampling() {
        forall("linear-min-witness", 48, |rng| {
            let d = 3;
            let mk = |rng: &mut Pcg64| {
                let mut m = Mat::from_fn(d, d, |_, _| rng.normal());
                m.symmetrize();
                m
            };
            let h = mk(rng);
            let p = mk(rng);
            let q = mk(rng);
            let r = rng.uniform() * 1.5 + 0.1;
            let (hq, hn, hp, pq, pn_sq) = (q.dot(&h), h.norm(), p.dot(&h), p.dot(&q), p.norm_sq());
            let got = linear_min(hq, hn, hp, pq, pn_sq, r);

            // feasible witness achieving the value (skip the degenerate
            // H∥P branch where the theorem's value is a limit)
            let sphere_feasible = pq - r * hp / hn >= 0.0;
            let witness = if sphere_feasible {
                let mut x = q.clone();
                x.axpy(-r / hn, &h);
                Some(x)
            } else {
                let denom = r * r * pn_sq - pq * pq;
                if denom > 1e-9 {
                    let alpha = ((pn_sq * hn * hn - hp * hp) / denom).sqrt();
                    if alpha > 1e-9 {
                        let beta = (hp - alpha * pq) / pn_sq;
                        // X* = (βP − H)/α + Q
                        let mut x = p.scaled(beta);
                        x.axpy(-1.0, &h);
                        x.scale(1.0 / alpha);
                        x.axpy(1.0, &q);
                        Some(x)
                    } else {
                        None
                    }
                } else {
                    None
                }
            };
            if let Some(x) = witness {
                let feas_sphere = x.sub(&q).norm() <= r * (1.0 + 1e-8) + 1e-10;
                let feas_half = p.dot(&x) >= -1e-8 * (1.0 + pn_sq.sqrt());
                if feas_sphere && feas_half {
                    crate::util::quickcheck::close(x.dot(&h), got, 1e-7, 1e-7, "witness value")?;
                }
            }

            // sampled feasible points never beat the analytic minimum
            for _ in 0..60 {
                let mut w = mk(rng);
                let nw = w.norm();
                if nw > 0.0 {
                    w.scale(r * rng.uniform() / nw);
                }
                let x = q.add(&w);
                if p.dot(&x) >= 0.0 {
                    let v = x.dot(&h);
                    if v < got - 1e-8 * (1.0 + v.abs()) {
                        return Err(format!("sampled feasible {v} < analytic min {got}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn linear_rule_screens_with_halfspace_but_not_sphere() {
        // construct a case where the sphere dips below the threshold only
        // in the infeasible halfspace: Q far along H, P = H direction.
        // Sphere min = hq − r·hn crosses below thr_r but the halfspace
        // <P,X> >= 0 cuts that cap off.
        let d = 2;
        let h = Mat::from_rows(d, d, vec![1.0, 0.0, 0.0, 0.0]); // H = e1 e1^T
        let p = h.clone(); // halfspace <H, X> >= 0
        let q = h.scaled(1.2); // hq = 1.2
        let r = 1.4; // sphere min = 1.2 - 1.4 = -0.2 (not > 1)
        let (hq, hn, hp, pq, pn) = (q.dot(&h), h.norm(), p.dot(&h), p.dot(&q), p.norm_sq());
        assert_eq!(sphere_rule(hq, hn, r, 0.95, 1.0), Decision::None);
        // with the halfspace, min over {<H,X> >= 0} is >= 0 — still not R;
        // but the max side: max = hq + r = 2.6, no L either. Verify the
        // minimum is clamped up by the constraint:
        let lin = linear_min(hq, hn, hp, pq, pn, r);
        assert!(lin >= -1e-9, "constrained min should be >= 0, got {lin}");
    }

    #[test]
    fn degenerate_inputs_safe() {
        // H = 0
        assert_eq!(linear_min(0.0, 0.0, 0.0, 1.0, 1.0, 1.0), 0.0);
        // P = 0 -> sphere fallback
        let v = linear_min(2.0, 1.0, 0.0, 0.0, 0.0, 0.5);
        assert!((v - 1.5).abs() < 1e-12);
    }
}
