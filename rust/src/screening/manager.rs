//! Screening orchestration: build the configured sphere from solver state,
//! evaluate the configured rule over all active triplets, return the
//! screened id lists.
//!
//! Cost structure follows the paper's §3.3 analysis:
//! - DGB's center is the iterate itself ⇒ `⟨H_t,Q⟩` *reuses* the margins
//!   already computed for the objective (no extra kernel pass);
//! - RPB/RRPB centers are scalar multiples of the fixed reference `M₀` ⇒
//!   one margins pass per λ, cached and reused across dynamic screenings;
//! - GB/PGB/CDGB centers move with the iterate ⇒ one fresh margins pass
//!   per screening invocation (the extra inner-product cost the paper
//!   attributes to PGB);
//! - the SDLS rule additionally pays per-triplet eigen work.

use super::bounds::{self, Sphere};
use super::rules::{self, Decision};
use super::sdls::{self, SdlsQuery};
use super::{BoundKind, RuleKind, ScreeningConfig};
use crate::linalg::psd_split;
use crate::runtime::Engine;
use crate::solver::{Problem, ScreenCtx};
use crate::util::timer::PhaseTimers;

/// Reference solution for the regularization-path bounds.
#[derive(Clone, Debug)]
pub struct RefSolution {
    pub m0: crate::linalg::Mat,
    pub lambda0: f64,
    /// `‖M₀* − M₀‖ ≤ ε` certificate (from the λ₀ duality gap, Thm 3.5)
    pub eps: f64,
}

/// Cumulative screening statistics.
#[derive(Clone, Debug, Default)]
pub struct ScreeningStats {
    pub calls: usize,
    pub screened_l: usize,
    pub screened_r: usize,
    /// total triplet-rule evaluations
    pub rule_evals: usize,
}

/// Stateful screening engine for one regularization-path run.
pub struct ScreeningManager {
    pub cfg: ScreeningConfig,
    reference: Option<RefSolution>,
    /// `⟨H_t, M₀⟩` for every triplet id (cached at `set_reference`)
    ref_margins: Vec<f64>,
    pub stats: ScreeningStats,
}

impl ScreeningManager {
    pub fn new(cfg: ScreeningConfig) -> ScreeningManager {
        ScreeningManager {
            cfg,
            reference: None,
            ref_margins: Vec::new(),
            stats: ScreeningStats::default(),
        }
    }

    /// Install the reference solution (previous λ on the path). Computes
    /// and caches `⟨H_t, M₀⟩` for all triplets — one margins pass.
    pub fn set_reference(
        &mut self,
        m0: crate::linalg::Mat,
        lambda0: f64,
        eps: f64,
        store: &crate::triplet::TripletStore,
        engine: &dyn Engine,
    ) {
        let mut margins = vec![0.0; store.len()];
        engine.margins(&m0, &store.a, &store.b, &mut margins);
        self.reference = Some(RefSolution { m0, lambda0, eps });
        self.ref_margins = margins;
    }

    pub fn reference(&self) -> Option<&RefSolution> {
        self.reference.as_ref()
    }

    /// Build the configured sphere from the current solver state.
    /// Returns None when prerequisites are missing (e.g. RPB without a
    /// reference) — the caller then skips screening.
    pub fn build_sphere(
        &self,
        problem: &Problem,
        ctx: &ScreenCtx,
        engine: &dyn Engine,
    ) -> Option<Sphere> {
        let lambda = problem.lambda;
        Some(match self.cfg.bound {
            BoundKind::Gb => bounds::gb(ctx.m, ctx.grad, lambda),
            BoundKind::Pgb => bounds::pgb(ctx.m, ctx.grad, lambda).0,
            BoundKind::Dgb => bounds::dgb(ctx.m, ctx.gap, lambda),
            BoundKind::Cdgb => {
                // gap at the dual iterate M_λ(α) = [K]_+/λ: one extra
                // primal evaluation (Thm 3.6 discussion)
                let center = ctx.k_plus.scaled(1.0 / lambda);
                let mut scratch = PhaseTimers::default();
                let ev = problem.eval(&center, engine, &mut scratch);
                bounds::cdgb(ctx.k_plus, ev.p - ctx.d, lambda)
            }
            BoundKind::Rpb => {
                let r = self.reference.as_ref()?;
                bounds::rpb(&r.m0, r.lambda0, lambda)
            }
            BoundKind::Rrpb => {
                let r = self.reference.as_ref()?;
                bounds::rrpb(&r.m0, r.eps, r.lambda0, lambda)
            }
        })
    }

    /// `⟨H_t, Q⟩` for all active triplets, exploiting center structure.
    fn center_margins(
        &self,
        sphere: &Sphere,
        problem: &Problem,
        ctx: &ScreenCtx,
        engine: &dyn Engine,
    ) -> Vec<f64> {
        match self.cfg.bound {
            BoundKind::Dgb => ctx.margins.to_vec(),
            BoundKind::Rpb | BoundKind::Rrpb => {
                let r = self.reference.as_ref().expect("checked in build_sphere");
                let scale = (r.lambda0 + problem.lambda) / (2.0 * problem.lambda);
                problem
                    .active_idx()
                    .iter()
                    .map(|&t| scale * self.ref_margins[t])
                    .collect()
            }
            _ => {
                let mut hq = vec![0.0; problem.active_idx().len()];
                engine.margins(&sphere.q, problem.active_a(), problem.active_b(), &mut hq);
                hq
            }
        }
    }

    /// Run one screening pass; returns `(new_l, new_r)` triplet ids.
    pub fn screen(
        &mut self,
        problem: &Problem,
        ctx: &ScreenCtx,
        engine: &dyn Engine,
    ) -> (Vec<usize>, Vec<usize>) {
        let Some(sphere) = self.build_sphere(problem, ctx, engine) else {
            return (vec![], vec![]);
        };
        self.stats.calls += 1;
        let hq = self.center_margins(&sphere, problem, ctx, engine);
        let thr_l = problem.loss.l_threshold();
        let thr_r = problem.loss.r_threshold();
        let hn = problem.active_h_norm();
        let ids = problem.active_idx();
        self.stats.rule_evals += ids.len();

        let mut new_l = Vec::new();
        let mut new_r = Vec::new();
        match self.cfg.rule {
            RuleKind::Sphere => {
                for (k, &t) in ids.iter().enumerate() {
                    match rules::sphere_rule(hq[k], hn[k], sphere.r, thr_l, thr_r) {
                        Decision::ScreenL => new_l.push(t),
                        Decision::ScreenR => new_r.push(t),
                        Decision::None => {}
                    }
                }
            }
            RuleKind::Linear => {
                // supporting hyperplane of the PSD cone (§3.1.3): prefer
                // P = −[Q^GB]_− from the projection of the gradient-step
                // point M − ∇P̃/(2λ) — the halfspace Fig 3(a) shows is
                // tighter than PGB; fall back to the optimizer's own
                // pre-projection split, then to the plain sphere rule.
                let mut gb_center = ctx.m.clone();
                gb_center.axpy(-0.5 / problem.lambda, ctx.grad);
                let gb_split = psd_split(&gb_center);
                let p = if gb_split.minus_norm_sq > 1e-24 {
                    Some(gb_split.minus.scaled(-1.0))
                } else {
                    ctx.pre_split.map(|s| s.minus.scaled(-1.0))
                };
                match p {
                    Some(p) if p.norm_sq() > 0.0 => {
                        let mut hp = vec![0.0; ids.len()];
                        engine.margins(&p, problem.active_a(), problem.active_b(), &mut hp);
                        let pq = p.dot(&sphere.q);
                        let pn_sq = p.norm_sq();
                        for (k, &t) in ids.iter().enumerate() {
                            match rules::linear_rule(
                                hq[k], hn[k], hp[k], pq, pn_sq, sphere.r, thr_l, thr_r,
                            ) {
                                Decision::ScreenL => new_l.push(t),
                                Decision::ScreenR => new_r.push(t),
                                Decision::None => {}
                            }
                        }
                    }
                    _ => {
                        for (k, &t) in ids.iter().enumerate() {
                            match rules::sphere_rule(hq[k], hn[k], sphere.r, thr_l, thr_r) {
                                Decision::ScreenL => new_l.push(t),
                                Decision::ScreenR => new_r.push(t),
                                Decision::None => {}
                            }
                        }
                    }
                }
            }
            RuleKind::SemiDefinite => {
                // sphere decision is implied by the SDLS decision (smaller
                // feasible set) — run it first, SDLS only on the undecided;
                // per-triplet dual ascents are independent → parallel
                let r_sq = sphere.r * sphere.r;
                let q_norm_sq = sphere.q.norm_sq();
                // anchor margins for non-PSD centers: X0 = [Q]_+ must be
                // inside the sphere for the anchor argument to hold
                let anchor = if sphere.psd_center {
                    None
                } else {
                    let split = psd_split(&sphere.q);
                    if split.minus_norm_sq.sqrt() <= sphere.r {
                        let mut hx0 = vec![0.0; ids.len()];
                        engine.margins(&split.plus, problem.active_a(), problem.active_b(), &mut hx0);
                        Some(hx0)
                    } else {
                        None // no certified anchor: SDLS cannot conclude
                    }
                };
                let sphere_ref = &sphere;
                let anchor_ref = &anchor;
                let hq_ref = &hq;
                let max_iter = self.cfg.sdls_max_iter;
                let workers = crate::util::parallel::default_threads();
                let chunks = crate::util::parallel::par_ranges(ids.len(), workers, |range| {
                    let mut l = Vec::new();
                    let mut r = Vec::new();
                    for k in range {
                        let t = ids[k];
                        match rules::sphere_rule(hq_ref[k], hn[k], sphere_ref.r, thr_l, thr_r) {
                            Decision::ScreenL => {
                                l.push(t);
                                continue;
                            }
                            Decision::ScreenR => {
                                r.push(t);
                                continue;
                            }
                            Decision::None => {}
                        }
                        let hx0 = if sphere_ref.psd_center {
                            hq_ref[k]
                        } else {
                            match anchor_ref {
                                Some(v) => v[k],
                                None => continue,
                            }
                        };
                        let query = SdlsQuery {
                            q: &sphere_ref.q,
                            q_norm_sq,
                            psd_center: sphere_ref.psd_center,
                            r_sq,
                            a: problem.active_a().row(k),
                            b: problem.active_b().row(k),
                            hq: hq_ref[k],
                            hn: hn[k],
                            hx0,
                        };
                        if sdls::sdls_screens_r(&query, thr_r, max_iter) {
                            r.push(t);
                        } else if sdls::sdls_screens_l(&query, thr_l, max_iter) {
                            l.push(t);
                        }
                    }
                    (l, r)
                });
                for (l, r) in chunks {
                    new_l.extend(l);
                    new_r.extend(r);
                }
            }
        }
        self.stats.screened_l += new_l.len();
        self.stats.screened_r += new_r.len();
        (new_l, new_r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::linalg::Mat;
    use crate::loss::Loss;
    use crate::runtime::NativeEngine;
    use crate::solver::{Solver, SolverConfig};
    use crate::triplet::TripletStore;
    use crate::util::rng::Pcg64;

    struct Fix {
        store: TripletStore,
        loss: Loss,
        lmax: f64,
        engine: NativeEngine,
    }

    fn fix(seed: u64) -> Fix {
        let mut rng = Pcg64::seed(seed);
        let ds = synthetic::gaussian_mixture("g", 45, 4, 3, 2.6, &mut rng);
        let store = TripletStore::from_dataset(&ds, 3, &mut rng);
        let loss = Loss::smoothed_hinge(0.05);
        let engine = NativeEngine::new(2);
        let lmax = Problem::lambda_max(&store, &loss, &engine);
        Fix {
            store,
            loss,
            lmax,
            engine,
        }
    }

    fn exact_solution(f: &Fix, lambda: f64) -> Mat {
        let mut prob = Problem::new(&f.store, f.loss, lambda);
        let (m, st) = Solver::new(SolverConfig {
            tol: 1e-12,
            tol_relative: false,
            max_iters: 50_000,
            ..Default::default()
        })
        .solve(&mut prob, &f.engine, Mat::zeros(4, 4), None);
        assert!(st.converged);
        m
    }

    /// The master safety test: for every bound × rule, run the solver with
    /// screening and verify each screened triplet against the true optimum
    /// membership (margins at a 1e-12-gap solution).
    #[test]
    fn all_bound_rule_combinations_are_safe() {
        let f = fix(1);
        let lambda = f.lmax * 0.15;
        let m_star = exact_solution(&f, lambda);
        let mut true_margins = vec![0.0; f.store.len()];
        f.engine
            .margins(&m_star, &f.store.a, &f.store.b, &mut true_margins);

        for bound in [
            BoundKind::Gb,
            BoundKind::Pgb,
            BoundKind::Dgb,
            BoundKind::Cdgb,
            BoundKind::Rrpb,
            BoundKind::Rpb,
        ] {
            for rule in [RuleKind::Sphere, RuleKind::Linear, RuleKind::SemiDefinite] {
                let mut mgr = ScreeningManager::new(ScreeningConfig::new(bound, rule));
                if bound.needs_reference() {
                    // reference: solve at a larger λ0 accurately
                    let l0 = lambda / 0.8;
                    let m0 = exact_solution(&f, l0);
                    mgr.set_reference(m0, l0, 1e-9, &f.store, &f.engine);
                }
                let mut prob = Problem::new(&f.store, f.loss, lambda);
                let engine = &f.engine;
                let mut cb = |p: &Problem, ctx: &ScreenCtx| mgr.screen(p, ctx, engine);
                let solver = Solver::new(SolverConfig {
                    tol: 1e-10,
                    tol_relative: false,
                    ..Default::default()
                });
                let (m, stats) = solver.solve(&mut prob, &f.engine, Mat::zeros(4, 4), Some(&mut cb));
                assert!(stats.converged, "{bound:?}/{rule:?} did not converge");
                // solution must match unscreened optimum
                let diff = m.sub(&m_star).max_abs();
                assert!(
                    diff < 1e-4 * (1.0 + m_star.max_abs()),
                    "{bound:?}/{rule:?}: solution drifted by {diff}"
                );
                // every screened triplet is truly in L*/R*
                for t in 0..f.store.len() {
                    match prob.status().get(t) {
                        crate::triplet::TripletStatus::ScreenedL => assert!(
                            true_margins[t] < f.loss.l_threshold() + 1e-6,
                            "{bound:?}/{rule:?}: t={t} screened L but margin {}",
                            true_margins[t]
                        ),
                        crate::triplet::TripletStatus::ScreenedR => assert!(
                            true_margins[t] > f.loss.r_threshold() - 1e-6,
                            "{bound:?}/{rule:?}: t={t} screened R but margin {}",
                            true_margins[t]
                        ),
                        crate::triplet::TripletStatus::Active => {}
                    }
                }
            }
        }
    }

    #[test]
    fn dgb_reuses_objective_margins() {
        // center_margins for DGB must be exactly ctx.margins
        let f = fix(2);
        let lambda = f.lmax * 0.3;
        let mut prob = Problem::new(&f.store, f.loss, lambda);
        let mut timers = PhaseTimers::default();
        let m = Mat::identity(4).scaled(0.01);
        let ev = prob.eval(&m, &f.engine, &mut timers);
        let grad = prob.grad(&m, &ev.k);
        let (d_val, split) = prob.dual(&ev.margins, &ev.k, &mut timers);
        let ctx = ScreenCtx {
            m: &m,
            grad: &grad,
            p: ev.p,
            d: d_val,
            gap: ev.p - d_val,
            k_plus: &split.plus,
            pre_split: None,
            margins: &ev.margins,
            iter: 0,
        };
        let mgr = ScreeningManager::new(ScreeningConfig::new(BoundKind::Dgb, RuleKind::Sphere));
        let sphere = mgr.build_sphere(&prob, &ctx, &f.engine).unwrap();
        let hq = mgr.center_margins(&sphere, &prob, &ctx, &f.engine);
        assert_eq!(hq, ev.margins);
        let _ = &mut prob;
    }

    #[test]
    fn rpb_without_reference_skips() {
        let f = fix(3);
        let mut mgr = ScreeningManager::new(ScreeningConfig::new(BoundKind::Rpb, RuleKind::Sphere));
        let prob = Problem::new(&f.store, f.loss, f.lmax * 0.5);
        let m = Mat::zeros(4, 4);
        let grad = Mat::zeros(4, 4);
        let kp = Mat::zeros(4, 4);
        let margins = vec![0.0; prob.active_idx().len()];
        let ctx = ScreenCtx {
            m: &m,
            grad: &grad,
            p: 0.0,
            d: 0.0,
            gap: 0.0,
            k_plus: &kp,
            pre_split: None,
            margins: &margins,
            iter: 0,
        };
        let (l, r) = mgr.screen(&prob, &ctx, &f.engine);
        assert!(l.is_empty() && r.is_empty());
        assert_eq!(mgr.stats.calls, 0);
    }

    #[test]
    fn tighter_bounds_screen_no_less() {
        // With identical reference state, PGB (⊆ GB) must screen at least
        // as many triplets as GB under the sphere rule.
        let f = fix(4);
        let lambda = f.lmax * 0.2;
        // moderately accurate iterate
        let mut prob = Problem::new(&f.store, f.loss, lambda);
        let (m, _) = Solver::new(SolverConfig {
            tol: 1e-4,
            tol_relative: false,
            ..Default::default()
        })
        .solve(&mut prob, &f.engine, Mat::zeros(4, 4), None);
        let mut timers = PhaseTimers::default();
        let ev = prob.eval(&m, &f.engine, &mut timers);
        let grad = prob.grad(&m, &ev.k);
        let (d_val, split) = prob.dual(&ev.margins, &ev.k, &mut timers);
        let ctx = ScreenCtx {
            m: &m,
            grad: &grad,
            p: ev.p,
            d: d_val,
            gap: ev.p - d_val,
            k_plus: &split.plus,
            pre_split: None,
            margins: &ev.margins,
            iter: 0,
        };
        let count = |bound: BoundKind| {
            let mut mgr = ScreeningManager::new(ScreeningConfig::new(bound, RuleKind::Sphere));
            let (l, r) = mgr.screen(&prob, &ctx, &f.engine);
            l.len() + r.len()
        };
        assert!(count(BoundKind::Pgb) >= count(BoundKind::Gb));
    }
}
