//! Screening orchestration: build the configured sphere from solver state,
//! evaluate the configured rule over the **active workset** in cache-sized
//! parallel blocks, return the screened id lists.
//!
//! Cost structure follows the paper's §3.3 analysis, tightened by the
//! workset pipeline:
//! - every pass is O(|active|), never O(|T|): the compacted workset rows
//!   are handed to the engine directly and retired ids are never revisited;
//! - DGB's center is the iterate itself ⇒ `⟨H_t,Q⟩` *reuses* the margins
//!   already computed for the objective (no extra kernel pass);
//! - RPB/RRPB centers are scalar multiples of the fixed reference `M₀`,
//!   which lives in a shared [`ReferenceFrame`]: its margins are gathered
//!   **once per reference** (path driver) into the workset's row-aligned
//!   lane and only scaled here; because the sphere is *constant* during
//!   one λ solve, a triplet observed not to fire is memoized (`no_fire`)
//!   and skipped on every later dynamic call — and when the frame carries
//!   exact RRPB λ-intervals (`use_frame_certs`), the memo is *pre-seeded*
//!   from them, so under RRPB + sphere rule a fresh λ step evaluates zero
//!   rules instead of one pass over the actives;
//! - GB/PGB/CDGB centers move with the iterate ⇒ one fresh margins pass
//!   per screening invocation (the extra inner-product cost the paper
//!   attributes to PGB);
//! - the SDLS rule additionally pays per-triplet eigen work, so the plain
//!   sphere rule pre-filters and SDLS runs only on the undecided.
//!
//! Rule evaluation fans out across `util::parallel` workers in blocks of
//! [`RULE_BLOCK`] triplets; per-triplet lanes (`hq`, `‖H‖`, `hp`, `hx0`)
//! live in reusable scratch buffers, so a screening call allocates only
//! the returned decision lists.
//!
//! Every margins pass a rule needs (GB/PGB/CDGB centers, the linear
//! rule's support plane, SDLS anchors) goes through the same
//! [`Engine`] the solver uses — i.e. the tiled GEMM core of
//! `linalg::gemm` on the native engine — so screening and solving share
//! one compute core and one tile geometry.

use super::bounds::{self, Sphere};
use super::frame::ReferenceFrame;
use super::rules::{self, Decision};
use super::sdls::{self, SdlsQuery};
use super::{BoundKind, RuleKind, ScreeningConfig};
use crate::linalg::psd_split;
use crate::runtime::{Engine, PrecisionTier};
use crate::solver::{Problem, ScreenCtx};
use crate::util::parallel;
use crate::util::timer::PhaseTimers;
use std::rc::Rc;

/// Rule-evaluation block size: per-triplet lanes for one block
/// (`hq` + `hn` + decision ids) stay L2-resident while a worker streams
/// its contiguous group of blocks.
const RULE_BLOCK: usize = 4096;

/// Cumulative screening statistics.
#[derive(Clone, Debug, Default)]
pub struct ScreeningStats {
    /// screening-manager invocations
    pub calls: usize,
    /// triplets newly decided into L̂ across all calls
    pub screened_l: usize,
    /// triplets newly decided into R̂ across all calls
    pub screened_r: usize,
    /// total triplet-rule evaluations actually performed
    pub rule_evals: usize,
    /// evaluations avoided by the fixed-sphere no-fire memo
    pub skipped: usize,
    /// streaming admission: candidates tested (the initial mining sweep
    /// plus every certificate-expiry re-test)
    pub adm_candidates: usize,
    /// candidates rejected without workset allocation: L-certified, their
    /// `H_t` folded into the external L̂ mass
    pub adm_rejected_l: usize,
    /// candidates rejected without workset allocation: R-certified (they
    /// contribute nothing to the problem)
    pub adm_rejected_r: usize,
    /// candidates admitted into the workset (rows copied)
    pub adm_admitted: usize,
    /// mixed-precision tier: evaluations decided by the f32 pass alone
    /// (the rounding envelope cleared both endpoints — the decision is
    /// provably the exact-f64 one)
    pub rule_evals_f32: usize,
    /// mixed-precision tier: boundary-ambiguous evaluations promoted to
    /// the exact f64 path (per pass: one gathered f64 margins kernel
    /// call over exactly these rows)
    pub promotions: usize,
    /// sum of the rounding envelopes over all mixed-tier evaluations —
    /// `envelope_sum / envelope_count` is the mean envelope width
    /// reported as bench telemetry
    pub envelope_sum: f64,
    /// number of envelopes accumulated into `envelope_sum`
    pub envelope_count: usize,
}

impl ScreeningStats {
    /// Saturating accumulation of another counter set — the path-level
    /// aggregation primitive. Counters are per-call deltas summed over
    /// arbitrarily long regularization paths (and over sibling managers),
    /// so the aggregate must saturate instead of wrapping: telemetry may
    /// pin at `usize::MAX`, never double back to a small number.
    pub fn merge(&mut self, other: &ScreeningStats) {
        self.calls = self.calls.saturating_add(other.calls);
        self.screened_l = self.screened_l.saturating_add(other.screened_l);
        self.screened_r = self.screened_r.saturating_add(other.screened_r);
        self.rule_evals = self.rule_evals.saturating_add(other.rule_evals);
        self.skipped = self.skipped.saturating_add(other.skipped);
        self.adm_candidates = self.adm_candidates.saturating_add(other.adm_candidates);
        self.adm_rejected_l = self.adm_rejected_l.saturating_add(other.adm_rejected_l);
        self.adm_rejected_r = self.adm_rejected_r.saturating_add(other.adm_rejected_r);
        self.adm_admitted = self.adm_admitted.saturating_add(other.adm_admitted);
        self.rule_evals_f32 = self.rule_evals_f32.saturating_add(other.rule_evals_f32);
        self.promotions = self.promotions.saturating_add(other.promotions);
        self.envelope_sum += other.envelope_sum;
        self.envelope_count = self.envelope_count.saturating_add(other.envelope_count);
    }

    /// Candidates rejected at admission time on either side.
    pub fn adm_rejected(&self) -> usize {
        self.adm_rejected_l.saturating_add(self.adm_rejected_r)
    }
}

/// Reusable per-call scratch lanes (grown once, reused across calls).
#[derive(Default)]
struct Scratch {
    /// `⟨H_t, Q⟩` for active rows
    hq: Vec<f64>,
    /// `⟨H_t, P⟩` for the linear rule's support plane
    hp: Vec<f64>,
    /// `⟨H_t, X₀⟩` anchor margins for SDLS with non-PSD centers
    hx0: Vec<f64>,
    /// per-row rounding envelopes of the mixed-precision f32 pass
    env: Vec<f64>,
}

/// Identity of a fixed (iterate-independent) sphere: RPB/RRPB spheres
/// depend only on (reference, λ, loss), so rule outcomes are memoizable.
/// The reference is identified by its frame tag — process-unique, so a
/// memo can never survive into a different reference.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct FixedKey {
    lambda_bits: u64,
    gamma_bits: u64,
    frame_tag: u64,
}

/// Per-block rule-evaluation outcome (merged serially in block order).
struct BlockOut {
    l: Vec<usize>,
    r: Vec<usize>,
    /// ids proven not to fire under a fixed sphere (memo candidates)
    cleared: Vec<usize>,
    evals: usize,
    /// mixed tier: evaluations certified by the f32 pass alone
    evals_f32: usize,
    /// mixed tier: active-row positions `k` whose f32 evaluation was
    /// boundary-ambiguous — decided by one gathered f64 pass afterwards
    promote: Vec<usize>,
    /// mixed tier: envelope telemetry (sum of widths, count)
    env_sum: f64,
    env_count: usize,
}

/// Stateful screening engine for one regularization-path run.
pub struct ScreeningManager {
    /// the bound × rule configuration this manager evaluates
    pub cfg: ScreeningConfig,
    /// the λ-crossing reference state, shared with the path driver and
    /// any sibling manager (identity tag, `M₀`/`λ₀`/`ε`, margins lane,
    /// certified λ-intervals)
    frame: Option<Rc<ReferenceFrame>>,
    fixed_key: Option<FixedKey>,
    /// id-indexed: proven non-firing under the current fixed sphere
    no_fire: Vec<bool>,
    scratch: Scratch,
    /// cumulative counters (rule evaluations, memo skips, admission)
    pub stats: ScreeningStats,
}

impl ScreeningManager {
    /// Fresh manager with empty memo/stats.
    pub fn new(cfg: ScreeningConfig) -> ScreeningManager {
        ScreeningManager {
            cfg,
            frame: None,
            fixed_key: None,
            no_fire: Vec::new(),
            scratch: Scratch::default(),
            stats: ScreeningStats::default(),
        }
    }

    /// Install a shared reference frame (the path driver builds one per
    /// reference solution and hands the same `Rc` to every RPB/RRPB
    /// manager). Invalidates the fixed-sphere memo: the frame's tag is
    /// process-unique, so state from another reference can never leak in.
    pub fn set_frame(&mut self, frame: Rc<ReferenceFrame>) {
        self.frame = Some(frame);
        self.fixed_key = None;
    }

    /// Convenience for standalone use (tests, single solves): build a
    /// certificate-free [`ReferenceFrame`] from `m0` (one margins pass)
    /// and install it.
    pub fn set_reference(
        &mut self,
        m0: crate::linalg::Mat,
        lambda0: f64,
        eps: f64,
        store: &crate::triplet::TripletStore,
        engine: &dyn Engine,
    ) {
        let frame = ReferenceFrame::build(m0, lambda0, eps, store, engine, None);
        self.set_frame(Rc::new(frame));
    }

    /// The installed reference frame, if any.
    pub fn frame(&self) -> Option<&ReferenceFrame> {
        self.frame.as_deref()
    }

    /// Screen-on-admission over one mined batch (streaming pipeline):
    /// one margins pass with the frame's `M₀` over the batch rows, then
    /// the closed-form RRPB ranges per candidate
    /// ([`ReferenceFrame::admission_decision`]). Fills `hm` with
    /// `⟨H, M₀⟩` (the caller extends the workset reference-margin lane
    /// with the admitted entries) and `out` with one decision per batch
    /// row; admission counters land in [`ScreeningStats`]. Returns false
    /// — leaving both outputs empty — when no reference frame is
    /// installed (admission cannot prove anything without one).
    ///
    /// Under [`PrecisionTier::MixedCertified`] the margins pass runs in
    /// f32 and decisions are certified through
    /// [`ReferenceFrame::admission_decision_enveloped`] with the
    /// per-candidate rounding envelope. Candidates whose f32 evaluation
    /// lands inside the envelope of a decision boundary are promoted:
    /// one gathered exact f64 margins pass covers exactly the promoted
    /// rows *plus every admitted row* — admitted entries feed the
    /// workset's reference-margin lane, which must only ever carry exact
    /// f64 values (the lane scales into `hq` on all later RRPB passes).
    /// Robust f32 rejections keep their f32 margin in `hm`; it is never
    /// consumed downstream.
    pub fn admit_batch(
        &mut self,
        batch: &crate::triplet::CandidateBatch,
        lambda: f64,
        loss: &crate::loss::Loss,
        engine: &dyn Engine,
        hm: &mut Vec<f64>,
        out: &mut Vec<super::frame::Admission>,
    ) -> bool {
        use super::frame::Admission;
        use super::CertSide;
        hm.clear();
        out.clear();
        let Some(frame) = self.frame.as_deref() else {
            return false;
        };
        hm.resize(batch.len(), 0.0);
        out.reserve(batch.len());
        let mut mixed = false;
        if engine.precision() == PrecisionTier::MixedCertified && !batch.is_empty() {
            self.scratch.env.resize(batch.len(), 0.0);
            mixed = engine.margins_f32(frame.m0(), &batch.a, &batch.b, hm, &mut self.scratch.env);
        }
        if mixed {
            let env: &[f64] = &self.scratch.env;
            // batch indices needing an exact f64 margin: boundary-ambiguous
            // (decision promoted) ∪ admitted (lane exactness contract)
            let mut need_f64: Vec<usize> = Vec::new();
            let mut ambiguous: Vec<usize> = Vec::new();
            for t in 0..batch.len() {
                self.stats.envelope_sum += env[t];
                self.stats.envelope_count = self.stats.envelope_count.saturating_add(1);
                match frame.admission_decision_enveloped(
                    hm[t],
                    batch.h_norm[t],
                    lambda,
                    loss,
                    env[t],
                ) {
                    Some(Admission::Admit) => {
                        self.stats.rule_evals_f32 += 1;
                        need_f64.push(t);
                        out.push(Admission::Admit);
                    }
                    Some(certified) => {
                        self.stats.rule_evals_f32 += 1;
                        out.push(certified);
                    }
                    None => {
                        self.stats.promotions += 1;
                        need_f64.push(t);
                        ambiguous.push(t);
                        // placeholder, overwritten from the exact margin below
                        out.push(Admission::Admit);
                    }
                }
            }
            if !need_f64.is_empty() {
                let pa = batch.a.select_rows(&need_f64);
                let pb = batch.b.select_rows(&need_f64);
                let mut pm = vec![0.0; need_f64.len()];
                engine.margins(frame.m0(), &pa, &pb, &mut pm);
                for (j, &t) in need_f64.iter().enumerate() {
                    hm[t] = pm[j];
                }
                for &t in &ambiguous {
                    out[t] = frame.admission_decision(hm[t], batch.h_norm[t], lambda, loss);
                }
            }
        } else {
            if !batch.is_empty() {
                // reference-scoped margins: the factored backend answers
                // these in O(r) per row from cached embeddings of the
                // batch; dense engines route to the plain kernels. (The
                // mixed tier above stays on the dense f32/f64 kernels —
                // its rounding envelope is certified against the dense
                // f64 pass.)
                engine.ref_margins(frame.m0(), &batch.a, &batch.b, hm);
            }
            for t in 0..batch.len() {
                out.push(frame.admission_decision(hm[t], batch.h_norm[t], lambda, loss));
            }
        }
        for decision in out.iter() {
            self.stats.adm_candidates = self.stats.adm_candidates.saturating_add(1);
            match decision {
                Admission::Admit => {
                    self.stats.adm_admitted = self.stats.adm_admitted.saturating_add(1);
                }
                Admission::Certified { side: CertSide::L, .. } => {
                    self.stats.adm_rejected_l = self.stats.adm_rejected_l.saturating_add(1);
                }
                Admission::Certified { side: CertSide::R, .. } => {
                    self.stats.adm_rejected_r = self.stats.adm_rejected_r.saturating_add(1);
                }
            }
        }
        true
    }

    /// Build the configured sphere from the current solver state.
    /// Returns None when prerequisites are missing (e.g. RPB without a
    /// reference) — the caller then skips screening.
    pub fn build_sphere(
        &self,
        problem: &Problem,
        ctx: &ScreenCtx,
        engine: &dyn Engine,
    ) -> Option<Sphere> {
        let lambda = problem.lambda;
        Some(match self.cfg.bound {
            BoundKind::Gb => bounds::gb(ctx.m, ctx.grad, lambda),
            BoundKind::Pgb => bounds::pgb(ctx.m, ctx.grad, lambda).0,
            BoundKind::Dgb => bounds::dgb(ctx.m, ctx.gap, lambda),
            BoundKind::Cdgb => {
                // gap at the dual iterate M_λ(α) = [K]_+/λ: one extra
                // primal evaluation (Thm 3.6 discussion)
                let center = ctx.k_plus.scaled(1.0 / lambda);
                let mut scratch = PhaseTimers::default();
                let ev = problem.eval(&center, engine, &mut scratch);
                bounds::cdgb(ctx.k_plus, ev.p - ctx.d, lambda)
            }
            BoundKind::Rpb => {
                // the frame's cached norm (engine-provided: the factored
                // backend computes it from the r×r Gram at build time)
                // keeps sphere construction free of d×d norm passes
                let f = self.frame.as_ref()?;
                bounds::rpb_with_norm(f.m0(), f.m0_norm(), f.lambda0(), lambda)
            }
            BoundKind::Rrpb => {
                let f = self.frame.as_ref()?;
                bounds::rrpb_with_norm(f.m0(), f.m0_norm(), f.eps(), f.lambda0(), lambda)
            }
        })
    }

    /// Fill the scratch `hq` lane with `⟨H_t, Q⟩` for all active rows,
    /// exploiting center structure, and return it.
    fn center_margins(
        &mut self,
        sphere: &Sphere,
        problem: &Problem,
        ctx: &ScreenCtx,
        engine: &dyn Engine,
    ) -> &[f64] {
        let n = problem.active_idx().len();
        self.scratch.hq.resize(n, 0.0);
        match self.cfg.bound {
            BoundKind::Dgb => self.scratch.hq.copy_from_slice(ctx.margins),
            BoundKind::Rpb | BoundKind::Rrpb => {
                let f = self.frame.as_ref().expect("checked in build_sphere");
                let scale = (f.lambda0() + problem.lambda) / (2.0 * problem.lambda);
                if let Some(lane) = problem.active_ref_margins(f.tag()) {
                    // row-aligned lane installed by the path driver for
                    // exactly this frame (tag-checked): contiguous scale,
                    // no per-id gather
                    for (dst, &m0) in self.scratch.hq.iter_mut().zip(lane) {
                        *dst = scale * m0;
                    }
                } else {
                    let ref_margins = f.margins();
                    for (dst, &t) in self.scratch.hq.iter_mut().zip(problem.active_idx()) {
                        *dst = scale * ref_margins[t];
                    }
                }
            }
            _ => engine.margins(
                &sphere.q,
                problem.active_a(),
                problem.active_b(),
                &mut self.scratch.hq,
            ),
        }
        &self.scratch.hq
    }

    /// Run one screening pass; returns `(new_l, new_r)` triplet ids.
    pub fn screen(
        &mut self,
        problem: &Problem,
        ctx: &ScreenCtx,
        engine: &dyn Engine,
    ) -> (Vec<usize>, Vec<usize>) {
        let Some(sphere) = self.build_sphere(problem, ctx, engine) else {
            return (vec![], vec![]);
        };
        self.stats.calls += 1;
        let n = problem.active_idx().len();

        let thr_l = problem.loss.l_threshold();
        let thr_r = problem.loss.r_threshold();

        // Fixed-sphere memo: RPB/RRPB spheres do not move during one λ
        // solve, so with an iterate-independent rule a triplet evaluated
        // to Decision::None can never fire later under the same key. The
        // linear rule's support plane tracks the iterate, so it stays out.
        let fixed = matches!(self.cfg.bound, BoundKind::Rpb | BoundKind::Rrpb)
            && self.cfg.rule != RuleKind::Linear;
        if fixed {
            let key = FixedKey {
                lambda_bits: problem.lambda.to_bits(),
                gamma_bits: problem.loss.gamma.to_bits(),
                frame_tag: self.frame.as_ref().map_or(0, |f| f.tag()),
            };
            if self.fixed_key != Some(key) {
                self.fixed_key = Some(key);
                self.no_fire.clear();
                self.no_fire.resize(problem.status().len(), false);
                // Certificate seeding: the frame's RRPB λ-intervals are
                // *exact* for the sphere rule (the rule fires at λ iff λ
                // is inside), so every active triplet whose intervals
                // exclude this λ is proven non-firing before any rule
                // runs. After the driver's range pass this covers the
                // whole workset — the pass below then evaluates nothing.
                if self.cfg.use_frame_certs
                    && self.cfg.bound == BoundKind::Rrpb
                    && self.cfg.rule == RuleKind::Sphere
                {
                    if let Some(f) = &self.frame {
                        if f.has_exact_rrpb(&problem.loss)
                            && f.margins().len() == problem.status().len()
                        {
                            for &t in problem.active_idx() {
                                if f.rrpb_sphere_decision(t, problem.lambda).is_none() {
                                    self.no_fire[t] = true;
                                }
                            }
                        }
                    }
                }
            }
        }
        // When the memo (certificate-seeded or accumulated) already
        // covers every active triplet, skip the margins fill and the
        // parallel rule dispatch entirely — the certificate fast path
        // costs O(active) boolean loads, not a kernel pass.
        if fixed && problem.active_idx().iter().all(|&t| self.no_fire[t]) {
            self.stats.skipped += n;
            return (vec![], vec![]);
        }

        // Mixed-precision tier: the engine-pass bounds (GB/PGB/CDGB) under
        // the plain sphere rule run their margins pass in f32 with a
        // per-row rounding envelope. DGB reuses f64 margins already paid
        // for by the objective and RPB/RRPB only scale the f64 reference
        // lane, so f32 would save nothing there — they stay exact.
        let mixed_eligible = self.cfg.rule == RuleKind::Sphere
            && matches!(
                self.cfg.bound,
                BoundKind::Gb | BoundKind::Pgb | BoundKind::Cdgb
            )
            && engine.precision() == PrecisionTier::MixedCertified;
        let mut mixed = false;
        if mixed_eligible {
            self.scratch.hq.resize(n, 0.0);
            self.scratch.env.resize(n, 0.0);
            mixed = engine.margins_f32(
                &sphere.q,
                problem.active_a(),
                problem.active_b(),
                &mut self.scratch.hq,
                &mut self.scratch.env,
            );
        }
        if !mixed {
            self.center_margins(&sphere, problem, ctx, engine);
        }

        // Linear-rule support plane (one margins pass with P): prefer
        // P = −[Q^GB]_− from the projection of the gradient-step point
        // M − ∇P̃/(2λ) — the halfspace Fig 3(a) shows is tighter than PGB;
        // fall back to the optimizer's own pre-projection split, then to
        // the plain sphere rule.
        let mut lin: Option<(f64, f64)> = None; // (⟨P,Q⟩, ‖P‖²)
        if self.cfg.rule == RuleKind::Linear {
            let mut gb_center = ctx.m.clone();
            gb_center.axpy(-0.5 / problem.lambda, ctx.grad);
            let gb_split = psd_split(&gb_center);
            let p = if gb_split.minus_norm_sq > 1e-24 {
                Some(gb_split.minus.scaled(-1.0))
            } else {
                ctx.pre_split.map(|s| s.minus.scaled(-1.0))
            };
            if let Some(p) = p {
                if p.norm_sq() > 0.0 {
                    self.scratch.hp.resize(n, 0.0);
                    engine.margins(
                        &p,
                        problem.active_a(),
                        problem.active_b(),
                        &mut self.scratch.hp,
                    );
                    lin = Some((p.dot(&sphere.q), p.norm_sq()));
                }
            }
        }

        // SDLS anchor margins for non-PSD centers: X₀ = [Q]_+ must lie
        // inside the sphere for the anchor argument to hold.
        let mut sdls_anchor_ok = true;
        if self.cfg.rule == RuleKind::SemiDefinite && !sphere.psd_center {
            let split = psd_split(&sphere.q);
            if split.minus_norm_sq.sqrt() <= sphere.r {
                self.scratch.hx0.resize(n, 0.0);
                engine.margins(
                    &split.plus,
                    problem.active_a(),
                    problem.active_b(),
                    &mut self.scratch.hx0,
                );
            } else {
                sdls_anchor_ok = false; // no certified anchor: SDLS cannot conclude
            }
        }

        // ---- blocked, parallel rule evaluation ----
        let ids = problem.active_idx();
        let hn = problem.active_h_norm();
        let hq: &[f64] = &self.scratch.hq;
        let hp: &[f64] = &self.scratch.hp;
        let hx0: &[f64] = &self.scratch.hx0;
        let env: &[f64] = &self.scratch.env;
        let no_fire: &[bool] = &self.no_fire;
        let rule = self.cfg.rule;
        let max_iter = self.cfg.sdls_max_iter;
        let q_norm_sq = sphere.q.norm_sq();
        let r_sq = sphere.r * sphere.r;
        let sphere_ref = &sphere;
        // one `--threads` knob governs every pooled pass: the rule loop
        // rides the same worker count the engine's kernels dispatch at
        let workers = engine.workers();

        let blocks = parallel::par_blocks(n, RULE_BLOCK, workers, |range| {
            let mut out = BlockOut {
                l: Vec::new(),
                r: Vec::new(),
                cleared: Vec::new(),
                evals: 0,
                evals_f32: 0,
                promote: Vec::new(),
                env_sum: 0.0,
                env_count: 0,
            };
            for k in range {
                let t = ids[k];
                if fixed && no_fire[t] {
                    continue; // proven non-firing under this sphere
                }
                out.evals += 1;
                let decision = match rule {
                    RuleKind::Sphere => {
                        if mixed {
                            out.env_sum += env[k];
                            out.env_count += 1;
                            match rules::sphere_rule_enveloped(
                                hq[k],
                                hn[k],
                                sphere_ref.r,
                                thr_l,
                                thr_r,
                                env[k],
                            ) {
                                Some(decision) => {
                                    out.evals_f32 += 1;
                                    decision
                                }
                                // boundary-ambiguous: decided by the
                                // gathered f64 pass after the blocks
                                None => {
                                    out.promote.push(k);
                                    continue;
                                }
                            }
                        } else {
                            rules::sphere_rule(hq[k], hn[k], sphere_ref.r, thr_l, thr_r)
                        }
                    }
                    RuleKind::Linear => match lin {
                        Some((pq, pn_sq)) => rules::linear_rule(
                            hq[k], hn[k], hp[k], pq, pn_sq, sphere_ref.r, thr_l, thr_r,
                        ),
                        None => rules::sphere_rule(hq[k], hn[k], sphere_ref.r, thr_l, thr_r),
                    },
                    RuleKind::SemiDefinite => {
                        // sphere decision is implied by the SDLS decision
                        // (smaller feasible set) — pre-filter, SDLS only
                        // on the undecided
                        let pre = rules::sphere_rule(hq[k], hn[k], sphere_ref.r, thr_l, thr_r);
                        if pre != Decision::None || !sdls_anchor_ok {
                            pre
                        } else {
                            let anchor = if sphere_ref.psd_center { hq[k] } else { hx0[k] };
                            let query = SdlsQuery {
                                q: &sphere_ref.q,
                                q_norm_sq,
                                psd_center: sphere_ref.psd_center,
                                r_sq,
                                a: problem.active_a().row(k),
                                b: problem.active_b().row(k),
                                hq: hq[k],
                                hn: hn[k],
                                hx0: anchor,
                            };
                            if sdls::sdls_screens_r(&query, thr_r, max_iter) {
                                Decision::ScreenR
                            } else if sdls::sdls_screens_l(&query, thr_l, max_iter) {
                                Decision::ScreenL
                            } else {
                                Decision::None
                            }
                        }
                    }
                };
                match decision {
                    Decision::ScreenL => out.l.push(t),
                    Decision::ScreenR => out.r.push(t),
                    Decision::None => {
                        if fixed {
                            out.cleared.push(t);
                        }
                    }
                }
            }
            out
        });

        let mut new_l = Vec::new();
        let mut new_r = Vec::new();
        let mut evals = 0usize;
        let mut evals_f32 = 0usize;
        let mut env_sum = 0.0f64;
        let mut env_count = 0usize;
        let mut cleared = Vec::new();
        let mut promote: Vec<usize> = Vec::new();
        for b in blocks {
            new_l.extend(b.l);
            new_r.extend(b.r);
            cleared.extend(b.cleared);
            evals += b.evals;
            evals_f32 += b.evals_f32;
            env_sum += b.env_sum;
            env_count += b.env_count;
            promote.extend(b.promote);
        }
        for t in cleared {
            self.no_fire[t] = true;
        }
        // Promotion pass: one gathered exact f64 margins call over the
        // boundary-ambiguous rows, then the exact sphere rule. Margins are
        // computed per row (no cross-row reduction), so the gathered pass
        // is bitwise identical to a full f64 pass over the same rows —
        // mixed-tier decisions match the pure-f64 run exactly.
        if !promote.is_empty() {
            let pa = problem.active_a().select_rows(&promote);
            let pb = problem.active_b().select_rows(&promote);
            let mut pm = vec![0.0; promote.len()];
            engine.margins(&sphere.q, &pa, &pb, &mut pm);
            for (j, &k) in promote.iter().enumerate() {
                match rules::sphere_rule(pm[j], hn[k], sphere.r, thr_l, thr_r) {
                    Decision::ScreenL => new_l.push(ids[k]),
                    Decision::ScreenR => new_r.push(ids[k]),
                    Decision::None => {}
                }
            }
        }
        self.stats.rule_evals += evals;
        self.stats.rule_evals_f32 += evals_f32;
        self.stats.promotions += promote.len();
        self.stats.envelope_sum += env_sum;
        self.stats.envelope_count = self.stats.envelope_count.saturating_add(env_count);
        self.stats.skipped += n - evals;
        self.stats.screened_l += new_l.len();
        self.stats.screened_r += new_r.len();
        (new_l, new_r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::linalg::Mat;
    use crate::loss::Loss;
    use crate::runtime::NativeEngine;
    use crate::solver::{Solver, SolverConfig};
    use crate::triplet::TripletStore;
    use crate::util::rng::Pcg64;

    struct Fix {
        store: TripletStore,
        loss: Loss,
        lmax: f64,
        engine: NativeEngine,
    }

    fn fix(seed: u64) -> Fix {
        let mut rng = Pcg64::seed(seed);
        let ds = synthetic::gaussian_mixture("g", 45, 4, 3, 2.6, &mut rng);
        let store = TripletStore::from_dataset(&ds, 3, &mut rng);
        let loss = Loss::smoothed_hinge(0.05);
        let engine = NativeEngine::new(2);
        let lmax = Problem::lambda_max(&store, &loss, &engine);
        Fix {
            store,
            loss,
            lmax,
            engine,
        }
    }

    fn exact_solution(f: &Fix, lambda: f64) -> Mat {
        let mut prob = Problem::new(&f.store, f.loss, lambda);
        let (m, st) = Solver::new(SolverConfig {
            tol: 1e-12,
            tol_relative: false,
            max_iters: 50_000,
            ..Default::default()
        })
        .solve(&mut prob, &f.engine, Mat::zeros(4, 4), None);
        assert!(st.converged);
        m
    }

    /// The master safety test: for every bound × rule, run the solver with
    /// screening and verify each screened triplet against the true optimum
    /// membership (margins at a 1e-12-gap solution).
    #[test]
    fn all_bound_rule_combinations_are_safe() {
        let f = fix(1);
        let lambda = f.lmax * 0.15;
        let m_star = exact_solution(&f, lambda);
        let mut true_margins = vec![0.0; f.store.len()];
        f.engine
            .margins(&m_star, &f.store.a, &f.store.b, &mut true_margins);

        for bound in [
            BoundKind::Gb,
            BoundKind::Pgb,
            BoundKind::Dgb,
            BoundKind::Cdgb,
            BoundKind::Rrpb,
            BoundKind::Rpb,
        ] {
            for rule in [RuleKind::Sphere, RuleKind::Linear, RuleKind::SemiDefinite] {
                let mut mgr = ScreeningManager::new(ScreeningConfig::new(bound, rule));
                if bound.needs_reference() {
                    // reference: solve at a larger λ0 accurately
                    let l0 = lambda / 0.8;
                    let m0 = exact_solution(&f, l0);
                    mgr.set_reference(m0, l0, 1e-9, &f.store, &f.engine);
                }
                let mut prob = Problem::new(&f.store, f.loss, lambda);
                let engine = &f.engine;
                let mut cb = |p: &Problem, ctx: &ScreenCtx| mgr.screen(p, ctx, engine);
                let solver = Solver::new(SolverConfig {
                    tol: 1e-10,
                    tol_relative: false,
                    ..Default::default()
                });
                let (m, stats) = solver.solve(&mut prob, &f.engine, Mat::zeros(4, 4), Some(&mut cb));
                assert!(stats.converged, "{bound:?}/{rule:?} did not converge");
                // solution must match unscreened optimum
                let diff = m.sub(&m_star).max_abs();
                assert!(
                    diff < 1e-4 * (1.0 + m_star.max_abs()),
                    "{bound:?}/{rule:?}: solution drifted by {diff}"
                );
                // every screened triplet is truly in L*/R*
                for t in 0..f.store.len() {
                    match prob.status().get(t) {
                        crate::triplet::TripletStatus::ScreenedL => assert!(
                            true_margins[t] < f.loss.l_threshold() + 1e-6,
                            "{bound:?}/{rule:?}: t={t} screened L but margin {}",
                            true_margins[t]
                        ),
                        crate::triplet::TripletStatus::ScreenedR => assert!(
                            true_margins[t] > f.loss.r_threshold() - 1e-6,
                            "{bound:?}/{rule:?}: t={t} screened R but margin {}",
                            true_margins[t]
                        ),
                        crate::triplet::TripletStatus::Active => {}
                    }
                }
            }
        }
    }

    #[test]
    fn stats_merge_saturates_instead_of_wrapping() {
        // the path-level aggregation runs over arbitrarily long paths and
        // multiple managers — near-ceiling counters must pin at MAX, not
        // wrap (which would read as a tiny count in telemetry)
        let mut a = ScreeningStats {
            calls: usize::MAX - 1,
            rule_evals: usize::MAX,
            skipped: 3,
            adm_candidates: usize::MAX - 2,
            ..Default::default()
        };
        a.rule_evals_f32 = usize::MAX - 1;
        a.envelope_sum = 1.5;
        let b = ScreeningStats {
            calls: 7,
            rule_evals: 9,
            skipped: 4,
            adm_candidates: 5,
            adm_rejected_l: 2,
            adm_rejected_r: 1,
            adm_admitted: 8,
            rule_evals_f32: 6,
            promotions: 3,
            envelope_sum: 0.25,
            envelope_count: 4,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.calls, usize::MAX);
        assert_eq!(a.rule_evals, usize::MAX);
        assert_eq!(a.skipped, 7);
        assert_eq!(a.adm_candidates, usize::MAX);
        assert_eq!(a.adm_rejected_l, 2);
        assert_eq!(a.adm_rejected_r, 1);
        assert_eq!(a.adm_admitted, 8);
        assert_eq!(a.rule_evals_f32, usize::MAX);
        assert_eq!(a.promotions, 3);
        assert!((a.envelope_sum - 1.75).abs() < 1e-15);
        assert_eq!(a.envelope_count, 4);
        assert_eq!(
            ScreeningStats {
                adm_rejected_l: usize::MAX,
                adm_rejected_r: 1,
                ..Default::default()
            }
            .adm_rejected(),
            usize::MAX
        );
    }

    #[test]
    fn admit_batch_splits_batch_and_counts() {
        // admission over a mined batch must agree candidate-by-candidate
        // with the frame's closed-form decision, and the stats counters
        // must add up to the batch size
        let f = fix(6);
        let l0 = f.lmax * 0.4;
        let m0 = exact_solution(&f, l0);
        let lambda = l0 * 0.8;
        let mut mgr = ScreeningManager::new(ScreeningConfig::new(BoundKind::Rrpb, RuleKind::Sphere));

        // no frame installed: admission refuses to decide
        let mut rng = crate::util::rng::Pcg64::seed(77);
        let ds = synthetic::gaussian_mixture("adm", 40, 4, 3, 2.6, &mut rng);
        let mut miner = crate::triplet::TripletMiner::new(
            &ds,
            3,
            crate::triplet::MiningStrategy::Exhaustive,
            64,
        );
        let mut batch = crate::triplet::CandidateBatch::new(ds.d());
        assert!(miner.next_into(&mut batch));
        let (mut hm, mut out) = (Vec::new(), Vec::new());
        assert!(!mgr.admit_batch(&batch, lambda, &f.loss, &f.engine, &mut hm, &mut out));
        assert!(hm.is_empty() && out.is_empty());
        assert_eq!(mgr.stats.adm_candidates, 0);

        // with the frame: decisions match admission_decision, counters add up
        mgr.set_reference(m0.clone(), l0, 1e-9, &f.store, &f.engine);
        assert!(mgr.admit_batch(&batch, lambda, &f.loss, &f.engine, &mut hm, &mut out));
        assert_eq!(out.len(), batch.len());
        assert_eq!(hm.len(), batch.len());
        let frame = mgr.frame().expect("frame installed");
        for t in 0..batch.len() {
            let want = frame.admission_decision(hm[t], batch.h_norm[t], lambda, &f.loss);
            assert_eq!(out[t], want, "candidate {t} decision diverged");
        }
        assert_eq!(mgr.stats.adm_candidates, batch.len());
        assert_eq!(mgr.stats.adm_admitted + mgr.stats.adm_rejected(), batch.len());
    }

    #[test]
    fn mixed_tier_screen_matches_f64_decisions_and_conserves_evals() {
        // For every engine-pass bound under the sphere rule, the mixed
        // tier must reach the exact same screening decisions as the pure
        // f64 engine (both-endpoint certification + f64 promotion), and
        // every evaluation must land in exactly one of the two counters:
        // rule_evals == rule_evals_f32 + promotions.
        let f = fix(7);
        let lambda = f.lmax * 0.2;
        let mut prob = Problem::new(&f.store, f.loss, lambda);
        let (m, _) = Solver::new(SolverConfig {
            tol: 1e-4,
            tol_relative: false,
            ..Default::default()
        })
        .solve(&mut prob, &f.engine, Mat::zeros(4, 4), None);
        let mut timers = PhaseTimers::default();
        let ev = prob.eval(&m, &f.engine, &mut timers);
        let grad = prob.grad(&m, &ev.k);
        let (d_val, split) = prob.dual(&ev.margins, &ev.k, &mut timers);
        let ctx = ScreenCtx {
            m: &m,
            grad: &grad,
            p: ev.p,
            d: d_val,
            gap: ev.p - d_val,
            k_plus: &split.plus,
            pre_split: None,
            margins: &ev.margins,
            iter: 0,
        };
        let mixed_engine =
            NativeEngine::new(2).with_precision(crate::runtime::PrecisionTier::MixedCertified);
        for bound in [BoundKind::Gb, BoundKind::Pgb, BoundKind::Cdgb] {
            let mut exact = ScreeningManager::new(ScreeningConfig::new(bound, RuleKind::Sphere));
            let (mut le, mut re) = exact.screen(&prob, &ctx, &f.engine);
            let mut mixed = ScreeningManager::new(ScreeningConfig::new(bound, RuleKind::Sphere));
            let (mut lm, mut rm) = mixed.screen(&prob, &ctx, &mixed_engine);
            le.sort_unstable();
            re.sort_unstable();
            lm.sort_unstable();
            rm.sort_unstable();
            assert_eq!(le, lm, "{bound:?}: mixed L set diverged from f64");
            assert_eq!(re, rm, "{bound:?}: mixed R set diverged from f64");
            let s = &mixed.stats;
            assert!(s.rule_evals_f32 > 0, "{bound:?}: f32 tier did no work");
            assert_eq!(
                s.rule_evals,
                s.rule_evals_f32 + s.promotions,
                "{bound:?}: evaluation conservation violated"
            );
            assert_eq!(s.envelope_count, s.rule_evals, "{bound:?}: envelope telemetry gap");
            assert!(s.envelope_sum > 0.0, "{bound:?}: zero-width envelopes");
            // the exact manager never touches the mixed counters
            assert_eq!(exact.stats.rule_evals_f32, 0);
            assert_eq!(exact.stats.promotions, 0);
            assert_eq!(exact.stats.envelope_count, 0);
        }
    }

    #[test]
    fn mixed_admission_matches_exact_and_keeps_lane_exact() {
        // Mixed-tier admission must (a) reach the same admit/reject split
        // as the exact path (certified expires may be conservative but the
        // side must agree), and (b) hand back bitwise-exact f64 margins
        // for every admitted candidate — the workset reference-margin lane
        // consumes them on all later RRPB passes.
        let f = fix(8);
        let l0 = f.lmax * 0.4;
        let m0 = exact_solution(&f, l0);
        let lambda = l0 * 0.8;
        let mut rng = crate::util::rng::Pcg64::seed(78);
        let ds = synthetic::gaussian_mixture("adm32", 40, 4, 3, 2.6, &mut rng);
        let mut miner = crate::triplet::TripletMiner::new(
            &ds,
            3,
            crate::triplet::MiningStrategy::Exhaustive,
            64,
        );
        let mut batch = crate::triplet::CandidateBatch::new(ds.d());
        assert!(miner.next_into(&mut batch));

        let mixed_engine =
            NativeEngine::new(2).with_precision(crate::runtime::PrecisionTier::MixedCertified);
        let mut exact = ScreeningManager::new(ScreeningConfig::new(BoundKind::Rrpb, RuleKind::Sphere));
        exact.set_reference(m0.clone(), l0, 1e-9, &f.store, &f.engine);
        let mut mixed = ScreeningManager::new(ScreeningConfig::new(BoundKind::Rrpb, RuleKind::Sphere));
        mixed.set_reference(m0.clone(), l0, 1e-9, &f.store, &f.engine);

        let (mut hm_e, mut out_e) = (Vec::new(), Vec::new());
        assert!(exact.admit_batch(&batch, lambda, &f.loss, &f.engine, &mut hm_e, &mut out_e));
        let (mut hm_m, mut out_m) = (Vec::new(), Vec::new());
        assert!(mixed.admit_batch(&batch, lambda, &f.loss, &mixed_engine, &mut hm_m, &mut out_m));

        assert_eq!(out_e.len(), out_m.len());
        use super::super::frame::Admission;
        for t in 0..batch.len() {
            match (&out_e[t], &out_m[t]) {
                (Admission::Admit, Admission::Admit) => {
                    // lane contract: admitted margins are exact f64
                    assert_eq!(
                        hm_e[t].to_bits(),
                        hm_m[t].to_bits(),
                        "candidate {t}: admitted margin not exact"
                    );
                }
                (
                    Admission::Certified { side: se, expires: ee },
                    Admission::Certified { side: sm, expires: em },
                ) => {
                    assert_eq!(se, sm, "candidate {t}: certified side diverged");
                    // mixed expires is max over the envelope endpoints —
                    // conservative, never below the exact certificate
                    assert!(
                        *em >= *ee - 1e-15,
                        "candidate {t}: mixed certificate expires earlier than exact"
                    );
                }
                (e, m) => panic!("candidate {t}: decisions diverged: {e:?} vs {m:?}"),
            }
        }
        assert_eq!(mixed.stats.adm_candidates, batch.len());
        assert_eq!(mixed.stats.adm_admitted, exact.stats.adm_admitted);
        assert_eq!(mixed.stats.adm_rejected(), exact.stats.adm_rejected());
        // every candidate was either f32-certified or promoted
        assert_eq!(
            mixed.stats.rule_evals_f32 + mixed.stats.promotions,
            batch.len(),
            "admission conservation violated"
        );
        assert_eq!(mixed.stats.envelope_count, batch.len());
    }

    #[test]
    fn dgb_reuses_objective_margins() {
        // center_margins for DGB must be exactly ctx.margins
        let f = fix(2);
        let lambda = f.lmax * 0.3;
        let mut prob = Problem::new(&f.store, f.loss, lambda);
        let mut timers = PhaseTimers::default();
        let m = Mat::identity(4).scaled(0.01);
        let ev = prob.eval(&m, &f.engine, &mut timers);
        let grad = prob.grad(&m, &ev.k);
        let (d_val, split) = prob.dual(&ev.margins, &ev.k, &mut timers);
        let ctx = ScreenCtx {
            m: &m,
            grad: &grad,
            p: ev.p,
            d: d_val,
            gap: ev.p - d_val,
            k_plus: &split.plus,
            pre_split: None,
            margins: &ev.margins,
            iter: 0,
        };
        let mut mgr = ScreeningManager::new(ScreeningConfig::new(BoundKind::Dgb, RuleKind::Sphere));
        let sphere = mgr.build_sphere(&prob, &ctx, &f.engine).unwrap();
        let hq = mgr.center_margins(&sphere, &prob, &ctx, &f.engine);
        assert_eq!(hq, &ev.margins[..]);
        let _ = &mut prob;
    }

    #[test]
    fn rpb_without_reference_skips() {
        let f = fix(3);
        let mut mgr = ScreeningManager::new(ScreeningConfig::new(BoundKind::Rpb, RuleKind::Sphere));
        let prob = Problem::new(&f.store, f.loss, f.lmax * 0.5);
        let m = Mat::zeros(4, 4);
        let grad = Mat::zeros(4, 4);
        let kp = Mat::zeros(4, 4);
        let margins = vec![0.0; prob.active_idx().len()];
        let ctx = ScreenCtx {
            m: &m,
            grad: &grad,
            p: 0.0,
            d: 0.0,
            gap: 0.0,
            k_plus: &kp,
            pre_split: None,
            margins: &margins,
            iter: 0,
        };
        let (l, r) = mgr.screen(&prob, &ctx, &f.engine);
        assert!(l.is_empty() && r.is_empty());
        assert_eq!(mgr.stats.calls, 0);
    }

    #[test]
    fn tighter_bounds_screen_no_less() {
        // With identical reference state, PGB (⊆ GB) must screen at least
        // as many triplets as GB under the sphere rule.
        let f = fix(4);
        let lambda = f.lmax * 0.2;
        // moderately accurate iterate
        let mut prob = Problem::new(&f.store, f.loss, lambda);
        let (m, _) = Solver::new(SolverConfig {
            tol: 1e-4,
            tol_relative: false,
            ..Default::default()
        })
        .solve(&mut prob, &f.engine, Mat::zeros(4, 4), None);
        let mut timers = PhaseTimers::default();
        let ev = prob.eval(&m, &f.engine, &mut timers);
        let grad = prob.grad(&m, &ev.k);
        let (d_val, split) = prob.dual(&ev.margins, &ev.k, &mut timers);
        let ctx = ScreenCtx {
            m: &m,
            grad: &grad,
            p: ev.p,
            d: d_val,
            gap: ev.p - d_val,
            k_plus: &split.plus,
            pre_split: None,
            margins: &ev.margins,
            iter: 0,
        };
        let count = |bound: BoundKind| {
            let mut mgr = ScreeningManager::new(ScreeningConfig::new(bound, RuleKind::Sphere));
            let (l, r) = mgr.screen(&prob, &ctx, &f.engine);
            l.len() + r.len()
        };
        assert!(count(BoundKind::Pgb) >= count(BoundKind::Gb));
    }

    #[test]
    fn fixed_sphere_memo_skips_reevaluation() {
        // Under RRPB (fixed sphere within one λ) the second screening call
        // on the same problem must evaluate zero rules — every surviving
        // triplet is memoized as non-firing — and return nothing new.
        let f = fix(5);
        let l0 = f.lmax * 0.3;
        let lambda = l0 * 0.8;
        let m0 = exact_solution(&f, l0);
        let mut mgr = ScreeningManager::new(ScreeningConfig::new(BoundKind::Rrpb, RuleKind::Sphere));
        mgr.set_reference(m0, l0, 1e-9, &f.store, &f.engine);

        let mut prob = Problem::new(&f.store, f.loss, lambda);
        let mut timers = PhaseTimers::default();
        let m = Mat::zeros(4, 4);
        let ev = prob.eval(&m, &f.engine, &mut timers);
        let grad = prob.grad(&m, &ev.k);
        let (d_val, split) = prob.dual(&ev.margins, &ev.k, &mut timers);
        let ctx = ScreenCtx {
            m: &m,
            grad: &grad,
            p: ev.p,
            d: d_val,
            gap: ev.p - d_val,
            k_plus: &split.plus,
            pre_split: None,
            margins: &ev.margins,
            iter: 0,
        };
        let (l1, r1) = mgr.screen(&prob, &ctx, &f.engine);
        let evals_first = mgr.stats.rule_evals;
        assert_eq!(evals_first, f.store.len(), "first call evaluates all active");
        prob.apply_screening(&l1, &r1);

        // second call at the same λ with the same reference: zero evals
        let ev2 = prob.eval(&m, &f.engine, &mut timers);
        let grad2 = prob.grad(&m, &ev2.k);
        let (d2, split2) = prob.dual(&ev2.margins, &ev2.k, &mut timers);
        let ctx2 = ScreenCtx {
            m: &m,
            grad: &grad2,
            p: ev2.p,
            d: d2,
            gap: ev2.p - d2,
            k_plus: &split2.plus,
            pre_split: None,
            margins: &ev2.margins,
            iter: 1,
        };
        let (l2, r2) = mgr.screen(&prob, &ctx2, &f.engine);
        assert!(l2.is_empty() && r2.is_empty());
        assert_eq!(mgr.stats.rule_evals, evals_first, "memoized call re-evaluated rules");
        assert_eq!(mgr.stats.skipped, prob.active_idx().len());

        // a new reference invalidates the memo
        if !prob.active_idx().is_empty() {
            let m0b = exact_solution(&f, l0 * 0.999);
            mgr.set_reference(m0b, l0 * 0.999, 1e-9, &f.store, &f.engine);
            let (_, _) = mgr.screen(&prob, &ctx2, &f.engine);
            assert!(mgr.stats.rule_evals > evals_first, "memo not invalidated");
        }
    }
}
