//! The λ-crossing **reference frame** (paper §4 + Appendix K.1): one
//! first-class object owning everything the screening pipeline carries
//! across regularization-path steps.
//!
//! A frame is built once per reference solution `(M₀, λ₀, ε)` and holds:
//!
//! - the reference identity (`tag`, process-unique) that keys the workset
//!   reference-margin lane and the managers' no-fire memos;
//! - the shared full-store margins lane `⟨H_t, M₀⟩` (one kernel pass,
//!   consumed by every RPB/RRPB manager and the certificate derivation);
//! - per-triplet **certified λ-intervals**: ranges of λ on which a
//!   screening rule provably keeps firing, computed once per reference
//!   from the closed-form RRPB ranges (Thm 4.1 + the L-side extension)
//!   and, optionally, the DGB/GB general forms of Appendix K.1
//!   ([`crate::screening::general_range::RangeForm`]) — the union of all
//!   certificates per (triplet, side) is kept, merged into disjoint
//!   intervals;
//! - an **expiry schedule**: certificates sorted by their upper endpoint
//!   so a monotonically decreasing λ sweep touches each certificate only
//!   when it enters coverage and drops it exactly when it expires —
//!   O(entering + expiring) bookkeeping per step (plus emission of the
//!   live ids) instead of the former O(|T|) full-store
//!   interval scan per λ.
//!
//! The DGB and GB families are λ-independent certificates: the reference
//! primal `M₀` is feasible and the dual coefficients `α_t = −ℓ'(⟨M₀,H_t⟩)`
//! are dual-feasible *for every λ*, so the duality-gap and gradient
//! spheres evaluated at the reference state remain valid bounds on `M*_λ`
//! along the whole path (this is exactly what makes the §4 extension work
//! for every sphere family, not only RRPB).

use super::general_range::{general_l_range, general_r_range, RangeForm};
use super::range::{l_range, r_range, LambdaRange};
use crate::linalg::{psd_split, Mat};
use crate::loss::Loss;
use crate::runtime::Engine;
use crate::triplet::{ActiveWorkset, TripletStore};
use std::cell::RefCell;

/// Process-unique frame identities: a workset lane or a no-fire memo
/// tagged with a frame's tag can never be confused with state derived
/// from another frame (another reference, another manager, another run).
static FRAME_NONCE: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(1);

/// Which optimal-set membership a certificate fixes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CertSide {
    /// `t ∈ L*` (α* = 1)
    L,
    /// `t ∈ R*` (α* = 0)
    R,
}

/// One certified λ-interval for one triplet: for every `λ ∈ (lo, hi)` the
/// screening rule fires, so the triplet can be fixed without evaluation.
#[derive(Clone, Copy, Debug)]
pub struct Certificate {
    /// triplet id within the store the frame was built over
    pub id: u32,
    /// which optimal-set membership is fixed
    pub side: CertSide,
    /// interval lower endpoint (exclusive)
    pub lo: f64,
    /// interval upper endpoint (exclusive)
    pub hi: f64,
}

/// Which sphere families contribute certificates (Appendix K.1).
#[derive(Clone, Copy, Debug)]
pub struct CertFamilies {
    /// closed-form RRPB ranges (Thm 4.1 + L-side) — exact for the sphere
    /// rule, so they double as the managers' no-fire certificates
    pub rrpb: bool,
    /// duality-gap sphere at the reference state (one extra `wgram` +
    /// eigendecomposition per reference)
    pub dgb: bool,
    /// gradient sphere at the reference state (one extra margins pass
    /// with `K` per reference)
    pub gb: bool,
}

impl CertFamilies {
    /// Only the closed-form RRPB ranges (the cheap default).
    pub fn rrpb_only() -> CertFamilies {
        CertFamilies {
            rrpb: true,
            dgb: false,
            gb: false,
        }
    }

    /// RRPB plus the DGB/GB general forms (wider coverage, one extra
    /// `wgram` + margins pass per reference).
    pub fn all() -> CertFamilies {
        CertFamilies {
            rrpb: true,
            dgb: true,
            gb: true,
        }
    }
}

/// Admission-time outcome for one candidate triplet that is **not yet in
/// any store** (streaming pipeline): either provably inactive at the
/// query λ under the frame's RRPB closed forms — with the λ at which
/// that proof expires — or undecided, in which case the candidate must
/// be copied into the workset.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Admission {
    /// No certificate fires: admit the candidate (its rows enter the
    /// reduced problem).
    Admit,
    /// Certified into L*/R* at the query λ: reject without allocation.
    /// The proof stays valid for every λ above `expires` (the RRPB
    /// range's lower endpoint), so the candidate needs no re-test until
    /// the path crosses it.
    Certified {
        /// which optimal-set membership is fixed
        side: CertSide,
        /// lower endpoint of the certified λ-interval (clamped to ≥ 0)
        expires: f64,
    },
}

/// Mutable sweep state of the expiry schedule (interior: the frame is
/// shared read-only with the screening managers; only the path driver
/// advances the sweep, strictly monotonically in λ).
struct Sweep {
    /// next un-ingested certificate in the `hi`-descending schedule
    cursor: usize,
    /// certificates currently covering the sweep position
    covered: Vec<Certificate>,
    last_lambda: f64,
}

/// Screening reference carried across λ steps; see the module docs.
///
/// Build one per reference solution and share it (via `Rc`) across every
/// consumer. The exact λ_max solution makes an ε = 0 reference:
///
/// ```
/// use triplet_screen::prelude::*;
/// use triplet_screen::linalg::psd_project;
/// use triplet_screen::screening::{Admission, CertFamilies, ReferenceFrame};
/// use triplet_screen::solver::Problem;
/// use triplet_screen::triplet::ActiveWorkset;
///
/// let mut rng = Pcg64::seed(7);
/// let ds = synthetic::gaussian_mixture("doc", 30, 4, 2, 2.5, &mut rng);
/// let store = TripletStore::from_dataset(&ds, 2, &mut rng);
/// let engine = NativeEngine::new(1);
/// let loss = Loss::smoothed_hinge(0.05);
///
/// // exact reference at λ_max: M₀ = [ΣH]_+ / λ_max, ε = 0
/// let lambda0 = Problem::lambda_max(&store, &loss, &engine);
/// let ones = vec![1.0; store.len()];
/// let m0 = psd_project(&engine.wgram(&store.a, &store.b, &ones)).scaled(1.0 / lambda0);
/// let frame = ReferenceFrame::build(
///     m0, lambda0, 0.0, &store, &engine,
///     Some((&loss, CertFamilies::rrpb_only())),
/// );
///
/// // certificate sweep: ids provably inactive at 0.9·λ₀, no rule evals
/// let ws = ActiveWorkset::full(&store);
/// let (mut cert_l, mut cert_r) = (Vec::new(), Vec::new());
/// frame.advance(lambda0 * 0.9, &ws, &mut cert_l, &mut cert_r);
///
/// // admission query for a candidate the frame has never seen: only the
/// // scalars ⟨H, M₀⟩ and ‖H‖ are needed
/// let decision = frame.admission_decision(0.0, 1.0, lambda0 * 0.9, &loss);
/// assert!(matches!(decision, Admission::Admit | Admission::Certified { .. }));
/// ```
pub struct ReferenceFrame {
    m0: Mat,
    lambda0: f64,
    eps: f64,
    m0_norm: f64,
    tag: u64,
    /// full-store `⟨H_t, M₀⟩`
    margins: Vec<f64>,
    /// loss the certificates were derived against (None = no certificates)
    gamma: Option<f64>,
    /// exact per-triplet RRPB sphere-rule intervals (empty unless the
    /// RRPB family was derived) — `rrpb_l[t]`/`rrpb_r[t]` contain λ iff
    /// the L-/R-rule fires at λ under this reference
    rrpb_l: Vec<LambdaRange>,
    rrpb_r: Vec<LambdaRange>,
    /// entry schedule: all certificates sorted by `hi`, descending
    schedule: Vec<Certificate>,
    sweep: RefCell<Sweep>,
}

impl ReferenceFrame {
    /// Build a frame from a reference solution: one full-store margins
    /// pass, plus O(|T|) closed-form certificate derivation when `certs`
    /// is given (and one `wgram` + margins pass for the DGB/GB families).
    ///
    /// The reference is first handed through
    /// [`Engine::compress_reference`]: dense engines return it untouched
    /// with zero ε inflation, while the factored backend swaps in its
    /// rank-r reconstruction `M̃ = LᵀL` and reports the exact truncation
    /// error τ, which is folded into ε here — Thm 3.10 then keeps every
    /// rule built from this frame safe for the *dense* problem. The
    /// margins lane and the cached norm go through
    /// [`Engine::ref_margins`] / [`Engine::ref_norm`], so a factored
    /// engine serves them in O(r) per row / from the r×r Gram.
    pub fn build(
        m0: Mat,
        lambda0: f64,
        eps: f64,
        store: &TripletStore,
        engine: &dyn Engine,
        certs: Option<(&Loss, CertFamilies)>,
    ) -> ReferenceFrame {
        let (m0, eps_extra) = engine.compress_reference(m0);
        let eps = eps + eps_extra;
        let mut margins = vec![0.0; store.len()];
        engine.ref_margins(&m0, &store.a, &store.b, &mut margins);
        let m0_norm = engine.ref_norm(&m0);
        let mut frame = ReferenceFrame {
            m0,
            lambda0,
            eps,
            m0_norm,
            tag: FRAME_NONCE.fetch_add(1, std::sync::atomic::Ordering::Relaxed),
            margins,
            gamma: None,
            rrpb_l: Vec::new(),
            rrpb_r: Vec::new(),
            schedule: Vec::new(),
            sweep: RefCell::new(Sweep {
                cursor: 0,
                covered: Vec::new(),
                last_lambda: f64::INFINITY,
            }),
        };
        if let Some((loss, families)) = certs {
            frame.derive_certificates(store, engine, loss, families);
        }
        frame
    }

    /// The reference solution `M₀`.
    pub fn m0(&self) -> &Mat {
        &self.m0
    }

    /// The λ the reference was solved at.
    pub fn lambda0(&self) -> f64 {
        self.lambda0
    }

    /// The reference's accuracy certificate: `‖M₀ − M*_{λ₀}‖_F ≤ ε`.
    pub fn eps(&self) -> f64 {
        self.eps
    }

    /// Cached `‖M₀‖_F`.
    pub fn m0_norm(&self) -> f64 {
        self.m0_norm
    }

    /// Identity tag keying the workset lane and the no-fire memos.
    pub fn tag(&self) -> u64 {
        self.tag
    }

    /// Full-store `⟨H_t, M₀⟩` margins (id-indexed).
    pub fn margins(&self) -> &[f64] {
        &self.margins
    }

    /// Total certificates in the expiry schedule.
    pub fn n_certificates(&self) -> usize {
        self.schedule.len()
    }

    /// The merged certified λ-intervals, sorted by upper endpoint
    /// descending (the sweep's entry order). Read-only view for the
    /// DGB/GB-vs-RRPB certificate studies (`benches/screening.rs`,
    /// `coordinator::experiments::run_range_study`): interval widths and
    /// per-side counts are computed from these without touching the
    /// sweep state.
    pub fn certificates(&self) -> &[Certificate] {
        &self.schedule
    }

    /// Whether the frame carries *exact* RRPB sphere-rule intervals for
    /// `loss` — exact means "the rule fires at λ iff λ is inside", so a
    /// manager may treat exclusion as a no-fire proof.
    pub fn has_exact_rrpb(&self, loss: &Loss) -> bool {
        self.gamma == Some(loss.gamma) && !self.rrpb_r.is_empty()
    }

    /// Exact RRPB sphere-rule outcome at `lambda` for triplet `t` (only
    /// meaningful when [`Self::has_exact_rrpb`] holds): the side whose
    /// rule fires, or None when provably neither does.
    pub fn rrpb_sphere_decision(&self, t: usize, lambda: f64) -> Option<CertSide> {
        if self.rrpb_r[t].contains(lambda) {
            Some(CertSide::R)
        } else if self.rrpb_l[t].contains(lambda) {
            Some(CertSide::L)
        } else {
            None
        }
    }

    /// Screen a candidate at admission time from its scalar statistics
    /// alone: `hm = ⟨H, M₀⟩` and `hn = ‖H‖_F`. The closed-form RRPB
    /// ranges (Thm 4.1 + the L-side extension) need no per-triplet frame
    /// state, so this works for ids the frame has **never seen** — the
    /// miner's not-yet-admitted candidates. The reference `(M₀, λ₀, ε)`
    /// certifies the *full* problem, so the proof is sound for
    /// candidates outside the current store. R is checked first,
    /// matching [`Self::rrpb_sphere_decision`]'s precedence.
    pub fn admission_decision(&self, hm: f64, hn: f64, lambda: f64, loss: &Loss) -> Admission {
        let rr = r_range(hm, hn, self.m0_norm, self.eps, self.lambda0, loss.r_threshold());
        if rr.contains(lambda) {
            return Admission::Certified {
                side: CertSide::R,
                expires: rr.lo.max(0.0),
            };
        }
        let rl = l_range(hm, hn, self.m0_norm, self.eps, self.lambda0, loss.l_threshold());
        if rl.contains(lambda) {
            return Admission::Certified {
                side: CertSide::L,
                expires: rl.lo.max(0.0),
            };
        }
        Admission::Admit
    }

    /// Certified admission over an approximate margin `hm ± env` (the
    /// mixed-precision tier: `hm` from the f32 admission pre-pass, `env`
    /// its [`crate::screening::bounds::eps_round`] envelope).
    ///
    /// At fixed λ the RRPB rules act on the scaled margin
    /// `((λ₀+λ)/2λ)·hm` against a radius independent of `hm`, so the
    /// decision regions in `hm` are the ordered intervals Certified-L /
    /// Admit / Certified-R; as in
    /// [`crate::screening::rules::sphere_rule_enveloped`], agreement of
    /// [`Self::admission_decision`] at the interval's two endpoints
    /// certifies the exact-f64 decision on the whole interval. `None`
    /// means the true margin may straddle a boundary: the caller must
    /// promote the candidate to an exact f64 margin before deciding.
    ///
    /// On an agreeing Certified pair, the reported `expires` is the
    /// **max** of the endpoints' expiries: the R-range's lower endpoint
    /// is non-increasing in `hm` and the L-range's non-decreasing, so
    /// the max bounds the true expiry from above — the certificate is
    /// dropped no later than the exact path would drop it (conservative,
    /// never unsafe).
    pub fn admission_decision_enveloped(
        &self,
        hm: f64,
        hn: f64,
        lambda: f64,
        loss: &Loss,
        env: f64,
    ) -> Option<Admission> {
        debug_assert!(env >= 0.0, "envelope must be >= 0, got {env}");
        let lo = self.admission_decision(hm - env, hn, lambda, loss);
        let hi = self.admission_decision(hm + env, hn, lambda, loss);
        match (lo, hi) {
            (Admission::Admit, Admission::Admit) => Some(Admission::Admit),
            (
                Admission::Certified {
                    side: sl,
                    expires: el,
                },
                Admission::Certified {
                    side: sh,
                    expires: eh,
                },
            ) if sl == sh => Some(Admission::Certified {
                side: sl,
                expires: el.max(eh),
            }),
            _ => None,
        }
    }

    /// Advance the certificate sweep to `lambda` (strictly below the
    /// previous call's λ) and emit the ids certified at `lambda` into
    /// `out_l`/`out_r`, skipping ids already retired from `active`.
    /// Returns the number of certificates *entering or expiring* in this
    /// step — the incremental bookkeeping cost recorded in path
    /// telemetry. (Emitting the live certificates is additionally
    /// O(live), proportional to the ids actually handed out, a cost the
    /// former full-scan pipeline paid on top of its O(|T|) scan too.)
    pub fn advance(
        &self,
        lambda: f64,
        active: &ActiveWorkset,
        out_l: &mut Vec<usize>,
        out_r: &mut Vec<usize>,
    ) -> usize {
        self.advance_filtered(lambda, Some(active), out_l, out_r)
    }

    /// Like [`Self::advance`] but emits **every** live certified id at
    /// `lambda`, regardless of workset state. The persistent-problem
    /// retarget ([`crate::solver::Problem::retarget_lambda`]) consumes
    /// this as the λ's full coverage set: covered ids stay retired across
    /// the λ crossing (their rows are never re-copied), everything else
    /// is revived into the reduced problem.
    pub fn advance_covered(
        &self,
        lambda: f64,
        out_l: &mut Vec<usize>,
        out_r: &mut Vec<usize>,
    ) -> usize {
        self.advance_filtered(lambda, None, out_l, out_r)
    }

    fn advance_filtered(
        &self,
        lambda: f64,
        active: Option<&ActiveWorkset>,
        out_l: &mut Vec<usize>,
        out_r: &mut Vec<usize>,
    ) -> usize {
        out_l.clear();
        out_r.clear();
        let mut sw = self.sweep.borrow_mut();
        debug_assert!(
            lambda < sw.last_lambda,
            "frame sweep must move to strictly smaller λ ({} -> {lambda})",
            sw.last_lambda
        );
        sw.last_lambda = lambda;
        let mut work = 0usize;
        while sw.cursor < self.schedule.len() && self.schedule[sw.cursor].hi > lambda {
            let c = self.schedule[sw.cursor];
            sw.cursor += 1;
            work += 1;
            // an interval the sweep jumped over entirely (lo ≥ λ already)
            // never becomes live
            if c.lo < lambda {
                sw.covered.push(c);
            }
        }
        let live_before = sw.covered.len();
        sw.covered.retain(|c| c.lo < lambda);
        work += live_before - sw.covered.len(); // expired this step
        for c in &sw.covered {
            // soundness net for non-monotone misuse in release builds
            // (the debug_assert above): never emit outside (lo, hi)
            if c.hi <= lambda {
                continue;
            }
            let id = c.id as usize;
            if active.is_some_and(|ws| !ws.is_active(id)) {
                continue;
            }
            match c.side {
                CertSide::L => out_l.push(id),
                CertSide::R => out_r.push(id),
            }
        }
        work
    }

    /// Derive the certified λ-intervals and build the expiry schedule.
    fn derive_certificates(
        &mut self,
        store: &TripletStore,
        engine: &dyn Engine,
        loss: &Loss,
        fam: CertFamilies,
    ) {
        let n = store.len();
        assert!(n < u32::MAX as usize, "triplet count exceeds certificate id space");
        self.gamma = Some(loss.gamma);
        let thr_l = loss.l_threshold();
        let thr_r = loss.r_threshold();

        // Shared DGB/GB aggregates from the reference state (App K.1).
        // The dual-feasible α_t = −ℓ'(⟨M₀,H_t⟩) and K = Σ α_t H_t do not
        // depend on λ, so one wgram (+ one margins pass with K for GB)
        // certifies the whole path.
        let mut hk: Vec<f64> = Vec::new();
        let mut dgb: Option<(f64, f64, f64)> = None; // (‖M₀‖², L_p + L_d, ‖[K]_+‖)
        let mut gb: Option<(f64, f64, f64)> = None; // (‖M₀‖², ⟨Ξ,M₀⟩, ‖Ξ‖²)
        if fam.dgb || fam.gb {
            let alphas: Vec<f64> = self.margins.iter().map(|&m| loss.alpha(m)).collect();
            let k = engine.wgram(&store.a, &store.b, &alphas);
            let m_norm_sq = self.m0.norm_sq();
            if fam.gb {
                hk = vec![0.0; n];
                engine.margins(&k, &store.a, &store.b, &mut hk);
                // Ξ = Σ ℓ'(⟨M₀,H_t⟩)·H_t = −K, so ∇P_λ(M₀) = λM₀ + Ξ
                gb = Some((m_norm_sq, -k.dot(&self.m0), k.norm_sq()));
            }
            if fam.dgb {
                // full-problem gap at (M₀, α): r²(λ) = ‖M₀‖² + 2L/λ + ‖[K]_+‖²/λ²
                let l_p: f64 = self.margins.iter().map(|&m| loss.value(m)).sum();
                let l_d: f64 = alphas.iter().map(|&a| loss.conjugate(a)).sum();
                let k_plus_norm = psd_split(&k).plus.norm();
                dgb = Some((m_norm_sq, l_p + l_d, k_plus_norm));
            }
        }

        if fam.rrpb {
            self.rrpb_l.reserve(n);
            self.rrpb_r.reserve(n);
        }
        let mut l_ints: Vec<LambdaRange> = Vec::new();
        let mut r_ints: Vec<LambdaRange> = Vec::new();
        for t in 0..n {
            let (hm, hn) = (self.margins[t], store.h_norm[t]);
            l_ints.clear();
            r_ints.clear();
            if fam.rrpb {
                let rl = l_range(hm, hn, self.m0_norm, self.eps, self.lambda0, thr_l);
                let rr = r_range(hm, hn, self.m0_norm, self.eps, self.lambda0, thr_r);
                self.rrpb_l.push(rl);
                self.rrpb_r.push(rr);
                l_ints.push(rl);
                r_ints.push(rr);
            }
            if let Some((mn_sq, l_sum, k_norm)) = dgb {
                let form = RangeForm::dgb(hm, mn_sq, l_sum, k_norm, hn);
                l_ints.extend(general_l_range(&form, thr_l));
                r_ints.extend(general_r_range(&form, thr_r));
            }
            if let Some((mn_sq, xi_m, xi_norm_sq)) = gb {
                let form = RangeForm::gb(hm, -hk[t], mn_sq, xi_m, xi_norm_sq, hn);
                l_ints.extend(general_l_range(&form, thr_l));
                r_ints.extend(general_r_range(&form, thr_r));
            }
            push_merged(&mut self.schedule, t, CertSide::L, &mut l_ints);
            push_merged(&mut self.schedule, t, CertSide::R, &mut r_ints);
        }
        // entry schedule: upper endpoints descending, so the decreasing-λ
        // sweep ingests exactly the certificates it has reached
        self.schedule
            .sort_by(|a, b| b.hi.partial_cmp(&a.hi).unwrap());
    }
}

/// Merge the (individually sound, possibly overlapping) intervals for one
/// (triplet, side) into disjoint certificates and append them to `out`.
fn push_merged(out: &mut Vec<Certificate>, id: usize, side: CertSide, ints: &mut Vec<LambdaRange>) {
    ints.retain(|r| !r.is_empty() && r.hi > 0.0);
    if ints.is_empty() {
        return;
    }
    ints.sort_by(|a, b| a.lo.partial_cmp(&b.lo).unwrap());
    let mut cur = ints[0];
    for r in ints[1..].iter() {
        if r.lo < cur.hi {
            // overlapping certified intervals: the union is certified
            cur.hi = cur.hi.max(r.hi);
        } else {
            out.push(Certificate {
                id: id as u32,
                side,
                lo: cur.lo.max(0.0),
                hi: cur.hi,
            });
            cur = *r;
        }
    }
    out.push(Certificate {
        id: id as u32,
        side,
        lo: cur.lo.max(0.0),
        hi: cur.hi,
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::runtime::NativeEngine;
    use crate::util::rng::Pcg64;

    fn fixture() -> (TripletStore, Mat, NativeEngine) {
        let mut rng = Pcg64::seed(21);
        let ds = synthetic::gaussian_mixture("g", 40, 4, 2, 2.6, &mut rng);
        let store = TripletStore::from_dataset(&ds, 3, &mut rng);
        let mut base = Mat::from_fn(4, 4, |_, _| rng.normal());
        base.symmetrize();
        let m0 = crate::linalg::psd_project(&base).scaled(0.5);
        (store, m0, NativeEngine::new(2))
    }

    /// RRPB-only frame: the schedule sweep must emit exactly the ids the
    /// closed-form intervals contain at every λ of a decreasing grid —
    /// parity with the former per-λ full-store scan.
    #[test]
    fn sweep_matches_direct_interval_checks() {
        let (store, m0, engine) = fixture();
        let loss = Loss::smoothed_hinge(0.05);
        let (l0, eps) = (3.0, 1e-3);
        let frame = ReferenceFrame::build(
            m0.clone(),
            l0,
            eps,
            &store,
            &engine,
            Some((&loss, CertFamilies::rrpb_only())),
        );
        let mut hm = vec![0.0; store.len()];
        engine.margins(&m0, &store.a, &store.b, &mut hm);
        let mn = m0.norm();
        let ws = ActiveWorkset::full(&store);
        let (mut rl, mut rr) = (Vec::new(), Vec::new());
        let mut lam = l0;
        for _ in 0..25 {
            lam *= 0.9;
            frame.advance(lam, &ws, &mut rl, &mut rr);
            for t in 0..store.len() {
                let hn = store.h_norm[t];
                let want_r = r_range(hm[t], hn, mn, eps, l0, loss.r_threshold()).contains(lam);
                let want_l = l_range(hm[t], hn, mn, eps, l0, loss.l_threshold()).contains(lam);
                assert_eq!(rr.contains(&t), want_r, "R mismatch t={t} λ={lam}");
                assert_eq!(rl.contains(&t), want_l, "L mismatch t={t} λ={lam}");
            }
        }
    }

    /// Retired ids must never be emitted again, even while their
    /// certificates are still live.
    #[test]
    fn advance_skips_retired_ids() {
        let (store, m0, engine) = fixture();
        let loss = Loss::smoothed_hinge(0.05);
        let frame = ReferenceFrame::build(
            m0,
            3.0,
            1e-3,
            &store,
            &engine,
            Some((&loss, CertFamilies::rrpb_only())),
        );
        let mut ws = ActiveWorkset::full(&store);
        for id in 0..store.len() / 2 {
            ws.retire(id);
        }
        let (mut rl, mut rr) = (Vec::new(), Vec::new());
        let mut lam = 3.0;
        for _ in 0..10 {
            lam *= 0.85;
            frame.advance(lam, &ws, &mut rl, &mut rr);
            for &t in rl.iter().chain(rr.iter()) {
                assert!(ws.is_active(t), "retired id {t} emitted at λ={lam}");
            }
        }
    }

    /// Adding the DGB/GB general-form families can only widen coverage.
    #[test]
    fn general_families_only_widen() {
        let (store, m0, engine) = fixture();
        let loss = Loss::smoothed_hinge(0.05);
        let (l0, eps) = (3.0, 1e-3);
        let narrow = ReferenceFrame::build(
            m0.clone(),
            l0,
            eps,
            &store,
            &engine,
            Some((&loss, CertFamilies::rrpb_only())),
        );
        let wide = ReferenceFrame::build(
            m0,
            l0,
            eps,
            &store,
            &engine,
            Some((&loss, CertFamilies::all())),
        );
        assert!(wide.n_certificates() >= narrow.n_certificates());
        let ws = ActiveWorkset::full(&store);
        let (mut nl, mut nr) = (Vec::new(), Vec::new());
        let (mut wl, mut wr) = (Vec::new(), Vec::new());
        let mut lam = l0;
        for _ in 0..20 {
            lam *= 0.9;
            narrow.advance(lam, &ws, &mut nl, &mut nr);
            wide.advance(lam, &ws, &mut wl, &mut wr);
            for &t in &nl {
                assert!(wl.contains(&t), "L coverage lost for t={t} at λ={lam}");
            }
            for &t in &nr {
                assert!(wr.contains(&t), "R coverage lost for t={t} at λ={lam}");
            }
        }
    }

    /// `advance_covered` must emit exactly the filtered sweep's ids plus
    /// the retired ones — the coverage set the persistent problem keys
    /// its stay-retired decisions on.
    #[test]
    fn advance_covered_supersets_filtered_sweep() {
        let (store, m0, engine) = fixture();
        let loss = Loss::smoothed_hinge(0.05);
        let build = || {
            ReferenceFrame::build(
                m0.clone(),
                3.0,
                1e-3,
                &store,
                &engine,
                Some((&loss, CertFamilies::rrpb_only())),
            )
        };
        // two identical frames: each owns its own sweep cursor
        let filtered = build();
        let covered = build();
        let mut ws = ActiveWorkset::full(&store);
        for id in 0..store.len() / 3 {
            ws.retire(id);
        }
        let (mut fl, mut fr) = (Vec::new(), Vec::new());
        let (mut cl, mut cr) = (Vec::new(), Vec::new());
        let mut lam = 3.0;
        for _ in 0..12 {
            lam *= 0.88;
            let w1 = filtered.advance(lam, &ws, &mut fl, &mut fr);
            let w2 = covered.advance_covered(lam, &mut cl, &mut cr);
            assert_eq!(w1, w2, "sweep bookkeeping diverged at λ={lam}");
            for &t in fl.iter() {
                assert!(cl.contains(&t), "filtered L id {t} missing from coverage");
            }
            for &t in fr.iter() {
                assert!(cr.contains(&t), "filtered R id {t} missing from coverage");
            }
            // everything extra in the coverage set is a retired id
            for &t in cl.iter().chain(cr.iter()) {
                assert!(
                    ws.is_active(t) || t < store.len() / 3,
                    "coverage emitted unexpected id {t}"
                );
            }
        }
    }

    /// Admission decisions agree with the closed-form ranges — and the
    /// expiry endpoint is the range's lower bound, so a rejected
    /// candidate needs no re-test until the path crosses it.
    #[test]
    fn admission_decision_matches_ranges() {
        let (store, m0, engine) = fixture();
        let loss = Loss::smoothed_hinge(0.05);
        let (l0, eps) = (2.5, 1e-3);
        let frame = ReferenceFrame::build(m0.clone(), l0, eps, &store, &engine, None);
        let mut hm = vec![0.0; store.len()];
        engine.margins(&m0, &store.a, &store.b, &mut hm);
        let mn = m0.norm();
        let mut certified = 0usize;
        for t in 0..store.len() {
            let hn = store.h_norm[t];
            for k in 1..=10 {
                let lam = l0 * 0.95f64.powi(k);
                let rr = r_range(hm[t], hn, mn, eps, l0, loss.r_threshold());
                let rl = l_range(hm[t], hn, mn, eps, l0, loss.l_threshold());
                let got = frame.admission_decision(hm[t], hn, lam, &loss);
                if rr.contains(lam) {
                    assert_eq!(
                        got,
                        Admission::Certified {
                            side: CertSide::R,
                            expires: rr.lo.max(0.0),
                        }
                    );
                    certified += 1;
                } else if rl.contains(lam) {
                    assert_eq!(
                        got,
                        Admission::Certified {
                            side: CertSide::L,
                            expires: rl.lo.max(0.0),
                        }
                    );
                    certified += 1;
                } else {
                    assert_eq!(got, Admission::Admit);
                }
            }
        }
        assert!(certified > 0, "fixture produced no certified candidates");
    }

    /// The enveloped admission either certifies the exact decision for
    /// every margin in `hm ± env` (checked by dense sampling) or
    /// abstains — and a certified expiry is never below the true one.
    #[test]
    fn enveloped_admission_certifies_exactly_or_abstains() {
        let (store, m0, engine) = fixture();
        let loss = Loss::smoothed_hinge(0.05);
        let (l0, eps) = (2.5, 1e-3);
        let frame = ReferenceFrame::build(m0.clone(), l0, eps, &store, &engine, None);
        let mut hm = vec![0.0; store.len()];
        engine.margins(&m0, &store.a, &store.b, &mut hm);
        let (mut agreed, mut abstained) = (0usize, 0usize);
        for t in 0..store.len() {
            let hn = store.h_norm[t];
            for k in 1..=8 {
                let lam = l0 * 0.93f64.powi(k);
                // envelopes from tiny (realistic) to huge (forces overlap
                // with a boundary somewhere in the fixture)
                for env in [1e-9, 1e-3, 0.3] {
                    let got = frame.admission_decision_enveloped(hm[t], hn, lam, &loss, env);
                    let exact = frame.admission_decision(hm[t], hn, lam, &loss);
                    match got {
                        None => {
                            abstained += 1;
                            // abstention must come from genuine endpoint
                            // disagreement
                            let lo = frame.admission_decision(hm[t] - env, hn, lam, &loss);
                            let hi = frame.admission_decision(hm[t] + env, hn, lam, &loss);
                            assert_ne!(lo, hi, "abstained on agreeing endpoints");
                        }
                        Some(Admission::Admit) => {
                            agreed += 1;
                            assert_eq!(exact, Admission::Admit);
                            // dense interior sample: every margin admits
                            for s in 0..=8 {
                                let m = hm[t] - env + 2.0 * env * (s as f64 / 8.0);
                                assert_eq!(
                                    frame.admission_decision(m, hn, lam, &loss),
                                    Admission::Admit
                                );
                            }
                        }
                        Some(Admission::Certified { side, expires }) => {
                            agreed += 1;
                            let Admission::Certified {
                                side: es,
                                expires: ee,
                            } = exact
                            else {
                                panic!("certified {side:?} but exact admits (t={t})");
                            };
                            assert_eq!(side, es);
                            // conservative: never expires later than the
                            // exact certificate claims to last
                            assert!(
                                expires >= ee - 1e-15,
                                "expiry {expires} below exact {ee}"
                            );
                        }
                    }
                }
            }
        }
        assert!(agreed > 0, "fixture never certified an enveloped decision");
        assert!(abstained > 0, "fixture never forced a promotion");
    }

    /// The exact RRPB decision helper agrees with the closed forms.
    #[test]
    fn rrpb_decision_matches_ranges() {
        let (store, m0, engine) = fixture();
        let loss = Loss::smoothed_hinge(0.05);
        let frame = ReferenceFrame::build(
            m0.clone(),
            2.0,
            1e-4,
            &store,
            &engine,
            Some((&loss, CertFamilies::rrpb_only())),
        );
        assert!(frame.has_exact_rrpb(&loss));
        assert!(!frame.has_exact_rrpb(&Loss::smoothed_hinge(0.1)));
        let mut hm = vec![0.0; store.len()];
        engine.margins(&m0, &store.a, &store.b, &mut hm);
        let mn = m0.norm();
        for t in 0..store.len() {
            for k in 1..=12 {
                let lam = 2.0 * k as f64 / 12.0;
                let hn = store.h_norm[t];
                let want = if r_range(hm[t], hn, mn, 1e-4, 2.0, 1.0).contains(lam) {
                    Some(CertSide::R)
                } else if l_range(hm[t], hn, mn, 1e-4, 2.0, 0.95).contains(lam) {
                    Some(CertSide::L)
                } else {
                    None
                };
                assert_eq!(frame.rrpb_sphere_decision(t, lam), want);
            }
        }
    }
}
