//! Sphere bounds (paper §3.2): regions guaranteed to contain `M*`.
//!
//! Each constructor returns a [`Sphere`] `{Q, r}` with `‖M* − Q‖_F ≤ r`.
//! Derivations are referenced next to each function; the geometric
//! relations the paper proves (PGB ⊆ GB, RPB ⊆ DGB at the optimum,
//! PGB = RPB at the optimum) are asserted in the test suite.
//!
//! This module also owns the mixed-precision tier's rounding envelope
//! [`eps_round`]: the certified forward-error bound that, added to a
//! rule's effective radius (equivalently: evaluating the rule at both
//! endpoints of `m̂ ± ε_round`), makes an f32 screening statistic safe —
//! see `docs/PAPER_MAP.md` for the derivation and the per-rule mapping.

use crate::linalg::{psd_split, Mat, PsdSplit};

/// Unit roundoff of IEEE-754 binary32 (`2⁻²⁴`) — the `u` of the
/// [`eps_round`] forward-error bound.
pub const F32_UNIT_ROUNDOFF: f64 = 5.960_464_477_539_062_5e-8;

/// Certified rounding envelope of one f32 margin evaluation
/// `m̂ = fl₃₂(aᵀQa − bᵀQb)`:
///
/// `ε_round(d, ‖Q‖_F, xsq) = γ_n · ‖Q‖_F · xsq`, with
/// `γ_n = n·u/(1 − n·u)`, `u = 2⁻²⁴`, `n = 2d + 16`, and
/// `xsq = ‖a‖² + ‖b‖²` (the data norms the store/batch already holds).
///
/// Why this bounds `|m̂ − m|`: each quad form is a GEMV (every `y_i`
/// sums `d` products) followed by a length-`d` dot, so its longest
/// sequential accumulation chain has `2d + 2` rounded operations; the
/// standard forward-error bound (Higham, *Accuracy and Stability of
/// Numerical Algorithms*, §3.1) then gives
/// `|fl(aᵀQa) − aᵀQa| ≤ γ_{2d+2}·Σ_{ij}|a_i||Q_ij||a_j|`, and by
/// Cauchy–Schwarz `Σ_{ij}|a_i||Q_ij||a_j| ≤ ‖a‖²·‖Q‖_F`. The slack of
/// `n = 2d + 16` over `2d + 2` absorbs the f64→f32 input conversions
/// (one relative `u` per operand), the final subtraction of the two
/// quad forms, the f64 reference's own (2⁻⁵³-scale) error, and the
/// SIMD lane split (which only *shortens* chains). The envelope is
/// monotone in `d`, `‖Q‖_F`, and `xsq` by construction — inflating a
/// radius with it can never tighten a bound — and saturates to
/// `+∞` once `n·u ≥ 1` (d ≈ 8.4M, far past any metric-learning
/// dimension), which degrades to "promote everything", still safe.
pub fn eps_round(d: usize, q_norm: f64, xsq: f64) -> f64 {
    let nu = (2 * d + 16) as f64 * F32_UNIT_ROUNDOFF;
    if nu >= 1.0 {
        return f64::INFINITY;
    }
    let gamma = nu / (1.0 - nu);
    gamma * q_norm * xsq
}

/// A Frobenius-norm ball `{X : ‖X − Q‖_F ≤ r}` containing `M*`.
#[derive(Clone, Debug)]
pub struct Sphere {
    /// center `Q`
    pub q: Mat,
    /// radius `r ≥ 0`
    pub r: f64,
    /// true when `Q ⪰ O` by construction (enables the cheap min-eig path
    /// in the SDLS rule, §3.1.2)
    pub psd_center: bool,
}

impl Sphere {
    /// Wrap a center/radius pair (radius must be finite and ≥ 0).
    pub fn new(q: Mat, r: f64, psd_center: bool) -> Sphere {
        debug_assert!(r.is_finite() && r >= 0.0, "radius must be >= 0, got {r}");
        Sphere { q, r, psd_center }
    }

    /// Does the sphere contain `X`? (tests)
    pub fn contains(&self, x: &Mat) -> bool {
        x.sub(&self.q).norm() <= self.r * (1.0 + 1e-12) + 1e-12
    }
}

/// **GB** (Thm 3.2). For any feasible `M ⪰ O`:
/// center `M − ∇P_λ(M)/(2λ)`, radius `‖∇P_λ(M)‖_F/(2λ)`.
pub fn gb(m: &Mat, grad: &Mat, lambda: f64) -> Sphere {
    let gn = grad.norm();
    let mut q = m.clone();
    q.axpy(-0.5 / lambda, grad);
    Sphere::new(q, 0.5 * gn / lambda, false)
}

/// **PGB** (Thm 3.3): project the GB center onto the PSD cone;
/// `r² = r_GB² − ‖[Q^GB]_−‖²`. Returns the sphere together with the split
/// of the GB center — the `[Q^GB]_−` part doubles as the supporting
/// hyperplane `P = −[Q^GB]_−` for the linear rule (§3.1.3, Fig 3a).
pub fn pgb(m: &Mat, grad: &Mat, lambda: f64) -> (Sphere, PsdSplit) {
    let g = gb(m, grad, lambda);
    let split = psd_split(&g.q);
    let r_sq = (g.r * g.r - split.minus_norm_sq).max(0.0);
    (Sphere::new(split.plus.clone(), r_sq.sqrt(), true), split)
}

/// **DGB** (Thm 3.5): center = the primal feasible `M`,
/// `r = sqrt(2·gap/λ)` where gap = `P_λ(M) − D_λ(α, Γ)`.
pub fn dgb(m: &Mat, gap: f64, lambda: f64) -> Sphere {
    Sphere::new(m.clone(), (2.0 * gap.max(0.0) / lambda).sqrt(), true)
}

/// **CDGB** (Thm 3.6): center = the dual iterate `M_λ(α) = [K]_+/λ`,
/// `r = sqrt(G_D(α)/λ)` with `G_D(α) = P_λ(M_λ(α)) − D_λ(α)` — the caller
/// provides that gap (it requires one extra primal evaluation at the dual
/// iterate; the √2-smaller radius is the payoff).
pub fn cdgb(k_plus: &Mat, gap_at_dual: f64, lambda: f64) -> Sphere {
    let center = k_plus.scaled(1.0 / lambda);
    Sphere::new(center, (gap_at_dual.max(0.0) / lambda).sqrt(), true)
}

/// **RPB** (Thm 3.7): given the *optimal* `M₀*` at λ₀, for λ₁:
/// center `((λ₀+λ₁)/2λ₁)·M₀*`, radius `(|λ₀−λ₁|/2λ₁)·‖M₀*‖`.
pub fn rpb(m0_star: &Mat, lambda0: f64, lambda1: f64) -> Sphere {
    rpb_with_norm(m0_star, m0_star.norm(), lambda0, lambda1)
}

/// [`rpb`] with the reference norm supplied by the caller — the frame
/// caches `‖M₀‖` once (under the factored backend it comes from the
/// r×r Gram via `Engine::ref_norm`, never a d×d pass), so per-λ sphere
/// construction touches no d×d object beyond the O(d²) center scaling.
pub fn rpb_with_norm(m0_star: &Mat, m0_norm: f64, lambda0: f64, lambda1: f64) -> Sphere {
    let c = (lambda0 + lambda1) / (2.0 * lambda1);
    let r = (lambda0 - lambda1).abs() / (2.0 * lambda1) * m0_norm;
    Sphere::new(m0_star.scaled(c), r, true)
}

/// **RRPB** (Thm 3.10): RPB with an approximate reference
/// `‖M₀* − M₀‖ ≤ ε`:
/// center `((λ₀+λ₁)/2λ₁)·M₀`, radius
/// `(|λ₀−λ₁|/2λ₁)‖M₀‖ + ((|λ₀−λ₁|+λ₀+λ₁)/2λ₁)·ε`.
pub fn rrpb(m0: &Mat, eps: f64, lambda0: f64, lambda1: f64) -> Sphere {
    rrpb_with_norm(m0, m0.norm(), eps, lambda0, lambda1)
}

/// [`rrpb`] with the reference norm supplied by the caller (see
/// [`rpb_with_norm`]). Under the factored backend the frame's ε already
/// carries the compression error τ — Thm 3.10 makes no assumption about
/// *why* the reference is ε-approximate, so the same radius formula
/// covers truncation and solver inexactness uniformly.
pub fn rrpb_with_norm(m0: &Mat, m0_norm: f64, eps: f64, lambda0: f64, lambda1: f64) -> Sphere {
    let dl = (lambda0 - lambda1).abs();
    let c = (lambda0 + lambda1) / (2.0 * lambda1);
    let r = dl / (2.0 * lambda1) * m0_norm + (dl + lambda0 + lambda1) / (2.0 * lambda1) * eps;
    Sphere::new(m0.scaled(c), r, true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::loss::Loss;
    use crate::runtime::NativeEngine;
    use crate::solver::{Problem, Solver, SolverConfig};
    use crate::triplet::TripletStore;
    use crate::util::rng::Pcg64;
    use crate::util::timer::PhaseTimers;

    struct Fixture {
        store: TripletStore,
        loss: Loss,
        lmax: f64,
    }

    fn fixture(seed: u64) -> Fixture {
        let mut rng = Pcg64::seed(seed);
        let ds = synthetic::gaussian_mixture("g", 40, 4, 2, 2.6, &mut rng);
        let store = TripletStore::from_dataset(&ds, 3, &mut rng);
        let loss = Loss::smoothed_hinge(0.05);
        let engine = NativeEngine::new(2);
        let lmax = Problem::lambda_max(&store, &loss, &engine);
        Fixture { store, loss, lmax }
    }

    fn solve(f: &Fixture, lambda: f64, tol: f64) -> Mat {
        let engine = NativeEngine::new(2);
        let mut prob = Problem::new(&f.store, f.loss, lambda);
        let solver = Solver::new(SolverConfig {
            tol,
            tol_relative: false,
            ..Default::default()
        });
        let (m, stats) = solver.solve(&mut prob, &engine, Mat::zeros(f.store.d, f.store.d), None);
        assert!(stats.converged);
        m
    }

    /// All bounds must contain a near-exact optimum when built from a
    /// rough iterate — the fundamental safety property.
    #[test]
    fn all_bounds_contain_optimum() {
        let f = fixture(1);
        let engine = NativeEngine::new(2);
        let lambda = f.lmax * 0.3;
        let m_star = solve(&f, lambda, 1e-11);

        // rough reference: a few iterations only
        let mut prob = Problem::new(&f.store, f.loss, lambda);
        let rough_solver = Solver::new(SolverConfig {
            tol: 1e-2,
            tol_relative: false,
            max_iters: 50,
            ..Default::default()
        });
        let (m_rough, _) =
            rough_solver.solve(&mut prob, &engine, Mat::zeros(f.store.d, f.store.d), None);

        let mut timers = PhaseTimers::default();
        let ev = prob.eval(&m_rough, &engine, &mut timers);
        let grad = prob.grad(&m_rough, &ev.k);
        let (d_val, split) = prob.dual(&ev.margins, &ev.k, &mut timers);
        let gap = ev.p - d_val;

        let s_gb = gb(&m_rough, &grad, lambda);
        assert!(s_gb.contains(&m_star), "GB violated");
        let (s_pgb, _) = pgb(&m_rough, &grad, lambda);
        assert!(s_pgb.contains(&m_star), "PGB violated");
        let s_dgb = dgb(&m_rough, gap, lambda);
        assert!(s_dgb.contains(&m_star), "DGB violated");

        // CDGB: gap at the dual iterate
        let center = split.plus.scaled(1.0 / lambda);
        let ev_c = prob.eval(&center, &engine, &mut timers);
        let s_cdgb = cdgb(&split.plus, ev_c.p - d_val, lambda);
        assert!(s_cdgb.contains(&m_star), "CDGB violated");
    }

    #[test]
    fn pgb_tighter_than_gb() {
        let f = fixture(2);
        let engine = NativeEngine::new(2);
        let lambda = f.lmax * 0.2;
        let mut prob = Problem::new(&f.store, f.loss, lambda);
        let (m, _) = Solver::new(SolverConfig {
            tol: 1e-3,
            tol_relative: false,
            ..Default::default()
        })
        .solve(&mut prob, &engine, Mat::zeros(4, 4), None);
        let mut timers = PhaseTimers::default();
        let ev = prob.eval(&m, &engine, &mut timers);
        let grad = prob.grad(&m, &ev.k);
        let (s_pgb, _) = pgb(&m, &grad, lambda);
        let s_gb = gb(&m, &grad, lambda);
        assert!(s_pgb.r <= s_gb.r + 1e-15);
    }

    /// Thm 3.8: at the previous-λ optimum, PGB (with the dual subgradient)
    /// coincides with RPB — center and radius.
    #[test]
    fn pgb_equals_rpb_at_optimum() {
        let f = fixture(3);
        let engine = NativeEngine::new(2);
        let l0 = f.lmax * 0.5;
        let l1 = l0 * 0.8;
        let m0 = solve(&f, l0, 1e-12);

        // ∇P_{λ1}(M0*) with the dual-variable subgradient = λ1·M0* − K(M0*)
        let prob1 = Problem::new(&f.store, f.loss, l1);
        let mut timers = PhaseTimers::default();
        let ev = prob1.eval(&m0, &engine, &mut timers);
        let grad = prob1.grad(&m0, &ev.k);

        let (s_pgb, _) = pgb(&m0, &grad, l1);
        let s_rpb = rpb(&m0, l0, l1);
        assert!(
            s_pgb.q.sub(&s_rpb.q).max_abs() < 1e-6 * (1.0 + s_rpb.q.max_abs()),
            "centers differ"
        );
        assert!(
            (s_pgb.r - s_rpb.r).abs() < 1e-6 * (1.0 + s_rpb.r),
            "radii differ: PGB={} RPB={}",
            s_pgb.r,
            s_rpb.r
        );
    }

    /// Thm 3.9: at the previous-λ optimum, r_DGB = 2·r_RPB and the RPB
    /// ball is inside the DGB ball.
    #[test]
    fn dgb_twice_rpb_at_optimum() {
        let f = fixture(4);
        let engine = NativeEngine::new(2);
        let l0 = f.lmax * 0.5;
        let l1 = l0 * 0.7;
        let m0 = solve(&f, l0, 1e-12);

        let prob1 = Problem::new(&f.store, f.loss, l1);
        let mut timers = PhaseTimers::default();
        let ev = prob1.eval(&m0, &engine, &mut timers);
        let (d_val, _) = prob1.dual(&ev.margins, &ev.k, &mut timers);
        let gap = ev.p - d_val;

        let s_dgb = dgb(&m0, gap, l1);
        let s_rpb = rpb(&m0, l0, l1);
        assert!(
            (s_dgb.r - 2.0 * s_rpb.r).abs() < 1e-5 * (1.0 + s_dgb.r),
            "r_DGB={} vs 2 r_RPB={}",
            s_dgb.r,
            2.0 * s_rpb.r
        );
        // center distance = r_RPB (Appendix I) => inclusion
        let cd = s_dgb.q.sub(&s_rpb.q).norm();
        assert!((cd - s_rpb.r).abs() < 1e-5 * (1.0 + s_rpb.r));
        assert!(cd + s_rpb.r <= s_dgb.r + 1e-9);
    }

    /// RRPB must contain the λ1 optimum when built from an ε-accurate λ0
    /// solution; and with ε = 0 it reduces to RPB.
    #[test]
    fn rrpb_contains_next_optimum() {
        let f = fixture(5);
        let l0 = f.lmax * 0.4;
        let l1 = l0 * 0.6;
        let m0_star = solve(&f, l0, 1e-12);
        let m1_star = solve(&f, l1, 1e-11);

        // perturb the reference by a known amount
        let mut rng = Pcg64::seed(99);
        let mut noise = Mat::from_fn(4, 4, |_, _| rng.normal());
        noise.symmetrize();
        noise.scale(1e-3 / noise.norm());
        let m0 = m0_star.add(&noise);
        let eps = m0.sub(&m0_star).norm() * 1.0001;

        let s = rrpb(&m0, eps, l0, l1);
        assert!(s.contains(&m1_star), "RRPB violated");

        let s0 = rrpb(&m0_star, 0.0, l0, l1);
        let sr = rpb(&m0_star, l0, l1);
        assert!((s0.r - sr.r).abs() < 1e-12);
        assert!(s0.q.sub(&sr.q).max_abs() < 1e-12);
    }

    /// Thm 3.4 / convergence: bounds built at (near-)optimal references
    /// have (near-)zero radius — DGB/CDGB via the gap, PGB via Thm 3.4.
    #[test]
    fn radii_vanish_at_optimum() {
        let f = fixture(6);
        let engine = NativeEngine::new(2);
        let lambda = f.lmax * 0.3;
        let m_star = solve(&f, lambda, 1e-12);
        let prob = Problem::new(&f.store, f.loss, lambda);
        let mut timers = PhaseTimers::default();
        let ev = prob.eval(&m_star, &engine, &mut timers);
        let grad = prob.grad(&m_star, &ev.k);
        let (d_val, _) = prob.dual(&ev.margins, &ev.k, &mut timers);
        let gap = (ev.p - d_val).max(0.0);

        let scale = m_star.norm().max(1.0);
        assert!(dgb(&m_star, gap, lambda).r < 1e-4 * scale);
        let (s_pgb, _) = pgb(&m_star, &grad, lambda);
        assert!(s_pgb.r < 1e-4 * scale, "PGB radius {}", s_pgb.r);
        // GB radius does NOT vanish in general (Thm 3.4 discussion)
        let s_gb = gb(&m_star, &grad, lambda);
        assert!(s_gb.r >= s_pgb.r);
    }

    #[test]
    fn eps_round_positive_finite_and_scaled() {
        let e = eps_round(300, 2.0, 5.0);
        assert!(e > 0.0 && e.is_finite());
        // γ_n ≈ n·u at these sizes: within 1% of the first-order value
        let nu = (2.0 * 300.0 + 16.0) * F32_UNIT_ROUNDOFF;
        assert!((e - nu * 2.0 * 5.0).abs() < 0.01 * e);
        // homogeneous in both norms
        assert!((eps_round(300, 4.0, 5.0) - 2.0 * e).abs() < 1e-18);
        assert!((eps_round(300, 2.0, 10.0) - 2.0 * e).abs() < 1e-18);
        // zero data ⇒ zero envelope (still never negative)
        assert_eq!(eps_round(300, 0.0, 5.0), 0.0);
    }

    #[test]
    fn eps_round_monotone_and_saturating() {
        // monotone in d — the inflation can only grow with chain length
        let mut prev = 0.0;
        for d in [1usize, 8, 64, 300, 512, 768, 10_000] {
            let e = eps_round(d, 1.0, 1.0);
            assert!(e >= prev, "not monotone at d={d}");
            prev = e;
        }
        // n·u ≥ 1 degrades to +∞ (promote everything) instead of a
        // bogus finite bound
        assert_eq!(eps_round(usize::MAX / 4, 1.0, 1.0), f64::INFINITY);
    }
}
