//! Sphere rule with the exact semi-definite constraint (paper §3.1.2).
//!
//! Per triplet, decide emptiness of
//! `{X : ⟨X,H⟩ ⋛ C} ∩ B(Q, r) ∩ PSD` by solving the *Semi-Definite Least
//! Squares* problem (Malick [20])
//!
//!   min ‖X − Q‖_F²  s.t.  ⟨X, H⟩ = C,  X ⪰ O                     (SDLS)
//!
//! through its one-dimensional dual
//!
//!   D(y) = −‖[Q + yH]_+‖_F² + 2Cy + ‖Q‖_F²,
//!
//! ascending in `y`. Weak duality gives the early stop: the moment
//! `D(y) > r²` the hyperplane cannot meet `B ∩ PSD`, and — provided an
//! anchor `X0 ∈ B ∩ PSD` sits strictly on the screening side — the whole
//! feasible set does, so the triplet is screened.
//!
//! When the center is PSD, `Q + yH` has at most one negative eigenvalue
//! (H has exactly one), so `[·]_+` needs only the minimum eigenpair
//! (Lanczos, O(d²) per step) instead of a full O(d³) decomposition — the
//! cost asymmetry the paper reports between PGB+SDLS and GB+SDLS.

use crate::linalg::{min_eigpair, psd_split, Mat};

/// One SDLS screening query.
pub struct SdlsQuery<'a> {
    /// sphere center
    pub q: &'a Mat,
    /// cached `‖Q‖_F²`
    pub q_norm_sq: f64,
    /// is `q` PSD by construction? (enables the min-eig fast path)
    pub psd_center: bool,
    /// squared sphere radius
    pub r_sq: f64,
    /// triplet difference rows: `H = a a^T − b b^T`
    pub a: &'a [f64],
    /// same-class difference row (the `− b bᵀ` part of `H`)
    pub b: &'a [f64],
    /// `⟨H, Q⟩` (from the margins pass with Q)
    pub hq: f64,
    /// `‖H‖_F`
    pub hn: f64,
    /// `⟨H, X0⟩` for a point `X0 ∈ B ∩ PSD` (the feasibility anchor; for
    /// PSD centers simply `hq`)
    pub hx0: f64,
}

/// Evaluate `(φ(y), ‖[Z]_+‖²)` at `Z = Q + yH` where `φ = ⟨[Z]_+, H⟩`.
fn eval_plus(query: &SdlsQuery, y: f64) -> (f64, f64) {
    let d = query.q.rows();
    // Z = Q + y(aa^T − bb^T)
    let mut z = query.q.clone();
    for i in 0..d {
        let (ai, bi) = (query.a[i], query.b[i]);
        let row = z.row_mut(i);
        for j in 0..d {
            row[j] += y * (ai * query.a[j] - bi * query.b[j]);
        }
    }
    let z_hq = query.hq + y * query.hn * query.hn; // ⟨Z, H⟩
    let z_nsq = query.q_norm_sq + 2.0 * y * query.hq + y * y * query.hn * query.hn;
    if query.psd_center {
        // at most one negative eigenvalue: [Z]_+ = Z − λ_min v v^T
        let (lam, v) = min_eigpair(&z, 1e-9, 32);
        if lam >= 0.0 {
            (z_hq, z_nsq)
        } else {
            let av: f64 = query.a.iter().zip(&v).map(|(x, y)| x * y).sum();
            let bv: f64 = query.b.iter().zip(&v).map(|(x, y)| x * y).sum();
            let vhv = av * av - bv * bv;
            (z_hq - lam * vhv, z_nsq - lam * lam)
        }
    } else {
        let split = psd_split(&z);
        let plus_nsq = split.plus.norm_sq();
        // φ = a^T [Z]_+ a − b^T [Z]_+ b
        let phi = split.plus.quad_form(query.a) - split.plus.quad_form(query.b);
        (phi, plus_nsq)
    }
}

/// Dual value `D(y)` from an `eval_plus` result.
#[inline]
fn dual_value(query: &SdlsQuery, y: f64, plus_nsq: f64, c: f64) -> f64 {
    -plus_nsq + 2.0 * c * y + query.q_norm_sq
}

/// Can the triplet be screened to the `⟨X,H⟩ > c` side (R* when `c = 1`)?
///
/// Safe: returns true only when `D(y) > r²` was certified for some `y`
/// *and* the anchor satisfies `⟨X0,H⟩ > c`.
pub fn sdls_screens_r(query: &SdlsQuery, c: f64, max_iter: usize) -> bool {
    if !(query.hx0 > c) || query.hn <= 0.0 {
        return false;
    }
    ascend(query, c, max_iter)
}

/// Can the triplet be screened to the `⟨X,H⟩ < c` side (L* when `c = 1−γ`)?
pub fn sdls_screens_l(query: &SdlsQuery, c: f64, max_iter: usize) -> bool {
    if !(query.hx0 < c) || query.hn <= 0.0 {
        return false;
    }
    ascend(query, c, max_iter)
}

/// Maximize `D(y)`; return true iff some iterate certifies `D(y) > r²`.
fn ascend(query: &SdlsQuery, c: f64, max_iter: usize) -> bool {
    let hn_sq = query.hn * query.hn;
    // start at the PSD-unconstrained optimum: y* = (c − hq)/‖H‖².
    let mut y = (c - query.hq) / hn_sq;
    let (mut phi, mut plus_nsq) = eval_plus(query, y);
    if dual_value(query, y, plus_nsq, c) > query.r_sq {
        return true;
    }
    // If Z(y*) is PSD the dual is maximized there (D'(y*) = 2(c − φ) = 0
    // exactly when the projection is inactive) — nothing more to gain.
    if (phi - c).abs() <= 1e-9 * (1.0 + c.abs()) {
        return false;
    }
    // secant ascent on g(y) = φ(y) − c  (φ is nondecreasing; D concave)
    let mut y_prev = y;
    let mut g_prev = phi - c;
    // second point: move against the sign of g with the unconstrained slope
    y = y_prev - g_prev / hn_sq;
    for _ in 0..max_iter {
        let (phi_y, pn) = eval_plus(query, y);
        phi = phi_y;
        plus_nsq = pn;
        if dual_value(query, y, plus_nsq, c) > query.r_sq {
            return true;
        }
        let g = phi - c;
        if g.abs() <= 1e-10 * (1.0 + c.abs()) {
            break; // converged: final D is the best certificate we get
        }
        let denom = g - g_prev;
        let step = if denom.abs() > 1e-300 {
            g * (y - y_prev) / denom
        } else {
            g / hn_sq
        };
        y_prev = y;
        g_prev = g;
        y -= step;
        if !y.is_finite() {
            break;
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn unit_query<'a>(
        q: &'a Mat,
        a: &'a [f64],
        b: &'a [f64],
        r: f64,
        psd_center: bool,
    ) -> SdlsQuery<'a> {
        let h = Mat::outer(a).sub(&Mat::outer(b));
        let hq = q.dot(&h);
        SdlsQuery {
            q,
            q_norm_sq: q.norm_sq(),
            psd_center,
            r_sq: r * r,
            a,
            b,
            hq,
            hn: h.norm(),
            hx0: hq,
        }
    }

    #[test]
    fn agrees_with_sphere_rule_when_psd_inactive() {
        // Q comfortably PSD and far inside the cone: the PSD constraint
        // never binds, SDLS min distance = ((hq − c)/hn)², so the decision
        // must match the plain sphere rule.
        let mut rng = Pcg64::seed(1);
        for _ in 0..20 {
            let d = 4;
            let mut base = Mat::from_fn(d, d, |_, _| rng.normal() * 0.1);
            base.symmetrize();
            let q = Mat::identity(d).scaled(5.0).add(&base); // strongly PSD
            let a: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..d).map(|_| rng.normal() * 0.2).collect();
            let query = unit_query(&q, &a, &b, 0.3, true);
            let c = 1.0;
            if query.hq <= c {
                continue;
            }
            let sphere_fires = query.hq - 0.3 * query.hn > c;
            let sdls_fires = sdls_screens_r(&query, c, 40);
            // SDLS can only be stronger; when the constraint is inactive
            // and the sphere fires, SDLS must fire too.
            if sphere_fires {
                assert!(sdls_fires, "SDLS weaker than sphere on inactive-PSD case");
            }
        }
    }

    #[test]
    fn stronger_than_sphere_near_cone_boundary() {
        // Center ON the cone boundary, H pointing so that the sphere cap
        // below the hyperplane lies outside the cone: sphere rule fails,
        // SDLS screens.
        // Q = diag(2, 0); H = e2 e2^T (a = e2, b = 0): ⟨X,H⟩ = X_22 ≥ 0 on
        // the cone. Take c = -0.5: every PSD X has ⟨X,H⟩ ≥ 0 > c... use
        // the L-side: screen ⟨X,H⟩ < c with c = −0.5 impossible; instead
        // test R-side with c small negative — any X in B∩PSD has
        // ⟨X,H⟩ ≥ 0 > c, while the sphere alone dips to −r‖H‖ < c.
        let q = Mat::from_rows(2, 2, vec![2.0, 0.0, 0.0, 0.0]);
        let a = [0.0, 1.0];
        let b = [0.0, 0.0];
        let r = 1.0;
        let query = unit_query(&q, &a, &b, r, true);
        let c = -0.5;
        // sphere min = hq − r·hn = 0 − 1 = −1 < c: sphere rule cannot screen
        assert!(query.hq - r * query.hn < c);
        // SDLS must certify: {⟨X,H⟩ = −0.5} ∩ PSD = ∅ entirely
        assert!(sdls_screens_r(&query, c, 40));
    }

    #[test]
    fn l_side_screens() {
        // Q strongly PSD with hq far below c and a small sphere: the
        // hyperplane ⟨X,H⟩ = c stays out of reach.
        let q = Mat::identity(3).scaled(0.1);
        let a = [1.0, 0.0, 0.0];
        let b = [0.0, 1.0, 0.0];
        // hq = 0.1 − 0.1 = 0
        let query = unit_query(&q, &a, &b, 0.2, true);
        let c = 0.95;
        assert!(query.hq < c);
        assert!(sdls_screens_l(&query, c, 40));
        // with a huge radius it must refuse
        let query_wide = unit_query(&q, &a, &b, 5.0, true);
        assert!(!sdls_screens_l(&query_wide, c, 40));
    }

    #[test]
    fn anchor_precondition_blocks_wrong_side() {
        let q = Mat::identity(3).scaled(2.0);
        let a = [1.0, 0.0, 0.0];
        let b = [0.0, 0.1, 0.0];
        let query = unit_query(&q, &a, &b, 0.01, true);
        // hq ≈ 2 > 1: R-side ok, L-side must refuse immediately
        assert!(query.hq > 1.0);
        assert!(!sdls_screens_l(&query, 0.95, 40));
    }

    #[test]
    fn non_psd_center_full_eig_path() {
        // GB-style center with a negative eigenvalue: the full-eig branch
        // must still certify clear cases.
        let q = Mat::from_rows(2, 2, vec![3.0, 0.0, 0.0, -0.5]);
        let a = [1.0, 0.0];
        let b = [0.0, 0.0];
        // hq = 3; H = e1e1^T; sphere r = 0.5 → sphere min = 3 − 0.5 = 2.5 > 1
        let query = unit_query(&q, &a, &b, 0.5, false);
        assert!(sdls_screens_r(&query, 1.0, 40));
    }

    #[test]
    fn dual_never_exceeds_primal_distance() {
        // weak duality audit: for random feasible instances where we can
        // find SOME X with ⟨X,H⟩ = c, X PSD, the certified D(y) at the
        // converged point must be ≤ ‖X − Q‖² for that witness.
        let mut rng = Pcg64::seed(7);
        for _ in 0..20 {
            let d = 3;
            let mut base = Mat::from_fn(d, d, |_, _| rng.normal());
            base.symmetrize();
            let q = crate::linalg::psd_project(&base).add(&Mat::identity(d).scaled(0.2));
            let a: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..d).map(|_| rng.normal() * 0.3).collect();
            let h = Mat::outer(&a).sub(&Mat::outer(&b));
            // witness: X = t·aa^T with ⟨X,H⟩ = t(‖a‖⁴ − (a·b)²)... choose c from it
            let t = 0.7;
            let x = Mat::outer(&a).scaled(t);
            let c = x.dot(&h);
            let dist_sq = x.sub(&q).norm_sq();
            let query = SdlsQuery {
                q: &q,
                q_norm_sq: q.norm_sq(),
                psd_center: true,
                r_sq: dist_sq * 0.999, // witness is *outside* the sphere…
                a: &a,
                b: &b,
                hq: q.dot(&h),
                hn: h.norm(),
                hx0: q.dot(&h),
            };
            // …so screening may or may not fire, but if it fires with
            // r_sq >= dist_sq that would contradict weak duality:
            let query_big = SdlsQuery {
                r_sq: dist_sq * 1.001,
                ..query
            };
            let side_ok_r = query_big.hx0 > c;
            let side_ok_l = query_big.hx0 < c;
            if side_ok_r {
                assert!(
                    !sdls_screens_r(&query_big, c, 60),
                    "screened despite witness inside sphere"
                );
            } else if side_ok_l {
                assert!(!sdls_screens_l(&query_big, c, 60));
            }
        }
    }
}
