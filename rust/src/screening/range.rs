//! Range-based extension (paper §4, Thm 4.1): intervals of λ on which a
//! triplet's screening rule is guaranteed to keep holding, evaluated from
//! one RRPB reference solution `M₀` (accuracy ε) at λ₀.
//!
//! For the R-rule with threshold `c_r` (paper: 2 = 2·c_r with c_r = 1) the
//! sphere rule under the RRPB sphere becomes, after clearing 2λ:
//!
//!   λ ≤ λ₀:  (λ+λ₀)·hm − (λ₀−λ)·mn·hn − 2λ₀ε·hn > 2λ·c_r
//!   λ ≥ λ₀:  (λ+λ₀)·hm − (λ−λ₀)·mn·hn − 2λε·hn  > 2λ·c_r
//!
//! with `hm = ⟨H,M₀⟩`, `hn = ‖H‖`, `mn = ‖M₀‖` — linear in λ, so each side
//! yields a closed-form endpoint (Appendix K.2). The L-side (threshold
//! `c_l = 1−γ`, rule `hq + r·hn < c_l`) follows by the same algebra; the
//! paper derives only the R-side, the L-side is our §8 extension and is
//! verified against brute-force rule evaluation in the tests.

/// A (possibly empty / half-open) λ interval `(lo, hi)`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LambdaRange {
    /// lower endpoint (exclusive)
    pub lo: f64,
    /// upper endpoint (exclusive)
    pub hi: f64,
}

impl LambdaRange {
    /// The canonical empty interval (`lo > hi`).
    pub const EMPTY: LambdaRange = LambdaRange {
        lo: f64::INFINITY,
        hi: f64::NEG_INFINITY,
    };

    /// Whether no λ satisfies the interval.
    pub fn is_empty(&self) -> bool {
        !(self.lo < self.hi)
    }

    /// Strict interior membership: `lo < λ < hi`.
    pub fn contains(&self, lambda: f64) -> bool {
        self.lo < lambda && lambda < self.hi
    }
}

/// R-side range (Thm 4.1): λ interval on which the RRPB sphere rule
/// certifies `t ∈ R*`. `c_r` is the zero-part threshold (1 for both
/// losses). Returns EMPTY when the validity condition fails.
pub fn r_range(hm: f64, hn: f64, mn: f64, eps: f64, lambda0: f64, c_r: f64) -> LambdaRange {
    // λ ≤ λ₀ branch: λ·(hm + mn·hn − 2c_r) > λ₀·(mn·hn − hm + 2ε·hn)
    let denom_a = hm + mn * hn - 2.0 * c_r;
    if denom_a <= 0.0 {
        // Thm 4.1 validity condition (⟨H,M₀⟩ − 2 + ‖H‖‖M₀‖ > 0) fails:
        // the rule cannot hold anywhere below λ₀ — and the λ ≥ λ₀ branch
        // needs the rule at λ₀ itself, which this also excludes.
        return LambdaRange::EMPTY;
    }
    let lo = lambda0 * (mn * hn - hm + 2.0 * eps * hn) / denom_a;
    // λ ≥ λ₀ branch: λ·(mn·hn − hm + 2ε·hn + 2c_r) < λ₀·(mn·hn + hm)
    let denom_b = mn * hn - hm + 2.0 * eps * hn + 2.0 * c_r;
    let hi = if denom_b > 0.0 {
        lambda0 * (mn * hn + hm) / denom_b
    } else {
        f64::INFINITY // cannot happen for c_r > 0 by Cauchy–Schwarz, kept safe
    };
    LambdaRange { lo, hi }
}

/// L-side range (our extension of Thm 4.1): λ interval on which the RRPB
/// sphere rule certifies `t ∈ L*`. `c_l = 1 − γ`.
pub fn l_range(hm: f64, hn: f64, mn: f64, eps: f64, lambda0: f64, c_l: f64) -> LambdaRange {
    if c_l <= 0.0 {
        return LambdaRange::EMPTY;
    }
    // λ ≤ λ₀ branch: (λ+λ₀)hm + (λ₀−λ)mn·hn + 2λ₀ε·hn < 2λ·c_l
    //   ⇔ λ·(hm − mn·hn − 2c_l) < −λ₀·(hm + mn·hn + 2ε·hn)
    // coefficient is < 0 (hm ≤ mn·hn by C-S, c_l > 0), so dividing flips:
    let denom_a = mn * hn - hm + 2.0 * c_l;
    debug_assert!(denom_a > 0.0);
    let lo = lambda0 * (hm + mn * hn + 2.0 * eps * hn) / denom_a;
    // λ ≥ λ₀ branch: λ·(hm + mn·hn + 2ε·hn − 2c_l) < λ₀·(mn·hn − hm)
    let denom_b = hm + mn * hn + 2.0 * eps * hn - 2.0 * c_l;
    let hi = if denom_b > 0.0 {
        lambda0 * (mn * hn - hm) / denom_b
    } else {
        f64::INFINITY // rule holds for every λ ≥ λ₀
    };
    LambdaRange { lo, hi }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::screening::bounds::rrpb;
    use crate::util::quickcheck::forall;
    use crate::util::rng::Pcg64;

    /// Brute-force check: does the RRPB sphere rule fire at λ?
    fn rule_fires_r(m0: &Mat, h: &Mat, eps: f64, l0: f64, l: f64, c_r: f64) -> bool {
        let s = rrpb(m0, eps, l0, l);
        s.q.dot(h) - s.r * h.norm() > c_r
    }

    fn rule_fires_l(m0: &Mat, h: &Mat, eps: f64, l0: f64, l: f64, c_l: f64) -> bool {
        let s = rrpb(m0, eps, l0, l);
        s.q.dot(h) + s.r * h.norm() < c_l
    }

    fn random_case(rng: &mut Pcg64) -> (Mat, Mat, f64, f64) {
        let d = 2 + rng.below(4);
        let mut base = Mat::from_fn(d, d, |_, _| rng.normal());
        base.symmetrize();
        let m0 = crate::linalg::psd_project(&base).scaled(rng.uniform() * 2.0 + 0.1);
        let a: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..d).map(|_| rng.normal() * rng.uniform()).collect();
        let h = Mat::outer(&a).sub(&Mat::outer(&b));
        let eps = rng.uniform() * 0.01;
        let l0 = rng.uniform() * 10.0 + 0.5;
        (m0, h, eps, l0)
    }

    #[test]
    fn r_range_matches_bruteforce() {
        forall("r-range", 64, |rng| {
            let (m0, h, eps, l0) = random_case(rng);
            let (hm, hn, mn) = (m0.dot(&h), h.norm(), m0.norm());
            let range = r_range(hm, hn, mn, eps, l0, 1.0);
            // sample λ across (0.05 λ₀, 20 λ₀): range membership must
            // exactly match direct rule evaluation
            for k in 1..=40 {
                let l = l0 * 0.05 * k as f64;
                let fires = rule_fires_r(&m0, &h, eps, l0, l, 1.0);
                let inside = range.contains(l);
                if fires != inside {
                    // boundary ties allowed within float tolerance
                    let near = (l - range.lo).abs() < 1e-6 * l0.max(range.lo.abs())
                        || (l - range.hi).abs() < 1e-6 * l0.max(range.hi.abs());
                    if !near {
                        return Err(format!(
                            "λ={l}: fires={fires} inside={inside} range={range:?}"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn l_range_matches_bruteforce() {
        forall("l-range", 64, |rng| {
            let (m0, h, eps, l0) = random_case(rng);
            let (hm, hn, mn) = (m0.dot(&h), h.norm(), m0.norm());
            let c_l = 0.95;
            let range = l_range(hm, hn, mn, eps, l0, c_l);
            for k in 1..=40 {
                let l = l0 * 0.05 * k as f64;
                let fires = rule_fires_l(&m0, &h, eps, l0, l, c_l);
                let inside = range.contains(l);
                if fires != inside {
                    let near = (l - range.lo).abs() < 1e-6 * l0.max(range.lo.abs())
                        || (l - range.hi).abs() < 1e-6 * l0.max(range.hi.abs());
                    if !near {
                        return Err(format!(
                            "λ={l}: fires={fires} inside={inside} range={range:?}"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn empty_range_when_validity_fails() {
        // hm + mn·hn ≤ 2: denominator nonpositive → EMPTY
        let r = r_range(0.1, 1.0, 1.0, 0.0, 5.0, 1.0);
        assert!(r.is_empty());
    }

    #[test]
    fn wider_eps_shrinks_ranges() {
        let (hm, hn, mn, l0) = (8.0, 2.0, 3.0, 4.0);
        let tight = r_range(hm, hn, mn, 0.0, l0, 1.0);
        let loose = r_range(hm, hn, mn, 0.1, l0, 1.0);
        assert!(!tight.is_empty());
        assert!(loose.lo >= tight.lo);
        assert!(loose.hi <= tight.hi);
        let tight_l = l_range(0.01, hn, mn, 0.0, l0, 0.95);
        let loose_l = l_range(0.01, hn, mn, 0.1, l0, 0.95);
        assert!(loose_l.lo >= tight_l.lo);
        assert!(loose_l.hi <= tight_l.hi);
    }

    #[test]
    fn range_contains_semantics() {
        let r = LambdaRange { lo: 1.0, hi: 2.0 };
        assert!(r.contains(1.5));
        assert!(!r.contains(1.0));
        assert!(!r.contains(2.0));
        assert!(LambdaRange::EMPTY.is_empty());
    }
}
