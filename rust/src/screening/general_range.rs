//! General-form range extension (paper §4 + Appendix K.1).
//!
//! Every sphere the paper derives can be written with a center affine in
//! `1/λ` and a squared radius quadratic in `1/λ`:
//!
//!   Q(λ)  = A + B·(1/λ),        r²(λ) = a + b·(1/λ) + c·(1/λ²).
//!
//! Appendix K.1 gives the coefficients for GB, DGB, RPB and RRPB. The
//! R-side sphere rule `⟨H,Q⟩ − r‖H‖ > c_r` is then equivalent to the
//! intersection of one linear and one quadratic inequality in `u = 1/λ`
//! (§4), which this module solves in closed form — so a *range of λ* can
//! be certified for **any** of those bounds, not only RRPB (Thm 4.1 is
//! recovered as a special case, which the tests assert).
//!
//! With `hq(u) = ⟨H,A⟩ + ⟨H,B⟩·u =: p + q·u` and threshold `c`:
//!
//!   R-rule  ⟺  p + q·u − c > 0   ∧  (p + q·u − c)² > ‖H‖²(a + b·u + c₂u²)
//!   L-rule  ⟺  c − p − q·u > 0   ∧  (c − p − q·u)² > ‖H‖²(a + b·u + c₂u²)
//!
//! Both reduce to: linear side condition ∧ quadratic `αu² + βu + γ > 0`.

use super::range::LambdaRange;

/// Sphere family with center `A + B/λ` and radius² `a + b/λ + c/λ²`,
/// pre-contracted against one triplet: `p = ⟨H,A⟩`, `q = ⟨H,B⟩`.
#[derive(Clone, Copy, Debug)]
pub struct RangeForm {
    /// `⟨H, A⟩` — the constant part of the center contraction
    pub p: f64,
    /// `⟨H, B⟩` — the `1/λ` part of the center contraction
    pub q: f64,
    /// radius² constant coefficient
    pub a: f64,
    /// radius² `1/λ` coefficient
    pub b: f64,
    /// radius² `1/λ²` coefficient
    pub c: f64,
    /// `‖H‖_F²`
    pub hn_sq: f64,
}

impl RangeForm {
    /// DGB coefficients (Appendix K.1) for a *fixed* primal/dual reference
    /// `(M, α)`: center = M (no 1/λ part), radius² = ‖M‖² + 2·L/λ + K²/λ²
    /// where `L = Σ(ℓ + ℓ*)` and `K = ‖Σ α_t H_t + Γ‖`.
    pub fn dgb(hm: f64, m_norm_sq: f64, l_sum: f64, k_norm: f64, hn: f64) -> RangeForm {
        RangeForm {
            p: hm,
            q: 0.0,
            a: m_norm_sq,
            b: 2.0 * l_sum,
            c: k_norm * k_norm,
            hn_sq: hn * hn,
        }
    }

    /// GB coefficients (Appendix K.1) for a fixed reference `M` with loss
    /// subgradient aggregate `Ξ = Σ Ξ_t` (note `∇P = Ξ + λM`):
    /// center = M/2 − Ξ/(2λ), radius² = ‖M‖²/4 + ⟨Ξ,M⟩/(2λ) + ‖Ξ‖²/(4λ²).
    pub fn gb(hm: f64, hxi: f64, m_norm_sq: f64, xi_m: f64, xi_norm_sq: f64, hn: f64) -> RangeForm {
        RangeForm {
            p: 0.5 * hm,
            q: -0.5 * hxi,
            a: 0.25 * m_norm_sq,
            b: 0.5 * xi_m,
            c: 0.25 * xi_norm_sq,
            hn_sq: hn * hn,
        }
    }

    /// RRPB coefficients for the λ ≤ λ₀ branch (Appendix K.1):
    /// center = M₀/2 + (λ₀/2)·M₀/λ,
    /// radius = −‖M₀‖/2 + (λ₀‖M₀‖/2 + λ₀ε)/λ  (nonnegative on the branch).
    /// The radius is affine in u, so radius² has
    /// a = ‖M₀‖²/4, b = −‖M₀‖·(λ₀‖M₀‖/2 + λ₀ε), c = (λ₀‖M₀‖/2 + λ₀ε)².
    pub fn rrpb_low(hm0: f64, m0_norm: f64, eps: f64, lambda0: f64, hn: f64) -> RangeForm {
        let s = lambda0 * m0_norm / 2.0 + lambda0 * eps;
        RangeForm {
            p: 0.5 * hm0,
            q: 0.5 * lambda0 * hm0,
            a: 0.25 * m0_norm * m0_norm,
            b: -m0_norm * s,
            c: s * s,
            hn_sq: hn * hn,
        }
    }
}

/// Solve `αu² + βu + γ > 0` for `u > 0`, returning up to two open
/// u-intervals (ascending).
fn quad_positive(alpha: f64, beta: f64, gamma: f64) -> Vec<(f64, f64)> {
    const INF: f64 = f64::INFINITY;
    if alpha.abs() < 1e-300 {
        if beta.abs() < 1e-300 {
            return if gamma > 0.0 { vec![(0.0, INF)] } else { vec![] };
        }
        let root = -gamma / beta;
        return if beta > 0.0 {
            vec![(root.max(0.0), INF)]
        } else if root > 0.0 {
            vec![(0.0, root)]
        } else {
            vec![]
        };
    }
    let disc = beta * beta - 4.0 * alpha * gamma;
    if disc <= 0.0 {
        return if alpha > 0.0 { vec![(0.0, INF)] } else { vec![] };
    }
    let sq = disc.sqrt();
    let (r1, r2) = {
        let x1 = (-beta - sq) / (2.0 * alpha);
        let x2 = (-beta + sq) / (2.0 * alpha);
        (x1.min(x2), x1.max(x2))
    };
    if alpha > 0.0 {
        // positive outside the roots
        let mut out = Vec::new();
        if r1 > 0.0 {
            out.push((0.0, r1));
        }
        out.push((r2.max(0.0), INF));
        out
    } else {
        // positive between the roots
        if r2 <= 0.0 {
            vec![]
        } else {
            vec![(r1.max(0.0), r2)]
        }
    }
}

fn intersect(a: (f64, f64), b: (f64, f64)) -> Option<(f64, f64)> {
    let lo = a.0.max(b.0);
    let hi = a.1.min(b.1);
    (lo < hi).then_some((lo, hi))
}

/// λ ranges certifying the R-rule (`min > c_r`) for the sphere family.
/// Returns intervals in λ (converted from u = 1/λ), merged & ascending.
pub fn general_r_range(f: &RangeForm, c_r: f64) -> Vec<LambdaRange> {
    solve(f, c_r, true)
}

/// λ ranges certifying the L-rule (`max < c_l`).
pub fn general_l_range(f: &RangeForm, c_l: f64) -> Vec<LambdaRange> {
    solve(f, c_l, false)
}

fn solve(f: &RangeForm, thr: f64, r_side: bool) -> Vec<LambdaRange> {
    // signed margin s(u) = ±(p + q·u − thr) must be positive
    let (s0, s1) = if r_side {
        (f.p - thr, f.q)
    } else {
        (thr - f.p, -f.q)
    };
    // linear side condition s0 + s1·u > 0 on u > 0
    let side: (f64, f64) = if s1.abs() < 1e-300 {
        if s0 > 0.0 {
            (0.0, f64::INFINITY)
        } else {
            return vec![];
        }
    } else {
        let root = -s0 / s1;
        if s1 > 0.0 {
            (root.max(0.0), f64::INFINITY)
        } else if root > 0.0 {
            (0.0, root)
        } else {
            return vec![];
        }
    };
    // quadratic condition s(u)² − hn²·r²(u) > 0
    let alpha = s1 * s1 - f.hn_sq * f.c;
    let beta = 2.0 * s0 * s1 - f.hn_sq * f.b;
    let gamma = s0 * s0 - f.hn_sq * f.a;
    let mut out = Vec::new();
    for qi in quad_positive(alpha, beta, gamma) {
        if let Some((ulo, uhi)) = intersect(qi, side) {
            // u = 1/λ: (ulo, uhi) -> λ ∈ (1/uhi, 1/ulo)
            let lo = if uhi.is_infinite() { 0.0 } else { 1.0 / uhi };
            let hi = if ulo <= 0.0 { f64::INFINITY } else { 1.0 / ulo };
            if lo < hi {
                out.push(LambdaRange { lo, hi });
            }
        }
    }
    out.sort_by(|x, y| x.lo.partial_cmp(&y.lo).unwrap());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::screening::bounds::rrpb;
    use crate::screening::range::r_range;
    use crate::util::quickcheck::forall;
    use crate::util::rng::Pcg64;

    fn random_case(rng: &mut Pcg64) -> (Mat, Mat, f64, f64) {
        let d = 2 + rng.below(4);
        let mut base = Mat::from_fn(d, d, |_, _| rng.normal());
        base.symmetrize();
        let m0 = crate::linalg::psd_project(&base).scaled(rng.uniform() * 2.0 + 0.1);
        let a: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..d).map(|_| rng.normal() * rng.uniform()).collect();
        let h = Mat::outer(&a).sub(&Mat::outer(&b));
        let eps = rng.uniform() * 0.01;
        let l0 = rng.uniform() * 10.0 + 0.5;
        (m0, h, eps, l0)
    }

    /// On the λ ≤ λ₀ branch, the general solver must reproduce Thm 4.1's
    /// closed form (our specialized `r_range`).
    #[test]
    fn recovers_thm41_below_lambda0() {
        forall("general-vs-thm41", 64, |rng| {
            let (m0, h, eps, l0) = random_case(rng);
            let (hm, hn, mn) = (m0.dot(&h), h.norm(), m0.norm());
            let special = r_range(hm, hn, mn, eps, l0, 1.0);
            let form = RangeForm::rrpb_low(hm, mn, eps, l0, hn);
            let general = general_r_range(&form, 1.0);
            // compare membership on a grid of λ ≤ λ₀
            for k in 1..=30 {
                let lam = l0 * k as f64 / 30.0;
                let want = special.contains(lam) && lam <= l0;
                let got = general.iter().any(|r| r.contains(lam)) && lam <= l0;
                if want != got {
                    let near = (lam - special.lo).abs() < 1e-6 * l0
                        || (lam - special.hi).abs() < 1e-6 * l0;
                    if !near {
                        return Err(format!(
                            "λ={lam}: thm41={want} general={got} (special {special:?}, general {general:?})"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    /// The general ranges must match brute-force rule evaluation for the
    /// RRPB sphere on its valid branch.
    #[test]
    fn matches_bruteforce_rrpb() {
        forall("general-range-brute", 48, |rng| {
            let (m0, h, eps, l0) = random_case(rng);
            let (hm, hn, mn) = (m0.dot(&h), h.norm(), m0.norm());
            let form = RangeForm::rrpb_low(hm, mn, eps, l0, hn);
            let ranges = general_r_range(&form, 1.0);
            for k in 1..=30 {
                let lam = l0 * k as f64 / 30.0; // λ ≤ λ₀ branch only
                let s = rrpb(&m0, eps, l0, lam);
                let fires = s.q.dot(&h) - s.r * h.norm() > 1.0;
                let inside = ranges.iter().any(|r| r.contains(lam));
                if fires != inside {
                    let near = ranges.iter().any(|r| {
                        (lam - r.lo).abs() < 1e-6 * l0 || (lam - r.hi).abs() < 1e-6 * l0
                    });
                    if !near {
                        return Err(format!("λ={lam}: fires={fires} inside={inside}"));
                    }
                }
            }
            Ok(())
        });
    }

    /// The general L-side ranges must match brute-force rule evaluation
    /// for the RRPB sphere on its valid branch (mirror of
    /// `matches_bruteforce_rrpb` for `general_l_range`).
    #[test]
    fn l_side_matches_bruteforce_rrpb() {
        forall("general-l-range-brute", 48, |rng| {
            let (m0, h, eps, l0) = random_case(rng);
            let (hm, hn, mn) = (m0.dot(&h), h.norm(), m0.norm());
            let form = RangeForm::rrpb_low(hm, mn, eps, l0, hn);
            let c_l = 0.95;
            let ranges = general_l_range(&form, c_l);
            for k in 1..=30 {
                let lam = l0 * k as f64 / 30.0; // λ ≤ λ₀ branch only
                let s = rrpb(&m0, eps, l0, lam);
                let fires = s.q.dot(&h) + s.r * h.norm() < c_l;
                let inside = ranges.iter().any(|r| r.contains(lam));
                if fires != inside {
                    let near = ranges.iter().any(|r| {
                        (lam - r.lo).abs() < 1e-6 * l0 || (lam - r.hi).abs() < 1e-6 * l0
                    });
                    if !near {
                        return Err(format!("λ={lam}: fires={fires} inside={inside}"));
                    }
                }
            }
            Ok(())
        });
    }

    /// The GB range form must match brute-force evaluation of the GB
    /// sphere rule at every λ, on both sides: with a λ-independent loss
    /// aggregate Ξ, ∇P_λ(M₀) = λM₀ + Ξ and the GB sphere built from it
    /// fires exactly when the general range contains λ.
    #[test]
    fn gb_form_matches_bruteforce() {
        forall("gb-range-brute", 48, |rng| {
            let (m0, h, _, l0) = random_case(rng);
            let d = m0.rows();
            let mut xi = Mat::from_fn(d, d, |_, _| rng.normal());
            xi.symmetrize();
            let (hm, hn) = (m0.dot(&h), h.norm());
            let form = RangeForm::gb(hm, xi.dot(&h), m0.norm_sq(), xi.dot(&m0), xi.norm_sq(), hn);
            let (c_r, c_l) = (1.0, 0.95);
            let r_ranges = general_r_range(&form, c_r);
            let l_ranges = general_l_range(&form, c_l);
            for k in 1..=40 {
                let lam = l0 * 0.1 * k as f64;
                let mut grad = m0.scaled(lam);
                grad.axpy(1.0, &xi);
                let s = crate::screening::bounds::gb(&m0, &grad, lam);
                let hq = s.q.dot(&h);
                for (fires, ranges, side) in [
                    (hq - s.r * hn > c_r, &r_ranges, "R"),
                    (hq + s.r * hn < c_l, &l_ranges, "L"),
                ] {
                    let inside = ranges.iter().any(|r| r.contains(lam));
                    if fires != inside {
                        let near = ranges.iter().any(|r| {
                            (lam - r.lo).abs() < 1e-6 * l0 || (lam - r.hi).abs() < 1e-6 * l0
                        });
                        if !near {
                            return Err(format!(
                                "{side} λ={lam}: fires={fires} inside={inside}"
                            ));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn quad_positive_cases() {
        // upward parabola with two positive roots -> outside intervals
        let v = quad_positive(1.0, -3.0, 2.0); // roots 1, 2
        assert_eq!(v.len(), 2);
        assert!((v[0].1 - 1.0).abs() < 1e-12 && (v[1].0 - 2.0).abs() < 1e-12);
        // downward parabola -> between roots
        let v = quad_positive(-1.0, 3.0, -2.0);
        assert_eq!(v.len(), 1);
        assert!((v[0].0 - 1.0).abs() < 1e-12 && (v[0].1 - 2.0).abs() < 1e-12);
        // no real roots, positive leading -> everywhere
        assert_eq!(quad_positive(1.0, 0.0, 1.0), vec![(0.0, f64::INFINITY)]);
        // linear fallback
        assert_eq!(quad_positive(0.0, 1.0, -1.0), vec![(1.0, f64::INFINITY)]);
        // constant negative -> empty
        assert!(quad_positive(0.0, 0.0, -1.0).is_empty());
    }

    /// DGB range form: at u = 1/λ₀ with an exact reference the radius
    /// must equal the DGB radius and the rule match direct evaluation.
    #[test]
    fn dgb_form_consistent_at_reference() {
        let mut rng = Pcg64::seed(9);
        let (m0, h, _, l0) = random_case(&mut rng);
        let (hm, hn) = (m0.dot(&h), h.norm());
        // synthetic loss aggregates
        let l_sum = 3.7;
        let k_norm = 2.2;
        let form = RangeForm::dgb(hm, m0.norm_sq(), l_sum, k_norm, hn);
        // radius² at λ: direct formula
        let lam = l0 * 0.8;
        let r_sq = form.a + form.b / lam + form.c / (lam * lam);
        let fires = hm - r_sq.max(0.0).sqrt() * hn > 1.0;
        let ranges = general_r_range(&form, 1.0);
        let inside = ranges.iter().any(|r| r.contains(lam));
        assert_eq!(fires, inside);
    }
}
