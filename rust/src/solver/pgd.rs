//! Projected gradient descent with Barzilai–Borwein steps.
//!
//! The paper's base optimizer (§5): `M ← [M − η ∇P̃(M)]_+` with the BB
//! step size
//!
//!   η = ½ | ⟨ΔM,ΔG⟩/⟨ΔG,ΔG⟩ + ⟨ΔM,ΔM⟩/⟨ΔM,ΔG⟩ |,
//!
//! duality-gap termination, and a screening hook invoked every
//! `screen_every` iterations (the paper's *dynamic screening*). The
//! pre-projection split `[M − η∇P̃]_−` is retained for the linear-
//! relaxation rule (§3.1.3), which gets its supporting hyperplane for free
//! from the projection the optimizer performs anyway.

use super::problem::Problem;
use crate::linalg::{psd_split, Mat, PsdSplit};
use crate::runtime::Engine;
use crate::util::timer::PhaseTimers;

/// Solver configuration.
#[derive(Clone, Debug)]
pub struct SolverConfig {
    /// duality-gap tolerance
    pub tol: f64,
    /// interpret `tol` relative to max(1, |P̃|) (paper uses absolute 1e-6;
    /// relative is the robust default for synthetic scales)
    pub tol_relative: bool,
    /// hard iteration cap
    pub max_iters: usize,
    /// dynamic-screening cadence (0 = never; paper: every 10 iterations)
    pub screen_every: usize,
    /// gap evaluation cadence (each gap costs one d×d eigendecomposition)
    pub gap_every: usize,
}

impl Default for SolverConfig {
    fn default() -> Self {
        SolverConfig {
            tol: 1e-6,
            tol_relative: true,
            max_iters: 20_000,
            screen_every: 10,
            gap_every: 1,
        }
    }
}

/// Everything a screening implementation may need at a screening point.
pub struct ScreenCtx<'s> {
    /// current iterate (PSD)
    pub m: &'s Mat,
    /// `∇P̃(M)`
    pub grad: &'s Mat,
    /// reduced primal at `m`
    pub p: f64,
    /// reduced dual at the induced α
    pub d: f64,
    /// `p − d`
    pub gap: f64,
    /// `[K]_+` where `K = Σ α_t H_t` (dual iterate = k_plus/λ)
    pub k_plus: &'s Mat,
    /// split of the last pre-projection point `M_prev − η ∇P̃(M_prev)`
    /// (None on the first screening call before any step)
    pub pre_split: Option<&'s PsdSplit>,
    /// margins of active triplets at `m`, aligned with `problem.active_idx()`
    pub margins: &'s [f64],
    /// solver iteration the screening point was taken at
    pub iter: usize,
}

/// Outcome statistics of one solve.
#[derive(Clone, Debug, Default)]
pub struct SolveStats {
    /// iterations performed
    pub iters: usize,
    /// reduced primal at the returned iterate
    pub p: f64,
    /// duality gap at the returned iterate
    pub gap: f64,
    /// whether the gap tolerance was reached
    pub converged: bool,
    /// triplets newly screened into L̂ during this solve
    pub screen_l: usize,
    /// triplets newly screened into R̂ during this solve
    pub screen_r: usize,
    /// active-set working-subproblem cache hits: refreshes whose selected
    /// ids were unchanged, so the row copies were reused (see
    /// [`crate::solver::ActiveSetSolver`]); always 0 for the plain solver
    pub ws_reuses: usize,
    /// time spent per phase (compute / eig / screening)
    pub timers: PhaseTimers,
}

/// Projected-gradient RTLM solver.
pub struct Solver {
    /// solver configuration
    pub cfg: SolverConfig,
}

impl Solver {
    /// Wrap a configuration.
    pub fn new(cfg: SolverConfig) -> Solver {
        Solver { cfg }
    }

    /// Minimize P̃ for `problem`, starting from `m0` (projected to PSD).
    /// `screen` is invoked every `screen_every` iterations with the
    /// current state; it may screen triplets via the returned decision
    /// lists, which the solver applies before continuing.
    pub fn solve(
        &self,
        problem: &mut Problem,
        engine: &dyn Engine,
        m0: Mat,
        mut screen: Option<&mut dyn FnMut(&Problem, &ScreenCtx) -> (Vec<usize>, Vec<usize>)>,
    ) -> (Mat, SolveStats) {
        let mut stats = SolveStats::default();
        let mut timers = PhaseTimers::default();
        let lambda = problem.lambda;

        let mut m = timers.eig.time(|| psd_split(&m0)).plus;
        let mut ev = problem.eval(&m, engine, &mut timers);
        let mut grad = problem.grad(&m, &ev.k);
        let mut pre_split: Option<PsdSplit> = None;
        let mut prev: Option<(Mat, Mat)> = None; // (m, grad) of previous iterate

        let mut iter = 0;
        loop {
            // ---- duality gap / convergence ----
            let mut gap_info = None;
            if iter % self.cfg.gap_every.max(1) == 0 || iter + 1 >= self.cfg.max_iters {
                let (d_val, split) = problem.dual(&ev.margins, &ev.k, &mut timers);
                let gap = ev.p - d_val;
                let scale = if self.cfg.tol_relative {
                    ev.p.abs().max(1.0)
                } else {
                    1.0
                };
                if gap <= self.cfg.tol * scale {
                    stats.converged = true;
                    stats.p = ev.p;
                    stats.gap = gap;
                    stats.iters = iter;
                    break;
                }
                gap_info = Some((d_val, gap, split));
            }
            if iter >= self.cfg.max_iters {
                if let Some((d_val, gap, _)) = gap_info {
                    stats.p = ev.p;
                    stats.gap = gap;
                    let _ = d_val;
                }
                stats.iters = iter;
                break;
            }

            // ---- dynamic screening ----
            if let Some(cb) = screen.as_deref_mut() {
                if self.cfg.screen_every > 0 && iter % self.cfg.screen_every == 0 {
                    // screening needs the gap; compute if this iteration skipped it
                    let (d_val, gap, split) = match gap_info.take() {
                        Some(x) => x,
                        None => {
                            let (d_val, split) = problem.dual(&ev.margins, &ev.k, &mut timers);
                            (d_val, ev.p - d_val, split)
                        }
                    };
                    let ctx = ScreenCtx {
                        m: &m,
                        grad: &grad,
                        p: ev.p,
                        d: d_val,
                        gap,
                        k_plus: &split.plus,
                        pre_split: pre_split.as_ref(),
                        margins: &ev.margins,
                        iter,
                    };
                    let t0 = std::time::Instant::now();
                    let (new_l, new_r) = cb(problem, &ctx);
                    timers.screening.add(t0.elapsed());
                    if !new_l.is_empty() || !new_r.is_empty() {
                        // the workset reports what was *newly* retired, so a
                        // redundant decision list costs no extra eval pass
                        let (nl, nr) = problem.apply_screening(&new_l, &new_r);
                        stats.screen_l += nl;
                        stats.screen_r += nr;
                        if nl + nr > 0 {
                            // the active set changed: recompute at the same m
                            ev = problem.eval(&m, engine, &mut timers);
                            grad = problem.grad(&m, &ev.k);
                            prev = None; // BB history refers to the old objective
                        }
                    }
                }
            }

            // ---- BB step ----
            let eta = match &prev {
                Some((pm, pg)) => {
                    let dm = m.sub(pm);
                    let dg = grad.sub(pg);
                    let dm_dg = dm.dot(&dg);
                    let dg_dg = dg.norm_sq();
                    let dm_dm = dm.norm_sq();
                    if dm_dg > 1e-300 && dg_dg > 1e-300 {
                        0.5 * (dm_dg / dg_dg + dm_dm / dm_dg).abs()
                    } else {
                        1.0 / lambda
                    }
                }
                None => 1.0 / lambda,
            };

            // ---- projected step ----
            let mut a_pre = m.clone();
            a_pre.axpy(-eta, &grad);
            let split = timers.eig.time(|| psd_split(&a_pre));
            let m_next = split.plus.clone();
            pre_split = Some(split);

            let ev_next = problem.eval(&m_next, engine, &mut timers);
            let grad_next = problem.grad(&m_next, &ev_next.k);

            prev = Some((std::mem::replace(&mut m, m_next), std::mem::replace(&mut grad, grad_next)));
            ev = ev_next;
            iter += 1;
        }
        stats.timers = timers;
        (m, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::loss::Loss;
    use crate::runtime::NativeEngine;
    use crate::triplet::TripletStore;
    use crate::util::rng::Pcg64;

    fn setup(seed: u64) -> TripletStore {
        let mut rng = Pcg64::seed(seed);
        let ds = synthetic::gaussian_mixture("g", 50, 4, 2, 2.5, &mut rng);
        TripletStore::from_dataset(&ds, 3, &mut rng)
    }

    #[test]
    fn converges_to_small_gap() {
        let store = setup(1);
        let loss = Loss::smoothed_hinge(0.05);
        let engine = NativeEngine::new(2);
        let lmax = Problem::lambda_max(&store, &loss, &engine);
        let mut prob = Problem::new(&store, loss, lmax * 0.1);
        let solver = Solver::new(SolverConfig {
            tol: 1e-8,
            ..Default::default()
        });
        let (m, stats) = solver.solve(&mut prob, &engine, Mat::zeros(4, 4), None);
        assert!(stats.converged, "no convergence: {stats:?}");
        assert!(stats.gap <= 1e-8 * stats.p.abs().max(1.0));
        // solution is PSD
        let e = crate::linalg::sym_eig(&m);
        assert!(e.values[0] > -1e-9, "min eig {}", e.values[0]);
    }

    #[test]
    fn warm_start_reduces_iterations() {
        let store = setup(2);
        let loss = Loss::smoothed_hinge(0.05);
        let engine = NativeEngine::new(2);
        let lmax = Problem::lambda_max(&store, &loss, &engine);
        let solver = Solver::new(SolverConfig::default());

        let mut prob = Problem::new(&store, loss, lmax * 0.5);
        let (m_prev, _) = solver.solve(&mut prob, &engine, Mat::zeros(4, 4), None);

        let mut prob_cold = Problem::new(&store, loss, lmax * 0.45);
        let (_, cold) = solver.solve(&mut prob_cold, &engine, Mat::zeros(4, 4), None);
        let mut prob_warm = Problem::new(&store, loss, lmax * 0.45);
        let (_, warm) = solver.solve(&mut prob_warm, &engine, m_prev, None);
        assert!(
            warm.iters <= cold.iters,
            "warm {} > cold {}",
            warm.iters,
            cold.iters
        );
    }

    #[test]
    fn optimality_kkt_margins() {
        // At the optimum, λM = [Σ α_t H_t]_+ (stationarity of the reduced
        // problem after PSD projection).
        let store = setup(3);
        let loss = Loss::smoothed_hinge(0.05);
        let engine = NativeEngine::new(2);
        let lmax = Problem::lambda_max(&store, &loss, &engine);
        let mut prob = Problem::new(&store, loss, lmax * 0.2);
        let solver = Solver::new(SolverConfig {
            tol: 1e-10,
            ..Default::default()
        });
        let (m, stats) = solver.solve(&mut prob, &engine, Mat::zeros(4, 4), None);
        assert!(stats.converged);
        let mut timers = crate::util::timer::PhaseTimers::default();
        let ev = prob.eval(&m, &engine, &mut timers);
        let k_plus = crate::linalg::psd_project(&ev.k);
        let resid = m.scaled(prob.lambda).sub(&k_plus).max_abs();
        assert!(resid < 1e-4 * (1.0 + k_plus.max_abs()), "KKT residual {resid}");
    }

    #[test]
    fn screening_callback_invoked_and_safe() {
        // a callback that screens using the exact margins at the current
        // iterate + DGB radius must not change the final solution
        let store = setup(4);
        let loss = Loss::smoothed_hinge(0.05);
        let engine = NativeEngine::new(2);
        let lmax = Problem::lambda_max(&store, &loss, &engine);
        let lambda = lmax * 0.3;

        let solver = Solver::new(SolverConfig {
            tol: 1e-9,
            ..Default::default()
        });
        let mut prob_plain = Problem::new(&store, loss, lambda);
        let (m_plain, _) = solver.solve(&mut prob_plain, &engine, Mat::zeros(4, 4), None);

        let mut calls = 0usize;
        let mut cb = |prob: &Problem, ctx: &ScreenCtx| -> (Vec<usize>, Vec<usize>) {
            calls += 1;
            // DGB sphere rule by hand: r = sqrt(2 gap / λ), center M
            let r = (2.0 * ctx.gap.max(0.0) / prob.lambda).sqrt();
            let mut l = vec![];
            let mut rr = vec![];
            for (k, &t) in prob.active_idx().iter().enumerate() {
                let hq = ctx.margins[k];
                let hn = prob.active_h_norm()[k];
                if hq - r * hn > prob.loss.r_threshold() {
                    rr.push(t);
                } else if hq + r * hn < prob.loss.l_threshold() {
                    l.push(t);
                }
            }
            (l, rr)
        };
        let mut prob_scr = Problem::new(&store, loss, lambda);
        let (m_scr, stats) = solver.solve(&mut prob_scr, &engine, Mat::zeros(4, 4), Some(&mut cb));
        assert!(calls > 0);
        assert!(stats.converged);
        let diff = m_plain.sub(&m_scr).max_abs();
        assert!(
            diff < 1e-5 * (1.0 + m_plain.max_abs()),
            "screened solution deviates: {diff} (screened L={} R={})",
            stats.screen_l,
            stats.screen_r
        );
    }

    #[test]
    fn max_iters_respected() {
        let store = setup(5);
        let loss = Loss::smoothed_hinge(0.05);
        let engine = NativeEngine::new(1);
        let lmax = Problem::lambda_max(&store, &loss, &engine);
        let mut prob = Problem::new(&store, loss, lmax * 0.1);
        let solver = Solver::new(SolverConfig {
            tol: 1e-16,
            tol_relative: false,
            max_iters: 3,
            ..Default::default()
        });
        let (_, stats) = solver.solve(&mut prob, &engine, Mat::zeros(4, 4), None);
        assert!(!stats.converged);
        assert_eq!(stats.iters, 3);
    }
}
