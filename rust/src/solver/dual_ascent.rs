//! Dual-based optimizer (the paper's alternative solver family, after
//! Shen et al. [21]: "the nonlinear semi-definite programming problem of
//! RTLM can be solved by ... the dual-based approach").
//!
//! Maximizes the box-constrained dual (Dual2)
//!
//!   D_λ(α) = −(γ/2)‖α‖² + αᵀ1 − (λ/2)‖M_λ(α)‖²,
//!   M_λ(α) = (1/λ)[Σ_t α_t H_t]_+ ,
//!
//! by projected gradient ascent with BB steps over `α ∈ [0,1]^{|T|}`.
//! `∇D = 1 − γα − margins(M_λ(α))` — one wgram + one PSD projection + one
//! margins pass per iteration, all through the [`Engine`] kernels.
//!
//! The primal iterate `M_λ(α)` is feasible by construction, so DGB/CDGB
//! screening applies directly (the paper's §3.2.2 "when a dual based
//! optimization algorithm is employed, a primal feasible solution can be
//! created by (1)"). This solver exists as (a) the paper's baseline
//! optimizer family, and (b) an independent cross-check of the primal PGD
//! solution in the test suite.

use super::problem::Problem;
use crate::linalg::psd_split;
use crate::runtime::Engine;
use crate::util::timer::PhaseTimers;

/// Dual solver configuration.
#[derive(Clone, Debug)]
pub struct DualConfig {
    /// duality-gap tolerance, relative to max(1, |P|)
    pub tol: f64,
    /// hard iteration cap
    pub max_iters: usize,
}

impl Default for DualConfig {
    fn default() -> Self {
        DualConfig {
            tol: 1e-6,
            max_iters: 5000,
        }
    }
}

/// Dual solve outcome.
#[derive(Clone, Debug, Default)]
pub struct DualStats {
    /// ascent iterations performed
    pub iters: usize,
    /// primal value at the induced `M_λ(α)`
    pub p: f64,
    /// dual value at the returned α
    pub d: f64,
    /// `p − d`
    pub gap: f64,
    /// whether the gap tolerance was reached
    pub converged: bool,
    /// time spent per phase
    pub timers: PhaseTimers,
}

/// Projected-gradient dual ascent on the (unscreened part of the)
/// problem's dual. Returns the primal-feasible `M_λ(α)` and stats.
pub fn solve_dual(
    problem: &Problem,
    engine: &dyn Engine,
    cfg: &DualConfig,
) -> (crate::linalg::Mat, DualStats) {
    let lambda = problem.lambda;
    let gamma = problem.loss.gamma;
    let n = problem.active_idx().len();
    let a_act = problem.active_a();
    let b_act = problem.active_b();
    let mut timers = PhaseTimers::default();
    let mut stats = DualStats::default();

    // α init: 0.5 (interior) — keeps the first gradient informative
    let mut alpha = vec![0.5; n];
    let mut margins = vec![0.0; n];
    let mut grad = vec![0.0; n];
    let mut prev: Option<(Vec<f64>, Vec<f64>)> = None;

    // effective screened-L mass: the store-rowed H_L plus the streaming
    // pipeline's row-less external L̂ mass (Problem::set_external_l) —
    // both carry α = 1, so K, D and P must all see them
    let h_l_ext: Option<crate::linalg::Mat> = if problem.n_external_l() > 0 {
        let mut h = problem.h_l().clone();
        h.axpy(1.0, problem.external_h_l());
        Some(h)
    } else {
        None
    };
    let h_l_eff: &crate::linalg::Mat = h_l_ext.as_ref().unwrap_or(problem.h_l());
    let fixed_l = (problem.n_screened_l() + problem.n_external_l()) as f64;

    let eval = |alpha: &[f64],
                margins: &mut [f64],
                timers: &mut PhaseTimers|
     -> (f64, f64, crate::linalg::Mat) {
        // K = Σ α H (+ screened-L aggregates), M = [K]_+/λ
        let mut k = timers.compute.time(|| engine.wgram(a_act, b_act, alpha));
        k.axpy(1.0, h_l_eff);
        let split = timers.eig.time(|| psd_split(&k));
        let m = split.plus.scaled(1.0 / lambda);
        timers.compute.time(|| engine.margins(&m, a_act, b_act, margins));
        // D(α) over active ∪ screened (screened-L, rowed or external: α=1)
        let asq: f64 = alpha.iter().map(|a| a * a).sum::<f64>() + fixed_l;
        let asum: f64 = alpha.iter().sum::<f64>() + fixed_l;
        let d_val = -0.5 * gamma * asq + asum - split.plus.norm_sq() / (2.0 * lambda);
        // P(M) for the gap
        let mut p = 0.5 * lambda * m.norm_sq() + (1.0 - gamma / 2.0) * fixed_l - m.dot(h_l_eff);
        for &mg in margins.iter() {
            p += problem.loss.value(mg);
        }
        (p, d_val, m)
    };

    let (mut p, mut d_val, mut m) = eval(&alpha, &mut margins, &mut timers);
    for iter in 0..cfg.max_iters {
        let gap = p - d_val;
        if gap <= cfg.tol * p.abs().max(1.0) {
            stats.converged = true;
            stats.iters = iter;
            break;
        }
        // ∇D = 1 − γα − margins(M_λ(α))
        for t in 0..n {
            grad[t] = 1.0 - gamma * alpha[t] - margins[t];
        }
        // BB step (spectral, on the box-projected path)
        let eta = match &prev {
            Some((pa, pg)) => {
                let mut dadg = 0.0;
                let mut dgdg = 0.0;
                let mut dada = 0.0;
                for t in 0..n {
                    let da = alpha[t] - pa[t];
                    let dg = grad[t] - pg[t];
                    dadg += da * dg;
                    dgdg += dg * dg;
                    dada += da * da;
                }
                // ascent: curvature is negative; use |·|
                if dadg.abs() > 1e-300 && dgdg > 1e-300 {
                    0.5 * ((dadg / dgdg).abs() + (dada / dadg.abs()))
                } else {
                    1.0 / (gamma + 1.0)
                }
            }
            None => 1.0 / (gamma + 1.0),
        };
        let alpha_next: Vec<f64> = (0..n)
            .map(|t| (alpha[t] + eta * grad[t]).clamp(0.0, 1.0))
            .collect();
        let (p_n, d_n, m_n) = eval(&alpha_next, &mut margins, &mut timers);
        let grad_next: Vec<f64> = (0..n)
            .map(|t| 1.0 - gamma * alpha_next[t] - margins[t])
            .collect();
        prev = Some((
            std::mem::replace(&mut alpha, alpha_next),
            std::mem::replace(&mut grad, grad_next),
        ));
        p = p_n;
        d_val = d_n;
        m = m_n;
        stats.iters = iter + 1;
    }
    stats.p = p;
    stats.d = d_val;
    stats.gap = p - d_val;
    stats.timers = timers;
    (m, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::linalg::Mat;
    use crate::loss::Loss;
    use crate::runtime::NativeEngine;
    use crate::solver::{Solver, SolverConfig};
    use crate::triplet::TripletStore;
    use crate::util::rng::Pcg64;

    fn setup(seed: u64) -> TripletStore {
        let mut rng = Pcg64::seed(seed);
        let ds = synthetic::gaussian_mixture("g", 40, 4, 2, 2.6, &mut rng);
        TripletStore::from_dataset(&ds, 3, &mut rng)
    }

    #[test]
    fn dual_reaches_small_gap() {
        let store = setup(1);
        let loss = Loss::smoothed_hinge(0.05);
        let engine = NativeEngine::new(2);
        let lmax = Problem::lambda_max(&store, &loss, &engine);
        let prob = Problem::new(&store, loss, lmax * 0.1);
        let (m, stats) = solve_dual(
            &prob,
            &engine,
            &DualConfig {
                tol: 1e-7,
                max_iters: 20_000,
            },
        );
        assert!(stats.converged, "{stats:?}");
        // primal iterate PSD
        let e = crate::linalg::sym_eig(&m);
        assert!(e.values[0] > -1e-9);
    }

    #[test]
    fn external_l_mass_enters_the_dual() {
        // the row-less external L̂ mass (streaming pipeline) must make
        // solve_dual behave exactly like screening the same triplets
        // into L̂ the row-carrying way — same K, same D/P, same M
        let store = setup(4);
        let loss = Loss::smoothed_hinge(0.05);
        let engine = NativeEngine::new(2);
        let lmax = Problem::lambda_max(&store, &loss, &engine);
        let lambda = lmax * 0.3;
        let ext_ids = [0usize, 5, 11];

        let mut with_rows = Problem::new(&store, loss, lambda);
        with_rows.apply_screening(&ext_ids, &[]);

        let mut small = TripletStore::empty(store.d);
        for t in 0..store.len() {
            if !ext_ids.contains(&t) {
                small.push(store.idx[t], store.a.row(t), store.b.row(t), store.h_norm[t]);
            }
        }
        let mut h_ext = Mat::zeros(store.d, store.d);
        for &t in &ext_ids {
            h_ext.add_h_outer(store.a.row(t), store.b.row(t), 1.0);
        }
        let mut rowless = Problem::new(&small, loss, lambda);
        rowless.set_external_l(&h_ext, ext_ids.len());

        let cfg = DualConfig {
            tol: 1e-8,
            max_iters: 50_000,
        };
        let (m_a, s_a) = solve_dual(&with_rows, &engine, &cfg);
        let (m_b, s_b) = solve_dual(&rowless, &engine, &cfg);
        assert_eq!(s_a.converged, s_b.converged);
        // the two active sets hold the same triplets (different row
        // order), so the solved problems are identical; both runs are
        // gap-certified around the same optimum
        let p_tol = (s_a.gap.max(0.0) + s_b.gap.max(0.0)) + 1e-7 * (1.0 + s_a.p.abs());
        assert!((s_a.p - s_b.p).abs() < p_tol, "P {} vs {}", s_a.p, s_b.p);
        assert!((s_a.d - s_b.d).abs() < p_tol, "D {} vs {}", s_a.d, s_b.d);
        let diff = m_a.sub(&m_b).max_abs();
        let bound = (2.0 * (s_a.gap.max(0.0) + s_b.gap.max(0.0)) / lambda).sqrt() + 1e-4;
        assert!(diff < bound.max(1e-3), "M drifted by {diff} (bound {bound})");
    }

    #[test]
    fn dual_matches_primal_solver() {
        let store = setup(2);
        let loss = Loss::smoothed_hinge(0.05);
        let engine = NativeEngine::new(2);
        let lmax = Problem::lambda_max(&store, &loss, &engine);
        let lambda = lmax * 0.2;

        let mut prob = Problem::new(&store, loss, lambda);
        let (m_primal, sp) = Solver::new(SolverConfig {
            tol: 1e-9,
            tol_relative: false,
            ..Default::default()
        })
        .solve(&mut prob, &engine, Mat::zeros(4, 4), None);
        assert!(sp.converged);

        let prob2 = Problem::new(&store, loss, lambda);
        let (m_dual, sd) = solve_dual(
            &prob2,
            &engine,
            &DualConfig {
                tol: 1e-8,
                max_iters: 50_000,
            },
        );
        assert!(sd.converged, "{sd:?}");
        let diff = m_primal.sub(&m_dual).max_abs();
        // both within their gap-certified balls of M*
        let bound = (2.0 * (sp.gap + sd.gap.max(0.0)) / lambda).sqrt() + 1e-4;
        assert!(diff < bound.max(1e-3), "primal vs dual diff {diff}");
    }

    #[test]
    fn dual_respects_screened_problem() {
        // dual solve on a screened problem must match unscreened optimum
        let store = setup(3);
        let loss = Loss::smoothed_hinge(0.05);
        let engine = NativeEngine::new(2);
        let lmax = Problem::lambda_max(&store, &loss, &engine);
        let lambda = lmax * 0.1;

        let prob_plain = Problem::new(&store, loss, lambda);
        let (m_plain, s_plain) = solve_dual(&prob_plain, &engine, &DualConfig::default());
        assert!(s_plain.converged);

        // screen exactly using a high-accuracy primal solution
        let mut prob_acc = Problem::new(&store, loss, lambda);
        let (m_star, _) = Solver::new(SolverConfig {
            tol: 1e-11,
            tol_relative: false,
            ..Default::default()
        })
        .solve(&mut prob_acc, &engine, Mat::zeros(4, 4), None);
        let mut margins = vec![0.0; store.len()];
        engine.margins(&m_star, &store.a, &store.b, &mut margins);
        let l: Vec<usize> = (0..store.len())
            .filter(|&t| margins[t] < loss.l_threshold() - 1e-6)
            .collect();
        let r: Vec<usize> = (0..store.len())
            .filter(|&t| margins[t] > loss.r_threshold() + 1e-6)
            .collect();
        let mut prob_scr = Problem::new(&store, loss, lambda);
        prob_scr.apply_screening(&l, &r);
        let (m_scr, s_scr) = solve_dual(&prob_scr, &engine, &DualConfig::default());
        assert!(s_scr.converged);
        let diff = m_plain.sub(&m_scr).max_abs();
        assert!(diff < 1e-2 * (1.0 + m_plain.max_abs()), "diff {diff}");
    }
}
