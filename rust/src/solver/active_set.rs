//! Active-set heuristic (paper §5.3, following Weinberger & Saul [1]).
//!
//! Only triplets with positive loss (margin below the zero-part threshold,
//! plus a small buffer) are kept in the working set; gradients are
//! computed over the working set alone. Every `refresh_every` inner
//! iterations the full margins are recomputed: the working set is
//! refreshed, safe screening (if attached) runs, and overall optimality is
//! certified by the duality gap over the *full* reduced problem — the
//! heuristic never compromises the final optimality guarantee.
//!
//! Refreshes reuse the workset margins lane for selection and cache the
//! working subproblem by triplet *ids*: rows shift when screening
//! compacts the workset, ids don't, and the `a`/`b` rows of a given id
//! never change — so when the selected ids are unchanged (the common
//! case near convergence) the O(|W|·d) row copies are skipped entirely
//! (`SolveStats::ws_reuses` counts the savings).

use super::pgd::{ScreenCtx, SolveStats, SolverConfig};
use super::problem::Problem;
use crate::linalg::{psd_split, Mat, PsdSplit};
use crate::runtime::Engine;
use crate::util::timer::PhaseTimers;

/// Cached working subproblem, keyed by the selected triplet ids.
struct WsCache {
    ids: Vec<usize>,
    a: Mat,
    b: Mat,
}

/// Active-set wrapper around the PGD inner loop.
pub struct ActiveSetSolver {
    /// inner-solver configuration
    pub cfg: SolverConfig,
    /// inner PGD iterations between full refreshes (paper: 10)
    pub refresh_every: usize,
    /// margin slack for working-set membership: keep t if
    /// `margin_t ≤ r_threshold + buffer`
    pub buffer: f64,
}

impl ActiveSetSolver {
    /// Wrap a configuration with the paper's refresh/buffer defaults.
    pub fn new(cfg: SolverConfig) -> ActiveSetSolver {
        ActiveSetSolver {
            cfg,
            refresh_every: 10,
            buffer: 0.1,
        }
    }

    /// Minimize P̃ with the active-set heuristic.
    pub fn solve(
        &self,
        problem: &mut Problem,
        engine: &dyn Engine,
        m0: Mat,
        mut screen: Option<&mut dyn FnMut(&Problem, &ScreenCtx) -> (Vec<usize>, Vec<usize>)>,
    ) -> (Mat, SolveStats) {
        let mut stats = SolveStats::default();
        let mut timers = PhaseTimers::default();
        let lambda = problem.lambda;

        let mut m = timers.eig.time(|| psd_split(&m0)).plus;
        let mut pre_split: Option<PsdSplit> = None;
        let mut inner_iters = 0usize;
        let mut cache: Option<WsCache> = None;
        let mut sel_ids: Vec<usize> = Vec::new();
        // reusable inner-loop margins lane (resized per refresh, never
        // reallocated while the selection size is stable)
        let mut margins_w: Vec<f64> = Vec::new();

        'outer: for _round in 0..(self.cfg.max_iters / self.refresh_every.max(1) + 2) {
            // ---- full evaluation over all (unscreened) active triplets ----
            let ev = problem.eval(&m, engine, &mut timers);
            let grad = problem.grad(&m, &ev.k);
            let (d_val, split) = problem.dual(&ev.margins, &ev.k, &mut timers);
            let gap = ev.p - d_val;
            let scale = if self.cfg.tol_relative {
                ev.p.abs().max(1.0)
            } else {
                1.0
            };
            if gap <= self.cfg.tol * scale {
                stats.converged = true;
                stats.p = ev.p;
                stats.gap = gap;
                break 'outer;
            }
            if inner_iters >= self.cfg.max_iters {
                stats.p = ev.p;
                stats.gap = gap;
                break 'outer;
            }

            // ---- safe screening at the refresh point ----
            if let Some(cb) = screen.as_deref_mut() {
                let ctx = ScreenCtx {
                    m: &m,
                    grad: &grad,
                    p: ev.p,
                    d: d_val,
                    gap,
                    k_plus: &split.plus,
                    pre_split: pre_split.as_ref(),
                    margins: &ev.margins,
                    iter: inner_iters,
                };
                let t0 = std::time::Instant::now();
                let (new_l, new_r) = cb(problem, &ctx);
                timers.screening.add(t0.elapsed());
                if !new_l.is_empty() || !new_r.is_empty() {
                    let (nl, nr) = problem.apply_screening(&new_l, &new_r);
                    stats.screen_l += nl;
                    stats.screen_r += nr;
                    if nl + nr > 0 {
                        continue 'outer; // re-evaluate on the reduced problem
                    }
                }
            }

            // ---- working-set selection on fresh full margins ----
            // effective screened-L mass: the store-rowed H_L plus the
            // streaming pipeline's row-less external L̂ mass — the inner
            // gradient must see both or the subproblem would drift from
            // the problem the outer gap certifies
            let h_l_ext: Option<Mat> = if problem.n_external_l() > 0 {
                let mut h = problem.h_l().clone();
                h.axpy(1.0, problem.external_h_l());
                Some(h)
            } else {
                None
            };
            let h_l_eff: &Mat = h_l_ext.as_ref().unwrap_or(problem.h_l());
            let threshold = problem.loss.r_threshold() + self.buffer;
            let w_local: Vec<usize> = ev
                .margins
                .iter()
                .enumerate()
                .filter(|(_, &mg)| mg <= threshold)
                .map(|(k, _)| k)
                .collect();
            if w_local.is_empty() {
                // nothing active: P̃ is quadratic + linear; one exact step
                // M = [H_L]_+ / λ
                m = timers.eig.time(|| psd_split(h_l_eff)).plus;
                m.scale(1.0 / lambda);
                inner_iters += 1;
                continue 'outer;
            }
            // ids — not rows — identify the subproblem: reuse the cached
            // row copies whenever the selection is unchanged
            sel_ids.clear();
            sel_ids.extend(w_local.iter().map(|&k| problem.active_idx()[k]));
            let reuse = cache.as_ref().is_some_and(|c| c.ids == sel_ids);
            if reuse {
                stats.ws_reuses += 1;
            } else {
                cache = Some(WsCache {
                    ids: sel_ids.clone(),
                    a: problem.active_a().select_rows(&w_local),
                    b: problem.active_b().select_rows(&w_local),
                });
            }
            let ws = cache.as_ref().expect("cache ensured above");
            let (a_w, b_w) = (&ws.a, &ws.b);

            // ---- inner PGD on the working subproblem (margins through
            //      the same tiled engine core as the full problem) ----
            margins_w.clear();
            margins_w.resize(w_local.len(), 0.0);
            let eval_w = |m: &Mat, margins_w: &mut Vec<f64>, timers: &mut PhaseTimers| -> Mat {
                let (_, g) = timers
                    .compute
                    .time(|| engine.step(m, a_w, b_w, problem.loss.gamma, margins_w));
                let mut k = g;
                k.axpy(1.0, h_l_eff);
                let mut grad = m.scaled(lambda);
                grad.axpy(-1.0, &k);
                grad
            };
            let mut grad_w = eval_w(&m, &mut margins_w, &mut timers);
            let mut prev: Option<(Mat, Mat)> = None;
            for _ in 0..self.refresh_every {
                let eta = match &prev {
                    Some((pm, pg)) => {
                        let dm = m.sub(pm);
                        let dg = grad_w.sub(pg);
                        let dm_dg = dm.dot(&dg);
                        let dg_dg = dg.norm_sq();
                        if dm_dg > 1e-300 && dg_dg > 1e-300 {
                            0.5 * (dm_dg / dg_dg + dm.norm_sq() / dm_dg).abs()
                        } else {
                            1.0 / lambda
                        }
                    }
                    None => 1.0 / lambda,
                };
                let mut a_pre = m.clone();
                a_pre.axpy(-eta, &grad_w);
                let split = timers.eig.time(|| psd_split(&a_pre));
                let m_next = split.plus.clone();
                pre_split = Some(split);
                let grad_next = eval_w(&m_next, &mut margins_w, &mut timers);
                prev = Some((
                    std::mem::replace(&mut m, m_next),
                    std::mem::replace(&mut grad_w, grad_next),
                ));
                inner_iters += 1;
            }
        }
        stats.iters = inner_iters;
        stats.timers = timers;
        (m, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::loss::Loss;
    use crate::solver::Solver;
    use crate::triplet::TripletStore;
    use crate::util::rng::Pcg64;

    fn setup(seed: u64) -> TripletStore {
        let mut rng = Pcg64::seed(seed);
        let ds = synthetic::gaussian_mixture("g", 50, 4, 2, 2.8, &mut rng);
        TripletStore::from_dataset(&ds, 3, &mut rng)
    }

    #[test]
    fn matches_plain_pgd_solution() {
        let store = setup(1);
        let loss = Loss::smoothed_hinge(0.05);
        let engine = crate::runtime::NativeEngine::new(2);
        let lmax = Problem::lambda_max(&store, &loss, &engine);
        let lambda = lmax * 0.05;
        let cfg = SolverConfig {
            tol: 1e-9,
            ..Default::default()
        };

        let mut p1 = Problem::new(&store, loss, lambda);
        let (m1, s1) = Solver::new(cfg.clone()).solve(&mut p1, &engine, Mat::zeros(4, 4), None);
        assert!(s1.converged);

        let mut p2 = Problem::new(&store, loss, lambda);
        let (m2, s2) = ActiveSetSolver::new(cfg).solve(&mut p2, &engine, Mat::zeros(4, 4), None);
        assert!(s2.converged, "{s2:?}");
        // both solutions are within sqrt(2·gap/λ) of M*; allow their sum
        let bound = 2.0 * (2.0 * (s1.gap.max(s2.gap)).max(1e-9) / lambda).sqrt();
        let diff = m1.sub(&m2).max_abs();
        assert!(diff < bound.max(1e-4), "diff {diff} > bound {bound}");
    }

    #[test]
    fn certifies_full_gap() {
        let store = setup(2);
        let loss = Loss::smoothed_hinge(0.05);
        let engine = crate::runtime::NativeEngine::new(2);
        let lmax = Problem::lambda_max(&store, &loss, &engine);
        let mut prob = Problem::new(&store, loss, lmax * 0.2);
        let cfg = SolverConfig {
            tol: 1e-8,
            ..Default::default()
        };
        let (m, stats) = ActiveSetSolver::new(cfg).solve(&mut prob, &engine, Mat::zeros(4, 4), None);
        assert!(stats.converged);
        // independent gap audit at the returned m
        let mut timers = PhaseTimers::default();
        let ev = prob.eval(&m, &engine, &mut timers);
        let (d, _) = prob.dual(&ev.margins, &ev.k, &mut timers);
        assert!(ev.p - d <= 1e-7 * ev.p.abs().max(1.0));
    }

    #[test]
    fn working_set_cache_reused_on_long_solves() {
        // Near convergence the margins stabilize, so the selected ids stop
        // changing and the cached row copies must be reused. Only assert
        // when the solve actually spans multiple refreshes.
        let store = setup(4);
        let loss = Loss::smoothed_hinge(0.05);
        let engine = crate::runtime::NativeEngine::new(2);
        let lmax = Problem::lambda_max(&store, &loss, &engine);
        let mut prob = Problem::new(&store, loss, lmax * 0.05);
        let cfg = SolverConfig {
            tol: 1e-10,
            tol_relative: false,
            ..Default::default()
        };
        let solver = ActiveSetSolver::new(cfg);
        let (_, stats) = solver.solve(&mut prob, &engine, Mat::zeros(4, 4), None);
        assert!(stats.converged);
        if stats.iters > 4 * solver.refresh_every {
            assert!(
                stats.ws_reuses > 0,
                "selection never reused across {} iters",
                stats.iters
            );
        }
    }

    #[test]
    fn large_lambda_all_alpha_one_converges() {
        // near λ_max everything sits in the linear part; working set = all
        let store = setup(3);
        let loss = Loss::smoothed_hinge(0.05);
        let engine = crate::runtime::NativeEngine::new(2);
        let lmax = Problem::lambda_max(&store, &loss, &engine);
        let mut prob = Problem::new(&store, loss, lmax * 2.0);
        let (m, stats) =
            ActiveSetSolver::new(SolverConfig::default()).solve(&mut prob, &engine, Mat::zeros(4, 4), None);
        assert!(stats.converged);
        // closed form: M* = [ΣH]_+ / λ
        let ones = vec![1.0; store.len()];
        let sum_h = engine.wgram(&store.a, &store.b, &ones);
        let want = crate::linalg::psd_project(&sum_h).scaled(1.0 / prob.lambda);
        assert!(m.sub(&want).max_abs() < 1e-5 * (1.0 + want.max_abs()));
    }
}
