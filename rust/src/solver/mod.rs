//! RTLM optimization: the reduced problem, projected gradient descent with
//! Barzilai–Borwein steps, duality-gap certification, and the active-set
//! heuristic (paper §5.3).

mod active_set;
mod dual_ascent;
mod pgd;
mod problem;

pub use active_set::ActiveSetSolver;
pub use dual_ascent::{solve_dual, DualConfig, DualStats};
pub use pgd::{ScreenCtx, SolveStats, Solver, SolverConfig};
pub use problem::{EvalOut, Problem, ProblemState, RetargetStats};
