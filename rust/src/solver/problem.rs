//! The (possibly screened) RTLM problem instance.
//!
//! After screening fixes subsets `L̂ ⊆ L*` (α* = 1) and `R̂ ⊆ R*` (α* = 0),
//! the reduced primal (paper §3) is
//!
//!   P̃_λ(M) = Σ_{t ∈ active} ℓ(⟨M,H_t⟩) + (λ/2)‖M‖_F²
//!           + (1 − γ/2)|L̂| − ⟨M, Σ_{t∈L̂} H_t⟩ ,
//!
//! which shares its optimum with the full problem. This struct owns the
//! screening status, the compacted [`ActiveWorkset`] the engines and the
//! screening rules consume, and the cached screened-L aggregate
//! `H_L = Σ_{L̂} H_t`.
//!
//! Screening a triplet costs O(d) (workset swap-remove) plus the O(d²)
//! rank-2 `H_L` update for L-side decisions — the old O(|T|·d) full
//! recompaction per `apply_screening` call is gone.
//!
//! ## Persistent cross-λ lifecycle
//!
//! A `Problem` is no longer rebuilt per regularization-path step. The
//! path driver constructs it once and crosses λ boundaries with
//! [`Problem::retarget_lambda`], handing it the frame's certificate
//! coverage at the new λ:
//!
//! - a screened triplet whose decision is **re-certified** at the new λ
//!   stays retired — its rows are *never re-copied*;
//! - a screened triplet **not** covered is revived (O(d) row append,
//!   `H_L` rank-2 downdate for L-side) — these revives are the only row
//!   copies the crossing performs, reported as
//!   [`RetargetStats::rows_copied`] (a from-scratch rebuild costs |T|);
//! - active triplets newly covered are retired exactly as a screening
//!   decision would retire them.
//!
//! [`Problem::reset_for_lambda`] remains the certificate-free crossing
//! (full fresh workset, all guarantees re-derived); `retarget_lambda`
//! with empty coverage is its allocation-free equivalent.

use crate::linalg::{psd_split, Mat, PsdSplit};
use crate::loss::Loss;
use crate::runtime::Engine;
use crate::triplet::{ActiveWorkset, StatusVec, TripletStore};
use crate::util::timer::PhaseTimers;

/// Output of one objective/gradient evaluation at `M`.
#[derive(Clone, Debug)]
pub struct EvalOut {
    /// reduced primal value P̃_λ(M)
    pub p: f64,
    /// `K = Σ_t α_t H_t` over active ∪ L̂ (α = 1 on L̂);
    /// `∇P̃ = λM − K`.
    pub k: Mat,
    /// margins `⟨M, H_t⟩` for active triplets, aligned with `active_idx`
    pub margins: Vec<f64>,
}

/// Telemetry of one cross-λ retarget (see [`Problem::retarget_lambda`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct RetargetStats {
    /// rows copied back into the workset — revived triplets are the
    /// *only* O(d) copies a retarget performs; a from-scratch rebuild
    /// (`Problem::new` / `reset_for_lambda`) costs |T| of them
    pub rows_copied: usize,
    /// previously screened triplets whose decision was not re-certified
    /// at the new λ and re-entered the reduced problem
    pub revived: usize,
    /// coverage decisions newly applied to triplets that were active
    /// before the call
    pub newly_screened: usize,
}

/// Everything a [`Problem`] owns besides the store borrow — the
/// streamed-path handoff: the driver calls [`Problem::into_state`], grows
/// the backing store with newly admitted triplets, and rebuilds via
/// [`Problem::resume`], which ingests the new ids through the revive
/// machinery. All screening decisions, the compacted workset rows and
/// the `H_L` aggregates survive the crossing untouched.
pub struct ProblemState {
    status: StatusVec,
    workset: ActiveWorkset,
    h_l: Mat,
    n_l: usize,
    ext_h_l: Mat,
    ext_n_l: usize,
}

impl ProblemState {
    /// Ids this state covers (the store length at `into_state` time).
    pub fn len(&self) -> usize {
        self.status.len()
    }

    /// Whether the state covers no ids.
    pub fn is_empty(&self) -> bool {
        self.status.is_empty()
    }

    /// Extract the final per-triplet screening status (diagnostics /
    /// safety oracles on the streamed path's admitted store).
    pub fn into_status(self) -> StatusVec {
        self.status
    }
}

/// One RTLM problem: store + loss + λ + screening state.
pub struct Problem<'a> {
    /// the backing triplet set (admitted set, for a streamed source)
    pub store: &'a TripletStore,
    /// the loss defining thresholds and duals
    pub loss: Loss,
    /// current regularization weight
    pub lambda: f64,
    status: StatusVec,
    /// compacted active set (swap-remove arena, permanently retires
    /// screened ids; see `triplet::workset`)
    workset: ActiveWorkset,
    // ---- screened-L aggregates ----
    h_l: Mat,
    n_l: usize,
    /// external (row-less) L̂ mass: `Σ H_t` and count over triplets the
    /// admission screen certified into L* that were never copied into
    /// the store (streaming pipeline). Enters the objective, gradient
    /// and dual exactly like screened-L triplets; owned bookkeeping-wise
    /// by the path driver, which re-installs it per λ via
    /// [`Problem::set_external_l`]. Untouched by `reset_for_lambda` /
    /// `retarget_lambda`: the problem cannot revive a row-less triplet,
    /// so dropping the mass silently would be unsafe.
    ext_h_l: Mat,
    ext_n_l: usize,
    /// reusable per-id coverage marks for `retarget_lambda`
    /// (0 = uncovered, 1 = L, 2 = R)
    retarget_mark: Vec<u8>,
}

impl<'a> Problem<'a> {
    /// Fresh, unscreened problem over every triplet of `store`.
    pub fn new(store: &'a TripletStore, loss: Loss, lambda: f64) -> Problem<'a> {
        assert!(lambda > 0.0, "lambda must be positive");
        let n = store.len();
        Problem {
            store,
            loss,
            lambda,
            status: StatusVec::new(n),
            workset: ActiveWorkset::full(store),
            h_l: Mat::zeros(store.d, store.d),
            n_l: 0,
            ext_h_l: Mat::zeros(store.d, store.d),
            ext_n_l: 0,
            retarget_mark: Vec::new(),
        }
    }

    /// Tear the problem down to its owned state so the backing store can
    /// be grown (streaming admission); see [`ProblemState`].
    pub fn into_state(self) -> ProblemState {
        ProblemState {
            status: self.status,
            workset: self.workset,
            h_l: self.h_l,
            n_l: self.n_l,
            ext_h_l: self.ext_h_l,
            ext_n_l: self.ext_n_l,
        }
    }

    /// Rebuild a problem around a store that may have **grown** since
    /// [`Self::into_state`] (streaming admission appends rows; existing
    /// ids never move). Newly appended store ids are ingested as Active
    /// workset rows through the revive machinery, so admitted candidates
    /// enter the reduced problem exactly like certificate-expired
    /// revives. The caller still runs [`Self::retarget_lambda`] to apply
    /// certificate coverage at the new λ.
    pub fn resume(
        store: &'a TripletStore,
        loss: Loss,
        lambda: f64,
        state: ProblemState,
    ) -> Problem<'a> {
        assert!(lambda > 0.0, "lambda must be positive");
        let ProblemState {
            mut status,
            mut workset,
            h_l,
            n_l,
            ext_h_l,
            ext_n_l,
        } = state;
        let old_n = status.len();
        assert!(
            old_n <= store.len(),
            "state covers {} ids but the store holds {}",
            old_n,
            store.len()
        );
        assert_eq!(h_l.rows(), store.d, "state dimension mismatch");
        status.extend_active(store.len() - old_n);
        workset.extend_ids(store.len() - old_n);
        for id in old_n..store.len() {
            let fresh = workset.revive(id, store);
            assert!(fresh, "ingested id {id} was already active");
        }
        Problem {
            store,
            loss,
            lambda,
            status,
            workset,
            h_l,
            n_l,
            ext_h_l,
            ext_n_l,
            retarget_mark: Vec::new(),
        }
    }

    /// Change λ keeping the screening state *reset* (each λ must re-derive
    /// its own guarantees; the range-based extension carries them instead).
    pub fn reset_for_lambda(&mut self, lambda: f64) {
        assert!(lambda > 0.0);
        self.lambda = lambda;
        self.status.reset();
        self.workset = ActiveWorkset::full(self.store);
        self.h_l = Mat::zeros(self.store.d, self.store.d);
        self.n_l = 0;
    }

    /// Cross a λ boundary **keeping the problem alive** (see the module
    /// docs). `cover_l`/`cover_r` are the triplet ids whose membership is
    /// certified at the *new* λ (the frame's certificate coverage,
    /// [`crate::screening::ReferenceFrame::advance_covered`]); pass empty
    /// slices when no certificates exist — every screened triplet is then
    /// revived, which is the safe certificate-free semantics of
    /// [`Self::reset_for_lambda`] without the O(|T|·d) rebuild.
    ///
    /// Invariants on return:
    /// - a triplet is retired iff its side is in the coverage sets —
    ///   decisions from the previous λ never leak into the new one;
    /// - `H_L = Σ_{t ∈ L̂} H_t` over the new L̂ to f64 rounding (the
    ///   rank-2 down- and up-dates are the exact mirror of
    ///   `apply_screening`'s; interleaved cycles accumulate only
    ///   a-few-ulps residue instead of being rebuilt);
    /// - the reference-margin lane is dropped whenever a row was revived
    ///   (the driver re-installs it for the new λ), so a misaligned lane
    ///   can never feed a rule.
    pub fn retarget_lambda(
        &mut self,
        lambda: f64,
        cover_l: &[usize],
        cover_r: &[usize],
    ) -> RetargetStats {
        assert!(lambda > 0.0, "lambda must be positive");
        self.lambda = lambda;
        let n = self.store.len();
        self.retarget_mark.clear();
        self.retarget_mark.resize(n, 0u8);
        for &t in cover_l {
            self.retarget_mark[t] = 1;
        }
        for &t in cover_r {
            debug_assert_ne!(self.retarget_mark[t], 1, "id {t} certified both L and R");
            self.retarget_mark[t] = 2;
        }
        let mut st = RetargetStats::default();
        // 1. revive every screened triplet whose decision is not
        //    re-certified at the new λ
        for t in 0..n {
            let was = self.status.get(t);
            let keep = match was {
                crate::triplet::TripletStatus::Active => continue,
                crate::triplet::TripletStatus::ScreenedL => self.retarget_mark[t] == 1,
                crate::triplet::TripletStatus::ScreenedR => self.retarget_mark[t] == 2,
            };
            if keep {
                continue; // certificate-covered: stays retired, no copy
            }
            if was == crate::triplet::TripletStatus::ScreenedL {
                // H_L -= H_t: downdate with the same rank-2 kernel the
                // screen path uses, so the two stay bit-symmetric
                self.h_l_rank2(t, -1.0);
                self.n_l -= 1;
            }
            self.status.reactivate(t);
            self.workset.revive(t, self.store);
            st.rows_copied += 1;
            st.revived += 1;
        }
        // 2. apply the coverage decisions: only newly active ids change
        //    state (ids kept retired above are no-ops here)
        let (nl, nr) = self.apply_screening(cover_l, cover_r);
        st.newly_screened = nl + nr;
        st
    }

    /// Per-triplet screening status.
    pub fn status(&self) -> &StatusVec {
        &self.status
    }

    /// Feature dimension.
    pub fn d(&self) -> usize {
        self.store.d
    }

    /// Triplets currently fixed into L̂ **with store rows** (excludes the
    /// external row-less mass; see [`Self::n_external_l`]).
    pub fn n_screened_l(&self) -> usize {
        self.n_l
    }

    /// Row-less admission-certified L̂ triplets currently installed.
    pub fn n_external_l(&self) -> usize {
        self.ext_n_l
    }

    /// Install the external (row-less) L̂ mass: `h = Σ H_t` and `n` the
    /// count over triplets the admission screen certified into L* without
    /// ever copying their rows (streaming pipeline). Replaces any
    /// previously installed mass; the path driver owns the bookkeeping
    /// and re-installs after every certificate transition.
    pub fn set_external_l(&mut self, h: &Mat, n: usize) {
        assert_eq!(h.rows(), self.store.d, "external H_L dimension mismatch");
        assert_eq!(h.cols(), self.store.d, "external H_L dimension mismatch");
        self.ext_h_l = h.clone();
        self.ext_n_l = n;
    }

    /// The compacted active workset (read-only view).
    pub fn workset(&self) -> &ActiveWorkset {
        &self.workset
    }

    /// Active-triplet ids (compaction row order, aligned with eval margins).
    pub fn active_idx(&self) -> &[usize] {
        self.workset.ids()
    }

    /// Compacted `x_i − x_l` rows of the active triplets.
    pub fn active_a(&self) -> &Mat {
        self.workset.a()
    }

    /// Compacted `x_i − x_j` rows of the active triplets.
    pub fn active_b(&self) -> &Mat {
        self.workset.b()
    }

    /// `‖H_t‖_F` for active triplets (aligned with `active_idx`).
    pub fn active_h_norm(&self) -> &[f64] {
        self.workset.h_norm()
    }

    /// Thread a [`crate::screening::ReferenceFrame`] into this problem:
    /// installs the frame's `⟨H_t, M₀⟩` margins as the workset's
    /// row-aligned lane under the frame's identity tag. The lane is then
    /// compacted in lockstep as triplets retire, so every RPB/RRPB
    /// manager sharing the frame reads a contiguous slice instead of
    /// gathering by id.
    pub fn install_frame(&mut self, frame: &crate::screening::ReferenceFrame) {
        self.workset.install_ref_margins(frame.margins(), frame.tag());
    }

    /// Low-level lane install (id-indexed over the full store, arbitrary
    /// tag) — prefer [`Self::install_frame`]; kept for tests and custom
    /// pipelines.
    pub fn install_ref_margins(&mut self, full: &[f64], tag: u64) {
        self.workset.install_ref_margins(full, tag);
    }

    /// Row-aligned reference margins — only when the installed lane's tag
    /// matches `tag`, so a stale lane can never feed a screening rule.
    pub fn active_ref_margins(&self, tag: u64) -> Option<&[f64]> {
        self.workset.ref_margins(tag)
    }

    /// `H_L = Σ_{t ∈ L̂} H_t` over the store-rowed L̂ (excludes the
    /// external mass; see [`Self::external_h_l`]).
    pub fn h_l(&self) -> &Mat {
        &self.h_l
    }

    /// The external (row-less) L̂ mass installed by
    /// [`Self::set_external_l`] — zeros unless the streaming pipeline
    /// installed one.
    pub fn external_h_l(&self) -> &Mat {
        &self.ext_h_l
    }

    /// Apply screening decisions (triplet ids). Retires each id from the
    /// workset (O(d) swap-remove) and updates `H_L` incrementally; ids
    /// that are already screened are ignored. Returns how many triplets
    /// were *newly* retired on each side, so callers can skip the
    /// objective re-evaluation when nothing actually changed.
    pub fn apply_screening(&mut self, new_l: &[usize], new_r: &[usize]) -> (usize, usize) {
        let mut applied_l = 0usize;
        let mut applied_r = 0usize;
        for &t in new_l {
            if self.status.get(t) == crate::triplet::TripletStatus::Active {
                self.status.screen_l(t);
                self.workset.retire(t);
                self.h_l_rank2(t, 1.0); // H_L += H_t
                self.n_l += 1;
                applied_l += 1;
            }
        }
        for &t in new_r {
            if self.status.get(t) == crate::triplet::TripletStatus::Active {
                self.status.screen_r(t);
                self.workset.retire(t);
                applied_r += 1;
            } else {
                // keep the L→R conflict panic of StatusVec (an unsafe rule)
                self.status.screen_r(t);
            }
        }
        (applied_l, applied_r)
    }

    /// `H_L += sign · H_t` — the rank-2 update shared by screening a
    /// triplet into L̂ (`sign = 1`) and reviving it out (`sign = −1`).
    /// One kernel for both directions keeps the up- and downdates exact
    /// mirrors: IEEE negation is exact, so a revive applies the bitwise
    /// negation of the screen's summands. A single uninterleaved
    /// screen/revive pair cancels exactly; interleaved cycles leave the
    /// usual a-few-ulps summation residue (well inside every tolerance
    /// the oracle identities assert).
    fn h_l_rank2(&mut self, t: usize, sign: f64) {
        self.h_l.add_h_outer(self.store.a.row(t), self.store.b.row(t), sign);
    }

    /// Constant part of P̃ contributed by L̂ (store-rowed + external):
    /// `(1 − γ/2)|L̂|`.
    fn l_const(&self) -> f64 {
        (1.0 - self.loss.gamma / 2.0) * (self.n_l + self.ext_n_l) as f64
    }

    /// Evaluate P̃, K = Σ α_t H_t and margins at `M`.
    pub fn eval(&self, m: &Mat, engine: &dyn Engine, timers: &mut PhaseTimers) -> EvalOut {
        let n_act = self.workset.len();
        let mut margins = vec![0.0; n_act];
        let (loss_sum, g) = timers.compute.time(|| {
            engine.step(
                m,
                self.workset.a(),
                self.workset.b(),
                self.loss.gamma,
                &mut margins,
            )
        });
        let mut k = g;
        k.axpy(1.0, &self.h_l);
        let mut p = loss_sum + self.l_const() - m.dot(&self.h_l)
            + 0.5 * self.lambda * m.norm_sq();
        if self.ext_n_l > 0 {
            // row-less admission-certified L̂ mass (streaming pipeline);
            // gated so the materialized hot path pays nothing
            k.axpy(1.0, &self.ext_h_l);
            p -= m.dot(&self.ext_h_l);
        }
        EvalOut { p, k, margins }
    }

    /// `∇P̃(M) = λM − K`.
    pub fn grad(&self, m: &Mat, k: &Mat) -> Mat {
        let mut g = m.scaled(self.lambda);
        g.axpy(-1.0, k);
        g
    }

    /// Dual value D̃(α) and `[K]_+` at the dual-feasible point induced by
    /// the active margins (α = −ℓ'(m_t); fixed 1 / 0 on L̂ / R̂).
    ///
    /// Returns `(d_val, k_split)`; the dual iterate is
    /// `M_λ(α) = [K]_+ / λ` (used by CDGB).
    pub fn dual(
        &self,
        margins: &[f64],
        k: &Mat,
        timers: &mut PhaseTimers,
    ) -> (f64, PsdSplit) {
        debug_assert_eq!(margins.len(), self.workset.len());
        let gamma = self.loss.gamma;
        let mut alpha_sq = 0.0;
        let mut alpha_sum = 0.0;
        for &m in margins {
            let a = self.loss.alpha(m);
            alpha_sq += a * a;
            alpha_sum += a;
        }
        let fixed_l = (self.n_l + self.ext_n_l) as f64;
        alpha_sq += fixed_l; // α = 1 on L̂ (store-rowed and external)
        alpha_sum += fixed_l;
        let split = timers.eig.time(|| psd_split(k));
        let d_val =
            -0.5 * gamma * alpha_sq + alpha_sum - split.plus.norm_sq() / (2.0 * self.lambda);
        (d_val, split)
    }

    /// Exact λ_max: above it the all-α=1 solution `M = [ΣH]_+/λ` remains
    /// optimal (every margin stays below the loss's linear-part threshold).
    /// `λ_max = max_t ⟨H_t, [Σ_s H_s]_+⟩ / (1 − γ)`.
    pub fn lambda_max(store: &TripletStore, loss: &Loss, engine: &dyn Engine) -> f64 {
        let ones = vec![1.0; store.len()];
        let sum_h = engine.wgram(&store.a, &store.b, &ones);
        let plus = psd_split(&sum_h).plus;
        let mut hq = vec![0.0; store.len()];
        engine.margins(&plus, &store.a, &store.b, &mut hq);
        let max_hq = hq.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        Self::lambda_max_from_parts(max_hq, loss)
    }

    /// The λ_max closed form from its precomputed numerator
    /// `max_hq = max_t ⟨H_t, [ΣH]_+⟩` — shared with the streamed driver
    /// ([`crate::triplet::TripletMiner::max_margin_streamed`] computes the
    /// numerator without materializing the store), so the two pipelines
    /// can never walk different λ grids because one clamp was edited.
    pub fn lambda_max_from_parts(max_hq: f64, loss: &Loss) -> f64 {
        let denom = (1.0 - loss.gamma).max(1e-12);
        (max_hq / denom).max(1e-12)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::runtime::NativeEngine;
    use crate::util::rng::Pcg64;

    fn setup() -> (TripletStore, Loss) {
        let mut rng = Pcg64::seed(3);
        let ds = synthetic::gaussian_mixture("g", 40, 4, 2, 2.5, &mut rng);
        let store = TripletStore::from_dataset(&ds, 3, &mut rng);
        (store, Loss::smoothed_hinge(0.05))
    }

    /// Brute-force P_λ over ALL triplets (no screening) for cross-checks.
    fn full_primal(store: &TripletStore, loss: &Loss, lambda: f64, m: &Mat) -> f64 {
        let mut p = 0.5 * lambda * m.norm_sq();
        for t in 0..store.len() {
            let margin = m.dot(&store.h_mat(t));
            p += loss.value(margin);
        }
        p
    }

    #[test]
    fn eval_matches_bruteforce_unscreened() {
        let (store, loss) = setup();
        let lambda = 10.0;
        let prob = Problem::new(&store, loss, lambda);
        let engine = NativeEngine::new(2);
        let mut rng = Pcg64::seed(9);
        let mut b = Mat::from_fn(4, 4, |_, _| rng.normal());
        b = b.matmul(&b.transpose()).scaled(0.05); // PSD iterate
        let mut timers = PhaseTimers::default();
        let out = prob.eval(&b, &engine, &mut timers);
        let want = full_primal(&store, &loss, lambda, &b);
        assert!((out.p - want).abs() < 1e-8 * (1.0 + want.abs()));
    }

    #[test]
    fn eval_invariant_under_safe_screening() {
        // Fixing truly-L triplets into L̂ and truly-R into R̂ must keep
        // P̃(M) == P(M) at a point where those conditions hold.
        let (store, loss) = setup();
        let lambda = 5.0;
        let engine = NativeEngine::new(2);
        let mut rng = Pcg64::seed(11);
        let mut b = Mat::from_fn(4, 4, |_, _| rng.normal());
        b = b.matmul(&b.transpose()).scaled(0.02);

        let mut prob = Problem::new(&store, loss, lambda);
        let mut timers = PhaseTimers::default();
        let full = prob.eval(&b, &engine, &mut timers);

        // classify by the margins at b itself (so the fixture is exact at b)
        let mut margins_all = vec![0.0; store.len()];
        engine.margins(&b, &store.a, &store.b, &mut margins_all);
        let new_l: Vec<usize> = (0..store.len())
            .filter(|&t| margins_all[t] < loss.l_threshold() - 1e-9)
            .collect();
        let new_r: Vec<usize> = (0..store.len())
            .filter(|&t| margins_all[t] > loss.r_threshold() + 1e-9)
            .collect();
        prob.apply_screening(&new_l, &new_r);
        assert!(prob.status().n_active() < store.len());
        prob.workset().assert_consistent(&store);
        assert_eq!(prob.workset().len(), prob.status().n_active());

        let reduced = prob.eval(&b, &engine, &mut timers);
        assert!(
            (reduced.p - full.p).abs() < 1e-8 * (1.0 + full.p.abs()),
            "P̃ = {} vs P = {}",
            reduced.p,
            full.p
        );
        // gradients must agree too
        let g_full = prob.grad(&b, &full.k);
        let g_red = prob.grad(&b, &reduced.k);
        assert!(g_full.sub(&g_red).max_abs() < 1e-8);
    }

    #[test]
    fn weak_duality_holds() {
        let (store, loss) = setup();
        let prob = Problem::new(&store, loss, 20.0);
        let engine = NativeEngine::new(2);
        let mut timers = PhaseTimers::default();
        let mut rng = Pcg64::seed(13);
        for _ in 0..5 {
            let mut b = Mat::from_fn(4, 4, |_, _| rng.normal());
            b = b.matmul(&b.transpose()).scaled(rng.uniform() * 0.1);
            let out = prob.eval(&b, &engine, &mut timers);
            let (d, _) = prob.dual(&out.margins, &out.k, &mut timers);
            assert!(d <= out.p + 1e-8, "D={d} > P={}", out.p);
        }
    }

    #[test]
    fn lambda_max_pins_all_alpha_one() {
        let (store, loss) = setup();
        let engine = NativeEngine::new(2);
        let lmax = Problem::lambda_max(&store, &loss, &engine);
        // at λ slightly above λ_max, M = [ΣH]_+/λ has every margin < 1-γ
        let lambda = lmax * 1.01;
        let ones = vec![1.0; store.len()];
        let sum_h = engine.wgram(&store.a, &store.b, &ones);
        let m = crate::linalg::psd_project(&sum_h).scaled(1.0 / lambda);
        let mut margins = vec![0.0; store.len()];
        engine.margins(&m, &store.a, &store.b, &mut margins);
        for (t, &mg) in margins.iter().enumerate() {
            assert!(
                mg <= loss.l_threshold() + 1e-9,
                "t={t}: margin {mg} above 1-gamma at lambda_max*1.01"
            );
        }
        // and at λ somewhat below, at least one margin exceeds it
        let lambda = lmax * 0.5;
        let m = crate::linalg::psd_project(&sum_h).scaled(1.0 / lambda);
        engine.margins(&m, &store.a, &store.b, &mut margins);
        assert!(margins.iter().any(|&mg| mg > loss.l_threshold()));
    }

    #[test]
    fn reset_for_lambda_clears_screening() {
        let (store, loss) = setup();
        let mut prob = Problem::new(&store, loss, 5.0);
        prob.apply_screening(&[0, 1], &[2]);
        assert_eq!(prob.status().n_active(), store.len() - 3);
        assert_eq!(prob.workset().len(), store.len() - 3);
        prob.reset_for_lambda(2.0);
        assert_eq!(prob.status().n_active(), store.len());
        assert_eq!(prob.workset().len(), store.len());
        assert_eq!(prob.lambda, 2.0);
        assert_eq!(prob.h_l().max_abs(), 0.0);
    }

    #[test]
    fn screening_retires_ids_permanently() {
        let (store, loss) = setup();
        let mut prob = Problem::new(&store, loss, 5.0);
        prob.apply_screening(&[4, 9], &[17]);
        for id in [4usize, 9, 17] {
            assert!(!prob.workset().is_active(id));
            assert!(!prob.active_idx().contains(&id));
        }
        // re-applying the same decisions is a no-op
        prob.apply_screening(&[4, 9], &[17]);
        assert_eq!(prob.status().n_active(), store.len() - 3);
        prob.workset().assert_consistent(&store);
    }

    #[test]
    fn retarget_keeps_covered_revives_the_rest() {
        let (store, loss) = setup();
        let mut prob = Problem::new(&store, loss, 5.0);
        // λ=5 decisions: L = {0, 1}, R = {2, 3}
        prob.apply_screening(&[0, 1], &[2, 3]);
        let h_l_before = prob.h_l().clone();
        assert_eq!(prob.workset().len(), store.len() - 4);

        // new λ certifies only 1 (L) and 3 (R), plus fresh coverage of 6 (R)
        let st = prob.retarget_lambda(4.0, &[1], &[3, 6]);
        assert_eq!(prob.lambda, 4.0);
        // 0 and 2 revived (2 copies); 6 newly screened
        assert_eq!(st.revived, 2);
        assert_eq!(st.rows_copied, 2);
        assert_eq!(st.newly_screened, 1);
        assert!(prob.workset().is_active(0));
        assert!(prob.workset().is_active(2));
        assert!(!prob.workset().is_active(1));
        assert!(!prob.workset().is_active(3));
        assert!(!prob.workset().is_active(6));
        assert_eq!(prob.status().get(1), crate::triplet::TripletStatus::ScreenedL);
        assert_eq!(prob.status().get(6), crate::triplet::TripletStatus::ScreenedR);
        assert_eq!(prob.workset().len(), store.len() - 3);
        prob.workset().assert_consistent(&store);

        // H_L now covers exactly {1}: old H_L minus H_0
        let mut want = h_l_before;
        want.axpy(-1.0, &Mat::outer(store.a.row(0)));
        want.axpy(1.0, &Mat::outer(store.b.row(0)));
        assert!(prob.h_l().sub(&want).max_abs() < 1e-12);
        assert_eq!(prob.n_screened_l(), 1);
    }

    #[test]
    fn retarget_empty_coverage_equals_reset() {
        let (store, loss) = setup();
        let mut prob = Problem::new(&store, loss, 5.0);
        prob.apply_screening(&[0, 4, 7], &[2, 9]);
        let st = prob.retarget_lambda(3.0, &[], &[]);
        assert_eq!(st.revived, 5);
        assert_eq!(st.rows_copied, 5);
        assert_eq!(st.newly_screened, 0);
        assert_eq!(prob.workset().len(), store.len());
        assert_eq!(prob.status().n_active(), store.len());
        // interleaved multi-triplet accumulation leaves at most a few
        // ulps of rounding residue in H_L (only a single uninterleaved
        // screen/revive pair cancels bitwise)
        assert!(prob.h_l().max_abs() < 1e-12);
        prob.workset().assert_consistent(&store);
    }

    #[test]
    fn retarget_side_flip_revives_then_retires() {
        // a triplet screened L at the old λ but certified R at the new λ
        // must take the revive → retire path, not corrupt H_L
        let (store, loss) = setup();
        let mut prob = Problem::new(&store, loss, 5.0);
        prob.apply_screening(&[0], &[]);
        let st = prob.retarget_lambda(4.0, &[], &[0]);
        assert_eq!(st.revived, 1);
        assert_eq!(st.newly_screened, 1);
        assert_eq!(prob.status().get(0), crate::triplet::TripletStatus::ScreenedR);
        assert_eq!(prob.n_screened_l(), 0);
        assert_eq!(prob.h_l().max_abs(), 0.0);
        prob.workset().assert_consistent(&store);
    }

    #[test]
    fn retarget_eval_matches_fresh_problem() {
        // the persistent problem after several crossings must evaluate
        // bit-for-tolerance identically to a fresh problem with the same
        // screened sets
        let (store, loss) = setup();
        let engine = NativeEngine::new(2);
        let mut rng = Pcg64::seed(17);
        let mut b = Mat::from_fn(4, 4, |_, _| rng.normal());
        b = b.matmul(&b.transpose()).scaled(0.02);

        let mut persistent = Problem::new(&store, loss, 6.0);
        persistent.apply_screening(&[0, 1, 2], &[5, 6]);
        persistent.retarget_lambda(5.0, &[1, 2], &[6, 8]);
        persistent.retarget_lambda(4.5, &[2], &[8]);

        let mut fresh = Problem::new(&store, loss, 4.5);
        fresh.apply_screening(&[2], &[8]);

        let mut timers = PhaseTimers::default();
        let p_out = persistent.eval(&b, &engine, &mut timers);
        let f_out = fresh.eval(&b, &engine, &mut timers);
        assert!(
            (p_out.p - f_out.p).abs() < 1e-10 * (1.0 + f_out.p.abs()),
            "persistent P̃ {} vs fresh {}",
            p_out.p,
            f_out.p
        );
        assert!(p_out.k.sub(&f_out.k).max_abs() < 1e-10);
        assert_eq!(persistent.workset().len(), fresh.workset().len());
        persistent.workset().assert_consistent(&store);
    }

    #[test]
    fn resume_ingests_grown_store_ids_as_active() {
        // streaming admission: screen some triplets, tear down to state,
        // grow the store, resume — old decisions survive, new ids are
        // active, and evaluation matches a fresh problem on the full set
        let (store, loss) = setup();
        let engine = NativeEngine::new(2);
        let keep = store.len() - 6;
        let mut grown = TripletStore::empty(store.d);
        for t in 0..keep {
            grown.push(store.idx[t], store.a.row(t), store.b.row(t), store.h_norm[t]);
        }
        let mut prob = Problem::new(&grown, loss, 5.0);
        prob.apply_screening(&[0, 2], &[4]);
        let state = prob.into_state();
        assert_eq!(state.len(), keep);
        for t in keep..store.len() {
            grown.push(store.idx[t], store.a.row(t), store.b.row(t), store.h_norm[t]);
        }
        let prob = Problem::resume(&grown, loss, 4.0, state);
        assert_eq!(prob.lambda, 4.0);
        assert_eq!(prob.status().len(), store.len());
        assert_eq!(prob.status().n_active(), store.len() - 3);
        for id in keep..store.len() {
            assert!(prob.workset().is_active(id), "ingested id {id} not active");
        }
        assert!(!prob.workset().is_active(0));
        prob.workset().assert_consistent(&grown);

        // evaluation parity with a from-scratch problem carrying the
        // same decisions over the same (full) store
        let mut fresh = Problem::new(&grown, loss, 4.0);
        fresh.apply_screening(&[0, 2], &[4]);
        let mut rng = Pcg64::seed(23);
        let mut b = Mat::from_fn(4, 4, |_, _| rng.normal());
        b = b.matmul(&b.transpose()).scaled(0.03);
        let mut timers = PhaseTimers::default();
        let p_out = prob.eval(&b, &engine, &mut timers);
        let f_out = fresh.eval(&b, &engine, &mut timers);
        assert!((p_out.p - f_out.p).abs() < 1e-10 * (1.0 + f_out.p.abs()));
        assert!(p_out.k.sub(&f_out.k).max_abs() < 1e-10);
    }

    #[test]
    fn external_l_mass_matches_screened_l() {
        // the row-less external L̂ mass must make the objective, gradient
        // and dual indistinguishable from screening the same triplets
        // into L̂ the ordinary (row-carrying) way
        let (store, loss) = setup();
        let engine = NativeEngine::new(2);
        let lambda = 5.0;
        let ext_ids = [1usize, 3, 8];

        // reference: ordinary screened-L problem over the full store
        let mut with_rows = Problem::new(&store, loss, lambda);
        with_rows.apply_screening(&ext_ids, &[]);

        // streamed analogue: a store WITHOUT those triplets + external mass
        let mut small = TripletStore::empty(store.d);
        for t in 0..store.len() {
            if !ext_ids.contains(&t) {
                small.push(store.idx[t], store.a.row(t), store.b.row(t), store.h_norm[t]);
            }
        }
        let mut h_ext = Mat::zeros(store.d, store.d);
        for &t in &ext_ids {
            h_ext.add_h_outer(store.a.row(t), store.b.row(t), 1.0);
        }
        let mut rowless = Problem::new(&small, loss, lambda);
        rowless.set_external_l(&h_ext, ext_ids.len());
        assert_eq!(rowless.n_external_l(), ext_ids.len());

        let mut rng = Pcg64::seed(29);
        let mut b = Mat::from_fn(4, 4, |_, _| rng.normal());
        b = b.matmul(&b.transpose()).scaled(0.02);
        let mut timers = PhaseTimers::default();
        let a_out = with_rows.eval(&b, &engine, &mut timers);
        let b_out = rowless.eval(&b, &engine, &mut timers);
        assert!(
            (a_out.p - b_out.p).abs() < 1e-9 * (1.0 + a_out.p.abs()),
            "P̃ with rows {} vs row-less {}",
            a_out.p,
            b_out.p
        );
        assert!(a_out.k.sub(&b_out.k).max_abs() < 1e-9);
        let (da, _) = with_rows.dual(&a_out.margins, &a_out.k, &mut timers);
        let (db, _) = rowless.dual(&b_out.margins, &b_out.k, &mut timers);
        assert!((da - db).abs() < 1e-9 * (1.0 + da.abs()), "dual {da} vs {db}");
    }

    #[test]
    fn ref_margin_lane_survives_screening() {
        let (store, loss) = setup();
        let mut prob = Problem::new(&store, loss, 5.0);
        let full: Vec<f64> = (0..store.len()).map(|t| t as f64).collect();
        prob.install_ref_margins(&full, 7);
        prob.apply_screening(&[0, 5, 6], &[1, 2]);
        let lane = prob.active_ref_margins(7).unwrap();
        for (row, &id) in prob.active_idx().iter().enumerate() {
            assert_eq!(lane[row], id as f64);
        }
        // wrong tag: lane invisible (stale-reference protection)
        assert!(prob.active_ref_margins(8).is_none());
    }
}
