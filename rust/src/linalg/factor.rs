//! Low-rank factor `M̃ = LᵀL` — the representation behind the factored
//! screening backend.
//!
//! *Metric Learning in an RKHS* (PAPERS.md) motivates the regime: for
//! very high d the learned metric is naturally low-rank, `M = LᵀL` with
//! `L` an r×d factor, r ≪ d. Everything the screening rules consume is
//! then cheap in factored form:
//!
//! - **margins**: `⟨LᵀL, H_t⟩ = ‖L a_t‖² − ‖L b_t‖²` — O(r) per triplet
//!   after the O(n·d·r) embedding `Z = X·Lᵀ` ([`gemm::embed_into`]),
//!   against the O(d²)-amortized dense GEMM;
//! - **norms**: `‖LᵀL‖_F = ‖L Lᵀ‖_F` (cyclic trace:
//!   `tr(LᵀLLᵀL) = tr((LLᵀ)²)`), so the Frobenius scalar every sphere
//!   bound needs comes from the r×r Gram `G = L Lᵀ` — O(r²·d) once,
//!   O(r²) per query, never a d×d object.
//!
//! [`LowRankFactor::compress`] builds the factor from a dense reference
//! with an **exact** approximation error: the screening layer treats the
//! truncated reference `M̃` as just another approximate reference under
//! the paper's Theorem 3.10 — `‖M̃ − M*‖ ≤ ε + τ` with
//! `τ = ‖M̃ − M‖_F` — so factored screening stays *safe for the true
//! dense problem* by inflating the reference-ball radius by τ (see
//! `runtime/factored.rs`). At r = d the compression keeps the whole
//! (PSD part of the) spectrum, τ is round-off, and factored decisions
//! match dense decisions exactly; at r < d τ is the exactly-known tail
//! mass `√(‖M‖²_F − ‖S_B‖²_F)`.

use super::{gemm, sym_eig, Mat};
use std::sync::atomic::{AtomicU64, Ordering};

/// Monotone version counter distinguishing factor instances (the
/// embedding cache keys on it — see `runtime/factored.rs`).
static FACTOR_VERSION: AtomicU64 = AtomicU64::new(0);

/// Fixed seed of the randomized range finder: compression must be a
/// pure function of `(M, r)` so repeated frame builds (and replays of
/// the same λ-path) reconstruct bit-identical factors.
const RANGE_FINDER_SEED: u64 = 0xFAC7_0EED_5EED_0001;

/// A rank-r factor `L` (stored r×d) of a symmetric PSD approximation
/// `M̃ = LᵀL`, with its r×r Gram `G = L Lᵀ` and Frobenius norm cached.
#[derive(Clone, Debug)]
pub struct LowRankFactor {
    l: Mat,
    gram: Mat,
    norm: f64,
    version: u64,
}

impl LowRankFactor {
    /// Wrap an explicit r×d factor, caching its Gram and norm.
    pub fn from_l(l: Mat) -> LowRankFactor {
        let gram = row_gram(&l);
        let norm = gram.norm();
        LowRankFactor {
            l,
            gram,
            norm,
            version: FACTOR_VERSION.fetch_add(1, Ordering::Relaxed) + 1,
        }
    }

    /// Compress a symmetric d×d reference to rank `r`, returning the
    /// factor and the **exact** approximation error
    /// `τ = ‖M − LᵀL‖_F` (plus a deterministic floating-point envelope
    /// `2d·ε_machine·‖M‖_F` covering the round-off of the error
    /// accounting itself).
    ///
    /// - `r = d`: direct eigendecomposition; `L = Λ₊^{1/2}Vᵀ` keeps the
    ///   whole PSD part, `τ² = Σ_{λ<0} λ²` exactly (≈ 0 for the PSD
    ///   references the solver produces).
    /// - `r < d`: seeded randomized range finder (one power iteration,
    ///   twice-reorthogonalized Gram–Schmidt), then the PSD part of the
    ///   small projected matrix `B = QᵀMQ`; `τ² = ‖M‖²_F − ‖S_B‖²_F`
    ///   by the Pythagorean split `⟨M, QS_BQᵀ⟩ = ‖S_B‖²_F`.
    ///
    /// Panics if `r = 0` or `r > d` — callers validate user input first
    /// (see `runtime/factored.rs` `parse_rank`).
    pub fn compress(m: &Mat, r: usize) -> (LowRankFactor, f64) {
        assert!(m.is_square(), "compress needs a square reference");
        let d = m.rows();
        assert!(r >= 1, "rank must be at least 1");
        assert!(r <= d, "rank {r} exceeds the feature dimension {d}");
        let m_norm = m.norm();
        let fp_envelope = 2.0 * d as f64 * f64::EPSILON * m_norm;
        if r == d {
            // exact path: spectral split, keep the PSD part whole
            let e = sym_eig(m);
            let l = Mat::from_fn(d, d, |k, i| {
                e.values[k].max(0.0).sqrt() * e.vectors[(i, k)]
            });
            let tail_sq: f64 = e
                .values
                .iter()
                .map(|&v| v.min(0.0) * v.min(0.0))
                .sum();
            return (LowRankFactor::from_l(l), tail_sq.sqrt() + fp_envelope);
        }
        // randomized range finder, row form (rows are candidate
        // directions): P₁ = ΩᵀM, Q₁ = orth(P₁); one power iteration
        // P₂ = Q₁M, Q = orth(P₂) — M is symmetric, so row- and
        // column-space sketches coincide.
        let mut rng =
            crate::util::rng::Pcg64::seed(RANGE_FINDER_SEED ^ ((d as u64) << 16) ^ (r as u64));
        let omega_t = Mat::from_fn(r, d, |_, _| rng.normal());
        let mut q = omega_t.matmul(m);
        orthonormalize_rows(&mut q);
        let mut q2 = q.matmul(m);
        orthonormalize_rows(&mut q2);
        let q = q2;
        // B = QᵀMQ in row form: T = Q·M (r×d), B = T·Qᵀ (r×r)
        let t = q.matmul(m);
        let mut b = t.matmul(&q.transpose());
        b.symmetrize();
        let eb = sym_eig(&b);
        // PSD part S_B = WΘ₊Wᵀ; factor rows l_k = √θ_k·(w_kᵀQ)
        let wq = eb.vectors.transpose().matmul(&q);
        let l = Mat::from_fn(r, d, |k, i| eb.values[k].max(0.0).sqrt() * wq[(k, i)]);
        let kept_sq: f64 = eb
            .values
            .iter()
            .map(|&v| v.max(0.0) * v.max(0.0))
            .sum();
        let tau = (m.norm_sq() - kept_sq).max(0.0).sqrt() + fp_envelope;
        (LowRankFactor::from_l(l), tau)
    }

    /// The factor rows (r×d).
    pub fn l(&self) -> &Mat {
        &self.l
    }

    /// The cached r×r Gram `G = L Lᵀ`.
    pub fn gram(&self) -> &Mat {
        &self.gram
    }

    /// `‖M̃‖_F = ‖G‖_F` — the O(r²)-per-query norm scalar the sphere
    /// bounds consume (never recomputed from any d×d object).
    pub fn norm(&self) -> f64 {
        self.norm
    }

    /// Factor rank r (rows of `L`).
    pub fn rank(&self) -> usize {
        self.l.rows()
    }

    /// Ambient feature dimension d (columns of `L`).
    pub fn dim(&self) -> usize {
        self.l.cols()
    }

    /// Monotone instance id — embedding caches key on it.
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Embed `n` data rows: `Z = X·Lᵀ` (n×r), through the pool-parallel
    /// panel GEMM (bitwise worker-invariant).
    pub fn embed(&self, x: &Mat, workers: usize) -> Mat {
        let mut z = Mat::zeros(x.rows(), self.rank());
        gemm::embed_parallel(x, &self.l, &mut z, workers);
        z
    }

    /// Reconstruct the dense `M̃ = LᵀL = Σ_k l_k l_kᵀ` through the
    /// single-sided SYRK (upper triangle + mirror — bitwise symmetric,
    /// bitwise worker-invariant).
    pub fn to_dense(&self, workers: usize) -> Mat {
        let (r, d) = (self.rank(), self.dim());
        let mut out = Mat::zeros(d, d);
        let w = vec![1.0; r];
        gemm::ssyrk_upper_parallel(&mut out, &self.l, 0..r, &w, workers);
        gemm::mirror_upper(&mut out);
        out
    }
}

/// Row Gram `G = L Lᵀ` (r×r): each cell one whole [`gemm::dot`] chain,
/// upper triangle + mirror. O(r²·d) — once per factor.
fn row_gram(l: &Mat) -> Mat {
    let r = l.rows();
    let mut g = Mat::zeros(r, r);
    for i in 0..r {
        for j in i..r {
            g[(i, j)] = gemm::dot(l.row(i), l.row(j));
        }
    }
    gemm::mirror_upper(&mut g);
    g
}

/// Twice-through modified Gram–Schmidt over the *rows* of `q`:
/// orthonormal rows on exit (rows that vanish under projection are
/// zeroed — harmless for the range finder, their spectral weight is 0).
fn orthonormalize_rows(q: &mut Mat) {
    let (r, d) = (q.rows(), q.cols());
    for _pass in 0..2 {
        for i in 0..r {
            for j in 0..i {
                let c = gemm::dot(q.row(i), q.row(j));
                if c != 0.0 {
                    for u in 0..d {
                        q[(i, u)] -= c * q[(j, u)];
                    }
                }
            }
            let nrm = gemm::dot(q.row(i), q.row(i)).sqrt();
            if nrm > 1e-300 {
                for u in 0..d {
                    q[(i, u)] /= nrm;
                }
            } else {
                for u in 0..d {
                    q[(i, u)] = 0.0;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{close, forall};
    use crate::util::rng::Pcg64;

    fn rand_psd(rng: &mut Pcg64, d: usize, rank: usize) -> Mat {
        // Σ of `rank` random outer products — PSD with known rank
        let mut m = Mat::zeros(d, d);
        for _ in 0..rank {
            let v: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
            m.axpy(1.0, &Mat::outer(&v));
        }
        m
    }

    #[test]
    fn gram_norm_matches_dense_norm() {
        forall("factor-norm-identity", 16, |rng| {
            let d = 1 + rng.below(20);
            let r = 1 + rng.below(d);
            let l = Mat::from_fn(r, d, |_, _| rng.normal());
            let f = LowRankFactor::from_l(l);
            let dense = f.to_dense(1);
            close(f.norm(), dense.norm(), 1e-10, 1e-10, "‖G‖_F vs ‖LᵀL‖_F")
        });
    }

    #[test]
    fn compress_tau_is_exact_frobenius_error() {
        forall("factor-tau-exact", 12, |rng| {
            let d = 4 + rng.below(16);
            let r = 1 + rng.below(d - 1); // strictly r < d
            let m = rand_psd(rng, d, 2 + rng.below(d));
            let (f, tau) = LowRankFactor::compress(&m, r);
            assert_eq!(f.rank(), r);
            let err = m.sub(&f.to_dense(1)).norm();
            // τ = exact error up to round-off (the √ of a difference of
            // squared norms cancels to ~√ε_machine·‖M‖ when the tail is
            // tiny, hence the absolute term)
            close(tau, err, 1e-6, 1e-7 * (1.0 + m.norm()), "τ vs ‖M − M̃‖_F")
        });
    }

    #[test]
    fn compress_full_rank_is_lossless_on_psd() {
        forall("factor-full-rank", 12, |rng| {
            let d = 1 + rng.below(14);
            let m = rand_psd(rng, d, d + 2);
            let (f, tau) = LowRankFactor::compress(&m, d);
            let err = m.sub(&f.to_dense(1)).max_abs();
            close(err, 0.0, 0.0, 1e-9 * (1.0 + m.max_abs()), "r = d reconstruction")?;
            // τ collapses to the fp envelope on a PSD reference
            if tau > 1e-9 * (1.0 + m.norm()) {
                return Err(format!("τ = {tau} not tiny at r = d on PSD input"));
            }
            Ok(())
        });
    }

    #[test]
    fn compress_captures_low_rank_exactly() {
        // a reference of true rank k is reproduced by any r ≥ k sketch
        let mut rng = Pcg64::seed(7);
        let (d, k) = (24usize, 3usize);
        let m = rand_psd(&mut rng, d, k);
        let (f, tau) = LowRankFactor::compress(&m, 8);
        let err = m.sub(&f.to_dense(1)).norm();
        assert!(err < 1e-8 * m.norm(), "rank-{k} input not captured: {err}");
        assert!(tau < 1e-7 * m.norm(), "τ = {tau} should be near zero");
    }

    #[test]
    fn compress_is_deterministic() {
        let mut rng = Pcg64::seed(9);
        let m = rand_psd(&mut rng, 17, 6);
        let (f1, t1) = LowRankFactor::compress(&m, 5);
        let (f2, t2) = LowRankFactor::compress(&m, 5);
        assert_eq!(t1.to_bits(), t2.to_bits());
        for (a, b) in f1.l().as_slice().iter().zip(f2.l().as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits(), "range finder not deterministic");
        }
    }

    #[test]
    fn embed_margins_match_dense_quad_forms() {
        forall("factor-embed-margins", 12, |rng| {
            let d = 2 + rng.below(16);
            let r = 1 + rng.below(d);
            let n = 1 + rng.below(50);
            let l = Mat::from_fn(r, d, |_, _| rng.normal());
            let f = LowRankFactor::from_l(l);
            let dense = f.to_dense(1);
            let a = Mat::from_fn(n, d, |_, _| rng.normal());
            let b = Mat::from_fn(n, d, |_, _| rng.normal());
            let (za, zb) = (f.embed(&a, 1), f.embed(&b, 1));
            let mut out = vec![0.0; n];
            gemm::embed_margins_into(&za, &zb, 0..n, &mut out);
            for t in 0..n {
                let want = dense.quad_form(a.row(t)) - dense.quad_form(b.row(t));
                close(out[t], want, 1e-9, 1e-9 * (1.0 + want.abs()), "factored margin")?;
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "rank must be at least 1")]
    fn compress_rejects_rank_zero() {
        let m = Mat::identity(4);
        let _ = LowRankFactor::compress(&m, 0);
    }

    #[test]
    #[should_panic(expected = "exceeds the feature dimension")]
    fn compress_rejects_rank_above_dim() {
        let m = Mat::identity(4);
        let _ = LowRankFactor::compress(&m, 5);
    }

    #[test]
    fn versions_are_distinct() {
        let f1 = LowRankFactor::from_l(Mat::identity(3));
        let f2 = LowRankFactor::from_l(Mat::identity(3));
        assert_ne!(f1.version(), f2.version());
    }
}
