//! Row-major dense matrix with the Frobenius-space operations used all
//! over the screening math. Deliberately small: this is a substrate, not a
//! general-purpose linear-algebra library.

use crate::util::parallel;

/// Row-major `rows x cols` matrix of f64.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    /// All-zeros `rows × cols` matrix.
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    /// The `n × n` identity.
    pub fn identity(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Wrap a row-major buffer (length must be `rows · cols`).
    pub fn from_rows(rows: usize, cols: usize, data: Vec<f64>) -> Mat {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Mat { rows, cols, data }
    }

    /// Build elementwise from `f(i, j)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Mat {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    /// Rank-one `x x^T`.
    pub fn outer(x: &[f64]) -> Mat {
        Mat::from_fn(x.len(), x.len(), |i, j| x[i] * x[j])
    }

    /// Row count.
    pub fn rows(&self) -> usize {
        self.rows
    }
    /// Column count.
    pub fn cols(&self) -> usize {
        self.cols
    }
    /// Whether `rows == cols`.
    pub fn is_square(&self) -> bool {
        self.rows == self.cols
    }

    /// Row `i` as a slice.
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }
    /// Row `i` as a mutable slice.
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }
    /// The whole row-major buffer.
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }
    /// The whole row-major buffer, mutable.
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Select a subset of rows (compaction for the active triplet set).
    pub fn select_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (k, &i) in idx.iter().enumerate() {
            out.row_mut(k).copy_from_slice(self.row(i));
        }
        out
    }

    /// Overwrite row `dst` with row `src` in place (no-op when equal).
    pub fn copy_row_within(&mut self, src: usize, dst: usize) {
        if src == dst {
            return;
        }
        let c = self.cols;
        self.data.copy_within(src * c..(src + 1) * c, dst * c);
    }

    /// Drop every row past the first `n` (keeps the allocation).
    pub fn truncate_rows(&mut self, n: usize) {
        assert!(n <= self.rows, "truncate_rows past end");
        self.data.truncate(n * self.cols);
        self.rows = n;
    }

    /// Append a row at the end (O(cols)). The workset *revive* primitive:
    /// a triplet re-entering the reduced problem is pushed back onto
    /// every lane.
    pub fn push_row(&mut self, row: &[f64]) {
        assert_eq!(row.len(), self.cols, "push_row width mismatch");
        self.data.extend_from_slice(row);
        self.rows += 1;
    }

    /// Remove row `i` by moving the last row into its slot (O(cols)).
    /// The workset compaction primitive: order is not preserved.
    pub fn swap_remove_row(&mut self, i: usize) {
        assert!(i < self.rows, "swap_remove_row past end");
        let last = self.rows - 1;
        self.copy_row_within(last, i);
        self.truncate_rows(last);
    }

    /// `Aᵀ` (new allocation).
    pub fn transpose(&self) -> Mat {
        Mat::from_fn(self.cols, self.rows, |i, j| self[(j, i)])
    }

    /// `(A + A^T) / 2` — used to clean accumulated asymmetry.
    pub fn symmetrize(&mut self) {
        assert!(self.is_square());
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let v = 0.5 * (self[(i, j)] + self[(j, i)]);
                self[(i, j)] = v;
                self[(j, i)] = v;
            }
        }
    }

    // -------------------------------------------------- Frobenius algebra

    /// `<A, B> = tr(A^T B)`.
    pub fn dot(&self, other: &Mat) -> f64 {
        debug_assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a * b)
            .sum()
    }

    /// Squared Frobenius norm.
    pub fn norm_sq(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum()
    }

    /// Frobenius norm.
    pub fn norm(&self) -> f64 {
        self.norm_sq().sqrt()
    }

    /// `self *= s` in place.
    pub fn scale(&mut self, s: f64) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    /// `s · self` (new allocation).
    pub fn scaled(&self, s: f64) -> Mat {
        let mut out = self.clone();
        out.scale(s);
        out
    }

    /// `self += sign · (a aᵀ − b bᵀ)` — the rank-2 triplet update shared
    /// by the screened-L aggregate `H_L` and the streaming pipeline's
    /// external L̂ mass. One kernel for both directions keeps up- and
    /// downdates exact mirrors (IEEE negation is exact), so a single
    /// uninterleaved add/remove pair cancels bitwise.
    pub fn add_h_outer(&mut self, a: &[f64], b: &[f64], sign: f64) {
        let d = self.cols;
        debug_assert!(self.rows == d && a.len() == d && b.len() == d);
        for i in 0..d {
            let (ai, bi) = (sign * a[i], sign * b[i]);
            let row = self.row_mut(i);
            for j in 0..d {
                row[j] += ai * a[j] - bi * b[j];
            }
        }
    }

    /// `self += s * other`.
    pub fn axpy(&mut self, s: f64, other: &Mat) {
        debug_assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (x, y) in self.data.iter_mut().zip(&other.data) {
            *x += s * y;
        }
    }

    /// `self + other` (new allocation).
    pub fn add(&self, other: &Mat) -> Mat {
        let mut out = self.clone();
        out.axpy(1.0, other);
        out
    }

    /// `self − other` (new allocation).
    pub fn sub(&self, other: &Mat) -> Mat {
        let mut out = self.clone();
        out.axpy(-1.0, other);
        out
    }

    /// Matrix-vector product `y = A x`.
    pub fn matvec(&self, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), self.cols);
        debug_assert_eq!(y.len(), self.rows);
        for i in 0..self.rows {
            let row = self.row(i);
            let mut acc = 0.0;
            for j in 0..self.cols {
                acc += row[j] * x[j];
            }
            y[i] = acc;
        }
    }

    /// Bilinear form `x^T A x` in O(d²).
    pub fn quad_form(&self, x: &[f64]) -> f64 {
        debug_assert!(self.is_square());
        let mut acc = 0.0;
        for i in 0..self.rows {
            let row = self.row(i);
            let mut rx = 0.0;
            for j in 0..self.cols {
                rx += row[j] * x[j];
            }
            acc += x[i] * rx;
        }
        acc
    }

    /// Dense matmul `self * other`, ikj loop order (cache-friendly for
    /// row-major), parallel over row blocks.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        let workers = parallel::default_threads();
        let a = &self.data;
        let b = &other.data;
        parallel::par_fill(&mut out.data, workers.min(m.max(1)), |range, chunk| {
            // range is over flat cells; recover the row window
            let r0 = range.start / n;
            let r1 = (range.end + n - 1) / n;
            debug_assert_eq!(range.start % n, 0);
            let _ = r1;
            for (local_i, i) in (r0..r0 + chunk.len() / n).enumerate() {
                let crow = &mut chunk[local_i * n..(local_i + 1) * n];
                for kk in 0..k {
                    let aik = a[i * k + kk];
                    if aik == 0.0 {
                        continue;
                    }
                    let brow = &b[kk * n..(kk + 1) * n];
                    for j in 0..n {
                        crow[j] += aik * brow[j];
                    }
                }
            }
        });
        out
    }

    /// Largest absolute entry (∞-norm over elements).
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, x| m.max(x.abs()))
    }

    /// The diagonal as a vector (square matrices only).
    pub fn diag(&self) -> Vec<f64> {
        assert!(self.is_square());
        (0..self.rows).map(|i| self[(i, i)]).collect()
    }

    /// `tr(A)` (square matrices only).
    pub fn trace(&self) -> f64 {
        assert!(self.is_square());
        (0..self.rows).map(|i| self[(i, i)]).sum()
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn randmat(rng: &mut Pcg64, r: usize, c: usize) -> Mat {
        Mat::from_fn(r, c, |_, _| rng.normal())
    }

    #[test]
    fn identity_matmul() {
        let mut rng = Pcg64::seed(1);
        let a = randmat(&mut rng, 7, 7);
        let i = Mat::identity(7);
        let ai = a.matmul(&i);
        assert!(ai.sub(&a).max_abs() < 1e-14);
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Pcg64::seed(2);
        let a = randmat(&mut rng, 13, 5);
        let b = randmat(&mut rng, 5, 9);
        let c = a.matmul(&b);
        for i in 0..13 {
            for j in 0..9 {
                let want: f64 = (0..5).map(|k| a[(i, k)] * b[(k, j)]).sum();
                assert!((c[(i, j)] - want).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn quad_form_matches_matvec() {
        let mut rng = Pcg64::seed(3);
        let a = randmat(&mut rng, 6, 6);
        let x: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
        let mut ax = vec![0.0; 6];
        a.matvec(&x, &mut ax);
        let want: f64 = x.iter().zip(&ax).map(|(xi, yi)| xi * yi).sum();
        assert!((a.quad_form(&x) - want).abs() < 1e-12);
    }

    #[test]
    fn dot_trace_identity() {
        // <A, B> = tr(A^T B)
        let mut rng = Pcg64::seed(4);
        let a = randmat(&mut rng, 5, 5);
        let b = randmat(&mut rng, 5, 5);
        let tr = a.transpose().matmul(&b).trace();
        assert!((a.dot(&b) - tr).abs() < 1e-12);
    }

    #[test]
    fn outer_rank_one() {
        let x = [1.0, -2.0, 3.0];
        let m = Mat::outer(&x);
        assert_eq!(m[(0, 1)], -2.0);
        assert_eq!(m[(1, 2)], -6.0);
        assert!((m.trace() - 14.0).abs() < 1e-14);
    }

    #[test]
    fn select_rows_compacts() {
        let m = Mat::from_fn(4, 2, |i, j| (i * 10 + j) as f64);
        let s = m.select_rows(&[3, 1]);
        assert_eq!(s.row(0), &[30.0, 31.0]);
        assert_eq!(s.row(1), &[10.0, 11.0]);
    }

    #[test]
    fn push_row_appends() {
        let mut m = Mat::from_fn(2, 3, |i, j| (i * 10 + j) as f64);
        m.push_row(&[7.0, 8.0, 9.0]);
        assert_eq!(m.rows(), 3);
        assert_eq!(m.row(2), &[7.0, 8.0, 9.0]);
        assert_eq!(m.row(0), &[0.0, 1.0, 2.0]);
        // push after a swap-remove reuses the freed slot
        m.swap_remove_row(0);
        m.push_row(&[1.0, 1.0, 1.0]);
        assert_eq!(m.rows(), 3);
        assert_eq!(m.row(2), &[1.0, 1.0, 1.0]);
    }

    #[test]
    fn swap_remove_row_compacts() {
        let mut m = Mat::from_fn(4, 3, |i, j| (i * 10 + j) as f64);
        m.swap_remove_row(1);
        assert_eq!(m.rows(), 3);
        assert_eq!(m.row(0), &[0.0, 1.0, 2.0]);
        assert_eq!(m.row(1), &[30.0, 31.0, 32.0]); // last row moved in
        assert_eq!(m.row(2), &[20.0, 21.0, 22.0]);
        // removing the last row is a plain truncation
        m.swap_remove_row(2);
        assert_eq!(m.rows(), 2);
        assert_eq!(m.row(1), &[30.0, 31.0, 32.0]);
    }

    #[test]
    fn symmetrize_symmetric() {
        let mut rng = Pcg64::seed(5);
        let mut a = randmat(&mut rng, 6, 6);
        a.symmetrize();
        for i in 0..6 {
            for j in 0..6 {
                assert_eq!(a[(i, j)], a[(j, i)]);
            }
        }
    }

    #[test]
    fn axpy_and_norms() {
        let a = Mat::from_rows(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        let mut b = Mat::zeros(2, 2);
        b.axpy(2.0, &a);
        assert!((b.norm_sq() - 8.0).abs() < 1e-14);
        assert!((b.norm() - 8.0f64.sqrt()).abs() < 1e-14);
    }
}
