//! Minimum-eigenpair of a symmetric operator via Lanczos.
//!
//! The SDLS screening rule (paper §3.1.2) repeatedly needs `λ_min` and its
//! eigenvector of `Q + y H_ijl`, which has **at most one negative
//! eigenvalue** when `Q ⪰ O` (H has at most one negative eigenvalue).
//! A full eigendecomposition per dual-ascent step would cost O(d³); Lanczos
//! with full reorthogonalization converges in a handful of O(d²) matvecs —
//! exactly the "conjugate gradient method for the Rayleigh quotient" the
//! paper cites [22, 31].

use super::{sym_eig, Mat};
use crate::util::rng::Pcg64;

/// Smallest eigenvalue and (unit) eigenvector of the symmetric matrix `a`.
///
/// `tol` is the residual tolerance on `‖A v − λ v‖`. Falls back to the
/// dense solver for tiny matrices where Lanczos bookkeeping isn't worth it.
pub fn min_eigpair(a: &Mat, tol: f64, max_iter: usize) -> (f64, Vec<f64>) {
    assert!(a.is_square());
    let n = a.rows();
    if n <= 8 {
        let e = sym_eig(a);
        let v = (0..n).map(|i| e.vectors[(i, 0)]).collect();
        return (e.values[0], v);
    }

    // Krylov space for A; we target the *smallest* eigenvalue directly by
    // computing the tridiagonal Rayleigh–Ritz values each iteration.
    let m = max_iter.min(n).max(2);
    let mut q: Vec<Vec<f64>> = Vec::with_capacity(m + 1);
    let mut alpha = Vec::with_capacity(m);
    let mut beta: Vec<f64> = Vec::with_capacity(m);

    // deterministic start vector (seeded RNG keeps runs reproducible)
    let mut rng = Pcg64::seed(0x1a2b3c4d ^ n as u64);
    let mut v0: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    normalize(&mut v0);
    q.push(v0);

    let mut w = vec![0.0; n];
    for j in 0..m {
        a.matvec(&q[j], &mut w);
        let aj: f64 = dotv(&w, &q[j]);
        alpha.push(aj);
        // w -= alpha_j q_j + beta_{j-1} q_{j-1}
        for i in 0..n {
            w[i] -= aj * q[j][i];
        }
        if j > 0 {
            let bj = beta[j - 1];
            for i in 0..n {
                w[i] -= bj * q[j - 1][i];
            }
        }
        // full reorthogonalization (d is small; stability over speed)
        for qk in q.iter() {
            let c = dotv(&w, qk);
            for i in 0..n {
                w[i] -= c * qk[i];
            }
        }
        let bj = norm(&w);

        // Rayleigh–Ritz on the (j+1) tridiagonal
        let (theta, s) = tridiag_min_eig(&alpha, &beta);
        // residual estimate: |beta_j * s_last|
        let resid = bj * s.last().copied().unwrap_or(1.0).abs();
        if resid <= tol || bj <= 1e-14 || j + 1 == m {
            // assemble the Ritz vector
            let mut v = vec![0.0; n];
            for (k, qk) in q.iter().enumerate() {
                let sk = s[k];
                for i in 0..n {
                    v[i] += sk * qk[i];
                }
            }
            normalize(&mut v);
            // one Rayleigh-quotient polish
            a.matvec(&v, &mut w);
            let lam = dotv(&v, &w);
            let _ = theta;
            return (lam, v);
        }
        beta.push(bj);
        let mut qn = w.clone();
        for x in &mut qn {
            *x /= bj;
        }
        q.push(qn);
    }
    unreachable!("loop returns on last iteration");
}

/// Smallest eigenpair of the symmetric tridiagonal (alpha, beta) via the
/// dense solver on the small Krylov matrix (k is tiny).
fn tridiag_min_eig(alpha: &[f64], beta: &[f64]) -> (f64, Vec<f64>) {
    let k = alpha.len();
    let mut t = Mat::zeros(k, k);
    for i in 0..k {
        t[(i, i)] = alpha[i];
        if i + 1 < k {
            t[(i, i + 1)] = beta[i];
            t[(i + 1, i)] = beta[i];
        }
    }
    let e = sym_eig(&t);
    let v = (0..k).map(|i| e.vectors[(i, 0)]).collect();
    (e.values[0], v)
}

fn dotv(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

fn norm(a: &[f64]) -> f64 {
    dotv(a, a).sqrt()
}

fn normalize(a: &mut [f64]) {
    let n = norm(a);
    if n > 0.0 {
        for x in a {
            *x /= n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{close, forall};

    fn rand_sym(rng: &mut Pcg64, n: usize) -> Mat {
        let mut m = Mat::from_fn(n, n, |_, _| rng.normal());
        m.symmetrize();
        m
    }

    #[test]
    fn matches_dense_solver() {
        forall("lanczos-vs-dense", 16, |rng| {
            let n = 4 + rng.below(30);
            let a = rand_sym(rng, n);
            let dense = sym_eig(&a).values[0];
            let (lam, v) = min_eigpair(&a, 1e-10, 200);
            close(lam, dense, 1e-7, 1e-7, "lambda_min")?;
            // eigen-equation residual
            let mut av = vec![0.0; n];
            a.matvec(&v, &mut av);
            let resid: f64 = av
                .iter()
                .zip(&v)
                .map(|(x, y)| (x - lam * y).powi(2))
                .sum::<f64>()
                .sqrt();
            close(resid, 0.0, 0.0, 1e-6 * (1.0 + lam.abs()), "residual")
        });
    }

    #[test]
    fn psd_plus_rank_two_structure() {
        // The SDLS use case: Q PSD + y H with H = aa^T - bb^T.
        let mut rng = Pcg64::seed(77);
        let n = 24;
        let b = Mat::from_fn(n, n, |_, _| rng.normal());
        let q = b.matmul(&b.transpose());
        let av: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let bv: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let h = Mat::outer(&av).sub(&Mat::outer(&bv));
        let x = q.add(&h.scaled(-3.0));
        let dense = sym_eig(&x).values[0];
        let (lam, _) = min_eigpair(&x, 1e-10, 200);
        assert!((lam - dense).abs() < 1e-7 * (1.0 + dense.abs()));
    }

    #[test]
    fn tiny_matrix_dense_path() {
        let a = Mat::from_rows(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let (lam, v) = min_eigpair(&a, 1e-12, 10);
        assert!((lam - 1.0).abs() < 1e-10);
        assert!((v[0] + v[1]).abs() < 1e-8); // eigenvector ∝ (1, -1)
    }
}
