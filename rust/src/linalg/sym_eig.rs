//! Symmetric eigendecomposition.
//!
//! Primary path: Householder tridiagonalization (`tred2`) followed by
//! implicit-shift QL iteration (`tql2`) — the classic EISPACK pair, O(d³)
//! with excellent constants for the d ≤ a-few-hundred regime of metric
//! learning. A cyclic Jacobi solver is kept as an independent oracle for
//! the test suite.
//!
//! Conventions: `A = V diag(w) V^T`, eigenvalues ascending, eigenvectors
//! in the *columns* of `V`.

use super::{gemm, Mat};
use crate::util::pool::ScratchPool;

/// Pool of reusable off-diagonal workspace lanes for tred2/tql2. The
/// sub-diagonal `e` is the decomposition's only true intermediate (`d`
/// and `v` become the returned values/vectors), yet it used to be
/// reallocated on every call — and `Problem::dual` → `psd_split` calls
/// `sym_eig` once per solver iteration, plus once per PSD projection.
/// Lanes are taken/returned around each decomposition (same capped pool
/// the engine workers use, see `util::pool`).
static EIG_SCRATCH: ScratchPool = ScratchPool::new(64);

/// Eigendecomposition result: `a = vectors * diag(values) * vectors^T`.
#[derive(Clone, Debug)]
pub struct SymEig {
    /// Ascending eigenvalues.
    pub values: Vec<f64>,
    /// Orthonormal eigenvectors, column `k` pairs with `values[k]`.
    pub vectors: Mat,
}

impl SymEig {
    /// Reconstruct `V f(Λ) V^T` for an elementwise spectral map `f`.
    ///
    /// Evaluated as a scaled rank-k update `Σ_k f(λ_k)·v_k v_kᵀ` through
    /// the tiled [`gemm::ssyrk_upper_parallel`] panels (upper triangle —
    /// half the FLOPs of the old per-element triple loop — then
    /// [`gemm::mirror_upper`], so the output is bitwise symmetric).
    /// Spectral terms with `f(λ_k) = 0` are skipped outright, preserving
    /// the zero shortcut the PSD projection's `max(λ, 0)` map relies on,
    /// and the band-parallel SYRK keeps whole per-cell chains per
    /// worker, so the result is bitwise identical at any worker count.
    pub fn apply_spectral(&self, f: impl Fn(f64) -> f64) -> Mat {
        let d = self.values.len();
        let mut w = Vec::with_capacity(d);
        let mut kept = Vec::with_capacity(d);
        for k in 0..d {
            let fk = f(self.values[k]);
            if fk != 0.0 {
                w.push(fk);
                kept.push(k);
            }
        }
        // gather the kept eigenvectors (columns of `vectors`) as
        // contiguous rows for the SYRK's streaming access pattern
        let v = Mat::from_fn(kept.len(), d, |r, i| self.vectors[(i, kept[r])]);
        let mut out = Mat::zeros(d, d);
        gemm::ssyrk_upper_parallel(
            &mut out,
            &v,
            0..kept.len(),
            &w,
            crate::util::parallel::default_threads(),
        );
        gemm::mirror_upper(&mut out);
        out
    }
}

/// Eigendecomposition of a symmetric matrix via tred2 + tql2.
///
/// Panics if the QL iteration fails to converge (50 sweeps per eigenvalue;
/// never observed on symmetric input).
pub fn sym_eig(a: &Mat) -> SymEig {
    assert!(a.is_square(), "sym_eig needs a square matrix");
    let n = a.rows();
    if n == 0 {
        return SymEig {
            values: vec![],
            vectors: Mat::zeros(0, 0),
        };
    }
    // v starts as a copy of A and is overwritten with the accumulated
    // orthogonal transform.
    let mut v = a.clone();
    v.symmetrize();
    let mut d = vec![0.0; n];
    let mut e = EIG_SCRATCH.take_zeroed(n);
    tred2(&mut v, &mut d, &mut e);
    tql2(&mut v, &mut d, &mut e);
    EIG_SCRATCH.put(e);
    SymEig {
        values: d,
        vectors: v,
    }
}

/// Householder reduction of `v` (symmetric) to tridiagonal form.
/// On exit: `d` diagonal, `e` sub-diagonal (e[0] = 0), `v` the accumulated
/// transform. Translated from the public-domain EISPACK/JAMA routine.
fn tred2(v: &mut Mat, d: &mut [f64], e: &mut [f64]) {
    let n = d.len();
    for j in 0..n {
        d[j] = v[(n - 1, j)];
    }
    for i in (1..n).rev() {
        // scale to avoid under/overflow
        let mut scale = 0.0;
        let mut h = 0.0;
        for k in 0..i {
            scale += d[k].abs();
        }
        if scale == 0.0 {
            e[i] = d[i - 1];
            for j in 0..i {
                d[j] = v[(i - 1, j)];
                v[(i, j)] = 0.0;
                v[(j, i)] = 0.0;
            }
        } else {
            for k in 0..i {
                d[k] /= scale;
                h += d[k] * d[k];
            }
            let mut f = d[i - 1];
            let mut g = h.sqrt();
            if f > 0.0 {
                g = -g;
            }
            e[i] = scale * g;
            h -= f * g;
            d[i - 1] = f - g;
            for j in 0..i {
                e[j] = 0.0;
            }
            // apply similarity transformation to remaining columns
            for j in 0..i {
                f = d[j];
                v[(j, i)] = f;
                g = e[j] + v[(j, j)] * f;
                for k in (j + 1)..i {
                    g += v[(k, j)] * d[k];
                    e[k] += v[(k, j)] * f;
                }
                e[j] = g;
            }
            f = 0.0;
            for j in 0..i {
                e[j] /= h;
                f += e[j] * d[j];
            }
            let hh = f / (h + h);
            for j in 0..i {
                e[j] -= hh * d[j];
            }
            for j in 0..i {
                f = d[j];
                g = e[j];
                for k in j..i {
                    v[(k, j)] -= f * e[k] + g * d[k];
                }
                d[j] = v[(i - 1, j)];
                v[(i, j)] = 0.0;
            }
        }
        d[i] = h;
    }
    // accumulate transformations
    for i in 0..(n - 1) {
        v[(n - 1, i)] = v[(i, i)];
        v[(i, i)] = 1.0;
        let h = d[i + 1];
        if h != 0.0 {
            for k in 0..=i {
                d[k] = v[(k, i + 1)] / h;
            }
            for j in 0..=i {
                let mut g = 0.0;
                for k in 0..=i {
                    g += v[(k, i + 1)] * v[(k, j)];
                }
                for k in 0..=i {
                    v[(k, j)] -= g * d[k];
                }
            }
        }
        for k in 0..=i {
            v[(k, i + 1)] = 0.0;
        }
    }
    for j in 0..n {
        d[j] = v[(n - 1, j)];
        v[(n - 1, j)] = 0.0;
    }
    v[(n - 1, n - 1)] = 1.0;
    e[0] = 0.0;
}

/// Implicit-shift QL on the tridiagonal (d, e), accumulating eigenvectors
/// into `v`. Eigenvalues returned ascending in `d`.
fn tql2(v: &mut Mat, d: &mut [f64], e: &mut [f64]) {
    let n = d.len();
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;

    let mut f = 0.0f64;
    let mut tst1 = 0.0f64;
    let eps = f64::EPSILON;
    for l in 0..n {
        tst1 = tst1.max(d[l].abs() + e[l].abs());
        let mut m = l;
        while m < n {
            if e[m].abs() <= eps * tst1 {
                break;
            }
            m += 1;
        }
        if m > l {
            let mut iter = 0;
            loop {
                iter += 1;
                assert!(iter <= 50, "tql2 failed to converge");
                // implicit shift
                let mut g = d[l];
                let mut p = (d[l + 1] - g) / (2.0 * e[l]);
                let mut r = (p * p + 1.0).sqrt();
                if p < 0.0 {
                    r = -r;
                }
                d[l] = e[l] / (p + r);
                d[l + 1] = e[l] * (p + r);
                let dl1 = d[l + 1];
                let mut h = g - d[l];
                for i in (l + 2)..n {
                    d[i] -= h;
                }
                f += h;
                // QL sweep
                p = d[m];
                let mut c = 1.0;
                let mut c2 = c;
                let mut c3 = c;
                let el1 = e[l + 1];
                let mut s = 0.0;
                let mut s2 = 0.0;
                for i in (l..m).rev() {
                    c3 = c2;
                    c2 = c;
                    s2 = s;
                    g = c * e[i];
                    h = c * p;
                    r = (p * p + e[i] * e[i]).sqrt();
                    e[i + 1] = s * r;
                    s = e[i] / r;
                    c = p / r;
                    p = c * d[i] - s * g;
                    d[i + 1] = h + s * (c * g + s * d[i]);
                    // accumulate
                    for k in 0..n {
                        h = v[(k, i + 1)];
                        v[(k, i + 1)] = s * v[(k, i)] + c * h;
                        v[(k, i)] = c * v[(k, i)] - s * h;
                    }
                }
                p = -s * s2 * c3 * el1 * e[l] / dl1;
                e[l] = s * p;
                d[l] = c * p;
                if e[l].abs() <= eps * tst1 {
                    break;
                }
            }
        }
        d[l] += f;
        e[l] = 0.0;
    }

    // sort ascending (selection sort, swapping vector columns)
    for i in 0..n.saturating_sub(1) {
        let mut k = i;
        let mut p = d[i];
        for j in (i + 1)..n {
            if d[j] < p {
                k = j;
                p = d[j];
            }
        }
        if k != i {
            d[k] = d[i];
            d[i] = p;
            for r in 0..n {
                let tmp = v[(r, i)];
                v[(r, i)] = v[(r, k)];
                v[(r, k)] = tmp;
            }
        }
    }
}

/// Cyclic Jacobi eigensolver — slower but independently derived; serves as
/// the oracle for `sym_eig` in tests.
pub fn jacobi_eig(a: &Mat) -> SymEig {
    assert!(a.is_square());
    let n = a.rows();
    let mut m = a.clone();
    m.symmetrize();
    let mut v = Mat::identity(n);
    for _sweep in 0..100 {
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if off.sqrt() <= 1e-14 * m.norm().max(1e-300) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let theta = (m[(q, q)] - m[(p, p)]) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // rotate rows/cols p, q of m
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }
    // extract + sort ascending
    let mut pairs: Vec<(f64, usize)> = (0..n).map(|i| (m[(i, i)], i)).collect();
    pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let values: Vec<f64> = pairs.iter().map(|p| p.0).collect();
    let vectors = Mat::from_fn(n, n, |i, k| v[(i, pairs[k].1)]);
    SymEig { values, vectors }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{close, forall};
    use crate::util::rng::Pcg64;

    fn rand_sym(rng: &mut Pcg64, n: usize) -> Mat {
        let mut m = Mat::from_fn(n, n, |_, _| rng.normal());
        m.symmetrize();
        m
    }

    fn reconstruct(e: &SymEig) -> Mat {
        e.apply_spectral(|x| x)
    }

    #[test]
    fn diagonal_matrix() {
        let a = Mat::from_rows(3, 3, vec![3.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 2.0]);
        let e = sym_eig(&a);
        assert!((e.values[0] - 1.0).abs() < 1e-12);
        assert!((e.values[1] - 2.0).abs() < 1e-12);
        assert!((e.values[2] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 1, 3
        let a = Mat::from_rows(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let e = sym_eig(&a);
        assert!((e.values[0] - 1.0).abs() < 1e-12);
        assert!((e.values[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn reconstruction_and_orthogonality_random() {
        forall("sym_eig-reconstructs", 24, |rng| {
            let n = 1 + rng.below(12);
            let a = rand_sym(rng, n);
            let e = sym_eig(&a);
            // ascending
            for k in 1..n {
                if e.values[k] < e.values[k - 1] - 1e-12 {
                    return Err(format!("values not ascending: {:?}", e.values));
                }
            }
            // V V^T = I
            let vvt = e.vectors.matmul(&e.vectors.transpose());
            close(vvt.sub(&Mat::identity(n)).max_abs(), 0.0, 0.0, 1e-10, "V V^T - I")?;
            // A = V Λ V^T
            let diff = reconstruct(&e).sub(&a).max_abs();
            close(diff, 0.0, 0.0, 1e-10 * (1.0 + a.max_abs()), "reconstruction")
        });
    }

    #[test]
    fn matches_jacobi_oracle() {
        forall("sym_eig-vs-jacobi", 16, |rng| {
            let n = 1 + rng.below(10);
            let a = rand_sym(rng, n);
            let e1 = sym_eig(&a);
            let e2 = jacobi_eig(&a);
            for k in 0..n {
                close(e1.values[k], e2.values[k], 1e-9, 1e-9, "eigenvalue")?;
            }
            Ok(())
        });
    }

    #[test]
    fn eigenvector_equation_holds() {
        let mut rng = Pcg64::seed(42);
        let n = 9;
        let a = rand_sym(&mut rng, n);
        let e = sym_eig(&a);
        for k in 0..n {
            let v: Vec<f64> = (0..n).map(|i| e.vectors[(i, k)]).collect();
            let mut av = vec![0.0; n];
            a.matvec(&v, &mut av);
            for i in 0..n {
                assert!(
                    (av[i] - e.values[k] * v[i]).abs() < 1e-9,
                    "A v != lambda v for k={k}"
                );
            }
        }
    }

    #[test]
    fn rank_one_spectrum() {
        // x x^T has eigenvalues {‖x‖², 0, ..., 0}
        let x = [1.0, 2.0, -1.0, 0.5];
        let a = Mat::outer(&x);
        let e = sym_eig(&a);
        let ns: f64 = x.iter().map(|v| v * v).sum();
        assert!((e.values[3] - ns).abs() < 1e-12);
        for k in 0..3 {
            assert!(e.values[k].abs() < 1e-12);
        }
    }

    #[test]
    fn repeated_eigenvalues() {
        let a = Mat::identity(5).scaled(2.5);
        let e = sym_eig(&a);
        for v in &e.values {
            assert!((v - 2.5).abs() < 1e-12);
        }
        let vvt = e.vectors.matmul(&e.vectors.transpose());
        assert!(vvt.sub(&Mat::identity(5)).max_abs() < 1e-12);
    }

    #[test]
    fn trace_preserved() {
        forall("eig-trace", 16, |rng| {
            let n = 2 + rng.below(10);
            let a = rand_sym(rng, n);
            let e = sym_eig(&a);
            close(
                e.values.iter().sum::<f64>(),
                a.trace(),
                1e-10,
                1e-10,
                "tr(A) = sum of eigenvalues",
            )
        });
    }

    #[test]
    fn apply_spectral_matches_naive_oracle() {
        // the tiled SYRK path must reproduce the per-element reference
        // sum (including the f(λ) = 0 skip) and stay bitwise symmetric
        forall("apply_spectral-oracle", 16, |rng| {
            let n = 1 + rng.below(14);
            let a = rand_sym(rng, n);
            let e = sym_eig(&a);
            let maps: [fn(f64) -> f64; 3] = [|x| x, |x| x.max(0.0), |x| x.abs().sqrt()];
            for f in maps {
                let got = e.apply_spectral(f);
                let mut want = Mat::zeros(n, n);
                for k in 0..n {
                    let fk = f(e.values[k]);
                    if fk == 0.0 {
                        continue;
                    }
                    for i in 0..n {
                        for j in 0..n {
                            want[(i, j)] += fk * e.vectors[(i, k)] * e.vectors[(j, k)];
                        }
                    }
                }
                close(
                    got.sub(&want).max_abs(),
                    0.0,
                    0.0,
                    1e-10 * (1.0 + a.max_abs()),
                    "apply_spectral vs naive",
                )?;
                for i in 0..n {
                    for j in 0..n {
                        if got[(i, j)].to_bits() != got[(j, i)].to_bits() {
                            return Err(format!("asymmetry at ({i},{j})"));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn empty_and_one() {
        let e = sym_eig(&Mat::zeros(0, 0));
        assert!(e.values.is_empty());
        let e1 = sym_eig(&Mat::from_rows(1, 1, vec![-4.0]));
        assert_eq!(e1.values, vec![-4.0]);
    }
}
