//! Projections onto the positive semi-definite cone.
//!
//! For symmetric `A = V Λ V^T` the paper's notation (§Notation) splits
//! `A = A_+ + A_-` with `A_+ = V Λ_+ V^T` (the Frobenius projection onto
//! the PSD cone) and `A_- = V Λ_- V^T`; `<A_+, A_-> = 0`.

use super::gemm::mirror_upper;
use super::{sym_eig, Mat};

/// Result of splitting `A` into its PSD and NSD parts.
#[derive(Clone, Debug)]
pub struct PsdSplit {
    /// `[A]_+` — projection onto the PSD cone.
    pub plus: Mat,
    /// `‖[A]_-‖_F²` (needed by PGB without materializing `minus`).
    pub minus_norm_sq: f64,
    /// `[A]_-` — the NSD remainder (`A = plus + minus`).
    pub minus: Mat,
    /// Smallest eigenvalue of `A` (handy for PSD checks).
    pub min_eig: f64,
}

/// Project a symmetric matrix onto the PSD cone, `[A]_+`.
pub fn psd_project(a: &Mat) -> Mat {
    psd_split(a).plus
}

/// Full split `A = [A]_+ + [A]_-`.
///
/// The spectral reconstructions accumulate the **upper triangle only**
/// and mirror once — half the FLOPs, and the outputs are exactly
/// symmetric by construction. That bitwise symmetry is load-bearing:
/// every solver iterate is a `psd_split` output, and the tiled margins
/// kernel's scalar-order-identical summation (see `linalg::gemm`) holds
/// precisely for bitwise-symmetric inputs, which keeps the two compute
/// cores' trajectories identical.
pub fn psd_split(a: &Mat) -> PsdSplit {
    let e = sym_eig(a);
    let d = e.values.len();
    let mut plus = Mat::zeros(d, d);
    let mut minus = Mat::zeros(d, d);
    let mut minus_norm_sq = 0.0;
    for k in 0..d {
        let lk = e.values[k];
        if lk == 0.0 {
            continue;
        }
        let target = if lk > 0.0 { &mut plus } else { &mut minus };
        if lk < 0.0 {
            minus_norm_sq += lk * lk;
        }
        for i in 0..d {
            let vik = e.vectors[(i, k)];
            if vik == 0.0 {
                continue;
            }
            let w = lk * vik;
            for j in i..d {
                target[(i, j)] += w * e.vectors[(j, k)];
            }
        }
    }
    mirror_upper(&mut plus);
    mirror_upper(&mut minus);
    let min_eig = e.values.first().copied().unwrap_or(0.0);
    PsdSplit {
        plus,
        minus_norm_sq,
        minus,
        min_eig,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{close, forall};
    use crate::util::rng::Pcg64;

    fn rand_sym(rng: &mut Pcg64, n: usize) -> Mat {
        let mut m = Mat::from_fn(n, n, |_, _| rng.normal());
        m.symmetrize();
        m
    }

    #[test]
    fn split_reconstructs_and_is_orthogonal() {
        forall("psd-split", 24, |rng| {
            let n = 1 + rng.below(10);
            let a = rand_sym(rng, n);
            let s = psd_split(&a);
            close(
                s.plus.add(&s.minus).sub(&a).max_abs(),
                0.0,
                0.0,
                1e-10,
                "plus + minus = A",
            )?;
            close(s.plus.dot(&s.minus), 0.0, 0.0, 1e-8, "<A+, A-> = 0")?;
            close(
                s.minus.norm_sq(),
                s.minus_norm_sq,
                1e-10,
                1e-10,
                "minus norm cached",
            )?;
            // plus is PSD: all eigenvalues >= -tol
            let e = sym_eig(&s.plus);
            if e.values.iter().any(|&v| v < -1e-9) {
                return Err(format!("plus not PSD: {:?}", e.values));
            }
            Ok(())
        });
    }

    #[test]
    fn projection_is_idempotent() {
        let mut rng = Pcg64::seed(8);
        let a = rand_sym(&mut rng, 7);
        let p1 = psd_project(&a);
        let p2 = psd_project(&p1);
        assert!(p2.sub(&p1).max_abs() < 1e-9);
    }

    #[test]
    fn psd_input_unchanged() {
        let mut rng = Pcg64::seed(9);
        let b = Mat::from_fn(6, 4, |_, _| rng.normal());
        let a = b.matmul(&b.transpose()); // PSD by construction
        let p = psd_project(&a);
        assert!(p.sub(&a).max_abs() < 1e-9 * (1.0 + a.max_abs()));
    }

    #[test]
    fn nsd_input_projects_to_zero() {
        let mut rng = Pcg64::seed(10);
        let b = Mat::from_fn(5, 3, |_, _| rng.normal());
        let a = b.matmul(&b.transpose()).scaled(-1.0);
        let p = psd_project(&a);
        assert!(p.max_abs() < 1e-9);
    }

    #[test]
    fn projection_is_frobenius_nearest() {
        // ‖A - [A]_+‖ <= ‖A - X‖ for sampled PSD X
        let mut rng = Pcg64::seed(11);
        let a = rand_sym(&mut rng, 5);
        let p = psd_project(&a);
        let best = a.sub(&p).norm();
        for _ in 0..20 {
            let b = Mat::from_fn(5, 5, |_, _| rng.normal());
            let x = b.matmul(&b.transpose());
            assert!(a.sub(&x).norm() >= best - 1e-9);
        }
    }
}
