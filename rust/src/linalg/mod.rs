//! Dense linear algebra substrate (LAPACK/BLAS stand-in).
//!
//! Everything the screening machinery needs: a row-major [`Mat`] with
//! Frobenius-space operations, the tiled GEMM/SYRK compute core behind
//! every engine ([`gemm`]: panel-tiled margins + half-FLOP weighted
//! SYRK, embedding GEMM + single-sided scaled SYRK for the low-rank
//! tier), the rank-r factor type [`LowRankFactor`] (`M̃ = LᵀL` with
//! cached r×r Gram and exact compression error), a symmetric eigensolver
//! (Householder tridiagonalization + implicit-shift QL, with a
//! cyclic-Jacobi oracle), positive-semidefinite cone projections
//! `[·]_+ / [·]_-`, and a Lanczos minimum-eigenpair solver used by the
//! SDLS screening rule.

pub mod gemm;
mod factor;
mod mat;
mod sym_eig;
mod psd;
mod lanczos;

pub use factor::LowRankFactor;
pub use lanczos::min_eigpair;
pub use mat::Mat;
pub use psd::{psd_project, psd_split, PsdSplit};
pub use sym_eig::{jacobi_eig, sym_eig, SymEig};
