//! Tiled GEMM/SYRK compute core — the FLOP-bearing kernels behind every
//! engine.
//!
//! The paper's cost model (§5, Table 3) puts the per-iteration solver
//! cost at O(|T_active|·d²), split across exactly two kernels: the
//! triplet margins `⟨M, H_t⟩ = a_tᵀ M a_t − b_tᵀ M b_t` and the gradient
//! accumulation `Σ_t α_t H_t = Aᵀdiag(α)A − Bᵀdiag(α)B`. This module
//! implements both as cache-tiled, SIMD-friendly primitives that the
//! [`crate::runtime::NativeEngine`] (and, through the shared `Engine`
//! trait, the screening manager and the active-set subproblem) route
//! every FLOP through:
//!
//! - **Panel-tiled margins** ([`margins_into`]): rows of `a`/`b` are
//!   processed in panels of [`PANEL_ROWS`]; for each panel the GEMM
//!   `Y = X_panel · M` streams `M` row-by-row, so every loaded row of `M`
//!   is reused [`PANEL_ROWS`] times from L1 while the panel's `Y` scratch
//!   (PANEL_ROWS × d doubles) stays L1/L2-resident, and `M` itself stays
//!   L2-resident for the d ≤ a-few-hundred regime of metric learning.
//!   The inner loops are contiguous `axpy`/`dot` over full rows —
//!   auto-vectorizable, no gather.
//! - **Weighted SYRK** ([`wsyrk_upper`] + [`mirror_upper`]): the gradient
//!   accumulation is symmetric, so only the upper triangle is
//!   accumulated (j ≥ i) — **half the FLOPs** of the scalar rank-1
//!   reference — and mirrored once after the parallel reduction.
//! - **d-blocked panels** ([`margins_into_d_blocked`],
//!   [`wsyrk_upper_d_blocked`]): the row-stream geometry above assumes
//!   the panel `Y` scratch (PANEL_ROWS × d) and the d × d Gram stay
//!   L1/L2-resident — which breaks down for d ≳ 512 (the paper's
//!   higher-dimensional benchmarks: `Y` alone is 192 KB at d = 768 and
//!   the Gram 4.7 MB). The d-blocked variants additionally split the
//!   feature dimension into [`D_BLOCK`]-column blocks: the margins GEMM
//!   computes `Y` one (row-panel × d-block) tile at a time (PANEL_ROWS ×
//!   D_BLOCK scratch, M streamed in D_BLOCK-wide row slices) and the
//!   SYRK accumulates the upper triangle one D_BLOCK × D_BLOCK Gram tile
//!   at a time, streaming `a`/`b` column slices through it — every hot
//!   buffer is cache-sized *independently of d*.
//! - **Band-parallel SYRK** ([`wsyrk_upper_parallel`],
//!   [`wsyrk_upper_d_blocked_parallel`]): the upper-triangle rows are
//!   partitioned by [`syrk_bands`] into cell-balanced contiguous bands,
//!   one per pool worker, each accumulating its disjoint row slice of
//!   the Gram outright. No worker ever holds a *partial* accumulator
//!   for a cell — every `Σ_t` chain lives whole inside one band — so
//!   N-worker output is **bitwise identical** to 1-worker (and to the
//!   serial kernels), the same `==`-on-bits contract the d-blocked
//!   geometry already carries. Margins parallelize in the engine by
//!   [`PANEL_ROWS`]-aligned row chunks (each row's margin is an
//!   independent chain, and aligned chunks keep the panel decomposition
//!   itself worker-invariant).
//!
//! **Element-generic panels + SIMD microkernels.** The panel drivers are
//! generic over the element scalar ([`Elem`]: `f64` for the exact tier,
//! `f32` for the certified bulk tier — see
//! [`crate::runtime::PrecisionTier`]), so both precisions share one body
//! of panel code ([`margins_into_g`], [`margins_into_d_blocked_g`],
//! [`wsyrk_upper_g`]). Their inner loops are three explicit microkernels
//! — [`axpy_mk`], [`axpy2_mk`] (elementwise; any vector width is
//! bitwise-invisible) and the lane-split dot `dot_into_lanes` — whose
//! accumulator width is the compile-time [`LANES`] constant: 1 without
//! the `simd` cargo feature (the *bitwise* scalar-fallback oracle —
//! exactly the pre-SIMD summation chains), 4 with it (independent
//! per-lane chains the autovectorizer maps onto the vector ISA; the
//! microkernels are also the single swap-in point for `std::simd` once
//! portable SIMD stabilizes). Lane assignment is by **global feature
//! index mod LANES** with a fixed left-to-right lane reduction, so the
//! row-stream and d-blocked geometries stay bitwise identical to each
//! other under *either* feature set, for *any* block width.
//!
//! Numerical contract: for a bitwise-symmetric `M` the panel GEMM
//! accumulates the margin in exactly the scalar reference's summation
//! order (ascending j, then ascending i per lane) — parity with
//! the scalar core is at f64 round-off (`rust/tests/kernel_parity.rs`
//! checks 1e-10 on arbitrary shapes, including row counts and dimensions
//! that are not multiples of the panel size), and without the `simd`
//! feature the chains are bit-for-bit the scalar reference's. The
//! d-blocked variants are **bitwise identical** to the row-stream
//! kernels: blocking the columns of `Y` never splits a `Σ_j`
//! accumulation chain (each `y[k][i]` still sums ascending j), the
//! per-panel margin dot visits `i` globally ascending *within each
//! lane* because blocks are walked in order with a carried per-lane
//! accumulator (block phase = start column mod [`LANES`]), and each
//! Gram cell's `Σ_t` chain lives entirely inside one tile with `t`
//! ascending — so core selection can never change a solver trajectory
//! or a screening decision (unit tests here assert `==`, not a
//! tolerance).
//!
//! **Factored kernels** ([`embed_into`], [`embed_margins_into`],
//! [`ssyrk_upper`]): the low-rank backend (`M = LᵀL`, `L` stored r×d —
//! see [`crate::linalg::LowRankFactor`]) needs three more primitives:
//! the embedding GEMM `Z = X·Lᵀ` (panel-tiled like the margins kernel,
//! each factor row reused [`PANEL_ROWS`] times from L1), the O(r)
//! norm-difference margins `‖z_a‖² − ‖z_b‖²` over cached embeddings,
//! and a *single-sided* scaled SYRK `G += Σ_k w_k·v_k v_kᵀ` (upper
//! triangle + [`mirror_upper`], the same half-FLOP geometry as
//! [`wsyrk_upper`]) used for factor reconstruction and
//! `SymEig::apply_spectral`. Every output cell of the embed and
//! embed-margins kernels is one whole [`dot`] chain and the scaled SYRK
//! parallelizes by the same [`syrk_bands`] row bands, so all three are
//! bitwise worker-invariant like the dense kernels.
//!
//! The same tile geometry is mirrored by the PJRT grid: the Pallas
//! kernels dispatch row-blocks with per-block accumulators (and, for
//! high d, feature-dimension blocks), so native-vs-PJRT comparisons
//! measure the backend, not the blocking.

// Under the default single-lane build `LANES` const-folds to 1, turning
// the lane arithmetic below (`% LANES`, `/ LANES * LANES`) into no-ops
// clippy would flag — they are the degenerate case of the generic lane
// splitting, not mistakes, and the real widths appear under the `simd`
// feature (which the lint pass does not build).
#![allow(clippy::modulo_one, clippy::identity_op)]

use super::Mat;

/// Rows of `a`/`b` per tile: the panel's `Y` scratch (PANEL_ROWS × d)
/// stays L1-resident for d ≤ 256 while each streamed row of `M` is
/// reused PANEL_ROWS times. Mirrors the Pallas kernels' row-block size
/// so native and PJRT runs share one grid decomposition.
pub const PANEL_ROWS: usize = 32;

/// Columns per feature-dimension block of the d-blocked kernels: one
/// `Y` tile is PANEL_ROWS × D_BLOCK doubles (32 KB — L1/L2-resident on
/// anything) and one Gram tile D_BLOCK × D_BLOCK doubles (128 KB —
/// L2-resident), independently of d.
pub const D_BLOCK: usize = 128;

/// Feature dimension at which [`crate::runtime::KernelCore::Auto`]
/// switches from the row-stream geometry to the d-blocked one: below
/// this the row-stream panel scratch (PANEL_ROWS · d doubles) still
/// fits L2 comfortably and the d-blocked variant's extra passes over
/// the `a`/`b` panel rows buy nothing.
pub const D_BLOCK_MIN_D: usize = 512;

/// Dot-microkernel accumulator lanes: 1 without the `simd` feature (the
/// bitwise scalar-fallback oracle — summation chains identical to the
/// pre-SIMD kernels), 4 with it (independent per-lane chains, reduced
/// in a fixed left-to-right order). Lane membership of a product term
/// is its **global** feature index mod LANES, so blocked and row-stream
/// geometries agree bitwise under either setting.
pub const LANES: usize = if cfg!(feature = "simd") { 4 } else { 1 };

/// Length of the per-panel lane-accumulator scratch the d-blocked
/// kernels carry across feature blocks: one [`LANES`]-wide accumulator
/// row per panel row. Callers allocating the `acc` scratch size it with
/// this.
pub const PANEL_ACC_LEN: usize = PANEL_ROWS * LANES;

/// Element scalar of the generic panel kernels: `f64` (the exact tier)
/// and `f32` (the certified bulk tier of
/// [`crate::runtime::PrecisionTier::MixedCertified`]) share the panel
/// drivers and microkernels through this trait.
pub trait Elem:
    Copy
    + PartialEq
    + std::ops::Add<Output = Self>
    + std::ops::Sub<Output = Self>
    + std::ops::Mul<Output = Self>
    + std::ops::AddAssign
{
    /// Additive identity (accumulator seed; also the skip sentinel of
    /// the GEMM zero-coefficient shortcut).
    const ZERO: Self;
}

impl Elem for f64 {
    const ZERO: f64 = 0.0;
}

impl Elem for f32 {
    const ZERO: f32 = 0.0;
}

/// FLOPs of one margins pass over `n` rows: two quad forms per row, each
/// a d×d GEMM row (2d²) plus a length-d dot (2d).
pub fn margins_flops(n: usize, d: usize) -> f64 {
    2.0 * n as f64 * (2.0 * (d * d) as f64 + 2.0 * d as f64)
}

/// FLOPs of one weighted-SYRK pass over `n` rows, upper triangle only:
/// d(d+1)/2 cells × 4 flops per row, plus the 2d row scalings — half the
/// 4d² the full rank-1 reference spends.
pub fn wgram_flops(n: usize, d: usize) -> f64 {
    n as f64 * (2.0 * (d * (d + 1)) as f64 + 2.0 * d as f64)
}

/// axpy microkernel: `y[i] += c·m[i]`, walked in [`LANES`]-wide chunks
/// with a scalar tail. Elementwise — no cross-element reduction chain —
/// so the chunking is bitwise-invisible at every LANES.
#[inline(always)]
fn axpy_mk<E: Elem>(y: &mut [E], c: E, m: &[E]) {
    debug_assert_eq!(y.len(), m.len());
    let body = y.len() / LANES * LANES;
    for (yc, mc) in y[..body]
        .chunks_exact_mut(LANES)
        .zip(m[..body].chunks_exact(LANES))
    {
        for v in 0..LANES {
            yc[v] += c * mc[v];
        }
    }
    for (yi, &mi) in y[body..].iter_mut().zip(&m[body..]) {
        *yi += c * mi;
    }
}

/// Fused two-sided axpy microkernel of the SYRK row update:
/// `g[j] += wa·a[j] − wb·b[j]`, [`LANES`]-chunked like [`axpy_mk`] —
/// elementwise, bitwise-invisible chunking.
#[inline(always)]
fn axpy2_mk<E: Elem>(g: &mut [E], wa: E, a: &[E], wb: E, b: &[E]) {
    debug_assert_eq!(g.len(), a.len());
    debug_assert_eq!(g.len(), b.len());
    let body = g.len() / LANES * LANES;
    for ((gc, ac), bc) in g[..body]
        .chunks_exact_mut(LANES)
        .zip(a[..body].chunks_exact(LANES))
        .zip(b[..body].chunks_exact(LANES))
    {
        for v in 0..LANES {
            gc[v] += wa * ac[v] - wb * bc[v];
        }
    }
    for ((gj, &aj), &bj) in g[body..].iter_mut().zip(&a[body..]).zip(&b[body..]) {
        *gj += wa * aj - wb * bj;
    }
}

/// Lane-split dot microkernel: folds `x[u]·y[u]` into
/// `lanes[(phase + u) % LANES]` with each lane's partial sum
/// accumulating in ascending `u`. `phase` is the *global* index of
/// `x[0]` (mod LANES), so a dot split across column blocks — each block
/// calling this with its own phase on a carried `lanes` array — builds
/// exactly the same per-lane chains as one unblocked call: lane
/// membership depends only on the global index.
#[inline(always)]
fn dot_into_lanes<E: Elem>(x: &[E], y: &[E], phase: usize, lanes: &mut [E; LANES]) {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    // scalar head until the next lane-0 boundary …
    let head = ((LANES - phase % LANES) % LANES).min(n);
    let mut lane = phase % LANES;
    for (&xi, &yi) in x[..head].iter().zip(&y[..head]) {
        lanes[lane] += xi * yi;
        lane = (lane + 1) % LANES;
    }
    // … LANES-wide body (chunk element v lands in lane v) …
    let body = (n - head) / LANES * LANES;
    for (xc, yc) in x[head..head + body]
        .chunks_exact(LANES)
        .zip(y[head..head + body].chunks_exact(LANES))
    {
        for v in 0..LANES {
            lanes[v] += xc[v] * yc[v];
        }
    }
    // … scalar tail (shorter than LANES, starting back at lane 0).
    for (v, (&xi, &yi)) in x[head + body..].iter().zip(&y[head + body..]).enumerate() {
        lanes[v] += xi * yi;
    }
}

/// Fixed left-to-right lane reduction `((l₀+l₁)+l₂)+l₃` — the one place
/// the lane partial sums meet, shared by every caller so the chain is
/// identical everywhere. With `LANES = 1` this is the identity.
#[inline(always)]
fn reduce_lanes<E: Elem>(lanes: &[E; LANES]) -> E {
    let mut s = lanes[0];
    for &l in &lanes[1..] {
        s = s + l;
    }
    s
}

/// Panel-tiled margins: `out[k] = a_tᵀ M a_t − b_tᵀ M b_t` for every row
/// `t` in `rows`, written to `out` (aligned with `rows`). `y` is caller
/// scratch, grown to at most `PANEL_ROWS · d` and reusable across calls.
///
/// ```
/// use triplet_screen::linalg::{gemm, Mat};
///
/// let m = Mat::identity(3); // ⟨I, H⟩ = ‖a‖² − ‖b‖²
/// let a = Mat::from_rows(2, 3, vec![1.0, 2.0, 2.0, 0.0, 3.0, 4.0]);
/// let b = Mat::from_rows(2, 3, vec![1.0, 0.0, 0.0, 0.0, 0.0, 5.0]);
/// let (mut out, mut y) = (vec![0.0; 2], Vec::new());
/// gemm::margins_into(&m, &a, &b, 0..2, &mut out, &mut y);
/// assert_eq!(out, vec![8.0, 0.0]);
/// ```
pub fn margins_into(
    mat: &Mat,
    a: &Mat,
    b: &Mat,
    rows: std::ops::Range<usize>,
    out: &mut [f64],
    y: &mut Vec<f64>,
) {
    let d = mat.cols();
    debug_assert!(mat.is_square());
    debug_assert_eq!(a.cols(), d);
    debug_assert_eq!(b.cols(), d);
    margins_into_g(
        mat.as_slice(),
        d,
        a.as_slice(),
        b.as_slice(),
        rows,
        out,
        y,
    );
}

/// Element-generic body of [`margins_into`]: `mat` is a row-major d×d
/// buffer, `a`/`b` row-major with `d` columns (covering at least
/// `rows.end` rows). The f64 instantiation *is* the exact kernel; the
/// f32 instantiation is the bulk pass of the certified mixed-precision
/// tier (callers convert inputs once per pass — O(n·d) against the
/// O(n·d²) kernel).
pub fn margins_into_g<E: Elem>(
    mat: &[E],
    d: usize,
    a: &[E],
    b: &[E],
    rows: std::ops::Range<usize>,
    out: &mut [E],
    y: &mut Vec<E>,
) {
    debug_assert_eq!(mat.len(), d * d);
    debug_assert!(a.len() >= rows.end * d);
    debug_assert!(b.len() >= rows.end * d);
    debug_assert_eq!(out.len(), rows.len());
    if rows.is_empty() {
        return;
    }
    y.resize(PANEL_ROWS.min(rows.len()) * d.max(1), E::ZERO);
    let mut p0 = rows.start;
    while p0 < rows.end {
        let pr = PANEL_ROWS.min(rows.end - p0);
        let chunk = &mut out[p0 - rows.start..p0 - rows.start + pr];
        quad_forms_panel(mat, d, a, p0, pr, chunk, y, true);
        quad_forms_panel(mat, d, b, p0, pr, chunk, y, false);
        p0 += pr;
    }
}

/// One panel of quad forms: `out[k] (= | -=) x_{p0+k}ᵀ M x_{p0+k}`.
#[allow(clippy::too_many_arguments)]
fn quad_forms_panel<E: Elem>(
    mat: &[E],
    d: usize,
    x: &[E],
    p0: usize,
    pr: usize,
    out: &mut [E],
    y: &mut [E],
    assign: bool,
) {
    let yp = &mut y[..pr * d];
    yp.fill(E::ZERO);
    // Y = X_panel · M: stream M one row at a time; each hot M row is
    // multiplied into all pr panel rows before the next row is loaded.
    for j in 0..d {
        let mrow = &mat[j * d..(j + 1) * d];
        for k in 0..pr {
            let c = x[(p0 + k) * d + j];
            if c == E::ZERO {
                continue;
            }
            axpy_mk(&mut yp[k * d..(k + 1) * d], c, mrow);
        }
    }
    for k in 0..pr {
        let xr = &x[(p0 + k) * d..(p0 + k + 1) * d];
        let yr = &yp[k * d..(k + 1) * d];
        let mut lanes = [E::ZERO; LANES];
        dot_into_lanes(xr, yr, 0, &mut lanes);
        let acc = reduce_lanes(&lanes);
        if assign {
            out[k] = acc;
        } else {
            out[k] = out[k] - acc;
        }
    }
}

/// d-blocked panel margins: identical contract (and **bitwise identical
/// output**) to [`margins_into`], but the feature dimension is walked in
/// `d_block`-column blocks so the hot working set — one `Y` tile of
/// `PANEL_ROWS · d_block` doubles (the required `y` capacity) plus a
/// `d_block`-wide slice of each streamed `M` row — is cache-sized
/// independently of d. `acc` is the per-panel margin accumulator lane
/// (grown to `PANEL_ROWS · LANES`); it carries each row's per-lane
/// partial dots across blocks so every lane's `Σ x_i·y_i` chain still
/// visits its `i ≡ lane (mod LANES)` subsequence globally ascending.
///
/// Engines pass [`D_BLOCK`]; the parameter exists so tests can place
/// block boundaries anywhere.
///
/// ```
/// use triplet_screen::linalg::{gemm, Mat};
///
/// let m = Mat::identity(5);
/// let a = Mat::from_rows(1, 5, vec![1.0, 2.0, 0.0, 2.0, 4.0]);
/// let b = Mat::from_rows(1, 5, vec![3.0, 0.0, 0.0, 4.0, 0.0]);
/// let (mut out, mut y, mut acc) = (vec![0.0; 1], Vec::new(), Vec::new());
/// // block width 2 splits d = 5 into blocks of 2 + 2 + 1
/// gemm::margins_into_d_blocked(&m, &a, &b, 0..1, &mut out, &mut y, &mut acc, 2);
/// assert_eq!(out, vec![0.0]); // ‖a‖² = ‖b‖² = 25
/// ```
#[allow(clippy::too_many_arguments)]
pub fn margins_into_d_blocked(
    mat: &Mat,
    a: &Mat,
    b: &Mat,
    rows: std::ops::Range<usize>,
    out: &mut [f64],
    y: &mut Vec<f64>,
    acc: &mut Vec<f64>,
    d_block: usize,
) {
    let d = mat.cols();
    debug_assert!(mat.is_square());
    debug_assert_eq!(a.cols(), d);
    debug_assert_eq!(b.cols(), d);
    margins_into_d_blocked_g(
        mat.as_slice(),
        d,
        a.as_slice(),
        b.as_slice(),
        rows,
        out,
        y,
        acc,
        d_block,
    );
}

/// Element-generic body of [`margins_into_d_blocked`] (see
/// [`margins_into_g`] for the buffer layout contract).
#[allow(clippy::too_many_arguments)]
pub fn margins_into_d_blocked_g<E: Elem>(
    mat: &[E],
    d: usize,
    a: &[E],
    b: &[E],
    rows: std::ops::Range<usize>,
    out: &mut [E],
    y: &mut Vec<E>,
    acc: &mut Vec<E>,
    d_block: usize,
) {
    debug_assert_eq!(mat.len(), d * d);
    debug_assert!(a.len() >= rows.end * d);
    debug_assert!(b.len() >= rows.end * d);
    debug_assert_eq!(out.len(), rows.len());
    assert!(d_block > 0, "d_block must be positive");
    if rows.is_empty() {
        return;
    }
    let bw_max = d_block.min(d.max(1));
    let pr_max = PANEL_ROWS.min(rows.len());
    y.resize(pr_max * bw_max, E::ZERO);
    acc.resize(pr_max * LANES, E::ZERO);
    let mut p0 = rows.start;
    while p0 < rows.end {
        let pr = PANEL_ROWS.min(rows.end - p0);
        let chunk = &mut out[p0 - rows.start..p0 - rows.start + pr];
        quad_forms_panel_d_blocked(mat, d, a, p0, pr, chunk, y, acc, d_block, true);
        quad_forms_panel_d_blocked(mat, d, b, p0, pr, chunk, y, acc, d_block, false);
        p0 += pr;
    }
}

/// One d-blocked panel of quad forms: `out[k] (= | -=) x_{p0+k}ᵀ M
/// x_{p0+k}`, accumulated one `d_block`-column tile of `Y = X_panel · M`
/// at a time. Per-element summation chains are those of
/// [`quad_forms_panel`] exactly: every `y` cell still sums over
/// ascending j, and the margin dot walks the blocks (hence each lane's
/// `i` subsequence) in ascending order through the carried per-row
/// `acc` lane group, with each block's lane phase pinned to its global
/// start column (`c0 % LANES`).
#[allow(clippy::too_many_arguments)]
fn quad_forms_panel_d_blocked<E: Elem>(
    mat: &[E],
    d: usize,
    x: &[E],
    p0: usize,
    pr: usize,
    out: &mut [E],
    y: &mut [E],
    acc: &mut [E],
    d_block: usize,
    assign: bool,
) {
    let accp = &mut acc[..pr * LANES];
    accp.fill(E::ZERO);
    let mut c0 = 0;
    while c0 < d {
        let c1 = (c0 + d_block).min(d);
        let bw = c1 - c0;
        let yb = &mut y[..pr * bw];
        yb.fill(E::ZERO);
        // Y tile = X_panel · M[:, c0..c1]: stream the D_BLOCK-wide slice
        // of each M row; each hot slice is multiplied into all pr panel
        // rows before the next row is loaded.
        for j in 0..d {
            let mrow = &mat[j * d + c0..j * d + c1];
            for k in 0..pr {
                let c = x[(p0 + k) * d + j];
                if c == E::ZERO {
                    continue;
                }
                axpy_mk(&mut yb[k * bw..(k + 1) * bw], c, mrow);
            }
        }
        // fold this block's dot contribution into the carried lanes
        for k in 0..pr {
            let xr = &x[(p0 + k) * d + c0..(p0 + k) * d + c1];
            let yr = &yb[k * bw..(k + 1) * bw];
            let lanes: &mut [E; LANES] =
                (&mut accp[k * LANES..(k + 1) * LANES]).try_into().unwrap();
            dot_into_lanes(xr, yr, c0, lanes);
        }
        c0 = c1;
    }
    for k in 0..pr {
        let lanes: &[E; LANES] = (&accp[k * LANES..(k + 1) * LANES]).try_into().unwrap();
        let s = reduce_lanes(lanes);
        if assign {
            out[k] = s;
        } else {
            out[k] = out[k] - s;
        }
    }
}

/// Weighted SYRK, upper triangle: `G[i][j] += Σ_k w[k]·(a_t[i]a_t[j] −
/// b_t[i]b_t[j])` for `j ≥ i`, `t = rows.start + k`. `w` is aligned with
/// `rows`; zero weights are skipped. The lower triangle is left
/// untouched — call [`mirror_upper`] once after reducing all partial
/// accumulators.
///
/// ```
/// use triplet_screen::linalg::{gemm, Mat};
///
/// let a = Mat::from_rows(1, 2, vec![1.0, 2.0]);
/// let b = Mat::from_rows(1, 2, vec![2.0, 0.0]);
/// let mut g = Mat::zeros(2, 2);
/// gemm::wsyrk_upper(&mut g, &a, &b, 0..1, &[1.0]);
/// gemm::mirror_upper(&mut g);
/// // a·aᵀ − b·bᵀ = [[1,2],[2,4]] − [[4,0],[0,0]]
/// assert_eq!((g[(0, 0)], g[(0, 1)], g[(1, 0)], g[(1, 1)]), (-3.0, 2.0, 2.0, 4.0));
/// ```
pub fn wsyrk_upper(g: &mut Mat, a: &Mat, b: &Mat, rows: std::ops::Range<usize>, w: &[f64]) {
    let d = a.cols();
    debug_assert_eq!(b.cols(), d);
    debug_assert_eq!((g.rows(), g.cols()), (d, d));
    wsyrk_upper_g(g.as_mut_slice(), d, a.as_slice(), b.as_slice(), rows, w);
}

/// Element-generic body of [`wsyrk_upper`]: `g` is a row-major d×d
/// buffer, `a`/`b` row-major with `d` columns. The row update is the
/// [`axpy2_mk`] microkernel — elementwise, so its output is bitwise
/// independent of [`LANES`].
pub fn wsyrk_upper_g<E: Elem>(
    g: &mut [E],
    d: usize,
    a: &[E],
    b: &[E],
    rows: std::ops::Range<usize>,
    w: &[E],
) {
    debug_assert_eq!(g.len(), d * d);
    wsyrk_upper_band_g(g, d, a, b, rows, w, 0..d);
}

/// One horizontal band of [`wsyrk_upper_g`]: accumulate the upper-triangle
/// cells of Gram rows `band` only, into a band-local buffer `g` of
/// `band.len() · d` elements (cell `(i, j)` lands at
/// `(i − band.start)·d + j`). With `band = 0..d` this *is*
/// [`wsyrk_upper_g`]. Each cell's `Σ_t` chain (t ascending, same
/// summands) is untouched by the banding, so any row partition of the
/// triangle reassembles bitwise into the serial result — this is the
/// unit of work the band-parallel driver [`wsyrk_upper_parallel_g`]
/// hands each pool worker.
pub fn wsyrk_upper_band_g<E: Elem>(
    g: &mut [E],
    d: usize,
    a: &[E],
    b: &[E],
    rows: std::ops::Range<usize>,
    w: &[E],
    band: std::ops::Range<usize>,
) {
    debug_assert_eq!(g.len(), band.len() * d);
    debug_assert!(band.end <= d);
    debug_assert!(a.len() >= rows.end * d);
    debug_assert!(b.len() >= rows.end * d);
    debug_assert_eq!(w.len(), rows.len());
    for (k, t) in rows.enumerate() {
        let wt = w[k];
        if wt == E::ZERO {
            continue;
        }
        let (ra, rb) = (&a[t * d..(t + 1) * d], &b[t * d..(t + 1) * d]);
        for i in band.clone() {
            let (wai, wbi) = (wt * ra[i], wt * rb[i]);
            let row0 = (i - band.start) * d;
            axpy2_mk(&mut g[row0 + i..row0 + d], wai, &ra[i..], wbi, &rb[i..]);
        }
    }
}

/// d-blocked weighted SYRK: identical contract (and **bitwise identical
/// output**) to [`wsyrk_upper`], but the upper triangle is accumulated
/// one `d_block × d_block` Gram tile at a time, streaming the matching
/// `a`/`b` column slices through it — so the hot Gram working set is
/// `d_block²` doubles instead of `d²` (4.7 MB at d = 768, far past L2;
/// 128 KB per [`D_BLOCK`] tile). Each Gram cell lives in exactly one
/// tile and its `Σ_t` chain keeps `t` ascending inside that tile, so
/// the summand sequence per cell is exactly [`wsyrk_upper`]'s.
///
/// The trade: `a`/`b` panel rows are re-streamed once per tile-column
/// instead of once total — O(n·d·(d/d_block)) extra loads against
/// O(n·d²) FLOPs, a win as soon as the full Gram stops fitting in
/// cache. Engines pass [`D_BLOCK`]; tests place boundaries anywhere.
pub fn wsyrk_upper_d_blocked(
    g: &mut Mat,
    a: &Mat,
    b: &Mat,
    rows: std::ops::Range<usize>,
    w: &[f64],
    d_block: usize,
) {
    let d = a.cols();
    debug_assert_eq!(b.cols(), d);
    debug_assert_eq!((g.rows(), g.cols()), (d, d));
    wsyrk_upper_d_blocked_band_g(
        g.as_mut_slice(),
        d,
        a.as_slice(),
        b.as_slice(),
        rows,
        w,
        d_block,
        0..d,
    );
}

/// One horizontal band of the d-blocked SYRK (see
/// [`wsyrk_upper_band_g`] for the band-local `g` layout): tile rows walk
/// `band` in `d_block` steps, tile columns walk `j0.max(band tile
/// start)..d` as in [`wsyrk_upper_d_blocked`]. Every Gram cell still
/// lives in exactly one tile with its `Σ_t` chain ascending, so band
/// boundaries — wherever they fall relative to `d_block` — never change
/// a bit of any cell.
#[allow(clippy::too_many_arguments)]
pub fn wsyrk_upper_d_blocked_band_g<E: Elem>(
    g: &mut [E],
    d: usize,
    a: &[E],
    b: &[E],
    rows: std::ops::Range<usize>,
    w: &[E],
    d_block: usize,
    band: std::ops::Range<usize>,
) {
    debug_assert_eq!(g.len(), band.len() * d);
    debug_assert!(band.end <= d);
    debug_assert!(a.len() >= rows.end * d);
    debug_assert!(b.len() >= rows.end * d);
    debug_assert_eq!(w.len(), rows.len());
    assert!(d_block > 0, "d_block must be positive");
    let mut i0 = band.start;
    while i0 < band.end {
        let i1 = (i0 + d_block).min(band.end);
        let mut j0 = i0;
        while j0 < d {
            let j1 = (j0 + d_block).min(d);
            for (k, t) in rows.clone().enumerate() {
                let wt = w[k];
                if wt == E::ZERO {
                    continue;
                }
                let (ra, rb) = (&a[t * d..(t + 1) * d], &b[t * d..(t + 1) * d]);
                for i in i0..i1 {
                    let js = j0.max(i);
                    if js >= j1 {
                        continue;
                    }
                    let (wai, wbi) = (wt * ra[i], wt * rb[i]);
                    let row0 = (i - band.start) * d;
                    axpy2_mk(
                        &mut g[row0 + js..row0 + j1],
                        wai,
                        &ra[js..j1],
                        wbi,
                        &rb[js..j1],
                    );
                }
            }
            j0 = j1;
        }
        i0 = i1;
    }
}

/// Partition the rows of a d×d upper triangle into at most `workers`
/// contiguous bands of near-equal **cell count** `Σ_{i∈band} (d − i)` —
/// the first rows of the triangle are the longest, so an equal-row split
/// would leave the last worker nearly idle. Bands are non-empty, in
/// order, and cover `0..d` exactly; the band list depends only on
/// `(d, workers)`, never on data or scheduling.
pub fn syrk_bands(d: usize, workers: usize) -> Vec<std::ops::Range<usize>> {
    let workers = workers.max(1).min(d.max(1));
    if d == 0 {
        return Vec::new();
    }
    let total = d * (d + 1) / 2;
    let mut out = Vec::with_capacity(workers);
    let mut start = 0usize;
    let mut acc = 0usize;
    for i in 0..d {
        acc += d - i;
        // close band b as soon as the cumulative cell count reaches
        // (b + 1)/workers of the triangle
        if acc * workers >= total * (out.len() + 1) {
            out.push(start..i + 1);
            start = i + 1;
            if out.len() == workers {
                break;
            }
        }
    }
    if start < d {
        out.push(start..d);
    }
    out
}

/// Band-parallel weighted SYRK, element-generic: the upper-triangle rows
/// are split by [`syrk_bands`] and each pool worker accumulates its band
/// directly into its disjoint row slice of `g` via
/// [`wsyrk_upper_band_g`]. Every Gram cell's whole `Σ_t` chain lives in
/// exactly one worker — no partial-accumulator reduction anywhere — so
/// the output is **bitwise identical** to the serial [`wsyrk_upper_g`]
/// at any worker count (and composes with the [`LANES`] microkernels,
/// which are elementwise here).
pub fn wsyrk_upper_parallel_g<E: Elem + Send + Sync>(
    g: &mut [E],
    d: usize,
    a: &[E],
    b: &[E],
    rows: std::ops::Range<usize>,
    w: &[E],
    workers: usize,
) {
    debug_assert_eq!(g.len(), d * d);
    let bands = syrk_bands(d, workers);
    if bands.len() <= 1 {
        wsyrk_upper_g(g, d, a, b, rows, w);
        return;
    }
    // bands are contiguous rows of the row-major `g`, so each worker's
    // slice is a contiguous element range — a clean disjoint split
    let elems: Vec<std::ops::Range<usize>> =
        bands.iter().map(|bd| bd.start * d..bd.end * d).collect();
    crate::util::parallel::par_fill_ranges(g, elems, |r, chunk| {
        wsyrk_upper_band_g(chunk, d, a, b, rows.clone(), w, r.start / d..r.end / d);
    });
}

/// [`wsyrk_upper_parallel_g`] on the f64 [`Mat`] wrapper (the engine's
/// row-stream wgram path).
pub fn wsyrk_upper_parallel(
    g: &mut Mat,
    a: &Mat,
    b: &Mat,
    rows: std::ops::Range<usize>,
    w: &[f64],
    workers: usize,
) {
    let d = a.cols();
    debug_assert_eq!(b.cols(), d);
    debug_assert_eq!((g.rows(), g.cols()), (d, d));
    wsyrk_upper_parallel_g(g.as_mut_slice(), d, a.as_slice(), b.as_slice(), rows, w, workers);
}

/// Band-parallel d-blocked weighted SYRK, element-generic: [`syrk_bands`]
/// rows per worker, each running [`wsyrk_upper_d_blocked_band_g`] over
/// its disjoint row slice. Bitwise identical to
/// [`wsyrk_upper_d_blocked`] — and therefore to [`wsyrk_upper`] — at any
/// worker count (per-cell `Σ_t` chains are tile- and band-independent).
#[allow(clippy::too_many_arguments)]
pub fn wsyrk_upper_d_blocked_parallel_g<E: Elem + Send + Sync>(
    g: &mut [E],
    d: usize,
    a: &[E],
    b: &[E],
    rows: std::ops::Range<usize>,
    w: &[E],
    d_block: usize,
    workers: usize,
) {
    debug_assert_eq!(g.len(), d * d);
    let bands = syrk_bands(d, workers);
    if bands.len() <= 1 {
        wsyrk_upper_d_blocked_band_g(g, d, a, b, rows, w, d_block, 0..d);
        return;
    }
    let elems: Vec<std::ops::Range<usize>> =
        bands.iter().map(|bd| bd.start * d..bd.end * d).collect();
    crate::util::parallel::par_fill_ranges(g, elems, |r, chunk| {
        wsyrk_upper_d_blocked_band_g(
            chunk,
            d,
            a,
            b,
            rows.clone(),
            w,
            d_block,
            r.start / d..r.end / d,
        );
    });
}

/// [`wsyrk_upper_d_blocked_parallel_g`] on the f64 [`Mat`] wrapper (the
/// engine's d-blocked wgram path).
pub fn wsyrk_upper_d_blocked_parallel(
    g: &mut Mat,
    a: &Mat,
    b: &Mat,
    rows: std::ops::Range<usize>,
    w: &[f64],
    d_block: usize,
    workers: usize,
) {
    let d = a.cols();
    debug_assert_eq!(b.cols(), d);
    debug_assert_eq!((g.rows(), g.cols()), (d, d));
    wsyrk_upper_d_blocked_parallel_g(
        g.as_mut_slice(),
        d,
        a.as_slice(),
        b.as_slice(),
        rows,
        w,
        d_block,
        workers,
    );
}

/// Reflect the accumulated upper triangle into the lower half, restoring
/// the full symmetric matrix after a [`wsyrk_upper`] reduction.
pub fn mirror_upper(g: &mut Mat) {
    debug_assert!(g.is_square());
    let d = g.rows();
    for i in 0..d {
        for j in (i + 1)..d {
            g[(j, i)] = g[(i, j)];
        }
    }
}

/// FLOPs of one embedding pass `Z = X·Lᵀ` over `n` rows at rank `r`:
/// one length-d dot (2d FLOPs) per (data row, factor row) pair. Compare
/// with [`margins_flops`]: the factored reference pass costs
/// `2·embed_flops + O(n·r)` against the dense pass's `4·n·d²` — the
/// r/d-fold saving the low-rank backend exists for.
pub fn embed_flops(n: usize, d: usize, r: usize) -> f64 {
    2.0 * n as f64 * d as f64 * r as f64
}

/// Lane-split dot product `Σ_u x[u]·y[u]` with exactly the microkernels'
/// summation chains (lane membership by global index mod [`LANES`],
/// fixed left-to-right lane reduction). One call owns the entire
/// accumulation chain of its result, so any row partition of a caller's
/// output built from whole `dot` calls is bitwise worker-invariant —
/// the contract the factored embed/margins kernels below rely on.
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    let mut lanes = [0.0; LANES];
    dot_into_lanes(x, y, 0, &mut lanes);
    reduce_lanes(&lanes)
}

/// Panel-tiled embedding GEMM `Z = X·Lᵀ`: for every data row `t` in
/// `rows` and factor row `k`, `out[(t − rows.start)·r + k] = ⟨x_t, l_k⟩`
/// (`out` is row-major `rows.len() × r`). Rows of `x` are processed in
/// [`PANEL_ROWS`] panels with `l` streamed row-by-row, so each loaded
/// factor row is reused PANEL_ROWS times from L1 — the margins kernel's
/// geometry with `L` in the role of `M`. Every output cell is one whole
/// [`dot`] chain: cutting `rows` anywhere reassembles bitwise.
///
/// ```
/// use triplet_screen::linalg::{gemm, Mat};
///
/// let x = Mat::from_rows(2, 3, vec![1.0, 2.0, 0.0, 0.0, 1.0, 1.0]);
/// let l = Mat::from_rows(1, 3, vec![3.0, 0.0, 4.0]); // r = 1
/// let mut z = vec![0.0; 2];
/// gemm::embed_into(&x, &l, 0..2, &mut z);
/// assert_eq!(z, vec![3.0, 4.0]);
/// ```
pub fn embed_into(x: &Mat, l: &Mat, rows: std::ops::Range<usize>, out: &mut [f64]) {
    let d = x.cols();
    let r = l.rows();
    debug_assert_eq!(l.cols(), d);
    debug_assert!(x.rows() >= rows.end);
    debug_assert_eq!(out.len(), rows.len() * r);
    let mut p0 = rows.start;
    while p0 < rows.end {
        let pr = PANEL_ROWS.min(rows.end - p0);
        for k in 0..r {
            let lrow = l.row(k);
            for t in 0..pr {
                out[(p0 - rows.start + t) * r + k] = dot(x.row(p0 + t), lrow);
            }
        }
        p0 += pr;
    }
}

/// Pool-parallel [`embed_into`] filling the full `z = x·lᵀ` (n × r):
/// rows are split into [`PANEL_ROWS`]-aligned chunks, one per worker,
/// so the panel decomposition — and with it every bit of `z` — is
/// identical at any worker count.
pub fn embed_parallel(x: &Mat, l: &Mat, z: &mut Mat, workers: usize) {
    let r = l.rows();
    debug_assert_eq!((z.rows(), z.cols()), (x.rows(), r));
    if r == 0 {
        return;
    }
    crate::util::parallel::par_fill_aligned(
        z.as_mut_slice(),
        workers,
        PANEL_ROWS * r,
        |range, chunk| embed_into(x, l, range.start / r..range.end / r, chunk),
    );
}

/// Factored margins from cached embeddings: `out[k] = ‖za_t‖² − ‖zb_t‖²`
/// for every row `t` in `rows` — the O(r) form of the triplet margin,
/// since `⟨LᵀL, H_t⟩ = ‖L a_t‖² − ‖L b_t‖²`. Each row's two norm dots
/// are whole [`dot`] chains, so any row partition is bitwise
/// worker-invariant.
pub fn embed_margins_into(za: &Mat, zb: &Mat, rows: std::ops::Range<usize>, out: &mut [f64]) {
    debug_assert_eq!(za.cols(), zb.cols());
    debug_assert!(za.rows() >= rows.end);
    debug_assert!(zb.rows() >= rows.end);
    debug_assert_eq!(out.len(), rows.len());
    for (k, t) in rows.enumerate() {
        let (ra, rb) = (za.row(t), zb.row(t));
        out[k] = dot(ra, ra) - dot(rb, rb);
    }
}

/// Pool-parallel [`embed_margins_into`]: plain row split (each margin is
/// an independent pair of [`dot`] chains, so no alignment is needed for
/// worker invariance).
pub fn embed_margins_parallel(za: &Mat, zb: &Mat, out: &mut [f64], workers: usize) {
    crate::util::parallel::par_fill(out, workers, |range, chunk| {
        embed_margins_into(za, zb, range, chunk)
    });
}

/// One horizontal band of the single-sided scaled SYRK `G += Σ_k
/// w[k]·v_k v_kᵀ` over the rows `v_k` of `v` (row-major, `d` columns):
/// upper-triangle cells of Gram rows `band` only, into a band-local
/// buffer `g` of `band.len() · d` elements (cell `(i, j)` at
/// `(i − band.start)·d + j`), exactly the [`wsyrk_upper_band_g`] layout.
/// Zero weights are skipped (the `f(λ) = 0` shortcut of
/// `SymEig::apply_spectral`); each cell's `Σ_k` chain lives whole inside
/// one band with `k` ascending, so any row partition reassembles
/// bitwise.
pub fn ssyrk_upper_band_g<E: Elem>(
    g: &mut [E],
    d: usize,
    v: &[E],
    rows: std::ops::Range<usize>,
    w: &[E],
    band: std::ops::Range<usize>,
) {
    debug_assert_eq!(g.len(), band.len() * d);
    debug_assert!(band.end <= d);
    debug_assert!(v.len() >= rows.end * d);
    debug_assert_eq!(w.len(), rows.len());
    for (k, t) in rows.enumerate() {
        let wt = w[k];
        if wt == E::ZERO {
            continue;
        }
        let rv = &v[t * d..(t + 1) * d];
        for i in band.clone() {
            let wvi = wt * rv[i];
            let row0 = (i - band.start) * d;
            axpy_mk(&mut g[row0 + i..row0 + d], wvi, &rv[i..]);
        }
    }
}

/// Single-sided scaled SYRK, upper triangle: `G[i][j] += Σ_k
/// w[k]·v_k[i]·v_k[j]` for `j ≥ i` — half the FLOPs of the rank-1
/// reference, like [`wsyrk_upper`]. Call [`mirror_upper`] once after.
///
/// ```
/// use triplet_screen::linalg::{gemm, Mat};
///
/// let v = Mat::from_rows(1, 2, vec![1.0, 2.0]);
/// let mut g = Mat::zeros(2, 2);
/// gemm::ssyrk_upper(&mut g, &v, 0..1, &[2.0]);
/// gemm::mirror_upper(&mut g);
/// // 2·v·vᵀ = [[2,4],[4,8]]
/// assert_eq!((g[(0, 0)], g[(0, 1)], g[(1, 0)], g[(1, 1)]), (2.0, 4.0, 4.0, 8.0));
/// ```
pub fn ssyrk_upper(g: &mut Mat, v: &Mat, rows: std::ops::Range<usize>, w: &[f64]) {
    let d = v.cols();
    debug_assert_eq!((g.rows(), g.cols()), (d, d));
    ssyrk_upper_band_g(g.as_mut_slice(), d, v.as_slice(), rows, w, 0..d);
}

/// Band-parallel [`ssyrk_upper`]: [`syrk_bands`] rows per pool worker,
/// each accumulating its disjoint row slice outright — whole `Σ_k`
/// chains per worker, so the output is **bitwise identical** to the
/// serial kernel at any worker count.
pub fn ssyrk_upper_parallel(
    g: &mut Mat,
    v: &Mat,
    rows: std::ops::Range<usize>,
    w: &[f64],
    workers: usize,
) {
    let d = v.cols();
    debug_assert_eq!((g.rows(), g.cols()), (d, d));
    let bands = syrk_bands(d, workers);
    if bands.len() <= 1 {
        ssyrk_upper(g, v, rows, w);
        return;
    }
    let elems: Vec<std::ops::Range<usize>> =
        bands.iter().map(|bd| bd.start * d..bd.end * d).collect();
    crate::util::parallel::par_fill_ranges(g.as_mut_slice(), elems, |er, chunk| {
        ssyrk_upper_band_g(
            chunk,
            d,
            v.as_slice(),
            rows.clone(),
            w,
            er.start / d..er.end / d,
        );
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{close, forall};
    use crate::util::rng::Pcg64;

    fn rand_inputs(rng: &mut Pcg64, n: usize, d: usize) -> (Mat, Mat, Mat) {
        let mut m = Mat::from_fn(d, d, |_, _| rng.normal());
        m.symmetrize();
        let a = Mat::from_fn(n, d, |_, _| rng.normal());
        let b = Mat::from_fn(n, d, |_, _| rng.normal());
        (m, a, b)
    }

    #[test]
    fn lane_count_matches_feature() {
        if cfg!(feature = "simd") {
            assert_eq!(LANES, 4);
        } else {
            assert_eq!(LANES, 1);
        }
    }

    #[test]
    fn margins_match_quad_form_oracle() {
        forall("gemm-margins", 24, |rng| {
            // shapes deliberately straddle PANEL_ROWS boundaries
            let d = 1 + rng.below(24);
            let n = 1 + rng.below(3 * PANEL_ROWS + 2);
            let (m, a, b) = rand_inputs(rng, n, d);
            let mut out = vec![0.0; n];
            let mut y = Vec::new();
            margins_into(&m, &a, &b, 0..n, &mut out, &mut y);
            for t in 0..n {
                let want = m.quad_form(a.row(t)) - m.quad_form(b.row(t));
                close(out[t], want, 1e-12, 1e-12, "margin")?;
            }
            Ok(())
        });
    }

    #[test]
    fn margins_subrange_alignment() {
        let mut rng = Pcg64::seed(3);
        let (m, a, b) = rand_inputs(&mut rng, 100, 7);
        let mut full = vec![0.0; 100];
        let mut y = Vec::new();
        margins_into(&m, &a, &b, 0..100, &mut full, &mut y);
        // a sub-range (not panel-aligned) must land in out[0..len]
        let mut part = vec![0.0; 41];
        margins_into(&m, &a, &b, 37..78, &mut part, &mut y);
        for (k, t) in (37..78).enumerate() {
            assert_eq!(part[k], full[t], "sub-range row {t} misaligned");
        }
    }

    #[test]
    fn f32_instantiation_tracks_f64_panels() {
        // the generic drivers share one body: the f32 instantiation must
        // reproduce the f64 margins to f32 round-off on modest inputs
        forall("gemm-f32", 16, |rng| {
            let d = 1 + rng.below(24);
            let n = 1 + rng.below(2 * PANEL_ROWS + 3);
            let (m, a, b) = rand_inputs(rng, n, d);
            let m32: Vec<f32> = m.as_slice().iter().map(|&v| v as f32).collect();
            let a32: Vec<f32> = a.as_slice().iter().map(|&v| v as f32).collect();
            let b32: Vec<f32> = b.as_slice().iter().map(|&v| v as f32).collect();
            let mut out = vec![0.0; n];
            let mut y = Vec::new();
            margins_into(&m, &a, &b, 0..n, &mut out, &mut y);
            let mut out32 = vec![0.0f32; n];
            let mut y32: Vec<f32> = Vec::new();
            margins_into_g(&m32, d, &a32, &b32, 0..n, &mut out32, &mut y32);
            let (mut acc32, mut out32b) = (Vec::new(), vec![0.0f32; n]);
            margins_into_d_blocked_g(
                &m32, d, &a32, &b32, 0..n, &mut out32b, &mut y32, &mut acc32, 3,
            );
            for t in 0..n {
                // loose: f32 arithmetic over ~2d-long chains
                let tol = 1e-4 * (1.0 + d as f64);
                close(out32[t] as f64, out[t], tol, tol, "f32 margin")?;
                // blocked and row-stream f32 agree bitwise, like f64
                if out32b[t].to_bits() != out32[t].to_bits() {
                    return Err(format!("f32 d-blocked split bits at {t}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn wsyrk_matches_outer_sum_oracle() {
        forall("gemm-wsyrk", 24, |rng| {
            let d = 1 + rng.below(12);
            let n = 1 + rng.below(80);
            let (_, a, b) = rand_inputs(rng, n, d);
            let w: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mut g = Mat::zeros(d, d);
            wsyrk_upper(&mut g, &a, &b, 0..n, &w);
            mirror_upper(&mut g);
            let mut want = Mat::zeros(d, d);
            for t in 0..n {
                want.axpy(w[t], &Mat::outer(a.row(t)));
                want.axpy(-w[t], &Mat::outer(b.row(t)));
            }
            close(g.sub(&want).max_abs(), 0.0, 0.0, 1e-10, "wsyrk")
        });
    }

    #[test]
    fn mirror_restores_symmetry() {
        let mut rng = Pcg64::seed(5);
        let (_, a, b) = rand_inputs(&mut rng, 33, 6);
        let w = vec![0.7; 33];
        let mut g = Mat::zeros(6, 6);
        wsyrk_upper(&mut g, &a, &b, 0..33, &w);
        mirror_upper(&mut g);
        for i in 0..6 {
            for j in 0..6 {
                assert_eq!(g[(i, j)], g[(j, i)], "asymmetry at ({i},{j})");
            }
        }
    }

    #[test]
    fn d_blocked_margins_bitwise_match_row_stream() {
        // blocking the feature dimension must not change a single bit:
        // arbitrary shapes, block widths straddling every boundary case
        // (1, smaller than d, equal, larger) — and under the simd
        // feature, block widths not divisible by LANES exercise the
        // lane-phase carry
        forall("gemm-dblock-margins", 24, |rng| {
            let d = 1 + rng.below(40);
            let n = 1 + rng.below(2 * PANEL_ROWS + 3);
            let (m, a, b) = rand_inputs(rng, n, d);
            let mut base = vec![0.0; n];
            let mut y = Vec::new();
            margins_into(&m, &a, &b, 0..n, &mut base, &mut y);
            let mut acc = Vec::new();
            for d_block in [1, 2, 3, d.saturating_sub(1).max(1), d, d + 3] {
                let mut out = vec![0.0; n];
                margins_into_d_blocked(&m, &a, &b, 0..n, &mut out, &mut y, &mut acc, d_block);
                for t in 0..n {
                    if out[t].to_bits() != base[t].to_bits() {
                        return Err(format!(
                            "d={d} block={d_block} t={t}: {} != {}",
                            out[t], base[t]
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn d_blocked_margins_subrange_alignment() {
        let mut rng = Pcg64::seed(4);
        let (m, a, b) = rand_inputs(&mut rng, 90, 11);
        let (mut y, mut acc) = (Vec::new(), Vec::new());
        let mut full = vec![0.0; 90];
        margins_into_d_blocked(&m, &a, &b, 0..90, &mut full, &mut y, &mut acc, 4);
        let mut part = vec![0.0; 33];
        margins_into_d_blocked(&m, &a, &b, 41..74, &mut part, &mut y, &mut acc, 4);
        for (k, t) in (41..74).enumerate() {
            assert_eq!(part[k], full[t], "sub-range row {t} misaligned");
        }
    }

    #[test]
    fn d_blocked_wsyrk_bitwise_matches_row_stream() {
        forall("gemm-dblock-wsyrk", 24, |rng| {
            let d = 1 + rng.below(24);
            let n = 1 + rng.below(60);
            let (_, a, b) = rand_inputs(rng, n, d);
            let w: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mut base = Mat::zeros(d, d);
            wsyrk_upper(&mut base, &a, &b, 0..n, &w);
            for d_block in [1, 3, d.saturating_sub(1).max(1), d, d + 5] {
                let mut g = Mat::zeros(d, d);
                wsyrk_upper_d_blocked(&mut g, &a, &b, 0..n, &w, d_block);
                for i in 0..d {
                    for j in 0..d {
                        if g[(i, j)].to_bits() != base[(i, j)].to_bits() {
                            return Err(format!(
                                "d={d} block={d_block}: cell ({i},{j}) {} != {}",
                                g[(i, j)],
                                base[(i, j)]
                            ));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn syrk_bands_cover_triangle_balanced() {
        for d in [1usize, 2, 5, 17, 64, 300] {
            for w in [1usize, 2, 3, 7, 8, 64] {
                let bands = syrk_bands(d, w);
                assert!(!bands.is_empty());
                assert!(bands.len() <= w.min(d));
                let mut next = 0;
                for bd in &bands {
                    assert_eq!(bd.start, next, "d={d} w={w}");
                    assert!(!bd.is_empty(), "d={d} w={w}: empty band");
                    next = bd.end;
                }
                assert_eq!(next, d, "d={d} w={w}: bands do not cover 0..d");
                // cell counts near-balanced: no band above ~2x the ideal
                // share (the first row alone can force that much at small d)
                if bands.len() == w {
                    let total = d * (d + 1) / 2;
                    for bd in &bands {
                        let cells: usize = bd.clone().map(|i| d - i).sum();
                        assert!(
                            cells * w <= 2 * total + 2 * d * w,
                            "d={d} w={w}: band {bd:?} holds {cells} of {total} cells"
                        );
                    }
                }
            }
        }
        assert!(syrk_bands(0, 4).is_empty());
    }

    #[test]
    fn parallel_wsyrk_bitwise_matches_serial_any_worker_count() {
        // the tentpole determinism contract: every band partition of the
        // triangle reassembles bit-for-bit into the serial SYRK, for both
        // geometries, at worker counts around and past the core count
        forall("gemm-par-wsyrk", 16, |rng| {
            let d = 1 + rng.below(40);
            let n = 1 + rng.below(60);
            let (_, a, b) = rand_inputs(rng, n, d);
            let w: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mut base = Mat::zeros(d, d);
            wsyrk_upper(&mut base, &a, &b, 0..n, &w);
            for workers in [1usize, 2, 7] {
                let mut g = Mat::zeros(d, d);
                wsyrk_upper_parallel(&mut g, &a, &b, 0..n, &w, workers);
                let mut gdb = Mat::zeros(d, d);
                wsyrk_upper_d_blocked_parallel(&mut gdb, &a, &b, 0..n, &w, 7, workers);
                for i in 0..d {
                    for j in i..d {
                        if g[(i, j)].to_bits() != base[(i, j)].to_bits() {
                            return Err(format!(
                                "d={d} workers={workers}: row-stream cell ({i},{j}) split bits"
                            ));
                        }
                        if gdb[(i, j)].to_bits() != base[(i, j)].to_bits() {
                            return Err(format!(
                                "d={d} workers={workers}: d-blocked cell ({i},{j}) split bits"
                            ));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn parallel_wsyrk_f32_bitwise_matches_serial() {
        let mut rng = Pcg64::seed(11);
        let (d, n) = (23usize, 41usize);
        let (_, a, b) = rand_inputs(&mut rng, n, d);
        let a32: Vec<f32> = a.as_slice().iter().map(|&v| v as f32).collect();
        let b32: Vec<f32> = b.as_slice().iter().map(|&v| v as f32).collect();
        let w32: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let mut base = vec![0.0f32; d * d];
        wsyrk_upper_g(&mut base, d, &a32, &b32, 0..n, &w32);
        for workers in [2usize, 7] {
            let mut g = vec![0.0f32; d * d];
            wsyrk_upper_parallel_g(&mut g, d, &a32, &b32, 0..n, &w32, workers);
            let mut gdb = vec![0.0f32; d * d];
            wsyrk_upper_d_blocked_parallel_g(&mut gdb, d, &a32, &b32, 0..n, &w32, 5, workers);
            for i in 0..d {
                for j in i..d {
                    assert_eq!(
                        g[i * d + j].to_bits(),
                        base[i * d + j].to_bits(),
                        "f32 row-stream workers={workers} cell ({i},{j})"
                    );
                    assert_eq!(
                        gdb[i * d + j].to_bits(),
                        base[i * d + j].to_bits(),
                        "f32 d-blocked workers={workers} cell ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn flop_counters_positive_and_scaled() {
        assert!(margins_flops(100, 8) > 0.0);
        assert!(wgram_flops(100, 8) > 0.0);
        // SYRK claims roughly half the full rank-1 cost at large d
        let full = 100.0 * 4.0 * 64.0 * 64.0;
        assert!(wgram_flops(100, 64) < 0.6 * full);
        // margins dominated by 4·n·d²
        assert!((margins_flops(1, 100) - (4.0 * 100.0 * 100.0 + 4.0 * 100.0)).abs() < 1e-9);
        // one embed pass at r = d is half a margins pass (one GEMM, no dot)
        assert!((embed_flops(10, 64, 16) - 2.0 * 10.0 * 64.0 * 16.0).abs() < 1e-9);
    }

    #[test]
    fn dot_matches_scalar_sum() {
        forall("gemm-dot", 16, |rng| {
            let n = rng.below(70);
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let want: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
            close(dot(&x, &y), want, 1e-12, 1e-12, "dot")
        });
    }

    #[test]
    fn embed_matches_matvec_oracle() {
        forall("gemm-embed", 24, |rng| {
            // shapes straddle PANEL_ROWS boundaries; r down to 1
            let d = 1 + rng.below(24);
            let r = 1 + rng.below(d);
            let n = 1 + rng.below(3 * PANEL_ROWS + 2);
            let x = Mat::from_fn(n, d, |_, _| rng.normal());
            let l = Mat::from_fn(r, d, |_, _| rng.normal());
            let mut z = vec![0.0; n * r];
            embed_into(&x, &l, 0..n, &mut z);
            for t in 0..n {
                for k in 0..r {
                    let want: f64 = x.row(t).iter().zip(l.row(k)).map(|(a, b)| a * b).sum();
                    close(z[t * r + k], want, 1e-12, 1e-12, "embed cell")?;
                }
            }
            // sub-range lands at out[0..], like margins_into
            let (lo, hi) = (n / 3, n / 3 + n.div_ceil(2).min(n - n / 3));
            let mut part = vec![0.0; (hi - lo) * r];
            embed_into(&x, &l, lo..hi, &mut part);
            for (k, t) in (lo..hi).enumerate() {
                for c in 0..r {
                    if part[k * r + c].to_bits() != z[t * r + c].to_bits() {
                        return Err(format!("sub-range row {t} col {c} misaligned"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn embed_parallel_bitwise_matches_serial() {
        forall("gemm-embed-par", 12, |rng| {
            let d = 1 + rng.below(20);
            let r = 1 + rng.below(d);
            let n = 1 + rng.below(3 * PANEL_ROWS + 2);
            let x = Mat::from_fn(n, d, |_, _| rng.normal());
            let l = Mat::from_fn(r, d, |_, _| rng.normal());
            let mut base = vec![0.0; n * r];
            embed_into(&x, &l, 0..n, &mut base);
            for workers in [1usize, 2, 7] {
                let mut z = Mat::zeros(n, r);
                embed_parallel(&x, &l, &mut z, workers);
                for (u, (&got, &want)) in z.as_slice().iter().zip(&base).enumerate() {
                    if got.to_bits() != want.to_bits() {
                        return Err(format!("workers={workers} elem {u}: {got} != {want}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn embed_margins_match_norm_oracle_and_worker_invariant() {
        forall("gemm-embed-margins", 16, |rng| {
            let r = 1 + rng.below(12);
            let n = 1 + rng.below(90);
            let za = Mat::from_fn(n, r, |_, _| rng.normal());
            let zb = Mat::from_fn(n, r, |_, _| rng.normal());
            let mut base = vec![0.0; n];
            embed_margins_into(&za, &zb, 0..n, &mut base);
            for t in 0..n {
                let want = za.row(t).iter().map(|v| v * v).sum::<f64>()
                    - zb.row(t).iter().map(|v| v * v).sum::<f64>();
                close(base[t], want, 1e-12, 1e-12, "embed margin")?;
            }
            for workers in [2usize, 7] {
                let mut out = vec![0.0; n];
                embed_margins_parallel(&za, &zb, &mut out, workers);
                for t in 0..n {
                    if out[t].to_bits() != base[t].to_bits() {
                        return Err(format!("workers={workers} t={t} split bits"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn ssyrk_matches_outer_sum_oracle() {
        forall("gemm-ssyrk", 24, |rng| {
            let d = 1 + rng.below(12);
            let n = 1 + rng.below(40);
            let v = Mat::from_fn(n, d, |_, _| rng.normal());
            // mix of negative, zero (skip path) and positive weights
            let w: Vec<f64> = (0..n)
                .map(|_| {
                    if rng.below(4) == 0 {
                        0.0
                    } else {
                        rng.normal()
                    }
                })
                .collect();
            let mut g = Mat::zeros(d, d);
            ssyrk_upper(&mut g, &v, 0..n, &w);
            mirror_upper(&mut g);
            let mut want = Mat::zeros(d, d);
            for t in 0..n {
                want.axpy(w[t], &Mat::outer(v.row(t)));
            }
            close(g.sub(&want).max_abs(), 0.0, 0.0, 1e-10, "ssyrk")
        });
    }

    #[test]
    fn parallel_ssyrk_bitwise_matches_serial_any_worker_count() {
        forall("gemm-par-ssyrk", 12, |rng| {
            let d = 1 + rng.below(40);
            let n = 1 + rng.below(40);
            let v = Mat::from_fn(n, d, |_, _| rng.normal());
            let w: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mut base = Mat::zeros(d, d);
            ssyrk_upper(&mut base, &v, 0..n, &w);
            for workers in [1usize, 2, 7] {
                let mut g = Mat::zeros(d, d);
                ssyrk_upper_parallel(&mut g, &v, 0..n, &w, workers);
                for i in 0..d {
                    for j in i..d {
                        if g[(i, j)].to_bits() != base[(i, j)].to_bits() {
                            return Err(format!(
                                "d={d} workers={workers}: cell ({i},{j}) split bits"
                            ));
                        }
                    }
                }
            }
            Ok(())
        });
    }
}
