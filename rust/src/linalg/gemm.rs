//! Tiled GEMM/SYRK compute core — the FLOP-bearing kernels behind every
//! engine.
//!
//! The paper's cost model (§5, Table 3) puts the per-iteration solver
//! cost at O(|T_active|·d²), split across exactly two kernels: the
//! triplet margins `⟨M, H_t⟩ = a_tᵀ M a_t − b_tᵀ M b_t` and the gradient
//! accumulation `Σ_t α_t H_t = Aᵀdiag(α)A − Bᵀdiag(α)B`. This module
//! implements both as cache-tiled, SIMD-friendly primitives that the
//! [`crate::runtime::NativeEngine`] (and, through the shared `Engine`
//! trait, the screening manager and the active-set subproblem) route
//! every FLOP through:
//!
//! - **Panel-tiled margins** ([`margins_into`]): rows of `a`/`b` are
//!   processed in panels of [`PANEL_ROWS`]; for each panel the GEMM
//!   `Y = X_panel · M` streams `M` row-by-row, so every loaded row of `M`
//!   is reused [`PANEL_ROWS`] times from L1 while the panel's `Y` scratch
//!   (PANEL_ROWS × d doubles) stays L1/L2-resident, and `M` itself stays
//!   L2-resident for the d ≤ a-few-hundred regime of metric learning.
//!   The inner loops are contiguous `axpy`/`dot` over full rows —
//!   auto-vectorizable, no gather.
//! - **Weighted SYRK** ([`wsyrk_upper`] + [`mirror_upper`]): the gradient
//!   accumulation is symmetric, so only the upper triangle is
//!   accumulated (j ≥ i) — **half the FLOPs** of the scalar rank-1
//!   reference — and mirrored once after the parallel reduction.
//!
//! Numerical contract: for a bitwise-symmetric `M` the panel GEMM
//! accumulates the margin in exactly the scalar reference's summation
//! order (ascending j, then ascending i), and the SYRK upper triangle is
//! summand-for-summand the scalar loop's upper triangle — parity with
//! the scalar core is at f64 round-off (`rust/tests/kernel_parity.rs`
//! checks 1e-10 on arbitrary shapes, including row counts and dimensions
//! that are not multiples of the panel size).
//!
//! The same tile geometry is mirrored by the PJRT grid: the Pallas
//! kernels dispatch row-blocks with per-block accumulators, so
//! native-vs-PJRT comparisons measure the backend, not the blocking.

use super::Mat;

/// Rows of `a`/`b` per tile: the panel's `Y` scratch (PANEL_ROWS × d)
/// stays L1-resident for d ≤ 256 while each streamed row of `M` is
/// reused PANEL_ROWS times. Mirrors the Pallas kernels' row-block size
/// so native and PJRT runs share one grid decomposition.
pub const PANEL_ROWS: usize = 32;

/// FLOPs of one margins pass over `n` rows: two quad forms per row, each
/// a d×d GEMM row (2d²) plus a length-d dot (2d).
pub fn margins_flops(n: usize, d: usize) -> f64 {
    2.0 * n as f64 * (2.0 * (d * d) as f64 + 2.0 * d as f64)
}

/// FLOPs of one weighted-SYRK pass over `n` rows, upper triangle only:
/// d(d+1)/2 cells × 4 flops per row, plus the 2d row scalings — half the
/// 4d² the full rank-1 reference spends.
pub fn wgram_flops(n: usize, d: usize) -> f64 {
    n as f64 * (2.0 * (d * (d + 1)) as f64 + 2.0 * d as f64)
}

/// Panel-tiled margins: `out[k] = a_tᵀ M a_t − b_tᵀ M b_t` for every row
/// `t` in `rows`, written to `out` (aligned with `rows`). `y` is caller
/// scratch, grown to at most `PANEL_ROWS · d` and reusable across calls.
pub fn margins_into(
    mat: &Mat,
    a: &Mat,
    b: &Mat,
    rows: std::ops::Range<usize>,
    out: &mut [f64],
    y: &mut Vec<f64>,
) {
    let d = mat.cols();
    debug_assert!(mat.is_square());
    debug_assert_eq!(a.cols(), d);
    debug_assert_eq!(b.cols(), d);
    debug_assert_eq!(out.len(), rows.len());
    if rows.is_empty() {
        return;
    }
    y.resize(PANEL_ROWS.min(rows.len()) * d, 0.0);
    let mut p0 = rows.start;
    while p0 < rows.end {
        let pr = PANEL_ROWS.min(rows.end - p0);
        let chunk = &mut out[p0 - rows.start..p0 - rows.start + pr];
        quad_forms_panel(mat, a, p0, pr, chunk, y, true);
        quad_forms_panel(mat, b, p0, pr, chunk, y, false);
        p0 += pr;
    }
}

/// One panel of quad forms: `out[k] (= | -=) x_{p0+k}ᵀ M x_{p0+k}`.
fn quad_forms_panel(
    mat: &Mat,
    x: &Mat,
    p0: usize,
    pr: usize,
    out: &mut [f64],
    y: &mut [f64],
    assign: bool,
) {
    let d = mat.cols();
    let yp = &mut y[..pr * d];
    yp.fill(0.0);
    // Y = X_panel · M: stream M one row at a time; each hot M row is
    // multiplied into all pr panel rows before the next row is loaded.
    for j in 0..d {
        let mrow = mat.row(j);
        for k in 0..pr {
            let c = x.row(p0 + k)[j];
            if c == 0.0 {
                continue;
            }
            let yrow = &mut yp[k * d..(k + 1) * d];
            for (yi, &mi) in yrow.iter_mut().zip(mrow) {
                *yi += c * mi;
            }
        }
    }
    for k in 0..pr {
        let xr = x.row(p0 + k);
        let yr = &yp[k * d..(k + 1) * d];
        let mut acc = 0.0;
        for (xi, yi) in xr.iter().zip(yr) {
            acc += xi * yi;
        }
        if assign {
            out[k] = acc;
        } else {
            out[k] -= acc;
        }
    }
}

/// Weighted SYRK, upper triangle: `G[i][j] += Σ_k w[k]·(a_t[i]a_t[j] −
/// b_t[i]b_t[j])` for `j ≥ i`, `t = rows.start + k`. `w` is aligned with
/// `rows`; zero weights are skipped. The lower triangle is left
/// untouched — call [`mirror_upper`] once after reducing all partial
/// accumulators.
pub fn wsyrk_upper(g: &mut Mat, a: &Mat, b: &Mat, rows: std::ops::Range<usize>, w: &[f64]) {
    let d = a.cols();
    debug_assert_eq!(b.cols(), d);
    debug_assert_eq!((g.rows(), g.cols()), (d, d));
    debug_assert_eq!(w.len(), rows.len());
    for (k, t) in rows.enumerate() {
        let wt = w[k];
        if wt == 0.0 {
            continue;
        }
        let (ra, rb) = (a.row(t), b.row(t));
        for i in 0..d {
            let (wai, wbi) = (wt * ra[i], wt * rb[i]);
            let grow = &mut g.row_mut(i)[i..];
            for ((gj, &aj), &bj) in grow.iter_mut().zip(&ra[i..]).zip(&rb[i..]) {
                *gj += wai * aj - wbi * bj;
            }
        }
    }
}

/// Reflect the accumulated upper triangle into the lower half, restoring
/// the full symmetric matrix after a [`wsyrk_upper`] reduction.
pub fn mirror_upper(g: &mut Mat) {
    debug_assert!(g.is_square());
    let d = g.rows();
    for i in 0..d {
        for j in (i + 1)..d {
            g[(j, i)] = g[(i, j)];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{close, forall};
    use crate::util::rng::Pcg64;

    fn rand_inputs(rng: &mut Pcg64, n: usize, d: usize) -> (Mat, Mat, Mat) {
        let mut m = Mat::from_fn(d, d, |_, _| rng.normal());
        m.symmetrize();
        let a = Mat::from_fn(n, d, |_, _| rng.normal());
        let b = Mat::from_fn(n, d, |_, _| rng.normal());
        (m, a, b)
    }

    #[test]
    fn margins_match_quad_form_oracle() {
        forall("gemm-margins", 24, |rng| {
            // shapes deliberately straddle PANEL_ROWS boundaries
            let d = 1 + rng.below(24);
            let n = 1 + rng.below(3 * PANEL_ROWS + 2);
            let (m, a, b) = rand_inputs(rng, n, d);
            let mut out = vec![0.0; n];
            let mut y = Vec::new();
            margins_into(&m, &a, &b, 0..n, &mut out, &mut y);
            for t in 0..n {
                let want = m.quad_form(a.row(t)) - m.quad_form(b.row(t));
                close(out[t], want, 1e-12, 1e-12, "margin")?;
            }
            Ok(())
        });
    }

    #[test]
    fn margins_subrange_alignment() {
        let mut rng = Pcg64::seed(3);
        let (m, a, b) = rand_inputs(&mut rng, 100, 7);
        let mut full = vec![0.0; 100];
        let mut y = Vec::new();
        margins_into(&m, &a, &b, 0..100, &mut full, &mut y);
        // a sub-range (not panel-aligned) must land in out[0..len]
        let mut part = vec![0.0; 41];
        margins_into(&m, &a, &b, 37..78, &mut part, &mut y);
        for (k, t) in (37..78).enumerate() {
            assert_eq!(part[k], full[t], "sub-range row {t} misaligned");
        }
    }

    #[test]
    fn wsyrk_matches_outer_sum_oracle() {
        forall("gemm-wsyrk", 24, |rng| {
            let d = 1 + rng.below(12);
            let n = 1 + rng.below(80);
            let (_, a, b) = rand_inputs(rng, n, d);
            let w: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mut g = Mat::zeros(d, d);
            wsyrk_upper(&mut g, &a, &b, 0..n, &w);
            mirror_upper(&mut g);
            let mut want = Mat::zeros(d, d);
            for t in 0..n {
                want.axpy(w[t], &Mat::outer(a.row(t)));
                want.axpy(-w[t], &Mat::outer(b.row(t)));
            }
            close(g.sub(&want).max_abs(), 0.0, 0.0, 1e-10, "wsyrk")
        });
    }

    #[test]
    fn mirror_restores_symmetry() {
        let mut rng = Pcg64::seed(5);
        let (_, a, b) = rand_inputs(&mut rng, 33, 6);
        let w = vec![0.7; 33];
        let mut g = Mat::zeros(6, 6);
        wsyrk_upper(&mut g, &a, &b, 0..33, &w);
        mirror_upper(&mut g);
        for i in 0..6 {
            for j in 0..6 {
                assert_eq!(g[(i, j)], g[(j, i)], "asymmetry at ({i},{j})");
            }
        }
    }

    #[test]
    fn flop_counters_positive_and_scaled() {
        assert!(margins_flops(100, 8) > 0.0);
        assert!(wgram_flops(100, 8) > 0.0);
        // SYRK claims roughly half the full rank-1 cost at large d
        let full = 100.0 * 4.0 * 64.0 * 64.0;
        assert!(wgram_flops(100, 64) < 0.6 * full);
        // margins dominated by 4·n·d²
        assert!((margins_flops(1, 100) - (4.0 * 100.0 * 100.0 + 4.0 * 100.0)).abs() < 1e-9);
    }
}
