//! Tiled GEMM/SYRK compute core — the FLOP-bearing kernels behind every
//! engine.
//!
//! The paper's cost model (§5, Table 3) puts the per-iteration solver
//! cost at O(|T_active|·d²), split across exactly two kernels: the
//! triplet margins `⟨M, H_t⟩ = a_tᵀ M a_t − b_tᵀ M b_t` and the gradient
//! accumulation `Σ_t α_t H_t = Aᵀdiag(α)A − Bᵀdiag(α)B`. This module
//! implements both as cache-tiled, SIMD-friendly primitives that the
//! [`crate::runtime::NativeEngine`] (and, through the shared `Engine`
//! trait, the screening manager and the active-set subproblem) route
//! every FLOP through:
//!
//! - **Panel-tiled margins** ([`margins_into`]): rows of `a`/`b` are
//!   processed in panels of [`PANEL_ROWS`]; for each panel the GEMM
//!   `Y = X_panel · M` streams `M` row-by-row, so every loaded row of `M`
//!   is reused [`PANEL_ROWS`] times from L1 while the panel's `Y` scratch
//!   (PANEL_ROWS × d doubles) stays L1/L2-resident, and `M` itself stays
//!   L2-resident for the d ≤ a-few-hundred regime of metric learning.
//!   The inner loops are contiguous `axpy`/`dot` over full rows —
//!   auto-vectorizable, no gather.
//! - **Weighted SYRK** ([`wsyrk_upper`] + [`mirror_upper`]): the gradient
//!   accumulation is symmetric, so only the upper triangle is
//!   accumulated (j ≥ i) — **half the FLOPs** of the scalar rank-1
//!   reference — and mirrored once after the parallel reduction.
//! - **d-blocked panels** ([`margins_into_d_blocked`],
//!   [`wsyrk_upper_d_blocked`]): the row-stream geometry above assumes
//!   the panel `Y` scratch (PANEL_ROWS × d) and the d × d Gram stay
//!   L1/L2-resident — which breaks down for d ≳ 512 (the paper's
//!   higher-dimensional benchmarks: `Y` alone is 192 KB at d = 768 and
//!   the Gram 4.7 MB). The d-blocked variants additionally split the
//!   feature dimension into [`D_BLOCK`]-column blocks: the margins GEMM
//!   computes `Y` one (row-panel × d-block) tile at a time (PANEL_ROWS ×
//!   D_BLOCK scratch, M streamed in D_BLOCK-wide row slices) and the
//!   SYRK accumulates the upper triangle one D_BLOCK × D_BLOCK Gram tile
//!   at a time, streaming `a`/`b` column slices through it — every hot
//!   buffer is cache-sized *independently of d*.
//!
//! Numerical contract: for a bitwise-symmetric `M` the panel GEMM
//! accumulates the margin in exactly the scalar reference's summation
//! order (ascending j, then ascending i), and the SYRK upper triangle is
//! summand-for-summand the scalar loop's upper triangle — parity with
//! the scalar core is at f64 round-off (`rust/tests/kernel_parity.rs`
//! checks 1e-10 on arbitrary shapes, including row counts and dimensions
//! that are not multiples of the panel size). The d-blocked variants are
//! **bitwise identical** to the row-stream kernels: blocking the columns
//! of `Y` never splits a `Σ_j` accumulation chain (each `y[k][i]` still
//! sums ascending j), the per-panel margin dot visits `i` globally
//! ascending because blocks are walked in order with a carried
//! accumulator, and each Gram cell's `Σ_t` chain lives entirely inside
//! one tile with `t` ascending — so core selection can never change a
//! solver trajectory or a screening decision (unit tests here assert
//! `==`, not a tolerance).
//!
//! The same tile geometry is mirrored by the PJRT grid: the Pallas
//! kernels dispatch row-blocks with per-block accumulators (and, for
//! high d, feature-dimension blocks), so native-vs-PJRT comparisons
//! measure the backend, not the blocking.

use super::Mat;

/// Rows of `a`/`b` per tile: the panel's `Y` scratch (PANEL_ROWS × d)
/// stays L1-resident for d ≤ 256 while each streamed row of `M` is
/// reused PANEL_ROWS times. Mirrors the Pallas kernels' row-block size
/// so native and PJRT runs share one grid decomposition.
pub const PANEL_ROWS: usize = 32;

/// Columns per feature-dimension block of the d-blocked kernels: one
/// `Y` tile is PANEL_ROWS × D_BLOCK doubles (32 KB — L1/L2-resident on
/// anything) and one Gram tile D_BLOCK × D_BLOCK doubles (128 KB —
/// L2-resident), independently of d.
pub const D_BLOCK: usize = 128;

/// Feature dimension at which [`crate::runtime::KernelCore::Auto`]
/// switches from the row-stream geometry to the d-blocked one: below
/// this the row-stream panel scratch (PANEL_ROWS · d doubles) still
/// fits L2 comfortably and the d-blocked variant's extra passes over
/// the `a`/`b` panel rows buy nothing.
pub const D_BLOCK_MIN_D: usize = 512;

/// FLOPs of one margins pass over `n` rows: two quad forms per row, each
/// a d×d GEMM row (2d²) plus a length-d dot (2d).
pub fn margins_flops(n: usize, d: usize) -> f64 {
    2.0 * n as f64 * (2.0 * (d * d) as f64 + 2.0 * d as f64)
}

/// FLOPs of one weighted-SYRK pass over `n` rows, upper triangle only:
/// d(d+1)/2 cells × 4 flops per row, plus the 2d row scalings — half the
/// 4d² the full rank-1 reference spends.
pub fn wgram_flops(n: usize, d: usize) -> f64 {
    n as f64 * (2.0 * (d * (d + 1)) as f64 + 2.0 * d as f64)
}

/// Panel-tiled margins: `out[k] = a_tᵀ M a_t − b_tᵀ M b_t` for every row
/// `t` in `rows`, written to `out` (aligned with `rows`). `y` is caller
/// scratch, grown to at most `PANEL_ROWS · d` and reusable across calls.
///
/// ```
/// use triplet_screen::linalg::{gemm, Mat};
///
/// let m = Mat::identity(3); // ⟨I, H⟩ = ‖a‖² − ‖b‖²
/// let a = Mat::from_rows(2, 3, vec![1.0, 2.0, 2.0, 0.0, 3.0, 4.0]);
/// let b = Mat::from_rows(2, 3, vec![1.0, 0.0, 0.0, 0.0, 0.0, 5.0]);
/// let (mut out, mut y) = (vec![0.0; 2], Vec::new());
/// gemm::margins_into(&m, &a, &b, 0..2, &mut out, &mut y);
/// assert_eq!(out, vec![8.0, 0.0]);
/// ```
pub fn margins_into(
    mat: &Mat,
    a: &Mat,
    b: &Mat,
    rows: std::ops::Range<usize>,
    out: &mut [f64],
    y: &mut Vec<f64>,
) {
    let d = mat.cols();
    debug_assert!(mat.is_square());
    debug_assert_eq!(a.cols(), d);
    debug_assert_eq!(b.cols(), d);
    debug_assert_eq!(out.len(), rows.len());
    if rows.is_empty() {
        return;
    }
    y.resize(PANEL_ROWS.min(rows.len()) * d, 0.0);
    let mut p0 = rows.start;
    while p0 < rows.end {
        let pr = PANEL_ROWS.min(rows.end - p0);
        let chunk = &mut out[p0 - rows.start..p0 - rows.start + pr];
        quad_forms_panel(mat, a, p0, pr, chunk, y, true);
        quad_forms_panel(mat, b, p0, pr, chunk, y, false);
        p0 += pr;
    }
}

/// One panel of quad forms: `out[k] (= | -=) x_{p0+k}ᵀ M x_{p0+k}`.
fn quad_forms_panel(
    mat: &Mat,
    x: &Mat,
    p0: usize,
    pr: usize,
    out: &mut [f64],
    y: &mut [f64],
    assign: bool,
) {
    let d = mat.cols();
    let yp = &mut y[..pr * d];
    yp.fill(0.0);
    // Y = X_panel · M: stream M one row at a time; each hot M row is
    // multiplied into all pr panel rows before the next row is loaded.
    for j in 0..d {
        let mrow = mat.row(j);
        for k in 0..pr {
            let c = x.row(p0 + k)[j];
            if c == 0.0 {
                continue;
            }
            let yrow = &mut yp[k * d..(k + 1) * d];
            for (yi, &mi) in yrow.iter_mut().zip(mrow) {
                *yi += c * mi;
            }
        }
    }
    for k in 0..pr {
        let xr = x.row(p0 + k);
        let yr = &yp[k * d..(k + 1) * d];
        let mut acc = 0.0;
        for (xi, yi) in xr.iter().zip(yr) {
            acc += xi * yi;
        }
        if assign {
            out[k] = acc;
        } else {
            out[k] -= acc;
        }
    }
}

/// d-blocked panel margins: identical contract (and **bitwise identical
/// output**) to [`margins_into`], but the feature dimension is walked in
/// `d_block`-column blocks so the hot working set — one `Y` tile of
/// `PANEL_ROWS · d_block` doubles (the required `y` capacity) plus a
/// `d_block`-wide slice of each streamed `M` row — is cache-sized
/// independently of d. `acc` is the per-panel margin accumulator lane
/// (grown to `PANEL_ROWS`); it carries each row's partial dot across
/// blocks so the `Σ_i x_i·y_i` chain still visits `i` globally
/// ascending.
///
/// Engines pass [`D_BLOCK`]; the parameter exists so tests can place
/// block boundaries anywhere.
///
/// ```
/// use triplet_screen::linalg::{gemm, Mat};
///
/// let m = Mat::identity(5);
/// let a = Mat::from_rows(1, 5, vec![1.0, 2.0, 0.0, 2.0, 4.0]);
/// let b = Mat::from_rows(1, 5, vec![3.0, 0.0, 0.0, 4.0, 0.0]);
/// let (mut out, mut y, mut acc) = (vec![0.0; 1], Vec::new(), Vec::new());
/// // block width 2 splits d = 5 into blocks of 2 + 2 + 1
/// gemm::margins_into_d_blocked(&m, &a, &b, 0..1, &mut out, &mut y, &mut acc, 2);
/// assert_eq!(out, vec![0.0]); // ‖a‖² = ‖b‖² = 25
/// ```
#[allow(clippy::too_many_arguments)]
pub fn margins_into_d_blocked(
    mat: &Mat,
    a: &Mat,
    b: &Mat,
    rows: std::ops::Range<usize>,
    out: &mut [f64],
    y: &mut Vec<f64>,
    acc: &mut Vec<f64>,
    d_block: usize,
) {
    let d = mat.cols();
    debug_assert!(mat.is_square());
    debug_assert_eq!(a.cols(), d);
    debug_assert_eq!(b.cols(), d);
    debug_assert_eq!(out.len(), rows.len());
    assert!(d_block > 0, "d_block must be positive");
    if rows.is_empty() {
        return;
    }
    let bw_max = d_block.min(d.max(1));
    let pr_max = PANEL_ROWS.min(rows.len());
    y.resize(pr_max * bw_max, 0.0);
    acc.resize(pr_max, 0.0);
    let mut p0 = rows.start;
    while p0 < rows.end {
        let pr = PANEL_ROWS.min(rows.end - p0);
        let chunk = &mut out[p0 - rows.start..p0 - rows.start + pr];
        quad_forms_panel_d_blocked(mat, a, p0, pr, chunk, y, acc, d_block, true);
        quad_forms_panel_d_blocked(mat, b, p0, pr, chunk, y, acc, d_block, false);
        p0 += pr;
    }
}

/// One d-blocked panel of quad forms: `out[k] (= | -=) x_{p0+k}ᵀ M
/// x_{p0+k}`, accumulated one `d_block`-column tile of `Y = X_panel · M`
/// at a time. Per-element summation chains are those of
/// [`quad_forms_panel`] exactly: every `y` cell still sums over
/// ascending j, and the margin dot walks the blocks (hence `i`) in
/// ascending order through the carried `acc` lane.
#[allow(clippy::too_many_arguments)]
fn quad_forms_panel_d_blocked(
    mat: &Mat,
    x: &Mat,
    p0: usize,
    pr: usize,
    out: &mut [f64],
    y: &mut [f64],
    acc: &mut [f64],
    d_block: usize,
    assign: bool,
) {
    let d = mat.cols();
    acc[..pr].fill(0.0);
    let mut c0 = 0;
    while c0 < d {
        let c1 = (c0 + d_block).min(d);
        let bw = c1 - c0;
        let yb = &mut y[..pr * bw];
        yb.fill(0.0);
        // Y tile = X_panel · M[:, c0..c1]: stream the D_BLOCK-wide slice
        // of each M row; each hot slice is multiplied into all pr panel
        // rows before the next row is loaded.
        for j in 0..d {
            let mrow = &mat.row(j)[c0..c1];
            for k in 0..pr {
                let c = x.row(p0 + k)[j];
                if c == 0.0 {
                    continue;
                }
                let yrow = &mut yb[k * bw..(k + 1) * bw];
                for (yi, &mi) in yrow.iter_mut().zip(mrow) {
                    *yi += c * mi;
                }
            }
        }
        // fold this block's dot contribution into the carried margin
        for k in 0..pr {
            let xr = &x.row(p0 + k)[c0..c1];
            let yr = &yb[k * bw..(k + 1) * bw];
            let mut s = acc[k];
            for (xi, yi) in xr.iter().zip(yr) {
                s += xi * yi;
            }
            acc[k] = s;
        }
        c0 = c1;
    }
    for k in 0..pr {
        if assign {
            out[k] = acc[k];
        } else {
            out[k] -= acc[k];
        }
    }
}

/// Weighted SYRK, upper triangle: `G[i][j] += Σ_k w[k]·(a_t[i]a_t[j] −
/// b_t[i]b_t[j])` for `j ≥ i`, `t = rows.start + k`. `w` is aligned with
/// `rows`; zero weights are skipped. The lower triangle is left
/// untouched — call [`mirror_upper`] once after reducing all partial
/// accumulators.
///
/// ```
/// use triplet_screen::linalg::{gemm, Mat};
///
/// let a = Mat::from_rows(1, 2, vec![1.0, 2.0]);
/// let b = Mat::from_rows(1, 2, vec![2.0, 0.0]);
/// let mut g = Mat::zeros(2, 2);
/// gemm::wsyrk_upper(&mut g, &a, &b, 0..1, &[1.0]);
/// gemm::mirror_upper(&mut g);
/// // a·aᵀ − b·bᵀ = [[1,2],[2,4]] − [[4,0],[0,0]]
/// assert_eq!((g[(0, 0)], g[(0, 1)], g[(1, 0)], g[(1, 1)]), (-3.0, 2.0, 2.0, 4.0));
/// ```
pub fn wsyrk_upper(g: &mut Mat, a: &Mat, b: &Mat, rows: std::ops::Range<usize>, w: &[f64]) {
    let d = a.cols();
    debug_assert_eq!(b.cols(), d);
    debug_assert_eq!((g.rows(), g.cols()), (d, d));
    debug_assert_eq!(w.len(), rows.len());
    for (k, t) in rows.enumerate() {
        let wt = w[k];
        if wt == 0.0 {
            continue;
        }
        let (ra, rb) = (a.row(t), b.row(t));
        for i in 0..d {
            let (wai, wbi) = (wt * ra[i], wt * rb[i]);
            let grow = &mut g.row_mut(i)[i..];
            for ((gj, &aj), &bj) in grow.iter_mut().zip(&ra[i..]).zip(&rb[i..]) {
                *gj += wai * aj - wbi * bj;
            }
        }
    }
}

/// d-blocked weighted SYRK: identical contract (and **bitwise identical
/// output**) to [`wsyrk_upper`], but the upper triangle is accumulated
/// one `d_block × d_block` Gram tile at a time, streaming the matching
/// `a`/`b` column slices through it — so the hot Gram working set is
/// `d_block²` doubles instead of `d²` (4.7 MB at d = 768, far past L2;
/// 128 KB per [`D_BLOCK`] tile). Each Gram cell lives in exactly one
/// tile and its `Σ_t` chain keeps `t` ascending inside that tile, so
/// the summand sequence per cell is exactly [`wsyrk_upper`]'s.
///
/// The trade: `a`/`b` panel rows are re-streamed once per tile-column
/// instead of once total — O(n·d·(d/d_block)) extra loads against
/// O(n·d²) FLOPs, a win as soon as the full Gram stops fitting in
/// cache. Engines pass [`D_BLOCK`]; tests place boundaries anywhere.
pub fn wsyrk_upper_d_blocked(
    g: &mut Mat,
    a: &Mat,
    b: &Mat,
    rows: std::ops::Range<usize>,
    w: &[f64],
    d_block: usize,
) {
    let d = a.cols();
    debug_assert_eq!(b.cols(), d);
    debug_assert_eq!((g.rows(), g.cols()), (d, d));
    debug_assert_eq!(w.len(), rows.len());
    assert!(d_block > 0, "d_block must be positive");
    let mut i0 = 0;
    while i0 < d {
        let i1 = (i0 + d_block).min(d);
        let mut j0 = i0;
        while j0 < d {
            let j1 = (j0 + d_block).min(d);
            for (k, t) in rows.clone().enumerate() {
                let wt = w[k];
                if wt == 0.0 {
                    continue;
                }
                let (ra, rb) = (a.row(t), b.row(t));
                for i in i0..i1 {
                    let js = j0.max(i);
                    if js >= j1 {
                        continue;
                    }
                    let (wai, wbi) = (wt * ra[i], wt * rb[i]);
                    let grow = &mut g.row_mut(i)[js..j1];
                    for ((gj, &aj), &bj) in grow.iter_mut().zip(&ra[js..j1]).zip(&rb[js..j1]) {
                        *gj += wai * aj - wbi * bj;
                    }
                }
            }
            j0 = j1;
        }
        i0 = i1;
    }
}

/// Reflect the accumulated upper triangle into the lower half, restoring
/// the full symmetric matrix after a [`wsyrk_upper`] reduction.
pub fn mirror_upper(g: &mut Mat) {
    debug_assert!(g.is_square());
    let d = g.rows();
    for i in 0..d {
        for j in (i + 1)..d {
            g[(j, i)] = g[(i, j)];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{close, forall};
    use crate::util::rng::Pcg64;

    fn rand_inputs(rng: &mut Pcg64, n: usize, d: usize) -> (Mat, Mat, Mat) {
        let mut m = Mat::from_fn(d, d, |_, _| rng.normal());
        m.symmetrize();
        let a = Mat::from_fn(n, d, |_, _| rng.normal());
        let b = Mat::from_fn(n, d, |_, _| rng.normal());
        (m, a, b)
    }

    #[test]
    fn margins_match_quad_form_oracle() {
        forall("gemm-margins", 24, |rng| {
            // shapes deliberately straddle PANEL_ROWS boundaries
            let d = 1 + rng.below(24);
            let n = 1 + rng.below(3 * PANEL_ROWS + 2);
            let (m, a, b) = rand_inputs(rng, n, d);
            let mut out = vec![0.0; n];
            let mut y = Vec::new();
            margins_into(&m, &a, &b, 0..n, &mut out, &mut y);
            for t in 0..n {
                let want = m.quad_form(a.row(t)) - m.quad_form(b.row(t));
                close(out[t], want, 1e-12, 1e-12, "margin")?;
            }
            Ok(())
        });
    }

    #[test]
    fn margins_subrange_alignment() {
        let mut rng = Pcg64::seed(3);
        let (m, a, b) = rand_inputs(&mut rng, 100, 7);
        let mut full = vec![0.0; 100];
        let mut y = Vec::new();
        margins_into(&m, &a, &b, 0..100, &mut full, &mut y);
        // a sub-range (not panel-aligned) must land in out[0..len]
        let mut part = vec![0.0; 41];
        margins_into(&m, &a, &b, 37..78, &mut part, &mut y);
        for (k, t) in (37..78).enumerate() {
            assert_eq!(part[k], full[t], "sub-range row {t} misaligned");
        }
    }

    #[test]
    fn wsyrk_matches_outer_sum_oracle() {
        forall("gemm-wsyrk", 24, |rng| {
            let d = 1 + rng.below(12);
            let n = 1 + rng.below(80);
            let (_, a, b) = rand_inputs(rng, n, d);
            let w: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mut g = Mat::zeros(d, d);
            wsyrk_upper(&mut g, &a, &b, 0..n, &w);
            mirror_upper(&mut g);
            let mut want = Mat::zeros(d, d);
            for t in 0..n {
                want.axpy(w[t], &Mat::outer(a.row(t)));
                want.axpy(-w[t], &Mat::outer(b.row(t)));
            }
            close(g.sub(&want).max_abs(), 0.0, 0.0, 1e-10, "wsyrk")
        });
    }

    #[test]
    fn mirror_restores_symmetry() {
        let mut rng = Pcg64::seed(5);
        let (_, a, b) = rand_inputs(&mut rng, 33, 6);
        let w = vec![0.7; 33];
        let mut g = Mat::zeros(6, 6);
        wsyrk_upper(&mut g, &a, &b, 0..33, &w);
        mirror_upper(&mut g);
        for i in 0..6 {
            for j in 0..6 {
                assert_eq!(g[(i, j)], g[(j, i)], "asymmetry at ({i},{j})");
            }
        }
    }

    #[test]
    fn d_blocked_margins_bitwise_match_row_stream() {
        // blocking the feature dimension must not change a single bit:
        // arbitrary shapes, block widths straddling every boundary case
        // (1, smaller than d, equal, larger)
        forall("gemm-dblock-margins", 24, |rng| {
            let d = 1 + rng.below(40);
            let n = 1 + rng.below(2 * PANEL_ROWS + 3);
            let (m, a, b) = rand_inputs(rng, n, d);
            let mut base = vec![0.0; n];
            let mut y = Vec::new();
            margins_into(&m, &a, &b, 0..n, &mut base, &mut y);
            let mut acc = Vec::new();
            for d_block in [1, 2, d.saturating_sub(1).max(1), d, d + 3] {
                let mut out = vec![0.0; n];
                margins_into_d_blocked(&m, &a, &b, 0..n, &mut out, &mut y, &mut acc, d_block);
                for t in 0..n {
                    if out[t].to_bits() != base[t].to_bits() {
                        return Err(format!(
                            "d={d} block={d_block} t={t}: {} != {}",
                            out[t], base[t]
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn d_blocked_margins_subrange_alignment() {
        let mut rng = Pcg64::seed(4);
        let (m, a, b) = rand_inputs(&mut rng, 90, 11);
        let (mut y, mut acc) = (Vec::new(), Vec::new());
        let mut full = vec![0.0; 90];
        margins_into_d_blocked(&m, &a, &b, 0..90, &mut full, &mut y, &mut acc, 4);
        let mut part = vec![0.0; 33];
        margins_into_d_blocked(&m, &a, &b, 41..74, &mut part, &mut y, &mut acc, 4);
        for (k, t) in (41..74).enumerate() {
            assert_eq!(part[k], full[t], "sub-range row {t} misaligned");
        }
    }

    #[test]
    fn d_blocked_wsyrk_bitwise_matches_row_stream() {
        forall("gemm-dblock-wsyrk", 24, |rng| {
            let d = 1 + rng.below(24);
            let n = 1 + rng.below(60);
            let (_, a, b) = rand_inputs(rng, n, d);
            let w: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mut base = Mat::zeros(d, d);
            wsyrk_upper(&mut base, &a, &b, 0..n, &w);
            for d_block in [1, 3, d.saturating_sub(1).max(1), d, d + 5] {
                let mut g = Mat::zeros(d, d);
                wsyrk_upper_d_blocked(&mut g, &a, &b, 0..n, &w, d_block);
                for i in 0..d {
                    for j in 0..d {
                        if g[(i, j)].to_bits() != base[(i, j)].to_bits() {
                            return Err(format!(
                                "d={d} block={d_block}: cell ({i},{j}) {} != {}",
                                g[(i, j)],
                                base[(i, j)]
                            ));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn flop_counters_positive_and_scaled() {
        assert!(margins_flops(100, 8) > 0.0);
        assert!(wgram_flops(100, 8) > 0.0);
        // SYRK claims roughly half the full rank-1 cost at large d
        let full = 100.0 * 4.0 * 64.0 * 64.0;
        assert!(wgram_flops(100, 64) < 0.6 * full);
        // margins dominated by 4·n·d²
        assert!((margins_flops(1, 100) - (4.0 * 100.0 * 100.0 + 4.0 * 100.0)).abs() < 1e-9);
    }
}
