//! Scoped fork-join parallelism over index ranges (rayon stand-in).
//!
//! All parallel loops in the crate go through [`par_ranges`]: the range
//! `[0, n)` is split into one contiguous chunk per worker, each worker runs
//! the closure on its chunk, and results are collected in chunk order —
//! deterministic regardless of scheduling.

/// Number of workers to use: respects `TS_THREADS`, defaults to the number
/// of available cores capped at 16 (the workloads here stop scaling past
/// that on the triplet sizes we run).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("TS_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(16)
}

/// Split `[0, n)` into at most `workers` contiguous ranges of near-equal
/// length (the first `n % workers` ranges are one longer).
pub fn split_ranges(n: usize, workers: usize) -> Vec<std::ops::Range<usize>> {
    let workers = workers.max(1).min(n.max(1));
    let base = n / workers;
    let extra = n % workers;
    let mut out = Vec::with_capacity(workers);
    let mut start = 0;
    for w in 0..workers {
        let len = base + usize::from(w < extra);
        if len == 0 {
            break;
        }
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Run `f` over chunks of `[0, n)` in parallel; returns per-chunk results
/// in chunk order. `f` must be `Sync` (called from many threads).
pub fn par_ranges<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(std::ops::Range<usize>) -> T + Sync,
{
    let ranges = split_ranges(n, workers);
    if ranges.len() <= 1 {
        return ranges.into_iter().map(&f).collect();
    }
    std::thread::scope(|scope| {
        let handles: Vec<_> = ranges
            .into_iter()
            .map(|r| scope.spawn(|| f(r)))
            .collect();
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    })
}

/// Parallel in-place map over disjoint mutable chunks of `out`, where chunk
/// `c` covers rows `[ranges[c])` and the closure fills its slice.
pub fn par_fill<T, F>(out: &mut [T], workers: usize, f: F)
where
    T: Send,
    F: Fn(std::ops::Range<usize>, &mut [T]) + Sync,
{
    let n = out.len();
    let ranges = split_ranges(n, workers);
    if ranges.len() <= 1 {
        if let Some(r) = ranges.into_iter().next() {
            f(r.clone(), out);
        }
        return;
    }
    std::thread::scope(|scope| {
        let mut rest = out;
        let mut offset = 0;
        for r in ranges {
            let (head, tail) = rest.split_at_mut(r.len());
            debug_assert_eq!(offset, r.start);
            offset += r.len();
            let fr = &f;
            scope.spawn(move || fr(r, head));
            rest = tail;
        }
    });
}

/// Run `f` over fixed-size blocks of `[0, n)` in parallel, returning the
/// per-block results in block order. Blocks are assigned to workers in
/// contiguous groups, so the decomposition is deterministic regardless of
/// scheduling. The screening pipeline uses this with cache-sized blocks:
/// each worker streams a handful of contiguous blocks whose per-triplet
/// lanes (`hq`, `‖H‖`, …) fit in L2, instead of one giant range.
pub fn par_blocks<T, F>(n: usize, block: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(std::ops::Range<usize>) -> T + Sync,
{
    let block = block.max(1);
    let nblocks = n.div_ceil(block);
    let per_worker = par_ranges(nblocks, workers, |brange| {
        brange
            .map(|bi| f(bi * block..((bi + 1) * block).min(n)))
            .collect::<Vec<T>>()
    });
    per_worker.into_iter().flatten().collect()
}

/// Parallel sum-reduction of per-chunk `f` results.
pub fn par_sum<F>(n: usize, workers: usize, f: F) -> f64
where
    F: Fn(std::ops::Range<usize>) -> f64 + Sync,
{
    par_ranges(n, workers, f).into_iter().sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_covers_range_exactly() {
        for n in [0usize, 1, 5, 16, 17, 1000] {
            for w in [1usize, 2, 3, 8, 64] {
                let rs = split_ranges(n, w);
                let total: usize = rs.iter().map(|r| r.len()).sum();
                assert_eq!(total, n);
                let mut next = 0;
                for r in &rs {
                    assert_eq!(r.start, next);
                    next = r.end;
                }
            }
        }
    }

    #[test]
    fn par_sum_matches_serial() {
        let xs: Vec<f64> = (0..10_000).map(|i| (i as f64).sin()).collect();
        let serial: f64 = xs.iter().sum();
        for w in [1, 2, 4, 7] {
            let par = par_sum(xs.len(), w, |r| xs[r].iter().sum());
            assert!((par - serial).abs() < 1e-9);
        }
    }

    #[test]
    fn par_fill_writes_every_cell() {
        let mut out = vec![0usize; 1003];
        par_fill(&mut out, 4, |r, chunk| {
            for (k, v) in chunk.iter_mut().enumerate() {
                *v = r.start + k;
            }
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i);
        }
    }

    #[test]
    fn par_blocks_covers_in_block_order() {
        for n in [0usize, 1, 5, 4096, 4097, 10_000] {
            for (block, w) in [(1usize, 1usize), (7, 3), (4096, 4), (16, 9)] {
                let out = par_blocks(n, block, w, |r| r);
                let expect_blocks = n.div_ceil(block);
                assert_eq!(out.len(), expect_blocks, "n={n} block={block}");
                let mut next = 0usize;
                for r in &out {
                    assert_eq!(r.start, next);
                    assert!(r.len() <= block && (!r.is_empty() || n == 0));
                    next = r.end;
                }
                assert_eq!(next, n);
            }
        }
    }

    #[test]
    fn par_ranges_order_is_chunk_order() {
        let res = par_ranges(100, 7, |r| r.start);
        let mut sorted = res.clone();
        sorted.sort_unstable();
        assert_eq!(res, sorted);
    }
}
