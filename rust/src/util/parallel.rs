//! Persistent worker-pool parallelism over index ranges (rayon stand-in).
//!
//! All parallel loops in the crate go through one lazily-started global
//! [`ThreadPool`]: a fork-join section ([`ThreadPool::run_scoped`]) splits
//! its work into one closure per chunk, enqueues all but the first on the
//! shared queue, runs the first inline on the calling thread, then
//! help-drains the queue until its own tasks have completed. Workers are
//! spawned once and reused forever, so the per-call cost of a parallel
//! section is a queue push + condvar wake instead of a `thread::spawn` —
//! the difference the screening rule loop (thousands of `screen()` calls
//! per path) actually feels.
//!
//! **Determinism contract.** The pool never decides *how* work splits —
//! callers pass explicit chunk lists ([`split_ranges`],
//! [`split_ranges_aligned`], or custom bands) and results come back in
//! chunk order. Every summation chain lives entirely inside one chunk, so
//! outputs are bitwise identical at any worker count, with any number of
//! pool threads (including zero: if spawning fails the caller drains the
//! whole queue itself and the results are the same bits).

use std::collections::VecDeque;
use std::ops::Range;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, OnceLock};

/// Parse a `TS_THREADS` value. `0` (and the empty string) means
/// auto-detect — it returns `None` so the caller falls back to
/// [`auto_threads`] — and anything non-numeric is a loud configuration
/// error instead of silently falling through to the core count.
pub fn parse_ts_threads(v: &str) -> Option<usize> {
    let v = v.trim();
    if v.is_empty() {
        return None;
    }
    match v.parse::<usize>() {
        Ok(0) => None,
        Ok(n) => Some(n),
        Err(_) => panic!(
            "TS_THREADS must be a non-negative integer (0 or unset = auto-detect), got {v:?}"
        ),
    }
}

/// Auto-detected worker count: available cores capped at 16 (the
/// workloads here stop scaling past that on the triplet sizes we run).
pub fn auto_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(16)
}

/// Number of workers to use: `TS_THREADS` if set (where `0` explicitly
/// selects auto-detection and garbage panics — see [`parse_ts_threads`]),
/// otherwise [`auto_threads`].
pub fn default_threads() -> usize {
    match std::env::var("TS_THREADS") {
        Ok(v) => parse_ts_threads(&v).unwrap_or_else(auto_threads),
        Err(_) => auto_threads(),
    }
}

/// Split `[0, n)` into at most `workers` contiguous ranges of near-equal
/// length (the first `n % workers` ranges are one longer).
pub fn split_ranges(n: usize, workers: usize) -> Vec<Range<usize>> {
    let workers = workers.max(1).min(n.max(1));
    let base = n / workers;
    let extra = n % workers;
    let mut out = Vec::with_capacity(workers);
    let mut start = 0;
    for w in 0..workers {
        let len = base + usize::from(w < extra);
        if len == 0 {
            break;
        }
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Like [`split_ranges`], but every chunk boundary (except possibly the
/// final `n`) is a multiple of `align`. Block-structured kernels (the
/// `PANEL_ROWS`-paneled margins GEMM) split on these so the panel
/// decomposition — and therefore every summation chain — is identical at
/// any worker count.
pub fn split_ranges_aligned(n: usize, workers: usize, align: usize) -> Vec<Range<usize>> {
    let align = align.max(1);
    split_ranges(n.div_ceil(align), workers)
        .into_iter()
        .map(|r| r.start * align..(r.end * align).min(n))
        .collect()
}

/// A borrowed fork-join closure, as accepted by
/// [`ThreadPool::run_scoped`].
pub type ScopedTask<'scope> = Box<dyn FnOnce() + Send + 'scope>;

/// A queued unit of work. Scoped closures are transmuted to `'static`
/// before enqueueing; [`ThreadPool::run_scoped`] guarantees they finish
/// before the borrowed scope ends.
type Task = ScopedTask<'static>;

struct PoolShared {
    queue: Mutex<VecDeque<Task>>,
    available: Condvar,
}

/// Completion latch for one fork-join scope: counts outstanding queued
/// tasks and stores the first panic payload for re-raising on the caller.
struct Latch {
    state: Mutex<LatchState>,
    done: Condvar,
}

struct LatchState {
    remaining: usize,
    panic: Option<Box<dyn std::any::Any + Send>>,
}

impl Latch {
    fn new(remaining: usize) -> Latch {
        Latch {
            state: Mutex::new(LatchState {
                remaining,
                panic: None,
            }),
            done: Condvar::new(),
        }
    }

    fn complete(&self, panic: Option<Box<dyn std::any::Any + Send>>) {
        let mut st = self.state.lock().unwrap();
        st.remaining -= 1;
        if st.panic.is_none() {
            st.panic = panic;
        }
        if st.remaining == 0 {
            self.done.notify_all();
        }
    }

    fn is_open(&self) -> bool {
        self.state.lock().unwrap().remaining == 0
    }

    fn wait_open(&self) {
        let mut st = self.state.lock().unwrap();
        while st.remaining > 0 {
            st = self.done.wait(st).unwrap();
        }
    }

    fn take_panic(&self) -> Option<Box<dyn std::any::Any + Send>> {
        self.state.lock().unwrap().panic.take()
    }
}

/// The persistent worker pool behind every `par_*` helper.
///
/// Threads are spawned lazily (first multi-chunk section) and capped at
/// [`ThreadPool::capacity`]; they block on a condvar between sections, so
/// an idle pool costs nothing. Dispatch and wall telemetry accumulate in
/// relaxed atomics — snapshot them with [`pool_stats`].
pub struct ThreadPool {
    shared: PoolShared,
    spawned: AtomicUsize,
    capacity: usize,
    scopes: AtomicU64,
    tasks: AtomicU64,
    wall_nanos: AtomicU64,
}

/// Telemetry snapshot of the global pool (see [`pool_stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PoolStats {
    /// Worker threads currently spawned (≤ the pool capacity; the
    /// calling thread, which always participates, is not counted).
    pub threads: usize,
    /// Fork-join sections dispatched since process start (multi-chunk
    /// only — single-chunk sections run inline and never touch the pool).
    pub scopes: u64,
    /// Total chunk closures executed across those sections, including
    /// the one the calling thread runs inline.
    pub tasks: u64,
    /// Cumulative wall-clock seconds spent inside fork-join sections,
    /// measured on the calling thread from dispatch to join.
    pub wall_seconds: f64,
}

impl ThreadPool {
    fn new() -> ThreadPool {
        ThreadPool {
            shared: PoolShared {
                queue: Mutex::new(VecDeque::new()),
                available: Condvar::new(),
            },
            spawned: AtomicUsize::new(0),
            // Enough threads for the configured worker count on this
            // host, bounded so a wild TS_THREADS cannot fork-bomb.
            capacity: default_threads().max(auto_threads()).min(64),
            scopes: AtomicU64::new(0),
            tasks: AtomicU64::new(0),
            wall_nanos: AtomicU64::new(0),
        }
    }

    /// Hard cap on pool threads (decided once at pool creation).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    fn ensure_workers(&'static self, wanted: usize) {
        let target = wanted.min(self.capacity);
        loop {
            let cur = self.spawned.load(Ordering::Relaxed);
            if cur >= target {
                return;
            }
            if self
                .spawned
                .compare_exchange(cur, cur + 1, Ordering::Relaxed, Ordering::Relaxed)
                .is_ok()
            {
                let spawned = std::thread::Builder::new()
                    .name(format!("ts-pool-{cur}"))
                    .spawn(move || self.worker_loop());
                if spawned.is_err() {
                    // Thread creation failed (resource limits): undo the
                    // reservation and fall back to caller-side draining —
                    // correctness never depends on pool threads existing.
                    self.spawned.fetch_sub(1, Ordering::Relaxed);
                    return;
                }
            }
        }
    }

    fn worker_loop(&'static self) {
        ON_POOL_THREAD.with(|flag| flag.set(true));
        loop {
            let task = {
                let mut q = self.shared.queue.lock().unwrap();
                loop {
                    if let Some(t) = q.pop_front() {
                        break t;
                    }
                    q = self.shared.available.wait(q).unwrap();
                }
            };
            // Queued tasks are latch wrappers that catch their own
            // panics, so `task()` cannot unwind through the worker.
            task();
        }
    }

    /// Run every closure in `tasks` to completion before returning — the
    /// fork-join primitive the `par_*` routers are built on. The first
    /// closure runs inline on the calling thread; the rest go on the
    /// shared queue, and the caller help-drains the queue (executing
    /// whatever it pops, including tasks of nested sections) until its
    /// own latch opens. A panic in any closure is re-raised here after
    /// all sibling closures have finished.
    pub fn run_scoped<'scope>(&'static self, mut tasks: Vec<ScopedTask<'scope>>) {
        if tasks.is_empty() {
            return;
        }
        if tasks.len() == 1 {
            (tasks.pop().unwrap())();
            return;
        }
        let t0 = std::time::Instant::now();
        self.scopes.fetch_add(1, Ordering::Relaxed);
        self.tasks.fetch_add(tasks.len() as u64, Ordering::Relaxed);
        self.ensure_workers(tasks.len() - 1);
        let latch = Latch::new(tasks.len() - 1);
        {
            let latch = &latch;
            let mut q = self.shared.queue.lock().unwrap();
            for task in tasks.drain(1..) {
                let wrapped: ScopedTask<'_> = Box::new(move || {
                    let res = catch_unwind(AssertUnwindSafe(task));
                    latch.complete(res.err());
                });
                // SAFETY: only the lifetime is transmuted (same layout).
                // All borrowed state inside the wrapper is dropped
                // before it counts the latch down, and this function
                // does not return (so neither `'scope` nor the latch
                // borrow ends) before waiting for exactly that.
                let wrapped: Task =
                    unsafe { std::mem::transmute::<ScopedTask<'_>, Task>(wrapped) };
                q.push_back(wrapped);
            }
            self.shared.available.notify_all();
        }
        let first = tasks.pop().unwrap();
        let first_panic = catch_unwind(AssertUnwindSafe(first)).err();
        // Help-drain: our queued tasks are FIFO-ahead of anything newer,
        // so once the queue is observed empty they are all executing (or
        // done) elsewhere and blocking on the latch cannot deadlock.
        while !latch.is_open() {
            let task = self.shared.queue.lock().unwrap().pop_front();
            match task {
                Some(t) => t(),
                None => {
                    latch.wait_open();
                    break;
                }
            }
        }
        let panic = latch.take_panic().or(first_panic);
        self.wall_nanos
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        if let Some(p) = panic {
            resume_unwind(p);
        }
    }

    fn stats(&self) -> PoolStats {
        PoolStats {
            threads: self.spawned.load(Ordering::Relaxed),
            scopes: self.scopes.load(Ordering::Relaxed),
            tasks: self.tasks.load(Ordering::Relaxed),
            wall_seconds: self.wall_nanos.load(Ordering::Relaxed) as f64 * 1e-9,
        }
    }
}

thread_local! {
    /// Set once, forever, on every compute pool worker the moment it
    /// enters `worker_loop`. Lets other layers assert they are *not*
    /// on a kernel thread — the serving front end's `Ticket::wait`
    /// refuses to block a compute worker on front-end progress, which
    /// keeps the two thread domains (front-end workers vs this pool)
    /// free of cross-domain blocking by construction.
    static ON_POOL_THREAD: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// Whether the calling thread is a compute pool worker (`ts-pool-{n}`).
/// Front-end worker threads (`ts-front-{i}`), the main thread, and test
/// threads all report `false`.
pub fn on_pool_thread() -> bool {
    ON_POOL_THREAD.with(|flag| flag.get())
}

/// The process-wide pool. Creation is cheap (no threads until the first
/// multi-chunk section), so this can be called freely.
pub fn pool() -> &'static ThreadPool {
    static POOL: OnceLock<ThreadPool> = OnceLock::new();
    POOL.get_or_init(ThreadPool::new)
}

/// Snapshot the global pool's dispatch telemetry. Counters are
/// process-cumulative; callers wanting per-phase numbers snapshot before
/// and after and subtract (`PathStep::kernel_par_wall_seconds` does).
pub fn pool_stats() -> PoolStats {
    pool().stats()
}

/// Run `f` over an explicit chunk list in parallel; returns per-chunk
/// results in chunk order. `f` must be `Sync` (called from many threads).
pub fn par_range_tasks<T, F>(ranges: Vec<Range<usize>>, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(Range<usize>) -> T + Sync,
{
    if ranges.len() <= 1 {
        return ranges.into_iter().map(&f).collect();
    }
    let n = ranges.len();
    let mut results: Vec<Option<T>> = Vec::with_capacity(n);
    results.resize_with(n, || None);
    {
        let fr = &f;
        let tasks: Vec<ScopedTask<'_>> = results
            .iter_mut()
            .zip(ranges)
            .map(|(slot, r)| Box::new(move || *slot = Some(fr(r))) as ScopedTask<'_>)
            .collect();
        pool().run_scoped(tasks);
    }
    results
        .into_iter()
        .map(|o| o.expect("scoped task completed"))
        .collect()
}

/// Run `f` over chunks of `[0, n)` in parallel; returns per-chunk results
/// in chunk order.
pub fn par_ranges<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(Range<usize>) -> T + Sync,
{
    par_range_tasks(split_ranges(n, workers), f)
}

/// Parallel in-place map over disjoint mutable chunks of `out` cut at the
/// given boundaries; `ranges` must be contiguous from 0 and cover
/// `out.len()` exactly (as produced by [`split_ranges`] /
/// [`split_ranges_aligned`] / the SYRK band splitter). The closure gets
/// each chunk's index range and its slice of `out`.
pub fn par_fill_ranges<T, F>(out: &mut [T], ranges: Vec<Range<usize>>, f: F)
where
    T: Send,
    F: Fn(Range<usize>, &mut [T]) + Sync,
{
    debug_assert_eq!(ranges.iter().map(|r| r.len()).sum::<usize>(), out.len());
    if ranges.len() <= 1 {
        if let Some(r) = ranges.into_iter().next() {
            f(r, out);
        }
        return;
    }
    let fr = &f;
    let mut tasks: Vec<ScopedTask<'_>> = Vec::with_capacity(ranges.len());
    let mut rest = out;
    for r in ranges {
        let (head, tail) = rest.split_at_mut(r.len());
        tasks.push(Box::new(move || fr(r, head)));
        rest = tail;
    }
    pool().run_scoped(tasks);
}

/// Parallel in-place map over disjoint mutable chunks of `out`, one
/// near-equal chunk per worker.
pub fn par_fill<T, F>(out: &mut [T], workers: usize, f: F)
where
    T: Send,
    F: Fn(Range<usize>, &mut [T]) + Sync,
{
    let ranges = split_ranges(out.len(), workers);
    par_fill_ranges(out, ranges, f);
}

/// [`par_fill`] with chunk boundaries on multiples of `align` — the
/// variant block-structured kernels use so their block decomposition is
/// worker-count-invariant (see [`split_ranges_aligned`]).
pub fn par_fill_aligned<T, F>(out: &mut [T], workers: usize, align: usize, f: F)
where
    T: Send,
    F: Fn(Range<usize>, &mut [T]) + Sync,
{
    let ranges = split_ranges_aligned(out.len(), workers, align);
    par_fill_ranges(out, ranges, f);
}

/// Run `f` over fixed-size blocks of `[0, n)` in parallel, returning the
/// per-block results in block order. Blocks are assigned to workers in
/// contiguous groups, so the decomposition is deterministic regardless of
/// scheduling. The screening pipeline uses this with cache-sized blocks:
/// each worker streams a handful of contiguous blocks whose per-triplet
/// lanes (`hq`, `‖H‖`, …) fit in L2, instead of one giant range.
pub fn par_blocks<T, F>(n: usize, block: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(Range<usize>) -> T + Sync,
{
    let block = block.max(1);
    let nblocks = n.div_ceil(block);
    let per_worker = par_ranges(nblocks, workers, |brange| {
        brange
            .map(|bi| f(bi * block..((bi + 1) * block).min(n)))
            .collect::<Vec<T>>()
    });
    per_worker.into_iter().flatten().collect()
}

/// Parallel sum-reduction of per-chunk `f` results (summed in chunk
/// order, so the reduction chain is worker-count-deterministic).
pub fn par_sum<F>(n: usize, workers: usize, f: F) -> f64
where
    F: Fn(Range<usize>) -> f64 + Sync,
{
    par_ranges(n, workers, f).into_iter().sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::forall;

    #[test]
    fn split_covers_range_exactly() {
        for n in [0usize, 1, 5, 16, 17, 1000] {
            for w in [1usize, 2, 3, 8, 64] {
                let rs = split_ranges(n, w);
                let total: usize = rs.iter().map(|r| r.len()).sum();
                assert_eq!(total, n);
                let mut next = 0;
                for r in &rs {
                    assert_eq!(r.start, next);
                    next = r.end;
                }
            }
        }
    }

    #[test]
    fn split_ranges_quickcheck_degenerate_shapes() {
        // ISSUE 7 satellite: explicit coverage for n < workers and n = 0,
        // randomized over both.
        forall("split-ranges-degenerate", 128, |rng| {
            let workers = 1 + rng.below(32);
            let n = rng.below(workers + 1); // 0 ≤ n ≤ workers, mostly n < workers
            let rs = split_ranges(n, workers);
            if n == 0 {
                if !rs.is_empty() {
                    return Err(format!("n=0 produced {} ranges", rs.len()));
                }
                return Ok(());
            }
            if rs.len() > n {
                return Err(format!("n={n} workers={workers}: {} ranges (> n)", rs.len()));
            }
            let mut next = 0;
            for r in &rs {
                if r.is_empty() {
                    return Err(format!("n={n} workers={workers}: empty range {r:?}"));
                }
                if r.start != next {
                    return Err(format!("gap before {r:?} (expected start {next})"));
                }
                next = r.end;
            }
            if next != n {
                return Err(format!("coverage ends at {next}, expected {n}"));
            }
            Ok(())
        });
    }

    #[test]
    fn split_aligned_boundaries_are_multiples() {
        for (n, w, align) in [
            (100usize, 4usize, 32usize),
            (1003, 7, 32),
            (31, 4, 32),
            (0, 3, 32),
            (64, 2, 32),
            (65, 3, 1),
        ] {
            let rs = split_ranges_aligned(n, w, align);
            let total: usize = rs.iter().map(|r| r.len()).sum();
            assert_eq!(total, n, "n={n} w={w} align={align}");
            let mut next = 0;
            for r in &rs {
                assert_eq!(r.start, next);
                assert_eq!(r.start % align, 0, "unaligned boundary in {r:?}");
                next = r.end;
            }
            assert_eq!(next, n);
        }
    }

    #[test]
    fn parse_ts_threads_is_explicit() {
        assert_eq!(parse_ts_threads("3"), Some(3));
        assert_eq!(parse_ts_threads(" 8 "), Some(8));
        // 0 and empty are explicit auto-detect, not a silent clamp to 1
        assert_eq!(parse_ts_threads("0"), None);
        assert_eq!(parse_ts_threads(""), None);
        assert_eq!(parse_ts_threads("  "), None);
    }

    #[test]
    #[should_panic(expected = "TS_THREADS must be a non-negative integer")]
    fn parse_ts_threads_rejects_garbage() {
        parse_ts_threads("lots");
    }

    #[test]
    fn par_sum_matches_serial() {
        let xs: Vec<f64> = (0..10_000).map(|i| (i as f64).sin()).collect();
        let serial: f64 = xs.iter().sum();
        for w in [1, 2, 4, 7] {
            let par = par_sum(xs.len(), w, |r| xs[r].iter().sum());
            assert!((par - serial).abs() < 1e-9);
        }
    }

    #[test]
    fn par_fill_writes_every_cell() {
        let mut out = vec![0usize; 1003];
        par_fill(&mut out, 4, |r, chunk| {
            for (k, v) in chunk.iter_mut().enumerate() {
                *v = r.start + k;
            }
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i);
        }
    }

    #[test]
    fn par_fill_aligned_writes_every_cell() {
        let mut out = vec![0usize; 1003];
        par_fill_aligned(&mut out, 7, 32, |r, chunk| {
            assert_eq!(r.start % 32, 0);
            for (k, v) in chunk.iter_mut().enumerate() {
                *v = r.start + k;
            }
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i);
        }
    }

    #[test]
    fn par_blocks_covers_in_block_order() {
        for n in [0usize, 1, 5, 4096, 4097, 10_000] {
            for (block, w) in [(1usize, 1usize), (7, 3), (4096, 4), (16, 9)] {
                let out = par_blocks(n, block, w, |r| r);
                let expect_blocks = n.div_ceil(block);
                assert_eq!(out.len(), expect_blocks, "n={n} block={block}");
                let mut next = 0usize;
                for r in &out {
                    assert_eq!(r.start, next);
                    assert!(r.len() <= block && (!r.is_empty() || n == 0));
                    next = r.end;
                }
                assert_eq!(next, n);
            }
        }
    }

    #[test]
    fn par_ranges_order_is_chunk_order() {
        let res = par_ranges(100, 7, |r| r.start);
        let mut sorted = res.clone();
        sorted.sort_unstable();
        assert_eq!(res, sorted);
    }

    #[test]
    fn pool_is_reused_across_sections() {
        // Dispatch many multi-chunk sections: the pool must reuse its
        // workers (threads never exceed capacity) while the scope/task
        // counters advance — the persistent-pool contract.
        let before = pool_stats();
        for _ in 0..50 {
            let s = par_sum(1000, 4, |r| r.len() as f64);
            assert_eq!(s, 1000.0);
        }
        let after = pool_stats();
        assert!(after.scopes >= before.scopes + 50);
        assert!(after.tasks >= before.tasks + 100);
        assert!(after.threads <= pool().capacity());
        assert!(after.wall_seconds >= before.wall_seconds);
    }

    #[test]
    fn worker_panic_propagates_to_caller() {
        let caught = std::panic::catch_unwind(|| {
            par_ranges(100, 4, |r| {
                if r.start >= 50 {
                    panic!("chunk {} failed", r.start);
                }
                r.len()
            })
        });
        assert!(caught.is_err(), "panic in a pooled chunk must propagate");
        // ... and the pool must still be usable afterwards
        assert_eq!(par_sum(100, 4, |r| r.len() as f64), 100.0);
    }

    #[test]
    fn nested_sections_complete() {
        // A pooled task that itself opens a section must help-drain
        // rather than deadlock, whatever the worker count.
        let outer = par_ranges(8, 4, |r| par_sum(64, 3, |inner| (inner.len() * r.len()) as f64));
        let total: f64 = outer.into_iter().sum();
        assert_eq!(total, 8.0 * 64.0);
    }
}
