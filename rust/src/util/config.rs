//! TOML-subset configuration files (DESIGN.md §7).
//!
//! Grammar: `[section]` headers, `key = value` pairs, `#` comments.
//! Values: strings ("…"), numbers, booleans, and flat arrays. Keys are
//! addressed as `section.key`; CLI `--set section.key=value` overrides
//! win over file values, and CLI flags win over both.
//!
//! Recognized sections: `[path]` / `[solver]` / `[screening]` / `[loss]`
//! (consumed by [`path_config`]) and `[engine]` (consumed by
//! [`engine_overrides`]: `kernel_core`, `d_threshold`, `threads`,
//! `precision`, `rank` — the kernel-core, precision-tier, and
//! factored-backend selection documented in `triplet-screen --help`).

use std::collections::BTreeMap;

/// A parsed configuration: flat `section.key -> value` map.
#[derive(Clone, Debug, Default)]
pub struct Config {
    values: BTreeMap<String, Value>,
}

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Num(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    fn parse(text: &str) -> Result<Value, String> {
        let t = text.trim();
        if t.is_empty() {
            return Err("empty value".into());
        }
        if let Some(inner) = t.strip_prefix('"').and_then(|s| s.strip_suffix('"')) {
            return Ok(Value::Str(inner.to_string()));
        }
        if t == "true" {
            return Ok(Value::Bool(true));
        }
        if t == "false" {
            return Ok(Value::Bool(false));
        }
        if let Some(inner) = t.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            let items: Result<Vec<Value>, String> = inner
                .split(',')
                .filter(|s| !s.trim().is_empty())
                .map(Value::parse)
                .collect();
            return Ok(Value::Arr(items?));
        }
        t.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| format!("cannot parse value {t:?}"))
    }
}

impl Config {
    /// Parse TOML-subset text.
    pub fn parse(text: &str) -> Result<Config, String> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = match raw.find('#') {
                // keep '#' inside quoted strings
                Some(pos) if raw[..pos].matches('"').count() % 2 == 1 => raw,
                Some(pos) => &raw[..pos],
                None => raw,
            }
            .trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let full_key = if section.is_empty() {
                key.trim().to_string()
            } else {
                format!("{section}.{}", key.trim())
            };
            let value =
                Value::parse(value).map_err(|e| format!("line {}: {e}", lineno + 1))?;
            cfg.values.insert(full_key, value);
        }
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn load(path: &str) -> Result<Config, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Self::parse(&text)
    }

    /// Apply a `--set section.key=value` style override.
    pub fn set(&mut self, assignment: &str) -> Result<(), String> {
        let (key, value) = assignment
            .split_once('=')
            .ok_or_else(|| format!("override {assignment:?} needs key=value"))?;
        self.values
            .insert(key.trim().to_string(), Value::parse(value)?);
        Ok(())
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        match self.get(key) {
            Some(Value::Str(s)) => s.clone(),
            Some(v) => format!("{v:?}"),
            None => default.to_string(),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        match self.get(key) {
            Some(Value::Num(x)) => *x,
            _ => default,
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        match self.get(key) {
            Some(Value::Num(x)) => *x as usize,
            _ => default,
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            Some(Value::Bool(b)) => *b,
            _ => default,
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }
}

/// Build a [`crate::path::PathConfig`] from a config (+ CLI overrides
/// already applied). Missing keys fall back to the library defaults.
pub fn path_config(cfg: &Config) -> crate::path::PathConfig {
    use crate::loss::Loss;
    use crate::screening::{BoundKind, RuleKind, ScreeningConfig};
    let gamma = cfg.f64_or("loss.gamma", 0.05);
    let loss = if gamma > 0.0 {
        Loss::smoothed_hinge(gamma)
    } else {
        Loss::hinge()
    };
    let bound = match cfg.str_or("screening.bound", "RRPB").to_ascii_uppercase().as_str() {
        "NONE" => None,
        "GB" => Some(BoundKind::Gb),
        "PGB" => Some(BoundKind::Pgb),
        "DGB" => Some(BoundKind::Dgb),
        "CDGB" => Some(BoundKind::Cdgb),
        "RPB" => Some(BoundKind::Rpb),
        _ => Some(BoundKind::Rrpb),
    };
    let rule = match cfg.str_or("screening.rule", "sphere").to_ascii_lowercase().as_str() {
        "linear" => RuleKind::Linear,
        "semidefinite" | "sdls" => RuleKind::SemiDefinite,
        _ => RuleKind::Sphere,
    };
    crate::path::PathConfig {
        loss,
        rho: cfg.f64_or("path.rho", 0.9),
        max_steps: cfg.usize_or("path.max_steps", 100),
        stop_ratio: cfg.f64_or("path.stop_ratio", 0.01),
        lambda_min: cfg.get("path.lambda_min").and_then(|v| match v {
            Value::Num(x) => Some(*x),
            _ => None,
        }),
        solver: crate::solver::SolverConfig {
            tol: cfg.f64_or("solver.tol", 1e-6),
            tol_relative: cfg.bool_or("solver.tol_relative", true),
            max_iters: cfg.usize_or("solver.max_iters", 20_000),
            screen_every: cfg.usize_or("solver.screen_every", 10),
            gap_every: cfg.usize_or("solver.gap_every", 1),
        },
        screening: bound.map(|b| ScreeningConfig::new(b, rule)),
        secondary_screening: None,
        active_set: cfg.bool_or("path.active_set", false),
        range_screening: cfg.bool_or("path.range_screening", false),
        range_general: cfg.bool_or("path.range_general", false),
        frame_every: cfg.usize_or("path.frame_every", 1).max(1),
    }
}

/// Native-engine selection from a config's `[engine]` section:
/// `(kernel_core, d_threshold, threads, precision, rank)`, each `None`
/// when the key is absent (CLI flags take precedence over these in
/// `main.rs`).
///
/// Panics on an unrecognized `engine.kernel_core` or `engine.precision`
/// spelling, on negative/fractional `d_threshold`/`threads`, and on a
/// zero/fractional `rank` — a config typo should fail loudly, not
/// silently truncate or fall back to a default. (`rank = 0` is rejected
/// outright: r = 0 has no factored form; omit the key for the dense
/// backend. The r ≤ d check needs the dataset and happens after the
/// data loads, in `crate::runtime::validate_rank`.)
pub fn engine_overrides(
    cfg: &Config,
) -> (
    Option<crate::runtime::KernelCore>,
    Option<usize>,
    Option<usize>,
    Option<crate::runtime::PrecisionTier>,
    Option<usize>,
) {
    let core = cfg.get("engine.kernel_core").map(|v| match v {
        Value::Str(s) => crate::runtime::KernelCore::parse(s)
            .unwrap_or_else(|| panic!("bad engine.kernel_core {s:?}")),
        other => panic!("engine.kernel_core expects a string, got {other:?}"),
    });
    let nonneg_int = |key: &str| {
        cfg.get(key).map(|v| match v {
            Value::Num(x) if *x >= 0.0 && x.fract() == 0.0 => *x as usize,
            other => panic!("{key} expects a non-negative integer, got {other:?}"),
        })
    };
    let d_threshold = nonneg_int("engine.d_threshold");
    let threads = nonneg_int("engine.threads");
    let precision = cfg.get("engine.precision").map(|v| match v {
        Value::Str(s) => crate::runtime::PrecisionTier::parse(s)
            .unwrap_or_else(|| panic!("bad engine.precision {s:?} (use f64 or mixed)")),
        other => panic!("engine.precision expects a string, got {other:?}"),
    });
    let rank = cfg.get("engine.rank").map(|v| match v {
        Value::Num(x) if *x >= 1.0 && x.fract() == 0.0 => *x as usize,
        other => panic!(
            "engine.rank must be a positive integer (r = 0 has no factored form; \
             omit the key for the dense backend), got {other:?}"
        ),
    });
    (core, d_threshold, threads, precision, rank)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
[path]
rho = 0.9
max_steps = 40     # dense enough
active_set = true

[solver]
tol = 1e-7
tol_relative = false

[screening]
bound = "PGB"
rule = "sphere"

[engine]
kernel_core = "d-blocked"
d_threshold = 300
threads = 2
precision = "mixed"
rank = 16

[data]
datasets = ["segment", "wine"]
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.f64_or("path.rho", 0.0), 0.9);
        assert_eq!(c.usize_or("path.max_steps", 0), 40);
        assert!(c.bool_or("path.active_set", false));
        assert_eq!(c.str_or("screening.bound", ""), "PGB");
        match c.get("data.datasets") {
            Some(Value::Arr(items)) => {
                assert_eq!(items[0], Value::Str("segment".into()));
                assert_eq!(items[1], Value::Str("wine".into()));
            }
            other => panic!("bad array: {other:?}"),
        }
    }

    #[test]
    fn overrides_win() {
        let mut c = Config::parse(SAMPLE).unwrap();
        c.set("path.rho=0.99").unwrap();
        c.set("solver.tol=1e-9").unwrap();
        assert_eq!(c.f64_or("path.rho", 0.0), 0.99);
        assert_eq!(c.f64_or("solver.tol", 0.0), 1e-9);
    }

    #[test]
    fn builds_path_config() {
        let c = Config::parse(SAMPLE).unwrap();
        let pc = path_config(&c);
        assert_eq!(pc.rho, 0.9);
        assert!(pc.active_set);
        assert!(!pc.solver.tol_relative);
        assert_eq!(pc.solver.tol, 1e-7);
        assert_eq!(
            pc.screening.map(|s| s.bound),
            Some(crate::screening::BoundKind::Pgb)
        );
    }

    #[test]
    fn engine_section_parses() {
        let c = Config::parse(SAMPLE).unwrap();
        let (core, d_threshold, threads, precision, rank) = engine_overrides(&c);
        assert_eq!(core, Some(crate::runtime::KernelCore::DBlocked));
        assert_eq!(d_threshold, Some(300));
        assert_eq!(threads, Some(2));
        assert_eq!(
            precision,
            Some(crate::runtime::PrecisionTier::MixedCertified)
        );
        assert_eq!(rank, Some(16));
        // absent section: all None
        let empty = Config::parse("[path]\nrho = 0.9\n").unwrap();
        assert_eq!(engine_overrides(&empty), (None, None, None, None, None));
    }

    #[test]
    fn engine_precision_spellings() {
        for (text, want) in [
            ("f64", crate::runtime::PrecisionTier::F64),
            ("double", crate::runtime::PrecisionTier::F64),
            ("exact", crate::runtime::PrecisionTier::F64),
            ("mixed", crate::runtime::PrecisionTier::MixedCertified),
            ("mixed-certified", crate::runtime::PrecisionTier::MixedCertified),
            ("F32", crate::runtime::PrecisionTier::MixedCertified),
        ] {
            let c =
                Config::parse(&format!("[engine]\nprecision = \"{text}\"\n")).unwrap();
            assert_eq!(engine_overrides(&c).3, Some(want), "spelling {text:?}");
        }
    }

    #[test]
    #[should_panic(expected = "bad engine.kernel_core")]
    fn engine_core_typo_fails_loudly() {
        let c = Config::parse("[engine]\nkernel_core = \"dblockedd\"\n").unwrap();
        let _ = engine_overrides(&c);
    }

    #[test]
    #[should_panic(expected = "bad engine.precision")]
    fn engine_precision_typo_fails_loudly() {
        let c = Config::parse("[engine]\nprecision = \"f16\"\n").unwrap();
        let _ = engine_overrides(&c);
    }

    #[test]
    #[should_panic(expected = "expects a string")]
    fn engine_precision_non_string_fails_loudly() {
        let c = Config::parse("[engine]\nprecision = 32\n").unwrap();
        let _ = engine_overrides(&c);
    }

    #[test]
    #[should_panic(expected = "non-negative integer")]
    fn engine_negative_threshold_fails_loudly() {
        let c = Config::parse("[engine]\nd_threshold = -1\n").unwrap();
        let _ = engine_overrides(&c);
    }

    #[test]
    #[should_panic(expected = "non-negative integer")]
    fn engine_fractional_threads_fail_loudly() {
        let c = Config::parse("[engine]\nthreads = 2.7\n").unwrap();
        let _ = engine_overrides(&c);
    }

    #[test]
    #[should_panic(expected = "engine.rank must be a positive integer")]
    fn engine_zero_rank_fails_loudly() {
        let c = Config::parse("[engine]\nrank = 0\n").unwrap();
        let _ = engine_overrides(&c);
    }

    #[test]
    #[should_panic(expected = "engine.rank must be a positive integer")]
    fn engine_fractional_rank_fails_loudly() {
        let c = Config::parse("[engine]\nrank = 12.5\n").unwrap();
        let _ = engine_overrides(&c);
    }

    #[test]
    #[should_panic(expected = "engine.rank must be a positive integer")]
    fn engine_non_numeric_rank_fails_loudly() {
        let c = Config::parse("[engine]\nrank = \"full\"\n").unwrap();
        let _ = engine_overrides(&c);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Config::parse("novalue").is_err());
        assert!(Config::parse("k = ").is_err());
    }

    #[test]
    fn comments_and_blank_lines() {
        let c = Config::parse("# only comments\n\n[a]\nk = 1 # trailing\n").unwrap();
        assert_eq!(c.f64_or("a.k", 0.0), 1.0);
    }
}
