//! Zero-dependency substrate utilities.
//!
//! The build environment is fully offline with only the `xla` and `anyhow`
//! crates vendored, so the conveniences a production crate would normally
//! pull in (rand, rayon, serde_json, clap, criterion, proptest) are
//! implemented here from scratch — each in its own small module.

pub mod bench;
pub mod cli;
pub mod config;
pub mod json;
pub mod parallel;
pub mod pool;
pub mod quickcheck;
pub mod rng;
pub mod timer;
