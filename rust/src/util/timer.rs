//! Timing utilities: stopwatch accumulators for the per-phase cost
//! breakdowns the paper's tables report (solver vs screening-eval time).

use std::time::{Duration, Instant};

/// Accumulating stopwatch: `start`/`stop` pairs add up.
#[derive(Debug, Default, Clone)]
pub struct Stopwatch {
    total: Duration,
    started: Option<Instant>,
    laps: u64,
}

impl Stopwatch {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn start(&mut self) {
        debug_assert!(self.started.is_none(), "stopwatch already running");
        self.started = Some(Instant::now());
    }

    pub fn stop(&mut self) {
        if let Some(t0) = self.started.take() {
            self.total += t0.elapsed();
            self.laps += 1;
        }
    }

    /// Time a closure, accumulating its duration.
    pub fn time<T>(&mut self, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.total += t0.elapsed();
        self.laps += 1;
        out
    }

    /// Add an externally measured duration as one lap.
    pub fn add(&mut self, d: Duration) {
        self.total += d;
        self.laps += 1;
    }

    pub fn secs(&self) -> f64 {
        self.total.as_secs_f64()
    }

    pub fn laps(&self) -> u64 {
        self.laps
    }
}

/// Per-phase cost breakdown of one solve.
#[derive(Debug, Default, Clone)]
pub struct PhaseTimers {
    /// margin/gradient kernel evaluations
    pub compute: Stopwatch,
    /// eigendecompositions (PSD projections)
    pub eig: Stopwatch,
    /// screening-rule evaluation (the quantity Table 4 parenthesizes)
    pub screening: Stopwatch,
    /// everything, wall clock
    pub total: Stopwatch,
}

impl PhaseTimers {
    pub fn merge(&mut self, other: &PhaseTimers) {
        self.compute.total += other.compute.total;
        self.compute.laps += other.compute.laps;
        self.eig.total += other.eig.total;
        self.eig.laps += other.eig.laps;
        self.screening.total += other.screening.total;
        self.screening.laps += other.screening.laps;
        self.total.total += other.total.total;
        self.total.laps += other.total.laps;
    }
}
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_laps() {
        let mut sw = Stopwatch::new();
        for _ in 0..3 {
            sw.time(|| std::thread::sleep(Duration::from_millis(2)));
        }
        assert_eq!(sw.laps(), 3);
        assert!(sw.secs() >= 0.006);
    }

    #[test]
    fn start_stop_pairs() {
        let mut sw = Stopwatch::new();
        sw.start();
        std::thread::sleep(Duration::from_millis(1));
        sw.stop();
        assert!(sw.secs() > 0.0);
        assert_eq!(sw.laps(), 1);
    }

    #[test]
    fn merge_adds() {
        let mut a = PhaseTimers::default();
        let mut b = PhaseTimers::default();
        a.compute.time(|| std::thread::sleep(Duration::from_millis(1)));
        b.compute.time(|| std::thread::sleep(Duration::from_millis(1)));
        let before = a.compute.secs();
        a.merge(&b);
        assert!(a.compute.secs() > before);
        assert_eq!(a.compute.laps(), 2);
    }
}
