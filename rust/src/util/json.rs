//! Minimal JSON reader/writer (serde_json stand-in).
//!
//! Reads the artifact `manifest.json` emitted by `python/compile/aot.py`
//! and writes experiment reports. Supports the full JSON value grammar;
//! numbers are kept as f64 (adequate for our payloads).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Build an object from pairs (report-writing convenience).
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    item.write(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

/// Recursively collect every object key appearing anywhere in `doc`
/// (array elements included) into `out`.
pub fn collect_keys(doc: &Json, out: &mut std::collections::BTreeSet<String>) {
    match doc {
        Json::Obj(map) => {
            for (k, v) in map {
                out.insert(k.clone());
                collect_keys(v, out);
            }
        }
        Json::Arr(items) => {
            for v in items {
                collect_keys(v, out);
            }
        }
        _ => {}
    }
}

/// Keys of `doc` that never appear as a standalone word in `schema_md` —
/// the bench-schema rot guard: `benches/screening.rs` runs this against
/// `rust/docs/BENCH_SCHEMA.md` (compiled in via `include_str!`) and
/// fails if a telemetry field was added without documenting it.
pub fn undocumented_keys(doc: &Json, schema_md: &str) -> Vec<String> {
    let mut keys = std::collections::BTreeSet::new();
    collect_keys(doc, &mut keys);
    keys.into_iter()
        .filter(|k| !appears_as_word(schema_md, k))
        .collect()
}

/// Whether `word` occurs in `text` with non-identifier characters (or
/// the text boundary) on both sides. Keys are ASCII identifiers, so
/// byte-level boundary checks are safe.
fn appears_as_word(text: &str, word: &str) -> bool {
    if word.is_empty() {
        return false;
    }
    let bytes = text.as_bytes();
    let mut start = 0;
    while let Some(pos) = text[start..].find(word) {
        let abs = start + pos;
        let end = abs + word.len();
        let before_ok = abs == 0 || !is_word_byte(bytes[abs - 1]);
        let after_ok = end >= bytes.len() || !is_word_byte(bytes[end]);
        if before_ok && after_ok {
            return true;
        }
        start = abs + 1;
    }
    false
}

fn is_word_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json, String> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing content at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {} (found {:?})",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos)),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {s:?}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u")?,
                                16,
                            )
                            .map_err(|_| "bad \\u digits")?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 scalar
                    let s = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| "invalid utf-8")?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => return Err(format!("expected , or ] (found {other:?})")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => return Err(format!("expected , or }} (found {other:?})")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let text = r#"{
          "dispatch_n": 8192,
          "pallas_block": 512,
          "dtype": "f64",
          "artifacts": [
            {"entry": "margins", "d": 19, "n": 8192, "file": "margins_d19_b8192.hlo.txt"}
          ]
        }"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("dispatch_n").unwrap().as_usize(), Some(8192));
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("entry").unwrap().as_str(), Some("margins"));
        assert_eq!(arts[0].get("d").unwrap().as_usize(), Some(19));
    }

    #[test]
    fn roundtrip_values() {
        let v = Json::obj(vec![
            ("a", Json::Num(1.5)),
            ("b", Json::Arr(vec![Json::Bool(true), Json::Null])),
            ("s", Json::Str("hi \"there\"\n".into())),
        ]);
        let text = v.to_string_pretty();
        let back = parse(&text).unwrap();
        assert_eq!(v, back);
        let compact = v.to_string_compact();
        assert_eq!(parse(&compact).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(parse("0").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap().as_str(), Some("A"));
    }

    #[test]
    fn collect_keys_walks_nested_arrays() {
        let doc = Json::obj(vec![
            ("top", Json::Num(1.0)),
            (
                "steps",
                Json::Arr(vec![Json::obj(vec![
                    ("lambda", Json::Num(0.5)),
                    ("inner", Json::obj(vec![("deep", Json::Null)])),
                ])]),
            ),
        ]);
        let mut keys = std::collections::BTreeSet::new();
        collect_keys(&doc, &mut keys);
        let got: Vec<&str> = keys.iter().map(|s| s.as_str()).collect();
        assert_eq!(got, vec!["deep", "inner", "lambda", "steps", "top"]);
    }

    #[test]
    fn undocumented_keys_respects_word_boundaries() {
        let doc = Json::obj(vec![
            ("wall", Json::Num(1.0)),
            ("wall_seconds", Json::Num(2.0)),
            ("missing_field", Json::Num(3.0)),
        ]);
        // `wall_seconds` documents itself but must NOT satisfy `wall`;
        // `{lambda, wall}`-style brace lists must count
        let md = "| `wall_seconds` | step wall |\narray of `{lambda, wall}` records\n";
        let missing = undocumented_keys(&doc, md);
        assert_eq!(missing, vec!["missing_field".to_string()]);
        let md2 = "only `wall_seconds` here";
        let missing2 = undocumented_keys(&doc, md2);
        assert_eq!(
            missing2,
            vec!["missing_field".to_string(), "wall".to_string()]
        );
    }
}
