//! Reusable scratch-lane pool.
//!
//! Hot kernels (`runtime::NativeEngine` workers) and the eigensolver
//! (`linalg::sym_eig`) need short-lived scratch lanes every call;
//! pooling them means the steady state allocates nothing. One
//! implementation serves the per-engine pools and the process-global
//! eig-workspace static (`new` is `const`). The pool is generic over
//! the lane element (default `f64`; the mixed-precision tier pools
//! `f32` conversion lanes through the same type).
//!
//! Discipline: `take(len)` hands out a lane of exactly `len` with
//! *unspecified* contents (recycled data or zeros) for consumers that
//! fully overwrite before reading — the hot kernels, whose per-call
//! memset this avoids; `take_zeroed(len)` adds the zero guarantee for
//! consumers that read before writing every slot. `put` returns the
//! lane. The pool is LIFO and capped — it can never hold more lanes
//! than a few full worker complements, so a burst of takers degrades to
//! plain allocation instead of unbounded growth.

use std::sync::Mutex;

/// Capped LIFO pool of reusable `Vec<T>` lanes (`T = f64` by default).
pub struct ScratchPool<T = f64> {
    bufs: Mutex<Vec<Vec<T>>>,
    cap: usize,
}

impl<T: Clone + Default> ScratchPool<T> {
    /// Pool retaining at most `cap` lanes (const: usable in statics).
    pub const fn new(cap: usize) -> ScratchPool<T> {
        ScratchPool {
            bufs: Mutex::new(Vec::new()),
            cap,
        }
    }

    /// A lane of length `len` with unspecified contents (recycled data
    /// in the prefix, zeros in any extension) — for consumers that
    /// fully overwrite before reading. No O(len) memset on the hot
    /// path.
    pub fn take(&self, len: usize) -> Vec<T> {
        let mut v = self
            .bufs
            .lock()
            .expect("scratch pool poisoned")
            .pop()
            .unwrap_or_default();
        v.truncate(len);
        v.resize(len, T::default());
        v
    }

    /// A zeroed lane of length `len` — for consumers that may read a
    /// slot before writing it.
    pub fn take_zeroed(&self, len: usize) -> Vec<T> {
        let mut v = self.take(len);
        v.fill(T::default());
        v
    }

    /// Return a lane to the pool (dropped when the pool is full).
    pub fn put(&self, v: Vec<T>) {
        let mut pool = self.bufs.lock().expect("scratch pool poisoned");
        if pool.len() < self.cap {
            pool.push(v);
        }
    }

    /// Lanes currently held (introspection for tests/diagnostics).
    pub fn pooled(&self) -> usize {
        self.bufs.lock().expect("scratch pool poisoned").len()
    }
}

impl<T: Clone + Default> Default for ScratchPool<T> {
    /// Default cap covers a few complements of the ≤16 parallel workers.
    fn default() -> Self {
        ScratchPool::new(64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recycles_lanes() {
        let pool = ScratchPool::default();
        let mut v = pool.take(8);
        v[3] = 5.0;
        pool.put(v);
        let v2 = pool.take(16);
        assert_eq!(v2.len(), 16);
        // extension beyond the recycled capacity is zeroed
        assert!(v2[8..].iter().all(|&x| x == 0.0));
        pool.put(v2);
        assert_eq!(pool.pooled(), 1);
    }

    #[test]
    fn take_zeroed_clears_recycled_data() {
        let pool = ScratchPool::default();
        let mut v = pool.take(8);
        v.fill(7.0);
        pool.put(v);
        let v2 = pool.take_zeroed(4);
        assert_eq!(v2.len(), 4);
        assert!(v2.iter().all(|&x| x == 0.0), "recycled lane not zeroed");
    }

    #[test]
    fn cap_bounds_growth() {
        let pool = ScratchPool::<f64>::new(3);
        let lanes: Vec<_> = (0..8).map(|_| pool.take(4)).collect();
        for v in lanes {
            pool.put(v);
        }
        assert!(pool.pooled() <= 3);
    }

    #[test]
    fn const_constructor_works_in_static() {
        static S: ScratchPool = ScratchPool::new(2);
        let v = S.take(5);
        assert_eq!(v.len(), 5);
        S.put(v);
        assert_eq!(S.pooled(), 1);
    }

    #[test]
    fn f32_lanes_pool_independently() {
        let pool: ScratchPool<f32> = ScratchPool::new(4);
        let mut v = pool.take(6);
        v[0] = 1.5f32;
        pool.put(v);
        let v2 = pool.take(3);
        assert_eq!(v2.len(), 3);
        pool.put(v2);
        assert_eq!(pool.pooled(), 1);
    }
}
