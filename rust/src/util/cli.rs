//! Tiny argument parser (clap stand-in).
//!
//! Grammar: `prog <subcommand> [positionals...] [--key value | --key=value | --flag]`.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positionals: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list (first token = subcommand unless it
    /// starts with `-`).
    pub fn parse_from<I: IntoIterator<Item = String>>(tokens: I) -> Args {
        let mut args = Args::default();
        let mut iter = tokens.into_iter().peekable();
        if let Some(first) = iter.peek() {
            if !first.starts_with('-') {
                args.subcommand = iter.next();
            }
        }
        while let Some(tok) = iter.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.options.insert(stripped.to_string(), v);
                } else {
                    args.flags.push(stripped.to_string());
                }
            } else {
                args.positionals.push(tok);
            }
        }
        args
    }

    /// Parse the process command line (skipping argv[0]).
    pub fn parse() -> Args {
        Args::parse_from(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got {s:?}")))
            .unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {s:?}")))
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("fig4 --dataset segment --rho=0.9 --verbose --n 500");
        assert_eq!(a.subcommand.as_deref(), Some("fig4"));
        assert_eq!(a.get("dataset"), Some("segment"));
        assert_eq!(a.get_f64("rho", 0.0), 0.9);
        assert_eq!(a.get_usize("n", 0), 500);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn no_subcommand_when_leading_flag() {
        let a = parse("--help");
        assert_eq!(a.subcommand, None);
        assert!(a.flag("help"));
    }

    #[test]
    fn trailing_flag_not_eating_next_option() {
        let a = parse("run --fast --k 3");
        assert!(a.flag("fast"));
        assert_eq!(a.get_usize("k", 0), 3);
    }

    #[test]
    fn positionals_collected() {
        let a = parse("bench kernels margins");
        assert_eq!(a.subcommand.as_deref(), Some("bench"));
        assert_eq!(a.positionals, vec!["kernels", "margins"]);
    }
}
