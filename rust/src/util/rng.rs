//! PCG64 pseudo-random generator + the distributions this crate needs.
//!
//! Deterministic across platforms (pure integer arithmetic), seedable, and
//! splittable via [`Pcg64::fork`] so parallel data generation stays
//! reproducible regardless of thread scheduling.

/// PCG-XSL-RR 128/64 (Melissa O'Neill's PCG family).
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Pcg64 {
    /// Seed with a stream id derived from the seed itself.
    pub fn seed(seed: u64) -> Self {
        Self::seed_stream(seed, 0xda3e39cb94b95bdb)
    }

    /// Seed with an explicit stream (increment) selector.
    pub fn seed_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
        };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Derive an independent generator (for a worker thread / sub-task).
    pub fn fork(&mut self, tag: u64) -> Pcg64 {
        let seed = self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15);
        Pcg64::seed_stream(seed, tag.wrapping_add(0x5851f42d4c957f2d))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free for our use).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // 128-bit multiply keeps the modulo bias below 2^-64 — negligible.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (we always consume pairs; caching one
    /// value would make `fork` reproducibility subtle for no gain).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (std::f64::consts::TAU * u2).cos();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (floyd's algorithm when
    /// k << n, shuffle otherwise).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 4 > n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            all
        } else {
            let mut set = std::collections::HashSet::with_capacity(k);
            let mut out = Vec::with_capacity(k);
            for j in (n - k)..n {
                let t = self.below(j + 1);
                let v = if set.insert(t) { t } else { j };
                if v != t {
                    set.insert(v);
                }
                out.push(v);
            }
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::seed(42);
        let mut b = Pcg64::seed(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seed(1);
        let mut b = Pcg64::seed(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_unit_interval_and_mean_half() {
        let mut rng = Pcg64::seed(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seed(11);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut rng = Pcg64::seed(3);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[rng.below(10)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seed(9);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg64::seed(5);
        for (n, k) in [(100, 5), (50, 40), (10, 10)] {
            let s = rng.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Pcg64::seed(1);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
