//! Mini property-testing framework (proptest stand-in).
//!
//! A property runs against `cases` randomly generated inputs; on failure it
//! reports the case index and the seed that reproduces it, so a failing run
//! can be replayed deterministically with `TS_QC_SEED`.

use crate::util::rng::Pcg64;

/// Number of cases per property (override with `TS_QC_CASES`).
pub fn default_cases() -> usize {
    std::env::var("TS_QC_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

fn base_seed() -> u64 {
    std::env::var("TS_QC_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0x5eed_cafe)
}

/// Check `prop(rng)` for `cases` independent generators; panic with a
/// reproducible seed on the first failure. `prop` returns `Err(msg)` to
/// fail, `Ok(())` to pass.
pub fn forall<F>(name: &str, cases: usize, prop: F)
where
    F: Fn(&mut Pcg64) -> Result<(), String>,
{
    let seed0 = base_seed();
    for case in 0..cases {
        let seed = seed0.wrapping_add(case as u64);
        let mut rng = Pcg64::seed(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property {name:?} failed on case {case}/{cases} \
                 (replay with TS_QC_SEED={seed} TS_QC_CASES=1): {msg}"
            );
        }
    }
}

/// Assert two floats are close (relative + absolute tolerance), with a
/// useful error payload for `forall`.
pub fn close(a: f64, b: f64, rtol: f64, atol: f64, what: &str) -> Result<(), String> {
    let diff = (a - b).abs();
    let bound = atol + rtol * a.abs().max(b.abs());
    if diff <= bound || (a.is_nan() && b.is_nan()) {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (|Δ|={diff:.3e} > {bound:.3e})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall("add-commutes", 32, |rng| {
            let (a, b) = (rng.normal(), rng.normal());
            close(a + b, b + a, 0.0, 0.0, "a+b")
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_reports_seed() {
        forall("always-fails", 4, |_| Err("nope".into()));
    }

    #[test]
    fn close_tolerances() {
        assert!(close(1.0, 1.0 + 1e-12, 1e-9, 0.0, "x").is_ok());
        assert!(close(1.0, 1.1, 1e-9, 0.0, "x").is_err());
        assert!(close(0.0, 1e-12, 0.0, 1e-9, "x").is_ok());
    }
}

/// Cross-module property tests: screening-rule brackets and workset
/// compaction, randomized over problem geometry. They live here so every
/// invariant the mini-quickcheck framework protects is exercised from one
/// place (and `TS_QC_SEED` replays apply uniformly).
#[cfg(test)]
mod screening_properties {
    use super::{close, forall};
    use crate::linalg::{psd_project, Mat};
    use crate::screening::rules;
    use crate::screening::sdls::{self, SdlsQuery};
    use crate::util::rng::Pcg64;

    struct Case {
        q: Mat,
        h: Mat,
        a: Vec<f64>,
        b: Vec<f64>,
        r: f64,
    }

    /// Random PSD sphere center + triplet H = aaᵀ − bbᵀ.
    fn random_case(rng: &mut Pcg64) -> Case {
        let d = 2 + rng.below(4);
        let mut base = Mat::from_fn(d, d, |_, _| rng.normal());
        base.symmetrize();
        let q = psd_project(&base).scaled(rng.uniform() * 2.0 + 0.05);
        let a: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..d).map(|_| rng.normal() * rng.uniform()).collect();
        let h = Mat::outer(&a).sub(&Mat::outer(&b));
        let r = rng.uniform() * 2.0 + 0.01;
        Case { q, h, a, b, r }
    }

    /// For every rule, the certified minimum/maximum of `⟨X, H⟩` over the
    /// rule's feasible set must bracket the center value `⟨H, Q⟩` whenever
    /// the center is feasible — a rule whose bracket excludes its own
    /// center would screen unsafely.
    #[test]
    fn rule_brackets_contain_center_value() {
        forall("rule-min-max-bracket", 96, |rng| {
            let c = random_case(rng);
            let (hq, hn) = (c.q.dot(&c.h), c.h.norm());

            // sphere rule bracket
            let (s_min, s_max) = (hq - c.r * hn, hq + c.r * hn);
            if !(s_min <= hq && hq <= s_max) {
                return Err(format!("sphere bracket [{s_min}, {s_max}] excludes hq={hq}"));
            }

            // linear rule bracket, with a halfspace that keeps Q feasible
            let d = c.q.rows();
            let mut p = Mat::from_fn(d, d, |_, _| rng.normal());
            p.symmetrize();
            if p.dot(&c.q) < 0.0 {
                p.scale(-1.0); // ⟨P, Q⟩ ≥ 0 ⇒ Q itself satisfies the halfspace
            }
            let (hp, pq, pn_sq) = (p.dot(&c.h), p.dot(&c.q), p.norm_sq());
            let l_min = rules::linear_min(hq, hn, hp, pq, pn_sq, c.r);
            let l_max = -rules::linear_min(-hq, hn, -hp, pq, pn_sq, c.r);
            let slack = 1e-9 * (1.0 + hq.abs());
            if l_min > hq + slack {
                return Err(format!("linear min {l_min} above feasible hq={hq}"));
            }
            if l_max < hq - slack {
                return Err(format!("linear max {l_max} below feasible hq={hq}"));
            }

            // SDLS rule: Q ∈ B ∩ PSD with value hq, so a threshold on the
            // wrong side of hq must never be certified
            let query = SdlsQuery {
                q: &c.q,
                q_norm_sq: c.q.norm_sq(),
                psd_center: true,
                r_sq: c.r * c.r,
                a: &c.a,
                b: &c.b,
                hq,
                hn,
                hx0: hq,
            };
            let c_r = hq + 0.1 * (1.0 + hq.abs());
            let c_l = hq - 0.1 * (1.0 + hq.abs());
            if sdls::sdls_screens_r(&query, c_r, 40) {
                return Err(format!("SDLS screened R past its own center (c={c_r}, hq={hq})"));
            }
            if sdls::sdls_screens_l(&query, c_l, 40) {
                return Err(format!("SDLS screened L past its own center (c={c_l}, hq={hq})"));
            }
            Ok(())
        });
    }

    /// The sphere-rule bracket is exactly the Cauchy–Schwarz extreme over
    /// the ball: sampled points inside B(Q, r) never escape it.
    #[test]
    fn sphere_bracket_is_sound_under_sampling() {
        forall("sphere-bracket-sampling", 48, |rng| {
            let c = random_case(rng);
            let (hq, hn) = (c.q.dot(&c.h), c.h.norm());
            let d = c.q.rows();
            for _ in 0..32 {
                let mut w = Mat::from_fn(d, d, |_, _| rng.normal());
                w.symmetrize();
                let nw = w.norm();
                if nw > 0.0 {
                    w.scale(c.r * rng.uniform() / nw);
                }
                let x = c.q.add(&w);
                let v = x.dot(&c.h);
                let lo = hq - c.r * hn - 1e-9 * (1.0 + v.abs());
                let hi = hq + c.r * hn + 1e-9 * (1.0 + v.abs());
                if v < lo || v > hi {
                    return Err(format!("sampled value {v} outside [{lo}, {hi}]"));
                }
            }
            Ok(())
        });
    }

    /// `close` sanity on the rule algebra: mirrored linear_min equals the
    /// negated max of the mirrored problem.
    #[test]
    fn linear_min_mirror_identity() {
        forall("linear-mirror", 64, |rng| {
            let c = random_case(rng);
            let (hq, hn) = (c.q.dot(&c.h), c.h.norm());
            let d = c.q.rows();
            let mut p = Mat::from_fn(d, d, |_, _| rng.normal());
            p.symmetrize();
            let (hp, pq, pn_sq) = (p.dot(&c.h), p.dot(&c.q), p.norm_sq());
            let max_via_min = -rules::linear_min(-hq, hn, -hp, pq, pn_sq, c.r);
            let min_direct = rules::linear_min(hq, hn, hp, pq, pn_sq, c.r);
            // max of ⟨X,H⟩ ≥ min of ⟨X,H⟩ over the same nonempty set
            if pq >= 0.0 && max_via_min < min_direct - 1e-9 * (1.0 + min_direct.abs()) {
                return Err(format!("max {max_via_min} < min {min_direct}"));
            }
            Ok(())
        });
    }

    #[test]
    fn close_helper_rejects_nan_mismatch() {
        assert!(close(f64::NAN, 1.0, 1e-9, 1e-9, "nan-vs-num").is_err());
    }
}

/// Factored-backend properties: the r×r Gram norm identity and the O(r)
/// embedded margins, randomized over factor shapes including rank 1 and
/// the GEMM panel boundaries `PANEL_ROWS ± 1`.
#[cfg(test)]
mod factored_properties {
    use super::{close, forall};
    use crate::linalg::gemm::{self, PANEL_ROWS};
    use crate::linalg::{LowRankFactor, Mat};
    use crate::runtime::{Engine, NativeEngine};
    use crate::util::rng::Pcg64;

    /// Random L (r×d) at shapes that straddle the panel boundaries.
    fn random_factor(rng: &mut Pcg64) -> (usize, Mat) {
        let dims = [1, 2, PANEL_ROWS - 1, PANEL_ROWS, PANEL_ROWS + 1];
        let d = dims[rng.below(dims.len())];
        let ranks = [1, 2, PANEL_ROWS - 1, PANEL_ROWS, PANEL_ROWS + 1];
        let r = ranks[rng.below(ranks.len())].min(d);
        (d, Mat::from_fn(r, d, |_, _| rng.normal()))
    }

    /// `‖LᵀL‖_F == ‖L Lᵀ‖_F` (cyclic trace): the factored backend's
    /// `ref_norm`, served from the r×r Gram, must equal the dense norm
    /// of the reconstruction it hands to the screening layer.
    #[test]
    fn gram_norm_equals_dense_reconstruction_norm() {
        forall("factored-norm-identity", 64, |rng| {
            let (_, l) = random_factor(rng);
            let f = LowRankFactor::from_l(l);
            let dense = f.to_dense(1);
            close(f.norm(), dense.norm(), 1e-10, 1e-12, "‖LLᵀ‖_F vs ‖LᵀL‖_F")
        });
    }

    /// Embedded margins (`‖z_a‖² − ‖z_b‖²` with `Z = X Lᵀ`) equal the
    /// dense margins of the reconstruction `M̃ = LᵀL` — at every rank
    /// (including r = d, the decision-parity regime), since both sides
    /// are exact quadratic forms of the same matrix.
    #[test]
    fn embedded_margins_match_dense_margins() {
        forall("factored-margin-identity", 48, |rng| {
            let (d, l) = random_factor(rng);
            let n = 1 + rng.below(2 * PANEL_ROWS);
            let a = Mat::from_fn(n, d, |_, _| rng.normal());
            let b = Mat::from_fn(n, d, |_, _| rng.normal());
            let f = LowRankFactor::from_l(l);
            let (za, zb) = (f.embed(&a, 1), f.embed(&b, 1));
            let mut fac = vec![0.0; n];
            gemm::embed_margins_into(&za, &zb, 0..n, &mut fac);
            let dense = f.to_dense(1);
            let engine = NativeEngine::scalar(1);
            let mut want = vec![0.0; n];
            engine.margins(&dense, &a, &b, &mut want);
            for t in 0..n {
                close(fac[t], want[t], 1e-9, 1e-9, &format!("margin[{t}]"))?;
            }
            Ok(())
        });
    }
}

#[cfg(test)]
mod workset_properties {
    use super::forall;
    use crate::data::synthetic;
    use crate::triplet::{ActiveWorkset, TripletStore};
    use crate::util::rng::Pcg64;

    /// Compaction must preserve the id↔row mapping under arbitrary retire
    /// sequences (random order, duplicates included), with every lane —
    /// a/b rows, ‖H‖, the reference-margin lane — staying in lockstep.
    #[test]
    fn compaction_preserves_mapping_under_arbitrary_retires() {
        forall("workset-compaction", 24, |rng| {
            let n_pts = 16 + rng.below(24);
            let d = 2 + rng.below(4);
            let ds = synthetic::gaussian_mixture("w", n_pts, d, 2, 2.0, rng);
            let store = TripletStore::from_dataset(&ds, 2, rng);
            let n = store.len();
            if n == 0 {
                return Ok(());
            }
            let mut ws = ActiveWorkset::full(&store);
            let lane: Vec<f64> = (0..n).map(|t| (t as f64).sin()).collect();
            ws.install_ref_margins(&lane, 5);

            let retires = 1 + rng.below(2 * n);
            let mut expected_active = vec![true; n];
            for _ in 0..retires {
                let id = rng.below(n);
                let was_active = expected_active[id];
                let did = ws.retire(id);
                if did != was_active {
                    return Err(format!(
                        "retire({id}) returned {did}, expected {was_active}"
                    ));
                }
                expected_active[id] = false;

                // spot-check the mapping after every retire
                if ws.row_of(id).is_some() {
                    return Err(format!("retired id {id} still mapped to a row"));
                }
                for (row, &rid) in ws.ids().iter().enumerate() {
                    if ws.row_of(rid) != Some(row) {
                        return Err(format!("row_of({rid}) != {row} after retiring {id}"));
                    }
                }
            }

            // full invariant audit: rows match the store, lanes aligned
            ws.assert_consistent(&store);
            let rm = ws.ref_margins(5).expect("lane installed");
            for (row, &rid) in ws.ids().iter().enumerate() {
                if rm[row] != lane[rid] {
                    return Err(format!("lane misaligned: row {row} id {rid}"));
                }
                if !expected_active[rid] {
                    return Err(format!("id {rid} active in workset but retired"));
                }
            }
            let n_active = expected_active.iter().filter(|&&x| x).count();
            if ws.len() != n_active {
                return Err(format!("len {} != expected {n_active}", ws.len()));
            }
            Ok(())
        });
    }
}
