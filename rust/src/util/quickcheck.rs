//! Mini property-testing framework (proptest stand-in).
//!
//! A property runs against `cases` randomly generated inputs; on failure it
//! reports the case index and the seed that reproduces it, so a failing run
//! can be replayed deterministically with `TS_QC_SEED`.

use crate::util::rng::Pcg64;

/// Number of cases per property (override with `TS_QC_CASES`).
pub fn default_cases() -> usize {
    std::env::var("TS_QC_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

fn base_seed() -> u64 {
    std::env::var("TS_QC_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0x5eed_cafe)
}

/// Check `prop(rng)` for `cases` independent generators; panic with a
/// reproducible seed on the first failure. `prop` returns `Err(msg)` to
/// fail, `Ok(())` to pass.
pub fn forall<F>(name: &str, cases: usize, prop: F)
where
    F: Fn(&mut Pcg64) -> Result<(), String>,
{
    let seed0 = base_seed();
    for case in 0..cases {
        let seed = seed0.wrapping_add(case as u64);
        let mut rng = Pcg64::seed(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property {name:?} failed on case {case}/{cases} \
                 (replay with TS_QC_SEED={seed} TS_QC_CASES=1): {msg}"
            );
        }
    }
}

/// Assert two floats are close (relative + absolute tolerance), with a
/// useful error payload for `forall`.
pub fn close(a: f64, b: f64, rtol: f64, atol: f64, what: &str) -> Result<(), String> {
    let diff = (a - b).abs();
    let bound = atol + rtol * a.abs().max(b.abs());
    if diff <= bound || (a.is_nan() && b.is_nan()) {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (|Δ|={diff:.3e} > {bound:.3e})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall("add-commutes", 32, |rng| {
            let (a, b) = (rng.normal(), rng.normal());
            close(a + b, b + a, 0.0, 0.0, "a+b")
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_reports_seed() {
        forall("always-fails", 4, |_| Err("nope".into()));
    }

    #[test]
    fn close_tolerances() {
        assert!(close(1.0, 1.0 + 1e-12, 1e-9, 0.0, "x").is_ok());
        assert!(close(1.0, 1.1, 1e-9, 0.0, "x").is_err());
        assert!(close(0.0, 1e-12, 0.0, 1e-9, "x").is_ok());
    }
}
