//! Micro-benchmark harness (criterion stand-in).
//!
//! Warms up, then runs timed iterations until both a minimum iteration
//! count and a minimum measurement window are reached; reports mean /
//! best / throughput. Used by the `benches/` targets (built with
//! `harness = false`).

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub best: Duration,
    /// optional items-per-iteration for throughput reporting
    pub items: Option<u64>,
}

impl Measurement {
    pub fn report(&self) -> String {
        let mean_s = self.mean.as_secs_f64();
        let mut s = format!(
            "{:<44} {:>12} {:>12}  x{}",
            self.name,
            fmt_duration(self.mean),
            fmt_duration(self.best),
            self.iters
        );
        if let Some(items) = self.items {
            let thr = items as f64 / mean_s;
            s.push_str(&format!("  {:>12}/s", fmt_count(thr)));
        }
        s
    }
}

fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.3}µs", s * 1e6)
    }
}

fn fmt_count(x: f64) -> String {
    if x >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.2}k", x / 1e3)
    } else {
        format!("{x:.1}")
    }
}

/// Benchmark runner with shared config.
pub struct Bench {
    pub min_iters: u64,
    pub min_time: Duration,
    pub warmup: Duration,
    results: Vec<Measurement>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            min_iters: 5,
            min_time: Duration::from_millis(300),
            warmup: Duration::from_millis(100),
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn quick() -> Bench {
        Bench {
            min_iters: 2,
            min_time: Duration::from_millis(50),
            warmup: Duration::from_millis(10),
            ..Default::default()
        }
    }

    /// Time `f`, preventing the result from being optimized away via the
    /// returned value sink.
    pub fn run<T>(&mut self, name: &str, items: Option<u64>, mut f: impl FnMut() -> T) {
        // warmup
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // measure
        let mut iters = 0u64;
        let mut best = Duration::MAX;
        let t0 = Instant::now();
        while iters < self.min_iters || t0.elapsed() < self.min_time {
            let it0 = Instant::now();
            std::hint::black_box(f());
            let dt = it0.elapsed();
            best = best.min(dt);
            iters += 1;
            if iters > 1_000_000 {
                break;
            }
        }
        let mean = t0.elapsed() / iters.max(1) as u32;
        let m = Measurement {
            name: name.to_string(),
            iters,
            mean,
            best,
            items,
        };
        println!("{}", m.report());
        self.results.push(m);
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    pub fn header() {
        println!(
            "{:<44} {:>12} {:>12}  iters  throughput",
            "benchmark", "mean", "best"
        );
        println!("{}", "-".repeat(96));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_and_reports() {
        let mut b = Bench {
            min_iters: 3,
            min_time: Duration::from_millis(1),
            warmup: Duration::from_millis(1),
            results: vec![],
        };
        b.run("spin", Some(1000), || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert_eq!(b.results().len(), 1);
        assert!(b.results()[0].iters >= 3);
        assert!(b.results()[0].report().contains("spin"));
    }

    #[test]
    fn format_helpers() {
        assert!(fmt_duration(Duration::from_secs(2)).contains('s'));
        assert!(fmt_duration(Duration::from_micros(5)).contains("µs"));
        assert_eq!(fmt_count(2_500_000.0), "2.50M");
    }
}
