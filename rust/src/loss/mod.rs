//! Triplet loss functions: smoothed hinge (γ > 0) and hinge (γ = 0).
//!
//! Paper §2.1. Both losses share a "zero part" (no penalty, m > 1) and a
//! "linear part" (slope −1, m < 1−γ); the smoothed hinge interpolates
//! quadratically in between. The dual-feasible coefficient is
//! `α = −ℓ'(m) ∈ [0, 1]` (eq. (3)); at the hinge kink any `α ∈ [0,1]` is a
//! valid subgradient and we pick 1 (consistent with treating `m = 1` as
//! the boundary of L*).
//!
//! Convex conjugate (Appendix A): `ℓ*(−α) = (γ/2)α² − α` for α ∈ [0, 1] —
//! a single formula valid for both losses (γ = 0 for hinge).

/// A triplet loss with the structure the screening machinery requires.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Loss {
    /// smoothing width γ ≥ 0; 0 = hinge
    pub gamma: f64,
}

impl Loss {
    /// Smoothed hinge with quadratic width `gamma > 0` (paper §2.1).
    pub fn smoothed_hinge(gamma: f64) -> Loss {
        assert!(gamma > 0.0, "smoothed hinge needs gamma > 0");
        Loss { gamma }
    }

    /// The plain hinge (`gamma = 0`).
    pub fn hinge() -> Loss {
        Loss { gamma: 0.0 }
    }

    /// Whether this is the non-smooth hinge.
    pub fn is_hinge(&self) -> bool {
        self.gamma == 0.0
    }

    /// ℓ(m).
    #[inline]
    pub fn value(&self, m: f64) -> f64 {
        let g = self.gamma;
        if m > 1.0 {
            0.0
        } else if g > 0.0 && m >= 1.0 - g {
            let z = 1.0 - m;
            z * z / (2.0 * g)
        } else {
            1.0 - m - g / 2.0
        }
    }

    /// `α(m) = −ℓ'(m) ∈ [0, 1]`; at the hinge kink returns 1 (a valid
    /// subgradient choice — see module docs).
    #[inline]
    pub fn alpha(&self, m: f64) -> f64 {
        let g = self.gamma;
        if m > 1.0 {
            0.0
        } else if g > 0.0 {
            ((1.0 - m) / g).clamp(0.0, 1.0)
        } else {
            1.0
        }
    }

    /// Convex conjugate ℓ*(−α) for α ∈ [0, 1].
    #[inline]
    pub fn conjugate(&self, alpha: f64) -> f64 {
        debug_assert!((-1e-12..=1.0 + 1e-12).contains(&alpha));
        self.gamma / 2.0 * alpha * alpha - alpha
    }

    /// Lower screening threshold: m < `l_threshold()` ⟹ triplet in L*.
    /// (The paper's 1 − γ.)
    #[inline]
    pub fn l_threshold(&self) -> f64 {
        1.0 - self.gamma
    }

    /// Upper screening threshold: m > `r_threshold()` ⟹ triplet in R*.
    #[inline]
    pub fn r_threshold(&self) -> f64 {
        1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{close, forall};

    #[test]
    fn smoothed_hinge_branch_values() {
        let l = Loss::smoothed_hinge(0.05);
        assert_eq!(l.value(2.0), 0.0);
        assert_eq!(l.value(1.0), 0.0);
        close(l.value(0.975), 0.025 * 0.025 / 0.1, 1e-12, 0.0, "mid").unwrap();
        close(l.value(0.95), 0.025, 1e-12, 0.0, "knee").unwrap();
        close(l.value(0.0), 0.975, 1e-12, 0.0, "linear").unwrap();
    }

    #[test]
    fn hinge_branch_values() {
        let l = Loss::hinge();
        assert_eq!(l.value(1.5), 0.0);
        assert_eq!(l.value(1.0), 0.0);
        assert_eq!(l.value(0.0), 1.0);
        assert_eq!(l.value(-2.0), 3.0);
    }

    #[test]
    fn alpha_branches() {
        let l = Loss::smoothed_hinge(0.05);
        assert_eq!(l.alpha(1.1), 0.0);
        close(l.alpha(0.975), 0.5, 1e-12, 0.0, "mid").unwrap();
        assert_eq!(l.alpha(0.9), 1.0);
        let h = Loss::hinge();
        assert_eq!(h.alpha(1.0 + 1e-12), 0.0);
        assert_eq!(h.alpha(1.0), 1.0);
        assert_eq!(h.alpha(-5.0), 1.0);
    }

    #[test]
    fn loss_is_convex_nonincreasing() {
        for gamma in [0.0, 0.01, 0.05, 0.5, 1.0] {
            let l = if gamma > 0.0 {
                Loss::smoothed_hinge(gamma)
            } else {
                Loss::hinge()
            };
            let xs: Vec<f64> = (0..400).map(|i| -2.0 + i as f64 * 0.01).collect();
            let vs: Vec<f64> = xs.iter().map(|&x| l.value(x)).collect();
            for w in vs.windows(2) {
                assert!(w[1] <= w[0] + 1e-12);
            }
            for w in vs.windows(3) {
                assert!(w[0] - 2.0 * w[1] + w[2] >= -1e-9, "gamma={gamma}");
            }
        }
    }

    #[test]
    fn fenchel_young_equality_at_derivative() {
        // ℓ(m) + ℓ*(−α(m)) = −α(m)·m for the maximizing α (eq. (3))
        forall("fenchel-young", 64, |rng| {
            let gamma = rng.range(1e-3, 1.0);
            let l = Loss::smoothed_hinge(gamma);
            let m = rng.range(-3.0, 3.0);
            let a = l.alpha(m);
            close(l.value(m) + l.conjugate(a), -a * m, 1e-9, 1e-9, "FY")
        });
    }

    #[test]
    fn fenchel_young_inequality_everywhere() {
        // ℓ(m) + ℓ*(−α) ≥ −α·m for all α ∈ [0,1]
        forall("fenchel-young-ineq", 64, |rng| {
            let gamma = rng.range(0.0, 1.0);
            let l = Loss { gamma };
            let m = rng.range(-3.0, 3.0);
            let a = rng.uniform();
            if l.value(m) + l.conjugate(a) >= -a * m - 1e-10 {
                Ok(())
            } else {
                Err(format!("violated at gamma={gamma} m={m} a={a}"))
            }
        });
    }

    #[test]
    fn smoothed_hinge_converges_to_hinge() {
        let h = Loss::hinge();
        let s = Loss::smoothed_hinge(1e-9);
        for m in [-2.0, 0.0, 0.5, 0.9999, 1.0001, 2.0] {
            assert!((h.value(m) - s.value(m)).abs() < 1e-8);
        }
    }

    #[test]
    fn thresholds() {
        let l = Loss::smoothed_hinge(0.05);
        assert_eq!(l.l_threshold(), 0.95);
        assert_eq!(l.r_threshold(), 1.0);
        assert_eq!(Loss::hinge().l_threshold(), 1.0);
    }
}
