//! Analytical TPU performance model for the L1 Pallas kernels.
//!
//! Interpret-mode Pallas gives CPU-numpy wallclock, which is *not* a TPU
//! proxy (DESIGN.md §Hardware-Adaptation). This module estimates what the
//! kernels would do on real hardware from their BlockSpec structure:
//! VMEM footprint, HBM traffic, MXU FLOPs, arithmetic intensity, and the
//! roofline-limited utilization — the §Perf L1 deliverable.
//!
//! Model (TPUv4-like defaults, configurable): one core with a 128×128 MXU
//! at `flops_peak`, `hbm_bw` bytes/s, `vmem_bytes` of scratchpad. A grid
//! step of `triplet_margins` moves two `[block, d]` tiles from HBM and
//! performs one `[block,d]×[d,d]` matmul per tile plus O(block·d)
//! elementwise work; `weighted_gram` moves the same tiles and performs two
//! `[d,block]×[block,d]` matmuls into a VMEM-resident accumulator.

/// Hardware profile for the estimate.
#[derive(Clone, Copy, Debug)]
pub struct TpuProfile {
    pub name: &'static str,
    /// peak matmul throughput, FLOP/s (f32 on MXU)
    pub flops_peak: f64,
    /// HBM bandwidth, bytes/s
    pub hbm_bw: f64,
    /// VMEM capacity, bytes
    pub vmem_bytes: f64,
    /// element width in bytes (f32 = 4; we ship f64 on CPU for exact gaps,
    /// a real TPU build would use f32/bf16)
    pub elem_bytes: f64,
}

impl TpuProfile {
    pub fn v4_like() -> TpuProfile {
        TpuProfile {
            name: "tpu-v4-like",
            flops_peak: 137.5e12,  // bf16/f32 MXU, per chip half for f32
            hbm_bw: 1.2e12,
            vmem_bytes: 16.0 * 1024.0 * 1024.0,
            elem_bytes: 4.0,
        }
    }
}

/// Estimate for one kernel configuration.
#[derive(Clone, Debug)]
pub struct KernelEstimate {
    pub kernel: &'static str,
    pub d: usize,
    pub block: usize,
    /// VMEM bytes live per grid step
    pub vmem_used: f64,
    /// fraction of VMEM capacity
    pub vmem_frac: f64,
    /// FLOPs per triplet row
    pub flops_per_row: f64,
    /// HBM bytes per triplet row
    pub bytes_per_row: f64,
    /// arithmetic intensity, FLOP/byte
    pub intensity: f64,
    /// roofline-limited fraction of MXU peak
    pub mxu_utilization: f64,
    /// estimated triplets/second at the roofline
    pub rows_per_sec: f64,
}

/// Margins kernel: per row `2·(2d² )` matmul FLOPs (a and b tiles) +
/// `4d` elementwise; per row HBM traffic `2d` elements in, 1 out
/// (M is grid-invariant and VMEM-resident).
pub fn margins_estimate(d: usize, block: usize, p: &TpuProfile) -> KernelEstimate {
    let df = d as f64;
    let bf = block as f64;
    let flops_per_row = 2.0 * (2.0 * df * df) + 4.0 * df;
    let bytes_per_row = (2.0 * df + 1.0) * p.elem_bytes;
    // VMEM: A,B tiles (+double buffer), M, margins out
    let vmem = (2.0 * bf * df * 2.0 + df * df + bf) * p.elem_bytes;
    finish("margins", d, block, vmem, flops_per_row, bytes_per_row, p)
}

/// Weighted-gram kernel: per row `2·(2d²)` FLOPs for the two rank-block
/// updates + `2d` scaling; traffic `2d + 1` in (accumulator stays in VMEM).
pub fn wgram_estimate(d: usize, block: usize, p: &TpuProfile) -> KernelEstimate {
    let df = d as f64;
    let bf = block as f64;
    let flops_per_row = 2.0 * (2.0 * df * df) + 2.0 * df;
    let bytes_per_row = (2.0 * df + 1.0) * p.elem_bytes;
    let vmem = (2.0 * bf * df * 2.0 + df * df + bf) * p.elem_bytes;
    finish("wgram", d, block, vmem, flops_per_row, bytes_per_row, p)
}

/// Fused step = margins + loss/α (elementwise) + wgram sharing the same
/// tile loads: per row `~8d²` FLOPs but the *same* `2d+1` HBM traffic —
/// the fusion's arithmetic-intensity win.
pub fn step_estimate(d: usize, block: usize, p: &TpuProfile) -> KernelEstimate {
    let df = d as f64;
    let bf = block as f64;
    let flops_per_row = 8.0 * df * df + 12.0 * df;
    let bytes_per_row = (2.0 * df + 1.0) * p.elem_bytes;
    let vmem = (2.0 * bf * df * 2.0 + 2.0 * df * df + 2.0 * bf) * p.elem_bytes;
    finish("step", d, block, vmem, flops_per_row, bytes_per_row, p)
}

fn finish(
    kernel: &'static str,
    d: usize,
    block: usize,
    vmem_used: f64,
    flops_per_row: f64,
    bytes_per_row: f64,
    p: &TpuProfile,
) -> KernelEstimate {
    let intensity = flops_per_row / bytes_per_row;
    let ridge = p.flops_peak / p.hbm_bw;
    // roofline: compute-bound iff intensity > ridge; MXU efficiency also
    // capped by how well [block,d]×[d,d] fills the 128×128 systolic array
    let fill = ((d as f64 / 128.0).min(1.0)) * ((block as f64 / 128.0).min(1.0));
    let roofline_frac = (intensity / ridge).min(1.0);
    let mxu_utilization = roofline_frac * fill;
    let rows_per_sec = if intensity >= ridge {
        p.flops_peak * fill / flops_per_row
    } else {
        p.hbm_bw / bytes_per_row
    };
    KernelEstimate {
        kernel,
        d,
        block,
        vmem_used,
        vmem_frac: vmem_used / p.vmem_bytes,
        flops_per_row,
        bytes_per_row,
        intensity,
        mxu_utilization,
        rows_per_sec,
    }
}

/// Render the estimate table for a set of dimensions (used by the bench
/// harness and EXPERIMENTS.md §Perf).
pub fn estimate_table(dims: &[usize], block: usize, p: &TpuProfile) -> super::report::Table {
    use super::report::{fnum, fpct, Table};
    let mut t = Table::new(
        format!("L1 TPU estimates ({}, block {block})", p.name),
        &[
            "kernel", "d", "VMEM", "VMEM%", "FLOP/row", "B/row", "AI", "MXU util",
            "rows/s",
        ],
    );
    for &d in dims {
        for est in [
            margins_estimate(d, block, p),
            wgram_estimate(d, block, p),
            step_estimate(d, block, p),
        ] {
            t.row(vec![
                est.kernel.to_string(),
                d.to_string(),
                format!("{:.2}MB", est.vmem_used / 1e6),
                fpct(est.vmem_frac),
                fnum(est.flops_per_row),
                fnum(est.bytes_per_row),
                format!("{:.1}", est.intensity),
                fpct(est.mxu_utilization),
                fnum(est.rows_per_sec),
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vmem_fits_for_paper_dimensions() {
        let p = TpuProfile::v4_like();
        for d in [19usize, 68, 100, 200] {
            let e = step_estimate(d, 512, &p);
            assert!(
                e.vmem_frac < 0.5,
                "d={d}: VMEM {:.1}% leaves no double-buffer headroom",
                100.0 * e.vmem_frac
            );
        }
    }

    #[test]
    fn fusion_increases_intensity() {
        let p = TpuProfile::v4_like();
        let d = 64;
        let m = margins_estimate(d, 512, &p);
        let s = step_estimate(d, 512, &p);
        assert!(s.intensity > 1.5 * m.intensity, "fusion should roughly double AI");
    }

    #[test]
    fn memory_bound_at_small_d_compute_bound_at_large() {
        let p = TpuProfile::v4_like();
        let ridge = p.flops_peak / p.hbm_bw; // ~115 FLOP/B
        let small = margins_estimate(8, 512, &p);
        assert!(small.intensity < ridge);
        let large = margins_estimate(512, 512, &p);
        assert!(large.intensity > ridge);
    }

    #[test]
    fn throughput_monotone_in_block_fill() {
        let p = TpuProfile::v4_like();
        let e64 = margins_estimate(200, 64, &p);
        let e512 = margins_estimate(200, 512, &p);
        assert!(e512.mxu_utilization >= e64.mxu_utilization);
    }

    #[test]
    fn table_renders() {
        let p = TpuProfile::v4_like();
        let t = estimate_table(&[19, 200], 512, &p);
        assert_eq!(t.rows.len(), 6);
        assert!(t.to_markdown().contains("MXU util"));
    }
}
