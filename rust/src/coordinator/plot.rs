//! Terminal plots for the experiment reports: line charts (screening rate
//! / time-ratio over the λ path, the paper's figure panels) and heatmaps
//! (Fig 6's range-screening matrix) rendered as unicode text that survives
//! markdown code fences.

use std::fmt::Write as _;

const SHADES: &[char] = &[' ', '░', '▒', '▓', '█'];

/// Render series as an ASCII line chart. `x` is shared; each series is
/// (label, ys). Y is auto-scaled; X is displayed left→right in index
/// order (the λ path prints λ decreasing, as the paper's figures do).
pub fn line_chart(
    title: &str,
    x_label: &str,
    series: &[(&str, &[f64])],
    height: usize,
    width: usize,
) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "{title}");
    let n: usize = series.iter().map(|(_, ys)| ys.len()).max().unwrap_or(0);
    if n == 0 {
        return out;
    }
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for (_, ys) in series {
        for &y in ys.iter() {
            if y.is_finite() {
                lo = lo.min(y);
                hi = hi.max(y);
            }
        }
    }
    if !lo.is_finite() || hi <= lo {
        hi = lo + 1.0;
    }
    let marks = ['*', 'o', '+', 'x', '#', '@', '%', '&'];
    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, ys)) in series.iter().enumerate() {
        let mark = marks[si % marks.len()];
        for (i, &y) in ys.iter().enumerate() {
            if !y.is_finite() {
                continue;
            }
            let col = i * (width - 1) / n.max(1).max(1);
            let row = ((y - lo) / (hi - lo) * (height - 1) as f64).round() as usize;
            let row = height - 1 - row.min(height - 1);
            grid[row][col.min(width - 1)] = mark;
        }
    }
    for (r, row) in grid.iter().enumerate() {
        let y_val = hi - (hi - lo) * r as f64 / (height - 1) as f64;
        let _ = writeln!(out, "{y_val:>9.3} |{}", row.iter().collect::<String>());
    }
    let _ = writeln!(out, "{:>9} +{}", "", "-".repeat(width));
    let _ = writeln!(out, "{:>10} {x_label} →", "");
    for (si, (label, _)) in series.iter().enumerate() {
        let _ = writeln!(out, "{:>10} {} = {label}", "", marks[si % marks.len()]);
    }
    out
}

/// Render a matrix of values in [0, 1] as a shaded heatmap (Fig 6 style).
pub fn heatmap(title: &str, rows: &[(&str, Vec<f64>)]) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "{title}");
    for (label, vals) in rows {
        let cells: String = vals
            .iter()
            .map(|&v| {
                let v = v.clamp(0.0, 1.0);
                SHADES[((v * (SHADES.len() - 1) as f64).round() as usize).min(SHADES.len() - 1)]
            })
            .collect();
        let _ = writeln!(out, "{label:>12} |{cells}|");
    }
    let _ = writeln!(out, "{:>12}  shades: 0% {} 100%", "", SHADES.iter().collect::<String>());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_chart_contains_series_marks() {
        let ys1: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let ys2: Vec<f64> = (0..20).map(|i| (20 - i) as f64).collect();
        let s = line_chart("T", "x", &[("up", &ys1), ("down", &ys2)], 8, 40);
        assert!(s.contains('*') && s.contains('o'));
        assert!(s.contains("= up") && s.contains("= down"));
    }

    #[test]
    fn heatmap_shades_extremes() {
        let s = heatmap("H", &[("r", vec![0.0, 0.5, 1.0])]);
        assert!(s.contains('█'));
        assert!(s.contains('▒') || s.contains('▓') || s.contains('░'));
    }

    #[test]
    fn empty_series_safe() {
        let s = line_chart("T", "x", &[("e", &[])], 4, 10);
        assert!(s.contains('T'));
    }

    #[test]
    fn non_finite_values_skipped() {
        let ys = vec![1.0, f64::NAN, 2.0, f64::INFINITY];
        let s = line_chart("T", "x", &[("v", &ys)], 5, 20);
        assert!(s.contains('*'));
    }
}
