//! Report formatting: markdown tables, CSV, JSON dumps for experiments.

use crate::util::json::Json;
use std::fmt::Write as _;

/// A simple column-oriented table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Table {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
    }

    /// Render as a GitHub-flavored markdown table.
    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "### {}", self.title);
            out.push('\n');
        }
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                let _ = write!(s, " {c:<w$} |");
            }
            s
        };
        let _ = writeln!(out, "{}", fmt_row(&self.headers, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            let _ = write!(sep, "{}|", "-".repeat(w + 2));
        }
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row, &widths));
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let esc = |s: &str| -> String {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{}",
            self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("title", Json::Str(self.title.clone())),
            (
                "headers",
                Json::Arr(self.headers.iter().map(|h| Json::Str(h.clone())).collect()),
            ),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| Json::Arr(r.iter().map(|c| Json::Str(c.clone())).collect()))
                        .collect(),
                ),
            ),
        ])
    }
}

/// Format a float compactly for tables.
pub fn fnum(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else if x.abs() >= 1e5 || x.abs() < 1e-3 {
        format!("{x:.2e}")
    } else if x.abs() >= 100.0 {
        format!("{x:.1}")
    } else {
        format!("{x:.3}")
    }
}

/// Percentage with one decimal.
pub fn fpct(x: f64) -> String {
    format!("{:.1}%", 100.0 * x)
}

/// Write a report file under `reports/`, creating the directory.
pub fn write_report(name: &str, content: &str) -> std::io::Result<std::path::PathBuf> {
    let dir = std::path::Path::new("reports");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(name);
    std::fs::write(&path, content)?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_alignment() {
        let mut t = Table::new("T", &["a", "long-header"]);
        t.row(vec!["1".into(), "x".into()]);
        t.row(vec!["22".into(), "yy".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### T"));
        assert!(md.contains("| a  | long-header |"));
        assert!(md.lines().count() >= 5);
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x,y".into(), "q\"uote".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"uote\""));
    }

    #[test]
    fn num_formats() {
        assert_eq!(fnum(0.0), "0");
        assert_eq!(fnum(1234567.0), "1.23e6");
        assert_eq!(fnum(12.3456), "12.346");
        assert_eq!(fpct(0.5), "50.0%");
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn row_width_checked() {
        let mut t = Table::new("", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }
}
