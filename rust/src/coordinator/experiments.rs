//! Runners for every table and figure of the paper's evaluation (§5).
//!
//! Each `run_*` function regenerates the corresponding artifact's *shape*
//! on the synthetic dataset analogues (see DESIGN.md §3 for the
//! substitution rationale): the rows/series the paper reports, printed as
//! markdown and persisted under `reports/`. Absolute seconds differ from
//! the paper's testbed; orderings, collapse points and speedup factors are
//! the reproduced quantities, recorded in EXPERIMENTS.md.

use super::report::{fnum, fpct, write_report, Table};
use crate::data::synthetic;
use crate::loss::Loss;
use crate::path::{PathConfig, PathResult, RegPath};
use crate::runtime::Engine;
use crate::screening::{BoundKind, RuleKind, ScreeningConfig};
use crate::solver::{Problem, SolverConfig};
use crate::triplet::TripletStore;
use crate::util::rng::Pcg64;

/// Shared experiment options (dataset scale, seed, engine choice).
#[derive(Clone, Debug)]
pub struct ExpOptions {
    /// scale factor on the analogue's n (1.0 = DESIGN.md defaults)
    pub scale: f64,
    pub seed: u64,
    /// number of random subsample trials to average (paper: 5)
    pub trials: usize,
    pub tol: f64,
    pub verbose: bool,
    /// maximum λ steps per path (0 = paper-length default)
    pub max_steps: usize,
}

impl Default for ExpOptions {
    fn default() -> Self {
        ExpOptions {
            scale: 1.0,
            seed: 7,
            trials: 1,
            tol: 1e-6,
            verbose: false,
            max_steps: 0,
        }
    }
}

/// Build the analogue dataset + triplet store for an experiment.
pub fn build_store(name: &str, opts: &ExpOptions, rng: &mut Pcg64) -> TripletStore {
    let spec = synthetic::spec(name).unwrap_or_else(|| panic!("unknown dataset {name}"));
    let mut ds = synthetic::analogue(name, rng);
    if opts.scale < 1.0 {
        let keep = ((ds.n() as f64 * opts.scale) as usize).max(spec.n_classes * 8);
        ds = ds.subsample(keep as f64 / ds.n() as f64, rng);
    }
    // paper protocol: random 90% subsample per trial
    let ds = ds.subsample(0.9, rng);
    TripletStore::from_dataset(&ds, spec.k, rng)
}

fn base_path_cfg(opts: &ExpOptions, rho: f64) -> PathConfig {
    PathConfig {
        loss: Loss::smoothed_hinge(0.05),
        rho,
        // long enough for the paper's λ_max→λ_min span (the loss-based
        // stop criterion usually fires earlier); overridable for CI budgets
        max_steps: if opts.max_steps > 0 {
            opts.max_steps
        } else if rho >= 0.99 {
            600
        } else {
            140
        },
        stop_ratio: 0.01,
        lambda_min: None,
        solver: SolverConfig {
            tol: opts.tol,
            tol_relative: true,
            max_iters: 4000,
            screen_every: 10,
            gap_every: 1,
        },
        screening: None,
        secondary_screening: None,
        active_set: false,
        range_screening: false,
        range_general: false,
        frame_every: 1,
    }
}

fn run_variant(
    store: &TripletStore,
    engine: &dyn Engine,
    cfg: &PathConfig,
    label: &str,
    verbose: bool,
) -> PathResult {
    if verbose {
        eprintln!("  running {label} …");
    }
    RegPath::new(cfg.clone()).run(store, engine)
}

/// Paper Table 1 / Table 3: dataset summary with λ_max and #triplets.
pub fn run_table1(engine: &dyn Engine, opts: &ExpOptions) -> Table {
    let mut table = Table::new(
        "Table 1/3 — dataset analogues",
        &["dataset", "d", "n", "classes", "k", "#triplet", "lambda_max"],
    );
    for spec in synthetic::ANALOGUES.iter().filter(|s| s.d <= 200) {
        let mut rng = Pcg64::seed(opts.seed);
        let store = build_store(spec.name, opts, &mut rng);
        let loss = Loss::smoothed_hinge(0.05);
        let lmax = Problem::lambda_max(&store, &loss, engine);
        table.row(vec![
            spec.name.to_string(),
            spec.d.to_string(),
            spec.n.to_string(),
            spec.n_classes.to_string(),
            if spec.k == usize::MAX {
                "inf".into()
            } else {
                spec.k.to_string()
            },
            store.len().to_string(),
            fnum(lmax),
        ]);
    }
    table
}

/// Figure 4 (and Figure 8 with `bound = Dgb`): screening-rule comparison —
/// regularization-path screening rate and CPU-time ratio per λ, for the
/// rule variants of one gradient bound on the segment analogue.
pub fn run_fig4(
    engine: &dyn Engine,
    opts: &ExpOptions,
    dataset: &str,
    gb_based: bool,
) -> (Table, Table) {
    let variants: Vec<(String, ScreeningConfig)> = if gb_based {
        vec![
            ("GB".into(), ScreeningConfig::new(BoundKind::Gb, RuleKind::Sphere)),
            ("PGB".into(), ScreeningConfig::new(BoundKind::Pgb, RuleKind::Sphere)),
            ("GB+Linear".into(), ScreeningConfig::new(BoundKind::Gb, RuleKind::Linear)),
            (
                "GB+Semidefinite".into(),
                ScreeningConfig::new(BoundKind::Gb, RuleKind::SemiDefinite),
            ),
            (
                "PGB+Semidefinite".into(),
                ScreeningConfig::new(BoundKind::Pgb, RuleKind::SemiDefinite),
            ),
        ]
    } else {
        vec![
            ("DGB".into(), ScreeningConfig::new(BoundKind::Dgb, RuleKind::Sphere)),
            ("DGB+Linear".into(), ScreeningConfig::new(BoundKind::Dgb, RuleKind::Linear)),
            (
                "DGB+Semidefinite".into(),
                ScreeningConfig::new(BoundKind::Dgb, RuleKind::SemiDefinite),
            ),
        ]
    };
    rule_comparison(engine, opts, dataset, &variants)
}

fn rule_comparison(
    engine: &dyn Engine,
    opts: &ExpOptions,
    dataset: &str,
    variants: &[(String, ScreeningConfig)],
) -> (Table, Table) {
    let mut rng = Pcg64::seed(opts.seed);
    let store = build_store(dataset, opts, &mut rng);
    let cfg0 = base_path_cfg(opts, 0.9);
    let naive = run_variant(&store, engine, &cfg0, "naive", opts.verbose);

    let mut rate = Table::new(
        format!("screening rate (reg-path) on {dataset}"),
        &[&["lambda"], variants.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>().as_slice()]
            .concat(),
    );
    let mut time = Table::new(
        format!("CPU-time ratio vs naive on {dataset}"),
        &[&["lambda"], variants.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>().as_slice()]
            .concat(),
    );

    let mut results = Vec::new();
    for (label, sc) in variants {
        let mut cfg = cfg0.clone();
        cfg.screening = Some(*sc);
        results.push(run_variant(&store, engine, &cfg, label, opts.verbose));
    }
    for (i, step) in naive.steps.iter().enumerate() {
        let mut rrow = vec![fnum(step.lambda)];
        let mut trow = vec![fnum(step.lambda)];
        for res in &results {
            if let Some(s) = res.steps.get(i) {
                rrow.push(fpct(s.rate_regpath));
                trow.push(fnum(s.wall / step.wall.max(1e-12)));
            } else {
                rrow.push("-".into());
                trow.push("-".into());
            }
        }
        rate.row(rrow);
        time.row(trow);
    }
    (rate, time)
}

/// Figure 5: bound comparison (GB/PGB/DGB/CDGB/RRPB, sphere rule) —
/// reg-path rate, final dynamic rate and CPU ratio per λ.
pub fn run_fig5(engine: &dyn Engine, opts: &ExpOptions, dataset: &str) -> (Table, Table, Table) {
    let bounds = [
        BoundKind::Gb,
        BoundKind::Pgb,
        BoundKind::Dgb,
        BoundKind::Cdgb,
        BoundKind::Rrpb,
    ];
    let mut rng = Pcg64::seed(opts.seed);
    let store = build_store(dataset, opts, &mut rng);
    let cfg0 = base_path_cfg(opts, 0.9);
    let naive = run_variant(&store, engine, &cfg0, "naive", opts.verbose);

    let names: Vec<&str> = bounds.iter().map(|b| b.name()).collect();
    let headers: Vec<&str> = [&["lambda"], names.as_slice()].concat();
    let mut rate = Table::new(format!("reg-path screening rate on {dataset}"), &headers);
    let mut dyn_rate = Table::new(format!("final dynamic screening rate on {dataset}"), &headers);
    let mut time = Table::new(format!("CPU-time ratio vs naive on {dataset}"), &headers);

    let mut results = Vec::new();
    for b in bounds {
        let mut cfg = cfg0.clone();
        cfg.screening = Some(ScreeningConfig::new(b, RuleKind::Sphere));
        results.push(run_variant(&store, engine, &cfg, b.name(), opts.verbose));
    }
    for (i, step) in naive.steps.iter().enumerate() {
        let mut r1 = vec![fnum(step.lambda)];
        let mut r2 = vec![fnum(step.lambda)];
        let mut r3 = vec![fnum(step.lambda)];
        for res in &results {
            match res.steps.get(i) {
                Some(s) => {
                    r1.push(fpct(s.rate_regpath));
                    r2.push(fpct(s.rate_final));
                    r3.push(fnum(s.wall / step.wall.max(1e-12)));
                }
                None => {
                    r1.push("-".into());
                    r2.push("-".into());
                    r3.push("-".into());
                }
            }
        }
        rate.row(r1);
        dyn_rate.row(r2);
        time.row(r3);
    }
    (rate, dyn_rate, time)
}

/// Figure 6: range-based screening-rate heatmap. Rows: reference λ₀ along
/// the path; columns: target λ; cell: fraction of triplets screened purely
/// by the range extension. `eps_accuracy` mirrors the paper's 1e-4 / 1e-6.
pub fn run_fig6(engine: &dyn Engine, opts: &ExpOptions, dataset: &str, eps_accuracy: f64) -> Table {
    use crate::screening::{CertFamilies, ReferenceFrame};
    use crate::triplet::ActiveWorkset;
    let mut rng = Pcg64::seed(opts.seed);
    let store = build_store(dataset, opts, &mut rng);
    let loss = Loss::smoothed_hinge(0.05);
    let mut cfg = base_path_cfg(opts, 0.9);
    cfg.solver.tol = eps_accuracy;
    cfg.solver.tol_relative = false;
    cfg.screening = Some(ScreeningConfig::new(BoundKind::Rrpb, RuleKind::Sphere));

    // run the path to fix the λ grid
    let res = RegPath::new(cfg.clone()).run(&store, engine);
    let lambdas: Vec<f64> = res.steps.iter().map(|s| s.lambda).collect();

    // re-solve at each λ0 and build its certificate frame (margins pass
    // + closed-form λ-intervals happen inside `ReferenceFrame::build`);
    // each row of the heatmap is then one schedule sweep over the λ grid
    // instead of a per-cell full-store interval scan
    let mut refs: Vec<(f64, ReferenceFrame)> = Vec::new();
    {
        let mut warm = crate::linalg::Mat::zeros(store.d, store.d);
        for &l0 in &lambdas {
            let mut prob = Problem::new(&store, loss, l0);
            let solver = crate::solver::Solver::new(cfg.solver.clone());
            let (m, st) = solver.solve(&mut prob, engine, warm.clone(), None);
            let eps = (2.0 * st.gap.max(0.0) / l0).sqrt();
            let frame = ReferenceFrame::build(
                m.clone(),
                l0,
                eps,
                &store,
                engine,
                Some((&loss, CertFamilies::rrpb_only())),
            );
            refs.push((l0, frame));
            warm = m;
        }
    }

    let mut table = Table::new(
        format!(
            "Fig 6 — range-based screening rate on {dataset} (ref accuracy {eps_accuracy:.0e})"
        ),
        &[&["lambda0 \\ lambda"], lambdas
            .iter()
            .map(|l| fnum(*l))
            .collect::<Vec<_>>()
            .iter()
            .map(|s| s.as_str())
            .collect::<Vec<_>>()
            .as_slice()]
        .concat(),
    );
    let ws = ActiveWorkset::full(&store);
    let (mut rl, mut rr) = (Vec::new(), Vec::new());
    for (l0, frame) in &refs {
        let mut row = vec![fnum(*l0)];
        for &l in &lambdas {
            frame.advance(l, &ws, &mut rl, &mut rr);
            row.push(fpct((rl.len() + rr.len()) as f64 / store.len() as f64));
        }
        table.row(row);
    }
    table
}

/// Figure 7: hinge-loss PGB performance (screening rate + time ratio).
pub fn run_fig7(engine: &dyn Engine, opts: &ExpOptions, dataset: &str) -> Table {
    let mut rng = Pcg64::seed(opts.seed);
    let store = build_store(dataset, opts, &mut rng);
    let mut cfg = base_path_cfg(opts, 0.9);
    cfg.loss = Loss::hinge();
    let naive = run_variant(&store, engine, &cfg, "naive(hinge)", opts.verbose);
    let mut cfg_s = cfg.clone();
    cfg_s.screening = Some(ScreeningConfig::new(BoundKind::Pgb, RuleKind::Sphere));
    let pgb = run_variant(&store, engine, &cfg_s, "PGB(hinge)", opts.verbose);

    let mut table = Table::new(
        format!("Fig 7 — hinge-loss PGB on {dataset}"),
        &["lambda", "rate_regpath", "rate_final", "time_ratio"],
    );
    for (i, step) in naive.steps.iter().enumerate() {
        if let Some(s) = pgb.steps.get(i) {
            table.row(vec![
                fnum(step.lambda),
                fpct(s.rate_regpath),
                fpct(s.rate_final),
                fnum(s.wall / step.wall.max(1e-12)),
            ]);
        }
    }
    table
}

/// Table 2 (and Table 4's structure): total path CPU time for the
/// active-set method variants, averaged over trials. The "+RRPB+PGB"
/// variant evaluates the rules of *both* spheres per screening call (the
/// paper's protocol).
pub fn run_table2(
    engine: &dyn Engine,
    opts: &ExpOptions,
    datasets: &[&str],
    rho: f64,
) -> Table {
    let labels = ["ActiveSet", "ActiveSet+RRPB", "ActiveSet+RRPB+PGB"];
    let mut table = Table::new(
        format!("Table 2 — total path time (s), rho = {rho}"),
        &[&["method"], datasets].concat(),
    );
    let mut rows: Vec<Vec<String>> = labels.iter().map(|n| vec![n.to_string()]).collect();
    for ds in datasets {
        let mut totals = vec![0.0; labels.len()];
        for trial in 0..opts.trials {
            let mut rng = Pcg64::seed(opts.seed + trial as u64);
            let store = build_store(ds, opts, &mut rng);
            for (vi, label) in labels.iter().enumerate() {
                let mut cfg = base_path_cfg(opts, rho);
                cfg.active_set = true;
                match vi {
                    0 => {}
                    1 => {
                        cfg.screening =
                            Some(ScreeningConfig::new(BoundKind::Rrpb, RuleKind::Sphere));
                        cfg.range_screening = true;
                    }
                    _ => {
                        cfg.screening = Some(ScreeningConfig::new(
                            BoundKind::Rrpb,
                            RuleKind::Sphere,
                        ));
                        cfg.secondary_screening =
                            Some(ScreeningConfig::new(BoundKind::Pgb, RuleKind::Sphere));
                        cfg.range_screening = true;
                    }
                }
                let res = run_variant(&store, engine, &cfg, &format!("{ds}/{label}"), opts.verbose);
                totals[vi] += res.total_wall;
            }
        }
        for (vi, t) in totals.iter().enumerate() {
            rows[vi].push(fnum(t / opts.trials as f64));
        }
    }
    for r in rows {
        table.row(r);
    }
    table
}

/// Table 4: total path time per bound (sphere rule), with screening-eval
/// seconds in parentheses.
pub fn run_table4(engine: &dyn Engine, opts: &ExpOptions, datasets: &[&str]) -> Table {
    let bounds: [Option<BoundKind>; 6] = [
        None,
        Some(BoundKind::Gb),
        Some(BoundKind::Pgb),
        Some(BoundKind::Dgb),
        Some(BoundKind::Cdgb),
        Some(BoundKind::Rrpb),
    ];
    let mut table = Table::new(
        "Table 4 — total path time seconds (screening-eval seconds)",
        &[&["bound"], datasets].concat(),
    );
    let mut rows: Vec<Vec<String>> = bounds
        .iter()
        .map(|b| vec![b.map_or("naive".to_string(), |b| b.name().to_string())])
        .collect();
    for ds in datasets {
        let mut rng = Pcg64::seed(opts.seed);
        let store = build_store(ds, opts, &mut rng);
        for (bi, b) in bounds.iter().enumerate() {
            let mut cfg = base_path_cfg(opts, 0.9);
            cfg.screening = b.map(|b| ScreeningConfig::new(b, RuleKind::Sphere));
            let res = run_variant(
                &store,
                engine,
                &cfg,
                &format!("{ds}/{:?}", b.map(|b| b.name())),
                opts.verbose,
            );
            let screen_secs: f64 = res.steps.iter().map(|s| s.screen_time).sum();
            rows[bi].push(format!("{} ({})", fnum(res.total_wall), fnum(screen_secs)));
        }
    }
    for r in rows {
        table.row(r);
    }
    table
}

/// Table 5: diagonal-M regularization path on the high-dimensional
/// analogues — plain vs +RRPB(sphere) vs +RRPB(analytic nonneg rule,
/// the Appendix-B counterpart of "+PGB").
pub fn run_table5(opts: &ExpOptions, datasets: &[&str]) -> Table {
    use crate::diag::{lambda_max, DiagProblem, DiagStore};
    let mut table = Table::new(
        "Table 5 — diagonal-M total path time (s)",
        &[&["method"], datasets].concat(),
    );
    let methods = ["plain", "+RRPB", "+RRPB+nonneg"];
    let mut rows: Vec<Vec<String>> = methods.iter().map(|m| vec![m.to_string()]).collect();
    for ds_name in datasets {
        let mut rng = Pcg64::seed(opts.seed);
        let spec = synthetic::spec(ds_name).unwrap_or_else(|| panic!("unknown {ds_name}"));
        let mut ds = synthetic::analogue(ds_name, &mut rng);
        if opts.scale < 1.0 {
            ds = ds.subsample(opts.scale.max(0.05), &mut rng);
        }
        let ds = ds.subsample(0.9, &mut rng);
        let store = DiagStore::from_dataset(&ds, spec.k.min(10), &mut rng);
        let loss = Loss::smoothed_hinge(0.05);
        let lmax = lambda_max(&store, &loss);
        let d = store.d;
        for (mi, method) in methods.iter().enumerate() {
            let t0 = std::time::Instant::now();
            let mut lambda = lmax;
            let mut m_warm = vec![0.0; d];
            let mut reference: Option<(Vec<f64>, f64, f64)> = None;
            let mut prev_loss: Option<f64> = None;
            for _ in 0..40 {
                let l_prev = lambda;
                lambda *= 0.9;
                let mut prob = DiagProblem::new(&store, loss, lambda);
                let screening = match (mi, &reference) {
                    (0, _) | (_, None) => None,
                    (1, Some((m0, l0, eps))) => Some((m0.as_slice(), *l0, *eps, false)),
                    (_, Some((m0, l0, eps))) => Some((m0.as_slice(), *l0, *eps, true)),
                };
                let (m, st) = prob.solve(m_warm.clone(), opts.tol, 4000, screening);
                let loss_term =
                    st.p - 0.5 * lambda * m.iter().map(|v| v * v).sum::<f64>();
                let eps = (2.0 * st.gap.max(0.0) / lambda).sqrt();
                reference = Some((m.clone(), lambda, eps));
                m_warm = m;
                if let Some(prev) = prev_loss {
                    if prev > 0.0
                        && ((prev - loss_term) / prev) * (l_prev / (l_prev - lambda)) < 0.01
                    {
                        break;
                    }
                }
                prev_loss = Some(loss_term);
            }
            rows[mi].push(fnum(t0.elapsed().as_secs_f64()));
            if opts.verbose {
                eprintln!("  table5 {ds_name}/{method} done");
            }
        }
    }
    for r in rows {
        table.row(r);
    }
    table
}

/// Per-family outcome of one [`range_study_for`] dimension: how many
/// certified λ-intervals one certificate family produced, how wide they
/// are, and what the expiry-schedule sweep over the λ grid cost/yielded.
#[derive(Clone, Debug)]
pub struct CertFamilyStats {
    /// merged certificates in the frame's expiry schedule
    pub certificates: usize,
    /// mean certified-interval width, clamped to (0, λ_max] (R-side
    /// upper endpoints are often +∞: the rule keeps firing for every
    /// larger λ, so the clamp measures the width *usable on the path*)
    pub mean_width: f64,
    /// Σ over the λ grid of ids certified at each λ
    pub coverage_total: usize,
    /// ids certified at the final (smallest) λ of the grid
    pub coverage_final: usize,
    /// Σ over the λ grid of certificates entering/expiring in the sweep
    pub range_pass_work: usize,
    /// seconds to build the frame (margins pass + derivation; the
    /// general families add one `wgram`, one eigendecomposition and one
    /// margins pass)
    pub build_seconds: f64,
}

/// One dimension of the DGB/GB-vs-RRPB certificate study
/// ([`range_study_for`]).
#[derive(Clone, Debug)]
pub struct RangeStudyRow {
    /// feature dimension of the synthetic problem
    pub d: usize,
    /// triplets in the store
    pub triplets: usize,
    /// exact λ_max of the problem
    pub lambda_max: f64,
    /// λ-grid steps swept (λ_t = ρᵗ·λ_max)
    pub steps: usize,
    /// closed-form RRPB certificates only (`CertFamilies::rrpb_only`)
    pub rrpb: CertFamilyStats,
    /// RRPB + the DGB/GB general forms (`CertFamilies::all`)
    pub general: CertFamilyStats,
    /// soundness cross-check: at every λ of the grid the general
    /// family's coverage was a superset of RRPB-only coverage, per side
    /// (must hold — the general frame's intervals are unions that
    /// include the RRPB ones)
    pub general_is_superset: bool,
}

/// The App. K.1 study for one dimension: build the exact λ_max reference
/// `M₀ = [ΣH]_+/λ_max` (ε = 0) over a synthetic d-dimensional store,
/// derive certificates under `CertFamilies::rrpb_only()` vs
/// `CertFamilies::all()` (the DGB/GB general range forms,
/// `PathConfig::range_general`'s machinery), and sweep both expiry
/// schedules down the λ grid — measuring exactly the marginal coverage
/// the general families buy, with no solver in the loop (so the study
/// stays tractable at d = 768, where every PGD iteration would pay an
/// O(d³) eigendecomposition).
pub fn range_study_for(
    engine: &dyn Engine,
    d: usize,
    n_points: usize,
    k: usize,
    steps: usize,
    rho: f64,
    seed: u64,
) -> RangeStudyRow {
    use crate::linalg::psd_split;
    use crate::screening::{CertFamilies, ReferenceFrame};

    let mut rng = Pcg64::seed(seed ^ d as u64);
    let ds = synthetic::gaussian_mixture(&format!("rs-d{d}"), n_points, d, 3, 2.5, &mut rng);
    let store = TripletStore::from_dataset(&ds, k, &mut rng);
    let loss = Loss::smoothed_hinge(0.05);
    let lambda_max = Problem::lambda_max(&store, &loss, engine);
    let ones = vec![1.0; store.len()];
    let m0 = psd_split(&engine.wgram(&store.a, &store.b, &ones))
        .plus
        .scaled(1.0 / lambda_max);

    let build = |families: CertFamilies| {
        let t0 = std::time::Instant::now();
        let frame = ReferenceFrame::build(
            m0.clone(),
            lambda_max,
            0.0,
            &store,
            engine,
            Some((&loss, families)),
        );
        (frame, t0.elapsed().as_secs_f64())
    };
    let (frame_rrpb, build_rrpb) = build(CertFamilies::rrpb_only());
    let (frame_gen, build_gen) = build(CertFamilies::all());

    let mean_width = |frame: &ReferenceFrame| {
        let widths: Vec<f64> = frame
            .certificates()
            .iter()
            .map(|c| (c.hi.min(lambda_max) - c.lo.max(0.0)).max(0.0))
            .collect();
        if widths.is_empty() {
            0.0
        } else {
            widths.iter().sum::<f64>() / widths.len() as f64
        }
    };

    let mut stats = [
        CertFamilyStats {
            certificates: frame_rrpb.n_certificates(),
            mean_width: mean_width(&frame_rrpb),
            coverage_total: 0,
            coverage_final: 0,
            range_pass_work: 0,
            build_seconds: build_rrpb,
        },
        CertFamilyStats {
            certificates: frame_gen.n_certificates(),
            mean_width: mean_width(&frame_gen),
            coverage_total: 0,
            coverage_final: 0,
            range_pass_work: 0,
            build_seconds: build_gen,
        },
    ];

    let mut superset = true;
    let (mut l_r, mut r_r) = (Vec::new(), Vec::new());
    let (mut l_g, mut r_g) = (Vec::new(), Vec::new());
    let mut lambda = lambda_max;
    for step in 0..steps {
        lambda *= rho;
        stats[0].range_pass_work += frame_rrpb.advance_covered(lambda, &mut l_r, &mut r_r);
        stats[1].range_pass_work += frame_gen.advance_covered(lambda, &mut l_g, &mut r_g);
        stats[0].coverage_total += l_r.len() + r_r.len();
        stats[1].coverage_total += l_g.len() + r_g.len();
        if step + 1 == steps {
            stats[0].coverage_final = l_r.len() + r_r.len();
            stats[1].coverage_final = l_g.len() + r_g.len();
        }
        for (sub, sup) in [(&mut l_r, &mut l_g), (&mut r_r, &mut r_g)] {
            sub.sort_unstable();
            sup.sort_unstable();
            if !sub.iter().all(|id| sup.binary_search(id).is_ok()) {
                superset = false;
            }
        }
    }
    let [rrpb, general] = stats;
    RangeStudyRow {
        d,
        triplets: store.len(),
        lambda_max,
        steps,
        rrpb,
        general,
        general_is_superset: superset,
    }
}

/// The DGB/GB-vs-RRPB certificate study across dimensions (this repo's
/// App. K.1 follow-up; `rangestudy` in the experiments binary). Columns
/// per family: certificate count, mean certified width, total/final
/// coverage over the λ grid, sweep work, frame build seconds.
pub fn run_range_study(engine: &dyn Engine, opts: &ExpOptions, dims: &[usize]) -> Table {
    let steps = if opts.max_steps > 0 { opts.max_steps } else { 25 };
    let n_points = ((48.0 * opts.scale) as usize).max(24);
    let mut table = Table::new(
        "range study — DGB/GB general-form certificates vs RRPB-only",
        &[
            "d",
            "triplets",
            "lambda_max",
            "rrpb_certs",
            "gen_certs",
            "rrpb_mean_width",
            "gen_mean_width",
            "rrpb_coverage",
            "gen_coverage",
            "rrpb_work",
            "gen_work",
            "superset",
        ],
    );
    for &d in dims {
        if opts.verbose {
            eprintln!("  range study d={d} …");
        }
        let row = range_study_for(engine, d, n_points, 3, steps, 0.9, opts.seed);
        assert!(
            row.general_is_superset,
            "d={d}: general-family coverage lost an RRPB-certified id"
        );
        table.row(vec![
            d.to_string(),
            row.triplets.to_string(),
            fnum(row.lambda_max),
            row.rrpb.certificates.to_string(),
            row.general.certificates.to_string(),
            fnum(row.rrpb.mean_width),
            fnum(row.general.mean_width),
            row.rrpb.coverage_total.to_string(),
            row.general.coverage_total.to_string(),
            row.rrpb.range_pass_work.to_string(),
            row.general.range_pass_work.to_string(),
            if row.general_is_superset { "yes" } else { "NO" }.to_string(),
        ]);
    }
    table
}

/// Persist a set of tables as one markdown report + CSVs.
pub fn emit(name: &str, tables: &[&Table]) {
    let mut md = String::new();
    for t in tables {
        md.push_str(&t.to_markdown());
        md.push('\n');
    }
    print!("{md}");
    if let Ok(path) = write_report(&format!("{name}.md"), &md) {
        eprintln!("wrote {}", path.display());
    }
    for (i, t) in tables.iter().enumerate() {
        let _ = write_report(&format!("{name}_{i}.csv"), &t.to_csv());
    }
}
