//! Experiment coordination: configs, runners for every paper table/figure,
//! and report formatting (markdown/CSV/JSON).

pub mod experiments;
pub mod plot;
pub mod report;
pub mod tpu_model;
