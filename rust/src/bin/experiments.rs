//! `experiments` — regenerate every table and figure of the paper (§5).
//!
//! Usage: `experiments <table1|fig4|fig5|fig6|fig7|fig8|table2|table4|`
//!   `table5|rangestudy|perf|all>`
//!   [--dataset NAME] [--engine native|native-scalar|pjrt]
//!   [--kernel-core auto|row-stream|d-blocked|scalar] [--d-threshold N]
//!   [--precision f64|mixed] [--rank R] [--scale F] [--trials N]
//!   [--seed N] [--tol F] [--verbose]
//!
//! `--rank R` wraps the native engine in the rank-R factored screening
//! backend (reference margins/norms in O(R) per row; the exact
//! compression error is folded into each frame's ε, so screening stays
//! safe for the dense problem).
//!
//! Outputs are printed as markdown and persisted under `reports/`.
//! See DESIGN.md §5 for the experiment index and EXPERIMENTS.md for the
//! paper-vs-measured record. `rangestudy` is this repo's App. K.1
//! extension study: DGB/GB general-form certificates vs RRPB-only, per
//! dimension.

use triplet_screen::coordinator::experiments as exp;
use triplet_screen::prelude::*;
use triplet_screen::runtime::{parse_rank, FactoredEngine, KernelCore};
use triplet_screen::util::cli::Args;

fn maybe_factored(inner: NativeEngine, rank: Option<usize>) -> Box<dyn Engine> {
    match rank {
        Some(r) => Box::new(FactoredEngine::new(inner, r)),
        None => Box::new(inner),
    }
}

fn make_engine(args: &Args) -> Box<dyn Engine> {
    let threads = args.get_usize("threads", 0);
    let rank = args.get("rank").and_then(parse_rank);
    match args.get_or("engine", "native") {
        "native" => {
            let core = args.get("kernel-core").map(KernelCore::parse_cli);
            let threshold = args
                .get("d-threshold")
                .map(|s| s.parse().expect("--d-threshold expects an integer"));
            let precision = args.get("precision").map(PrecisionTier::parse_cli);
            maybe_factored(
                NativeEngine::from_options(threads, core, threshold, precision),
                rank,
            )
        }
        "native-scalar" => maybe_factored(NativeEngine::scalar(threads), rank),
        "pjrt" => {
            assert!(
                rank.is_none(),
                "--rank wraps the native engines; it is not supported with --engine pjrt"
            );
            Box::new(
                PjrtEngine::from_default_dir()
                    .expect("loading PJRT artifacts (run `make artifacts`)"),
            )
        }
        other => panic!("unknown engine {other:?}"),
    }
}

fn options(args: &Args) -> exp::ExpOptions {
    exp::ExpOptions {
        scale: args.get_f64("scale", 1.0),
        seed: args.get_usize("seed", 7) as u64,
        trials: args.get_usize("trials", 1),
        tol: args.get_f64("tol", 1e-6),
        verbose: args.flag("verbose"),
        max_steps: args.get_usize("max-steps", 0),
    }
}

fn main() {
    let args = Args::parse();
    let engine = make_engine(&args);
    let opts = options(&args);
    let which = args.subcommand.clone().unwrap_or_else(|| {
        eprintln!(
            "usage: experiments \
             <table1|fig4|fig5|fig6|fig7|fig8|table2|table4|table5|rangestudy|perf|all>"
        );
        std::process::exit(2);
    });
    run(&which, engine.as_ref(), &opts, &args);
}

fn run(which: &str, engine: &dyn Engine, opts: &exp::ExpOptions, args: &Args) {
    match which {
        "table1" => {
            let t = exp::run_table1(engine, opts);
            exp::emit("table1", &[&t]);
        }
        "fig4" => {
            let ds = args.get_or("dataset", "segment");
            let (rate, time) = exp::run_fig4(engine, opts, ds, true);
            exp::emit("fig4", &[&rate, &time]);
        }
        "fig8" => {
            let ds = args.get_or("dataset", "segment");
            let (rate, time) = exp::run_fig4(engine, opts, ds, false);
            exp::emit("fig8", &[&rate, &time]);
        }
        "fig5" => {
            let ds = args.get_or("dataset", "phishing");
            let (rate, dyn_rate, time) = exp::run_fig5(engine, opts, ds);
            exp::emit("fig5", &[&rate, &dyn_rate, &time]);
        }
        "fig6" => {
            let ds = args.get_or("dataset", "segment");
            let t4 = exp::run_fig6(engine, opts, ds, 1e-4);
            let t6 = exp::run_fig6(engine, opts, ds, 1e-6);
            exp::emit("fig6", &[&t4, &t6]);
        }
        "fig7" => {
            let ds = args.get_or("dataset", "segment");
            let t = exp::run_fig7(engine, opts, ds);
            exp::emit("fig7", &[&t]);
        }
        "table2" => {
            let datasets: Vec<&str> = args
                .get("datasets")
                .map(|s| s.split(',').collect())
                .unwrap_or_else(|| vec!["phishing", "sensit", "a9a", "mnist"]);
            let rho = args.get_f64("rho", 0.99);
            let t = exp::run_table2(engine, opts, &datasets, rho);
            exp::emit("table2", &[&t]);
        }
        "table4" => {
            let datasets: Vec<&str> = args
                .get("datasets")
                .map(|s| s.split(',').collect())
                .unwrap_or_else(|| vec!["iris", "wine", "segment", "satimage"]);
            let t = exp::run_table4(engine, opts, &datasets);
            exp::emit("table4", &[&t]);
        }
        "table5" => {
            let datasets: Vec<&str> = args
                .get("datasets")
                .map(|s| s.split(',').collect())
                .unwrap_or_else(|| vec!["usps", "madelon", "colon-cancer", "gisette"]);
            let t = exp::run_table5(opts, &datasets);
            exp::emit("table5", &[&t]);
        }
        "rangestudy" => {
            // App. K.1 extension study: DGB/GB general-form certificates
            // vs RRPB-only across the paper's dimensional range (the
            // d ≥ 512 points exercise the d-blocked kernel geometry)
            let dims: Vec<usize> = args
                .get("dims")
                .map(|s| {
                    s.split(',')
                        .map(|t| t.parse().expect("--dims expects integers"))
                        .collect()
                })
                .unwrap_or_else(|| vec![64, 300, 768]);
            let t = exp::run_range_study(engine, opts, &dims);
            exp::emit("rangestudy", &[&t]);
        }
        "perf" => {
            // §Perf artifacts: L1 TPU structural estimates + native-vs-PJRT
            // kernel timings on this host
            let profile = triplet_screen::coordinator::tpu_model::TpuProfile::v4_like();
            let est = triplet_screen::coordinator::tpu_model::estimate_table(
                &[19, 68, 128, 200],
                512,
                &profile,
            );
            let mut timing = triplet_screen::coordinator::report::Table::new(
                "engine kernel timings (this host)",
                &["kernel", "d", "n", "native_ms", "pjrt_ms", "pjrt/native"],
            );
            let native = NativeEngine::new(0);
            let pjrt = PjrtEngine::from_default_dir().ok();
            let mut rng = Pcg64::seed(1);
            for (d, n) in [(19usize, 8192usize), (68, 8192), (128, 8192)] {
                use triplet_screen::linalg::Mat;
                let mut m = Mat::from_fn(d, d, |_, _| rng.normal());
                m.symmetrize();
                let m = m.scaled(0.05);
                let a = Mat::from_fn(n, d, |_, _| rng.normal());
                let b = Mat::from_fn(n, d, |_, _| rng.normal());
                let mut out = vec![0.0; n];
                let time_it = |f: &mut dyn FnMut()| -> f64 {
                    f(); // warm
                    let t0 = std::time::Instant::now();
                    let mut iters = 0;
                    while t0.elapsed().as_millis() < 200 {
                        f();
                        iters += 1;
                    }
                    t0.elapsed().as_secs_f64() * 1e3 / iters as f64
                };
                for kernel in ["margins", "step"] {
                    let nat = time_it(&mut || {
                        if kernel == "margins" {
                            native.margins(&m, &a, &b, &mut out);
                        } else {
                            let _ = native.step(&m, &a, &b, 0.05, &mut out);
                        }
                    });
                    let pj = pjrt.as_ref().filter(|p| p.supports_dim(d)).map(|p| {
                        time_it(&mut || {
                            if kernel == "margins" {
                                p.margins(&m, &a, &b, &mut out);
                            } else {
                                let _ = p.step(&m, &a, &b, 0.05, &mut out);
                            }
                        })
                    });
                    timing.row(vec![
                        kernel.to_string(),
                        d.to_string(),
                        n.to_string(),
                        format!("{nat:.2}"),
                        pj.map_or("-".into(), |v| format!("{v:.2}")),
                        pj.map_or("-".into(), |v| format!("{:.2}", v / nat)),
                    ]);
                }
            }
            exp::emit("perf", &[&est, &timing]);
        }
        "all" => {
            for w in [
                "table1",
                "fig4",
                "fig5",
                "fig6",
                "fig7",
                "fig8",
                "table2",
                "table4",
                "table5",
                "rangestudy",
            ] {
                eprintln!("=== {w} ===");
                run(w, engine, opts, args);
            }
        }
        other => {
            eprintln!("unknown experiment {other:?}");
            std::process::exit(2);
        }
    }
}
