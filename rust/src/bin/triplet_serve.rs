//! `triplet-serve` — multi-tenant serving binary.
//!
//! Drives the `service` subsystem end to end: per-tenant [`Session`]s
//! with sharded admission and a shared frame cache (`demo`), the
//! concurrent request front end with its line-oriented protocol
//! (`serve`), and cross-process frame export in the versioned TSFS
//! byte format (`export-frames` / `--import-frames`).
//!
//! `triplet-serve --help` prints the full option reference — the same
//! text as the `triplet-serve` CLI section of `rust/README.md`,
//! enforced byte-for-byte by the
//! `readme_service_section_embeds_help_verbatim` test below.

use std::sync::Arc;

use triplet_screen::coordinator::report::{fnum, Table};
use triplet_screen::data::synthetic;
use triplet_screen::prelude::*;
use triplet_screen::service::{
    parse_request, request_dataset, FrameStore, FrontConfig, ServeFront, ServeResult, Session,
    SessionConfig, SubmitOptions, Ticket,
};
use triplet_screen::util::cli::Args;

/// Full option reference, printed by `--help` and mirrored verbatim in
/// the `triplet-serve` CLI section of `rust/README.md`.
const HELP: &str = "\
usage: triplet-serve [demo|serve|export-frames] [options]

Multi-tenant serving on the shared worker pool.

demo: each tenant session runs the full lifecycle — a cold sharded
path solve, a replay of the same dataset (warm FrameStore hit, zero
rule evaluations), then an incremental update (one row perturbed, one
label flipped) served by a warm-started re-solve at the tenant's
pinned lambda instead of a fresh path from lambda_max.

serve: concurrent request front end. Reads newline-delimited requests

  solve <tenant> <n> <d> <classes> <seed>

from --requests (default: stdin), routes them through a bounded queue
into per-tenant actor mailboxes (each tenant stays serial, tenants run
concurrently on front-end worker threads), and drains gracefully at
end of input — every accepted request resolves before exit. Tenant ids
are tenant-0 .. tenant-(N-1). Lines starting with '#' are comments;
malformed lines and unknown tenants are typed per-line errors, never a
crash.

export-frames: run the same front end over --requests, then write
every cached frame to --out in the versioned, checksummed TSFS byte
format. A later `serve --import-frames FILE` starts warm: imported
frames answer repeat requests with zero rule evaluations.

options (all subcommands)
  --k N                 neighbors per anchor                      [3]
  --shards N            admission shards per request              [4]
  --rho F               geometric decay of the lambda path        [0.9]
  --max-steps N         lambda steps per cold solve               [8]
  --tol F               solver duality-gap tolerance              [1e-6]
  --gamma F             smoothed-hinge gamma (0 = plain hinge)    [0.05]
  --batch N             mining batch size                         [1024]
  --max-candidates N    per-request candidate budget (0 = off)    [0]
  --max-workset N       per-request workset-row budget (0 = off)  [0]
  --threads N           compute pool workers (0 = auto)           [0]
  --json                emit one telemetry JSON object per request

demo options
  --tenants N           tenant sessions to run                    [4]
  --dataset NAME        synthetic analogue per tenant             [segment-small]
  --seed N              RNG seed (tenant t solves seed+t)         [7]
  --frame-capacity N    FrameStore LRU capacity                   [8]

serve / export-frames options
  --tenants N           tenants (ids tenant-0 ..)                 [4]
  --requests FILE       request file ('-' = stdin)                [-]
  --workers N           front-end worker threads                  [2]
  --queue N             request-queue capacity                    [64]
  --store-shards N      shared-store lock shards                  [4]
  --frame-capacity N    cached frames per store shard             [8]
  --import-frames FILE  warm-start the store from exported frames
  --export-frames FILE  also write the store on exit (serve)
  --out FILE            export target (export-frames)
";

fn main() {
    let args = Args::parse();
    if args.flag("help") {
        print!("{HELP}");
        return;
    }
    match args.subcommand.as_deref() {
        Some("demo") | None => demo(&args),
        Some("serve") => serve(&args, false),
        Some("export-frames") => serve(&args, true),
        Some(other) => {
            eprintln!("unknown subcommand {other:?}");
            print!("{HELP}");
            std::process::exit(2);
        }
    }
}

/// The per-tenant session configuration every subcommand shares.
fn session_config(args: &Args) -> SessionConfig {
    SessionConfig {
        k: args.get_usize("k", 3),
        batch: args.get_usize("batch", 1024),
        shards: args.get_usize("shards", 4),
        rho: args.get_f64("rho", 0.9),
        max_steps: args.get_usize("max-steps", 8),
        stop_ratio: 0.0,
        gamma: args.get_f64("gamma", 0.05),
        tol: args.get_f64("tol", 1e-6),
        max_candidates: args.get_usize("max-candidates", 0),
        max_workset_rows: args.get_usize("max-workset", 0),
    }
}

fn demo(args: &Args) {
    let tenants = args.get_usize("tenants", 4);
    let cfg = session_config(args);
    let engine = NativeEngine::new(args.get_usize("threads", 0));
    let dataset = args.get_or("dataset", "segment-small");
    let seed = args.get_usize("seed", 7) as u64;
    let json = args.flag("json");

    let mut frames = FrameStore::new(args.get_usize("frame-capacity", 8));
    let headers = [
        "tenant",
        "request",
        "steps",
        "admitted",
        "reused",
        "shards",
        "faults",
        "rule_evals",
        "wall_s",
    ];
    let mut table = Table::new("triplet-serve demo", &headers);

    for t in 0..tenants {
        let name = format!("tenant-{t}");
        let mut session = Session::new(name.clone(), cfg.clone());
        let mut rng = Pcg64::seed(seed + t as u64);
        let ds = synthetic::analogue(dataset, &mut rng);

        let cold = session.serve(&ds, &mut frames, &engine).expect("cold solve");
        record(&mut table, &name, "cold", &cold, json);

        let warm = session.serve(&ds, &mut frames, &engine).expect("warm hit");
        assert_eq!(warm.telemetry.rule_evals, 0, "warm hit must skip the rules");
        record(&mut table, &name, "warm-hit", &warm, json);

        // incremental update: nudge one row, flip one label
        let mut updated = ds.clone();
        let r = rng.below(updated.n());
        updated.x.row_mut(r)[0] += 0.05;
        let f = rng.below(updated.n());
        updated.y[f] = (updated.y[f] + 1) % updated.n_classes;
        let inc = session
            .serve(&updated, &mut frames, &engine)
            .expect("incremental update");
        record(&mut table, &name, "incremental", &inc, json);
    }

    if !json {
        println!("{}", table.to_markdown());
        println!(
            "frame store: {} entries, {} hits, {} misses, {} evictions",
            frames.len(),
            frames.hits(),
            frames.misses(),
            frames.evictions()
        );
    }
}

fn record(table: &mut Table, tenant: &str, request: &str, res: &ServeResult, json: bool) {
    let tel = &res.telemetry;
    if json {
        println!("{}", tel.to_json().to_string_compact());
    }
    table.row(vec![
        tenant.to_string(),
        request.to_string(),
        res.steps.to_string(),
        res.admitted_idx.len().to_string(),
        tel.frames_reused.to_string(),
        tel.shards.to_string(),
        tel.shard_faults.to_string(),
        tel.rule_evals.to_string(),
        fnum(tel.wall_seconds),
    ]);
}

/// One request line's outcome, printed in line order after the drain.
enum LineOutcome {
    /// parse/submit rejection — resolved before any solve ran
    Done(String),
    /// accepted — resolves when the front end drains
    Pending { tenant: String, ticket: Ticket },
}

fn serve(args: &Args, export_mode: bool) {
    let out_path: Option<String> = if export_mode {
        match args.get("out") {
            Some(p) => Some(p.to_string()),
            None => {
                eprintln!("export-frames requires --out FILE");
                std::process::exit(2);
            }
        }
    } else {
        args.get("export-frames").map(|p| p.to_string())
    };

    let tenants = args.get_usize("tenants", 4);
    let tenant_names: Vec<String> = (0..tenants).map(|t| format!("tenant-{t}")).collect();
    let cfg = FrontConfig {
        workers: args.get_usize("workers", 2),
        queue_capacity: args.get_usize("queue", 64),
        store_shards: args.get_usize("store-shards", 4),
        store_capacity: args.get_usize("frame-capacity", 8),
        session: session_config(args),
    };
    let engine = Arc::new(NativeEngine::new(args.get_usize("threads", 0)));
    let mut front = ServeFront::new(cfg, &tenant_names, engine);
    let json = args.flag("json");

    if let Some(path) = args.get("import-frames") {
        let bytes = std::fs::read(path).unwrap_or_else(|e| {
            eprintln!("cannot read {path}: {e}");
            std::process::exit(2);
        });
        match front.store().import_bytes(&bytes) {
            Ok(n) => eprintln!("imported {n} frames from {path}"),
            Err(e) => {
                eprintln!("import of {path} failed: {e}");
                std::process::exit(2);
            }
        }
    }

    let source = args.get_or("requests", "-");
    let input = if source == "-" {
        std::io::read_to_string(std::io::stdin()).unwrap_or_else(|e| {
            eprintln!("cannot read stdin: {e}");
            std::process::exit(2);
        })
    } else {
        std::fs::read_to_string(source).unwrap_or_else(|e| {
            eprintln!("cannot read {source}: {e}");
            std::process::exit(2);
        })
    };

    // Submit every line first (tenants interleave across the queue),
    // then drain and report in line order.
    let mut outcomes: Vec<(usize, LineOutcome)> = Vec::new();
    for (i, line) in input.lines().enumerate() {
        let lineno = i + 1;
        if line.trim_start().starts_with('#') {
            continue;
        }
        let outcome = match parse_request(line) {
            Err(e) => LineOutcome::Done(format!("protocol error: {e}")),
            Ok(req) => {
                let ds = request_dataset(&req);
                match front.submit(&req.tenant, &ds, SubmitOptions::default()) {
                    Ok(ticket) => LineOutcome::Pending {
                        tenant: req.tenant,
                        ticket,
                    },
                    Err(e) => LineOutcome::Done(format!("rejected: {e}")),
                }
            }
        };
        outcomes.push((lineno, outcome));
    }
    if outcomes.is_empty() {
        // typed outcome for empty input: no requests is an explicit
        // protocol-level error, not a silent no-op
        eprintln!("protocol error: empty request input (no request lines)");
        std::process::exit(1);
    }

    // Graceful drain: closes the queue, processes everything accepted
    // above, joins the workers. Every Pending ticket resolves here.
    front.shutdown();

    for (lineno, outcome) in outcomes {
        match outcome {
            LineOutcome::Done(msg) => println!("line {lineno}: {msg}"),
            LineOutcome::Pending { tenant, ticket } => match ticket.wait() {
                Ok(res) => {
                    if json {
                        println!("{}", res.telemetry.to_json().to_string_compact());
                    }
                    println!(
                        "line {lineno}: ok tenant={tenant} steps={} admitted={} reused={} \
                         rule_evals={} wall_s={}",
                        res.steps,
                        res.admitted_idx.len(),
                        res.telemetry.frames_reused,
                        res.telemetry.rule_evals,
                        fnum(res.telemetry.wall_seconds),
                    );
                }
                Err(e) => println!("line {lineno}: error: {e}"),
            },
        }
    }

    let store = front.store();
    println!(
        "front end: {} accepted, {} rejected-full, {} completed, {} timed-out, {} panics",
        front.accepted(),
        front.rejected_full(),
        front.completed(),
        front.timed_out(),
        front.panics_caught()
    );
    println!(
        "frame store: {} entries, {} hits, {} misses, {} evictions",
        store.len(),
        store.hits(),
        store.misses(),
        store.evictions()
    );

    if let Some(path) = out_path {
        let bytes = store.export_bytes();
        let frames = store.len();
        std::fs::write(&path, &bytes).unwrap_or_else(|e| {
            eprintln!("cannot write {path}: {e}");
            std::process::exit(2);
        });
        println!("exported {frames} frames ({} bytes) to {path}", bytes.len());
    }
}

#[cfg(test)]
mod tests {
    use super::HELP;

    /// The README's `triplet-serve` section claims to mirror `--help`
    /// verbatim — hold it to that, byte for byte (same rot-guard as the
    /// `triplet-screen` CLI section).
    #[test]
    fn readme_service_section_embeds_help_verbatim() {
        let readme = include_str!("../../README.md");
        assert!(
            readme.contains(HELP),
            "rust/README.md triplet-serve section diverged from the HELP const in \
             triplet_serve.rs — update the fenced block to match `triplet-serve --help` \
             byte for byte"
        );
    }
}
