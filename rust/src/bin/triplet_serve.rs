//! `triplet-serve` — multi-tenant path-serving demo binary.
//!
//! Drives the `service` subsystem end to end: per-tenant [`Session`]s
//! with sharded admission, a shared [`FrameStore`], warm cache hits and
//! incremental updates, all on the persistent worker pool.
//!
//! `triplet-serve --help` prints the full option reference — the same
//! text as the `triplet-serve` CLI section of `rust/README.md`,
//! enforced byte-for-byte by the
//! `readme_service_section_embeds_help_verbatim` test below.

use triplet_screen::coordinator::report::{fnum, Table};
use triplet_screen::data::synthetic;
use triplet_screen::prelude::*;
use triplet_screen::service::{FrameStore, ServeResult, Session, SessionConfig};
use triplet_screen::util::cli::Args;

/// Full option reference, printed by `--help` and mirrored verbatim in
/// the `triplet-serve` CLI section of `rust/README.md`.
const HELP: &str = "\
usage: triplet-serve demo [options]

Multi-tenant serving demonstration on the shared worker pool. Each
tenant session runs the full lifecycle: a cold sharded path solve, a
replay of the same dataset (warm FrameStore hit, zero rule
evaluations), then an incremental update (one row perturbed, one label
flipped) served by a warm-started re-solve at the tenant's pinned
lambda instead of a fresh path from lambda_max.

options
  --tenants N           tenant sessions to run                    [4]
  --shards N            admission shards per request              [4]
  --dataset NAME        synthetic analogue per tenant             [segment-small]
  --k N                 neighbors per anchor                      [3]
  --seed N              RNG seed (tenant t solves seed+t)         [7]
  --rho F               geometric decay of the lambda path        [0.9]
  --max-steps N         lambda steps per cold solve               [8]
  --tol F               solver duality-gap tolerance              [1e-6]
  --gamma F             smoothed-hinge gamma (0 = plain hinge)    [0.05]
  --batch N             mining batch size                         [1024]
  --frame-capacity N    FrameStore LRU capacity                   [8]
  --max-candidates N    per-request candidate budget (0 = off)    [0]
  --max-workset N       per-request workset-row budget (0 = off)  [0]
  --threads N           worker threads (0 = auto)                 [0]
  --json                emit one telemetry JSON object per request
";

fn main() {
    let args = Args::parse();
    if args.flag("help") {
        print!("{HELP}");
        return;
    }
    match args.subcommand.as_deref() {
        Some("demo") | None => demo(&args),
        Some(other) => {
            eprintln!("unknown subcommand {other:?}");
            print!("{HELP}");
            std::process::exit(2);
        }
    }
}

fn demo(args: &Args) {
    let tenants = args.get_usize("tenants", 4);
    let cfg = SessionConfig {
        k: args.get_usize("k", 3),
        batch: args.get_usize("batch", 1024),
        shards: args.get_usize("shards", 4),
        rho: args.get_f64("rho", 0.9),
        max_steps: args.get_usize("max-steps", 8),
        stop_ratio: 0.0,
        gamma: args.get_f64("gamma", 0.05),
        tol: args.get_f64("tol", 1e-6),
        max_candidates: args.get_usize("max-candidates", 0),
        max_workset_rows: args.get_usize("max-workset", 0),
    };
    let engine = NativeEngine::new(args.get_usize("threads", 0));
    let dataset = args.get_or("dataset", "segment-small");
    let seed = args.get_usize("seed", 7) as u64;
    let json = args.flag("json");

    let mut frames = FrameStore::new(args.get_usize("frame-capacity", 8));
    let headers = [
        "tenant",
        "request",
        "steps",
        "admitted",
        "reused",
        "shards",
        "faults",
        "rule_evals",
        "wall_s",
    ];
    let mut table = Table::new("triplet-serve demo", &headers);

    for t in 0..tenants {
        let name = format!("tenant-{t}");
        let mut session = Session::new(name.clone(), cfg.clone());
        let mut rng = Pcg64::seed(seed + t as u64);
        let ds = synthetic::analogue(dataset, &mut rng);

        let cold = session.serve(&ds, &mut frames, &engine).expect("cold solve");
        record(&mut table, &name, "cold", &cold, json);

        let warm = session.serve(&ds, &mut frames, &engine).expect("warm hit");
        assert_eq!(warm.telemetry.rule_evals, 0, "warm hit must skip the rules");
        record(&mut table, &name, "warm-hit", &warm, json);

        // incremental update: nudge one row, flip one label
        let mut updated = ds.clone();
        let r = rng.below(updated.n());
        updated.x.row_mut(r)[0] += 0.05;
        let f = rng.below(updated.n());
        updated.y[f] = (updated.y[f] + 1) % updated.n_classes;
        let inc = session
            .serve(&updated, &mut frames, &engine)
            .expect("incremental update");
        record(&mut table, &name, "incremental", &inc, json);
    }

    if !json {
        println!("{}", table.to_markdown());
        println!(
            "frame store: {} entries, {} hits, {} misses, {} evictions",
            frames.len(),
            frames.hits(),
            frames.misses(),
            frames.evictions()
        );
    }
}

fn record(table: &mut Table, tenant: &str, request: &str, res: &ServeResult, json: bool) {
    let tel = &res.telemetry;
    if json {
        println!("{}", tel.to_json().to_string_compact());
    }
    table.row(vec![
        tenant.to_string(),
        request.to_string(),
        res.steps.to_string(),
        res.admitted_idx.len().to_string(),
        tel.frames_reused.to_string(),
        tel.shards.to_string(),
        tel.shard_faults.to_string(),
        tel.rule_evals.to_string(),
        fnum(tel.wall_seconds),
    ]);
}

#[cfg(test)]
mod tests {
    use super::HELP;

    /// The README's `triplet-serve` section claims to mirror `--help`
    /// verbatim — hold it to that, byte for byte (same rot-guard as the
    /// `triplet-screen` CLI section).
    #[test]
    fn readme_service_section_embeds_help_verbatim() {
        let readme = include_str!("../../README.md");
        assert!(
            readme.contains(HELP),
            "rust/README.md triplet-serve section diverged from the HELP const in \
             triplet_serve.rs — update the fenced block to match `triplet-serve --help` \
             byte for byte"
        );
    }
}
