//! The triplet store: difference vectors and per-triplet constants.
//!
//! A triplet `(i, j, l)` (same-class pair `i, j`; different-class `l`)
//! defines `H_ijl = (x_i−x_l)(x_i−x_l)^T − (x_i−x_j)(x_i−x_j)^T`. We never
//! materialize `H`: storing `a_t = x_i−x_l` (rows of `A`) and
//! `b_t = x_i−x_j` (rows of `B`) is enough for every quantity in the paper:
//!
//!   ⟨M, H_t⟩   = a^T M a − b^T M b            (margins kernel)
//!   Σ w_t H_t  = A^T diag(w) A − B^T diag(w) B (wgram kernel)
//!   ‖H_t‖_F²   = ‖a‖⁴ + ‖b‖⁴ − 2(a·b)²        (precomputed here)

use crate::data::{neighbors, Dataset};
use crate::linalg::Mat;
use crate::util::{parallel, rng::Pcg64};

/// Immutable triplet set for one learning problem.
#[derive(Clone, Debug)]
pub struct TripletStore {
    /// rows: `x_i − x_l` (different-class differences)
    pub a: Mat,
    /// rows: `x_i − x_j` (same-class differences)
    pub b: Mat,
    /// `‖H_t‖_F` per triplet
    pub h_norm: Vec<f64>,
    /// original (i, j, l) indices
    pub idx: Vec<(u32, u32, u32)>,
    /// feature dimension
    pub d: usize,
}

impl TripletStore {
    /// Build triplets following the paper's protocol (§5, after [21]):
    /// for each anchor `x_i`, take its `k` nearest same-class neighbors
    /// `x_j` and `k` nearest different-class neighbors `x_l`, forming k²
    /// triplets per anchor. `k = usize::MAX` enumerates all pairs. `rng`
    /// is unused today (generation is deterministic) but kept in the
    /// signature for subsampling strategies.
    pub fn from_dataset(ds: &Dataset, k: usize, _rng: &mut Pcg64) -> TripletStore {
        let (same, diff) = neighbors(ds, k);
        let mut idx = Vec::new();
        for i in 0..ds.n() {
            for &j in &same[i] {
                for &l in &diff[i] {
                    idx.push((i as u32, j as u32, l as u32));
                }
            }
        }
        Self::from_indices(ds, idx)
    }

    /// Build from explicit (i, j, l) triplets.
    pub fn from_indices(ds: &Dataset, idx: Vec<(u32, u32, u32)>) -> TripletStore {
        let d = ds.d();
        let t = idx.len();
        let mut a = Mat::zeros(t, d);
        let mut b = Mat::zeros(t, d);
        for (r, &(i, j, l)) in idx.iter().enumerate() {
            debug_assert_eq!(ds.y[i as usize], ds.y[j as usize], "j must share i's class");
            debug_assert_ne!(ds.y[i as usize], ds.y[l as usize], "l must differ in class");
            let (xi, xj, xl) = (
                ds.x.row(i as usize),
                ds.x.row(j as usize),
                ds.x.row(l as usize),
            );
            let ra = a.row_mut(r);
            for c in 0..d {
                ra[c] = xi[c] - xl[c];
            }
            let rb = b.row_mut(r);
            for c in 0..d {
                rb[c] = xi[c] - xj[c];
            }
        }
        let h_norm = Self::compute_h_norms(&a, &b);
        TripletStore {
            a,
            b,
            h_norm,
            idx,
            d,
        }
    }

    /// Empty growable store for feature dimension `d` — the streaming
    /// pipeline's admitted set, grown one [`Self::push`] at a time as
    /// candidates survive the admission screen.
    pub fn empty(d: usize) -> TripletStore {
        TripletStore {
            a: Mat::zeros(0, d),
            b: Mat::zeros(0, d),
            h_norm: Vec::new(),
            idx: Vec::new(),
            d,
        }
    }

    /// Append one admitted triplet in O(d) — the streaming pipeline's
    /// only write path. `a_row`/`b_row` are the `x_i−x_l` / `x_i−x_j`
    /// differences and `h_norm` the precomputed `‖H‖_F` (the miner's
    /// [`crate::triplet::CandidateBatch`] carries all three). Ids are
    /// assigned densely in push order, so every id handed out earlier
    /// stays valid.
    pub fn push(&mut self, idx: (u32, u32, u32), a_row: &[f64], b_row: &[f64], h_norm: f64) {
        assert_eq!(a_row.len(), self.d, "a row width mismatch");
        assert_eq!(b_row.len(), self.d, "b row width mismatch");
        self.a.push_row(a_row);
        self.b.push_row(b_row);
        self.h_norm.push(h_norm);
        self.idx.push(idx);
    }

    /// `‖H_t‖_F = sqrt(‖a‖⁴ + ‖b‖⁴ − 2 (a·b)²)` — exact, O(d) per triplet.
    fn compute_h_norms(a: &Mat, b: &Mat) -> Vec<f64> {
        let t = a.rows();
        let workers = parallel::default_threads();
        let mut out = vec![0.0; t];
        parallel::par_fill(&mut out, workers, |range, chunk| {
            for (k, r) in range.enumerate() {
                let (ra, rb) = (a.row(r), b.row(r));
                let (mut na, mut nb, mut ab) = (0.0, 0.0, 0.0);
                for c in 0..ra.len() {
                    na += ra[c] * ra[c];
                    nb += rb[c] * rb[c];
                    ab += ra[c] * rb[c];
                }
                // fl. rounding can push the radicand a hair below 0
                chunk[k] = (na * na + nb * nb - 2.0 * ab * ab).max(0.0).sqrt();
            }
        });
        out
    }

    /// Number of triplets in the store.
    pub fn len(&self) -> usize {
        self.idx.len()
    }

    /// Whether the store holds no triplets.
    pub fn is_empty(&self) -> bool {
        self.idx.is_empty()
    }

    /// `Σ_t H_t` over a subset of triplets (used for λ_max and for the
    /// screened-L fixed gradient term). O(|subset|·d²) via two rank-k
    /// accumulations.
    pub fn sum_h(&self, subset: impl Iterator<Item = usize>) -> Mat {
        let mut g = Mat::zeros(self.d, self.d);
        for t in subset {
            let (ra, rb) = (self.a.row(t), self.b.row(t));
            for i in 0..self.d {
                let (ai, bi) = (ra[i], rb[i]);
                let grow = g.row_mut(i);
                for j in 0..self.d {
                    grow[j] += ai * ra[j] - bi * rb[j];
                }
            }
        }
        g
    }

    /// Explicit `H_t` (tests / tiny problems only).
    pub fn h_mat(&self, t: usize) -> Mat {
        Mat::outer(self.a.row(t)).sub(&Mat::outer(self.b.row(t)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    fn toy_store() -> (Dataset, TripletStore) {
        let mut rng = Pcg64::seed(1);
        let ds = synthetic::gaussian_mixture("g", 60, 5, 3, 2.5, &mut rng);
        let store = TripletStore::from_dataset(&ds, 3, &mut rng);
        (ds, store)
    }

    #[test]
    fn triplet_count_matches_k_squared() {
        let (ds, store) = toy_store();
        // every anchor has >= 3 same-class and >= 3 diff-class neighbors
        assert_eq!(store.len(), ds.n() * 9);
    }

    #[test]
    fn difference_vectors_correct() {
        let (ds, store) = toy_store();
        for t in (0..store.len()).step_by(37) {
            let (i, j, l) = store.idx[t];
            for c in 0..ds.d() {
                assert_eq!(
                    store.a[(t, c)],
                    ds.x[(i as usize, c)] - ds.x[(l as usize, c)]
                );
                assert_eq!(
                    store.b[(t, c)],
                    ds.x[(i as usize, c)] - ds.x[(j as usize, c)]
                );
            }
        }
    }

    #[test]
    fn h_norm_matches_explicit_frobenius() {
        let (_, store) = toy_store();
        for t in (0..store.len()).step_by(53) {
            let h = store.h_mat(t);
            assert!(
                (store.h_norm[t] - h.norm()).abs() < 1e-9 * (1.0 + h.norm()),
                "t={t}"
            );
        }
    }

    #[test]
    fn sum_h_matches_explicit() {
        let (_, store) = toy_store();
        let take: Vec<usize> = (0..store.len()).step_by(11).collect();
        let got = store.sum_h(take.iter().copied());
        let mut want = Mat::zeros(store.d, store.d);
        for &t in &take {
            want.axpy(1.0, &store.h_mat(t));
        }
        assert!(got.sub(&want).max_abs() < 1e-9);
    }

    #[test]
    fn labels_respected() {
        let (ds, store) = toy_store();
        for &(i, j, l) in &store.idx {
            assert_eq!(ds.y[i as usize], ds.y[j as usize]);
            assert_ne!(ds.y[i as usize], ds.y[l as usize]);
        }
    }

    #[test]
    fn empty_store_grows_by_push_to_match_dense() {
        let (_, store) = toy_store();
        let mut grown = TripletStore::empty(store.d);
        assert!(grown.is_empty());
        for t in 0..store.len() {
            grown.push(store.idx[t], store.a.row(t), store.b.row(t), store.h_norm[t]);
        }
        assert_eq!(grown.len(), store.len());
        assert_eq!(grown.idx, store.idx);
        for t in (0..store.len()).step_by(29) {
            assert_eq!(grown.a.row(t), store.a.row(t));
            assert_eq!(grown.b.row(t), store.b.row(t));
            assert_eq!(grown.h_norm[t], store.h_norm[t]);
        }
    }

    #[test]
    fn h_trace_is_norm_difference() {
        // tr(H) = ‖a‖² − ‖b‖²
        let (_, store) = toy_store();
        for t in (0..store.len()).step_by(41) {
            let h = store.h_mat(t);
            let na: f64 = store.a.row(t).iter().map(|x| x * x).sum();
            let nb: f64 = store.b.row(t).iter().map(|x| x * x).sum();
            assert!((h.trace() - (na - nb)).abs() < 1e-10);
        }
    }
}
