//! Streaming triplet mining: the candidate set is *generated lazily* from
//! the k-NN structure instead of materialized up front.
//!
//! The paper's central pain point is that "the number of possible triplets
//! is quite huge even for a small dataset" — the dense [`super::TripletStore`]
//! costs O(|T|·d) memory before screening ever runs. The miner attacks |T|
//! from the other end: it enumerates the paper's §5 candidate universe
//! (for each anchor `x_i`, its `k` nearest same-class neighbors × `k`
//! nearest different-class instances) in **cache-sized batches**, so the
//! only per-candidate state that ever becomes resident is
//!
//! - a row in the admitted store, for candidates the admission screen
//!   could *not* decide (they enter the reduced problem), or
//! - a 24-byte [`PendingCert`] record (id triple + side + expiry λ), for
//!   candidates the RRPB closed forms proved inactive at the current λ —
//!   ~100× smaller than the two `d`-vector difference rows for typical d.
//!
//! Screening therefore bounds *memory*, not just compute: the path driver
//! ([`crate::path::RegPath::run_streamed`]) tests every candidate against
//! the current [`crate::screening::ReferenceFrame`] before a single row is
//! copied, and the workset peaks at the undecided subset instead of |T|.
//!
//! Three [`MiningStrategy`] orders are provided. `Exhaustive` reproduces
//! the exact candidate set (and enumeration order) of
//! [`TripletStore::from_dataset`], so the streamed and materialized
//! pipelines solve the same problem — the safety oracle in
//! `rust/tests/workset_safety.rs` relies on this. The other two reorder
//! (and, under a budget, subsample) the universe for the mining use cases
//! of Poorheravi et al. (arXiv:2009.14244): class-stratified sampling and
//! hard-negative-first mining.

use crate::data::{neighbors, Dataset};
use crate::linalg::Mat;
use crate::runtime::Engine;
use crate::screening::CertSide;
use std::collections::BinaryHeap;

/// Candidate enumeration order (and, combined with
/// [`TripletMiner::with_budget`], subsampling policy).
///
/// Strategy selection, end to end — every strategy enumerates the same
/// candidate universe, only the order (and therefore what a truncating
/// budget keeps) differs:
///
/// ```
/// use triplet_screen::prelude::*;
/// use triplet_screen::triplet::CandidateBatch;
///
/// let mut rng = Pcg64::seed(3);
/// let ds = synthetic::gaussian_mixture("doc", 24, 4, 2, 2.5, &mut rng);
/// let universe = TripletMiner::new(&ds, 2, MiningStrategy::Exhaustive, 16)
///     .total_candidates();
///
/// let mut batch = CandidateBatch::new(ds.d());
/// for strategy in [
///     MiningStrategy::Exhaustive,        // bit-parity with TripletStore
///     MiningStrategy::StratifiedByClass, // classes interleaved
///     MiningStrategy::HardNegativeFirst, // nearest negatives first
/// ] {
///     let mut miner = TripletMiner::new(&ds, 2, strategy, 16);
///     assert_eq!(miner.total_candidates(), universe);
///     let mut seen = 0;
///     while miner.next_into(&mut batch) {
///         seen += batch.len();
///     }
///     assert_eq!(seen, universe);
/// }
///
/// // a budget truncates the enumeration — pair it with a non-exhaustive
/// // strategy so the kept subset is meaningful (stratified/hard-negative)
/// let budgeted = TripletMiner::new(&ds, 2, MiningStrategy::StratifiedByClass, 16)
///     .with_budget(10);
/// assert_eq!(budgeted.total_candidates(), 10.min(universe));
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MiningStrategy {
    /// Every same×diff pair per anchor, anchor-major, same-class-neighbor
    /// major within an anchor — the exact candidate set *and order* of
    /// [`super::TripletStore::from_dataset`].
    Exhaustive,
    /// Anchors interleaved round-robin across classes (class 0's first
    /// anchor, class 1's first anchor, …, then every class's second
    /// anchor, …), so a truncated budget samples every class evenly.
    StratifiedByClass,
    /// Within each anchor, nearest different-class instances (the hard
    /// negatives) are enumerated first, so a truncated budget keeps the
    /// triplets with the smallest negative margin.
    HardNegativeFirst,
}

/// One cache-sized batch of mined candidates: the difference rows and
/// `‖H‖_F` of up to `batch_size` triplets, reusing its buffers across
/// refills. This is the unit the admission screen
/// ([`crate::screening::ScreeningManager::admit_batch`]) consumes.
#[derive(Clone, Debug)]
pub struct CandidateBatch {
    /// original `(i, j, l)` instance indices per candidate
    pub idx: Vec<(u32, u32, u32)>,
    /// rows `x_i − x_l` (different-class differences)
    pub a: Mat,
    /// rows `x_i − x_j` (same-class differences)
    pub b: Mat,
    /// `‖H_t‖_F` per candidate
    pub h_norm: Vec<f64>,
    /// scratch for assembling one difference row
    scratch: Vec<f64>,
}

impl CandidateBatch {
    /// Empty batch for feature dimension `d`.
    pub fn new(d: usize) -> CandidateBatch {
        CandidateBatch {
            idx: Vec::new(),
            a: Mat::zeros(0, d),
            b: Mat::zeros(0, d),
            h_norm: Vec::new(),
            scratch: vec![0.0; d],
        }
    }

    /// Candidates currently in the batch.
    pub fn len(&self) -> usize {
        self.idx.len()
    }

    /// Whether the batch holds no candidates.
    pub fn is_empty(&self) -> bool {
        self.idx.is_empty()
    }

    /// Drop all candidates, keeping the buffers.
    pub fn clear(&mut self) {
        self.idx.clear();
        self.a.truncate_rows(0);
        self.b.truncate_rows(0);
        self.h_norm.clear();
    }

    /// Append candidate `(i, j, l)`: O(d) — two difference rows plus the
    /// exact `‖H‖_F = sqrt(‖a‖⁴ + ‖b‖⁴ − 2(a·b)²)`.
    pub fn push(&mut self, ds: &Dataset, i: usize, j: usize, l: usize) {
        debug_assert_eq!(ds.y[i], ds.y[j], "j must share i's class");
        debug_assert_ne!(ds.y[i], ds.y[l], "l must differ in class");
        let d = ds.d();
        let xi = ds.x.row(i);
        let xl = ds.x.row(l);
        for c in 0..d {
            self.scratch[c] = xi[c] - xl[c];
        }
        self.a.push_row(&self.scratch);
        let xj = ds.x.row(j);
        for c in 0..d {
            self.scratch[c] = xi[c] - xj[c];
        }
        self.b.push_row(&self.scratch);
        let row = self.a.rows() - 1;
        let (ra, rb) = (self.a.row(row), self.b.row(row));
        let (mut na, mut nb, mut ab) = (0.0, 0.0, 0.0);
        for c in 0..d {
            na += ra[c] * ra[c];
            nb += rb[c] * rb[c];
            ab += ra[c] * rb[c];
        }
        // fl. rounding can push the radicand a hair below 0
        self.h_norm.push((na * na + nb * nb - 2.0 * ab * ab).max(0.0).sqrt());
        self.idx.push((i as u32, j as u32, l as u32));
    }
}

/// Lazy batch generator over the k-NN candidate universe; see the module
/// docs. Holds the k-NN neighbor lists (O(n·k) memory) and a cursor —
/// never the candidate rows.
pub struct TripletMiner<'a> {
    ds: &'a Dataset,
    /// per anchor: k nearest same-class neighbor indices
    same: Vec<Vec<usize>>,
    /// per anchor: k nearest different-class indices
    diff: Vec<Vec<usize>>,
    /// anchor visit order (strategy-dependent)
    anchor_order: Vec<usize>,
    strategy: MiningStrategy,
    batch_size: usize,
    /// candidate universe size after the optional budget cap
    total: usize,
    // ---- enumeration cursor ----
    a_pos: usize,
    pair_pos: usize,
    emitted: usize,
}

impl<'a> TripletMiner<'a> {
    /// Build a miner from the dataset's exact k-NN structure (one
    /// [`neighbors`] pass, the same construction
    /// [`super::TripletStore::from_dataset`] uses). `batch_size` caps the
    /// candidates per [`Self::next_into`] refill.
    pub fn new(
        ds: &'a Dataset,
        k: usize,
        strategy: MiningStrategy,
        batch_size: usize,
    ) -> TripletMiner<'a> {
        assert!(batch_size > 0, "batch_size must be positive");
        let (same, diff) = neighbors(ds, k);
        let n = ds.n();
        let anchor_order: Vec<usize> = match strategy {
            MiningStrategy::Exhaustive | MiningStrategy::HardNegativeFirst => (0..n).collect(),
            MiningStrategy::StratifiedByClass => {
                let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); ds.n_classes];
                for i in 0..n {
                    by_class[ds.y[i]].push(i);
                }
                let deepest = by_class.iter().map(|c| c.len()).max().unwrap_or(0);
                let mut order = Vec::with_capacity(n);
                for round in 0..deepest {
                    for class in &by_class {
                        if let Some(&i) = class.get(round) {
                            order.push(i);
                        }
                    }
                }
                order
            }
        };
        let total: usize = (0..n).map(|i| same[i].len() * diff[i].len()).sum();
        TripletMiner {
            ds,
            same,
            diff,
            anchor_order,
            strategy,
            batch_size,
            total,
            a_pos: 0,
            pair_pos: 0,
            emitted: 0,
        }
    }

    /// Cap the candidate universe at `budget` candidates (in enumeration
    /// order — combine with [`MiningStrategy::StratifiedByClass`] or
    /// [`MiningStrategy::HardNegativeFirst`] for meaningful subsampling).
    pub fn with_budget(mut self, budget: usize) -> TripletMiner<'a> {
        self.total = self.total.min(budget);
        self
    }

    /// The backing dataset.
    pub fn dataset(&self) -> &'a Dataset {
        self.ds
    }

    /// Feature dimension.
    pub fn d(&self) -> usize {
        self.ds.d()
    }

    /// Max candidates per [`Self::next_into`] refill.
    pub fn batch_size(&self) -> usize {
        self.batch_size
    }

    /// Size of the candidate universe this miner enumerates (after the
    /// optional budget cap) — the streamed pipeline's |T|.
    pub fn total_candidates(&self) -> usize {
        self.total
    }

    /// Rewind the enumeration cursor to the first candidate.
    pub fn reset(&mut self) {
        self.a_pos = 0;
        self.pair_pos = 0;
        self.emitted = 0;
    }

    /// Same×diff pairs for anchor `i`.
    fn pair_count(&self, i: usize) -> usize {
        self.same[i].len() * self.diff[i].len()
    }

    /// The `p`-th `(j, l)` pair of anchor `i` under the strategy order.
    fn pair_at(&self, i: usize, p: usize) -> (usize, usize) {
        match self.strategy {
            MiningStrategy::HardNegativeFirst => {
                // negative-major: hardest (nearest) l first
                let ns = self.same[i].len();
                (self.same[i][p % ns], self.diff[i][p / ns])
            }
            _ => {
                // same-major: matches TripletStore::from_dataset
                let nd = self.diff[i].len();
                (self.same[i][p / nd], self.diff[i][p % nd])
            }
        }
    }

    /// Refill `out` with the next ≤ `batch_size` candidates. Returns
    /// false (and leaves `out` empty) once the universe is exhausted;
    /// call [`Self::reset`] to start another pass.
    pub fn next_into(&mut self, out: &mut CandidateBatch) -> bool {
        out.clear();
        while out.len() < self.batch_size && self.emitted < self.total {
            while self.a_pos < self.anchor_order.len() {
                let i = self.anchor_order[self.a_pos];
                if self.pair_pos < self.pair_count(i) {
                    break;
                }
                self.a_pos += 1;
                self.pair_pos = 0;
            }
            if self.a_pos >= self.anchor_order.len() {
                break;
            }
            let i = self.anchor_order[self.a_pos];
            let (j, l) = self.pair_at(i, self.pair_pos);
            out.push(self.ds, i, j, l);
            self.pair_pos += 1;
            self.emitted += 1;
        }
        !out.is_empty()
    }

    /// Materialize explicit candidate triples into a batch — the
    /// certificate-expiry re-test path: a row-less [`PendingCert`] whose
    /// proof lapsed gets its rows recomputed from the dataset in O(d).
    pub fn materialize_into(&self, idx: &[(u32, u32, u32)], out: &mut CandidateBatch) {
        out.clear();
        for &(i, j, l) in idx {
            out.push(self.ds, i as usize, j as usize, l as usize);
        }
    }

    /// `Σ_t H_t` over the whole candidate universe, streamed in batches —
    /// the λ_max prerequisite without ever materializing |T| rows. Leaves
    /// the cursor reset.
    pub fn sum_h_streamed(&mut self, engine: &dyn Engine, batch: &mut CandidateBatch) -> Mat {
        self.reset();
        let mut g = Mat::zeros(self.d(), self.d());
        let mut ones: Vec<f64> = Vec::new();
        while self.next_into(batch) {
            ones.resize(batch.len(), 1.0);
            g.axpy(1.0, &engine.wgram(&batch.a, &batch.b, &ones));
        }
        self.reset();
        g
    }

    /// `max_t ⟨H_t, P⟩` over the candidate universe, streamed in batches
    /// (with `P = [Σ H]_+` this is the λ_max numerator — see
    /// [`crate::solver::Problem::lambda_max`]). Leaves the cursor reset.
    pub fn max_margin_streamed(
        &mut self,
        p: &Mat,
        engine: &dyn Engine,
        batch: &mut CandidateBatch,
    ) -> f64 {
        self.reset();
        let mut hq: Vec<f64> = Vec::new();
        let mut best = f64::NEG_INFINITY;
        while self.next_into(batch) {
            hq.resize(batch.len(), 0.0);
            engine.margins(p, &batch.a, &batch.b, &mut hq);
            best = hq.iter().cloned().fold(best, f64::max);
        }
        self.reset();
        best
    }
}

/// One admission-rejected candidate: tracked **row-less** — only its
/// instance triple, the certified side and the λ at which its certificate
/// expires (the RRPB range's lower endpoint). While `λ > expires` the
/// rejection stays proven; once the path crosses `expires` the candidate
/// must be re-tested (and possibly admitted).
///
/// Under the mixed-precision admission tier
/// ([`crate::runtime::PrecisionTier::MixedCertified`]) an f32-certified
/// rejection carries a *conservative* `expires` — the max over the
/// rounding-envelope endpoints, never below the exact value. The proof it
/// records is still exact (both endpoints agreed on the side); the only
/// effect is a possibly earlier re-test, which re-proves or admits under
/// the then-current frame, so streamed admission outcomes match the pure
/// f64 pipeline.
///
/// Note on identity: `PartialEq`/`Ord` compare **only `expires`** — they
/// exist to key the [`PendingPool`] expiry heap, not to identify
/// candidates. Two records for different triplets with equal expiry
/// compare equal; use `idx` for identity.
#[derive(Clone, Copy, Debug)]
pub struct PendingCert {
    /// original `(i, j, l)` instance indices
    pub idx: (u32, u32, u32),
    /// which optimal-set membership the certificate fixed
    pub side: CertSide,
    /// certificate lower endpoint: the proof holds for every λ > expires
    pub expires: f64,
}

impl PartialEq for PendingCert {
    fn eq(&self, other: &Self) -> bool {
        self.expires.total_cmp(&other.expires) == std::cmp::Ordering::Equal
    }
}

impl Eq for PendingCert {}

impl PartialOrd for PendingCert {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for PendingCert {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.expires.total_cmp(&other.expires)
    }
}

/// Expiry queue over [`PendingCert`] records: a max-heap on `expires`, so
/// a monotonically decreasing λ sweep pops exactly the certificates whose
/// proof lapsed — the streaming analogue of the
/// [`crate::screening::ReferenceFrame`] expiry schedule, for candidates
/// that never got rows.
#[derive(Clone, Debug, Default)]
pub struct PendingPool {
    heap: BinaryHeap<PendingCert>,
}

impl PendingPool {
    /// Empty pool.
    pub fn new() -> PendingPool {
        PendingPool::default()
    }

    /// Records currently pending.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Whether no records are pending.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Track a new row-less rejection.
    pub fn push(&mut self, rec: PendingCert) {
        self.heap.push(rec);
    }

    /// Pop every record whose certificate no longer covers `lambda`
    /// (`expires ≥ lambda`) into `out` (cleared first). The caller
    /// re-tests them against the current reference frame.
    pub fn pop_expired(&mut self, lambda: f64, out: &mut Vec<PendingCert>) {
        out.clear();
        while let Some(top) = self.heap.peek() {
            if top.expires >= lambda {
                out.push(self.heap.pop().expect("peeked"));
            } else {
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::runtime::NativeEngine;
    use crate::triplet::TripletStore;
    use crate::util::rng::Pcg64;

    fn fixture() -> (Dataset, TripletStore) {
        let mut rng = Pcg64::seed(31);
        let ds = synthetic::gaussian_mixture("m", 48, 5, 3, 2.5, &mut rng);
        let store = TripletStore::from_dataset(&ds, 3, &mut rng);
        (ds, store)
    }

    #[test]
    fn exhaustive_matches_materialized_store() {
        let (ds, store) = fixture();
        let mut miner = TripletMiner::new(&ds, 3, MiningStrategy::Exhaustive, 64);
        assert_eq!(miner.total_candidates(), store.len());
        let mut batch = CandidateBatch::new(ds.d());
        let mut idx = Vec::new();
        let mut row = 0usize;
        while miner.next_into(&mut batch) {
            assert!(batch.len() <= 64);
            for t in 0..batch.len() {
                assert_eq!(batch.a.row(t), store.a.row(row), "a row {row}");
                assert_eq!(batch.b.row(t), store.b.row(row), "b row {row}");
                assert!((batch.h_norm[t] - store.h_norm[row]).abs() < 1e-12);
                row += 1;
            }
            idx.extend_from_slice(&batch.idx);
        }
        assert_eq!(idx, store.idx, "candidate set/order diverged");
    }

    #[test]
    fn second_pass_after_reset_is_identical() {
        let (ds, _) = fixture();
        let mut miner = TripletMiner::new(&ds, 2, MiningStrategy::Exhaustive, 50);
        let mut batch = CandidateBatch::new(ds.d());
        let mut first = Vec::new();
        while miner.next_into(&mut batch) {
            first.extend_from_slice(&batch.idx);
        }
        // exhausted: further calls yield nothing until reset
        assert!(!miner.next_into(&mut batch));
        miner.reset();
        let mut second = Vec::new();
        while miner.next_into(&mut batch) {
            second.extend_from_slice(&batch.idx);
        }
        assert_eq!(first, second);
    }

    #[test]
    fn strategies_enumerate_the_same_universe() {
        let (ds, store) = fixture();
        for strategy in [
            MiningStrategy::StratifiedByClass,
            MiningStrategy::HardNegativeFirst,
        ] {
            let mut miner = TripletMiner::new(&ds, 3, strategy, 37);
            assert_eq!(miner.total_candidates(), store.len());
            let mut batch = CandidateBatch::new(ds.d());
            let mut seen = Vec::new();
            while miner.next_into(&mut batch) {
                seen.extend_from_slice(&batch.idx);
            }
            let mut want = store.idx.clone();
            seen.sort_unstable();
            want.sort_unstable();
            assert_eq!(seen, want, "{strategy:?} changed the candidate set");
        }
    }

    #[test]
    fn stratified_order_interleaves_classes() {
        let (ds, _) = fixture();
        let miner = TripletMiner::new(&ds, 3, MiningStrategy::StratifiedByClass, 16);
        // the first n_classes anchors must cover n_classes distinct classes
        let mut classes: Vec<usize> = miner.anchor_order[..ds.n_classes]
            .iter()
            .map(|&i| ds.y[i])
            .collect();
        classes.sort_unstable();
        classes.dedup();
        assert_eq!(classes.len(), ds.n_classes);
    }

    #[test]
    fn hard_negative_first_orders_negatives_outermost() {
        let (ds, _) = fixture();
        let mut miner = TripletMiner::new(&ds, 3, MiningStrategy::HardNegativeFirst, 1_000_000);
        let mut batch = CandidateBatch::new(ds.d());
        assert!(miner.next_into(&mut batch));
        // within one anchor, the first |same| candidates all use the
        // anchor's nearest different-class instance
        let anchor = batch.idx[0].0;
        let a = anchor as usize;
        let ns = miner.same[a].len();
        let hardest = miner.diff[a][0] as u32;
        for t in 0..ns {
            assert_eq!(batch.idx[t].0, anchor);
            assert_eq!(batch.idx[t].2, hardest, "candidate {t} not hardest-negative");
        }
    }

    #[test]
    fn budget_truncates_enumeration() {
        let (ds, store) = fixture();
        let mut miner = TripletMiner::new(&ds, 3, MiningStrategy::Exhaustive, 32).with_budget(70);
        assert_eq!(miner.total_candidates(), 70.min(store.len()));
        let mut batch = CandidateBatch::new(ds.d());
        let mut count = 0;
        while miner.next_into(&mut batch) {
            count += batch.len();
        }
        assert_eq!(count, miner.total_candidates());
    }

    #[test]
    fn materialize_into_matches_store_rows() {
        let (ds, store) = fixture();
        let miner = TripletMiner::new(&ds, 3, MiningStrategy::Exhaustive, 8);
        let picks: Vec<(u32, u32, u32)> =
            (0..store.len()).step_by(17).map(|t| store.idx[t]).collect();
        let mut batch = CandidateBatch::new(ds.d());
        miner.materialize_into(&picks, &mut batch);
        assert_eq!(batch.len(), picks.len());
        for (k, t) in (0..store.len()).step_by(17).enumerate() {
            assert_eq!(batch.a.row(k), store.a.row(t));
            assert_eq!(batch.b.row(k), store.b.row(t));
            assert!((batch.h_norm[k] - store.h_norm[t]).abs() < 1e-12);
        }
    }

    #[test]
    fn streamed_sum_h_and_max_margin_match_store() {
        let (ds, store) = fixture();
        let engine = NativeEngine::new(2);
        let mut miner = TripletMiner::new(&ds, 3, MiningStrategy::Exhaustive, 53);
        let mut batch = CandidateBatch::new(ds.d());
        let streamed = miner.sum_h_streamed(&engine, &mut batch);
        let ones = vec![1.0; store.len()];
        let dense = engine.wgram(&store.a, &store.b, &ones);
        let scale = 1.0 + dense.max_abs();
        assert!(
            streamed.sub(&dense).max_abs() < 1e-9 * scale,
            "streamed ΣH diverged"
        );

        let p = crate::linalg::psd_split(&dense).plus;
        let got = miner.max_margin_streamed(&p, &engine, &mut batch);
        let mut hq = vec![0.0; store.len()];
        engine.margins(&p, &store.a, &store.b, &mut hq);
        let want = hq.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert!((got - want).abs() < 1e-9 * (1.0 + want.abs()));
    }

    #[test]
    fn pending_pool_pops_in_expiry_order() {
        let mut pool = PendingPool::new();
        for (e, side) in [
            (0.5, CertSide::L),
            (0.9, CertSide::R),
            (0.1, CertSide::R),
            (0.7, CertSide::L),
        ] {
            pool.push(PendingCert {
                idx: (0, 1, 2),
                side,
                expires: e,
            });
        }
        let mut out = Vec::new();
        pool.pop_expired(0.8, &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].expires, 0.9);
        pool.pop_expired(0.3, &mut out);
        let exp: Vec<f64> = out.iter().map(|r| r.expires).collect();
        assert_eq!(exp, vec![0.7, 0.5]);
        assert_eq!(pool.len(), 1);
        // λ equal to the endpoint: contains() is strict, so it expires too
        pool.pop_expired(0.1, &mut out);
        assert_eq!(out.len(), 1);
        assert!(pool.is_empty());
    }

    #[test]
    fn anchors_without_pairs_are_skipped() {
        // single-class dataset: no different-class instances, so the
        // candidate universe is empty and the miner terminates cleanly
        let x = Mat::from_rows(4, 2, vec![0.0, 0.0, 1.0, 0.0, 0.0, 1.0, 1.0, 1.0]);
        let ds = Dataset::new("mono", x, vec![0, 0, 0, 0]);
        let mut miner = TripletMiner::new(&ds, 2, MiningStrategy::Exhaustive, 8);
        assert_eq!(miner.total_candidates(), 0);
        let mut batch = CandidateBatch::new(ds.d());
        assert!(!miner.next_into(&mut batch));
        assert!(batch.is_empty());
    }
}
