//! Compacted active-triplet workset: the screening pipeline's arena.
//!
//! Screening is monotone within one λ solve — a triplet that enters L̂ or
//! R̂ never comes back — so the hot path must never touch a retired
//! triplet again. The workset keeps every per-triplet quantity the rules
//! and kernels consume (`a`/`b` difference rows, `‖H‖_F`, the optional
//! reference margins `⟨H, M₀⟩` for RPB/RRPB) **contiguous** in row order,
//! and retires a triplet with an O(d) swap-remove instead of the old
//! O(|T|·d) full-store rebuild:
//!
//! ```text
//!   retire(id):  r = row_of[id]; move last row into r; truncate.
//! ```
//!
//! The `ids` (row → triplet id) and `row_of` (id → row) maps stay exact
//! inverses throughout, which `assert_consistent` verifies and the
//! property tests in `util::quickcheck` exercise under arbitrary retire
//! sequences. Engines receive `a()`/`b()` directly — a margins pass costs
//! O(|active|·d²), never O(|T|·d²).

use crate::linalg::Mat;
use crate::triplet::TripletStore;

/// Sentinel marking a retired id in the `row_of` map.
const RETIRED: u32 = u32::MAX;

/// Swap-remove arena over the active subset of a [`TripletStore`].
#[derive(Clone, Debug)]
pub struct ActiveWorkset {
    /// row → triplet id
    ids: Vec<usize>,
    /// triplet id → row (RETIRED once retired)
    row_of: Vec<u32>,
    /// compacted difference rows `x_i − x_l`
    a: Mat,
    /// compacted difference rows `x_i − x_j`
    b: Mat,
    /// compacted `‖H_t‖_F`
    h_norm: Vec<f64>,
    /// compacted `⟨H_t, M₀⟩` for the current screening reference, kept in
    /// lockstep with retires, tagged with the reference identity it was
    /// gathered from (None until installed)
    ref_margin: Option<(u64, Vec<f64>)>,
}

impl ActiveWorkset {
    /// Fresh workset with every triplet of `store` active.
    pub fn full(store: &TripletStore) -> ActiveWorkset {
        let n = store.len();
        assert!(n < RETIRED as usize, "triplet count exceeds u32 id space");
        ActiveWorkset {
            ids: (0..n).collect(),
            row_of: (0..n as u32).collect(),
            a: store.a.clone(),
            b: store.b.clone(),
            h_norm: store.h_norm.clone(),
            ref_margin: None,
        }
    }

    /// Active rows currently in the workset.
    pub fn len(&self) -> usize {
        self.ids.len()
    }

    /// Whether every triplet has been retired.
    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    /// Active triplet ids in row order (compaction order, not id order).
    pub fn ids(&self) -> &[usize] {
        &self.ids
    }

    /// Compacted `x_i − x_l` difference rows.
    pub fn a(&self) -> &Mat {
        &self.a
    }

    /// Compacted `x_i − x_j` difference rows.
    pub fn b(&self) -> &Mat {
        &self.b
    }

    /// Compacted `‖H_t‖_F` lane (row-aligned).
    pub fn h_norm(&self) -> &[f64] {
        &self.h_norm
    }

    /// Current row of `id`, or None once retired.
    pub fn row_of(&self, id: usize) -> Option<usize> {
        match self.row_of[id] {
            RETIRED => None,
            r => Some(r as usize),
        }
    }

    /// Whether `id` still has a workset row.
    pub fn is_active(&self, id: usize) -> bool {
        self.row_of[id] != RETIRED
    }

    /// Permanently remove `id` from the workset (O(d) swap-remove across
    /// every lane). Returns false when `id` was already retired.
    pub fn retire(&mut self, id: usize) -> bool {
        let row = match self.row_of[id] {
            RETIRED => return false,
            r => r as usize,
        };
        let last = self.ids.len() - 1;
        let moved = self.ids[last];
        let _ = self.ids.swap_remove(row);
        if row != last {
            self.row_of[moved] = row as u32;
        }
        self.row_of[id] = RETIRED;
        self.a.swap_remove_row(row);
        self.b.swap_remove_row(row);
        let _ = self.h_norm.swap_remove(row);
        if let Some((_, rm)) = self.ref_margin.as_mut() {
            let _ = rm.swap_remove(row);
        }
        true
    }

    /// Re-admit a retired `id` (O(d) row append from the backing store) —
    /// the persistent-problem primitive: a triplet screened at a previous
    /// λ whose certificate does not cover the new λ must rejoin the
    /// reduced problem. Appends to the end of every lane; the
    /// reference-margin lane is dropped (the path driver re-installs it
    /// for the new λ *after* retargeting, so a stale or misaligned lane
    /// can never feed a screening rule). Returns false when `id` is
    /// already active.
    pub fn revive(&mut self, id: usize, store: &TripletStore) -> bool {
        if self.row_of[id] != RETIRED {
            return false;
        }
        let row = self.ids.len();
        self.ids.push(id);
        self.row_of[id] = row as u32;
        self.a.push_row(store.a.row(id));
        self.b.push_row(store.b.row(id));
        self.h_norm.push(store.h_norm[id]);
        self.ref_margin = None;
        true
    }

    /// Grow the id space by `n_new` ids, all initially retired — the
    /// streaming-admission primitive. The path driver then [`Self::revive`]s
    /// each new id, appending its rows from the (grown) backing store, so
    /// admitted candidates enter the reduced problem through the same
    /// machinery as certificate-expired revives.
    pub fn extend_ids(&mut self, n_new: usize) {
        let total = self.row_of.len() + n_new;
        assert!(total < RETIRED as usize, "triplet count exceeds u32 id space");
        self.row_of.resize(total, RETIRED);
    }

    /// Install the reference-margin lane from an id-indexed full vector
    /// (`full[t] = ⟨H_t, M₀⟩` for every triplet of the store), tagged with
    /// the identity of the reference frame it was gathered from (the path
    /// driver threads it in via `Problem::install_frame`, using
    /// `ReferenceFrame::tag`). The lane is gathered into row order and
    /// then compacted in lockstep by `retire`; readers must present a
    /// matching tag, so a lane from a stale reference can never feed a
    /// screening rule.
    pub fn install_ref_margins(&mut self, full: &[f64], tag: u64) {
        debug_assert_eq!(full.len(), self.row_of.len());
        self.ref_margin = Some((tag, self.ids.iter().map(|&id| full[id]).collect()));
    }

    /// Row-aligned `⟨H_t, M₀⟩` lane, only when installed for exactly the
    /// reference identified by `tag`.
    pub fn ref_margins(&self, tag: u64) -> Option<&[f64]> {
        match &self.ref_margin {
            Some((t, rm)) if *t == tag => Some(rm),
            _ => None,
        }
    }

    /// The lane regardless of tag (consistency checks only).
    pub fn ref_margins_any(&self) -> Option<&[f64]> {
        self.ref_margin.as_ref().map(|(_, rm)| rm.as_slice())
    }

    /// Drop the reference-margin lane (stale-reference hygiene).
    pub fn clear_ref_margins(&mut self) {
        self.ref_margin = None;
    }

    /// Exhaustive invariant check against the backing store (tests; O(|T|·d)).
    pub fn assert_consistent(&self, store: &TripletStore) {
        assert_eq!(self.row_of.len(), store.len());
        assert_eq!(self.a.rows(), self.ids.len());
        assert_eq!(self.b.rows(), self.ids.len());
        assert_eq!(self.h_norm.len(), self.ids.len());
        if let Some((_, rm)) = &self.ref_margin {
            assert_eq!(rm.len(), self.ids.len());
        }
        let mut seen = vec![false; store.len()];
        for (row, &id) in self.ids.iter().enumerate() {
            assert!(!seen[id], "id {id} appears in two rows");
            seen[id] = true;
            assert_eq!(self.row_of[id], row as u32, "row_of out of sync for id {id}");
            assert_eq!(self.a.row(row), store.a.row(id), "a lane diverged for id {id}");
            assert_eq!(self.b.row(row), store.b.row(id), "b lane diverged for id {id}");
            assert_eq!(self.h_norm[row], store.h_norm[id]);
        }
        for id in 0..store.len() {
            if !seen[id] {
                assert_eq!(self.row_of[id], RETIRED, "retired id {id} still mapped");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::util::rng::Pcg64;

    fn store() -> TripletStore {
        let mut rng = Pcg64::seed(11);
        let ds = synthetic::gaussian_mixture("w", 30, 4, 2, 2.5, &mut rng);
        TripletStore::from_dataset(&ds, 2, &mut rng)
    }

    #[test]
    fn full_workset_is_identity_mapping() {
        let st = store();
        let ws = ActiveWorkset::full(&st);
        assert_eq!(ws.len(), st.len());
        for id in 0..st.len() {
            assert_eq!(ws.row_of(id), Some(id));
        }
        ws.assert_consistent(&st);
    }

    #[test]
    fn retire_swaps_last_row_in() {
        let st = store();
        let mut ws = ActiveWorkset::full(&st);
        let n = ws.len();
        assert!(ws.retire(0));
        assert_eq!(ws.len(), n - 1);
        assert_eq!(ws.ids()[0], n - 1); // last id moved into the hole
        assert_eq!(ws.row_of(n - 1), Some(0));
        assert_eq!(ws.row_of(0), None);
        assert!(!ws.is_active(0));
        // double retire is a no-op
        assert!(!ws.retire(0));
        assert_eq!(ws.len(), n - 1);
        ws.assert_consistent(&st);
    }

    #[test]
    fn ref_margin_lane_tracks_retires() {
        let st = store();
        let mut ws = ActiveWorkset::full(&st);
        let full: Vec<f64> = (0..st.len()).map(|t| t as f64 * 1.5).collect();
        ws.install_ref_margins(&full, 42);
        for id in [3usize, 0, 7, st.len() - 1, 5] {
            ws.retire(id);
        }
        let rm = ws.ref_margins(42).unwrap();
        for (row, &id) in ws.ids().iter().enumerate() {
            assert_eq!(rm[row], id as f64 * 1.5, "lane misaligned at row {row}");
        }
        // a mismatched tag must hide the lane entirely
        assert!(ws.ref_margins(43).is_none());
        ws.assert_consistent(&st);
    }

    #[test]
    fn retire_everything() {
        let st = store();
        let mut ws = ActiveWorkset::full(&st);
        for id in 0..st.len() {
            assert!(ws.retire(id));
        }
        assert!(ws.is_empty());
        ws.assert_consistent(&st);
    }

    #[test]
    fn revive_restores_lanes_and_mapping() {
        let st = store();
        let mut ws = ActiveWorkset::full(&st);
        let n = st.len();
        for id in [0usize, 5, 9, n - 1] {
            assert!(ws.retire(id));
        }
        assert_eq!(ws.len(), n - 4);
        // revive two of them; rows land at the end, lanes copied back
        assert!(ws.revive(5, &st));
        assert!(ws.revive(n - 1, &st));
        assert_eq!(ws.len(), n - 2);
        assert!(ws.is_active(5));
        assert_eq!(ws.row_of(5), Some(n - 4));
        assert_eq!(ws.a().row(n - 4), st.a.row(5));
        assert_eq!(ws.b().row(n - 3), st.b.row(n - 1));
        // revive on an active id is a no-op
        assert!(!ws.revive(5, &st));
        assert_eq!(ws.len(), n - 2);
        ws.assert_consistent(&st);
        // retire a revived id again: the full cycle stays consistent
        assert!(ws.retire(5));
        ws.assert_consistent(&st);
    }

    #[test]
    fn extend_ids_then_revive_ingests_new_rows() {
        // streaming admission: the store grows, the workset's id space is
        // extended (new ids retired) and each new id enters via revive
        let st = store();
        let keep = st.len() / 2;
        let mut small = TripletStore::empty(st.d);
        for t in 0..keep {
            small.push(st.idx[t], st.a.row(t), st.b.row(t), st.h_norm[t]);
        }
        let mut ws = ActiveWorkset::full(&small);
        ws.retire(1);
        // grow the store by two more triplets
        small.push(st.idx[keep], st.a.row(keep), st.b.row(keep), st.h_norm[keep]);
        small.push(st.idx[keep + 1], st.a.row(keep + 1), st.b.row(keep + 1), st.h_norm[keep + 1]);
        ws.extend_ids(2);
        assert!(!ws.is_active(keep));
        assert!(!ws.is_active(keep + 1));
        assert!(ws.revive(keep, &small));
        assert!(ws.revive(keep + 1, &small));
        assert_eq!(ws.len(), small.len() - 1); // id 1 still retired
        assert_eq!(ws.a().row(ws.row_of(keep).unwrap()), small.a.row(keep));
        ws.assert_consistent(&small);
    }

    #[test]
    fn revive_drops_stale_ref_margin_lane() {
        let st = store();
        let mut ws = ActiveWorkset::full(&st);
        let lane: Vec<f64> = (0..st.len()).map(|t| t as f64).collect();
        ws.install_ref_margins(&lane, 1);
        ws.retire(3);
        assert!(ws.ref_margins(1).is_some());
        ws.revive(3, &st);
        assert!(
            ws.ref_margins_any().is_none(),
            "misaligned lane survived a revive"
        );
        ws.assert_consistent(&st);
    }
}
