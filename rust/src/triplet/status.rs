//! Per-triplet screening status bookkeeping.
//!
//! Screening fixes a triplet's optimal dual variable (paper eq. (4)):
//! `ScreenedL` ⇒ α* = 1 (loss pinned to the linear part), `ScreenedR` ⇒
//! α* = 0 (loss pinned to the zero part). `Active` triplets remain in the
//! reduced problem.

/// Screening status of one triplet.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TripletStatus {
    /// Still in the reduced optimization problem.
    Active,
    /// Proven `(i,j,l) ∈ L*` (α* = 1).
    ScreenedL,
    /// Proven `(i,j,l) ∈ R*` (α* = 0).
    ScreenedR,
}

/// Status vector with cached counts and a compaction of active indices.
#[derive(Clone, Debug)]
pub struct StatusVec {
    status: Vec<TripletStatus>,
    n_l: usize,
    n_r: usize,
    /// bumped on every transition; consumers cache against it
    version: u64,
}

impl StatusVec {
    /// All-Active status vector over `n` triplets.
    pub fn new(n: usize) -> StatusVec {
        StatusVec {
            status: vec![TripletStatus::Active; n],
            n_l: 0,
            n_r: 0,
            version: 0,
        }
    }

    /// Total triplets tracked.
    pub fn len(&self) -> usize {
        self.status.len()
    }

    /// Whether no triplets are tracked.
    pub fn is_empty(&self) -> bool {
        self.status.is_empty()
    }

    /// Status of triplet `t`.
    #[inline]
    pub fn get(&self, t: usize) -> TripletStatus {
        self.status[t]
    }

    /// Triplets currently fixed into L̂.
    pub fn n_screened_l(&self) -> usize {
        self.n_l
    }

    /// Triplets currently fixed into R̂.
    pub fn n_screened_r(&self) -> usize {
        self.n_r
    }

    /// Triplets still in the reduced problem.
    pub fn n_active(&self) -> usize {
        self.len() - self.n_l - self.n_r
    }

    /// Fraction of triplets screened (the paper's "screening rate").
    pub fn screening_rate(&self) -> f64 {
        if self.is_empty() {
            0.0
        } else {
            (self.n_l + self.n_r) as f64 / self.len() as f64
        }
    }

    /// Monotone change counter (bumped on every transition).
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Transition a triplet to ScreenedL. Screening decisions are
    /// monotone within one λ solve; re-screening an already-screened
    /// triplet is a no-op, and L→R / R→L transitions panic (they would
    /// mean an unsafe rule fired).
    pub fn screen_l(&mut self, t: usize) {
        match self.status[t] {
            TripletStatus::Active => {
                self.status[t] = TripletStatus::ScreenedL;
                self.n_l += 1;
                self.version += 1;
            }
            TripletStatus::ScreenedL => {}
            TripletStatus::ScreenedR => panic!("triplet {t}: R -> L transition (unsafe rule)"),
        }
    }

    /// Transition a triplet to ScreenedR (see [`Self::screen_l`] for the
    /// monotonicity rules).
    pub fn screen_r(&mut self, t: usize) {
        match self.status[t] {
            TripletStatus::Active => {
                self.status[t] = TripletStatus::ScreenedR;
                self.n_r += 1;
                self.version += 1;
            }
            TripletStatus::ScreenedR => {}
            TripletStatus::ScreenedL => panic!("triplet {t}: L -> R transition (unsafe rule)"),
        }
    }

    /// Return a screened triplet to Active. The persistent-problem
    /// retarget path: a decision certified at a previous λ does not carry
    /// to the new λ unless a certificate covers it, so the triplet
    /// re-enters the reduced problem. No-op on active triplets.
    pub fn reactivate(&mut self, t: usize) {
        match self.status[t] {
            TripletStatus::Active => {}
            TripletStatus::ScreenedL => {
                self.status[t] = TripletStatus::Active;
                self.n_l -= 1;
                self.version += 1;
            }
            TripletStatus::ScreenedR => {
                self.status[t] = TripletStatus::Active;
                self.n_r -= 1;
                self.version += 1;
            }
        }
    }

    /// Append `n_new` Active entries — the streaming-admission primitive:
    /// the id space grows as candidates are admitted to the backing
    /// store; existing decisions are untouched.
    pub fn extend_active(&mut self, n_new: usize) {
        if n_new == 0 {
            return;
        }
        let total = self.status.len() + n_new;
        self.status.resize(total, TripletStatus::Active);
        self.version += 1;
    }

    /// Reset every triplet to Active (new λ without warm screening carry).
    pub fn reset(&mut self) {
        self.status.fill(TripletStatus::Active);
        self.n_l = 0;
        self.n_r = 0;
        self.version += 1;
    }

    /// Indices of active triplets (compaction order = triplet order).
    pub fn active_indices(&self) -> Vec<usize> {
        (0..self.len())
            .filter(|&t| self.status[t] == TripletStatus::Active)
            .collect()
    }

    /// Indices currently screened into L.
    pub fn screened_l_indices(&self) -> Vec<usize> {
        (0..self.len())
            .filter(|&t| self.status[t] == TripletStatus::ScreenedL)
            .collect()
    }

    /// Iterate statuses in id order.
    pub fn iter(&self) -> impl Iterator<Item = TripletStatus> + '_ {
        self.status.iter().copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_track_transitions() {
        let mut s = StatusVec::new(5);
        assert_eq!(s.n_active(), 5);
        s.screen_l(0);
        s.screen_r(3);
        s.screen_r(4);
        assert_eq!(s.n_screened_l(), 1);
        assert_eq!(s.n_screened_r(), 2);
        assert_eq!(s.n_active(), 2);
        assert!((s.screening_rate() - 0.6).abs() < 1e-12);
    }

    #[test]
    fn rescreening_is_noop() {
        let mut s = StatusVec::new(2);
        s.screen_l(0);
        let v = s.version();
        s.screen_l(0);
        assert_eq!(s.version(), v);
        assert_eq!(s.n_screened_l(), 1);
    }

    #[test]
    #[should_panic(expected = "unsafe rule")]
    fn conflicting_transition_panics() {
        let mut s = StatusVec::new(1);
        s.screen_l(0);
        s.screen_r(0);
    }

    #[test]
    fn active_indices_order() {
        let mut s = StatusVec::new(6);
        s.screen_r(1);
        s.screen_l(4);
        assert_eq!(s.active_indices(), vec![0, 2, 3, 5]);
        assert_eq!(s.screened_l_indices(), vec![4]);
    }

    #[test]
    fn reset_restores_active() {
        let mut s = StatusVec::new(3);
        s.screen_r(0);
        s.reset();
        assert_eq!(s.n_active(), 3);
    }

    #[test]
    fn extend_active_grows_without_touching_decisions() {
        let mut s = StatusVec::new(3);
        s.screen_l(0);
        s.screen_r(2);
        let v = s.version();
        s.extend_active(2);
        assert_eq!(s.len(), 5);
        assert_eq!(s.n_active(), 3);
        assert_eq!(s.get(0), TripletStatus::ScreenedL);
        assert_eq!(s.get(3), TripletStatus::Active);
        assert_eq!(s.get(4), TripletStatus::Active);
        assert!(s.version() > v);
        // zero-growth is a no-op (version unchanged)
        let v2 = s.version();
        s.extend_active(0);
        assert_eq!(s.version(), v2);
    }

    #[test]
    fn reactivate_reverses_both_sides() {
        let mut s = StatusVec::new(4);
        s.screen_l(0);
        s.screen_r(1);
        s.reactivate(0);
        s.reactivate(1);
        assert_eq!(s.n_active(), 4);
        assert_eq!(s.get(0), TripletStatus::Active);
        assert_eq!(s.get(1), TripletStatus::Active);
        // no-op on an active triplet, and re-screening works after
        let v = s.version();
        s.reactivate(2);
        assert_eq!(s.version(), v);
        s.screen_r(0); // L→R across a reactivation is legal (new λ)
        assert_eq!(s.n_screened_r(), 1);
    }
}
