//! Triplet set construction and bookkeeping.

mod status;
mod store;
mod workset;

pub use status::{StatusVec, TripletStatus};
pub use store::TripletStore;
pub use workset::ActiveWorkset;
