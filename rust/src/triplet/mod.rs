//! Triplet set construction and bookkeeping.
//!
//! Two ways to obtain a triplet set:
//!
//! - [`TripletStore::from_dataset`] materializes the full k-NN candidate
//!   universe up front (the classic pipeline);
//! - [`TripletMiner`] enumerates the same universe **lazily** in
//!   cache-sized [`CandidateBatch`]es so the path driver can screen each
//!   candidate *at admission time* and only copy the undecided ones into
//!   a growable store — see `miner` module docs and
//!   [`crate::path::TripletSource`].

mod miner;
mod status;
mod store;
mod workset;

pub use miner::{CandidateBatch, MiningStrategy, PendingCert, PendingPool, TripletMiner};
pub use status::{StatusVec, TripletStatus};
pub use store::TripletStore;
pub use workset::ActiveWorkset;
