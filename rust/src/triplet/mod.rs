//! Triplet set construction and bookkeeping.

mod status;
mod store;

pub use status::{StatusVec, TripletStatus};
pub use store::TripletStore;
