//! Exact k-nearest-neighbor queries.
//!
//! Two uses: (1) triplet generation — for each anchor `x_i`, the k nearest
//! *same-class* neighbors `x_j` and k nearest *different-class* neighbors
//! `x_l` (the paper follows Shen et al. [21]); (2) kNN classification under
//! a learned Mahalanobis metric for the examples.

use super::Dataset;
use crate::linalg::Mat;
use crate::util::parallel;

/// Squared Euclidean distance between rows.
#[inline]
fn dist_sq(a: &[f64], b: &[f64]) -> f64 {
    let mut acc = 0.0;
    for (x, y) in a.iter().zip(b) {
        let d = x - y;
        acc += d * d;
    }
    acc
}

/// Squared Mahalanobis distance `(a-b)^T M (a-b)`.
#[inline]
fn mahal_sq(a: &[f64], b: &[f64], m: &Mat, scratch: &mut [f64]) -> f64 {
    for (k, (x, y)) in a.iter().zip(b).enumerate() {
        scratch[k] = x - y;
    }
    m.quad_form(scratch)
}

/// For each anchor i: the `k` nearest same-class indices and the `k`
/// nearest different-class indices (Euclidean, exact, parallel).
/// `k = usize::MAX` means "all" (the paper's ∞ entries in Table 3).
pub fn neighbors(ds: &Dataset, k: usize) -> (Vec<Vec<usize>>, Vec<Vec<usize>>) {
    let n = ds.n();
    let workers = parallel::default_threads();
    let results = parallel::par_ranges(n, workers, |range| {
        let mut same_all = Vec::with_capacity(range.len());
        let mut diff_all = Vec::with_capacity(range.len());
        for i in range {
            let xi = ds.x.row(i);
            let mut same: Vec<(f64, usize)> = Vec::new();
            let mut diff: Vec<(f64, usize)> = Vec::new();
            for j in 0..n {
                if j == i {
                    continue;
                }
                let d = dist_sq(xi, ds.x.row(j));
                if ds.y[j] == ds.y[i] {
                    same.push((d, j));
                } else {
                    diff.push((d, j));
                }
            }
            let take = |mut v: Vec<(f64, usize)>, k: usize| -> Vec<usize> {
                let kk = k.min(v.len());
                if kk == 0 {
                    return vec![];
                }
                let pivot = kk - 1;
                v.select_nth_unstable_by(pivot, |a, b| a.0.partial_cmp(&b.0).unwrap());
                v.truncate(kk);
                v.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
                v.into_iter().map(|(_, j)| j).collect()
            };
            same_all.push(take(same, k));
            diff_all.push(take(diff, k));
        }
        (same_all, diff_all)
    });
    let mut same = Vec::with_capacity(n);
    let mut diff = Vec::with_capacity(n);
    for (s, d) in results {
        same.extend(s);
        diff.extend(d);
    }
    (same, diff)
}

/// kNN classification of `test` against `train` under metric `M`
/// (`M = I` recovers Euclidean kNN). Returns predicted labels.
pub fn knn_classify(train: &Dataset, test: &Dataset, k: usize, m: &Mat) -> Vec<usize> {
    assert_eq!(train.d(), test.d());
    let d = train.d();
    let workers = parallel::default_threads();
    let chunks = parallel::par_ranges(test.n(), workers, |range| {
        let mut preds = Vec::with_capacity(range.len());
        let mut scratch = vec![0.0; d];
        for t in range {
            let xt = test.x.row(t);
            let mut near: Vec<(f64, usize)> = (0..train.n())
                .map(|i| (mahal_sq(xt, train.x.row(i), m, &mut scratch), i))
                .collect();
            let kk = k.min(near.len());
            near.select_nth_unstable_by(kk - 1, |a, b| a.0.partial_cmp(&b.0).unwrap());
            near.truncate(kk);
            // majority vote (ties -> smallest label, deterministic)
            let mut votes = vec![0usize; train.n_classes];
            for &(_, i) in &near {
                votes[train.y[i]] += 1;
            }
            let best = votes
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.cmp(b.1).then(b.0.cmp(&a.0)))
                .unwrap()
                .0;
            preds.push(best);
        }
        preds
    });
    chunks.into_iter().flatten().collect()
}

/// Classification accuracy helper.
pub fn accuracy(pred: &[usize], truth: &[usize]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    let hit = pred.iter().zip(truth).filter(|(p, t)| p == t).count();
    hit as f64 / pred.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::util::rng::Pcg64;

    fn grid_dataset() -> Dataset {
        // 1-D points 0,1,2 (class 0) and 10,11,12 (class 1)
        let x = Mat::from_rows(6, 1, vec![0.0, 1.0, 2.0, 10.0, 11.0, 12.0]);
        Dataset::new("grid", x, vec![0, 0, 0, 1, 1, 1])
    }

    #[test]
    fn neighbors_pick_closest_same_and_diff() {
        let ds = grid_dataset();
        let (same, diff) = neighbors(&ds, 1);
        assert_eq!(same[0], vec![1]); // 0's nearest same-class is 1
        assert_eq!(diff[0], vec![3]); // 0's nearest diff-class is 10
        assert_eq!(same[5], vec![4]);
        assert_eq!(diff[5], vec![2]);
    }

    #[test]
    fn neighbors_k_larger_than_class() {
        let ds = grid_dataset();
        let (same, diff) = neighbors(&ds, 100);
        assert_eq!(same[0].len(), 2); // only 2 same-class others
        assert_eq!(diff[0].len(), 3);
    }

    #[test]
    fn neighbors_infinite_k() {
        let ds = grid_dataset();
        let (same, _) = neighbors(&ds, usize::MAX);
        assert_eq!(same[0].len(), 2);
    }

    #[test]
    fn neighbors_sorted_by_distance() {
        let ds = grid_dataset();
        let (same, _) = neighbors(&ds, 2);
        assert_eq!(same[0], vec![1, 2]);
    }

    #[test]
    fn singleton_class_has_no_same_neighbors() {
        // one lone instance of class 1: its same-class list must be
        // empty (not panic), and it still has different-class neighbors —
        // the miner then simply generates zero triplets for that anchor
        let x = Mat::from_rows(4, 1, vec![0.0, 1.0, 2.0, 10.0]);
        let ds = Dataset::new("singleton", x, vec![0, 0, 0, 1]);
        let (same, diff) = neighbors(&ds, 3);
        assert!(same[3].is_empty());
        assert_eq!(diff[3].len(), 3);
        assert_eq!(same[0].len(), 2);
        assert_eq!(diff[0], vec![3]);
    }

    #[test]
    fn single_class_dataset_has_no_diff_neighbors() {
        // all instances share one class: every diff list is empty and
        // the triplet universe is empty — neighbors must stay well-defined
        let x = Mat::from_rows(3, 2, vec![0.0, 0.0, 1.0, 0.0, 0.0, 1.0]);
        let ds = Dataset::new("mono", x, vec![0, 0, 0]);
        let (same, diff) = neighbors(&ds, 5);
        for i in 0..3 {
            assert!(diff[i].is_empty(), "anchor {i} found a diff neighbor");
            assert_eq!(same[i].len(), 2);
        }
    }

    #[test]
    fn empty_class_id_is_tolerated() {
        // labels {0, 2}: class 1 exists in the id space but has no
        // instances — neighbor queries and class counts must not panic
        let x = Mat::from_rows(4, 1, vec![0.0, 1.0, 5.0, 6.0]);
        let ds = Dataset::new("gap", x, vec![0, 0, 2, 2]);
        assert_eq!(ds.n_classes, 3);
        assert_eq!(ds.class_counts(), vec![2, 0, 2]);
        let (same, diff) = neighbors(&ds, 2);
        assert_eq!(same[0], vec![1]);
        assert_eq!(diff[0], vec![2, 3]);
        // classification against a vote table spanning the empty class
        let pred = knn_classify(&ds, &ds, 1, &Mat::identity(1));
        assert_eq!(pred, ds.y);
    }

    #[test]
    fn duplicate_points_tie_safely() {
        // exact duplicates produce zero distances and ties: selection
        // must not panic, lists have the right lengths, and every
        // returned neighbor has the required class relation
        let x = Mat::from_rows(6, 1, vec![1.0, 1.0, 1.0, 4.0, 4.0, 4.0]);
        let ds = Dataset::new("dups", x, vec![0, 0, 0, 1, 1, 1]);
        let (same, diff) = neighbors(&ds, 2);
        for i in 0..6 {
            assert_eq!(same[i].len(), 2, "anchor {i}");
            assert_eq!(diff[i].len(), 2, "anchor {i}");
            for &j in &same[i] {
                assert_ne!(j, i);
                assert_eq!(ds.y[j], ds.y[i]);
            }
            for &l in &diff[i] {
                assert_ne!(ds.y[l], ds.y[i]);
            }
        }
        // duplicates of the anchor are its nearest same-class neighbors
        let mut s0 = same[0].clone();
        s0.sort_unstable();
        assert_eq!(s0, vec![1, 2]);
    }

    #[test]
    fn k_larger_than_any_class_truncates_everywhere() {
        // k beyond both class sizes: lists clamp to what exists, the
        // miner's pair counts follow suit
        let x = Mat::from_rows(5, 1, vec![0.0, 1.0, 2.0, 9.0, 10.0]);
        let ds = Dataset::new("small", x, vec![0, 0, 0, 1, 1]);
        let (same, diff) = neighbors(&ds, 50);
        assert_eq!(same[0].len(), 2);
        assert_eq!(diff[0].len(), 2);
        assert_eq!(same[4].len(), 1);
        assert_eq!(diff[4].len(), 3);
    }

    #[test]
    fn knn_classifies_separated_blobs() {
        let mut rng = Pcg64::seed(4);
        let ds = synthetic::gaussian_mixture("g", 400, 6, 2, 4.0, &mut rng);
        let (train, test) = ds.split(0.8, &mut rng);
        let pred = knn_classify(&train, &test, 5, &Mat::identity(6));
        let acc = accuracy(&pred, &test.y);
        assert!(acc > 0.9, "euclidean kNN on separated blobs: acc={acc}");
    }

    #[test]
    fn metric_changes_predictions() {
        // metric that kills the informative dims should hurt accuracy
        let mut rng = Pcg64::seed(5);
        let ds = synthetic::xor_blobs(400, 4, &mut rng);
        let (train, test) = ds.split(0.8, &mut rng);
        let good = knn_classify(&train, &test, 5, &Mat::identity(4));
        let mut bad_m = Mat::identity(4);
        bad_m[(0, 0)] = 0.0;
        bad_m[(1, 1)] = 0.0; // only noise dims remain
        let bad = knn_classify(&train, &test, 5, &bad_m);
        let (ga, ba) = (accuracy(&good, &test.y), accuracy(&bad, &test.y));
        assert!(ga > ba + 0.2, "good={ga} bad={ba}");
    }

    #[test]
    fn accuracy_helper() {
        assert_eq!(accuracy(&[1, 2, 3], &[1, 2, 0]), 2.0 / 3.0);
    }
}
