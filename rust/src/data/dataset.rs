//! Labeled dataset container + preprocessing.

use crate::linalg::Mat;
use crate::util::rng::Pcg64;

/// A labeled dataset: `x` is `n × d` (rows are instances), labels are
/// contiguous class ids `0..n_classes`.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// dataset name (reporting)
    pub name: String,
    /// `n × d` feature matrix, rows are instances
    pub x: Mat,
    /// contiguous class ids, aligned with the rows of `x`
    pub y: Vec<usize>,
    /// number of class ids (`max(y) + 1`)
    pub n_classes: usize,
}

impl Dataset {
    /// Wrap features + labels (labels must be contiguous class ids).
    pub fn new(name: impl Into<String>, x: Mat, y: Vec<usize>) -> Dataset {
        assert_eq!(x.rows(), y.len(), "feature/label length mismatch");
        let n_classes = y.iter().copied().max().map_or(0, |m| m + 1);
        Dataset {
            name: name.into(),
            x,
            y,
            n_classes,
        }
    }

    /// Number of instances.
    pub fn n(&self) -> usize {
        self.x.rows()
    }

    /// Feature dimension.
    pub fn d(&self) -> usize {
        self.x.cols()
    }

    /// Per-class instance counts.
    pub fn class_counts(&self) -> Vec<usize> {
        let mut c = vec![0; self.n_classes];
        for &yi in &self.y {
            c[yi] += 1;
        }
        c
    }

    /// Standardize features to zero mean / unit variance in place
    /// (constant features are left centered). Returns (mean, std).
    pub fn standardize(&mut self) -> (Vec<f64>, Vec<f64>) {
        let (n, d) = (self.n(), self.d());
        let mut mean = vec![0.0; d];
        for i in 0..n {
            for (j, v) in self.x.row(i).iter().enumerate() {
                mean[j] += v;
            }
        }
        for m in &mut mean {
            *m /= n as f64;
        }
        let mut var = vec![0.0; d];
        for i in 0..n {
            for (j, v) in self.x.row(i).iter().enumerate() {
                var[j] += (v - mean[j]).powi(2);
            }
        }
        let std: Vec<f64> = var
            .iter()
            .map(|v| {
                let s = (v / n as f64).sqrt();
                if s > 1e-12 {
                    s
                } else {
                    1.0
                }
            })
            .collect();
        for i in 0..n {
            let row = self.x.row_mut(i);
            for j in 0..d {
                row[j] = (row[j] - mean[j]) / std[j];
            }
        }
        (mean, std)
    }

    /// Random subsample of a fraction of instances (the paper's protocol:
    /// "randomly selected 90% of the instances ... 5 times").
    pub fn subsample(&self, frac: f64, rng: &mut Pcg64) -> Dataset {
        let keep = ((self.n() as f64 * frac).round() as usize).clamp(1, self.n());
        let mut idx: Vec<usize> = (0..self.n()).collect();
        rng.shuffle(&mut idx);
        idx.truncate(keep);
        idx.sort_unstable();
        self.take(&idx)
    }

    /// Dataset restricted to the given row indices.
    pub fn take(&self, idx: &[usize]) -> Dataset {
        let x = self.x.select_rows(idx);
        let y = idx.iter().map(|&i| self.y[i]).collect();
        Dataset::new(self.name.clone(), x, y)
    }

    /// Split into (train, test) with the given train fraction.
    pub fn split(&self, train_frac: f64, rng: &mut Pcg64) -> (Dataset, Dataset) {
        let mut idx: Vec<usize> = (0..self.n()).collect();
        rng.shuffle(&mut idx);
        let cut = ((self.n() as f64 * train_frac).round() as usize).clamp(1, self.n() - 1);
        let (tr, te) = idx.split_at(cut);
        (self.take(tr), self.take(te))
    }

    /// PCA-reduce to `k` dimensions (the paper reduces rcv1 by PCA) using
    /// our own eigensolver on the covariance matrix.
    pub fn pca(&self, k: usize) -> Dataset {
        let (n, d) = (self.n(), self.d());
        let k = k.min(d);
        // covariance
        let mut mean = vec![0.0; d];
        for i in 0..n {
            for (j, v) in self.x.row(i).iter().enumerate() {
                mean[j] += v;
            }
        }
        for m in &mut mean {
            *m /= n as f64;
        }
        let mut cov = Mat::zeros(d, d);
        for i in 0..n {
            let row = self.x.row(i);
            for a in 0..d {
                let xa = row[a] - mean[a];
                for b in a..d {
                    cov[(a, b)] += xa * (row[b] - mean[b]);
                }
            }
        }
        for a in 0..d {
            for b in a..d {
                let v = cov[(a, b)] / n as f64;
                cov[(a, b)] = v;
                cov[(b, a)] = v;
            }
        }
        let e = crate::linalg::sym_eig(&cov);
        // top-k eigenvectors = last k columns (ascending order)
        let mut x = Mat::zeros(n, k);
        for i in 0..n {
            let row = self.x.row(i);
            for c in 0..k {
                let col = d - 1 - c;
                let mut acc = 0.0;
                for j in 0..d {
                    acc += (row[j] - mean[j]) * e.vectors[(j, col)];
                }
                x[(i, c)] = acc;
            }
        }
        Dataset::new(format!("{}-pca{k}", self.name), x, self.y.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        let x = Mat::from_rows(4, 2, vec![0.0, 0.0, 1.0, 0.0, 0.0, 2.0, 1.0, 2.0]);
        Dataset::new("toy", x, vec![0, 0, 1, 1])
    }

    #[test]
    fn counts_and_shape() {
        let d = toy();
        assert_eq!(d.n(), 4);
        assert_eq!(d.d(), 2);
        assert_eq!(d.n_classes, 2);
        assert_eq!(d.class_counts(), vec![2, 2]);
    }

    #[test]
    fn standardize_zero_mean_unit_var() {
        let mut d = toy();
        d.standardize();
        for j in 0..d.d() {
            let mean: f64 = (0..d.n()).map(|i| d.x[(i, j)]).sum::<f64>() / d.n() as f64;
            let var: f64 =
                (0..d.n()).map(|i| d.x[(i, j)].powi(2)).sum::<f64>() / d.n() as f64 - mean * mean;
            assert!(mean.abs() < 1e-12);
            assert!((var - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn subsample_and_take() {
        let d = toy();
        let mut rng = Pcg64::seed(1);
        let s = d.subsample(0.5, &mut rng);
        assert_eq!(s.n(), 2);
        assert_eq!(s.d(), 2);
    }

    #[test]
    fn split_partitions() {
        let d = toy();
        let mut rng = Pcg64::seed(2);
        let (tr, te) = d.split(0.75, &mut rng);
        assert_eq!(tr.n() + te.n(), 4);
        assert_eq!(tr.n(), 3);
    }

    #[test]
    fn pca_reduces_and_decorrelates() {
        // strongly correlated 2d data -> first PC captures nearly all var
        let mut rng = Pcg64::seed(3);
        let n = 200;
        let mut x = Mat::zeros(n, 3);
        for i in 0..n {
            let t = rng.normal();
            x[(i, 0)] = t;
            x[(i, 1)] = 2.0 * t + 0.01 * rng.normal();
            x[(i, 2)] = 0.01 * rng.normal();
        }
        let d = Dataset::new("corr", x, vec![0; n]);
        let r = d.pca(1);
        assert_eq!(r.d(), 1);
        let var: f64 = (0..n).map(|i| r.x[(i, 0)].powi(2)).sum::<f64>() / n as f64;
        assert!(var > 4.5, "first PC variance should be ~5, got {var}");
    }
}
