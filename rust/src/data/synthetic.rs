//! Synthetic dataset generators + the paper-analogue registry.
//!
//! The paper evaluates on LIBSVM/Keras datasets which are not shipped in
//! this offline environment. Screening behaviour depends on the *margin
//! distribution geometry* (how triplets populate the loss's zero/central/
//! linear regions along the λ path), which a Gaussian-mixture generator
//! with controlled class overlap reproduces; the registry below matches
//! each paper dataset's (d, #classes, k) and scales n to laptop budgets.
//! Any real LIBSVM file drops in through [`crate::data::read_libsvm`].

use super::Dataset;
use crate::linalg::Mat;
use crate::util::rng::Pcg64;

/// Gaussian mixture: `n_classes` anisotropic Gaussian blobs in `d` dims.
///
/// `sep` scales the between-class mean distance relative to the
/// within-class spread: ~1.5 gives heavily overlapping classes (many
/// triplets in the linear part), ~4 nearly separated ones (most triplets
/// screenable into R*).
pub fn gaussian_mixture(
    name: &str,
    n: usize,
    d: usize,
    n_classes: usize,
    sep: f64,
    rng: &mut Pcg64,
) -> Dataset {
    assert!(n_classes >= 2 && n >= n_classes);
    // class means: random directions scaled so E‖mu_a − mu_b‖ ≈ sep
    // Per-coordinate mean scale sep/√2 makes the between/within distance
    // ratio dimension-independent: E‖mu_a−mu_b‖² = d·sep² while the
    // within-class spread is ≈ d, so overlap is controlled by sep alone.
    let mean_scale = sep / (2.0f64).sqrt();
    let means: Vec<Vec<f64>> = (0..n_classes)
        .map(|_| (0..d).map(|_| rng.normal() * mean_scale).collect())
        .collect();
    // anisotropic within-class mixing: x = mu + (I + 0.4 R_c) z
    let mixers: Vec<Mat> = (0..n_classes)
        .map(|_| {
            let mut m = Mat::identity(d);
            for i in 0..d {
                for j in 0..d {
                    m[(i, j)] += 0.4 * rng.normal() / (d as f64).sqrt();
                }
            }
            m
        })
        .collect();

    let mut x = Mat::zeros(n, d);
    let mut y = Vec::with_capacity(n);
    let mut z = vec![0.0; d];
    let mut xz = vec![0.0; d];
    for i in 0..n {
        let c = i % n_classes; // balanced classes
        for v in &mut z {
            *v = rng.normal();
        }
        mixers[c].matvec(&z, &mut xz);
        let row = x.row_mut(i);
        for j in 0..d {
            row[j] = means[c][j] + xz[j];
        }
    }
    for i in 0..n {
        y.push(i % n_classes);
    }
    let mut ds = Dataset::new(name, x, y);
    ds.standardize();
    ds
}

/// Two concentric rings (classic non-linear metric-learning toy, 2-D).
pub fn two_rings(n: usize, noise: f64, rng: &mut Pcg64) -> Dataset {
    let mut x = Mat::zeros(n, 2);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let c = i % 2;
        let r = if c == 0 { 1.0 } else { 2.2 };
        let th = rng.uniform() * std::f64::consts::TAU;
        x[(i, 0)] = r * th.cos() + noise * rng.normal();
        x[(i, 1)] = r * th.sin() + noise * rng.normal();
        y.push(c);
    }
    Dataset::new("two-rings", x, y)
}

/// XOR-style blobs: classes that single features cannot separate — a
/// workload where learning a full (non-diagonal) M visibly helps kNN.
pub fn xor_blobs(n: usize, d: usize, rng: &mut Pcg64) -> Dataset {
    assert!(d >= 2);
    let mut x = Mat::zeros(n, d);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let quadrant = i % 4;
        let (sx, sy) = match quadrant {
            0 => (1.0, 1.0),
            1 => (-1.0, -1.0),
            2 => (1.0, -1.0),
            _ => (-1.0, 1.0),
        };
        let row = x.row_mut(i);
        row[0] = 2.0 * sx + 0.6 * rng.normal();
        row[1] = 2.0 * sy + 0.6 * rng.normal();
        for j in 2..d {
            row[j] = rng.normal(); // noise dims the metric should suppress
        }
        y.push(usize::from(quadrant >= 2));
    }
    Dataset::new("xor-blobs", x, y)
}

/// Registry entry for a paper dataset analogue.
#[derive(Clone, Copy, Debug)]
pub struct AnalogueSpec {
    /// registry key (the paper's dataset name, `-small` variants included)
    pub name: &'static str,
    /// feature dimension
    pub d: usize,
    /// instance count
    pub n: usize,
    /// class count
    pub n_classes: usize,
    /// neighborhood size used for triplet generation in the paper (Table 1/3);
    /// `usize::MAX` encodes the paper's "∞" (all pairs).
    pub k: usize,
    /// class-overlap control for the generator.
    pub sep: f64,
}

/// Paper Table 1 + Table 3 analogues. `n` is scaled down from the paper
/// where needed to keep the full experiment suite in CI budgets; the
/// `*-small` variants scale further for tests.
pub const ANALOGUES: &[AnalogueSpec] = &[
    AnalogueSpec { name: "iris", d: 4, n: 150, n_classes: 3, k: usize::MAX, sep: 2.6 },
    AnalogueSpec { name: "wine", d: 13, n: 178, n_classes: 3, k: usize::MAX, sep: 2.8 },
    AnalogueSpec { name: "segment", d: 19, n: 1200, n_classes: 7, k: 20, sep: 3.0 },
    AnalogueSpec { name: "satimage", d: 36, n: 1400, n_classes: 6, k: 15, sep: 2.6 },
    AnalogueSpec { name: "phishing", d: 68, n: 2200, n_classes: 2, k: 7, sep: 2.2 },
    AnalogueSpec { name: "sensit", d: 100, n: 2400, n_classes: 3, k: 3, sep: 2.2 },
    AnalogueSpec { name: "a9a", d: 16, n: 2600, n_classes: 2, k: 5, sep: 2.0 },
    AnalogueSpec { name: "mnist", d: 32, n: 3000, n_classes: 10, k: 5, sep: 3.0 },
    AnalogueSpec { name: "cifar10", d: 200, n: 1400, n_classes: 10, k: 2, sep: 2.4 },
    AnalogueSpec { name: "rcv1", d: 200, n: 1600, n_classes: 12, k: 3, sep: 2.6 },
    // Table 5 (diagonal-M, high dimensional)
    AnalogueSpec { name: "usps", d: 256, n: 900, n_classes: 10, k: 10, sep: 3.0 },
    AnalogueSpec { name: "madelon", d: 500, n: 500, n_classes: 2, k: 20, sep: 1.8 },
    AnalogueSpec { name: "colon-cancer", d: 2000, n: 62, n_classes: 2, k: usize::MAX, sep: 2.4 },
    AnalogueSpec { name: "gisette", d: 1000, n: 400, n_classes: 2, k: 15, sep: 2.0 },
];

/// Look up the spec for a paper dataset analogue.
pub fn spec(name: &str) -> Option<&'static AnalogueSpec> {
    let base = name.strip_suffix("-small").unwrap_or(name);
    ANALOGUES.iter().find(|s| s.name == base)
}

/// Generate a paper dataset analogue by name. A `-small` suffix divides n
/// by 6 (min 60) for fast tests, keeping d/classes/k.
pub fn analogue(name: &str, rng: &mut Pcg64) -> Dataset {
    let s = spec(name).unwrap_or_else(|| {
        panic!(
            "unknown analogue {name:?}; known: {:?}",
            ANALOGUES.iter().map(|s| s.name).collect::<Vec<_>>()
        )
    });
    let small = name.ends_with("-small");
    let n = if small {
        (s.n / 6).max(60).max(s.n_classes * 8)
    } else {
        s.n
    };
    let mut ds = gaussian_mixture(name, n, s.d, s.n_classes, s.sep, rng);
    ds.name = name.to_string();
    ds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mixture_shape_and_balance() {
        let mut rng = Pcg64::seed(1);
        let ds = gaussian_mixture("g", 300, 10, 3, 2.5, &mut rng);
        assert_eq!(ds.n(), 300);
        assert_eq!(ds.d(), 10);
        assert_eq!(ds.n_classes, 3);
        let counts = ds.class_counts();
        assert_eq!(counts, vec![100, 100, 100]);
    }

    #[test]
    fn mixture_classes_are_separated_in_mean() {
        let mut rng = Pcg64::seed(2);
        let ds = gaussian_mixture("g", 600, 8, 2, 3.5, &mut rng);
        // distance between class means should exceed within-class std
        let d = ds.d();
        let mut m0 = vec![0.0; d];
        let mut m1 = vec![0.0; d];
        let (mut n0, mut n1) = (0.0, 0.0);
        for i in 0..ds.n() {
            let row = ds.x.row(i);
            if ds.y[i] == 0 {
                n0 += 1.0;
                for j in 0..d {
                    m0[j] += row[j];
                }
            } else {
                n1 += 1.0;
                for j in 0..d {
                    m1[j] += row[j];
                }
            }
        }
        let dist: f64 = (0..d)
            .map(|j| (m0[j] / n0 - m1[j] / n1).powi(2))
            .sum::<f64>()
            .sqrt();
        assert!(dist > 1.0, "class means too close: {dist}");
    }

    #[test]
    fn registry_covers_all_paper_datasets() {
        for name in [
            "iris", "wine", "segment", "satimage", "phishing", "sensit", "a9a", "mnist",
            "cifar10", "rcv1", "usps", "madelon", "colon-cancer", "gisette",
        ] {
            let s = spec(name).expect(name);
            assert!(s.d > 0 && s.n_classes >= 2);
        }
    }

    #[test]
    fn analogue_small_variant() {
        let mut rng = Pcg64::seed(3);
        let ds = analogue("segment-small", &mut rng);
        assert_eq!(ds.d(), 19);
        assert_eq!(ds.n_classes, 7);
        assert!(ds.n() < 400);
    }

    #[test]
    #[should_panic(expected = "unknown analogue")]
    fn unknown_analogue_panics() {
        let mut rng = Pcg64::seed(4);
        analogue("nope", &mut rng);
    }

    #[test]
    fn rings_and_xor() {
        let mut rng = Pcg64::seed(5);
        let r = two_rings(100, 0.05, &mut rng);
        assert_eq!(r.d(), 2);
        assert_eq!(r.n_classes, 2);
        let x = xor_blobs(120, 6, &mut rng);
        assert_eq!(x.d(), 6);
        assert_eq!(x.class_counts().iter().sum::<usize>(), 120);
    }

    #[test]
    fn deterministic_generation() {
        let a = analogue("wine", &mut Pcg64::seed(9));
        let b = analogue("wine", &mut Pcg64::seed(9));
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }
}
