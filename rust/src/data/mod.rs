//! Datasets: container, LIBSVM-format parser, synthetic generators
//! (analogues of the paper's benchmark suite), preprocessing and exact kNN.

mod dataset;
mod knn;
mod libsvm;
pub mod synthetic;

pub use dataset::Dataset;
pub use knn::{accuracy, knn_classify, neighbors};
pub use libsvm::{parse_libsvm, read_libsvm};
