//! LIBSVM sparse-text format parser.
//!
//! The paper's datasets ship in LIBSVM format (`label idx:val idx:val ...`,
//! 1-based indices). We parse into dense rows (metric learning needs dense
//! features anyway) and remap arbitrary labels (including negatives and
//! floats like `+1`/`-1`) to contiguous class ids by order of first
//! appearance.

use super::Dataset;
use crate::linalg::Mat;
use std::collections::HashMap;

/// Parse LIBSVM text. `d_hint` fixes the dimensionality (0 = infer from
/// the max index seen).
pub fn parse_libsvm(text: &str, d_hint: usize) -> Result<Dataset, String> {
    let mut rows: Vec<Vec<(usize, f64)>> = Vec::new();
    let mut raw_labels: Vec<String> = Vec::new();
    let mut max_idx = 0usize;

    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let label = parts
            .next()
            .ok_or_else(|| format!("line {}: empty", lineno + 1))?;
        let mut feats = Vec::new();
        for tok in parts {
            if tok.starts_with('#') {
                break; // trailing comment
            }
            let (i, v) = tok
                .split_once(':')
                .ok_or_else(|| format!("line {}: bad token {tok:?}", lineno + 1))?;
            let idx: usize = i
                .parse()
                .map_err(|_| format!("line {}: bad index {i:?}", lineno + 1))?;
            if idx == 0 {
                return Err(format!("line {}: LIBSVM indices are 1-based", lineno + 1));
            }
            let val: f64 = v
                .parse()
                .map_err(|_| format!("line {}: bad value {v:?}", lineno + 1))?;
            max_idx = max_idx.max(idx);
            feats.push((idx - 1, val));
        }
        raw_labels.push(label.to_string());
        rows.push(feats);
    }

    let d = if d_hint > 0 { d_hint } else { max_idx };
    if max_idx > d {
        return Err(format!("feature index {max_idx} exceeds d_hint {d}"));
    }

    // map labels to contiguous ids by first appearance
    let mut label_ids: HashMap<String, usize> = HashMap::new();
    let mut y = Vec::with_capacity(raw_labels.len());
    for l in raw_labels {
        let next = label_ids.len();
        let id = *label_ids.entry(l).or_insert(next);
        y.push(id);
    }

    let n = rows.len();
    let mut x = Mat::zeros(n, d);
    for (i, feats) in rows.into_iter().enumerate() {
        for (j, v) in feats {
            x[(i, j)] = v;
        }
    }
    Ok(Dataset::new("libsvm", x, y))
}

/// Read a LIBSVM file from disk.
pub fn read_libsvm(path: &str, d_hint: usize) -> Result<Dataset, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    let mut ds = parse_libsvm(&text, d_hint)?;
    ds.name = std::path::Path::new(path)
        .file_stem()
        .and_then(|s| s.to_str())
        .unwrap_or("libsvm")
        .to_string();
    Ok(ds)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic_file() {
        let text = "+1 1:0.5 3:1.5\n-1 2:2.0\n+1 1:1.0 2:-1.0 3:0.0\n";
        let ds = parse_libsvm(text, 0).unwrap();
        assert_eq!(ds.n(), 3);
        assert_eq!(ds.d(), 3);
        assert_eq!(ds.y, vec![0, 1, 0]);
        assert_eq!(ds.x[(0, 0)], 0.5);
        assert_eq!(ds.x[(0, 1)], 0.0);
        assert_eq!(ds.x[(0, 2)], 1.5);
        assert_eq!(ds.x[(1, 1)], 2.0);
    }

    #[test]
    fn multiclass_labels_remapped_in_order() {
        let text = "7 1:1\n3 1:2\n7 1:3\n5 1:4\n";
        let ds = parse_libsvm(text, 0).unwrap();
        assert_eq!(ds.y, vec![0, 1, 0, 2]);
        assert_eq!(ds.n_classes, 3);
    }

    #[test]
    fn skips_blank_and_comment_lines() {
        let text = "\n# header\n1 1:1.0\n\n2 1:2.0\n";
        let ds = parse_libsvm(text, 0).unwrap();
        assert_eq!(ds.n(), 2);
    }

    #[test]
    fn d_hint_pads_dimensions() {
        let ds = parse_libsvm("1 1:1\n", 5).unwrap();
        assert_eq!(ds.d(), 5);
    }

    #[test]
    fn rejects_zero_index_and_bad_tokens() {
        assert!(parse_libsvm("1 0:1\n", 0).is_err());
        assert!(parse_libsvm("1 a:b\n", 0).is_err());
        assert!(parse_libsvm("1 nocolon\n", 0).is_err());
        assert!(parse_libsvm("1 3:1\n", 2).is_err()); // exceeds hint
    }

    #[test]
    fn scientific_notation_values() {
        let ds = parse_libsvm("1 1:1e-3 2:-2.5E2\n", 0).unwrap();
        assert_eq!(ds.x[(0, 0)], 1e-3);
        assert_eq!(ds.x[(0, 1)], -250.0);
    }

    #[test]
    fn empty_input_yields_empty_dataset() {
        // nothing to parse (including comment-only text) must produce a
        // well-formed empty dataset, not an error or a panic downstream
        for text in ["", "\n\n", "# only a comment\n"] {
            let ds = parse_libsvm(text, 0).unwrap();
            assert_eq!(ds.n(), 0, "text {text:?}");
            assert_eq!(ds.d(), 0);
            assert_eq!(ds.n_classes, 0);
            assert!(ds.class_counts().is_empty());
        }
        // a d_hint still fixes the width of the (empty) matrix
        let ds = parse_libsvm("", 7).unwrap();
        assert_eq!(ds.d(), 7);
    }

    #[test]
    fn label_only_lines_are_zero_rows() {
        // a line with a label and no features is legal LIBSVM: an
        // all-zeros instance (common for sparse negatives)
        let ds = parse_libsvm("1 1:2.0\n2\n1\n", 0).unwrap();
        assert_eq!(ds.n(), 3);
        assert_eq!(ds.d(), 1);
        assert_eq!(ds.x[(1, 0)], 0.0);
        assert_eq!(ds.x[(2, 0)], 0.0);
        assert_eq!(ds.y, vec![0, 1, 0]);
    }

    #[test]
    fn duplicate_feature_index_last_wins() {
        // repeated index within one line: the later assignment lands
        // last in the dense fill, so it wins deterministically
        let ds = parse_libsvm("1 2:5.0 2:7.0\n", 0).unwrap();
        assert_eq!(ds.d(), 2);
        assert_eq!(ds.x[(0, 1)], 7.0);
    }

    #[test]
    fn tabs_and_mixed_whitespace_tokenize() {
        let ds = parse_libsvm("1\t1:1.0\t 2:2.0\n-1  1:3.0\n", 0).unwrap();
        assert_eq!(ds.n(), 2);
        assert_eq!(ds.x[(0, 1)], 2.0);
        assert_eq!(ds.x[(1, 0)], 3.0);
        assert_eq!(ds.y, vec![0, 1]);
    }

    #[test]
    fn single_class_file_parses_with_one_class() {
        // every label identical: one class id, no panic in n_classes —
        // the miner then produces an empty candidate universe
        let ds = parse_libsvm("3 1:1\n3 1:2\n3 1:3\n", 0).unwrap();
        assert_eq!(ds.n_classes, 1);
        assert_eq!(ds.y, vec![0, 0, 0]);
    }
}
